"""Warm-start sweep smoke: the lifecycle sweep driver as a CI gate.

Runs a 2-arm Delta sweep through `repro.lifecycle.sweep` — the driver
behind DiSMEC's Fig. 5 frontier — on a small synthetic problem and emits
one `BENCH_lifecycle.json` record per arm plus a summary row. Three
assertions run live in --smoke (wired into tools/verify.sh through
`benchmarks.run --smoke`):

  * **fixed point**: the arm whose spec equals the base's reproduces the
    base checkpoint bit-for-bit from a warm start — the correctness
    anchor that says `fit(init_from=)` re-derives a converged model
    instead of drifting;
  * **size monotonicity**: a coarser Delta never yields more nonzeros
    (Fig. 5's x-axis moves the right way);
  * **policy**: `max_precision_under_size_mb` with a budget strictly
    between the two arm sizes must pick the arm that fits it — the
    declarative winner rule actually binds.

The full (non-smoke) run uses the paper-like shapes of fig5_delta_sweep's
regime but still finishes in minutes; the frontier itself (many Deltas,
real datasets) stays in fig5_delta_sweep — this module gates the DRIVER.
"""

from __future__ import annotations

import tempfile
import time

import jax.numpy as jnp
import numpy as np

from benchmarks._common import emit_json, print_table
from repro.data.xmc import make_xmc_dataset
from repro.lifecycle import sweep
from repro.specs import ScheduleSpec, SolverSpec, SweepPolicy
from repro.xmc_api import XMCSpec

OUT_JSON = "BENCH_lifecycle.json"
SCHEMA = 1

SMOKE = dict(n_train=200, n_test=64, n_features=512, n_labels=64,
             label_batch=32, block_shape=(16, 16))
FULL = dict(n_train=800, n_test=256, n_features=2048, n_labels=256,
            label_batch=128, block_shape=(32, 128))
HI_DELTA = 0.3


def main(smoke: bool = False):
    cfg = SMOKE if smoke else FULL
    data = make_xmc_dataset(n_train=cfg["n_train"], n_test=cfg["n_test"],
                            n_features=cfg["n_features"],
                            n_labels=cfg["n_labels"], seed=0)
    base_spec = XMCSpec(
        solver=SolverSpec(C=1.0, delta=0.01, eps=1e-2),
        schedule=ScheduleSpec(label_batch=cfg["label_batch"],
                              block_shape=cfg["block_shape"]))
    X, Y = jnp.asarray(data.X_train), jnp.asarray(data.Y_train)
    holdout = (np.asarray(data.X_test, np.float32), np.asarray(data.Y_test))

    with tempfile.TemporaryDirectory() as root:
        t0 = time.monotonic()
        report = sweep(X, Y, base_spec,
                       {"same": {}, "hi": {"delta": HI_DELTA}},
                       root, workers=2, holdout=holdout,
                       policy=SweepPolicy(kind="max_precision", metric="P@5"))
        wall = time.monotonic() - t0

    base, same, hi = report.arms
    for arm in report.arms:
        emit_json(OUT_JSON, {"bench": "lifecycle_sweep", "schema": SCHEMA,
                             "smoke": smoke, "mode": "arm",
                             "winner": report.winner, **arm.row()})
    emit_json(OUT_JSON, {"bench": "lifecycle_sweep", "schema": SCHEMA,
                         "smoke": smoke, "mode": "summary", "wall_s": wall,
                         **report.to_dict()})
    print_table(
        f"warm-start Delta sweep (L={cfg['n_labels']}, winner="
        f"{report.winner} by {report.policy.kind})",
        [{"arm": a.name, "delta": a.delta, "nnz": a.nnz,
          "model_mb": a.model_mb, "int8_mb": a.int8_mb,
          "P@5": a.metrics.get("P@5"), "fixed_pt": a.fixed_point,
          "train_s": a.train_s}
         for a in report.arms],
        ["arm", "delta", "nnz", "model_mb", "int8_mb", "P@5", "fixed_pt",
         "train_s"])

    # Sweep-driver acceptance gates, live in CI (tools/verify.sh --smoke).
    assert same.fixed_point is True, \
        ("unchanged-spec arm is NOT bit-identical to its warm-start source "
         "— the warm-start path drifted, every sweep number is suspect")
    assert same.nnz == base.nnz
    assert hi.nnz <= same.nnz and hi.model_mb <= same.model_mb, \
        (f"Delta {HI_DELTA} produced MORE nonzeros than Delta "
         f"{base_spec.solver.delta}: {hi.nnz} > {same.nnz}")
    budget = (hi.model_mb + same.model_mb) / 2
    pick = SweepPolicy(kind="max_precision_under_size_mb", metric="P@5",
                       size_mb=budget).select(report.arms)
    assert pick.model_mb <= budget, \
        (f"size-budget policy picked {pick.name} at {pick.model_mb:.3f}MB "
         f"over the {budget:.3f}MB budget despite a feasible arm")

    print(f"\nwrote {OUT_JSON}")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    main(smoke=args.smoke)
