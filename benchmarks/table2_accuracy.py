"""Paper Table 2 + Figure 3: P@k and nDCG@k, DiSMEC vs all baselines.

Scaled-down name-alikes of the paper's datasets (data/xmc.py docstring).
The claim being reproduced: on power-law datasets DiSMEC (OvR + squared
hinge + Delta-pruning) beats embedding-based (SLEEC/LEML) and tree-based
(FastXML) methods; on high-ALpP data (delicious-like) embeddings close the
gap (paper §4.1).

Usage: PYTHONPATH=src python -m benchmarks.table2_accuracy [--datasets a,b]
"""

from __future__ import annotations

import argparse

import jax.numpy as jnp

from benchmarks._common import DATASETS, fit_dismec, load, print_table, score
from repro.baselines.fastxml import train_fastxml
from repro.baselines.l1_svm import train_l1_svm
from repro.baselines.leml import train_leml
from repro.baselines.pd_sparse import train_pd_sparse
from repro.baselines.sleec import train_sleec
from repro.core.prediction import evaluate


def run(dataset_names=DATASETS) -> list[dict]:
    rows = []
    for name in dataset_names:
        data = load(name)
        Xtr, Ytr = jnp.asarray(data.X_train), jnp.asarray(data.Y_train)
        Xte, Yte = jnp.asarray(data.X_test), jnp.asarray(data.Y_test)

        model, t_fit = fit_dismec(data)
        rows.append({"dataset": name, "method": "DiSMEC",
                     **score(model.W, data), "train_s": t_fit})

        for mname, fn in [("SLEEC", train_sleec), ("LEML", train_leml),
                          ("FastXML", train_fastxml),
                          ("PD-Sparse", train_pd_sparse),
                          ("L1-SVM", train_l1_svm)]:
            import time
            t0 = time.time()
            m = fn(Xtr, Ytr)
            out = m.predict_topk(Xte, 5)
            idx = out[1] if isinstance(out, (tuple, list)) else out
            rows.append({"dataset": name, "method": mname,
                         **evaluate(Yte, idx), "train_s": time.time() - t0})
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--datasets", default=",".join(DATASETS))
    args = ap.parse_args()
    rows = run(args.datasets.split(","))
    print_table("Table 2: Precision@k / nDCG@k (scaled-down datasets)", rows,
                ["dataset", "method", "P@1", "P@3", "P@5",
                 "nDCG@3", "nDCG@5", "train_s"])
    # Paper's qualitative check: DiSMEC wins on power-law datasets.
    by_ds = {}
    for r in rows:
        by_ds.setdefault(r["dataset"], []).append(r)
    print("\nHeadline check (paper §4.1):")
    for ds, rs in by_ds.items():
        best = max(rs, key=lambda r: r["P@1"])
        dis = next(r for r in rs if r["method"] == "DiSMEC")
        flag = "OK " if best["method"] == "DiSMEC" or \
            dis["P@1"] >= best["P@1"] - 0.02 else "MISS"
        print(f"  [{flag}] {ds}: best={best['method']} "
              f"({best['P@1']:.3f}), DiSMEC={dis['P@1']:.3f}")
    return rows


if __name__ == "__main__":
    main()
