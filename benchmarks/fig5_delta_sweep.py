"""Paper Figure 5: impact of Delta on P@k and model size (WikiLSHTC-325K
in the paper; wikilshtc325k_like here).

Claim: Delta=0.01 preserves accuracy while shrinking the model by orders of
magnitude; much larger Delta degrades P@k monotonically.

Usage: PYTHONPATH=src python -m benchmarks.fig5_delta_sweep
"""

from __future__ import annotations

import jax.numpy as jnp

from benchmarks._common import fit_dismec, load, print_table, score
from repro.core.pruning import nnz, prune

DELTAS = (0.0, 0.005, 0.01, 0.05, 0.1, 0.2, 0.4)


def run(dataset: str = "wikilshtc325k_like") -> list[dict]:
    data = load(dataset)
    model, _ = fit_dismec(data, delta=0.0)     # train once, sweep pruning
    rows = []
    for d in DELTAS:
        W = prune(model.W, d)
        ev = score(W, data)
        rows.append({"delta": d, "nnz": int(nnz(W)),
                     "size_mb": float(nnz(W)) * 8 / 1e6,
                     "density": float(nnz(W)) / W.size, **ev})
    return rows


def main():
    rows = run()
    print_table("Fig 5: Delta sweep (model size vs accuracy)", rows,
                ["delta", "nnz", "size_mb", "density", "P@1", "P@3", "P@5"])
    # Claims:
    r0 = next(r for r in rows if r["delta"] == 0.0)
    r001 = next(r for r in rows if r["delta"] == 0.01)
    rbig = rows[-1]
    print("\nClaims:")
    print(f"  Delta=0.01 lossless: dP@1 = {r001['P@1'] - r0['P@1']:+.4f} "
          f"(paper: ~0), size x{r0['nnz'] / max(r001['nnz'], 1):.1f} smaller")
    print(f"  Large Delta degrades: P@1 {r001['P@1']:.3f} -> {rbig['P@1']:.3f}"
          f" at Delta={rbig['delta']}")
    return rows


if __name__ == "__main__":
    main()
