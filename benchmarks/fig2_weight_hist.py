"""Paper Figure 2: distribution of learnt weights before/after pruning.

Claim: l2-regularized OvR training leaves the overwhelming mass of weights
in a narrow band around 0 ("ambiguous weights"); step 7 removes them.

Usage: PYTHONPATH=src python -m benchmarks.fig2_weight_hist
"""

from __future__ import annotations

import numpy as np

from benchmarks._common import fit_dismec, load
from repro.core.pruning import ambiguous_fraction, prune, weight_histogram


def _ascii_hist(counts, edges, height: int = 12) -> str:
    counts = np.asarray(counts, np.float64)
    logc = np.log10(np.maximum(counts, 1.0))
    top = logc.max() or 1.0
    lines = []
    for h in range(height, 0, -1):
        row = "".join("#" if logc[i] / top * height >= h else " "
                      for i in range(len(counts)))
        lines.append(f"10^{top * h / height:4.1f}|{row}")
    lines.append("      " + "-" * len(counts))
    lines.append(f"      {edges[0]:+.2f}{'':{max(len(counts) - 12, 1)}s}"
                 f"{edges[-1]:+.2f}")
    return "\n".join(lines)


def run(dataset: str = "wiki31k_like") -> dict:
    data = load(dataset)
    model, _ = fit_dismec(data, delta=0.0)
    W = model.W
    before, edges = weight_histogram(W, bins=61, lim=0.1)
    after, _ = weight_histogram(prune(W, 0.01), bins=61, lim=0.1)
    # Exclude exact zeros from the "after" plot (they are the removed mass).
    Wp = np.asarray(prune(W, 0.01))
    after_nz, _ = np.histogram(Wp[Wp != 0.0], bins=np.linspace(-0.1, 0.1, 62))
    return {"before": np.asarray(before), "after_nz": after_nz,
            "edges": np.asarray(edges),
            "ambiguous_frac": float(ambiguous_fraction(W, 0.01))}


def main():
    out = run()
    print("== Fig 2a: learnt weight distribution (log10 counts) ==")
    print(_ascii_hist(out["before"], out["edges"]))
    print(f"\nambiguous |w| < 0.01 fraction: {out['ambiguous_frac']:.3f} "
          "(paper: 0.96 at Wiki-31K scale; smaller here at toy D)")
    print("\n== Fig 2b: after pruning (zeros removed) ==")
    print(_ascii_hist(out["after_nz"], out["edges"]))
    return out


if __name__ == "__main__":
    main()
