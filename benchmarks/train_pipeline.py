"""Streaming label-batch training pipeline: throughput, memory, resume.

Compares three ways of training the same DiSMEC model (train/xmc.py):

  one_shot — a single label batch covering all L labels: the whole (L, D)
             problem (and its TRON state) lives on device at once. This is
             what the paper says does NOT scale (870 GB dense).
  streamed — `XMCTrainJob` with label_batch << L: batches stream through one
             compiled solver, each pruned block is packed to BSR on the host
             and appended to the multi-shard checkpoint. Peak device memory
             is O(label_batch x D).
  resume   — kill the streamed job halfway (max_batches), then resume from
             the manifest; the overhead over an uninterrupted run is the
             price of crash tolerance.
  multiworker — the paper's layer 1 over real processes: N worker
             subprocesses each run `fit(..., worker=...)` against ONE
             shared out_dir and cooperatively drain the label-batch queue
             through the manifest lease table. Reports per-worker and
             cooperative batch throughput (the scaling is near-linear
             when workers have cores of their own; on one shared CPU the
             workers contend and the number says how much), and keeps the
             bit-identity gate live: the cooperative manifest and stitched
             weights must equal the single-worker streamed run's exactly.

Device memory is sampled between batches as the total bytes of live jax
arrays (plus the analytic TRON working set ~9 arrays of the solve shape,
which bounds the in-solve peak). Each record also carries the runtime
allocator's true per-device peaks (`device_peak_mb`, from
`device.memory_stats()["peak_bytes_in_use"]`) — on accelerators these see
the transient in-solve allocations live-array sampling cannot; on CPU the
allocator exposes no stats and the field is None per device. Emits one
BENCH_train.json line per mode.

Usage: PYTHONPATH=src python -m benchmarks.train_pipeline
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import tempfile
import time

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks._common import emit_json, print_table
from repro.checkpoint.io import BSR_MANIFEST, load_block_sparse
from repro.core.dismec import DiSMECConfig
from repro.data.xmc import make_xmc_dataset
from repro.train.xmc import XMCTrainJob

OUT_JSON = "BENCH_train.json"
N_WORKERS = 2
REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

N_TRAIN, N_FEATURES, N_LABELS = 500, 4096, 640
LABEL_BATCH = 128                      # L = 5 x label_batch
BLOCK = (128, 128)
# --smoke (tools/verify.sh / CI): same pipeline, tiny shapes — keeps the
# benchmark entrypoint exercised without the full CPU cost.
SMOKE_DIMS = dict(n_train=160, n_features=1024, n_labels=64,
                  label_batch=16, block=(16, 128))
# TRON working set per solve: W, f/g/gnorm/delta vectors, CG d/r/p/Hp and
# the W_try/g_try pair — ~9 (rows, D) arrays dominate.
TRON_ARRAYS = 9


def live_mb() -> float:
    return sum(b.nbytes for b in jax.live_arrays()) / 1e6


def device_peak_mb() -> list[dict]:
    """True per-device peak memory from the runtime allocator, one entry
    per jax device. `live_mb` sums the bytes of currently-live arrays —
    it cannot see transient allocations inside a jitted solve; the
    allocator's `peak_bytes_in_use` can. The peak is cumulative over the
    process (allocators don't rewind), so per-mode rows report the peak
    AS OF that mode's end. Backends without allocator stats (CPU) report
    `peak_mb: None` — the analytic `solve_working_set_mb` remains the
    bound there."""
    out = []
    for d in jax.devices():
        try:
            stats = d.memory_stats()
        except Exception:                 # backend without allocator stats
            stats = None
        peak = (stats or {}).get("peak_bytes_in_use")
        out.append({"device": str(d),
                    "peak_mb": None if peak is None else peak / 1e6})
    return out


def solve_peak_mb(rows: int, d: int) -> float:
    return TRON_ARRAYS * rows * d * 4 / 1e6


def run_job(job: XMCTrainJob, X, Y, out_dir, **kw):
    """Run one pipeline pass, sampling live device bytes and the completion
    timestamp after each batch."""
    samples, batch_ts = [], []

    def on_batch(b, n):
        samples.append(live_mb())
        batch_ts.append(time.time())

    t0 = time.time()
    res = job.run(X, Y, out_dir, on_batch=on_batch, **kw)
    wall = time.time() - t0
    peak = max(samples) if samples else live_mb()
    return res, wall, peak, batch_ts


def steady_labels_per_s(batch_ts: list[float], label_batch: int) -> float:
    """Post-warmup batch throughput: batches completed per second after the
    first completion (the first batch carries the solver compile)."""
    if len(batch_ts) < 2 or batch_ts[-1] <= batch_ts[0]:
        return float("inf")
    return (len(batch_ts) - 1) * label_batch / (batch_ts[-1] - batch_ts[0])


def main(smoke: bool = False):
    if smoke:
        n_train, n_features, n_labels = (SMOKE_DIMS["n_train"],
                                         SMOKE_DIMS["n_features"],
                                         SMOKE_DIMS["n_labels"])
        label_batch, block = SMOKE_DIMS["label_batch"], SMOKE_DIMS["block"]
    else:
        n_train, n_features, n_labels = N_TRAIN, N_FEATURES, N_LABELS
        label_batch, block = LABEL_BATCH, BLOCK
    data = make_xmc_dataset(n_train=n_train, n_test=64,
                            n_features=n_features, n_labels=n_labels, seed=0)
    X = jnp.asarray(data.X_train)
    Y = jnp.asarray(data.Y_train)
    base_mb = live_mb()                # X/Y and friends, common to all modes

    rows_out = []

    def record(mode, wall, peak_sampled, rows_solve, n_batches, extra=None,
               labels_solved=None):
        if labels_solved is None:
            labels_solved = n_labels
        rec = {"bench": "train_pipeline", "mode": mode, "smoke": smoke,
               "n_labels": n_labels, "n_features": n_features,
               "label_batch": rows_solve, "n_batches": n_batches,
               "wall_s": wall,
               "labels_per_s": labels_solved / wall,
               "peak_live_mb": peak_sampled,
               "solve_working_set_mb": solve_peak_mb(rows_solve, n_features),
               "baseline_live_mb": base_mb,
               "device_peak_mb": device_peak_mb()}
        rec.update(extra or {})
        emit_json(OUT_JSON, rec)
        rows_out.append({"mode": mode, "wall_s": wall,
                         "peak_live_mb": peak_sampled,
                         "solve_mb": rec["solve_working_set_mb"],
                         "labels/s": rec["labels_per_s"]})
        return rec

    cfg_stream = DiSMECConfig(delta=0.01, label_batch=label_batch)
    cfg_oneshot = DiSMECConfig(delta=0.01, label_batch=n_labels)

    # one_shot: all L labels in a single device solve (the non-scaling path).
    with tempfile.TemporaryDirectory() as d:
        res, wall, peak, _ = run_job(
            XMCTrainJob(cfg=cfg_oneshot, block_shape=block), X, Y, d)
        assert res.complete
        record("one_shot", wall, peak, n_labels, res.n_batches)

    # streamed: label batches through one compiled solver, BSR appended.
    with tempfile.TemporaryDirectory() as d:
        res, wall_streamed, peak_streamed, ts_streamed = run_job(
            XMCTrainJob(cfg=cfg_stream, block_shape=block), X, Y, d)
        assert res.complete and res.n_batches == n_labels // label_batch
        nnz = sum(s["nnz"] for s in res.manifest["shards"].values())
        record("streamed", wall_streamed, peak_streamed, label_batch,
               res.n_batches,
               {"model_nnz": nnz,
                "steady_labels_per_s": steady_labels_per_s(ts_streamed,
                                                           label_batch)})
        # Reference for the multiworker bit-identity gate below.
        with open(os.path.join(d, BSR_MANIFEST)) as f:
            manifest_single = json.load(f)
        W_single = np.asarray(load_block_sparse(d)[0].to_dense())

    # multiworker: N subprocesses cooperatively drain one shared out_dir
    # through the manifest lease table (layer 1 over real processes). The
    # reference is a SOLO subprocess measured the same way (its own
    # interpreter + compile inside its fit window), and co-workers
    # synchronize on a start barrier so their windows are concurrent —
    # scaling = solo window / cooperative window. On a box where each
    # worker gets its own cores this approaches the worker count as the
    # batch count grows; with all workers packed on one small CPU the
    # number reports the contention honestly.
    with tempfile.TemporaryDirectory() as d:
        env = {**os.environ,
               "PYTHONPATH": "src" + (os.pathsep + os.environ["PYTHONPATH"]
                                      if os.environ.get("PYTHONPATH") else "")}

        def launch(worker_id, out_dir, workers, barrier=None):
            cmd = [sys.executable, "-m", "benchmarks.train_pipeline",
                   "--drain-worker", out_dir, "--workers", str(workers),
                   "--worker-id", worker_id]
            if barrier:
                cmd += ["--barrier", barrier]
            if smoke:
                cmd.append("--smoke")
            return subprocess.Popen(cmd, cwd=REPO_ROOT, env=env,
                                    stdout=subprocess.PIPE, text=True)

        def wait(proc):
            out, _ = proc.communicate()
            assert proc.returncode == 0, f"worker failed:\n{out}"
            return json.loads(out.strip().splitlines()[-1])

        solo = wait(launch("solo", os.path.join(d, "solo"), 1))
        solo_wall = solo["t_fit_end"] - solo["t_fit_start"]

        coop_dir = os.path.join(d, "coop")
        t0 = time.time()
        procs = [launch(f"w{i}", coop_dir, N_WORKERS,
                        barrier=os.path.join(d, "barrier"))
                 for i in range(N_WORKERS)]
        reports = [wait(p) for p in procs]
        wall_spawn = time.time() - t0
        coop_wall = (max(r["t_fit_end"] for r in reports)
                     - min(r["t_fit_start"] for r in reports))
        assert any(r["complete"] for r in reports)
        assert sum(r["n_solved"] for r in reports) == n_labels // label_batch
        with open(os.path.join(coop_dir, BSR_MANIFEST)) as f:
            manifest_coop = json.load(f)
        assert manifest_coop == manifest_single          # bit-identity gate
        np.testing.assert_array_equal(
            np.asarray(load_block_sparse(coop_dir)[0].to_dense()), W_single)
        # Peak device memory lives in the worker subprocesses (each is the
        # streamed profile), not in this parent: report None.
        record("multiworker", coop_wall, None, label_batch,
               n_labels // label_batch,
               {"workers": N_WORKERS,
                "batches_per_worker": [r["n_solved"] for r in reports],
                "wall_s_incl_spawn": wall_spawn,
                "fit_window_s_solo": solo_wall,
                "fit_window_scaling": solo_wall / coop_wall,
                "manifest_identical": True})
        print(f"multiworker: {N_WORKERS} workers drained "
              f"{n_labels // label_batch} batches in {coop_wall:.1f}s vs "
              f"{solo_wall:.1f}s solo ({solo_wall / coop_wall:.2f}x; "
              f"batches/worker {[r['n_solved'] for r in reports]})")

    # resume: kill halfway, restart from the manifest.
    with tempfile.TemporaryDirectory() as d:
        job = XMCTrainJob(cfg=cfg_stream, block_shape=block)
        half = (n_labels // label_batch) // 2
        res1, wall_partial, _, _ = run_job(job, X, Y, d, max_batches=half)
        assert not res1.complete
        res2, wall_resume, peak, _ = run_job(job, X, Y, d)
        assert res2.complete and len(res2.skipped) == half
        overhead = wall_partial + wall_resume - wall_streamed
        record("resume", wall_resume, peak, label_batch, res2.n_batches,
               {"resumed_batches": len(res2.skipped),
                "resume_overhead_s": overhead,
                "resume_overhead_frac": overhead / wall_streamed},
               # The resume leg only re-solved the non-skipped batches.
               labels_solved=len(res2.solved) * label_batch)

    print_table(
        f"streaming train pipeline (L={n_labels}, D={n_features}, "
        f"label_batch={label_batch})",
        rows_out, ["mode", "wall_s", "peak_live_mb", "solve_mb", "labels/s"])

    one_shot_mb = solve_peak_mb(n_labels, n_features)
    streamed_mb = solve_peak_mb(label_batch, n_features)
    print(f"\nsolver working set: one_shot {one_shot_mb:.0f} MB vs streamed "
          f"{streamed_mb:.0f} MB ({one_shot_mb / streamed_mb:.1f}x — scales "
          "with label_batch, not L)")
    print(f"wrote {OUT_JSON}")


def drain_worker(out_dir: str, worker_id: str, workers: int, smoke: bool,
                 barrier: str | None = None) -> None:
    """Subprocess entry for the multiworker mode: one cooperative worker.

    Builds the SAME dataset and canonical spec as the in-process modes (so
    the manifest fingerprint admits it and bit-identity vs `streamed`
    holds) and emits one JSON report line on stdout for the parent.
    `barrier` is a path prefix co-workers rendezvous on right before
    `fit`, so their measured fit windows are concurrent rather than
    staggered by process startup.
    """
    import glob

    from repro.specs import ScheduleSpec, SolverSpec
    from repro.xmc_api import XMCSpec, fit

    if smoke:
        n_train, n_features, n_labels = (SMOKE_DIMS["n_train"],
                                         SMOKE_DIMS["n_features"],
                                         SMOKE_DIMS["n_labels"])
        label_batch, block = SMOKE_DIMS["label_batch"], SMOKE_DIMS["block"]
    else:
        n_train, n_features, n_labels = N_TRAIN, N_FEATURES, N_LABELS
        label_batch, block = LABEL_BATCH, BLOCK
    data = make_xmc_dataset(n_train=n_train, n_test=64,
                            n_features=n_features, n_labels=n_labels, seed=0)
    X = jnp.asarray(data.X_train)
    Y = jnp.asarray(data.Y_train)
    spec = XMCSpec(solver=SolverSpec(delta=0.01),
                   schedule=ScheduleSpec(label_batch=label_batch,
                                         block_shape=block, workers=workers,
                                         lease_ttl=60.0))
    if barrier is not None:
        open(f"{barrier}.{worker_id}", "w").close()
        deadline = time.time() + 300.0
        while len(glob.glob(f"{barrier}.*")) < workers:
            if time.time() > deadline:
                raise RuntimeError("start-barrier timeout")
            time.sleep(0.02)
    t_start = time.time()
    handle = fit(X, Y, spec, out_dir, worker=worker_id)
    res = handle.result
    print(json.dumps({"worker": worker_id, "n_solved": len(res.solved),
                      "complete": res.complete, "t_fit_start": t_start,
                      "t_fit_end": time.time()}))


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--drain-worker", default=None, metavar="OUT_DIR",
                    help="internal: run as one cooperative worker draining "
                         "OUT_DIR (used by the multiworker mode)")
    ap.add_argument("--worker-id", default=None)
    ap.add_argument("--workers", type=int, default=N_WORKERS)
    ap.add_argument("--barrier", default=None,
                    help="internal: path prefix for the co-worker start "
                         "rendezvous")
    args = ap.parse_args()
    if args.drain_worker:
        drain_worker(args.drain_worker, args.worker_id or "w0",
                     args.workers, args.smoke, barrier=args.barrier)
    else:
        main(smoke=args.smoke)
