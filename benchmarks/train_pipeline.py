"""Streaming label-batch training pipeline: throughput, memory, resume.

Compares three ways of training the same DiSMEC model (train/xmc.py):

  one_shot — a single label batch covering all L labels: the whole (L, D)
             problem (and its TRON state) lives on device at once. This is
             what the paper says does NOT scale (870 GB dense).
  streamed — `XMCTrainJob` with label_batch << L: batches stream through one
             compiled solver, each pruned block is packed to BSR on the host
             and appended to the multi-shard checkpoint. Peak device memory
             is O(label_batch x D).
  resume   — kill the streamed job halfway (max_batches), then resume from
             the manifest; the overhead over an uninterrupted run is the
             price of crash tolerance.

Device memory is sampled between batches as the total bytes of live jax
arrays (plus the analytic TRON working set ~9 arrays of the solve shape,
which bounds the in-solve peak). Emits one BENCH_train.json line per mode.

Usage: PYTHONPATH=src python -m benchmarks.train_pipeline
"""

from __future__ import annotations

import tempfile
import time

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks._common import emit_json, print_table
from repro.core.dismec import DiSMECConfig
from repro.data.xmc import make_xmc_dataset
from repro.train.xmc import XMCTrainJob

OUT_JSON = "BENCH_train.json"

N_TRAIN, N_FEATURES, N_LABELS = 500, 4096, 640
LABEL_BATCH = 128                      # L = 5 x label_batch
BLOCK = (128, 128)
# --smoke (tools/verify.sh / CI): same pipeline, tiny shapes — keeps the
# benchmark entrypoint exercised without the full CPU cost.
SMOKE_DIMS = dict(n_train=160, n_features=1024, n_labels=64,
                  label_batch=16, block=(16, 128))
# TRON working set per solve: W, f/g/gnorm/delta vectors, CG d/r/p/Hp and
# the W_try/g_try pair — ~9 (rows, D) arrays dominate.
TRON_ARRAYS = 9


def live_mb() -> float:
    return sum(b.nbytes for b in jax.live_arrays()) / 1e6


def solve_peak_mb(rows: int, d: int) -> float:
    return TRON_ARRAYS * rows * d * 4 / 1e6


def run_job(job: XMCTrainJob, X, Y, out_dir, **kw):
    """Run one pipeline pass, sampling live device bytes after each batch."""
    samples = []

    def on_batch(b, n):
        samples.append(live_mb())

    t0 = time.time()
    res = job.run(X, Y, out_dir, on_batch=on_batch, **kw)
    wall = time.time() - t0
    peak = max(samples) if samples else live_mb()
    return res, wall, peak


def main(smoke: bool = False):
    if smoke:
        n_train, n_features, n_labels = (SMOKE_DIMS["n_train"],
                                         SMOKE_DIMS["n_features"],
                                         SMOKE_DIMS["n_labels"])
        label_batch, block = SMOKE_DIMS["label_batch"], SMOKE_DIMS["block"]
    else:
        n_train, n_features, n_labels = N_TRAIN, N_FEATURES, N_LABELS
        label_batch, block = LABEL_BATCH, BLOCK
    data = make_xmc_dataset(n_train=n_train, n_test=64,
                            n_features=n_features, n_labels=n_labels, seed=0)
    X = jnp.asarray(data.X_train)
    Y = jnp.asarray(data.Y_train)
    base_mb = live_mb()                # X/Y and friends, common to all modes

    rows_out = []

    def record(mode, wall, peak_sampled, rows_solve, n_batches, extra=None,
               labels_solved=None):
        if labels_solved is None:
            labels_solved = n_labels
        rec = {"bench": "train_pipeline", "mode": mode, "smoke": smoke,
               "n_labels": n_labels, "n_features": n_features,
               "label_batch": rows_solve, "n_batches": n_batches,
               "wall_s": wall,
               "labels_per_s": labels_solved / wall,
               "peak_live_mb": peak_sampled,
               "solve_working_set_mb": solve_peak_mb(rows_solve, n_features),
               "baseline_live_mb": base_mb}
        rec.update(extra or {})
        emit_json(OUT_JSON, rec)
        rows_out.append({"mode": mode, "wall_s": wall,
                         "peak_live_mb": peak_sampled,
                         "solve_mb": rec["solve_working_set_mb"],
                         "labels/s": rec["labels_per_s"]})
        return rec

    cfg_stream = DiSMECConfig(delta=0.01, label_batch=label_batch)
    cfg_oneshot = DiSMECConfig(delta=0.01, label_batch=n_labels)

    # one_shot: all L labels in a single device solve (the non-scaling path).
    with tempfile.TemporaryDirectory() as d:
        res, wall, peak = run_job(
            XMCTrainJob(cfg=cfg_oneshot, block_shape=block), X, Y, d)
        assert res.complete
        record("one_shot", wall, peak, n_labels, res.n_batches)

    # streamed: label batches through one compiled solver, BSR appended.
    with tempfile.TemporaryDirectory() as d:
        res, wall_streamed, peak_streamed = run_job(
            XMCTrainJob(cfg=cfg_stream, block_shape=block), X, Y, d)
        assert res.complete and res.n_batches == n_labels // label_batch
        nnz = sum(s["nnz"] for s in res.manifest["shards"].values())
        record("streamed", wall_streamed, peak_streamed, label_batch,
               res.n_batches, {"model_nnz": nnz})

    # resume: kill halfway, restart from the manifest.
    with tempfile.TemporaryDirectory() as d:
        job = XMCTrainJob(cfg=cfg_stream, block_shape=block)
        half = (n_labels // label_batch) // 2
        res1, wall_partial, _ = run_job(job, X, Y, d, max_batches=half)
        assert not res1.complete
        res2, wall_resume, peak = run_job(job, X, Y, d)
        assert res2.complete and len(res2.skipped) == half
        overhead = wall_partial + wall_resume - wall_streamed
        record("resume", wall_resume, peak, label_batch, res2.n_batches,
               {"resumed_batches": len(res2.skipped),
                "resume_overhead_s": overhead,
                "resume_overhead_frac": overhead / wall_streamed},
               # The resume leg only re-solved the non-skipped batches.
               labels_solved=len(res2.solved) * label_batch)

    print_table(
        f"streaming train pipeline (L={n_labels}, D={n_features}, "
        f"label_batch={label_batch})",
        rows_out, ["mode", "wall_s", "peak_live_mb", "solve_mb", "labels/s"])

    one_shot_mb = solve_peak_mb(n_labels, n_features)
    streamed_mb = solve_peak_mb(label_batch, n_features)
    print(f"\nsolver working set: one_shot {one_shot_mb:.0f} MB vs streamed "
          f"{streamed_mb:.0f} MB ({one_shot_mb / streamed_mb:.1f}x — scales "
          "with label_batch, not L)")
    print(f"wrote {OUT_JSON}")


if __name__ == "__main__":
    main()
