"""Benchmark harness entry point: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run --only fig5_delta_sweep
  PYTHONPATH=src python -m benchmarks.run --smoke    # CI: pipeline benches
                                                     # on tiny shapes

Modules (deliverable d):
  table2_accuracy        Table 2 + Fig 3 (P@k / nDCG@k vs baselines)
  fig2_weight_hist       Fig 2 (weight distribution pre/post prune)
  fig4_l1_vs_l2          Fig 4 (l1 underfits vs l2+prune)
  fig5_delta_sweep       Fig 5 (Delta vs size vs accuracy)
  table3_scaling         SS4.3 (double-parallelization scaling)
  table_model_size       SS4.2 (model size accounting + paper-scale check)
  table_prediction_speed SS4.3 (prediction latency + BSR flops ratio)
  c_validation_sweep     SS3.3 (C tuned on validation) + shard balance
  train_pipeline         streaming label-batch training: throughput/mem/resume
                         (+ per-device peak-memory counters)
  tron_hotpath           CG matmul accounting + scheduler-overlap wall clock
  serve_latency          serving-engine p50/p99 per predict backend, the
                         shortlist-vs-exhaustive sub-linear gate (candidate
                         fraction < 25% at recall@5 >= 0.95), the
                         open-loop Poisson server benchmark (deadline beats
                         drain-on-full on p99; overload sheds with bounded
                         queue wait), and the zero-downtime refresh gate
                         (hot swap under load: zero drops, swap-window p99
                         <= 2x steady state), and the coarse-stage gates
                         (learned one-vs-rest coarse stage reaches the
                         recall gate at strictly fewer candidate blocks
                         than centroids; per-query ragged gather bit-exact
                         at full width; legacy/v1 artifact fallback) — all
                         live in --smoke, so tools/verify.sh gates them
  lifecycle_sweep        warm-start Delta sweep driver smoke: unchanged-spec
                         arm bit-identical to its warm-start source, model
                         size monotone in Delta, size-budget policy picks a
                         feasible arm — live in --smoke
  roofline               deliverable (g): 3-term roofline from the dry-run
"""

from __future__ import annotations

import argparse
import importlib
import inspect
import sys
import time
import traceback

MODULES = [
    "table2_accuracy",
    "fig2_weight_hist",
    "fig4_l1_vs_l2",
    "fig5_delta_sweep",
    "table3_scaling",
    "table_model_size",
    "table_prediction_speed",
    "c_validation_sweep",
    "train_pipeline",
    "tron_hotpath",
    "serve_latency",
    "lifecycle_sweep",
    "roofline",
]

# --smoke: the pipeline benchmarks (train / hot path / serve) on tiny
# shapes — a CI gate (tools/verify.sh) that keeps every benchmark
# entrypoint importable and runnable without the full CPU cost.
SMOKE_MODULES = ["train_pipeline", "tron_hotpath", "serve_latency",
                 "lifecycle_sweep"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated module subset")
    ap.add_argument("--smoke", action="store_true",
                    help=f"tiny-shape pass over {SMOKE_MODULES}")
    args = ap.parse_args()
    mods = (args.only.split(",") if args.only
            else SMOKE_MODULES if args.smoke else MODULES)

    failures = []
    for name in mods:
        print(f"\n{'=' * 72}\n== benchmarks.{name}"
              f"{' (smoke)' if args.smoke else ''}\n{'=' * 72}")
        t0 = time.time()
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
            if name == "roofline":
                sys.argv = ["roofline"]          # default args
            kwargs = {}
            if args.smoke:
                if "smoke" not in inspect.signature(mod.main).parameters:
                    raise TypeError(f"benchmarks.{name}.main has no smoke "
                                    "mode; drop it from SMOKE_MODULES or "
                                    "add the parameter")
                kwargs["smoke"] = True
            mod.main(**kwargs)
            print(f"\n[benchmarks.{name} done in {time.time() - t0:.1f}s]")
        except Exception:
            traceback.print_exc()
            failures.append(name)
    if failures:
        print(f"\nFAILED benchmarks: {failures}")
        sys.exit(1)
    print(f"\nAll {len(mods)} benchmarks completed.")


if __name__ == "__main__":
    main()
