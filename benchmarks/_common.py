"""Shared helpers for the benchmark harness (one module per paper table)."""

from __future__ import annotations

import json
import time

import jax.numpy as jnp
import numpy as np

from repro.core.prediction import evaluate, predict_topk
from repro.data.xmc import XMCDataset, load_paper_like
from repro.specs import ScheduleSpec, SolverSpec
from repro.xmc_api import XMCSpec, job_from_spec

# The scaled-down name-alikes of the paper's Table 1 datasets.
DATASETS = ("wiki31k_like", "amazon670k_like", "delicious200k_like",
            "wikilshtc325k_like")


def load(name: str) -> XMCDataset:
    return load_paper_like(name, seed=0)


# Layer-1 batch size for benchmark fits: smaller than every paper-like
# dataset's label count, so the batched scheduler (train/xmc.py) — not the
# one-shot solve — is what every benchmark measures.
LABEL_BATCH = 256


def fit_dismec(data: XMCDataset, *, C: float = 1.0, delta: float = 0.01,
               eps: float = 0.01):
    """Benchmark fits run as adapters over the one spec-driven session
    path (repro.xmc_api), materialized in memory for the table scorers."""
    spec = XMCSpec(
        solver=SolverSpec(C=C, delta=delta, eps=eps),
        schedule=ScheduleSpec(
            label_batch=min(data.n_labels, LABEL_BATCH)))
    t0 = time.time()
    model = job_from_spec(spec).run(
        jnp.asarray(data.X_train), jnp.asarray(data.Y_train)).model
    return model, time.time() - t0


def score(model_W, data: XMCDataset) -> dict:
    _, idx = predict_topk(jnp.asarray(data.X_test), model_W, 5)
    return evaluate(jnp.asarray(data.Y_test), idx)


def print_table(title: str, rows: list[dict], cols: list[str]):
    print(f"\n== {title} ==")
    hdr = " | ".join(f"{c:>12s}" for c in cols)
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        print(" | ".join(
            f"{r[c]:12.4f}" if isinstance(r[c], float) else f"{str(r[c]):>12s}"
            for c in cols))


def emit_json(path: str, obj):
    with open(path, "a") as f:
        f.write(json.dumps(obj) + "\n")
