"""Serving latency benchmark: p50/p99 per predict backend.

Drives the same ragged request stream through each `repro.serve.XMCEngine`
backend (dense / bsr / sharded) from one shared sparse checkpoint, and
emits a `BENCH_serve.json` line per backend with latency percentiles,
throughput, and the model's block density. This is the serving-side
companion of table_prediction_speed (which measures raw predict calls
without the queue/bucketing layer).
"""

from __future__ import annotations

import tempfile
import time

import numpy as np

from benchmarks._common import emit_json, print_table
from repro.checkpoint.io import load_block_sparse
from repro.serve import BACKENDS, XMCEngine
from repro.train.xmc import train_demo_checkpoint

OUT_JSON = "BENCH_serve.json"

N_REQUESTS = 64
MAX_ROWS = 8
K = 5


def main():
    rows_out = []
    with tempfile.TemporaryDirectory() as ckpt:
        # Shared demo pipeline (streaming label-batch trainer) — the same
        # setup behind launch/serve.py --xmc and examples/serve_xmc.py.
        data, _ = train_demo_checkpoint(ckpt, n_train=800, n_test=512,
                                        n_features=4096, n_labels=256,
                                        label_batch=128, seed=0)
        bsr, _ = load_block_sparse(ckpt)

        rng = np.random.default_rng(0)
        X = np.asarray(data.X_test, np.float32)
        requests = []
        for _ in range(N_REQUESTS):
            n_i = int(rng.integers(1, MAX_ROWS + 1))
            rows = rng.integers(0, X.shape[0], size=n_i)
            requests.append(X[rows])
        n_inst = sum(r.shape[0] for r in requests)

        for kind in BACKENDS:
            t0 = time.time()
            engine = XMCEngine.from_checkpoint(ckpt, backend=kind, k=K)
            t_load = time.time() - t0
            t0 = time.time()
            results = engine.serve(requests)
            wall = time.time() - t0
            stats = engine.latency_summary()
            assert len(results) == N_REQUESTS
            rec = {"bench": "serve_latency", "backend": kind,
                   "n_requests": N_REQUESTS, "n_instances": n_inst,
                   "k": K, "block_density": bsr.density,
                   "load_warmup_s": t_load,
                   "p50_ms": stats["p50_ms"], "p90_ms": stats["p90_ms"],
                   "p99_ms": stats["p99_ms"], "mean_ms": stats["mean_ms"],
                   "throughput_inst_per_s": n_inst / wall}
            emit_json(OUT_JSON, rec)
            rows_out.append({"backend": kind, "p50_ms": stats["p50_ms"],
                             "p99_ms": stats["p99_ms"],
                             "mean_ms": stats["mean_ms"],
                             "inst/s": n_inst / wall})

    print_table("serving latency per backend "
                f"({N_REQUESTS} ragged requests, {n_inst} instances, k={K})",
                rows_out, ["backend", "p50_ms", "p99_ms", "mean_ms", "inst/s"])
    print(f"\nwrote {OUT_JSON}")


if __name__ == "__main__":
    main()
