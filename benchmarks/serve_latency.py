"""Serving latency benchmark: p50/p99 per predict backend, the
shortlist-vs-exhaustive sub-linear serving gate, and the open-loop Poisson
server benchmark.

Part 1 drives the same ragged request stream through each
`repro.serve.XMCEngine` backend (dense / bsr / sharded / shortlist) from
one shared sparse checkpoint and emits a `BENCH_serve.json` line per
backend. Requests run CLOSED LOOP — one submit + step per request — so
every request contributes its own latency sample and the percentiles are
real order statistics over n_requests samples, not one batched-drain
timestamp smeared across every request (the old scheme made
p50 == p90 == p99 by construction).

Part 2 is the sub-linear serving gate: a second, finer-row-block demo
checkpoint (enough row blocks for a meaningful candidate stage) is served
by the shortlist backend against exhaustive BSR on identical requests, and
the emitted row records recall@k vs exhaustive, the candidate fraction
B / n_row_blocks, and the measured fine-stage FLOP fraction (gathered
blocks vs all packed blocks). The run asserts candidate fraction < 25%
at recall@k >= 0.95 — the acceptance criterion of the shortlist PR, live
in --smoke so tools/verify.sh gates it.

Part 3 is OPEN LOOP: a Poisson load generator submits requests to the
async continuous-batching server (`serve/server.py`) at a fixed offered
load, independent of completions — the regime closed-loop percentiles say
nothing about, because a closed loop never queues. Each scenario emits a
`mode="server_poisson"` record with arrival-to-completion p50/p99,
queue-wait percentiles, goodput (completed requests per second of wall),
and the reject rate. Two assertions run live in --smoke (the continuous-
batching PR's acceptance gates, wired into tools/verify.sh through
`benchmarks.run --smoke`):

  * at an offered load below saturation, deadline launch
    (max_batch_delay_ms small) beats drain-on-full batching (deadline
    effectively infinite, batches ship only when a bucket fills or at
    final flush) on p99 arrival-to-completion latency;
  * under overload with a finite `max_queue`, admission control rejects
    (reject_rate > 0) and the queue wait of ACCEPTED requests stays
    bounded, instead of the unbounded queue growth an un-admission-
    controlled open loop produces.

Part 4 is the int8 serving gate: the finer-block checkpoint of part 2 is
served int8 (exhaustive "int8" backend and the shortlist backend's int8
fine stage) against fp32 on identical requests, and the `int8_vs_fp32`
record reports top-k agreement@k, the mean top-k Jaccard, the weight
payload bytes ratio (int8 values + fp32 per-block scales vs fp32 blocks),
and paired p50 latencies. Two assertions run live in --smoke (wired into
tools/verify.sh through `benchmarks.run --smoke`): bytes_ratio <= 0.55 and
topk agreement@k >= 0.99, for both the exhaustive int8 path and the
shortlist-composed gathered-int8 path.

Part 5 is the zero-downtime refresh gate: open-loop Poisson traffic
flows through the async server while `XMCServer.swap()` installs a
warm-started variant of the model (fit with `init_from=` the serving
checkpoint, a different Delta) from a separate thread. The
`mode="refresh_under_load"` record reports per-request latency split
into the swap window vs steady state, the measured flip blackout
(`swap_blackout_ms`, time the dispatch lock is held to flip engines) and
off-thread warm time. Two assertions run live in --smoke (wired into
tools/verify.sh): every accepted request resolves — zero drops, zero
rejects, old and new model both answered — and the p99
arrival-to-completion latency of requests in flight during the swap is
<= 2x the steady-state p99 of the same run.

Part 6 is the coarse-stage comparison gate on a weaker-locality demo
checkpoint (block centroids dilute): the learned one-vs-rest coarse stage
must reach recall@k >= 0.95 at a STRICTLY smaller candidate width than
the centroid baseline (host-side width sweep via coverage == recall for
an exact fine stage, then re-served end-to-end at the winning width),
per-query ragged gather must collapse to the shared executable at
B = n_row_blocks and stay bit-exact vs exhaustive BSR, single-row
requests must be bit-identical between the per-query and shared paths,
and legacy / v1-artifact checkpoints must keep serving via fallback.
All live in --smoke, wired into tools/verify.sh.

Every record is stamped `"schema": 2` (closed-loop per-request
percentiles, smoke floor of 32 requests); trend tooling should skip
rows without it — pre-PR-6 rows were batched-drain timestamps with
p50 == p99 by construction.

This is the serving-side companion of table_prediction_speed (which
measures raw predict calls without the queue/bucketing layer).
"""

from __future__ import annotations

import os
import tempfile
import time

import numpy as np

from benchmarks._common import emit_json, print_table
from repro.serve import BACKENDS, Rejected
from repro.specs import ServeSpec
from repro.train.xmc import train_demo_checkpoint
from repro.xmc_api import CheckpointHandle

OUT_JSON = "BENCH_serve.json"

#: Record schema version stamped on every emitted row. 2 = closed-loop
#: per-request percentiles with the 32-request smoke floor; rows without
#: the field predate PR 6 (batched-drain timestamps, p50==p99).
SCHEMA = 2

N_REQUESTS = 64
SMOKE_FLOOR = 32          # no smoke config may serve fewer requests: below
                          # this, percentiles degenerate (p50==p99 again)
N_REQUESTS_SMOKE = max(32, SMOKE_FLOOR)
MAX_ROWS = 8
K = 5


def emit(rec: dict) -> None:
    """Append one schema-stamped record to the benchmark JSON."""
    rec.setdefault("schema", SCHEMA)
    emit_json(OUT_JSON, rec)

# Part 2's finer-block demo model: the default serving checkpoint tiles
# labels into 128-row blocks, which leaves the smoke model (64 labels) ONE
# row block — nothing to shortlist. These dims give R = 16 row blocks in
# both profiles, so a B-of-R candidate stage is measurable. The data knobs
# make the label space cluster-ordered (overlapping adjacent signature
# pools, co-occurring labels adjacent) — the regime real XMC candidate
# stages serve, where label orderings come from trees/clusters. With fully
# independent labels a query's top-k tail is unstructured noise that NO
# candidate stage can cover.
CLUSTER_DATA = dict(pool_stride=2, label_locality=0.9, multi_label_p=0.9)
SHORTLIST_DEMO = dict(n_train=800, n_test=512, n_features=4096,
                      n_labels=512, label_batch=128, block_shape=(32, 128),
                      data_kwargs=CLUSTER_DATA)
SHORTLIST_DEMO_SMOKE = dict(n_train=240, n_test=64, n_features=1024,
                            n_labels=128, label_batch=64,
                            block_shape=(8, 128), data_kwargs=CLUSTER_DATA)
SHORTLIST_B = 3                        # candidate blocks: 3/16 = 18.75% < 25%
RECALL_GATE = 0.95
FRACTION_GATE = 0.25

# Part 6's coarse-stage comparison demo: weaker locality (stride 3,
# label_locality 0.6) than CLUSTER_DATA, so block centroids DILUTE — the
# mean of a block's label vectors under-weights the block's minority
# clusters, and the learned one-vs-rest meta-classifier (trained on block
# membership, not weight geometry) needs strictly fewer candidate blocks
# for the same recall. Fixed seeds end to end keep the strict-win gate
# deterministic.
COARSE_DATA = dict(pool_stride=3, label_locality=0.6, multi_label_p=0.9)
COARSE_DEMO = dict(n_train=600, n_test=128, n_features=1024, n_labels=128,
                   label_batch=64, block_shape=(8, 128),
                   data_kwargs=COARSE_DATA)
COARSE_DEMO_SMOKE = dict(n_train=240, n_test=64, n_features=1024,
                         n_labels=128, label_batch=64, block_shape=(8, 128),
                         data_kwargs=COARSE_DATA)
COARSE_NEWTON = 20
COARSE_NEWTON_SMOKE = 8

# Part 3 (open-loop Poisson server): small buckets keep per-batch service
# time well under the arrival gaps, so "below saturation" holds even on the
# 2-core CI container; the overload scenario shrinks them further so a
# back-to-back burst genuinely outruns dispatch.
SERVER_BUCKETS = (1, 2, 4, 8, 16, 32, 64)
OVERLOAD_BUCKETS = (1, 2, 4, 8)
FILL_ONLY_DELAY_MS = 60_000.0   # deadline past any run: pure drain-on-full
SERVER_LOW = dict(n_requests=200, rate_rps=120.0, deadline_ms=2.0)
SERVER_LOW_SMOKE = dict(n_requests=40, rate_rps=60.0, deadline_ms=2.0)
SERVER_OVERLOAD = dict(n_requests=160, max_queue=8)
SERVER_OVERLOAD_SMOKE = dict(n_requests=80, max_queue=8)
QUEUE_WAIT_BOUND_MS = 1000.0    # overload queue wait must stay bounded

# Part 5 (refresh under load): offered load well below saturation so the
# steady-state p99 is a meaningful baseline, and enough requests that the
# swap window holds a usable sample. The window is the flip instant padded
# by SWAP_WINDOW_PAD_MS on both sides — requests whose lifetime intersects
# it are the "during swap" population.
REFRESH_LOAD = dict(n_requests=400, rate_rps=150.0)
REFRESH_LOAD_SMOKE = dict(n_requests=160, rate_rps=120.0)
SWAP_WINDOW_PAD_MS = 75.0
SWAP_P99_FACTOR = 2.0           # p99 during swap <= 2x steady-state p99
REFRESH_DELTA = 0.2             # the variant model's pruning threshold


def make_requests(X: np.ndarray, n_requests: int, seed: int = 0,
                  max_rows: int = MAX_ROWS):
    rng = np.random.default_rng(seed)
    requests = []
    for _ in range(n_requests):
        n_i = int(rng.integers(1, max_rows + 1))
        requests.append(X[rng.integers(0, X.shape[0], size=n_i)])
    return requests


def serve_closed_loop(engine, requests):
    """One submit + drain per request: each request is dispatched alone and
    lands one latency sample, so percentiles are per-request order
    statistics. Returns (results, wall_seconds)."""
    results = []
    t0 = time.time()
    for x in requests:
        engine.submit(x)
        results.extend(engine.step())
    return results, time.time() - t0


def run_open_loop(handle, pool: np.ndarray, *, n_requests: int,
                  rate_rps: float | None, delay_ms: float,
                  buckets, policy: str, smoke: bool,
                  max_queue: int | None = None, seed: int = 0) -> dict:
    """One open-loop scenario: submit `n_requests` single-instance requests
    to a fresh async server with Poisson inter-arrivals at `rate_rps`
    (None = back-to-back burst), flush, and report arrival-to-completion
    percentiles, queue wait, goodput, and the reject rate. The generator
    never waits for completions — offered load is independent of service,
    which is what makes tail latency and backpressure measurable at all."""
    rng = np.random.default_rng(seed)
    requests = [pool[rng.integers(0, pool.shape[0], size=1)]
                for _ in range(n_requests)]
    gaps = (np.zeros(n_requests) if rate_rps is None
            else rng.exponential(1.0 / rate_rps, size=n_requests))
    server = handle.server(ServeSpec(
        backend="dense", k=K, buckets=tuple(buckets),
        max_batch_delay_ms=delay_ms, max_queue=max_queue))
    t0 = time.monotonic()
    t_next = t0
    futures = []
    for x, gap in zip(requests, gaps):
        t_next += gap
        now = time.monotonic()
        if t_next > now:
            time.sleep(t_next - now)
        futures.append(server.submit(x))
    server.stop()                  # flush: every accepted request resolves
    wall = time.monotonic() - t0
    results = [f.result(timeout=60) for f in futures]
    n_rejected = sum(isinstance(r, Rejected) for r in results)
    st = server.stats()
    assert st["completed"] + n_rejected == n_requests
    return {"bench": "serve_latency", "mode": "server_poisson",
            "policy": policy, "smoke": smoke, "backend": "dense", "k": K,
            "n_offered": n_requests, "offered_load_rps": rate_rps,
            "max_batch_delay_ms": delay_ms, "max_queue": max_queue,
            "buckets": list(buckets), "batches": st["batches"],
            "n_completed": st["completed"], "n_rejected": st["rejected"],
            "reject_rate": st["reject_rate"],
            "goodput_rps": st["completed"] / wall, "wall_s": wall,
            "p50_ms": st["latency"].get("p50_ms"),
            "p99_ms": st["latency"].get("p99_ms"),
            "mean_ms": st["latency"].get("mean_ms"),
            "queue_wait_p50_ms": st["queue_wait"].get("p50_ms"),
            "queue_wait_p99_ms": st["queue_wait"].get("p99_ms")}


def run_refresh_under_load(*, smoke: bool, seed: int = 5) -> dict:
    """Part 5: hot-swap a warm-started variant into a live server under
    open-loop Poisson load and measure what the refresh costs the tail.

    Gen-1 model: the shared demo checkpoint. Gen-2 model: `fit` with a
    coarser Delta, warm-started from gen 1 (`init_from=`) — the exact
    artifact a sweep/retrain hands to `ModelRouter.refresh`. A collector
    thread timestamps completions in submission order (completions are
    FIFO: single dispatch thread, FIFO queue), so every request carries a
    client-side arrival-to-completion latency attributable to either the
    swap window or steady state."""
    import queue as queue_mod
    import threading

    import jax.numpy as jnp

    from repro.specs import ScheduleSpec, SolverSpec
    from repro.xmc_api import XMCSpec, fit

    cfg = REFRESH_LOAD_SMOKE if smoke else REFRESH_LOAD
    demo = (dict(n_train=200, n_test=64, n_features=512, n_labels=64,
                 label_batch=32) if smoke else
            dict(n_train=800, n_test=512, n_features=4096, n_labels=256,
                 label_batch=128))
    n = cfg["n_requests"]
    with tempfile.TemporaryDirectory() as root:
        base_dir = os.path.join(root, "gen1")
        next_dir = os.path.join(root, "gen2")
        data, _ = train_demo_checkpoint(base_dir, seed=0, **demo)
        handle = CheckpointHandle.open(base_dir)
        spec = XMCSpec(
            solver=SolverSpec(C=1.0, delta=REFRESH_DELTA),
            schedule=ScheduleSpec(label_batch=demo["label_batch"]))
        variant = fit(jnp.asarray(data.X_train), jnp.asarray(data.Y_train),
                      spec, next_dir, init_from=base_dir)
        serve = ServeSpec(backend="dense", k=K, buckets=SERVER_BUCKETS,
                          max_batch_delay_ms=2.0)
        server = handle.server(serve)
        new_engine = variant.engine(serve.replace(warmup=False))

        # Reference answers from both generations, for attribution.
        rng = np.random.default_rng(seed)
        pool = np.asarray(data.X_test, np.float32)
        requests = [pool[rng.integers(0, pool.shape[0], size=1)]
                    for _ in range(n)]
        ref_old = handle.engine(serve.replace(warmup=False))
        expect_old = [np.asarray(ref_old.backend.topk(jnp.asarray(x))[1])
                      for x in requests]
        expect_new = [np.asarray(new_engine.backend.topk(jnp.asarray(x))[1])
                      for x in requests]

        gaps = rng.exponential(1.0 / cfg["rate_rps"], size=n)
        swap_at = n // 2
        swap_win = {}

        def do_swap():
            swap_win["t0"] = time.monotonic()
            server.swap(new_engine)
            swap_win["t1"] = time.monotonic()

        swapper = threading.Thread(target=do_swap)
        inbox: queue_mod.Queue = queue_mod.Queue()
        t_sub = [0.0] * n
        t_fin = [0.0] * n
        results = [None] * n

        def collect():
            for _ in range(n):
                i, fut = inbox.get()
                results[i] = fut.result(timeout=120)
                t_fin[i] = time.monotonic()

        collector = threading.Thread(target=collect)
        collector.start()
        t_wall0 = time.monotonic()
        t_next = t_wall0
        for i, (x, gap) in enumerate(zip(requests, gaps)):
            t_next += gap
            now = time.monotonic()
            if t_next > now:
                time.sleep(t_next - now)
            if i == swap_at:
                swapper.start()
            t_sub[i] = time.monotonic()
            inbox.put((i, server.submit(x)))
        swapper.join()
        collector.join()
        wall = time.monotonic() - t_wall0
        server.stop()

        # Zero-downtime accounting: every accepted request resolved, none
        # rejected, and both generations actually answered traffic.
        counters = dict(server.counters)
        assert all(r is not None and not isinstance(r, Rejected)
                   for r in results)
        # Per-request attribution. The generations may agree on easy
        # queries (same top-k under either Delta) — those are "both";
        # "neither" means an answer matching no generation, which the
        # no-torn-batch guarantee forbids.
        n_old = n_new = n_neither = 0
        for i, r in enumerate(results):
            is_old = np.array_equal(r.labels, expect_old[i])
            is_new = np.array_equal(r.labels, expect_new[i])
            if is_old and not is_new:
                n_old += 1
            elif is_new and not is_old:
                n_new += 1
            elif not (is_old or is_new):
                n_neither += 1

        lat_ms = [(t_fin[i] - t_sub[i]) * 1e3 for i in range(n)]
        pad = SWAP_WINDOW_PAD_MS / 1e3
        w0, w1 = swap_win["t0"] - pad, swap_win["t1"] + pad
        in_w = [i for i in range(n) if t_sub[i] <= w1 and t_fin[i] >= w0]
        out_w = sorted(set(range(n)) - set(in_w))
        p99_in = (float(np.percentile([lat_ms[i] for i in in_w], 99))
                  if in_w else 0.0)
        p99_out = float(np.percentile([lat_ms[i] for i in out_w], 99))
        flip = server.last_swap
        return {"bench": "serve_latency", "mode": "refresh_under_load",
                "smoke": smoke, "backend": "dense", "k": K,
                "n_offered": n, "offered_load_rps": cfg["rate_rps"],
                "buckets": list(SERVER_BUCKETS), "wall_s": wall,
                "delta_old": 0.01, "delta_new": REFRESH_DELTA,
                "n_completed": counters["completed"],
                "n_rejected": counters["rejected"],
                "n_swaps": counters["swaps"],
                "n_old_model": n_old, "n_new_model": n_new,
                "n_unattributable": n_neither,
                "swap_warm_ms": flip["warm_ms"],
                "swap_blackout_ms": flip["flip_ms"],
                "swap_window_ms": (w1 - w0) * 1e3,
                "n_in_window": len(in_w),
                "p99_ms_during_swap": p99_in,
                "p99_ms_steady": p99_out,
                "p50_ms_steady": float(np.percentile(
                    [lat_ms[i] for i in out_w], 50))}


def recall_at_k(reference, candidate) -> float:
    """Mean fraction of the reference engine's top-k label set the
    candidate engine recovered, per instance."""
    hits, total = 0, 0
    for ref, got in zip(reference, candidate):
        for row_ref, row_got in zip(ref.labels, got.labels):
            hits += len(set(row_ref.tolist()) & set(row_got.tolist()))
            total += len(row_ref)
    return hits / total


def topk_jaccard(reference, candidate) -> float:
    """Mean per-instance Jaccard similarity of the two engines' top-k
    label sets (1.0 = identical sets; order-insensitive)."""
    vals = []
    for ref, got in zip(reference, candidate):
        for row_ref, row_got in zip(ref.labels, got.labels):
            a, b = set(row_ref.tolist()), set(row_got.tolist())
            vals.append(len(a & b) / len(a | b))
    return float(np.mean(vals))


def main(smoke: bool = False):
    n_requests = N_REQUESTS_SMOKE if smoke else N_REQUESTS
    demo = (dict(n_train=200, n_test=64, n_features=512, n_labels=64,
                 label_batch=32) if smoke else
            dict(n_train=800, n_test=512, n_features=4096, n_labels=256,
                 label_batch=128))
    rows_out = []

    # -- part 1: latency per backend on the shared demo checkpoint --------
    with tempfile.TemporaryDirectory() as ckpt:
        # Shared demo pipeline (spec-driven fit) — the same setup behind
        # launch/serve.py --xmc and examples/serve_xmc.py. The handle
        # serves each backend by overriding just the ServeSpec.
        data, _ = train_demo_checkpoint(ckpt, seed=0, **demo)
        handle = CheckpointHandle.open(ckpt)
        bsr, _ = handle.model()

        requests = make_requests(np.asarray(data.X_test, np.float32),
                                 n_requests)
        n_inst = sum(r.shape[0] for r in requests)

        for kind in BACKENDS:
            t0 = time.time()
            engine = handle.engine(ServeSpec(backend=kind, k=K))
            t_load = time.time() - t0
            results, wall = serve_closed_loop(engine, requests)
            stats = engine.latency_summary()
            assert len(results) == n_requests
            assert stats["count"] == n_requests
            rec = {"bench": "serve_latency", "backend": kind, "smoke": smoke,
                   "n_requests": n_requests, "n_instances": n_inst,
                   "k": K, "block_density": bsr.density,
                   "load_warmup_s": t_load,
                   "p50_ms": stats["p50_ms"], "p90_ms": stats["p90_ms"],
                   "p99_ms": stats["p99_ms"], "mean_ms": stats["mean_ms"],
                   "throughput_inst_per_s": n_inst / wall}
            emit(rec)
            rows_out.append({"backend": kind, "p50_ms": stats["p50_ms"],
                             "p99_ms": stats["p99_ms"],
                             "mean_ms": stats["mean_ms"],
                             "inst/s": n_inst / wall})

        # -- part 3: open-loop Poisson load through the async server ------
        # Same checkpoint; the load generator submits on its own clock.
        pool = np.asarray(data.X_test, np.float32)
        low = dict(SERVER_LOW_SMOKE if smoke else SERVER_LOW)
        over = dict(SERVER_OVERLOAD_SMOKE if smoke else SERVER_OVERLOAD)
        low["n_requests"] = max(SMOKE_FLOOR, low["n_requests"])
        over["n_requests"] = max(SMOKE_FLOOR, over["n_requests"])
        server_recs = {}
        for policy, delay_ms in (("deadline", low["deadline_ms"]),
                                 ("fill_only", FILL_ONLY_DELAY_MS)):
            server_recs[policy] = run_open_loop(
                handle, pool, n_requests=low["n_requests"],
                rate_rps=low["rate_rps"], delay_ms=delay_ms,
                buckets=SERVER_BUCKETS, policy=policy, smoke=smoke, seed=2)
        server_recs["overload"] = run_open_loop(
            handle, pool, n_requests=over["n_requests"], rate_rps=None,
            delay_ms=low["deadline_ms"], buckets=OVERLOAD_BUCKETS,
            policy="overload_admission", smoke=smoke,
            max_queue=over["max_queue"], seed=3)
        for rec in server_recs.values():
            emit(rec)

    print_table("serving latency per backend "
                f"({n_requests} ragged requests, {n_inst} instances, k={K})",
                rows_out, ["backend", "p50_ms", "p99_ms", "mean_ms", "inst/s"])

    print_table(
        f"open-loop Poisson server (arrival-to-completion, "
        f"{low['n_requests']} offered at {low['rate_rps']} rps; overload = "
        f"{over['n_requests']}-request burst, max_queue={over['max_queue']})",
        [{"policy": name, "p50_ms": r["p50_ms"], "p99_ms": r["p99_ms"],
          "qwait_p99_ms": r["queue_wait_p99_ms"],
          "goodput_rps": r["goodput_rps"], "reject_rate": r["reject_rate"]}
         for name, r in server_recs.items()],
        ["policy", "p50_ms", "p99_ms", "qwait_p99_ms", "goodput_rps",
         "reject_rate"])

    # Continuous-batching acceptance gates, live in CI (verify.sh --smoke):
    # deadline launch must beat drain-on-full on tail latency below
    # saturation, and admission control must shed overload with bounded
    # queue wait for what it accepts.
    dl, fo, ov = (server_recs["deadline"], server_recs["fill_only"],
                  server_recs["overload"])
    assert dl["p99_ms"] < fo["p99_ms"], \
        (f"deadline launch p99 {dl['p99_ms']:.1f}ms not below drain-on-full "
         f"p99 {fo['p99_ms']:.1f}ms at {low['rate_rps']} rps")
    assert ov["reject_rate"] > 0, \
        "overload burst produced no rejections: admission control inert"
    assert ov["queue_wait_p99_ms"] < QUEUE_WAIT_BOUND_MS, \
        (f"accepted-request queue wait p99 {ov['queue_wait_p99_ms']:.1f}ms "
         f"not bounded under overload (limit {QUEUE_WAIT_BOUND_MS}ms)")

    # -- part 5: zero-downtime refresh under open-loop load ---------------
    refresh = run_refresh_under_load(smoke=smoke)
    emit(refresh)
    print_table(
        f"refresh under load ({refresh['n_offered']} offered at "
        f"{refresh['offered_load_rps']} rps, swap mid-stream)",
        [{"p99_swap_ms": refresh["p99_ms_during_swap"],
          "p99_steady_ms": refresh["p99_ms_steady"],
          "blackout_ms": refresh["swap_blackout_ms"],
          "warm_ms": refresh["swap_warm_ms"],
          "old/new": f"{refresh['n_old_model']}/{refresh['n_new_model']}"}],
        ["p99_swap_ms", "p99_steady_ms", "blackout_ms", "warm_ms",
         "old/new"])

    # Zero-downtime refresh gates, live in CI (tools/verify.sh --smoke):
    # the swap drops nothing and both generations serve, and requests in
    # flight during the swap keep a tail within 2x of steady state.
    assert refresh["n_completed"] == refresh["n_offered"], \
        (f"refresh dropped accepted requests: {refresh['n_completed']} of "
         f"{refresh['n_offered']} completed")
    assert refresh["n_rejected"] == 0 and refresh["n_swaps"] == 1
    assert refresh["n_old_model"] > 0 and refresh["n_new_model"] > 0, \
        ("swap did not split traffic across generations: "
         f"{refresh['n_old_model']} old / {refresh['n_new_model']} new")
    assert refresh["n_unattributable"] == 0, \
        (f"{refresh['n_unattributable']} answers match neither generation "
         "— a micro-batch was torn across the swap")
    assert refresh["p99_ms_during_swap"] <= \
        SWAP_P99_FACTOR * refresh["p99_ms_steady"], \
        (f"p99 during swap {refresh['p99_ms_during_swap']:.1f}ms exceeds "
         f"{SWAP_P99_FACTOR}x steady-state p99 "
         f"{refresh['p99_ms_steady']:.1f}ms")

    # -- part 2: shortlist vs exhaustive on the finer-block checkpoint ----
    from repro.kernels.bsr_predict import ops as bsr_ops

    demo2 = SHORTLIST_DEMO_SMOKE if smoke else SHORTLIST_DEMO
    with tempfile.TemporaryDirectory() as ckpt:
        data, _ = train_demo_checkpoint(ckpt, seed=0, **demo2)
        handle = CheckpointHandle.open(ckpt)
        model, _ = handle.model()
        # Single-instance requests: block selection is per-micro-batch, so
        # this measures the per-QUERY candidate stage — the latency-serving
        # regime the sub-linear gate is about. Co-batching unrelated
        # queries shares one B-block shortlist across all of them; widen
        # shortlist_blocks accordingly for throughput-batched serving.
        requests = make_requests(np.asarray(data.X_test, np.float32),
                                 n_requests, seed=1, max_rows=1)
        n_inst = sum(r.shape[0] for r in requests)

        ex_engine = handle.engine(ServeSpec(backend="bsr", k=K))
        ex_results, ex_wall = serve_closed_loop(ex_engine, requests)
        ex_stats = ex_engine.latency_summary()

        sl_engine = handle.engine(
            ServeSpec(backend="shortlist", k=K,
                      shortlist_blocks=SHORTLIST_B))
        backend = sl_engine.backend
        assert backend.name == "shortlist", \
            "demo checkpoint is missing its shortlist artifact"
        sl_results, sl_wall = serve_closed_loop(sl_engine, requests)
        sl_stats = sl_engine.latency_summary()

        recall = recall_at_k(ex_results, sl_results)
        fraction = backend.candidate_fraction
        # Measured fine-stage work: FLOPs of the gathered blocks each
        # request actually scored vs exhaustive scoring of every packed
        # block — per-query compute proportional to B * block_size, not L.
        fine = sum(bsr_ops.gather_flops(model, r.shape[0],
                                        backend.select_blocks(r))
                   for r in requests)
        exhaustive = sum(bsr_ops.model_flops(model, r.shape[0])
                         for r in requests)
        rec = {"bench": "serve_latency", "backend": "shortlist_vs_bsr",
               "smoke": smoke, "n_requests": n_requests,
               "n_instances": n_inst, "k": K,
               "n_labels": demo2["n_labels"],
               "n_row_blocks": backend.artifact.n_row_blocks,
               "shortlist_blocks": backend.B,
               "candidate_fraction": fraction,
               "recall_at_k": recall,
               "fine_flops": fine, "exhaustive_flops": exhaustive,
               "fine_flops_frac": fine / exhaustive,
               "p50_ms_shortlist": sl_stats["p50_ms"],
               "p50_ms_exhaustive": ex_stats["p50_ms"],
               "mean_ms_shortlist": sl_stats["mean_ms"],
               "mean_ms_exhaustive": ex_stats["mean_ms"],
               "throughput_inst_per_s_shortlist": n_inst / sl_wall,
               "throughput_inst_per_s_exhaustive": n_inst / ex_wall}
        emit(rec)
        print_table(
            f"shortlist vs exhaustive (L={demo2['n_labels']}, "
            f"R={backend.artifact.n_row_blocks} row blocks, B={backend.B})",
            [{"scoring": "exhaustive bsr", "p50_ms": ex_stats["p50_ms"],
              "mean_ms": ex_stats["mean_ms"], "flops_frac": 1.0,
              "recall@k": 1.0},
             {"scoring": "shortlist", "p50_ms": sl_stats["p50_ms"],
              "mean_ms": sl_stats["mean_ms"],
              "flops_frac": fine / exhaustive, "recall@k": recall}],
            ["scoring", "p50_ms", "mean_ms", "flops_frac", "recall@k"])

        # The PR's acceptance gate, live in CI (tools/verify.sh --smoke).
        assert fraction < FRACTION_GATE, \
            f"candidate fraction {fraction:.3f} not sub-linear (< 25%)"
        assert recall >= RECALL_GATE, \
            f"recall@{K} {recall:.3f} below the {RECALL_GATE} gate"

        # -- part 4: int8 vs fp32 on the same finer-block checkpoint ------
        from repro.checkpoint.io import load_block_sparse_int8

        q_model, _ = load_block_sparse_int8(ckpt, model=model)
        bl, bd = model.block_shape
        fp32_bytes = 4 * model.n_blocks * bl * bd
        bytes_ratio = q_model.payload_bytes() / fp32_bytes

        i8_engine = handle.engine(ServeSpec(backend="int8", k=K))
        i8_results, i8_wall = serve_closed_loop(i8_engine, requests)
        i8_stats = i8_engine.latency_summary()
        agreement = recall_at_k(ex_results, i8_results)
        jaccard = topk_jaccard(ex_results, i8_results)

        # Composition: the shortlist coarse gate over the gathered-int8
        # fine stage, judged against the fp32 shortlist on the SAME
        # candidate sets (the coarse stage is identical, so any
        # disagreement is pure quantization).
        sli8_engine = handle.engine(
            ServeSpec(backend="shortlist", k=K,
                      shortlist_blocks=SHORTLIST_B, int8=True))
        assert getattr(sli8_engine.backend, "int8", False), \
            "shortlist backend did not engage its int8 fine stage"
        sli8_results, _ = serve_closed_loop(sli8_engine, requests)
        sl_agreement = recall_at_k(sl_results, sli8_results)
        sl_jaccard = topk_jaccard(sl_results, sli8_results)

        rec = {"bench": "serve_latency", "backend": "int8_vs_fp32",
               "smoke": smoke, "n_requests": n_requests,
               "n_instances": n_inst, "k": K,
               "n_labels": demo2["n_labels"], "n_blocks": model.n_blocks,
               "block_shape": [bl, bd],
               "bytes_int8": q_model.payload_bytes(),
               "bytes_fp32": fp32_bytes, "bytes_ratio": bytes_ratio,
               "topk_agreement_at_k": agreement, "topk_jaccard": jaccard,
               "shortlist_topk_agreement_at_k": sl_agreement,
               "shortlist_topk_jaccard": sl_jaccard,
               "p50_ms_int8": i8_stats["p50_ms"],
               "p50_ms_fp32": ex_stats["p50_ms"],
               "mean_ms_int8": i8_stats["mean_ms"],
               "mean_ms_fp32": ex_stats["mean_ms"],
               "throughput_inst_per_s_int8": n_inst / i8_wall}
        emit(rec)
        print_table(
            f"int8 vs fp32 (L={demo2['n_labels']}, {model.n_blocks} blocks "
            f"of {bl}x{bd}, bytes ratio {bytes_ratio:.3f})",
            [{"path": "exhaustive", "agreement@k": agreement,
              "jaccard": jaccard, "p50_ms_int8": i8_stats["p50_ms"],
              "p50_ms_fp32": ex_stats["p50_ms"]},
             {"path": "shortlist", "agreement@k": sl_agreement,
              "jaccard": sl_jaccard, "p50_ms_int8": None,
              "p50_ms_fp32": sl_stats["p50_ms"]}],
            ["path", "agreement@k", "jaccard", "p50_ms_int8",
             "p50_ms_fp32"])

        # Int8 acceptance gates, live in CI (tools/verify.sh --smoke):
        # the quantized artifact must actually be small, and must not
        # change what gets served — on the exhaustive path AND composed
        # with the shortlist gate.
        assert bytes_ratio <= 0.55, \
            (f"int8 payload {q_model.payload_bytes()} bytes is "
             f"{bytes_ratio:.3f}x fp32 (gate: <= 0.55)")
        assert agreement >= 0.99, \
            f"int8 top-{K} agreement {agreement:.4f} below the 0.99 gate"
        assert sl_agreement >= 0.99, \
            (f"shortlist-composed int8 top-{K} agreement "
             f"{sl_agreement:.4f} below the 0.99 gate")

    # -- part 6: learned coarse stage vs centroid + per-query gates -------
    import shutil

    from repro.checkpoint.io import (SHORTLIST_FILE, load_shortlist,
                                     upgrade_shortlist)
    from repro.serve.shortlist import build_learned_shortlist, coarse_scores

    demo6 = COARSE_DEMO_SMOKE if smoke else COARSE_DEMO
    newton = COARSE_NEWTON_SMOKE if smoke else COARSE_NEWTON
    with tempfile.TemporaryDirectory() as root:
        ckpt = os.path.join(root, "ckpt")
        data, _ = train_demo_checkpoint(ckpt, seed=0, **demo6)
        handle = CheckpointHandle.open(ckpt)
        model, _ = handle.model()
        bl = model.block_shape[0]
        requests = make_requests(np.asarray(data.X_test, np.float32),
                                 n_requests, seed=2, max_rows=1)
        ex_engine = handle.engine(ServeSpec(backend="bsr", k=K))
        ex_results, _ = serve_closed_loop(ex_engine, requests)

        # Coverage == id recall for an exact fine stage: the served top-k
        # is the exhaustive top-k restricted to the selected blocks, so
        # recall@k at width B is the fraction of exhaustive top-k labels
        # whose row block makes the query's top-B coarse blocks. The
        # width sweep therefore runs host-side (coarse_scores) instead of
        # re-serving at every B.
        Xq = np.concatenate(requests, axis=0)            # max_rows=1
        blocks_ex = np.stack(
            [np.asarray(r.labels)[0] for r in ex_results]) // bl

        cen_art = load_shortlist(ckpt)                   # finalize default
        assert cen_art is not None and cen_art.kind == "centroid"
        lrn_art = build_learned_shortlist(
            model, np.asarray(data.X_train, np.float32),
            np.asarray(data.Y_train), max_newton=newton)
        R = cen_art.n_row_blocks

        def min_width(art):
            order = np.argsort(-coarse_scores(art, Xq), axis=1)
            for B in range(1, R + 1):
                cov = float(np.mean([np.isin(blocks_ex[i], order[i, :B])
                                     .mean() for i in range(len(Xq))]))
                if cov >= RECALL_GATE:
                    return B, cov
            return R, 1.0

        b_cen, rec_cen = min_width(cen_art)
        b_lrn, rec_lrn = min_width(lrn_art)

        # Install the learned artifact (the post-finalize upgrade `fit`
        # performs for ServeSpec(shortlist_kind="learned")) and serve at
        # its minimal width — the host-side sweep must survive the real
        # serving stack.
        upgrade_shortlist(ckpt, lrn_art)
        assert load_shortlist(ckpt).kind == "learned"
        lrn_engine = handle.engine(
            ServeSpec(backend="shortlist", k=K, shortlist_blocks=b_lrn,
                      shortlist_kind="learned"))
        assert lrn_engine.backend.kind == "learned"
        lrn_results, _ = serve_closed_loop(lrn_engine, requests)
        recall_served = recall_at_k(ex_results, lrn_results)

        rec = {"bench": "serve_latency", "backend": "coarse_stage",
               "smoke": smoke, "n_requests": n_requests, "k": K,
               "n_labels": demo6["n_labels"], "n_row_blocks": R,
               "min_blocks_centroid": b_cen, "min_blocks_learned": b_lrn,
               "fraction_centroid": b_cen / R, "fraction_learned": b_lrn / R,
               "recall_centroid": rec_cen, "recall_learned": rec_lrn,
               "recall_learned_served": recall_served}
        emit(rec)
        print_table(
            f"coarse stage: min width for recall@{K} >= {RECALL_GATE} "
            f"(L={demo6['n_labels']}, R={R})",
            [{"coarse": "centroid", "min_B": b_cen,
              "fraction": b_cen / R, "recall@k": rec_cen},
             {"coarse": "learned", "min_B": b_lrn,
              "fraction": b_lrn / R, "recall@k": rec_lrn}],
            ["coarse", "min_B", "fraction", "recall@k"])

        # The learned-coarse-stage acceptance gates, live in CI
        # (tools/verify.sh --smoke): same recall, strictly fewer blocks.
        assert rec_lrn >= RECALL_GATE and recall_served >= RECALL_GATE, \
            (f"learned coarse stage recall {rec_lrn:.3f} / served "
             f"{recall_served:.3f} below the {RECALL_GATE} gate")
        assert b_lrn < b_cen, \
            (f"learned coarse stage needs {b_lrn}/{R} blocks, not strictly "
             f"fewer than the centroid baseline's {b_cen}/{R}")

        # Per-query ragged gather gates: B = R must collapse to the shared
        # executable and stay bit-exact vs exhaustive BSR (scores AND ids);
        # below full width, single-row requests are bit-identical between
        # the per-query and shared paths.
        pq_full = handle.engine(
            ServeSpec(backend="shortlist", k=K, shortlist_blocks=R,
                      shortlist_kind="learned", shortlist_per_query=True))
        assert pq_full.backend.per_query is False   # collapsed at B == R
        pq_results, _ = serve_closed_loop(pq_full, requests)
        for r_ex, r_pq in zip(ex_results, pq_results):
            assert np.array_equal(r_ex.labels, r_pq.labels) and \
                np.array_equal(r_ex.scores, r_pq.scores), \
                "per-query B=R is not bit-exact vs exhaustive BSR"

        shared_engine = handle.engine(
            ServeSpec(backend="shortlist", k=K, shortlist_blocks=b_lrn,
                      shortlist_kind="learned"))
        pq_engine = handle.engine(
            ServeSpec(backend="shortlist", k=K, shortlist_blocks=b_lrn,
                      shortlist_kind="learned", shortlist_per_query=True))
        assert pq_engine.backend.per_query is True
        sh_results, _ = serve_closed_loop(shared_engine, requests)
        pq_results, _ = serve_closed_loop(pq_engine, requests)
        for r_sh, r_pq in zip(sh_results, pq_results):
            assert np.array_equal(r_sh.labels, r_pq.labels) and \
                np.array_equal(r_sh.scores, r_pq.scores), \
                "per-query single-row serving diverged from the shared path"

        # Fallback regression: legacy checkpoints (no artifact) and v1
        # artifacts (pre-versioned npz) still serve through the same spec.
        legacy = os.path.join(root, "legacy")
        shutil.copytree(ckpt, legacy)
        os.remove(os.path.join(legacy, SHORTLIST_FILE))
        leg_engine = CheckpointHandle.open(legacy).engine(
            ServeSpec(backend="shortlist", k=K))
        assert leg_engine.backend.name == "bsr"     # silent exhaustive
        leg_results, _ = serve_closed_loop(leg_engine, requests[:8])
        for r_ex, r_leg in zip(ex_results[:8], leg_results):
            assert np.array_equal(r_ex.labels, r_leg.labels)

        v1 = os.path.join(root, "v1")
        shutil.copytree(ckpt, v1)
        np.savez(os.path.join(v1, SHORTLIST_FILE),   # exactly the v1 keys
                 centroids=np.asarray(cen_art.centroids, np.float32),
                 block_rows=np.int64(cen_art.block_rows),
                 n_labels=np.int64(cen_art.n_labels),
                 stat=np.asarray(cen_art.stat))
        v1_engine = CheckpointHandle.open(v1).engine(
            ServeSpec(backend="shortlist", k=K, shortlist_blocks=R))
        assert v1_engine.backend.kind == "centroid"  # v1 loads as centroid
        v1_results, _ = serve_closed_loop(v1_engine, requests[:8])
        for r_ex, r_v1 in zip(ex_results[:8], v1_results):
            assert np.array_equal(r_ex.labels, r_v1.labels)

    print(f"\nwrote {OUT_JSON}")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    main(smoke=args.smoke)
