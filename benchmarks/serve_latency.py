"""Serving latency benchmark: p50/p99 per predict backend.

Drives the same ragged request stream through each `repro.serve.XMCEngine`
backend (dense / bsr / sharded) from one shared sparse checkpoint, and
emits a `BENCH_serve.json` line per backend with latency percentiles,
throughput, and the model's block density. This is the serving-side
companion of table_prediction_speed (which measures raw predict calls
without the queue/bucketing layer).
"""

from __future__ import annotations

import tempfile
import time

import numpy as np

from benchmarks._common import emit_json, print_table
from repro.serve import BACKENDS
from repro.specs import ServeSpec
from repro.train.xmc import train_demo_checkpoint
from repro.xmc_api import CheckpointHandle

OUT_JSON = "BENCH_serve.json"

N_REQUESTS = 64
MAX_ROWS = 8
K = 5


def main(smoke: bool = False):
    n_requests = 8 if smoke else N_REQUESTS
    demo = (dict(n_train=200, n_test=64, n_features=512, n_labels=64,
                 label_batch=32) if smoke else
            dict(n_train=800, n_test=512, n_features=4096, n_labels=256,
                 label_batch=128))
    rows_out = []
    with tempfile.TemporaryDirectory() as ckpt:
        # Shared demo pipeline (spec-driven fit) — the same setup behind
        # launch/serve.py --xmc and examples/serve_xmc.py. The handle
        # serves each backend by overriding just the ServeSpec.
        data, _ = train_demo_checkpoint(ckpt, seed=0, **demo)
        handle = CheckpointHandle.open(ckpt)
        bsr, _ = handle.model()

        rng = np.random.default_rng(0)
        X = np.asarray(data.X_test, np.float32)
        requests = []
        for _ in range(n_requests):
            n_i = int(rng.integers(1, MAX_ROWS + 1))
            rows = rng.integers(0, X.shape[0], size=n_i)
            requests.append(X[rows])
        n_inst = sum(r.shape[0] for r in requests)

        for kind in BACKENDS:
            t0 = time.time()
            engine = handle.engine(ServeSpec(backend=kind, k=K))
            t_load = time.time() - t0
            t0 = time.time()
            results = engine.serve(requests)
            wall = time.time() - t0
            stats = engine.latency_summary()
            assert len(results) == n_requests
            rec = {"bench": "serve_latency", "backend": kind, "smoke": smoke,
                   "n_requests": n_requests, "n_instances": n_inst,
                   "k": K, "block_density": bsr.density,
                   "load_warmup_s": t_load,
                   "p50_ms": stats["p50_ms"], "p90_ms": stats["p90_ms"],
                   "p99_ms": stats["p99_ms"], "mean_ms": stats["mean_ms"],
                   "throughput_inst_per_s": n_inst / wall}
            emit_json(OUT_JSON, rec)
            rows_out.append({"backend": kind, "p50_ms": stats["p50_ms"],
                             "p99_ms": stats["p99_ms"],
                             "mean_ms": stats["mean_ms"],
                             "inst/s": n_inst / wall})

    print_table("serving latency per backend "
                f"({n_requests} ragged requests, {n_inst} instances, k={K})",
                rows_out, ["backend", "p50_ms", "p99_ms", "mean_ms", "inst/s"])
    print(f"\nwrote {OUT_JSON}")


if __name__ == "__main__":
    main()
