"""Serving latency benchmark: p50/p99 per predict backend, plus the
shortlist-vs-exhaustive sub-linear serving gate.

Part 1 drives the same ragged request stream through each
`repro.serve.XMCEngine` backend (dense / bsr / sharded / shortlist) from
one shared sparse checkpoint and emits a `BENCH_serve.json` line per
backend. Requests run CLOSED LOOP — one submit + step per request — so
every request contributes its own latency sample and the percentiles are
real order statistics over n_requests samples, not one batched-drain
timestamp smeared across every request (the old scheme made
p50 == p90 == p99 by construction).

Part 2 is the sub-linear serving gate: a second, finer-row-block demo
checkpoint (enough row blocks for a meaningful candidate stage) is served
by the shortlist backend against exhaustive BSR on identical requests, and
the emitted row records recall@k vs exhaustive, the candidate fraction
B / n_row_blocks, and the measured fine-stage FLOP fraction (gathered
blocks vs all packed blocks). The run asserts candidate fraction < 25%
at recall@k >= 0.95 — the acceptance criterion of the shortlist PR, live
in --smoke so tools/verify.sh gates it.

This is the serving-side companion of table_prediction_speed (which
measures raw predict calls without the queue/bucketing layer).
"""

from __future__ import annotations

import tempfile
import time

import numpy as np

from benchmarks._common import emit_json, print_table
from repro.serve import BACKENDS
from repro.specs import ServeSpec
from repro.train.xmc import train_demo_checkpoint
from repro.xmc_api import CheckpointHandle

OUT_JSON = "BENCH_serve.json"

N_REQUESTS = 64
N_REQUESTS_SMOKE = 32                  # enough samples for distinct p50/p90
MAX_ROWS = 8
K = 5

# Part 2's finer-block demo model: the default serving checkpoint tiles
# labels into 128-row blocks, which leaves the smoke model (64 labels) ONE
# row block — nothing to shortlist. These dims give R = 16 row blocks in
# both profiles, so a B-of-R candidate stage is measurable. The data knobs
# make the label space cluster-ordered (overlapping adjacent signature
# pools, co-occurring labels adjacent) — the regime real XMC candidate
# stages serve, where label orderings come from trees/clusters. With fully
# independent labels a query's top-k tail is unstructured noise that NO
# candidate stage can cover.
CLUSTER_DATA = dict(pool_stride=2, label_locality=0.9, multi_label_p=0.9)
SHORTLIST_DEMO = dict(n_train=800, n_test=512, n_features=4096,
                      n_labels=512, label_batch=128, block_shape=(32, 128),
                      data_kwargs=CLUSTER_DATA)
SHORTLIST_DEMO_SMOKE = dict(n_train=240, n_test=64, n_features=1024,
                            n_labels=128, label_batch=64,
                            block_shape=(8, 128), data_kwargs=CLUSTER_DATA)
SHORTLIST_B = 3                        # candidate blocks: 3/16 = 18.75% < 25%
RECALL_GATE = 0.95
FRACTION_GATE = 0.25


def make_requests(X: np.ndarray, n_requests: int, seed: int = 0,
                  max_rows: int = MAX_ROWS):
    rng = np.random.default_rng(seed)
    requests = []
    for _ in range(n_requests):
        n_i = int(rng.integers(1, max_rows + 1))
        requests.append(X[rng.integers(0, X.shape[0], size=n_i)])
    return requests


def serve_closed_loop(engine, requests):
    """One submit + drain per request: each request is dispatched alone and
    lands one latency sample, so percentiles are per-request order
    statistics. Returns (results, wall_seconds)."""
    results = []
    t0 = time.time()
    for x in requests:
        engine.submit(x)
        results.extend(engine.step())
    return results, time.time() - t0


def recall_at_k(reference, candidate) -> float:
    """Mean fraction of the reference engine's top-k label set the
    candidate engine recovered, per instance."""
    hits, total = 0, 0
    for ref, got in zip(reference, candidate):
        for row_ref, row_got in zip(ref.labels, got.labels):
            hits += len(set(row_ref.tolist()) & set(row_got.tolist()))
            total += len(row_ref)
    return hits / total


def main(smoke: bool = False):
    n_requests = N_REQUESTS_SMOKE if smoke else N_REQUESTS
    demo = (dict(n_train=200, n_test=64, n_features=512, n_labels=64,
                 label_batch=32) if smoke else
            dict(n_train=800, n_test=512, n_features=4096, n_labels=256,
                 label_batch=128))
    rows_out = []

    # -- part 1: latency per backend on the shared demo checkpoint --------
    with tempfile.TemporaryDirectory() as ckpt:
        # Shared demo pipeline (spec-driven fit) — the same setup behind
        # launch/serve.py --xmc and examples/serve_xmc.py. The handle
        # serves each backend by overriding just the ServeSpec.
        data, _ = train_demo_checkpoint(ckpt, seed=0, **demo)
        handle = CheckpointHandle.open(ckpt)
        bsr, _ = handle.model()

        requests = make_requests(np.asarray(data.X_test, np.float32),
                                 n_requests)
        n_inst = sum(r.shape[0] for r in requests)

        for kind in BACKENDS:
            t0 = time.time()
            engine = handle.engine(ServeSpec(backend=kind, k=K))
            t_load = time.time() - t0
            results, wall = serve_closed_loop(engine, requests)
            stats = engine.latency_summary()
            assert len(results) == n_requests
            assert stats["count"] == n_requests
            rec = {"bench": "serve_latency", "backend": kind, "smoke": smoke,
                   "n_requests": n_requests, "n_instances": n_inst,
                   "k": K, "block_density": bsr.density,
                   "load_warmup_s": t_load,
                   "p50_ms": stats["p50_ms"], "p90_ms": stats["p90_ms"],
                   "p99_ms": stats["p99_ms"], "mean_ms": stats["mean_ms"],
                   "throughput_inst_per_s": n_inst / wall}
            emit_json(OUT_JSON, rec)
            rows_out.append({"backend": kind, "p50_ms": stats["p50_ms"],
                             "p99_ms": stats["p99_ms"],
                             "mean_ms": stats["mean_ms"],
                             "inst/s": n_inst / wall})

    print_table("serving latency per backend "
                f"({n_requests} ragged requests, {n_inst} instances, k={K})",
                rows_out, ["backend", "p50_ms", "p99_ms", "mean_ms", "inst/s"])

    # -- part 2: shortlist vs exhaustive on the finer-block checkpoint ----
    from repro.kernels.bsr_predict import ops as bsr_ops

    demo2 = SHORTLIST_DEMO_SMOKE if smoke else SHORTLIST_DEMO
    with tempfile.TemporaryDirectory() as ckpt:
        data, _ = train_demo_checkpoint(ckpt, seed=0, **demo2)
        handle = CheckpointHandle.open(ckpt)
        model, _ = handle.model()
        # Single-instance requests: block selection is per-micro-batch, so
        # this measures the per-QUERY candidate stage — the latency-serving
        # regime the sub-linear gate is about. Co-batching unrelated
        # queries shares one B-block shortlist across all of them; widen
        # shortlist_blocks accordingly for throughput-batched serving.
        requests = make_requests(np.asarray(data.X_test, np.float32),
                                 n_requests, seed=1, max_rows=1)
        n_inst = sum(r.shape[0] for r in requests)

        ex_engine = handle.engine(ServeSpec(backend="bsr", k=K))
        ex_results, ex_wall = serve_closed_loop(ex_engine, requests)
        ex_stats = ex_engine.latency_summary()

        sl_engine = handle.engine(
            ServeSpec(backend="shortlist", k=K,
                      shortlist_blocks=SHORTLIST_B))
        backend = sl_engine.backend
        assert backend.name == "shortlist", \
            "demo checkpoint is missing its shortlist artifact"
        sl_results, sl_wall = serve_closed_loop(sl_engine, requests)
        sl_stats = sl_engine.latency_summary()

        recall = recall_at_k(ex_results, sl_results)
        fraction = backend.candidate_fraction
        # Measured fine-stage work: FLOPs of the gathered blocks each
        # request actually scored vs exhaustive scoring of every packed
        # block — per-query compute proportional to B * block_size, not L.
        fine = sum(bsr_ops.gather_flops(model, r.shape[0],
                                        backend.select_blocks(r))
                   for r in requests)
        exhaustive = sum(bsr_ops.model_flops(model, r.shape[0])
                         for r in requests)
        rec = {"bench": "serve_latency", "backend": "shortlist_vs_bsr",
               "smoke": smoke, "n_requests": n_requests,
               "n_instances": n_inst, "k": K,
               "n_labels": demo2["n_labels"],
               "n_row_blocks": backend.artifact.n_row_blocks,
               "shortlist_blocks": backend.B,
               "candidate_fraction": fraction,
               "recall_at_k": recall,
               "fine_flops": fine, "exhaustive_flops": exhaustive,
               "fine_flops_frac": fine / exhaustive,
               "p50_ms_shortlist": sl_stats["p50_ms"],
               "p50_ms_exhaustive": ex_stats["p50_ms"],
               "mean_ms_shortlist": sl_stats["mean_ms"],
               "mean_ms_exhaustive": ex_stats["mean_ms"],
               "throughput_inst_per_s_shortlist": n_inst / sl_wall,
               "throughput_inst_per_s_exhaustive": n_inst / ex_wall}
        emit_json(OUT_JSON, rec)
        print_table(
            f"shortlist vs exhaustive (L={demo2['n_labels']}, "
            f"R={backend.artifact.n_row_blocks} row blocks, B={backend.B})",
            [{"scoring": "exhaustive bsr", "p50_ms": ex_stats["p50_ms"],
              "mean_ms": ex_stats["mean_ms"], "flops_frac": 1.0,
              "recall@k": 1.0},
             {"scoring": "shortlist", "p50_ms": sl_stats["p50_ms"],
              "mean_ms": sl_stats["mean_ms"],
              "flops_frac": fine / exhaustive, "recall@k": recall}],
            ["scoring", "p50_ms", "mean_ms", "flops_frac", "recall@k"])

        # The PR's acceptance gate, live in CI (tools/verify.sh --smoke).
        assert fraction < FRACTION_GATE, \
            f"candidate fraction {fraction:.3f} not sub-linear (< 25%)"
        assert recall >= RECALL_GATE, \
            f"recall@{K} {recall:.3f} below the {RECALL_GATE} gate"

    print(f"\nwrote {OUT_JSON}")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    main(smoke=args.smoke)
