"""Paper §4.3 prediction complexity: 3 ms/instance on WikiLSHTC-325K via
distributed block evaluation of the pruned model.

On one CPU host we measure the per-instance wall time of:
  * dense predict (X @ W^T + top-k) — the naive baseline;
  * pruned-dense (same matmul on the Delta-pruned matrix — XLA can't skip
    zeros, so this isolates the *accuracy cost* of pruning from speed);
  * block-sparse predict (the Pallas BSR kernel in interpret mode — the
    FLOPs ratio is the structural speedup; wall time here reflects the
    Python interpreter, so the kernel reports model_flops/dense_flops).

Usage: PYTHONPATH=src python -m benchmarks.table_prediction_speed
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks._common import fit_dismec, load, print_table
from repro.core.pruning import to_block_sparse
from repro.kernels.bsr_predict import ops as bsr_ops


def _time(fn, *args, reps: int = 3):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.time() - t0) / reps


def run(dataset: str = "wikilshtc325k_like") -> list[dict]:
    data = load(dataset)
    model, _ = fit_dismec(data, delta=0.01)
    X = jnp.asarray(data.X_test)
    n = X.shape[0]

    dense_fn = jax.jit(lambda x, w: jax.lax.top_k(x @ w.T, 5))
    t_dense = _time(dense_fn, X, jnp.asarray(model.W))

    bsr = to_block_sparse(model.W, (128, 128))
    flops_ratio = bsr_ops.model_flops(bsr, n) / bsr_ops.dense_flops(bsr, n)

    return [{
        "dataset": dataset, "n_test": n,
        "dense_ms_per_inst": t_dense / n * 1e3,
        "bsr_block_density": bsr.density,
        "bsr_flops_ratio": flops_ratio,
        "modeled_bsr_ms": t_dense / n * 1e3 * flops_ratio,
    }]


def main():
    rows = run()
    print_table("SS4.3 prediction speed (per test instance)", rows,
                ["dataset", "n_test", "dense_ms_per_inst",
                 "bsr_block_density", "bsr_flops_ratio", "modeled_bsr_ms"])
    r = rows[0]
    print(f"\nBSR kernel executes {r['bsr_flops_ratio']:.2f}x the dense "
          "FLOPs (zero blocks skipped) -> paper's 'compact models => "
          "real-time prediction' claim, TPU-native form.")
    return rows


if __name__ == "__main__":
    main()
