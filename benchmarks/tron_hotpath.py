"""TRON hot-path benchmark: CG-iteration matmul accounting + scheduler
overlap (BENCH_tron.json).

Two claims of the margin-caching / double-buffering rework are measured:

  score passes   The generalized-Hessian product is "by far the most-
                 executed compute" (paper §2.1): it runs once per CG
                 iteration per Newton step. Pre-refactor, every CG
                 iteration re-derived the (L, N) active mask from a fresh
                 W @ X.T score matmul before the X v contraction — two
                 (L, N)-score-shaped passes per iteration. The cached-mask
                 protocol (core/tron.py) threads the mask `obj_grad_fn`
                 already produced, leaving ONE. Counted from the compiled
                 HLO of one CG iteration via `compat.cost_analysis`, cross-
                 checked against `launch.hlo_cost`'s dot-walking parser:
                 passes = total matmul flops / one (L,N,D) contraction,
                 minus the unavoidable X^T (act * Xv) output contraction.
                 The legacy protocol is emulated through the act_aux payload
                 (act_aux = W, hvp re-deriving the mask per call) — the same
                 trick lets us verify both protocols land on bit-identical
                 solutions.

  overlap        The streaming scheduler (train/xmc.py) used to block the
                 device through every host-side BSR pack + compressed shard
                 write. With overlap=True, batch b+1's solve is dispatched
                 before batch b's result leaves the device and the host leg
                 runs on a background worker: wall clock for the same
                 streamed training run drops below the sequential
                 scheduler's, and the checkpoints are byte-identical — the
                 served top-k from both must equal the legacy-protocol
                 solver's exactly.

Usage: PYTHONPATH=src python -m benchmarks.tron_hotpath
"""

from __future__ import annotations

import tempfile
import time

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks._common import emit_json, print_table
from repro.compat import cost_analysis
from repro.core import losses
from repro.core.dismec import DiSMECConfig
from repro.core.pruning import prune
from repro.core.tron import tron_solve
from repro.launch import hlo_cost
from repro.serve import XMCEngine
from repro.train.xmc import XMCTrainJob

OUT_JSON = "BENCH_tron.json"

# -- CG-iteration accounting problem: one (128, 128) tile so interpret-mode
#    Pallas lowers its grid to a single countable step.
L_CG, N_CG, D_CG = 128, 128, 256
C = 1.0

# -- Wall-clock solve problem: big enough that the removed (L, D) x (D, N)
#    mask matmul dominates the bookkeeping the cached protocol adds.
L_W, N_W, D_W = 256, 1024, 512

# -- Overlap smoke config (CPU-sized): enough batches to amortize the one
#    solver compile, and a shard write that is a large fraction of a batch
#    solve. On CPU the "device" compute and the host zlib pack share cores,
#    so concurrent writes stretch the solves they hide behind — a
#    write-heavy ratio keeps the overlap win visible through that
#    contention (a real TPU lane has no such sharing).
N_TRAIN, N_FEATURES, N_LABELS = 192, 4096, 640
LABEL_BATCH = 128
BLOCK = (128, 128)


def _cg_problem():
    rng = np.random.default_rng(0)
    X = jnp.asarray(rng.normal(size=(N_CG, D_CG)), jnp.float32)
    S = jnp.asarray(np.sign(rng.normal(size=(L_CG, N_CG))), jnp.float32)
    W = jnp.asarray(rng.normal(size=(L_CG, D_CG)) * 0.1, jnp.float32)
    V = jnp.asarray(rng.normal(size=(L_CG, D_CG)), jnp.float32)
    return X, S, W, V


def score_passes(fn, *args) -> dict:
    """Compile one CG iteration and convert its matmul flops into
    (L, N)-score-shaped passes: every contraction in the Hv chain touches
    2*L*N*D flops, and exactly one of them (X^T (act*Xv)) is the output
    contraction — the rest are score passes."""
    compiled = jax.jit(fn).lower(*args).compile()
    one_pass = 2.0 * L_CG * N_CG * D_CG
    flops_ca = float(cost_analysis(compiled).get("flops", 0.0))
    flops_hlo = float(hlo_cost.summarize(compiled.as_text())["flops"])
    return {
        "flops_cost_analysis": flops_ca,
        "flops_hlo_dots": flops_hlo,
        # cost_analysis includes elementwise flops; the dot-only HLO count
        # is the clean numerator. Both are emitted, the dot count decides.
        "score_passes_per_cg_iter": round(flops_hlo / one_pass) - 1,
        "score_passes_raw": flops_hlo / one_pass - 1.0,
    }


def bench_cg_passes():
    X, S, W, V = _cg_problem()
    act = losses.active_mask(W, X, S)

    def jnp_cached(v, a):
        return losses.hessian_vp(v, X, a, C)

    def jnp_legacy(v, w):
        return losses.hessian_vp(v, X, losses.active_mask(w, X, S), C)

    from repro.kernels.hvp import ops as hvp_ops

    def pallas_cached(v, a):
        return hvp_ops.hessian_vp(v, X, a, C)

    def pallas_legacy(v, w):
        return hvp_ops.hessian_vp(v, X, losses.active_mask(w, X, S), C)

    cases = [("jnp", "cached", jnp_cached, act),
             ("jnp", "legacy", jnp_legacy, W),
             ("pallas", "cached", pallas_cached, act),
             ("pallas", "legacy", pallas_legacy, W)]
    rows, by_key = [], {}
    for path, protocol, fn, aux in cases:
        rec = {"bench": "tron_hotpath", "metric": "cg_score_passes",
               "path": path, "protocol": protocol,
               "L": L_CG, "N": N_CG, "D": D_CG,
               **score_passes(fn, V, aux)}
        emit_json(OUT_JSON, rec)
        by_key[(path, protocol)] = rec["score_passes_per_cg_iter"]
        rows.append({"path": path, "protocol": protocol,
                     "passes/iter": rec["score_passes_per_cg_iter"],
                     "Mflops": rec["flops_hlo_dots"] / 1e6})
    print_table(f"(L,N)-score matmul passes per CG iteration "
                f"(L={L_CG}, N={N_CG}, D={D_CG})",
                rows, ["path", "protocol", "passes/iter", "Mflops"])
    for path in ("jnp", "pallas"):
        assert by_key[(path, "legacy")] == 2, by_key
        assert by_key[(path, "cached")] == 1, by_key
    print("score passes per CG iteration: 2 -> 1 on both paths")


def bench_solve_wall(L=L_W, N=N_W, D=D_W, repeats=3, smoke=False):
    """End-to-end tron_solve wall clock, cached vs legacy protocol, plus the
    bit-identity of their solutions (the legacy protocol emulated through
    the act_aux payload)."""
    rng = np.random.default_rng(5)
    X = jnp.asarray(rng.normal(size=(N, D)), jnp.float32)
    S = jnp.asarray(np.sign(rng.normal(size=(L, N))), jnp.float32)
    W0 = jnp.zeros((L, D), jnp.float32)

    def run(protocol):
        if protocol == "cached":
            args = (lambda W: losses.objective_grad_act(W, X, S, C),
                    lambda V, a: losses.hessian_vp(V, X, a, C))
        else:
            args = (lambda W: (*losses.objective_and_grad(W, X, S, C), W),
                    lambda V, W: losses.hessian_vp(
                        V, X, losses.active_mask(W, X, S), C))
        res = tron_solve(*args, W0, eps=1e-3)          # compile + solve
        jax.block_until_ready(res.W)
        best = float("inf")
        for _ in range(repeats):
            t0 = time.time()
            res = tron_solve(*args, W0, eps=1e-3)
            jax.block_until_ready(res.W)
            best = min(best, time.time() - t0)
        return res, best

    def module_score_dots(protocol):
        """Score-shaped dot count in the whole optimized solve module —
        the end-to-end view after XLA has had its say (loop-invariant code
        motion hoists the legacy CG-loop mask matmul to the Newton body and
        CSEs it with the Hd mask, so the compiled delta is the per-Newton
        3 -> 2, not the as-written per-CG 2 -> 1)."""
        if protocol == "cached":
            args = (lambda W: losses.objective_grad_act(W, X, S, C),
                    lambda V, a: losses.hessian_vp(V, X, a, C))
        else:
            args = (lambda W: (*losses.objective_and_grad(W, X, S, C), W),
                    lambda V, W: losses.hessian_vp(
                        V, X, losses.active_mask(W, X, S), C))
        compiled = jax.jit(
            tron_solve,
            static_argnames=("obj_grad_fn", "hvp_fn", "max_newton",
                             "max_cg")).lower(*args, W0, eps=1e-3).compile()
        want = (f"f32[{L},{N}]", f"f32[{N},{L}]")
        return sum(1 for line in compiled.as_text().splitlines()
                   if " dot(" in line and "= " in line
                   and line.split("= ")[1].split("{")[0].strip() in want)

    r_cached, t_cached = run("cached")
    r_legacy, t_legacy = run("legacy")
    np.testing.assert_array_equal(np.asarray(r_cached.W),
                                  np.asarray(r_legacy.W))
    dots_cached = module_score_dots("cached")
    dots_legacy = module_score_dots("legacy")
    rec = {"bench": "tron_hotpath", "metric": "solve_wall", "smoke": smoke,
           "L": L, "N": N, "D": D,
           "wall_s_cached": t_cached, "wall_s_legacy": t_legacy,
           "speedup": t_legacy / t_cached,
           "module_score_dots_cached": dots_cached,
           "module_score_dots_legacy": dots_legacy,
           "identical_W": True}
    emit_json(OUT_JSON, rec)
    assert dots_cached < dots_legacy, (dots_cached, dots_legacy)
    print(f"\nfull tron_solve (L={L}, N={N}, D={D}): score-shaped "
          f"dots in the compiled module {dots_legacy} -> {dots_cached}; "
          f"wall legacy {t_legacy:.3f}s vs cached {t_cached:.3f}s "
          f"({rec['speedup']:.2f}x), identical W")


def bench_overlap(n_train=N_TRAIN, n_features=N_FEATURES, n_labels=N_LABELS,
                  label_batch=LABEL_BATCH, block=BLOCK, repeats=2,
                  smoke=False):
    from repro.data.xmc import make_xmc_dataset
    data = make_xmc_dataset(n_train=n_train, n_test=64,
                            n_features=n_features, n_labels=n_labels,
                            seed=0)
    X, Y = jnp.asarray(data.X_train), jnp.asarray(data.Y_train)
    q = np.asarray(data.X_test[:32], np.float32)
    cfg = DiSMECConfig(delta=0.01, label_batch=label_batch, eps=1e-2)

    def run(overlap):
        """Returns (steady wall, total wall, top-k). Steady state = first
        batch done -> last batch done, stamped by on_batch: excludes the
        one-off solver compile whose run-to-run variance would swamp the
        per-batch overlap signal."""
        best_steady, best_total, labels = float("inf"), float("inf"), None
        for _ in range(repeats):               # best-of-N: CPU timing noise
            with tempfile.TemporaryDirectory() as d:
                job = XMCTrainJob(cfg=cfg, block_shape=block,
                                  overlap=overlap)
                stamps = []
                t0 = time.time()
                res = job.run(X, Y, d,
                              on_batch=lambda b, n: stamps.append(
                                  time.time()))
                best_total = min(best_total, time.time() - t0)
                best_steady = min(best_steady, stamps[-1] - stamps[0])
                assert res.complete
                eng = XMCEngine.from_checkpoint(d, backend="bsr", k=5,
                                                warmup=False)
                labels = np.asarray(eng.serve([q])[0].labels)
        return best_steady, best_total, labels

    steady_seq, wall_seq, topk_seq = run(overlap=False)
    steady_ovl, wall_ovl, topk_ovl = run(overlap=True)

    # Pre-refactor reference: the legacy (mask-recomputing) protocol solved
    # in one shot, served dense. Its top-k must match both checkpoints'.
    S = (2.0 * Y.T - 1.0).astype(jnp.float32)
    legacy = tron_solve(
        lambda W: (*losses.objective_and_grad(W, X, S, cfg.C), W),
        lambda V, W: losses.hessian_vp(
            V, X, losses.active_mask(W, X, S), cfg.C),
        jnp.zeros((n_labels, n_features), jnp.float32), eps=cfg.eps)
    from repro.core.dismec import DiSMECModel
    legacy_model = DiSMECModel(W=prune(legacy.W, cfg.delta), delta=cfg.delta,
                               n_labels=n_labels)
    eng = XMCEngine.from_dismec(legacy_model, backend="dense", k=5)
    topk_legacy = np.asarray(eng.serve([q])[0].labels)

    identical = (np.array_equal(topk_seq, topk_ovl)
                 and np.array_equal(topk_seq, topk_legacy))
    rec = {"bench": "tron_hotpath", "metric": "scheduler_overlap",
           "smoke": smoke,
           "n_labels": n_labels, "n_features": n_features,
           "label_batch": label_batch,
           "n_batches": n_labels // label_batch,
           "steady_wall_s_sequential": steady_seq,
           "steady_wall_s_overlapped": steady_ovl,
           "speedup": steady_seq / steady_ovl,
           "total_wall_s_sequential": wall_seq,
           "total_wall_s_overlapped": wall_ovl,
           "topk_identical_to_prerefactor": bool(identical)}
    emit_json(OUT_JSON, rec)
    print_table(
        f"streamed training, sequential vs double-buffered "
        f"(L={n_labels}, D={n_features}, label_batch={label_batch}, "
        "steady state)",
        [{"mode": "sequential", "steady_s": steady_seq, "total_s": wall_seq,
          "speedup": 1.0},
         {"mode": "overlapped", "steady_s": steady_ovl, "total_s": wall_ovl,
          "speedup": rec["speedup"]}],
        ["mode", "steady_s", "total_s", "speedup"])
    assert identical, "served top-k diverged from the pre-refactor solver"
    print(f"served top-k identical across sequential / overlapped / "
          f"pre-refactor solver; overlap speedup {rec['speedup']:.2f}x")
    return rec


def main(smoke: bool = False):
    bench_cg_passes()
    if smoke:
        # Same claims, tiny shapes: the 2->1 CG accounting above is exact
        # at any size; the solve/overlap legs just need to run end-to-end.
        bench_solve_wall(L=64, N=128, D=128, repeats=1, smoke=True)
        bench_overlap(n_train=96, n_features=1024, n_labels=96,
                      label_batch=32, block=(32, 128), repeats=1, smoke=True)
    else:
        bench_solve_wall()
        bench_overlap()
    print(f"\nwrote {OUT_JSON}")


if __name__ == "__main__":
    main()
