"""Paper Figure 4 / §4.1: l1 regularization vs l2 + Delta-pruning.

Claim: l1 yields (much) sparser models but underfits — lower P@k than the
l2-trained, Delta-pruned DiSMEC model.

Usage: PYTHONPATH=src python -m benchmarks.fig4_l1_vs_l2
"""

from __future__ import annotations

import jax.numpy as jnp

from benchmarks._common import fit_dismec, load, print_table, score
from repro.baselines.l1_svm import train_l1_svm
from repro.core.prediction import evaluate


def run(dataset: str = "wiki31k_like") -> list[dict]:
    data = load(dataset)
    Xtr, Ytr = jnp.asarray(data.X_train), jnp.asarray(data.Y_train)
    Xte, Yte = jnp.asarray(data.X_test), jnp.asarray(data.Y_test)

    rows = []
    model, _ = fit_dismec(data, delta=0.01)
    rows.append({"method": "l2 + prune(0.01)",
                 "density": model.nnz / model.W.size, **score(model.W, data)})

    for lam in (0.01, 0.05, 0.2):
        m = train_l1_svm(Xtr, Ytr, lam=lam)
        out = m.predict_topk(Xte, 5)
        idx = out[1] if isinstance(out, (tuple, list)) else out
        rows.append({"method": f"l1 (lam={lam})",
                     "density": m.nnz / m.W.size, **evaluate(Yte, idx)})
    return rows


def main():
    rows = run()
    print_table("Fig 4: l1 vs l2+prune", rows,
                ["method", "density", "P@1", "P@3", "P@5"])
    l2 = rows[0]
    best_l1 = max(rows[1:], key=lambda r: r["P@1"])
    print(f"\nClaim (l1 underfits): l2+prune P@1={l2['P@1']:.3f} vs "
          f"best l1 P@1={best_l1['P@1']:.3f} "
          f"({'OK' if l2['P@1'] >= best_l1['P@1'] - 0.005 else 'MISS'})")
    return rows


if __name__ == "__main__":
    main()
