"""Roofline analysis (deliverable g): three terms per (arch x shape), from
the dry-run's compiled artifacts (dryrun_results.jsonl).

Terms (TPU v5e: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI):

  compute_s    = flops_corrected / 197e12
                 trip-count-corrected HLO flops (launch/hlo_cost.py): XLA's
                 cost_analysis counts while bodies once, undercounting layer
                 scans; the corrected model multiplies by known_trip_count.
  memory_s     = (argument_bytes + output_bytes + 2*temp_bytes) / 819e9
                 a MIN-TRAFFIC FLOOR: every input buffer read once, every
                 output written once, every temp written+read once. The HLO
                 instruction-level byte counts (upper bound, also reported)
                 overcount CPU-pipeline fusion boundaries by 10-50x and are
                 not representative of a fusing TPU pipeline; the floor and
                 the upper bracket the truth and agree on dominance for all
                 pairs where it matters (EXPERIMENTS.md SSRoofline).
  collective_s = trip-corrected operand bytes of all-gather/all-reduce/
                 reduce-scatter/all-to-all/collective-permute / 50e9.

MODEL_FLOPS = 6 N D per train token (2 N D per inference token), N = active
params (MoE: routed top-k + shared). useful = MODEL_FLOPS / HLO_flops
exposes remat/capacity/padding waste.

`--bsr-predict` switches to the XMC serving roofline instead: the analytic
memory_s floor and compute_s of the BSR predict kernel at a few model
scales, fp32 blocks vs the int8 per-block-scaled artifact. The kernel is
bandwidth-bound at serving batch sizes (weights dominate bytes-moved), so
the memory_s floor tracks the weight payload: int8 moves the block bytes
to ~0.25x fp32 plus 4 bytes/block of scales, and the floor follows. The
byte accounting is `kernels.bsr_predict.ops.predict_bytes[_int8]` — the
same formulas the serving benchmarks report, not a parallel model.

Usage: PYTHONPATH=src python -m benchmarks.roofline [--json FILE] [--mesh M]
       PYTHONPATH=src python -m benchmarks.roofline --bsr-predict
"""

from __future__ import annotations

import argparse
import json

PEAK = 197e12
HBM = 819e9
ICI = 50e9
CHIPS = {"16x16": 256, "2x16x16": 512}

TOKENS = {"train_4k": 4096 * 256, "prefill_32k": 32768 * 32,
          "decode_32k": 128, "long_500k": 1}


def model_flops_global(arch: str, shape: str) -> float:
    from repro.configs.registry import get_config
    cfg = get_config(arch)
    n_active = cfg.active_param_count()
    mult = 6.0 if shape == "train_4k" else 2.0
    return mult * n_active * TOKENS[shape]


def analyse(rec: dict) -> dict:
    chips = CHIPS[rec["mesh"]]
    comp = rec["flops_corrected"] / PEAK
    mem_floor = (rec["argument_bytes"] + rec["output_bytes"]
                 + 2 * rec["temp_bytes"]) / HBM
    mem_upper = rec["hbm_bytes_corrected"] / HBM
    coll = sum(rec["collective_bytes_corrected"].values()) / ICI
    terms = {"compute": comp, "memory": mem_floor, "collective": coll}
    dom = max(terms, key=terms.get)
    mf = model_flops_global(rec["arch"], rec["shape"]) / chips
    total = max(comp, mem_floor, coll)
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "compute_s": comp, "memory_s": mem_floor, "memory_upper_s": mem_upper,
        "collective_s": coll, "dominant": dom,
        "bound_s": total,
        "model_flops_per_chip": mf,
        "useful_ratio": mf / rec["flops_corrected"]
        if rec["flops_corrected"] else 0.0,
        "peak_gb": rec["peak_bytes"] / 1e9,
    }


def lever(r: dict) -> str:
    """One sentence: what moves the dominant term down (per-pair)."""
    arch, shape, dom = r["arch"], r["shape"], r["dominant"]
    if dom == "collective":
        if "moe" in arch or arch.startswith(("mixtral", "qwen2-moe")):
            return ("expert-parallel all-to-all dominates: overlap a2a with "
                    "shared-expert compute; cap tokens/expert")
        return ("TP all-reduce dominates: switch wo/w2 outputs to "
                "reduce-scatter + sequence-sharded residual (1/2 bytes)")
    if dom == "memory":
        if shape in ("decode_32k", "long_500k"):
            return ("KV/state cache streaming dominates: shrink cache dtype "
                    "(bf16->f8), shard cache length over more devices, or "
                    "fuse cache read into the attention kernel")
        return ("activation traffic dominates: recompute cheap elementwise "
                "in bwd (less temp), bf16 activations, bigger microbatch to "
                "amortize weight reads")
    if r["useful_ratio"] < 0.5:
        return ("compute-bound with low useful ratio: cut remat recompute "
                "and head/vocab padding waste before anything else")
    return ("genuinely compute-bound near peak: only bf16/int8 matmuls or "
            "more chips move this")


#: XMC serving roofline configs: (name, L, D, block_shape, block_density,
#: batch). The first mirrors the serving benchmarks' demo profile; the
#: others are paper-scale datasets (Table 2 of the DiSMEC paper) at the
#: ~5% surviving-weight regime Delta-pruning leaves.
BSR_PREDICT_CONFIGS = (
    ("demo-512", 512, 4096, (32, 128), 0.50, 32),
    # Paper-scale rows use batch 1 — the latency-serving regime, where the
    # weight stream dominates bytes-moved and int8 shows its full effect
    # (larger batches re-read x per row block and dilute the ratio).
    ("wiki31k", 30938, 101938, (128, 128), 0.05, 1),
    ("wikiLSHTC-325k", 325056, 1617899, (128, 128), 0.02, 1),
)


def bsr_predict_roofline(markdown: bool = False) -> list[dict]:
    """Analytic fp32-vs-int8 roofline of the BSR predict kernel: memory_s
    floor (every weight block read once, x re-read per row block, output
    written once) and compute_s at TPU v5e peaks, per config and dtype."""
    from types import SimpleNamespace

    from repro.kernels.bsr_predict import ops as bsr_ops

    rows = []
    for name, L, D, (bl, bd), density, n in BSR_PREDICT_CONFIGS:
        R, C = -(-L // bl), -(-D // bd)
        n_blocks = max(1, int(R * C * density))
        # predict_bytes/_int8 only touch shape/block_shape/n_blocks — a
        # stand-in carrying those fields gives the real accounting without
        # materializing a paper-scale model.
        m = SimpleNamespace(shape=(R * bl, C * bd), block_shape=(bl, bd),
                            n_blocks=n_blocks)
        compute_s = bsr_ops.model_flops(m, n) / PEAK
        weight_fp32 = 4 * n_blocks * bl * bd
        weight_int8 = n_blocks * bl * bd + 4 * n_blocks
        for dtype, total_bytes, weight in (
                ("fp32", bsr_ops.predict_bytes(m, n), weight_fp32),
                ("int8", bsr_ops.predict_bytes_int8(m, n), weight_int8)):
            memory_s = total_bytes / HBM
            rows.append({
                "config": name, "dtype": dtype, "L": L, "D": D,
                "block_shape": [bl, bd], "density": density, "batch": n,
                "n_blocks": n_blocks, "weight_bytes": weight,
                "bytes_moved": total_bytes,
                "memory_s": memory_s, "compute_s": compute_s,
                "dominant": ("memory" if memory_s >= compute_s
                             else "compute"),
                "weight_ratio_vs_fp32": weight / weight_fp32,
            })

    if markdown:
        print("| config | dtype | weight GB | bytes moved GB | memory_s | "
              "compute_s | dominant | weight vs fp32 |")
        print("|---|---|---|---|---|---|---|---|")
        for r in rows:
            print(f"| {r['config']} | {r['dtype']} | "
                  f"{r['weight_bytes'] / 1e9:.3f} | "
                  f"{r['bytes_moved'] / 1e9:.3f} | {r['memory_s']:.2e} | "
                  f"{r['compute_s']:.2e} | {r['dominant']} | "
                  f"{r['weight_ratio_vs_fp32']:.3f} |")
    else:
        hdr = (f"{'config':18s} {'dtype':6s} {'weightGB':>9s} "
               f"{'movedGB':>9s} {'memory_s':>10s} {'compute_s':>10s} "
               f"{'dominant':>8s} {'w/fp32':>7s}")
        print(hdr)
        print("-" * len(hdr))
        for r in rows:
            print(f"{r['config']:18s} {r['dtype']:6s} "
                  f"{r['weight_bytes'] / 1e9:9.3f} "
                  f"{r['bytes_moved'] / 1e9:9.3f} {r['memory_s']:10.2e} "
                  f"{r['compute_s']:10.2e} {r['dominant']:>8s} "
                  f"{r['weight_ratio_vs_fp32']:7.3f}")
    print()
    for name in {r["config"] for r in rows}:
        fp32, int8 = [r for r in rows if r["config"] == name]
        print(f"{name}: int8 moves {int8['bytes_moved'] / fp32['bytes_moved']:.3f}x "
              f"the fp32 bytes (weights {int8['weight_ratio_vs_fp32']:.3f}x) "
              f"-> memory_s floor {int8['memory_s']:.2e}s vs "
              f"{fp32['memory_s']:.2e}s")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="dryrun_results.jsonl")
    ap.add_argument("--mesh", default="16x16",
                    help="roofline table mesh (single pod per the brief)")
    ap.add_argument("--markdown", action="store_true")
    ap.add_argument("--bsr-predict", action="store_true",
                    help="XMC serving roofline: BSR predict fp32 vs int8 "
                         "(analytic, no dry-run artifacts needed)")
    args = ap.parse_args()

    if args.bsr_predict:
        return bsr_predict_roofline(markdown=args.markdown)

    recs = [json.loads(l) for l in open(args.json)]
    seen, rows = set(), []
    for r in reversed(recs):                     # last result per key wins
        if r.get("skipped") or "error" in r or r["mesh"] != args.mesh:
            continue
        key = (r["arch"], r["shape"])
        if key in seen:
            continue
        seen.add(key)
        rows.append(analyse(r))
    rows.sort(key=lambda r: (r["arch"], r["shape"]))

    if args.markdown:
        print("| arch | shape | compute_s | memory_s | collective_s | "
              "dominant | useful | peak GB |")
        print("|---|---|---|---|---|---|---|---|")
        for r in rows:
            print(f"| {r['arch']} | {r['shape']} | {r['compute_s']:.4f} | "
                  f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | "
                  f"{r['dominant']} | {r['useful_ratio']:.3f} | "
                  f"{r['peak_gb']:.1f} |")
    else:
        hdr = (f"{'arch':22s} {'shape':12s} {'compute_s':>10s} "
               f"{'memory_s':>10s} {'collect_s':>10s} {'dominant':>10s} "
               f"{'useful':>7s} {'peakGB':>7s}")
        print(hdr)
        print("-" * len(hdr))
        for r in rows:
            print(f"{r['arch']:22s} {r['shape']:12s} {r['compute_s']:10.4f} "
                  f"{r['memory_s']:10.4f} {r['collective_s']:10.4f} "
                  f"{r['dominant']:>10s} {r['useful_ratio']:7.3f} "
                  f"{r['peak_gb']:7.1f}")
    print()
    for r in rows:
        print(f"{r['arch']} x {r['shape']}: {r['dominant']}-bound "
              f"({r['bound_s']:.3f}s) -> {lever(r)}")
    return rows


if __name__ == "__main__":
    main()
