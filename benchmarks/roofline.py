"""Roofline analysis (deliverable g): three terms per (arch x shape), from
the dry-run's compiled artifacts (dryrun_results.jsonl).

Terms (TPU v5e: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI):

  compute_s    = flops_corrected / 197e12
                 trip-count-corrected HLO flops (launch/hlo_cost.py): XLA's
                 cost_analysis counts while bodies once, undercounting layer
                 scans; the corrected model multiplies by known_trip_count.
  memory_s     = (argument_bytes + output_bytes + 2*temp_bytes) / 819e9
                 a MIN-TRAFFIC FLOOR: every input buffer read once, every
                 output written once, every temp written+read once. The HLO
                 instruction-level byte counts (upper bound, also reported)
                 overcount CPU-pipeline fusion boundaries by 10-50x and are
                 not representative of a fusing TPU pipeline; the floor and
                 the upper bracket the truth and agree on dominance for all
                 pairs where it matters (EXPERIMENTS.md SSRoofline).
  collective_s = trip-corrected operand bytes of all-gather/all-reduce/
                 reduce-scatter/all-to-all/collective-permute / 50e9.

MODEL_FLOPS = 6 N D per train token (2 N D per inference token), N = active
params (MoE: routed top-k + shared). useful = MODEL_FLOPS / HLO_flops
exposes remat/capacity/padding waste.

Usage: PYTHONPATH=src python -m benchmarks.roofline [--json FILE] [--mesh M]
"""

from __future__ import annotations

import argparse
import json

PEAK = 197e12
HBM = 819e9
ICI = 50e9
CHIPS = {"16x16": 256, "2x16x16": 512}

TOKENS = {"train_4k": 4096 * 256, "prefill_32k": 32768 * 32,
          "decode_32k": 128, "long_500k": 1}


def model_flops_global(arch: str, shape: str) -> float:
    from repro.configs.registry import get_config
    cfg = get_config(arch)
    n_active = cfg.active_param_count()
    mult = 6.0 if shape == "train_4k" else 2.0
    return mult * n_active * TOKENS[shape]


def analyse(rec: dict) -> dict:
    chips = CHIPS[rec["mesh"]]
    comp = rec["flops_corrected"] / PEAK
    mem_floor = (rec["argument_bytes"] + rec["output_bytes"]
                 + 2 * rec["temp_bytes"]) / HBM
    mem_upper = rec["hbm_bytes_corrected"] / HBM
    coll = sum(rec["collective_bytes_corrected"].values()) / ICI
    terms = {"compute": comp, "memory": mem_floor, "collective": coll}
    dom = max(terms, key=terms.get)
    mf = model_flops_global(rec["arch"], rec["shape"]) / chips
    total = max(comp, mem_floor, coll)
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "compute_s": comp, "memory_s": mem_floor, "memory_upper_s": mem_upper,
        "collective_s": coll, "dominant": dom,
        "bound_s": total,
        "model_flops_per_chip": mf,
        "useful_ratio": mf / rec["flops_corrected"]
        if rec["flops_corrected"] else 0.0,
        "peak_gb": rec["peak_bytes"] / 1e9,
    }


def lever(r: dict) -> str:
    """One sentence: what moves the dominant term down (per-pair)."""
    arch, shape, dom = r["arch"], r["shape"], r["dominant"]
    if dom == "collective":
        if "moe" in arch or arch.startswith(("mixtral", "qwen2-moe")):
            return ("expert-parallel all-to-all dominates: overlap a2a with "
                    "shared-expert compute; cap tokens/expert")
        return ("TP all-reduce dominates: switch wo/w2 outputs to "
                "reduce-scatter + sequence-sharded residual (1/2 bytes)")
    if dom == "memory":
        if shape in ("decode_32k", "long_500k"):
            return ("KV/state cache streaming dominates: shrink cache dtype "
                    "(bf16->f8), shard cache length over more devices, or "
                    "fuse cache read into the attention kernel")
        return ("activation traffic dominates: recompute cheap elementwise "
                "in bwd (less temp), bf16 activations, bigger microbatch to "
                "amortize weight reads")
    if r["useful_ratio"] < 0.5:
        return ("compute-bound with low useful ratio: cut remat recompute "
                "and head/vocab padding waste before anything else")
    return ("genuinely compute-bound near peak: only bf16/int8 matmuls or "
            "more chips move this")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="dryrun_results.jsonl")
    ap.add_argument("--mesh", default="16x16",
                    help="roofline table mesh (single pod per the brief)")
    ap.add_argument("--markdown", action="store_true")
    args = ap.parse_args()

    recs = [json.loads(l) for l in open(args.json)]
    seen, rows = set(), []
    for r in reversed(recs):                     # last result per key wins
        if r.get("skipped") or "error" in r or r["mesh"] != args.mesh:
            continue
        key = (r["arch"], r["shape"])
        if key in seen:
            continue
        seen.add(key)
        rows.append(analyse(r))
    rows.sort(key=lambda r: (r["arch"], r["shape"]))

    if args.markdown:
        print("| arch | shape | compute_s | memory_s | collective_s | "
              "dominant | useful | peak GB |")
        print("|---|---|---|---|---|---|---|---|")
        for r in rows:
            print(f"| {r['arch']} | {r['shape']} | {r['compute_s']:.4f} | "
                  f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | "
                  f"{r['dominant']} | {r['useful_ratio']:.3f} | "
                  f"{r['peak_gb']:.1f} |")
    else:
        hdr = (f"{'arch':22s} {'shape':12s} {'compute_s':>10s} "
               f"{'memory_s':>10s} {'collect_s':>10s} {'dominant':>10s} "
               f"{'useful':>7s} {'peakGB':>7s}")
        print(hdr)
        print("-" * len(hdr))
        for r in rows:
            print(f"{r['arch']:22s} {r['shape']:12s} {r['compute_s']:10.4f} "
                  f"{r['memory_s']:10.4f} {r['collective_s']:10.4f} "
                  f"{r['dominant']:>10s} {r['useful_ratio']:7.3f} "
                  f"{r['peak_gb']:7.1f}")
    print()
    for r in rows:
        print(f"{r['arch']} x {r['shape']}: {r['dominant']}-bound "
              f"({r['bound_s']:.3f}s) -> {lever(r)}")
    return rows


if __name__ == "__main__":
    main()
