"""Paper §4.2: model-size accounting (870 GB -> 3 GB on WikiLSHTC-325K).

Reports dense vs pruned-sparse vs block-sparse storage for each scaled
dataset, plus the paper-scale EXTRAPOLATION: we fit the ambiguous-weight
fraction on the toy problem and apply the paper's own reported fractions
(99.5% at 325K labels) to the full 325,056 x 1,617,899 matrix to recover
the paper's numbers analytically.

Usage: PYTHONPATH=src python -m benchmarks.table_model_size
"""

from __future__ import annotations

import numpy as np

from benchmarks._common import DATASETS, fit_dismec, load, print_table
from repro.core.pruning import to_block_sparse


def run() -> list[dict]:
    rows = []
    for name in DATASETS:
        data = load(name)
        model, _ = fit_dismec(data, delta=0.01)
        W = model.W
        bsr = to_block_sparse(W, (128, 128))
        dense_b = W.size * 4
        sparse_b = model.nnz * 8                     # (value, index) pairs
        bl, bd = bsr.block_shape
        bsr_b = bsr.n_blocks * (bl * bd * 4 + 8)     # blocks + coords
        # int8 serving artifact: 1-byte block values + 4-byte per-block
        # scale + the same 8-byte coords (checkpoint/io.py persists this
        # next to the fp32 blocks; the ratio is what serve_latency gates).
        int8_b = bsr.n_blocks * (bl * bd + 4 + 8)
        rows.append({
            "dataset": name, "L": W.shape[0], "D": W.shape[1],
            "dense_mb": dense_b / 1e6, "sparse_mb": sparse_b / 1e6,
            "bsr_mb": bsr_b / 1e6, "int8_mb": int8_b / 1e6,
            "density": float(model.nnz) / W.size,
            "block_density": bsr.density,
        })
    return rows


def paper_scale_extrapolation():
    """Paper's own numbers: 325,056 x 1,617,899 weights, 99.5% ambiguous."""
    L, D = 325_056, 1_617_899
    total = L * D
    dense_gb = total * 8 / 1e9            # f64 as liblinear stores
    pruned = total * (1 - 0.995)
    sparse_gb = pruned * 8 / 1e9          # and sparse (value,index)
    return {"dense_gb": dense_gb, "sparse_gb": sparse_gb,
            "paper_dense_gb": 870.0, "paper_sparse_gb": 3.0}


def main():
    rows = run()
    print_table("SS4.2 model size accounting", rows,
                ["dataset", "L", "D", "dense_mb", "sparse_mb", "bsr_mb",
                 "int8_mb", "density", "block_density"])
    ex = paper_scale_extrapolation()
    print(f"\nPaper-scale check (WikiLSHTC-325K, 99.5% ambiguous):")
    print(f"  dense  : {ex['dense_gb']:.0f} GB analytic vs "
          f"{ex['paper_dense_gb']:.0f} GB reported")
    print(f"  pruned : {ex['sparse_gb']:.1f} GB analytic vs "
          f"{ex['paper_sparse_gb']:.1f} GB reported")
    return rows


if __name__ == "__main__":
    main()
