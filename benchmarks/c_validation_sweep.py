"""Paper §3.3: "For DiSMEC, the hyper-parameter C was set on a validation
set which was extracted from the training set."

Reproduces that protocol: hold out 20% of train as validation, sweep C,
pick the P@1-argmax, refit on full train, report test metrics — and show
the sweep is not flat (C matters, the paper's reason for tuning it).

Also reports the per-shard TRON iteration balance with and without the
frequency-balanced label sharding (beyond-paper, core/dismec.py), since
both knobs govern the same §4.3 training-cost story.

Usage: PYTHONPATH=src python -m benchmarks.c_validation_sweep
"""

from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from benchmarks._common import LABEL_BATCH, load, print_table
from repro.core.dismec import (DiSMECConfig, balance_permutation,
                               signs_from_labels, train, train_label_batch)
from repro.core.prediction import evaluate, predict_topk

CS = (0.01, 0.1, 0.5, 1.0, 4.0, 16.0)


def run(dataset: str = "wiki31k_like") -> list[dict]:
    data = load(dataset)
    n = len(data.X_train)
    n_val = n // 5
    Xt = jnp.asarray(data.X_train[:-n_val])
    Yt = jnp.asarray(data.Y_train[:-n_val])
    Xv = jnp.asarray(data.X_train[-n_val:])
    Yv = jnp.asarray(data.Y_train[-n_val:])

    rows = []
    for C in CS:
        # Batched scheduler path (label_batch < n_labels), like production.
        m = train(Xt, Yt, DiSMECConfig(C=C, delta=0.01,
                                       label_batch=min(data.n_labels,
                                                       LABEL_BATCH)))
        _, idx = predict_topk(Xv, m.W, 5)
        ev = evaluate(Yv, idx)
        rows.append({"C": C, "val_P@1": ev["P@1"], "val_P@5": ev["P@5"],
                     "density": m.nnz / m.W.size})
    return rows, data


def shard_balance_report(data, n_shards: int = 8) -> list[dict]:
    """Per-shard max Newton iterations, contiguous vs balanced assignment —
    the quantity that sets each 'node's wall time in Algorithm 1."""
    X = jnp.asarray(data.X_train)
    Y = jnp.asarray(data.Y_train)
    S = signs_from_labels(Y)
    L = Y.shape[1]
    per = L // n_shards
    cfg = DiSMECConfig(eps=0.01)

    def shard_iters(order):
        iters = []
        for s in range(n_shards):
            sl = order[s * per:(s + 1) * per]
            res = train_label_batch(X, S[jnp.asarray(sl)], cfg)
            iters.append(int(jnp.max(res.n_newton)))
        return iters

    contiguous = shard_iters(np.arange(L))
    balanced = shard_iters(balance_permutation(Y, n_shards))
    return [
        {"assignment": "contiguous", "max_iters": max(contiguous),
         "mean_iters": float(np.mean(contiguous)),
         "imbalance": max(contiguous) / max(min(contiguous), 1)},
        {"assignment": "balanced", "max_iters": max(balanced),
         "mean_iters": float(np.mean(balanced)),
         "imbalance": max(balanced) / max(min(balanced), 1)},
    ]


def main():
    rows, data = run()
    print_table("SS3.3 C validation sweep (wiki31k_like, 20% held out)",
                rows, ["C", "val_P@1", "val_P@5", "density"])
    best = max(rows, key=lambda r: r["val_P@1"])
    print(f"\nselected C = {best['C']} (val P@1 {best['val_P@1']:.3f}); "
          f"spread across sweep: "
          f"{max(r['val_P@1'] for r in rows) - min(r['val_P@1'] for r in rows):.3f}")

    brows = shard_balance_report(data)
    print_table("Layer-1 shard balance (max TRON Newton iters per shard)",
                brows, ["assignment", "max_iters", "mean_iters", "imbalance"])
    return rows + brows


if __name__ == "__main__":
    main()
