"""Attribute trip-corrected FLOPs and collective bytes in a compiled HLO to
their op_name metadata — the dry-run 'profiler' used for SSPerf iterations.

Usage: PYTHONPATH=src python -m benchmarks.attribute_hlo /tmp/file.txt \
           [--what coll|flops] [--top 15]
"""

from __future__ import annotations

import argparse
import re
from collections import Counter

from repro.launch import hlo_cost as hc

META = re.compile(r'op_name="([^"]*)"')


def attribute(text: str, what: str = "coll") -> Counter:
    comps = hc.parse_module(text)
    parsed = {}
    for name, lines in comps.items():
        instrs = []
        for ln in lines:
            m = hc._INSTR.match(ln)
            if m:
                instrs.append({"name": m.group(1), "type": m.group(2),
                               "op": m.group(3), "rest": m.group(4),
                               "line": ln})
        parsed[name] = instrs
    symtab = {c: {i["name"]: i["type"] for i in instrs}
              for c, instrs in parsed.items()}
    memo: dict = {}

    def walk(cname: str) -> Counter:
        if cname in memo:
            return memo[cname]
        memo[cname] = Counter()
        total: Counter = Counter()
        syms = symtab.get(cname, {})
        for ins in parsed.get(cname, []):
            op, line = ins["op"], ins["line"]
            mm = META.search(line)
            key = mm.group(1) if mm else "?"
            if what == "flops" and op == "dot":
                dims = hc._shape_dims(ins["type"]) or []
                out_prod = 1
                for d in dims:
                    out_prod *= d
                ops = hc._OPERANDS_SPLIT.findall(ins["rest"].split("),")[0])
                lhs = hc._shape_dims(syms.get(ops[0] if ops else "", "")) or []
                cm = hc._LHS_C.search(line)
                cprod = 1
                if cm and lhs:
                    for ci in cm.group(1).split(","):
                        if ci:
                            cprod *= lhs[int(ci)]
                total[key] += 2.0 * out_prod * cprod
            if what == "coll":
                kind = op[:-6] if op.endswith("-start") else op
                if kind in hc.COLLECTIVES:
                    ob = sum(hc._shape_bytes(syms.get(o, ""))
                             for o in hc._OPERANDS_SPLIT.findall(
                                 ins["rest"].split("),")[0].split(")")[0])
                             if o in syms)
                    total[(kind, key)] += ob
            if op == "while":
                b = hc._BODY.search(line)
                t = hc._TRIP.search(line)
                trips = float(t.group(1)) if t else 1.0
                if b:
                    for k, v in walk(b.group(1)).items():
                        total[k] += v * trips
            else:
                cm2 = hc._CALLS.search(line)
                if cm2:
                    for k, v in walk(cm2.group(1)).items():
                        total[k] += v
        memo[cname] = total
        return total

    entry = next(c for c in parsed if c.startswith("main"))
    return walk(entry)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("path")
    ap.add_argument("--what", default="coll", choices=["coll", "flops"])
    ap.add_argument("--top", type=int, default=15)
    args = ap.parse_args()
    total = attribute(open(args.path).read(), args.what)
    s = sum(total.values())
    unit = "GB" if args.what == "coll" else "GFLOP"
    print(f"total {s / 1e9:.2f} {unit}")
    for k, v in total.most_common(args.top):
        label = f"{k[0]:18s} {k[1][-95:]}" if isinstance(k, tuple) else k[-110:]
        print(f"{v / 1e9:10.2f} ({v / s * 100:5.1f}%) {label}")


if __name__ == "__main__":
    main()
