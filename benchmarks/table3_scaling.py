"""Paper §4.3: double-parallelization scaling (6h@400 cores -> 3h@1000 cores).

On one CPU host we cannot measure real multi-device wall time, so the
benchmark reports BOTH:
  * measured: wall time of the batched TRON solve vs label-batch size on
    this host (layer-2 parallelism — the MXU/VMEM batching axis);
  * modeled:  per-device label count vs mesh `model`-axis size (layer 1 is
    embarrassingly parallel: no cross-label communication exists in
    Algorithm 1, so scaling is linear by construction — the dry-run HLO for
    train_sharded contains zero collectives in the paper-faithful mode,
    which we verify here by lowering it).

Usage: PYTHONPATH=src python -m benchmarks.table3_scaling
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks._common import load, print_table
from repro.core.dismec import DiSMECConfig, train_label_batch, signs_from_labels


def run(dataset: str = "wikilshtc325k_like") -> list[dict]:
    data = load(dataset)
    X = jnp.asarray(data.X_train)
    S_full = signs_from_labels(jnp.asarray(data.Y_train))
    cfg = DiSMECConfig(eps=0.01)

    rows = []
    for batch in (64, 128, 256, 512, 768):
        S = S_full[:batch]
        # Warm-up compile, then measure.
        res = train_label_batch(X, S, cfg)
        jax.block_until_ready(res.W)
        t0 = time.time()
        res = train_label_batch(X, S, cfg)
        jax.block_until_ready(res.W)
        dt = time.time() - t0
        rows.append({"labels": batch, "wall_s": dt,
                     "labels_per_s": batch / dt,
                     "newton_iters": float(jnp.max(res.n_newton))})
    return rows


def modeled_scaling(L: int = 325056) -> list[dict]:
    """Layer-1 model: labels/device vs mesh size; zero-collective training
    makes wall time proportional to labels/device (paper's near-linear
    6h@400 -> 3h@1000)."""
    rows = []
    for devices in (256, 512, 1024):
        rows.append({"devices": devices,
                     "labels_per_device": (L + devices - 1) // devices,
                     "relative_time": ((L + devices - 1) // devices)
                     / ((L + 255) // 256)})
    return rows


def main():
    rows = run()
    print_table("SS4.3 layer-2: batched-TRON throughput vs label-batch size",
                rows, ["labels", "wall_s", "labels_per_s", "newton_iters"])
    mrows = modeled_scaling()
    print_table("SS4.3 layer-1 (modeled, zero-collective): labels/device",
                mrows, ["devices", "labels_per_device", "relative_time"])
    print("\npaper: 6h@400c -> 3h@1000c (2.0x at 2.5x cores); model: "
          f"{mrows[0]['relative_time'] / mrows[2]['relative_time']:.2f}x "
          "at 4x devices (ideal 4.0x, integer-rounding loss only)")
    return rows + mrows


if __name__ == "__main__":
    main()
