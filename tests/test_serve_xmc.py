"""XMC serving subsystem: backend equivalence, sparse checkpoint round-trip,
and micro-batch queue/bucketing semantics."""

import os
import subprocess
import sys
import tempfile
import textwrap

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.pruning import BlockSparseModel, prune, to_block_sparse
from repro.serve import BACKENDS, XMCEngine, make_backend
from repro.serve.batching import (LatencyStats, MicroBatchQueue, pad_rows,
                                  pick_bucket)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _random_pruned_bsr(L, D, *, delta=0.05, seed=0, zero_rows=()):
    """A pruned weight matrix in both dense and packed-BSR form."""
    rng = np.random.default_rng(seed)
    W = rng.normal(size=(L, D)).astype(np.float32) * 0.1
    W = np.array(prune(jnp.asarray(W), delta))   # writable copy
    for r in zero_rows:
        W[r] = 0.0                       # fully pruned label
    return W, to_block_sparse(jnp.asarray(W), (128, 128))


# ---------------------------------------------------------------------------
# Backend equivalence
# ---------------------------------------------------------------------------

def test_backends_agree_on_topk():
    """dense / bsr / sharded must return identical top-k label ids for the
    same pruned model (the acceptance criterion of the serving refactor)."""
    L, D, k = 200, 512, 5
    W, bsr = _random_pruned_bsr(L, D, seed=1)
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(16, D)).astype(np.float32))

    out = {}
    for kind in BACKENDS:
        be = make_backend(kind, bsr, k, n_labels=L)
        vals, idx = be.topk(x)
        assert vals.shape == (16, k) and idx.shape == (16, k)
        out[kind] = np.asarray(idx)
        assert out[kind].max() < L, f"{kind} served a padding label"
    np.testing.assert_array_equal(out["dense"], out["bsr"])
    np.testing.assert_array_equal(out["dense"], out["sharded"])


def test_backends_agree_with_fully_pruned_rows():
    """Labels whose entire weight row was Delta-pruned score exactly 0 in
    every backend (BSR's skipped empty row-blocks included), so the top-k
    sets still agree even when 0.0 lands inside the top-k."""
    L, D, k = 130, 256, 5
    zero_rows = list(range(120, 130))    # kills the whole 2nd 128-row block
    W, bsr = _random_pruned_bsr(L, D, seed=3, zero_rows=zero_rows)
    # With few labels and negative-leaning x@W.T, zeros enter the top-k.
    rng = np.random.default_rng(4)
    x = jnp.asarray(-np.abs(rng.normal(size=(8, D))).astype(np.float32))

    out = {}
    for kind in BACKENDS:
        be = make_backend(kind, bsr, k, n_labels=L)
        _, idx = be.topk(x)
        out[kind] = np.asarray(idx)
        assert out[kind].max() < L, f"{kind} served a padding label"
    np.testing.assert_array_equal(out["dense"], out["bsr"])
    np.testing.assert_array_equal(out["dense"], out["sharded"])


def test_default_n_labels_never_serves_padding():
    """Without an explicit n_labels, backends must fall back to the true
    pre-padding label count (orig_shape), not the block-padded shape —
    zero-score padding rows would otherwise beat negative real scores."""
    L, D, k = 200, 512, 5
    _, bsr = _random_pruned_bsr(L, D, seed=11)
    assert bsr.orig_shape == (L, D) and bsr.shape[0] > L
    rng = np.random.default_rng(12)
    x = jnp.asarray(-np.abs(rng.normal(size=(4, D))).astype(np.float32))
    for kind in BACKENDS:
        be = make_backend(kind, bsr, k)          # no n_labels passed
        _, idx = be.topk(x)
        assert np.asarray(idx).max() < L, f"{kind} served a padding label"


def test_backends_handle_non_block_multiple_features():
    """D not divisible by the block width: dense/sharded must slice the
    densified model back to (L, D) so (n, D) requests work everywhere."""
    L, D, k = 100, 300, 3
    W, bsr = _random_pruned_bsr(L, D, seed=13)
    assert bsr.shape[1] > D                      # feature dim was padded
    rng = np.random.default_rng(14)
    x = jnp.asarray(rng.normal(size=(4, D)).astype(np.float32))
    out = {}
    for kind in BACKENDS:
        be = make_backend(kind, bsr, k)
        _, idx = be.topk(x)
        out[kind] = np.asarray(idx)
    np.testing.assert_array_equal(out["dense"], out["bsr"])
    np.testing.assert_array_equal(out["dense"], out["sharded"])


def test_engine_rejects_mismatched_request_dim():
    L, D = 140, 256
    _, bsr = _random_pruned_bsr(L, D, seed=15)
    be = make_backend("dense", bsr, 3)
    engine = XMCEngine(be, buckets=(2, 4), warmup=False, n_features=D)
    with pytest.raises(ValueError, match="feature dim"):
        engine.submit(np.zeros((2, D + 1), np.float32))


def test_sharded_backend_masks_shard_padding():
    """L not divisible by the shard count: the row padding the backend adds
    must never appear in served results (subprocess with 8 devices)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["JAX_PLATFORMS"] = "cpu"
    code = """
        import numpy as np
        import jax, jax.numpy as jnp
        from repro.core.pruning import prune, to_block_sparse
        from repro.serve import make_backend
        mesh = jax.make_mesh((1, 8), ("data", "model"))
        rng = np.random.default_rng(0)
        L, D, k = 50, 256, 5
        W = prune(jnp.asarray(rng.normal(size=(L, D)), jnp.float32) * 0.1,
                  0.05)
        bsr = to_block_sparse(W, (128, 128))
        dense = make_backend("dense", bsr, k, n_labels=L)
        sharded = make_backend("sharded", bsr, k, n_labels=L, mesh=mesh)
        x = jnp.asarray(-np.abs(rng.normal(size=(4, D))), jnp.float32)
        _, i1 = dense.topk(x)
        _, i2 = sharded.topk(x)
        assert np.asarray(i2).max() < L, "padding label served"
        np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
        print("OK")
    """
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env, timeout=560)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    assert "OK" in r.stdout


# ---------------------------------------------------------------------------
# Sparse checkpoint round-trip
# ---------------------------------------------------------------------------

def test_block_sparse_checkpoint_roundtrip():
    """blocks / block_rows / block_cols / row_ptr / shapes / meta all
    survive save -> load exactly; the loaded model serves identically."""
    L, D = 200, 512
    W, bsr = _random_pruned_bsr(L, D, seed=5)
    meta = {"n_labels": L, "n_features": D, "delta": 0.05}
    with tempfile.TemporaryDirectory() as d:
        bsr.save(d, meta=meta)
        loaded, meta2 = BlockSparseModel.load(d)
    assert meta2 == meta
    assert loaded.shape == bsr.shape
    assert loaded.block_shape == bsr.block_shape
    np.testing.assert_array_equal(np.asarray(loaded.blocks),
                                  np.asarray(bsr.blocks))
    np.testing.assert_array_equal(np.asarray(loaded.block_rows),
                                  np.asarray(bsr.block_rows))
    np.testing.assert_array_equal(np.asarray(loaded.block_cols),
                                  np.asarray(bsr.block_cols))
    np.testing.assert_array_equal(np.asarray(loaded.row_ptr),
                                  np.asarray(bsr.row_ptr))
    np.testing.assert_array_equal(np.asarray(loaded.to_dense())[:L, :D], W)


def test_engine_from_checkpoint_serves():
    """End-to-end: save sparse artifact, load an engine, serve a ragged
    stream, get per-request results in submission order."""
    L, D = 140, 256
    _, bsr = _random_pruned_bsr(L, D, seed=6)
    rng = np.random.default_rng(7)
    requests = [rng.normal(size=(int(n), D)).astype(np.float32)
                for n in rng.integers(1, 6, size=9)]
    with tempfile.TemporaryDirectory() as d:
        bsr.save(d, meta={"n_labels": L, "n_features": D})
        engine = XMCEngine.from_checkpoint(d, backend="dense", k=3,
                                           warmup=False)
        results = engine.serve(requests)
    assert [r.request_id for r in results] == list(range(9))
    for req, res in zip(requests, results):
        assert res.labels.shape == (req.shape[0], 3)
        assert res.scores.shape == (req.shape[0], 3)
        assert res.labels.max() < L
    stats = engine.latency_summary()
    assert stats["count"] == 9 and stats["p99_ms"] >= stats["p50_ms"]


# ---------------------------------------------------------------------------
# Queue / bucketing
# ---------------------------------------------------------------------------

def test_pick_bucket_and_pad_rows():
    assert pick_bucket(1, (1, 4, 16)) == 1
    assert pick_bucket(3, (1, 4, 16)) == 4
    assert pick_bucket(16, (1, 4, 16)) == 16
    with pytest.raises(ValueError):
        pick_bucket(17, (1, 4, 16))
    x = np.ones((3, 5), np.float32)
    p = pad_rows(x, 8)
    assert p.shape == (8, 5)
    np.testing.assert_array_equal(p[:3], x)
    assert (p[3:] == 0).all()


def test_micro_batch_queue_coalesces_and_unpads():
    """Ragged requests coalesce FIFO into bucket-padded batches and split
    back to per-request rows without loss or reordering."""
    q = MicroBatchQueue(buckets=(2, 4, 8))
    sizes = [3, 2, 1, 5, 8, 1]
    reqs = [np.full((n, 4), i, np.float32)
            for i, n in enumerate(sizes)]
    rids = [q.submit(r) for r in reqs]
    assert rids == list(range(6))

    got: dict[int, list[np.ndarray]] = {}
    for mb in q.drain():
        assert mb.bucket in (2, 4, 8)
        assert mb.x.shape[0] == mb.bucket
        assert sum(mb.row_counts) <= mb.bucket
        for rid, rows in mb.split(mb.x):
            got.setdefault(rid, []).append(rows)
    assert len(q) == 0
    for i, n in enumerate(sizes):
        rows = np.concatenate(got[i], axis=0)
        assert rows.shape == (n, 4)
        assert (rows == i).all()         # request identity preserved


def test_micro_batch_queue_splits_oversize_requests():
    q = MicroBatchQueue(buckets=(2, 4))
    rid = q.submit(np.ones((10, 3), np.float32))
    batches = list(q.drain())
    assert all(mb.bucket <= 4 for mb in batches)
    total = sum(sum(mb.row_counts) for mb in batches)
    assert total == 10
    assert all(set(mb.request_ids) == {rid} for mb in batches)


def test_queue_rejects_empty_request():
    q = MicroBatchQueue(buckets=(2, 4))
    with pytest.raises(ValueError, match="empty request"):
        q.submit(np.zeros((0, 3), np.float32))


def test_split_request_counts_once_in_latency_stats():
    """A request split across micro-batches is one request: one latency
    sample (the sum of its dispatches), one result."""
    L, D = 140, 256
    _, bsr = _random_pruned_bsr(L, D, seed=9)
    be = make_backend("dense", bsr, 3, n_labels=L)
    engine = XMCEngine(be, buckets=(2, 4), warmup=False, n_features=D)
    rng = np.random.default_rng(10)
    results = engine.serve([rng.normal(size=(10, D)).astype(np.float32)])
    assert len(results) == 1
    assert results[0].labels.shape == (10, 3)
    assert engine.latency_summary()["count"] == 1


def test_next_batch_launch_policy():
    """Continuous-batching launch decision, with an injected clock: no
    launch before the deadline, launch at the deadline, immediate launch
    when the largest bucket fills, and force for drain/shutdown."""
    q = MicroBatchQueue(buckets=(2, 4))
    assert q.next_batch(force=True) is None          # empty queue
    q.submit(np.ones((1, 3), np.float32), arrival=100.0)
    # Partially filled, deadline not reached: hold.
    assert q.next_batch(now=100.001, max_delay_s=0.002) is None
    # No deadline configured at all: hold until full.
    assert q.next_batch(now=999.0) is None
    # Deadline expired: ship the partial bucket.
    mb = q.next_batch(now=100.01, max_delay_s=0.002)
    assert mb is not None and mb.bucket == 2 and mb.row_counts == [1]
    assert mb.arrivals == [100.0]
    # Fill launch: 4 rows >= largest bucket ships with no deadline check.
    for i in range(4):
        q.submit(np.ones((1, 3), np.float32), arrival=200.0 + i)
    mb = q.next_batch(now=200.0)                     # zero elapsed time
    assert mb is not None and mb.bucket == 4
    assert mb.arrivals == [200.0, 201.0, 202.0, 203.0]
    assert q.next_batch(now=200.0) is None
    # Force drains regardless of clock or fill.
    q.submit(np.ones((1, 3), np.float32), arrival=300.0)
    assert q.next_batch(force=True) is not None


def test_queue_pending_and_arrival_accounting():
    """pending_requests counts distinct requests (a split request once),
    pending_rows counts instances, oldest_arrival tracks head-of-line —
    the three quantities the server's launch/admission decisions read."""
    q = MicroBatchQueue(buckets=(2, 4))
    assert q.pending_requests() == 0 and q.pending_rows() == 0
    assert q.oldest_arrival() is None
    q.submit(np.ones((10, 3), np.float32), arrival=5.0)   # 3 pieces, 1 req
    q.submit(np.ones((1, 3), np.float32), arrival=6.0)
    assert q.pieces_of(10) == 3 and q.pieces_of(4) == 1
    assert q.pending_requests() == 2
    assert q.pending_rows() == 11
    assert q.oldest_arrival() == 5.0
    q.next_batch(force=True)                         # first 4-row piece out
    assert q.pending_requests() == 2                 # split req still queued
    assert q.pending_rows() == 7
    list(q.drain())
    assert q.pending_requests() == 0 and q.pending_rows() == 0


def test_reserve_id_shares_namespace_with_submit():
    q = MicroBatchQueue(buckets=(2,))
    a = q.submit(np.ones((1, 3), np.float32))
    b = q.reserve_id()                               # e.g. a rejected request
    c = q.submit(np.ones((1, 3), np.float32))
    assert [a, b, c] == [0, 1, 2]
    assert q.pending_requests() == 2                 # reserve queues nothing


def test_latency_stats_percentiles():
    s = LatencyStats()
    for ms in [1, 2, 3, 4, 100]:
        s.record(ms / 1e3)
    out = s.summary()
    assert out["count"] == 5
    assert out["p50_ms"] == pytest.approx(3.0)
    assert out["p99_ms"] > out["p50_ms"]


def test_latency_stats_record_span_and_aggregate_wrapper():
    """record_span is the per-request primitive (enqueue -> completion
    timestamps); the legacy record(seconds, n) API stamps one duration onto
    n requests through the same samples list."""
    s = LatencyStats()
    s.record_span(10.0, 10.004)                      # 4 ms span
    s.record(0.002, n_requests=3)                    # 3 aggregate samples
    out = s.summary()
    assert s.count == 4 and out["count"] == 4
    assert out["p50_ms"] == pytest.approx(2.0)
    assert max(np.asarray(s._ms)) == pytest.approx(4.0)


def test_step_latency_includes_queue_wait():
    """Per-request spans start at enqueue, not at dispatch: a request that
    sat in the queue before step() ran reports that wait in its latency."""
    import time
    L, D = 140, 256
    _, bsr = _random_pruned_bsr(L, D, seed=16)
    be = make_backend("dense", bsr, 3, n_labels=L)
    engine = XMCEngine(be, buckets=(2,), warmup=False, n_features=D)
    engine.submit(np.zeros((1, D), np.float32))
    time.sleep(0.05)
    engine.step()
    stats = engine.latency_summary()
    assert stats["count"] == 1
    assert stats["p50_ms"] >= 50.0                   # the queue wait is real


def test_engine_bucket_warmup_counts():
    """warmup compiles each bucket once and never recompiles it."""
    L, D = 140, 256
    _, bsr = _random_pruned_bsr(L, D, seed=8)
    be = make_backend("dense", bsr, 3, n_labels=L)
    engine = XMCEngine(be, buckets=(2, 4), warmup=False, n_features=D)
    assert engine.warmup() == 2
    assert engine.warmup() == 0          # idempotent
