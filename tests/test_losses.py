"""core/losses.py against autodiff: the hand-derived gradient and generalized
Hessian-vector product must match jax.grad / jax.jvp on the same objective
(away from the hinge kink, where the generalized Hessian is the Hessian)."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import losses

L, N, D, C = 8, 64, 32, 1.3


@pytest.fixture(scope="module")
def problem():
    rng = np.random.default_rng(0)
    W = jnp.asarray(rng.normal(size=(L, D)) * 0.1, jnp.float32)
    X = jnp.asarray(rng.normal(size=(N, D)), jnp.float32)
    S = jnp.asarray(np.sign(rng.normal(size=(L, N))), jnp.float32)
    return W, X, S


def test_objective_matches_definition(problem):
    W, X, S = problem
    f = losses.objective(W, X, S, C)
    # Direct per-label evaluation of Eq. 2.2.
    scores = np.asarray(W) @ np.asarray(X).T
    z = np.maximum(1.0 - np.asarray(S) * scores, 0.0)
    f_ref = (np.asarray(W) ** 2).sum(axis=1) + C * (z ** 2).sum(axis=1)
    np.testing.assert_allclose(np.asarray(f), f_ref, rtol=1e-5)


def test_grad_matches_autodiff(problem):
    W, X, S = problem
    _, g = losses.objective_and_grad(W, X, S, C)
    g_auto = jax.grad(lambda w: jnp.sum(losses.objective(w, X, S, C)))(W)
    np.testing.assert_allclose(np.asarray(g), np.asarray(g_auto),
                               rtol=1e-4, atol=1e-5)


def test_objective_and_grad_consistent_with_objective(problem):
    W, X, S = problem
    f1 = losses.objective(W, X, S, C)
    f2, _ = losses.objective_and_grad(W, X, S, C)
    np.testing.assert_allclose(np.asarray(f1), np.asarray(f2), rtol=1e-5)


def test_hvp_matches_autodiff_jvp(problem):
    """At points where no margin is exactly 0, the generalized Hessian equals
    the true Hessian, so Hv must equal d/dt grad(W + tV)|_0."""
    W, X, S = problem
    act = losses.active_mask(W, X, S)
    rng = np.random.default_rng(1)
    V = jnp.asarray(rng.normal(size=(L, D)), jnp.float32)
    hv = losses.hessian_vp(V, X, act, C)

    grad_fn = lambda w: losses.objective_and_grad(w, X, S, C)[1]
    _, hv_auto = jax.jvp(grad_fn, (W,), (V,))
    np.testing.assert_allclose(np.asarray(hv), np.asarray(hv_auto),
                               rtol=1e-3, atol=1e-4)


def test_hvp_positive_definite(problem):
    """H = 2I + 2C X^T D X is PD: v^T H v >= 2||v||^2 > 0."""
    W, X, S = problem
    act = losses.active_mask(W, X, S)
    rng = np.random.default_rng(2)
    V = jnp.asarray(rng.normal(size=(L, D)), jnp.float32)
    hv = losses.hessian_vp(V, X, act, C)
    vHv = jnp.sum(V * hv, axis=-1)
    vv = jnp.sum(V * V, axis=-1)
    assert bool(jnp.all(vHv >= 2.0 * vv - 1e-3))


def test_active_mask_zero_weights():
    """At W=0 the margin is 1 - 0 = 1 > 0 for every instance: all active."""
    rng = np.random.default_rng(3)
    X = jnp.asarray(rng.normal(size=(N, D)), jnp.float32)
    S = jnp.asarray(np.sign(rng.normal(size=(L, N))), jnp.float32)
    act = losses.active_mask(jnp.zeros((L, D)), X, S)
    assert bool(jnp.all(act == 1.0))


def test_soft_threshold():
    w = jnp.asarray([-2.0, -0.5, 0.0, 0.3, 1.5])
    out = losses.soft_threshold(w, 0.5)
    np.testing.assert_allclose(np.asarray(out), [-1.5, 0.0, 0.0, 0.0, 1.0],
                               atol=1e-7)
