"""Paper baselines (§3.3) train and rank sensibly; DiSMEC beats them on
power-law data (Table 2's qualitative claim, scaled down)."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.baselines.fastxml import train_fastxml
from repro.baselines.l1_svm import train_l1_svm
from repro.baselines.leml import train_leml
from repro.baselines.pd_sparse import train_pd_sparse
from repro.baselines.sleec import train_sleec
from repro.core.prediction import evaluate, predict_topk

TRAINERS = {
    "l1_svm": train_l1_svm,
    "leml": train_leml,
    "sleec": train_sleec,
    "fastxml": train_fastxml,
    "pd_sparse": train_pd_sparse,
}


def _p1(model, Xte, Yte):
    out = model.predict_topk(Xte, 5)
    idx = out[1] if isinstance(out, (tuple, list)) else out
    return evaluate(Yte, idx)["P@1"]


@pytest.fixture(scope="module")
def scores(xmc_small_jnp, dismec_model):
    X, Y, Xte, Yte = xmc_small_jnp
    out = {}
    for name, fn in TRAINERS.items():
        out[name] = _p1(fn(X, Y), Xte, Yte)
    _, idx = predict_topk(Xte, dismec_model.W, 5)
    out["dismec"] = evaluate(Yte, idx)["P@1"]
    return out


def test_all_baselines_beat_random(scores, xmc_small):
    random_p1 = 1.0 / xmc_small.n_labels
    for name, p1 in scores.items():
        assert p1 > 5 * random_p1, (name, p1)


def test_dismec_beats_every_baseline(scores):
    """Table 2, qualitatively: DiSMEC >= all baselines on power-law data."""
    for name, p1 in scores.items():
        if name == "dismec":
            continue
        assert scores["dismec"] >= p1 - 0.02, (name, p1, scores["dismec"])


def test_l1_svm_sparser_but_weaker(scores, xmc_small_jnp, dismec_model):
    """Fig. 4 / §4.1: l1 regularization yields sparser models that underfit
    vs l2 + Delta-pruning."""
    X, Y, _, _ = xmc_small_jnp
    l1 = train_l1_svm(X, Y, lam=0.05)
    l1_density = l1.nnz / l1.W.size
    dismec_density = dismec_model.nnz / dismec_model.W.size
    assert l1_density < dismec_density          # sparser...
    assert scores["l1_svm"] <= scores["dismec"] + 0.01  # ...but not better


def test_fastxml_predicts_valid_labels(xmc_small_jnp):
    X, Y, Xte, _ = xmc_small_jnp
    model = train_fastxml(X, Y, n_trees=3, max_depth=6)
    out = model.predict_topk(Xte, 5)
    idx = np.asarray(out[1] if isinstance(out, (tuple, list)) else out)
    assert idx.shape == (Xte.shape[0], 5)
    assert (idx >= 0).all() and (idx < Y.shape[1]).all()


def test_leml_low_rank_structure(xmc_small_jnp):
    X, Y, _, _ = xmc_small_jnp
    model = train_leml(X, Y, rank=16)
    # Effective weight matrix W = U V^T has rank <= 16 by construction.
    W = np.asarray(model.U) @ np.asarray(model.V).T      # (D, L)
    s = np.linalg.svd(W, compute_uv=False)
    assert (s[16:] < 1e-3 * s[0]).all()
