"""End-to-end DiSMEC (Algorithm 1) behaviour on synthetic power-law XMC."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.dismec import DiSMECConfig, DiSMECModel, signs_from_labels, train
from repro.core.prediction import evaluate, predict_topk


def test_signs_from_labels():
    Y = jnp.asarray([[1, 0], [0, 1], [1, 1]])
    S = signs_from_labels(Y)
    assert S.shape == (2, 3)
    np.testing.assert_array_equal(np.asarray(S),
                                  [[1, -1, 1], [-1, 1, 1]])


def test_train_accuracy(dismec_model, xmc_small_jnp):
    """The paper's central claim scaled down: OvR + squared hinge reaches
    high P@1 on power-law data where signature features exist."""
    _, _, Xte, Yte = xmc_small_jnp
    _, idx = predict_topk(Xte, dismec_model.W, 5)
    ev = evaluate(Yte, idx)
    assert ev["P@1"] > 0.90, ev
    assert ev["nDCG@5"] > 0.90, ev


def test_model_is_pruned(dismec_model):
    """Step 7 ran: no weight survives in the open interval (0, delta)."""
    W = np.asarray(dismec_model.W)
    nz = W[W != 0.0]
    assert (np.abs(nz) >= dismec_model.delta).all()


def test_label_batching_invariance(xmc_small_jnp):
    """Algorithm 1's outer batch loop must not change the solution: training
    with label_batch=16 and label_batch=64 gives the same W (per-label
    problems are independent)."""
    X, Y, _, _ = xmc_small_jnp
    m1 = train(X, Y, DiSMECConfig(label_batch=64, eps=1e-3))
    m2 = train(X, Y, DiSMECConfig(label_batch=16, eps=1e-3))
    np.testing.assert_allclose(np.asarray(m1.W), np.asarray(m2.W),
                               rtol=1e-2, atol=2e-3)


def test_size_accounting(dismec_model):
    dense = dismec_model.dense_size_bytes()
    sparse = dismec_model.size_bytes()
    assert dense == 64 * 1024 * 4
    assert sparse == dismec_model.nnz * 8
    # Sparse (value, index) storage wins once density < 50% — the paper's
    # regime (0.5-4% density). At this toy scale density is higher; check
    # the formula crossover instead of the raw inequality.
    density = dismec_model.nnz / dismec_model.W.size
    assert (sparse < dense) == (density < 0.5)


def test_pallas_path_matches_jnp(xmc_small_jnp):
    """use_pallas=True routes obj/grad + Hv through the Pallas kernels
    (interpret mode on CPU) and must land on the same model."""
    X, Y, _, _ = xmc_small_jnp
    m_jnp = train(X, Y, DiSMECConfig(label_batch=64, eps=1e-2))
    m_pal = train(X, Y, DiSMECConfig(label_batch=64, eps=1e-2,
                                     use_pallas=True))
    # Same support and near-identical weights.
    np.testing.assert_allclose(np.asarray(m_jnp.W), np.asarray(m_pal.W),
                               rtol=5e-2, atol=5e-3)


def test_delta_zero_keeps_everything(xmc_small_jnp):
    X, Y, _, _ = xmc_small_jnp
    m = train(X, Y, DiSMECConfig(label_batch=64, delta=0.0))
    # With delta=0, prune() is the identity: many small weights survive.
    W = np.asarray(m.W)
    assert (np.abs(W[W != 0.0]) < 0.01).any()
