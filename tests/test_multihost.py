"""Multi-host layer-1 dispatch (manifest batch leases): cooperative
two-worker drains must be bit-identical to single-worker runs, dead
workers must be recovered by lease expiry with no manual cleanup, and the
spec fingerprint must keep mismatched co-workers out of the checkpoint.

Workers here are threads, not processes: each `fit()` builds its own
`BlockSparseWriter`, and the lease protocol (flock + reload-mutate-flush)
is identical whether the contending writers live in one process or on N
hosts — threads just keep the suite fast. The real multi-process path is
exercised by `benchmarks/train_pipeline.py --smoke` (multiworker mode)
and `examples/distributed_dismec.py`.
"""

import json
import os
import threading
import time

import numpy as np
import pytest

import jax.numpy as jnp

from repro.checkpoint.io import (BSR_MANIFEST, MANIFEST_VERSION,
                                 BlockSparseWriter, load_block_sparse)
from repro.specs import ScheduleSpec, SolverSpec
from repro.xmc_api import XMCSpec, fit

L, D = 64, 512
LABEL_BATCH = 16                      # 4 batches: a queue worth dealing
BLOCK = (16, 16)


def make_spec(**schedule_kw):
    schedule_kw.setdefault("label_batch", LABEL_BATCH)
    schedule_kw.setdefault("block_shape", BLOCK)
    return XMCSpec(solver=SolverSpec(eps=1e-2),
                   schedule=ScheduleSpec(**schedule_kw))


@pytest.fixture(scope="module")
def xmc_data():
    from repro.data.xmc import make_xmc_dataset
    d = make_xmc_dataset(n_train=150, n_test=30, n_features=D, n_labels=L,
                         seed=1)
    return jnp.asarray(d.X_train), jnp.asarray(d.Y_train)


@pytest.fixture(scope="module")
def single_ckpt(xmc_data, tmp_path_factory):
    """The single-worker reference every cooperative run must reproduce."""
    X, Y = xmc_data
    out = str(tmp_path_factory.mktemp("single"))
    res = fit(X, Y, make_spec(), out).result
    assert res.complete and res.n_batches == 4
    return out


def manifest_of(directory):
    with open(os.path.join(directory, BSR_MANIFEST)) as f:
        return json.load(f)


def assert_identical_checkpoint(a, b):
    assert manifest_of(a) == manifest_of(b)
    np.testing.assert_array_equal(
        np.asarray(load_block_sparse(a)[0].to_dense()),
        np.asarray(load_block_sparse(b)[0].to_dense()))


def run_workers(X, Y, out, names, spec=None, **fit_kw):
    """N cooperative fit() workers on threads; returns {name: result}."""
    spec = spec or make_spec(workers=len(names), lease_ttl=30.0)
    results, errors = {}, {}

    def work(name):
        try:
            results[name] = fit(X, Y, spec, out, worker=name,
                                **fit_kw).result
        except BaseException as e:                  # surfaced by the caller
            errors[name] = e

    threads = [threading.Thread(target=work, args=(n,)) for n in names]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    if errors:
        raise next(iter(errors.values()))
    return results


def test_two_worker_drain_bit_identical(xmc_data, single_ckpt, tmp_path):
    """Acceptance criterion: two fit() workers draining one out_dir yield
    a manifest and stitched weights identical to the single-worker run,
    with every batch solved exactly once across the pair."""
    X, Y = xmc_data
    coop = str(tmp_path / "coop")
    results = run_workers(X, Y, coop, ["a", "b"])
    solved = sorted(b for r in results.values() for b in r.solved)
    assert solved == [0, 1, 2, 3]                 # disjoint and exhaustive
    assert any(r.complete for r in results.values())
    assert_identical_checkpoint(coop, single_ckpt)
    # Completion clears the lease table: the artifact carries no residue
    # of how many workers built it.
    assert manifest_of(coop)["leases"] == {}


def test_solo_worker_coordinated_identical(xmc_data, single_ckpt, tmp_path):
    """The lease-claiming scheduler itself (workers=1 but an explicit
    worker id) writes the same bytes as the static skip-finished loop."""
    X, Y = xmc_data
    out = str(tmp_path / "solo")
    res = fit(X, Y, make_spec(), out, worker="only").result
    assert res.complete and res.solved == [0, 1, 2, 3]
    assert_identical_checkpoint(out, single_ckpt)


def test_killed_worker_releases_leases_for_instant_reclaim(
        xmc_data, single_ckpt, tmp_path):
    """A worker that dies by exception releases its held leases on the way
    out, so a successor reclaims its batches immediately — no TTL wait."""
    X, Y = xmc_data
    out = str(tmp_path / "killed")

    class Kill(RuntimeError):
        pass

    def die_after_one(b, n):
        raise Kill(f"killed after batch {b}")

    spec = make_spec(workers=2, lease_ttl=120.0)
    with pytest.raises(Kill):
        fit(X, Y, spec, out, worker="victim", on_batch=die_after_one)
    m = manifest_of(out)
    assert not m["complete"] and m["leases"] == {}

    t0 = time.time()
    res = fit(X, Y, spec, out, worker="successor").result
    assert res.complete
    assert time.time() - t0 < 60.0                # never waited out the TTL
    assert_identical_checkpoint(out, single_ckpt)


def test_drain_failure_aborts_instead_of_hanging(xmc_data, tmp_path,
                                                 monkeypatch):
    """A shard-write failure in the background drain thread must abort the
    coordinated run (releasing every held lease), not leave the claim-wait
    loop spinning behind its own perpetually-heartbeated lease.

    The failure is injected on the LAST batch: by then the main thread has
    claimed everything and sits inside the lease-wait loop (its own
    in-flight leases are the only unwritten batches) — exactly the window
    where a drain death used to hang the run forever, since the `failed`
    check at the dispatch semaphore is never reached again."""
    X, Y = xmc_data
    real = BlockSparseWriter.write_batch

    def failing(self, batch, part, **kw):
        if batch == 3:                           # last of the 4 batches
            time.sleep(1.0)       # let the main thread reach the wait loop
            raise RuntimeError("disk full")
        return real(self, batch, part, **kw)

    monkeypatch.setattr(BlockSparseWriter, "write_batch", failing)
    out = str(tmp_path / "ck")
    caught = []

    def go():
        try:
            fit(X, Y, make_spec(workers=2, lease_ttl=120.0), out,
                worker="w")
        except BaseException as e:
            caught.append(e)

    t = threading.Thread(target=go, daemon=True)
    t.start()
    t.join(timeout=90.0)
    assert not t.is_alive(), "coordinated run hung after a write failure"
    assert caught and "disk full" in str(caught[0])
    monkeypatch.undo()
    # Every lease was released on the way out: a co-worker (or retry)
    # reclaims immediately and can finish the checkpoint.
    assert manifest_of(out)["leases"] == {}
    res = fit(X, Y, make_spec(workers=2), out, worker="retry").result
    assert res.complete


def test_expired_lease_reclaimed_after_dead_worker(xmc_data, single_ckpt,
                                                   tmp_path):
    """Acceptance criterion: a worker killed so hard it left a live lease
    behind (SIGKILL — nothing ran on the way out) is recovered via lease
    expiry, without manual cleanup: the survivor reclaims the expired
    lease and finishes. The lease is back-dated past its TTL so expiry is
    a fact of the manifest, not of how long this test sleeps (the waiting
    semantics themselves are covered deterministically by
    `test_lease_expiry_via_injected_clock`)."""
    X, Y = xmc_data
    out = str(tmp_path / "abandoned")
    spec = make_spec(workers=2, lease_ttl=2.0)
    fit(X, Y, spec, out, worker="dead", max_batches=1)

    # Simulate the SIGKILL crash state: batch 1 leased by "dead", never to
    # be heartbeat again, already older than its TTL.
    path = os.path.join(out, BSR_MANIFEST)
    with open(path) as f:
        m = json.load(f)
    assert m["leases"] == {}                     # clean exit released all
    m["leases"]["1"] = {"worker": "dead", "ts": time.time() - 10.0,
                        "ttl": 2.0}
    with open(path, "w") as f:
        json.dump(m, f)

    res = fit(X, Y, spec, out, worker="survivor").result
    assert res.complete and 1 in res.solved
    assert_identical_checkpoint(out, single_ckpt)


def test_lease_expiry_via_injected_clock(tmp_path):
    """TTL semantics with NO wall-clock sleeps: the writer's injected
    `clock` drives expiry deterministically — a lease is live strictly
    inside its TTL, reclaimable the moment the clock passes it, and
    `claim_wait_seconds` reports exactly the earliest remaining life."""
    now = [1000.0]
    w = BlockSparseWriter(str(tmp_path / "ck"), n_labels=L, n_features=D,
                          block_shape=BLOCK, label_batch=LABEL_BATCH,
                          n_batches=2, clock=lambda: now[0])
    assert w.claim_next_batch("a", ttl=30.0) == 0
    assert w.claim_next_batch("b", ttl=20.0) == 1
    assert w.claim_next_batch("c", ttl=30.0) is None    # all leased, live
    assert w.claim_wait_seconds() == pytest.approx(20.0)  # b expires first
    now[0] += 19.0
    assert w.claim_next_batch("c", ttl=30.0) is None    # still inside TTLs
    assert w.claim_wait_seconds() == pytest.approx(1.0)
    now[0] += 2.0
    assert w.claim_next_batch("c", ttl=30.0) == 1       # b's lease expired
    now[0] += 10.0                                      # a now dead too
    assert w.claim_next_batch("d", ttl=30.0) == 0
    # Heartbeats stamp the injected clock: refreshed leases live on
    # (c's lease on 1 is also still inside its TTL here).
    w.heartbeat("d", [0])
    now[0] += 19.0
    assert w.claim_next_batch("e", ttl=30.0) is None


def test_coworker_spec_mismatch_raises(xmc_data, tmp_path):
    """Co-workers must share the canonical spec (and data): a joiner with
    a different solver is rejected by the manifest fingerprint instead of
    stitching incompatible shards — but runtime-only knob differences
    (workers / lease_ttl / overlap) are admitted."""
    X, Y = xmc_data
    out = str(tmp_path / "guarded")
    fit(X, Y, make_spec(workers=2, lease_ttl=30.0), out, worker="a",
        max_batches=1)
    bad = XMCSpec(solver=SolverSpec(C=10.0, eps=1e-2),
                  schedule=ScheduleSpec(label_batch=LABEL_BATCH,
                                        block_shape=BLOCK, workers=2))
    with pytest.raises(ValueError, match="manifest disagrees"):
        fit(X, Y, bad, out, worker="b")
    with pytest.raises(ValueError, match="manifest disagrees"):
        fit(X * 2.0, Y, make_spec(workers=2), out, worker="c")
    # Different runtime knobs are solution-neutral: this joiner finishes
    # the job.
    res = fit(X, Y, make_spec(workers=3, lease_ttl=9.0, overlap=False),
              out, worker="d").result
    assert res.complete


def test_divergent_serve_spec_meta_is_creator_wins(xmc_data, tmp_path):
    """Serving is deliberately not fingerprinted, so a co-worker with a
    different ServeSpec is admitted — but the manifest's meta.xmc_spec
    must stay the creator's (settled at init, not last-flush-wins), so
    the finished checkpoint is deterministic regardless of claim timing."""
    from repro.specs import ServeSpec
    from repro.xmc_api import CheckpointHandle
    X, Y = xmc_data
    out = str(tmp_path / "serve_meta")
    base = make_spec(workers=2, lease_ttl=30.0)
    creator = base.replace(serve=ServeSpec(backend="bsr", k=5))
    joiner = base.replace(serve=ServeSpec(backend="dense", k=9))
    fit(X, Y, creator, out, worker="first", max_batches=1)
    res = fit(X, Y, joiner, out, worker="second").result
    assert res.complete
    recovered = CheckpointHandle.open(out).spec
    assert recovered.serve == creator.serve


def test_claim_requires_flock(tmp_path, monkeypatch):
    """Without POSIX flock the lease protocol has no atomicity: claiming
    must refuse loudly instead of silently corrupting the shared queue."""
    import repro.checkpoint.io as io_mod
    w = BlockSparseWriter(str(tmp_path / "ck"), n_labels=L, n_features=D,
                          block_shape=BLOCK, label_batch=LABEL_BATCH,
                          n_batches=2)
    monkeypatch.setattr(io_mod, "fcntl", None)
    with pytest.raises(RuntimeError, match="flock"):
        w.claim_next_batch("a", ttl=30.0)


def test_worker_knobs_are_runtime_fields():
    """workers/lease_ttl never reach checkpoint identity: fingerprints and
    canonical specs are invariant in them (any worker count must write
    bit-identical checkpoints)."""
    base = ScheduleSpec(label_batch=LABEL_BATCH)
    tuned = ScheduleSpec(label_batch=LABEL_BATCH, workers=8, lease_ttl=7.0)
    assert tuned.fingerprint() == base.fingerprint()
    assert tuned.canonical() == base.canonical()
    assert "workers" not in tuned.fingerprint()
    with pytest.raises(ValueError, match="workers"):
        ScheduleSpec(workers=0).validate()
    with pytest.raises(ValueError, match="lease_ttl"):
        ScheduleSpec(lease_ttl=0.0).validate()


def test_v1_manifest_reads_and_upgrades(xmc_data, single_ckpt, tmp_path):
    """Backward compatibility: a pre-lease (v1) manifest — no
    manifest_version, no leases — still loads, and resuming into it
    upgrades it to v2 in place without disturbing the shards."""
    import shutil
    X, Y = xmc_data
    out = str(tmp_path / "v1")
    shutil.copytree(single_ckpt, out)
    path = os.path.join(out, BSR_MANIFEST)
    with open(path) as f:
        m = json.load(f)
    del m["manifest_version"], m["leases"]
    with open(path, "w") as f:
        json.dump(m, f)

    model, meta = load_block_sparse(out)          # v1 read path intact
    np.testing.assert_array_equal(
        np.asarray(model.to_dense()),
        np.asarray(load_block_sparse(single_ckpt)[0].to_dense()))

    res = fit(X, Y, make_spec(), out).result      # resume: nothing to solve
    assert res.complete and res.solved == []
    m2 = manifest_of(out)
    assert m2["manifest_version"] == MANIFEST_VERSION
    assert m2["leases"] == {}


def test_claim_ordering_and_exclusion(tmp_path):
    """Writer-level lease semantics: lowest-first claiming, live leases of
    other workers are skipped, a worker's own stale lease is reclaimed
    unless the batch is excluded (still in flight), and commit releases.
    Expiry is driven by the injected clock — no real sleeps."""
    now = [0.0]
    w = BlockSparseWriter(str(tmp_path / "ck"), n_labels=L, n_features=D,
                          block_shape=BLOCK, label_batch=LABEL_BATCH,
                          n_batches=3, clock=lambda: now[0])
    assert w.claim_next_batch("a", ttl=30.0) == 0
    assert w.claim_next_batch("b", ttl=30.0) == 1      # 0 is leased by a
    # a's own lease on 0 is excluded while in flight -> next free is 2.
    assert w.claim_next_batch("a", ttl=30.0, exclude=[0]) == 2
    # Everything leased: nothing claimable, and the wait is bounded by the
    # earliest expiry.
    assert w.claim_next_batch("c", ttl=30.0) is None
    assert 0.0 < w.claim_wait_seconds() <= 30.0
    # Crash-restart under the same id (no exclusion): reclaims its own
    # lease immediately.
    assert w.claim_next_batch("a", ttl=30.0) == 0
    # Expiry: an abandoned short lease becomes claimable for anyone.
    w.release_leases("b", [1])
    assert w.claim_next_batch("c", ttl=0.01) == 1
    now[0] += 0.05
    assert w.claim_next_batch("d", ttl=30.0) == 1
