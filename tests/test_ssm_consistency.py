"""Recurrent mixers: the one-token decode recurrence must reproduce the
full-sequence (chunkwise-parallel / scan) forward exactly — the property
that makes long_500k decode O(1) for the SSM/hybrid archs."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import ssm

CFG = ArchConfig(name="t", family="ssm", n_layers=1, d_model=64,
                 n_heads=4, n_kv_heads=4, d_ff=128, vocab=64,
                 ssm_state=8, mlstm_heads=4, dtype="float32")


def _x(B, T, d, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(B, T, d)) * 0.5, jnp.float32)


def test_mlstm_decode_matches_full():
    p = ssm.init_mlstm(CFG, jax.random.PRNGKey(0), jnp.float32)
    B, T, d = 2, 24, CFG.d_model
    x = _x(B, T, d)
    full = ssm.mlstm(CFG, p, x)

    state = ssm.mlstm_init_state(CFG, B)
    outs = []
    for t in range(T):
        o, state = ssm.mlstm_decode(CFG, p, x[:, t:t + 1], state)
        outs.append(o)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=2e-3, atol=2e-3)


def test_slstm_decode_matches_full():
    p = ssm.init_slstm(CFG, jax.random.PRNGKey(1), jnp.float32)
    B, T, d = 2, 16, CFG.d_model
    x = _x(B, T, d, seed=1)
    full = ssm.slstm(CFG, p, x)

    state = ssm.slstm_init_state(CFG, B)
    outs = []
    for t in range(T):
        o, state = ssm.slstm_decode(CFG, p, x[:, t:t + 1], state)
        outs.append(o)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=2e-3, atol=2e-3)


def test_mamba_decode_matches_full():
    d_inner = CFG.d_model
    p = ssm.init_mamba(CFG, jax.random.PRNGKey(2), jnp.float32, d_inner)
    B, T, d = 2, 20, CFG.d_model
    x = _x(B, T, d, seed=2)
    full, final_state = ssm.mamba(CFG, p, x, d_inner, return_state=True)

    state = ssm.mamba_init_state(CFG, B, d_inner)
    outs = []
    for t in range(T):
        o, state = ssm.mamba_decode(CFG, p, x[:, t:t + 1], state, d_inner)
        outs.append(o)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=3e-3, atol=3e-3)
    # Final recurrent state must agree too (it seeds continued decoding).
    np.testing.assert_allclose(np.asarray(state.h),
                               np.asarray(final_state.h),
                               rtol=3e-3, atol=3e-3)


def test_mlstm_chunk_boundary_invariance():
    """The chunkwise-parallel mLSTM must give identical results whatever
    the sequence length's relation to CHUNK (padding path included)."""
    p = ssm.init_mlstm(CFG, jax.random.PRNGKey(3), jnp.float32)
    B, d = 1, CFG.d_model
    for T in (ssm.CHUNK // 2, ssm.CHUNK, ssm.CHUNK + 7):
        x = _x(B, T, d, seed=T)
        full = ssm.mlstm(CFG, p, x)
        state = ssm.mlstm_init_state(CFG, B)
        outs = []
        for t in range(T):
            o, state = ssm.mlstm_decode(CFG, p, x[:, t:t + 1], state)
            outs.append(o)
        dec = jnp.concatenate(outs, axis=1)
        np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                                   rtol=2e-3, atol=2e-3, err_msg=f"T={T}")


def test_slstm_shard_map_island_matches_plain():
    """The shard_map island (SSPerf xlstm fix) must be numerically
    identical to the plain implementation (single device: trivial mesh)."""
    p = ssm.init_slstm(CFG, jax.random.PRNGKey(4), jnp.float32)
    x = _x(1, 12, CFG.d_model, seed=4)
    mesh = jax.make_mesh((1,), ("data",))
    plain = ssm.slstm(CFG, p, x)
    island = ssm.slstm(CFG, p, x, mesh=mesh, batch_axes=("data",))
    np.testing.assert_allclose(np.asarray(island), np.asarray(plain),
                               rtol=1e-5, atol=1e-5)
