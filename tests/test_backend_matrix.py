"""Backend-equivalence matrix: every registered predict backend x
{fp32, int8} x {legacy, shortlist-v1, shortlist-v2} checkpoint state must
return the SAME full-width top-k label ids as the dense reference.

This is the serving stack's one cross-cutting contract stated as a single
parametrized test instead of per-backend suites: whatever coarse artifact
generation is on disk (none at all, the pre-v2 centroid npz, or the v2
learned artifact) and whatever weight dtype serves the fine stage, a
full-width (B = n_row_blocks) configuration is exhaustive scoring and must
agree with dense top-k exactly, label ids included. The reference flips
with the dtype that actually serves: an int8 fine stage is compared
against dense scoring over the DEQUANTIZED model (quantization moves the
weights; it must not move the ranking relative to those moved weights).

The per-query knob rides the same matrix: at full width it must collapse
to the shared path and stay bit-identical (the ragged kernel never touches
a B = R request).
"""

import os

import numpy as np
import pytest

import jax.numpy as jnp

from repro.checkpoint.io import (SHORTLIST_FILE, load_block_sparse,
                                 load_shortlist, upgrade_shortlist)
from repro.core.pruning import (BlockSparseModel, dequantize_blocks, prune,
                                quantize_block_sparse, to_block_sparse)
from repro.serve import XMCEngine, available_backends
from repro.serve.shortlist import build_learned_shortlist
from repro.serve.xmc import DenseBackend

L, D, K = 140, 300, 5
BLOCK = (16, 128)
STATES = ("legacy", "v1", "v2")


@pytest.fixture(scope="module")
def ckpts(tmp_path_factory):
    """One pruned model saved in all three shortlist-artifact generations:
    legacy (no artifact file), v1 (the pre-versioned centroid npz, written
    by hand with exactly the old keys), v2 (the learned artifact installed
    by `upgrade_shortlist`)."""
    rng = np.random.default_rng(21)
    W = rng.normal(size=(L, D)).astype(np.float32) * 0.1
    W = np.array(prune(jnp.asarray(W), 0.05))
    bsr = to_block_sparse(jnp.asarray(W), BLOCK)
    x = rng.normal(size=(6, D)).astype(np.float32)
    dirs = {}
    for state in STATES:
        d = str(tmp_path_factory.mktemp(state))
        bsr.save(d, meta={"n_labels": L, "n_features": D})
        path = os.path.join(d, SHORTLIST_FILE)
        if state == "legacy":
            os.remove(path)                      # checkpoint predates PR 6
        elif state == "v1":
            art = load_shortlist(d)              # centroid payload...
            np.savez(path,                       # ...re-written as v1 keys
                     centroids=np.asarray(art.centroids, np.float32),
                     block_rows=np.int64(art.block_rows),
                     n_labels=np.int64(art.n_labels),
                     stat=np.asarray(art.stat))
            assert load_shortlist(d).kind == "centroid"   # v1 read path
        else:
            model, _ = load_block_sparse(d)
            Y = (x @ W.T > 0).astype(np.int8)    # any labels; builder only
            upgrade_shortlist(d, build_learned_shortlist(model, x, Y,
                                                         max_newton=3))
            assert load_shortlist(d).kind == "learned"
        dirs[state] = d
    return dirs, W, x


def _dequant_dense(W):
    """Dense weights after a quantize->dequantize round trip — the scoring
    matrix an int8 fine stage actually serves (deterministic in W)."""
    q = quantize_block_sparse(to_block_sparse(jnp.asarray(W), BLOCK))
    deq = BlockSparseModel(
        blocks=jnp.asarray(dequantize_blocks(np.asarray(q.blocks),
                                             np.asarray(q.scales))),
        block_rows=q.block_rows, block_cols=q.block_cols, row_ptr=q.row_ptr,
        shape=q.shape, block_shape=q.block_shape, orig_shape=q.orig_shape)
    return np.asarray(deq.to_dense())[:L, :D]


@pytest.mark.parametrize("state", STATES)
@pytest.mark.parametrize("dtype", ["fp32", "int8"])
@pytest.mark.parametrize("kind", sorted(available_backends()))
def test_full_width_topk_identity(ckpts, kind, dtype, state):
    dirs, W, x = ckpts
    R = -(-L // BLOCK[0])
    int8 = dtype == "int8"
    eng = XMCEngine.from_checkpoint(dirs[state], backend=kind, k=K,
                                    warmup=False, buckets=(8,),
                                    shortlist_blocks=R, int8=int8)
    got = eng.serve([x])[0]
    # dense/sharded have no int8 path: requesting int8 leaves them fp32
    # (make_backend filters the kwarg), so they compare against fp32 dense.
    int8_served = kind == "int8" or (int8 and kind in ("bsr", "shortlist"))
    Wref = _dequant_dense(W) if int8_served else W
    ref = DenseBackend(jnp.asarray(Wref), K, n_labels=L)
    _, want = ref.topk(jnp.asarray(x))           # dense rows are independent
    np.testing.assert_array_equal(got.labels, np.asarray(want))

    if kind == "shortlist":
        # Full-width per-query must collapse to the shared path: same
        # executable, bit-identical output, ragged kernel never engaged.
        eng_pq = XMCEngine.from_checkpoint(
            dirs[state], backend=kind, k=K, warmup=False, buckets=(8,),
            shortlist_blocks=R, int8=int8, shortlist_per_query=True)
        if state != "legacy":                    # legacy falls back to bsr
            assert eng_pq.backend.per_query is False
        res = eng_pq.serve([x])[0]
        np.testing.assert_array_equal(res.labels, got.labels)
        np.testing.assert_array_equal(res.scores, got.scores)
