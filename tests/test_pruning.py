"""Delta-pruning and block-sparse conversion — property-based (hypothesis)."""

import numpy as np
import pytest
from _hyp_compat import given, hnp, settings, st

import jax.numpy as jnp

from repro.core.pruning import (ambiguous_fraction, concat_block_sparse, nnz,
                                prune, sparsity, to_block_sparse,
                                weight_histogram)

W_STRAT = hnp.arrays(np.float32, hnp.array_shapes(min_dims=2, max_dims=2,
                                                  min_side=1, max_side=64),
                     elements=st.floats(-2.0, 2.0, width=32))


@given(W=W_STRAT, delta=st.floats(0.0, 0.5))
@settings(max_examples=60, deadline=None)
def test_prune_support_invariant(W, delta):
    """After pruning: every surviving weight has |w| >= delta, every removed
    weight had |w| < delta, survivors are bit-identical to the input."""
    Wp = np.asarray(prune(jnp.asarray(W), delta))
    surv = Wp != 0.0
    assert (np.abs(Wp[surv]) >= delta).all()
    np.testing.assert_array_equal(Wp[surv], W[surv])
    removed = (~surv) & (W != 0.0)
    assert (np.abs(W[removed]) < delta).all()


@given(W=W_STRAT, d1=st.floats(0.0, 0.3), d2=st.floats(0.0, 0.3))
@settings(max_examples=40, deadline=None)
def test_prune_monotone_and_idempotent(W, d1, d2):
    lo, hi = sorted([d1, d2])
    W = jnp.asarray(W)
    assert int(nnz(prune(W, hi))) <= int(nnz(prune(W, lo)))
    Wp = prune(W, hi)
    np.testing.assert_array_equal(np.asarray(prune(Wp, hi)), np.asarray(Wp))


@given(W=W_STRAT, delta=st.floats(0.0, 0.5))
@settings(max_examples=40, deadline=None)
def test_sparsity_ambiguous_consistency(W, delta):
    W = jnp.asarray(W)
    Wp = prune(W, delta)
    s = float(sparsity(Wp))
    assert 0.0 <= s <= 1.0
    # ambiguous_fraction on the raw W bounds the pruned sparsity from below
    # (zeros can only come from |w| < delta or pre-existing zeros).
    assert s >= float(ambiguous_fraction(W, delta)) - 1e-6 or delta == 0.0


@given(W=hnp.arrays(np.float32, st.tuples(st.integers(1, 40),
                                          st.integers(1, 40)),
                    elements=st.floats(-1.0, 1.0, width=32)),
       bl=st.sampled_from([4, 8, 16]), bd=st.sampled_from([4, 8, 16]))
@settings(max_examples=40, deadline=None)
def test_block_sparse_roundtrip(W, bl, bd):
    """to_dense(to_block_sparse(W)) == W up to zero padding."""
    model = to_block_sparse(jnp.asarray(W), (bl, bd))
    dense = np.asarray(model.to_dense())
    L, D = W.shape
    np.testing.assert_array_equal(dense[:L, :D], W)
    # Padding region must be zero.
    assert (dense[L:, :] == 0).all() and (dense[:, D:] == 0).all()
    assert 0.0 <= model.density <= 1.0


def test_block_sparse_skips_zero_blocks():
    W = np.zeros((64, 64), np.float32)
    W[:16, :16] = 1.0          # exactly one nonzero 16x16 block
    m = to_block_sparse(jnp.asarray(W), (16, 16))
    assert m.n_blocks == 1
    assert m.density == 1 / 16


@given(W=hnp.arrays(np.float32, st.tuples(st.sampled_from([16, 32, 48, 72]),
                                          st.integers(1, 40)),
                    elements=st.floats(-1.0, 1.0, width=32)),
       chunk=st.sampled_from([16, 32]))
@settings(max_examples=30, deadline=None)
def test_concat_append_form_matches_full_conversion(W, chunk):
    """Splitting W into row chunks, converting each in append form
    (row_block_offset) and concatenating must reproduce the one-shot
    conversion FIELD-BY-FIELD — the invariant the streamed multi-shard
    checkpoint relies on (no re-tiling, identical packing order)."""
    bl, bd = 16, 8
    full = to_block_sparse(jnp.asarray(W), (bl, bd))
    parts = [to_block_sparse(jnp.asarray(W[s:s + chunk]), (bl, bd),
                             row_block_offset=s // bl,
                             sentinel_if_empty=False)
             for s in range(0, W.shape[0], chunk)]
    cat = concat_block_sparse(parts, W.shape)
    assert cat.shape == full.shape and cat.block_shape == full.block_shape
    assert cat.orig_shape == full.orig_shape
    np.testing.assert_array_equal(np.asarray(cat.blocks),
                                  np.asarray(full.blocks))
    np.testing.assert_array_equal(np.asarray(cat.block_rows),
                                  np.asarray(full.block_rows))
    np.testing.assert_array_equal(np.asarray(cat.block_cols),
                                  np.asarray(full.block_cols))
    np.testing.assert_array_equal(np.asarray(cat.row_ptr),
                                  np.asarray(full.row_ptr))


def test_concat_all_empty_parts_yields_sentinel():
    """A fully-pruned model streamed in batches still loads: the concat of
    empty append-form parts carries the same single-zero-block sentinel the
    kernels expect from a one-shot conversion of an all-zero matrix."""
    Z = np.zeros((32, 16), np.float32)
    parts = [to_block_sparse(jnp.asarray(Z[s:s + 16]), (16, 16),
                             row_block_offset=s // 16,
                             sentinel_if_empty=False)
             for s in (0, 16)]
    assert all(int(p.row_ptr[-1]) == 0 for p in parts)
    cat = concat_block_sparse(parts, (32, 16))
    full = to_block_sparse(jnp.asarray(Z), (16, 16))
    np.testing.assert_array_equal(np.asarray(cat.blocks),
                                  np.asarray(full.blocks))
    np.testing.assert_array_equal(np.asarray(cat.row_ptr),
                                  np.asarray(full.row_ptr))
    np.testing.assert_array_equal(np.asarray(cat.to_dense()), Z)


def test_concat_rejects_mismatched_parts():
    a = to_block_sparse(jnp.ones((16, 16)), (16, 16), sentinel_if_empty=False)
    b = to_block_sparse(jnp.ones((16, 32)), (16, 16), row_block_offset=1,
                        sentinel_if_empty=False)
    with pytest.raises(ValueError, match="feature width"):
        concat_block_sparse([a, b], (32, 16))
    with pytest.raises(ValueError, match="at least one part"):
        concat_block_sparse([], (0, 16))


def test_weight_histogram_sums():
    rng = np.random.default_rng(0)
    W = jnp.asarray(rng.normal(size=(32, 32)) * 0.05, jnp.float32)
    counts, edges = weight_histogram(W, bins=41, lim=0.5)
    assert int(jnp.sum(counts)) <= W.size
    assert counts.shape[0] == 41 and edges.shape[0] == 42
