"""Delta-pruning and block-sparse conversion — property-based (hypothesis)."""

import numpy as np
from _hyp_compat import given, hnp, settings, st

import jax.numpy as jnp

from repro.core.pruning import (ambiguous_fraction, nnz, prune, sparsity,
                                to_block_sparse, weight_histogram)

W_STRAT = hnp.arrays(np.float32, hnp.array_shapes(min_dims=2, max_dims=2,
                                                  min_side=1, max_side=64),
                     elements=st.floats(-2.0, 2.0, width=32))


@given(W=W_STRAT, delta=st.floats(0.0, 0.5))
@settings(max_examples=60, deadline=None)
def test_prune_support_invariant(W, delta):
    """After pruning: every surviving weight has |w| >= delta, every removed
    weight had |w| < delta, survivors are bit-identical to the input."""
    Wp = np.asarray(prune(jnp.asarray(W), delta))
    surv = Wp != 0.0
    assert (np.abs(Wp[surv]) >= delta).all()
    np.testing.assert_array_equal(Wp[surv], W[surv])
    removed = (~surv) & (W != 0.0)
    assert (np.abs(W[removed]) < delta).all()


@given(W=W_STRAT, d1=st.floats(0.0, 0.3), d2=st.floats(0.0, 0.3))
@settings(max_examples=40, deadline=None)
def test_prune_monotone_and_idempotent(W, d1, d2):
    lo, hi = sorted([d1, d2])
    W = jnp.asarray(W)
    assert int(nnz(prune(W, hi))) <= int(nnz(prune(W, lo)))
    Wp = prune(W, hi)
    np.testing.assert_array_equal(np.asarray(prune(Wp, hi)), np.asarray(Wp))


@given(W=W_STRAT, delta=st.floats(0.0, 0.5))
@settings(max_examples=40, deadline=None)
def test_sparsity_ambiguous_consistency(W, delta):
    W = jnp.asarray(W)
    Wp = prune(W, delta)
    s = float(sparsity(Wp))
    assert 0.0 <= s <= 1.0
    # ambiguous_fraction on the raw W bounds the pruned sparsity from below
    # (zeros can only come from |w| < delta or pre-existing zeros).
    assert s >= float(ambiguous_fraction(W, delta)) - 1e-6 or delta == 0.0


@given(W=hnp.arrays(np.float32, st.tuples(st.integers(1, 40),
                                          st.integers(1, 40)),
                    elements=st.floats(-1.0, 1.0, width=32)),
       bl=st.sampled_from([4, 8, 16]), bd=st.sampled_from([4, 8, 16]))
@settings(max_examples=40, deadline=None)
def test_block_sparse_roundtrip(W, bl, bd):
    """to_dense(to_block_sparse(W)) == W up to zero padding."""
    model = to_block_sparse(jnp.asarray(W), (bl, bd))
    dense = np.asarray(model.to_dense())
    L, D = W.shape
    np.testing.assert_array_equal(dense[:L, :D], W)
    # Padding region must be zero.
    assert (dense[L:, :] == 0).all() and (dense[:, D:] == 0).all()
    assert 0.0 <= model.density <= 1.0


def test_block_sparse_skips_zero_blocks():
    W = np.zeros((64, 64), np.float32)
    W[:16, :16] = 1.0          # exactly one nonzero 16x16 block
    m = to_block_sparse(jnp.asarray(W), (16, 16))
    assert m.n_blocks == 1
    assert m.density == 1 / 16


def test_weight_histogram_sums():
    rng = np.random.default_rng(0)
    W = jnp.asarray(rng.normal(size=(32, 32)) * 0.05, jnp.float32)
    counts, edges = weight_histogram(W, bins=41, lim=0.5)
    assert int(jnp.sum(counts)) <= W.size
    assert counts.shape[0] == 41 and edges.shape[0] == 42
