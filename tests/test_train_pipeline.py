"""Streaming label-batch training pipeline (train/xmc.py): bit-exactness of
the streamed checkpoint vs the in-memory path, resume-after-kill semantics,
serving integration, and the append-form BSR plumbing underneath it."""

import json
import os
import tempfile

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint.io import (BSR_MANIFEST, has_block_sparse_checkpoint,
                                 load_block_sparse, load_block_sparse_meta)
from repro.core.dismec import DiSMECConfig, train
from repro.serve import XMCEngine
from repro.train.xmc import XMCTrainJob

L, D = 72, 512         # L = 4.5 x label_batch: exercises the partial batch
LABEL_BATCH = 16
BLOCK = (16, 16)
CFG = DiSMECConfig(label_batch=LABEL_BATCH, eps=1e-2)


@pytest.fixture(scope="module")
def xmc_data():
    from repro.data.xmc import make_xmc_dataset
    d = make_xmc_dataset(n_train=200, n_test=50, n_features=D, n_labels=L,
                         seed=0)
    return (jnp.asarray(d.X_train), jnp.asarray(d.Y_train),
            jnp.asarray(d.X_test))


@pytest.fixture(scope="module")
def streamed_ckpt(xmc_data, tmp_path_factory):
    """One streamed multi-shard checkpoint shared by the read-only tests."""
    X, Y, _ = xmc_data
    out = str(tmp_path_factory.mktemp("xmc_stream"))
    res = XMCTrainJob(cfg=CFG, block_shape=BLOCK).run(X, Y, out)
    assert res.complete and res.n_batches == 5
    return out


def test_streamed_checkpoint_bit_exact_with_train(xmc_data, streamed_ckpt):
    """The streamed artifact must hold EXACTLY the weights the in-memory
    wrapper returns: pack -> shard -> manifest -> stitch loses nothing."""
    X, Y, _ = xmc_data
    model = train(X, Y, CFG)                   # same scheduler, materialized
    loaded, meta = load_block_sparse(streamed_ckpt)
    W = np.asarray(loaded.to_dense())[:L, :D]
    np.testing.assert_array_equal(W, np.asarray(model.W))
    assert meta["n_labels"] == L and meta["n_features"] == D


def test_streamed_checkpoint_serves_identical_topk(xmc_data, streamed_ckpt):
    """Acceptance criterion: the streamed checkpoint through PR 1's engine
    returns identical top-k to a model trained one-shot (label_batch=L)."""
    X, Y, Xte = xmc_data
    one_shot = train(X, Y, DiSMECConfig(label_batch=L, eps=1e-2))
    eng_stream = XMCEngine.from_checkpoint(streamed_ckpt, backend="bsr",
                                           k=5, warmup=False)
    eng_one = XMCEngine.from_dismec(one_shot, backend="dense", k=5)
    q = np.asarray(Xte[:32], np.float32)
    r_stream = eng_stream.serve([q])[0]
    r_one = eng_one.serve([q])[0]
    np.testing.assert_array_equal(r_stream.labels, r_one.labels)


def test_resume_after_kill_identical_manifest(xmc_data, tmp_path):
    """Kill the job mid-run (max_batches), resume, and land on a manifest
    identical to an uninterrupted run — without re-solving done batches."""
    X, Y, _ = xmc_data
    job = XMCTrainJob(cfg=CFG, block_shape=BLOCK)
    a, b = str(tmp_path / "killed"), str(tmp_path / "clean")

    r1 = job.run(X, Y, a, max_batches=2)
    assert not r1.complete and r1.solved == [0, 1]
    assert not has_block_sparse_checkpoint(a)          # not servable yet
    with pytest.raises(ValueError, match="incomplete"):
        load_block_sparse(a)

    solved_on_resume = []
    r2 = job.run(X, Y, a, on_batch=lambda i, n: solved_on_resume.append(i))
    assert r2.complete
    assert r2.skipped == [0, 1]                        # no re-solving
    assert r2.solved == solved_on_resume == [2, 3, 4]

    r3 = job.run(X, Y, b)
    assert r3.complete
    with open(os.path.join(a, BSR_MANIFEST)) as f:
        ma = json.load(f)
    with open(os.path.join(b, BSR_MANIFEST)) as f:
        mb = json.load(f)
    assert ma == mb
    Wa = np.asarray(load_block_sparse(a)[0].to_dense())
    Wb = np.asarray(load_block_sparse(b)[0].to_dense())
    np.testing.assert_array_equal(Wa, Wb)


def test_overlap_checkpoint_identical_to_sequential(xmc_data, tmp_path):
    """The double-buffered scheduler (overlap=True, the default) must write
    a byte-identical checkpoint to the fully sequential one: same manifest,
    same stitched weights."""
    X, Y, _ = xmc_data
    a, b = str(tmp_path / "seq"), str(tmp_path / "ovl")
    r_seq = XMCTrainJob(cfg=CFG, block_shape=BLOCK, overlap=False).run(X, Y, a)
    r_ovl = XMCTrainJob(cfg=CFG, block_shape=BLOCK, overlap=True,
                        max_inflight=3).run(X, Y, b)
    assert r_seq.complete and r_ovl.complete
    assert r_seq.solved == r_ovl.solved                  # dispatch order kept
    with open(os.path.join(a, BSR_MANIFEST)) as f:
        ma = json.load(f)
    with open(os.path.join(b, BSR_MANIFEST)) as f:
        mb = json.load(f)
    assert ma == mb
    np.testing.assert_array_equal(
        np.asarray(load_block_sparse(a)[0].to_dense()),
        np.asarray(load_block_sparse(b)[0].to_dense()))


def test_overlap_kill_resume_bit_identical(xmc_data, tmp_path):
    """Satellite: a double-buffered job stopped mid-flight — while writes
    are still sitting in the background queue — leaves a manifest that
    resumes to a bit-identical checkpoint vs a sequential run.

    The kill is injected from the writer thread itself (on_batch raising
    after batch 1's shard write), so at the moment of death later batches
    are already dispatched and their results queued but unwritten: exactly
    the crash window double-buffering adds."""
    X, Y, _ = xmc_data

    class Kill(RuntimeError):
        pass

    def die_after_two(b, n):
        if b >= 1:
            raise Kill(f"killed after batch {b}")

    job = XMCTrainJob(cfg=CFG, block_shape=BLOCK, overlap=True,
                      max_inflight=3)
    killed, clean = str(tmp_path / "killed"), str(tmp_path / "clean")
    with pytest.raises(Kill):
        job.run(X, Y, killed, on_batch=die_after_two)
    with open(os.path.join(killed, BSR_MANIFEST)) as f:
        m_killed = json.load(f)
    # Only fully written batches are in the manifest; queued-but-unwritten
    # ones are not (they will be re-solved on resume).
    assert not m_killed["complete"]
    assert set(m_killed["shards"]) == {"0", "1"}

    r2 = job.run(X, Y, killed)                           # resume
    assert r2.complete and r2.skipped == [0, 1]

    r3 = XMCTrainJob(cfg=CFG, block_shape=BLOCK, overlap=False).run(
        X, Y, clean)
    assert r3.complete
    with open(os.path.join(killed, BSR_MANIFEST)) as f:
        ma = json.load(f)
    with open(os.path.join(clean, BSR_MANIFEST)) as f:
        mb = json.load(f)
    assert ma == mb
    np.testing.assert_array_equal(
        np.asarray(load_block_sparse(killed)[0].to_dense()),
        np.asarray(load_block_sparse(clean)[0].to_dense()))


def test_streaming_never_materializes_dense_W(tmp_path):
    """Device memory scales with label_batch: no live (L, D) / (L, N) array
    at any batch boundary of a streaming (materialize=False) run. Uses its
    own (L, D, N) so arrays cached by other tests can't collide."""
    from repro.data.xmc import make_xmc_dataset
    L2, D2, N2 = 80, 640, 150          # unique to this test; L = 5 x batch
    d = make_xmc_dataset(n_train=N2, n_test=10, n_features=D2, n_labels=L2,
                         seed=3)
    X, Y = jnp.asarray(d.X_train), jnp.asarray(d.Y_train)
    forbidden = {(L2, D2), (L2, N2)}

    def check(_b, _n):
        live = {tuple(a.shape) for a in jax.live_arrays() if a.ndim == 2}
        assert not (live & forbidden), live & forbidden

    res = XMCTrainJob(cfg=DiSMECConfig(label_batch=16, eps=1e-2),
                      block_shape=BLOCK).run(
        X, Y, str(tmp_path / "ck"), on_batch=check)
    assert res.complete and res.model is None


def test_misaligned_label_batch_raises(xmc_data, tmp_path):
    X, Y, _ = xmc_data
    job = XMCTrainJob(cfg=DiSMECConfig(label_batch=20), block_shape=(16, 16))
    with pytest.raises(ValueError, match="multiple of the BSR block height"):
        job.run(X, Y, str(tmp_path / "ck"))


def test_resume_config_mismatch_raises(xmc_data, streamed_ckpt):
    X, Y, _ = xmc_data
    job = XMCTrainJob(cfg=DiSMECConfig(label_batch=8, eps=1e-2),
                      block_shape=(8, 8))
    with pytest.raises(ValueError, match="manifest disagrees"):
        job.run(X, Y, streamed_ckpt)
    # Same shapes but different solver hyperparameters: the shards on disk
    # were solved under another C, so stitching more onto them is wrong.
    job2 = XMCTrainJob(cfg=DiSMECConfig(label_batch=LABEL_BATCH, eps=1e-2,
                                        C=10.0), block_shape=BLOCK)
    with pytest.raises(ValueError, match="manifest disagrees"):
        job2.run(X, Y, streamed_ckpt)
    # ...and so is resuming with different training data.
    job3 = XMCTrainJob(cfg=CFG, block_shape=BLOCK)
    with pytest.raises(ValueError, match="manifest disagrees"):
        job3.run(X * 2.0, Y, streamed_ckpt)


def test_stream_refuses_dir_with_single_shard_checkpoint(xmc_data, tmp_path):
    """A pre-existing single-shard artifact would shadow the stream on load
    (load_block_sparse prefers bsr_index.json): streaming into such a
    directory must fail loudly unless explicitly starting fresh — and after
    resume=False, loads must return the NEW model, not the stale one."""
    from repro.core.pruning import prune, to_block_sparse
    X, Y, _ = xmc_data
    out = str(tmp_path / "ck")
    rng = np.random.default_rng(0)
    stale = prune(jnp.asarray(rng.normal(size=(L, D)), jnp.float32), 0.5)
    to_block_sparse(stale, BLOCK).save(out, meta={"n_labels": L,
                                                  "n_features": D})
    job = XMCTrainJob(cfg=CFG, block_shape=BLOCK)
    with pytest.raises(ValueError, match="single-shard"):
        job.run(X, Y, out)
    res = job.run(X, Y, out, resume=False)
    assert res.complete
    W = np.asarray(load_block_sparse(out)[0].to_dense())[:L, :D]
    np.testing.assert_array_equal(W, np.asarray(train(X, Y, CFG).W))


def test_stream_meta_preflight(streamed_ckpt):
    """load_block_sparse_meta serves the same pre-flight schema for the
    multi-shard layout as for the single-shard one (serving CLI contract)."""
    index = load_block_sparse_meta(streamed_ckpt)
    assert index["format"] == "bsr" and index["layout"] == "stream"
    assert index["orig_shape"] == [L, D]
    assert index["meta"]["n_features"] == D
    assert index["n_blocks"] == sum(
        s["n_blocks"] for s in index["manifest"]["shards"].values())


def test_materializing_resume_reads_shards(xmc_data, tmp_path):
    """materialize=True over a partially-complete checkpoint rebuilds the
    already-solved rows from their shards instead of re-solving them."""
    X, Y, _ = xmc_data
    job = XMCTrainJob(cfg=CFG, block_shape=BLOCK)
    out = str(tmp_path / "ck")
    job.run(X, Y, out, max_batches=3)
    res = job.run(X, Y, out, materialize=True)
    assert res.complete and res.skipped == [0, 1, 2]
    np.testing.assert_array_equal(np.asarray(res.model.W),
                                  np.asarray(train(X, Y, CFG).W))
