"""Property-based invariants for the pack/quantize/serve pipeline.

Runs under real hypothesis when installed; otherwise tests/_hyp_compat.py
replays each property on a handful of fixed-seed examples, so the suite is
deterministic in the offline CI image either way.

Three families:

  * symmetric int8 quantization: per-element error is bounded by half the
    per-block scale, and all-zero (fully-pruned sentinel) blocks round-trip
    exactly — the bound the int8 serving kernels' accuracy story rests on;
  * BSR packing algebra: packing row-block-aligned slices independently and
    stitching them with `concat_block_sparse` is FIELD-exact (same packed
    blocks, coordinates, and row_ptr) as packing the whole matrix at once —
    the invariant that lets the streaming trainer emit per-batch slices;
  * pack-time label reorder: permute labels -> pack -> serve -> unmap via
    `RelabelBackend` returns exactly the ids and scores of serving the
    un-permuted model, for any permutation.
"""

import numpy as np

import jax.numpy as jnp

from repro.core.pruning import (INT8_QMAX, concat_block_sparse,
                                dequantize_blocks, prune, quantize_blocks,
                                to_block_sparse)
from repro.serve.xmc import DenseBackend, make_backend

from _hyp_compat import given, settings, st

SEEDS = st.integers(min_value=0, max_value=2 ** 31 - 1)


# ---------------------------------------------------------------------------
# int8 quantization
# ---------------------------------------------------------------------------

@settings(deadline=None, max_examples=25)
@given(seed=SEEDS,
       nb=st.integers(min_value=1, max_value=6),
       bl=st.sampled_from([1, 3, 8, 16]),
       bd=st.sampled_from([1, 4, 32]),
       scale=st.floats(min_value=1e-3, max_value=1e3))
def test_quantize_error_within_half_scale(seed, nb, bl, bd, scale):
    """|dequant - x| <= scales[k] / 2 element-wise, every block."""
    rng = np.random.default_rng(seed)
    b = (rng.standard_normal((nb, bl, bd)) * scale).astype(np.float32)
    q, scales = quantize_blocks(b)
    assert q.dtype == np.int8 and np.abs(q).max(initial=0) <= INT8_QMAX
    err = np.abs(dequantize_blocks(q, scales) - b)
    # exact bound is scales/2 (round-to-nearest); tiny fp32 slack on top
    bound = scales[:, None, None] * (0.5 + 1e-5)
    assert np.all(err <= bound)


@settings(deadline=None, max_examples=25)
@given(seed=SEEDS, nb=st.integers(min_value=2, max_value=6))
def test_quantize_zero_blocks_exact(seed, nb):
    """Fully-pruned (all-zero) blocks get scale 0 and reconstruct EXACTLY —
    quantization may never resurrect a pruned block."""
    rng = np.random.default_rng(seed)
    b = rng.standard_normal((nb, 4, 8)).astype(np.float32)
    zeros = rng.choice(nb, size=nb // 2, replace=False)
    b[zeros] = 0.0
    q, scales = quantize_blocks(b)
    assert np.all(scales[zeros] == 0.0)
    assert np.all(q[zeros] == 0)
    assert np.all(dequantize_blocks(q, scales)[zeros] == 0.0)
    # and quantization is deterministic (lazy re-quantization at load must
    # reproduce the persisted artifact bit-for-bit)
    q2, scales2 = quantize_blocks(b)
    np.testing.assert_array_equal(q, q2)
    np.testing.assert_array_equal(scales, scales2)


# ---------------------------------------------------------------------------
# BSR packing algebra
# ---------------------------------------------------------------------------

def _random_pruned(rng, L, D, delta=0.06):
    W = (rng.standard_normal((L, D)) * 0.1).astype(np.float32)
    return np.asarray(prune(jnp.asarray(W), delta))


@settings(deadline=None, max_examples=15)
@given(seed=SEEDS,
       bl=st.sampled_from([4, 8, 16]),
       n_splits=st.integers(min_value=1, max_value=5))
def test_split_pack_concat_field_exact(seed, bl, n_splits):
    """Packing random row-block-aligned slices + concat == packing whole."""
    rng = np.random.default_rng(seed)
    L, D = int(rng.integers(3 * bl, 8 * bl)), 96   # ragged final row block
    block = (bl, 32)
    W = _random_pruned(rng, L, D)
    whole = to_block_sparse(jnp.asarray(W), block)

    nbl = -(-L // bl)
    cuts = np.unique(rng.integers(1, nbl, size=n_splits)) * bl
    bounds = [0, *cuts.tolist(), L]
    parts = [
        to_block_sparse(jnp.asarray(W[a:b]), block,
                        row_block_offset=a // bl, sentinel_if_empty=False)
        for a, b in zip(bounds[:-1], bounds[1:])
    ]
    merged = concat_block_sparse(parts, orig_shape=(L, D))

    assert merged.shape == whole.shape
    assert merged.orig_shape == whole.orig_shape
    assert merged.block_shape == whole.block_shape
    np.testing.assert_array_equal(np.asarray(merged.row_ptr),
                                  np.asarray(whole.row_ptr))
    np.testing.assert_array_equal(np.asarray(merged.block_rows),
                                  np.asarray(whole.block_rows))
    np.testing.assert_array_equal(np.asarray(merged.block_cols),
                                  np.asarray(whole.block_cols))
    np.testing.assert_array_equal(np.asarray(merged.blocks),
                                  np.asarray(whole.blocks))


# ---------------------------------------------------------------------------
# pack-time label reorder round trip
# ---------------------------------------------------------------------------

@settings(deadline=None, max_examples=10)
@given(seed=SEEDS, kind=st.sampled_from(["dense", "bsr"]))
def test_permute_pack_serve_unmap_is_identity(seed, kind):
    """For ANY label permutation: pack the rows in permuted order, serve,
    unmap through `RelabelBackend` -> exactly the ids of serving the
    original order (scores to fp32 tolerance: block accumulation order
    differs from the dense reference). Continuous random weights make
    score ties a measure-zero event, so top-k id sequences must match
    exactly."""
    rng = np.random.default_rng(seed)
    L, D, k = 60, 64, 4
    W = _random_pruned(rng, L, D)
    x = rng.standard_normal((3, D)).astype(np.float32)
    order = rng.permutation(L).astype(np.int64)   # packed row i = label order[i]

    packed = to_block_sparse(jnp.asarray(W[order]), (8, 32))
    be = make_backend(kind, packed, k, n_labels=L, label_order=order)
    scores, labels = be.topk(jnp.asarray(x))

    ref_s, ref_l = DenseBackend(jnp.asarray(W), k, n_labels=L).topk(
        jnp.asarray(x))
    np.testing.assert_array_equal(np.asarray(labels), np.asarray(ref_l))
    np.testing.assert_allclose(np.asarray(scores), np.asarray(ref_s),
                               rtol=1e-5, atol=1e-6)


@settings(deadline=None, max_examples=10)
@given(seed=SEEDS, bl=st.sampled_from([4, 8]))
def test_cooccurrence_order_recovers_planted_blocks(seed, bl):
    """`cooccurrence_label_order` on data with planted label groups (each
    group's labels always co-occur, never across groups) is a permutation
    that reunites every group into one row block — for any scramble."""
    from repro.serve.shortlist import cooccurrence_label_order
    rng = np.random.default_rng(seed)
    n_groups, docs_per = 6, 4
    L = n_groups * bl
    scram = rng.permutation(L)
    Y = np.zeros((n_groups * docs_per, L), np.int8)
    for g in range(n_groups):
        members = scram[g * bl:(g + 1) * bl]          # scattered label ids
        Y[g * docs_per:(g + 1) * docs_per][:, members] = 1
    order = cooccurrence_label_order(Y, block_rows=bl)
    assert sorted(order.tolist()) == list(range(L))   # a true permutation
    group_of = np.empty(L, np.int64)
    for g in range(n_groups):
        group_of[scram[g * bl:(g + 1) * bl]] = g
    packed_groups = group_of[order].reshape(n_groups, bl)
    for row in packed_groups:                         # block-pure packing
        assert len(set(row.tolist())) == 1
