"""Beyond-paper features: frequency-balanced label sharding, MoE dispatch
invariants (hypothesis), and the dry-run analysis tooling (hlo_cost parser,
roofline term model)."""

import numpy as np
import pytest
from _hyp_compat import given, settings, st

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# balance_permutation
# ---------------------------------------------------------------------------

@given(L=st.integers(4, 100), n_shards=st.sampled_from([2, 4, 8]),
       seed=st.integers(0, 50))
@settings(max_examples=40, deadline=None)
def test_balance_permutation_is_permutation(L, n_shards, seed):
    from repro.core.dismec import balance_permutation
    rng = np.random.default_rng(seed)
    Y = (rng.random((64, L)) < rng.power(3, L)).astype(np.int8)
    perm = balance_permutation(jnp.asarray(Y), n_shards)
    assert sorted(perm.tolist()) == list(range(L))


def test_balance_equalizes_shard_mass():
    """Each shard's total positive count should be near-equal after
    balancing, even under a power-law label distribution."""
    from repro.core.dismec import balance_permutation
    from repro.data.xmc import make_xmc_dataset
    d = make_xmc_dataset(n_train=400, n_test=10, n_features=512,
                         n_labels=64, beta=1.2, seed=0)
    n_shards = 8
    perm = balance_permutation(jnp.asarray(d.Y_train), n_shards)
    counts = d.Y_train.sum(axis=0)
    per = 64 // n_shards
    shard_mass = counts[perm].reshape(n_shards, per).sum(axis=1)
    naive_mass = np.sort(counts)[::-1].reshape(n_shards, per).sum(axis=1)
    # Much better than contiguous-by-rank assignment (10-50x apart on
    # power-law data)...
    assert shard_mass.max() / max(shard_mass.min(), 1) \
        < naive_mass.max() / max(naive_mass.min(), 1)
    # ...and within 15% of the information-theoretic lower bound: no
    # assignment can beat max(heaviest single label, mean shard mass).
    lower = max(counts.max(), counts.sum() / n_shards)
    assert shard_mass.max() <= 1.15 * lower


# ---------------------------------------------------------------------------
# MoE dispatch invariants
# ---------------------------------------------------------------------------

@given(n=st.integers(4, 48), E=st.sampled_from([4, 8]),
       k=st.sampled_from([1, 2]), seed=st.integers(0, 30))
@settings(max_examples=30, deadline=None)
def test_moe_dispatch_combine_matches_dense(n, E, k, seed):
    """Sort-based dispatch/combine == dense per-token expert evaluation when
    nothing overflows capacity."""
    from repro.models.moe import _dispatch_combine

    rng = np.random.default_rng(seed)
    d, f = 16, 32
    xf = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    probs_raw = rng.random((n, E)).astype(np.float32)
    probs = jnp.asarray(probs_raw / probs_raw.sum(-1, keepdims=True))
    w1 = jnp.asarray(rng.normal(size=(E, d, f)) * 0.1, jnp.float32)
    w3 = jnp.asarray(rng.normal(size=(E, d, f)) * 0.1, jnp.float32)
    w2 = jnp.asarray(rng.normal(size=(E, f, d)) * 0.1, jnp.float32)

    out = _dispatch_combine(xf, probs, k, capacity=n * k, w1=w1, w3=w3,
                            w2=w2, model_axis=None)

    # Dense reference: every token through its top-k experts.
    gv, gi = jax.lax.top_k(probs, k)
    gv = gv / jnp.maximum(gv.sum(-1, keepdims=True), 1e-9)
    ref = np.zeros((n, d), np.float32)
    for i in range(n):
        for j in range(k):
            e = int(gi[i, j])
            h = np.asarray(jax.nn.silu(xf[i] @ w1[e]) * (xf[i] @ w3[e]))
            ref[i] += float(gv[i, j]) * (h @ np.asarray(w2[e]))
    np.testing.assert_allclose(np.asarray(out), ref, rtol=2e-3, atol=2e-3)


def test_moe_capacity_drops_tokens():
    """With capacity 1 per expert, overflowing tokens contribute zero —
    the documented Switch-style behaviour."""
    from repro.models.moe import _dispatch_combine
    n, E, d, f = 8, 2, 4, 8
    xf = jnp.ones((n, d), jnp.float32)
    probs = jnp.asarray(np.tile([[0.9, 0.1]], (n, 1)), jnp.float32)
    w1 = jnp.ones((E, d, f)) * 0.1
    w3 = jnp.ones((E, d, f)) * 0.1
    w2 = jnp.ones((E, f, d)) * 0.1
    out = _dispatch_combine(xf, probs, 1, capacity=1, w1=w1, w3=w3, w2=w2,
                            model_axis=None)
    nz_rows = int(jnp.sum(jnp.any(out != 0.0, axis=1)))
    assert nz_rows == 1          # only the first token fit expert 0


# ---------------------------------------------------------------------------
# hlo_cost parser + roofline term model
# ---------------------------------------------------------------------------

HLO_SAMPLE = """
HloModule test

%body (p: (f32[8,8], s32[])) -> (f32[8,8], s32[]) {
  %p = (f32[8,8], s32[]) parameter(0)
  %a = f32[8,8] get-tuple-element(%p), index=0
  %dot.1 = f32[8,8] dot(%a, %a), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,8] all-reduce(%dot.1), replica_groups={}
  %i = s32[] get-tuple-element(%p), index=1
  ROOT %t = (f32[8,8], s32[]) tuple(%ar, %i)
}

%cond (p2: (f32[8,8], s32[])) -> pred[] {
  %p2 = (f32[8,8], s32[]) parameter(0)
  ROOT %lt = pred[] constant(true)
}

ENTRY %main.1 (x: f32[8,8]) -> f32[8,8] {
  %x = f32[8,8] parameter(0)
  %w = (f32[8,8], s32[]) while(%x), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"4"}}
  ROOT %out = f32[8,8] get-tuple-element(%w), index=0
}
"""


def test_hlo_cost_trip_multiplication():
    from repro.launch.hlo_cost import summarize
    s = summarize(HLO_SAMPLE)
    # dot: 2*8*8*8 = 1024 flops, x4 trips
    assert s["flops"] == pytest.approx(4 * 1024)
    # all-reduce operand: 8*8*4 bytes = 256, x4 trips
    assert s["collectives"]["all-reduce"] == pytest.approx(4 * 256)
    # f32 share is 100% here
    assert s["collective_bytes_f32"] == pytest.approx(4 * 256)


def test_roofline_analyse_terms():
    from benchmarks.roofline import analyse
    rec = {
        "arch": "qwen1.5-0.5b", "shape": "train_4k", "mesh": "16x16",
        "flops_corrected": 197e12,            # exactly 1 second of compute
        "argument_bytes": 819e9 // 2, "output_bytes": 0,
        "temp_bytes": 819e9 // 4,             # floor = 1 second of HBM
        "hbm_bytes_corrected": 5 * 819e9,
        "collective_bytes_corrected": {"all-reduce": 25e9, "all-gather": 0,
                                       "reduce-scatter": 0, "all-to-all": 0,
                                       "collective-permute": 0},
        "peak_bytes": 10e9,
    }
    out = analyse(rec)
    assert out["compute_s"] == pytest.approx(1.0)
    assert out["memory_s"] == pytest.approx(1.0, rel=1e-6)
    assert out["collective_s"] == pytest.approx(0.5)
    assert out["dominant"] in ("compute", "memory")
    assert out["memory_upper_s"] == pytest.approx(5.0)
