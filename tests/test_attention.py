"""Attention path equivalences: dense SDPA == blockwise online-softmax ==
banded (block-skipping) sliding window, across GQA configs."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers

CFG = ArchConfig(name="t", family="dense", n_layers=1, d_model=64,
                 n_heads=4, n_kv_heads=2, d_ff=128, vocab=128,
                 dtype="float32")


def _qkv(B, T, H, KV, hd, seed=0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(B, T, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, T, KV, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, T, KV, hd)), jnp.float32)
    return q, k, v


def _dense_ref(q, k, v, window=None):
    B, T, H, hd = q.shape
    mask = layers.causal_mask(T, T, window=window)
    return layers._sdpa(CFG, q, k, v, mask)


@pytest.mark.parametrize("T", [256, 1000])
def test_blockwise_equals_dense(T):
    q, k, v = _qkv(2, T, 4, 2, 16)
    out_b = layers.blockwise_attention(CFG, q, k, v, q_chunk=128,
                                       kv_chunk=128)
    out_d = _dense_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out_b), np.asarray(out_d),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("T,window", [(512, 128), (1024, 256), (640, 100)])
def test_banded_equals_masked_dense(T, window):
    """banded_attention (skips KV blocks) == dense attention with the same
    sliding-window mask."""
    q, k, v = _qkv(2, T, 4, 2, 16, seed=T)
    out_band = layers.banded_attention(CFG, q, k, v, window=window,
                                       q_chunk=128)
    out_d = _dense_ref(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(out_band), np.asarray(out_d),
                               rtol=2e-4, atol=2e-4)


def test_banded_equals_blockwise_masked():
    T, window = 2048, 512
    q, k, v = _qkv(1, T, 4, 2, 16, seed=7)
    out_band = layers.banded_attention(CFG, q, k, v, window=window)
    out_blk = layers.blockwise_attention(CFG, q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(out_band), np.asarray(out_blk),
                               rtol=2e-4, atol=2e-4)


def test_banded_with_window_geq_T_is_full_causal():
    """window >= T makes the band the whole (causal) history: banded must
    equal plain causal attention."""
    T = 512
    q, k, v = _qkv(1, T, 4, 2, 16, seed=11)
    out_band = layers.banded_attention(CFG, q, k, v, window=T, q_chunk=128)
    out_full = _dense_ref(q, k, v, window=None)
    np.testing.assert_allclose(np.asarray(out_band), np.asarray(out_full),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("qc", [64, 128, 256])
def test_banded_chunk_size_invariance(qc):
    """The q-chunk size is an implementation knob: results must not
    depend on it."""
    T, window = 512, 160
    q, k, v = _qkv(1, T, 4, 2, 16, seed=13)
    out = layers.banded_attention(CFG, q, k, v, window=window, q_chunk=qc)
    ref = _dense_ref(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_window_segments():
    from repro.configs.registry import get_config
    from repro.models.transformer import window_segments

    hymba = get_config("hymba-1.5b")
    segs = window_segments(hymba, use_swa=True)
    # global at 0, 15, 31 -> 5 segments
    assert segs == [(0, 1, 0), (1, 15, 1024), (15, 16, 0),
                    (16, 31, 1024), (31, 32, 0)]
    mixtral = get_config("mixtral-8x22b")
    segs_m = window_segments(mixtral, use_swa=True)
    assert len(segs_m) == 1 and segs_m[0][2] == mixtral.sliding_window

    dense = get_config("qwen3-14b")
    assert window_segments(dense, use_swa=False) == [(0, 40, 0)]
