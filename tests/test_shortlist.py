"""Shortlist-gated sub-linear serving: gathered-block kernel parity, the
two-stage backend's equivalence/recall/fallback contracts, the persisted
artifact, and the shared warm-up compile ledger."""

import os
import tempfile

import numpy as np
import pytest

import jax.numpy as jnp

from repro.checkpoint.io import (SHORTLIST_FILE, load_block_sparse,
                                 load_block_sparse_meta, load_shortlist,
                                 save_shortlist)
from repro.core.pruning import prune, to_block_sparse
from repro.data.xmc import make_xmc_dataset
from repro.kernels.bsr_predict import ops as bsr_ops
from repro.kernels.bsr_predict import ref as bsr_ref
from repro.serve import (ShortlistBackend, XMCEngine, build_shortlist,
                         make_backend, reset_warmup_cache,
                         warmup_cache_stats)
from repro.serve.shortlist import ShortlistArtifact
from repro.specs import ServeSpec


def _random_pruned_bsr(L, D, *, block=(16, 128), delta=0.05, seed=0,
                       zero_rows=()):
    rng = np.random.default_rng(seed)
    W = rng.normal(size=(L, D)).astype(np.float32) * 0.1
    W = np.array(prune(jnp.asarray(W), delta))
    for r in zero_rows:
        W[r] = 0.0
    return W, to_block_sparse(jnp.asarray(W), block)


# ---------------------------------------------------------------------------
# Gathered-block kernel
# ---------------------------------------------------------------------------

def test_gather_kernel_matches_ref_non_tile_aligned():
    """Pallas gathered-block scoring == dense-gather oracle on shapes that
    hit both row padding (L=100 -> Lp=112 with bl=16) and feature padding
    (D=300 -> Dp=384), with an UNSORTED selection."""
    L, D = 100, 300
    _, bsr = _random_pruned_bsr(L, D, seed=1)
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(4, D)).astype(np.float32))
    sel = jnp.asarray([5, 0, 3], jnp.int32)          # arbitrary order
    got = bsr_ops.bsr_predict_gather(x, bsr, sel)
    want = bsr_ref.bsr_predict_gather(
        jnp.pad(x, ((0, 0), (0, bsr.shape[1] - D))), bsr, sel)
    assert got.shape == (4, 3 * 16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_gather_kernel_empty_row_block_is_exact_zero():
    """A selected row block whose labels were all Delta-pruned must come
    back EXACTLY 0.0 (the dense score of a pruned label), not garbage."""
    L, D = 64, 256
    zero_rows = list(range(16, 32))                  # kills row block 1
    _, bsr = _random_pruned_bsr(L, D, seed=3, zero_rows=zero_rows)
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.normal(size=(3, D)).astype(np.float32))
    out = np.asarray(bsr_ops.bsr_predict_gather(x, bsr, jnp.asarray([1, 2])))
    assert (out[:, :16] == 0.0).all()                # block 1: pruned
    assert (out[:, 16:] != 0.0).any()                # block 2: real scores


def test_gather_topk_full_coverage_is_bit_exact():
    """sel = every row block (sorted) reproduces the exhaustive fused
    predict->topk bit-for-bit, tie order included."""
    L, D, k = 100, 300, 5
    _, bsr = _random_pruned_bsr(L, D, seed=5)
    R = bsr.shape[0] // bsr.block_shape[0]
    rng = np.random.default_rng(6)
    x = jnp.asarray(rng.normal(size=(8, D)).astype(np.float32))
    v1, i1 = bsr_ops.bsr_predict_topk(x, bsr, k, n_labels=L)
    v2, i2 = bsr_ops.bsr_predict_gather_topk(x, bsr, jnp.arange(R), k,
                                             n_labels=L)
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))


# ---------------------------------------------------------------------------
# Shortlist backend
# ---------------------------------------------------------------------------

def test_shortlist_backend_full_width_equals_exhaustive():
    """B covering all row blocks == exhaustive BSR: identical scores AND
    identical label ids (the B-covers-all acceptance gate)."""
    L, D, k = 200, 300, 5
    _, bsr = _random_pruned_bsr(L, D, seed=7)
    art = build_shortlist(bsr)
    R = art.n_row_blocks
    rng = np.random.default_rng(8)
    x = jnp.asarray(rng.normal(size=(6, D)).astype(np.float32))
    sl = make_backend("shortlist", bsr, k, n_labels=L, shortlist=art,
                      shortlist_blocks=R)
    ex = make_backend("bsr", bsr, k, n_labels=L)
    v1, i1 = sl.topk(x)
    v2, i2 = ex.topk(x)
    assert isinstance(sl, ShortlistBackend) and sl.candidate_fraction == 1.0
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))


def test_shortlist_recall_gate_on_clustered_power_law():
    """On a cluster-ordered power-law label space (the regime candidate
    stages serve), a B = 3/16 shortlist recovers >= 95% of the exhaustive
    top-5 for single-query batches — at under 25% of the row blocks."""
    L, D, k = 128, 1024, 5
    data = make_xmc_dataset(n_train=8, n_test=48, n_features=D, n_labels=L,
                            pool_stride=2, label_locality=0.9,
                            multi_label_p=0.9, seed=9)
    # Analytic OvR weights from the generator's signature pools (training
    # would find ~these; the test needs the serving stack, not TRON).
    W = np.zeros((L, D), np.float32)
    for l in range(L):
        W[l, data.label_pools[l]] = 1.0
    bsr = to_block_sparse(jnp.asarray(W), (8, 128))
    art = build_shortlist(bsr)
    assert art.n_row_blocks == 16
    sl = make_backend("shortlist", bsr, k, n_labels=L, shortlist=art,
                      shortlist_blocks=3)
    ex = make_backend("bsr", bsr, k, n_labels=L)
    assert sl.candidate_fraction < 0.25

    hits = total = 0
    for q in np.asarray(data.X_test, np.float32):
        x = jnp.asarray(q[None, :])
        _, want = ex.topk(x)
        _, got = sl.topk(x)
        hits += len(set(np.asarray(want)[0].tolist())
                    & set(np.asarray(got)[0].tolist()))
        total += k
    assert hits / total >= 0.95, f"recall@{k} = {hits / total:.3f}"


def test_shortlist_spec_and_registry_fallback():
    """Without an artifact the "shortlist" kind degrades to exhaustive BSR
    (same results); old-style plugin factories without the shortlist
    kwargs still work through make_backend."""
    L, D, k = 100, 256, 3
    _, bsr = _random_pruned_bsr(L, D, seed=10)
    rng = np.random.default_rng(11)
    x = jnp.asarray(rng.normal(size=(4, D)).astype(np.float32))
    fb = make_backend("shortlist", bsr, k, n_labels=L)       # no artifact
    ex = make_backend("bsr", bsr, k, n_labels=L)
    assert fb.name == "bsr"
    np.testing.assert_array_equal(np.asarray(fb.topk(x)[1]),
                                  np.asarray(ex.topk(x)[1]))

    from repro.serve import register_backend, unregister_backend

    @register_backend("_old_style")
    def _old_factory(bsr_, k_, *, n_labels, mesh, label_axis, interpret):
        return make_backend("dense", bsr_, k_, n_labels=n_labels)
    try:
        be = make_backend("_old_style", bsr, k, n_labels=L,
                          shortlist=build_shortlist(bsr), shortlist_blocks=2)
        assert be.name == "dense"                # kwargs filtered, no crash
    finally:
        unregister_backend("_old_style")


# ---------------------------------------------------------------------------
# Artifact persistence
# ---------------------------------------------------------------------------

def test_artifact_roundtrip_and_validation():
    L, D = 150, 300
    _, bsr = _random_pruned_bsr(L, D, seed=12)
    art = build_shortlist(bsr)
    assert art.centroids.shape == (bsr.shape[0] // 16, bsr.shape[1])
    assert art.validate_against(bsr) is art
    with tempfile.TemporaryDirectory() as d:
        entry = save_shortlist(d, art)
        assert entry["file"] == SHORTLIST_FILE
        back = load_shortlist(d)
    np.testing.assert_array_equal(back.centroids, art.centroids)
    assert (back.block_rows, back.n_labels, back.stat) == (16, L, "mean")
    _, other = _random_pruned_bsr(64, D, block=(32, 128), seed=13)
    with pytest.raises(ValueError, match="does not match"):
        back.validate_against(other)


def test_centroids_are_true_block_means():
    L, D = 96, 256
    W, bsr = _random_pruned_bsr(L, D, seed=14)
    art = build_shortlist(bsr)
    dense = np.asarray(bsr.to_dense())
    for r in range(art.n_row_blocks):
        np.testing.assert_allclose(art.centroids[r],
                                   dense[r * 16:(r + 1) * 16].mean(axis=0),
                                   rtol=1e-6, atol=1e-6)


def test_save_writes_artifact_and_legacy_checkpoint_falls_back():
    """`BlockSparseModel.save` persists the shortlist next to the BSR
    arrays; deleting it (a checkpoint from before this PR) must silently
    fall back to exhaustive scoring with identical results."""
    L, D, k = 140, 300, 5
    _, bsr = _random_pruned_bsr(L, D, seed=15)
    rng = np.random.default_rng(16)
    x = np.asarray(rng.normal(size=(3, D)), np.float32)
    with tempfile.TemporaryDirectory() as d:
        bsr.save(d, meta={"n_labels": L, "n_features": D})
        assert os.path.exists(os.path.join(d, SHORTLIST_FILE))
        index = load_block_sparse_meta(d)
        assert index["shortlist"]["n_row_blocks"] == bsr.shape[0] // 16
        art = load_shortlist(d)
        art.validate_against(load_block_sparse(d)[0])

        eng = XMCEngine.from_checkpoint(d, backend="shortlist", k=k,
                                        warmup=False, shortlist_blocks=2)
        assert isinstance(eng.backend, ShortlistBackend)
        got_sl = eng.serve([x])[0].labels

        os.remove(os.path.join(d, SHORTLIST_FILE))      # legacy checkpoint
        eng_fb = XMCEngine.from_checkpoint(d, backend="shortlist", k=k,
                                           warmup=False)
        assert eng_fb.backend.name == "bsr"
        got_fb = eng_fb.serve([x])[0].labels
        eng_ex = XMCEngine.from_checkpoint(d, backend="bsr", k=k,
                                           warmup=False)
        np.testing.assert_array_equal(got_fb, eng_ex.serve([x])[0].labels)
        assert got_sl.shape == got_fb.shape


# ---------------------------------------------------------------------------
# ServeSpec knob
# ---------------------------------------------------------------------------

def test_serve_spec_shortlist_blocks_roundtrip_and_validation():
    spec = ServeSpec(backend="shortlist", shortlist_blocks=4)
    assert ServeSpec.from_dict(spec.to_dict()) == spec
    # Manifests written before the knob existed deserialize to the default.
    old = spec.to_dict()
    del old["shortlist_blocks"]
    assert ServeSpec.from_dict(old).shortlist_blocks is None
    with pytest.raises(ValueError, match="shortlist_blocks"):
        ServeSpec(shortlist_blocks=0).validate()


# ---------------------------------------------------------------------------
# Shared warm-up compile ledger
# ---------------------------------------------------------------------------

def test_warmup_shared_across_equal_backends():
    """A second engine over an equal-shaped model must not repeat the
    first's warm-up dispatches: every bucket is a shared hit (the jitted
    scoring functions are module-level, so jax's compile cache is keyed on
    shapes/statics, not backend instances)."""
    L, D, k = 140, 256, 3
    _, bsr1 = _random_pruned_bsr(L, D, seed=17)
    _, bsr2 = _random_pruned_bsr(L, D, seed=18)     # same shapes, new values
    reset_warmup_cache()
    try:
        e1 = XMCEngine(make_backend("dense", bsr1, k, n_labels=L),
                       buckets=(2, 4), warmup=False, n_features=D)
        assert e1.warmup() == 2
        assert warmup_cache_stats() == {"dispatches": 2, "shared_hits": 0}
        e2 = XMCEngine(make_backend("dense", bsr2, k, n_labels=L),
                       buckets=(2, 4), warmup=False, n_features=D)
        assert e2.warmup() == 2                     # per-engine count stays
        assert warmup_cache_stats() == {"dispatches": 2, "shared_hits": 2}
        # A different k is a different computation: no false sharing.
        e3 = XMCEngine(make_backend("dense", bsr1, k + 1, n_labels=L),
                       buckets=(2,), warmup=False, n_features=D)
        assert e3.warmup() == 1
        assert warmup_cache_stats()["dispatches"] == 3
    finally:
        reset_warmup_cache()


def test_warmup_shared_across_bsr_and_shortlist_instances():
    """The bsr and shortlist backends share warm-up state per kind too —
    and the two kinds never collide with each other."""
    L, D, k = 100, 256, 3
    _, bsr = _random_pruned_bsr(L, D, seed=19)
    art = build_shortlist(bsr)
    reset_warmup_cache()
    try:
        for expected_hits, make in ((0, lambda: make_backend(
                "bsr", bsr, k, n_labels=L)),
                (0, lambda: make_backend(
                    "shortlist", bsr, k, n_labels=L, shortlist=art,
                    shortlist_blocks=2)),
                (2, lambda: make_backend("bsr", bsr, k, n_labels=L)),
                (4, lambda: make_backend(
                    "shortlist", bsr, k, n_labels=L, shortlist=art,
                    shortlist_blocks=2))):
            eng = XMCEngine(make(), buckets=(1, 2), warmup=False,
                            n_features=D)
            assert eng.warmup() == 2
            assert warmup_cache_stats()["shared_hits"] == expected_hits
    finally:
        reset_warmup_cache()
