"""Shortlist-gated sub-linear serving: gathered-block kernel parity, the
two-stage backend's equivalence/recall/fallback contracts, the persisted
artifact, and the shared warm-up compile ledger."""

import os
import tempfile

import numpy as np
import pytest

import jax.numpy as jnp

from repro.checkpoint.io import (SHORTLIST_FILE, load_block_sparse,
                                 load_block_sparse_meta, load_shortlist,
                                 save_shortlist)
from repro.core.pruning import prune, to_block_sparse
from repro.data.xmc import make_xmc_dataset
from repro.kernels.bsr_predict import ops as bsr_ops
from repro.kernels.bsr_predict import ref as bsr_ref
from repro.serve import (ShortlistBackend, XMCEngine, build_shortlist,
                         make_backend, reset_warmup_cache,
                         warmup_cache_stats)
from repro.serve.shortlist import ShortlistArtifact
from repro.specs import ServeSpec


def _random_pruned_bsr(L, D, *, block=(16, 128), delta=0.05, seed=0,
                       zero_rows=()):
    rng = np.random.default_rng(seed)
    W = rng.normal(size=(L, D)).astype(np.float32) * 0.1
    W = np.array(prune(jnp.asarray(W), delta))
    for r in zero_rows:
        W[r] = 0.0
    return W, to_block_sparse(jnp.asarray(W), block)


# ---------------------------------------------------------------------------
# Gathered-block kernel
# ---------------------------------------------------------------------------

def test_gather_kernel_matches_ref_non_tile_aligned():
    """Pallas gathered-block scoring == dense-gather oracle on shapes that
    hit both row padding (L=100 -> Lp=112 with bl=16) and feature padding
    (D=300 -> Dp=384), with an UNSORTED selection."""
    L, D = 100, 300
    _, bsr = _random_pruned_bsr(L, D, seed=1)
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(4, D)).astype(np.float32))
    sel = jnp.asarray([5, 0, 3], jnp.int32)          # arbitrary order
    got = bsr_ops.bsr_predict_gather(x, bsr, sel)
    want = bsr_ref.bsr_predict_gather(
        jnp.pad(x, ((0, 0), (0, bsr.shape[1] - D))), bsr, sel)
    assert got.shape == (4, 3 * 16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_gather_kernel_empty_row_block_is_exact_zero():
    """A selected row block whose labels were all Delta-pruned must come
    back EXACTLY 0.0 (the dense score of a pruned label), not garbage."""
    L, D = 64, 256
    zero_rows = list(range(16, 32))                  # kills row block 1
    _, bsr = _random_pruned_bsr(L, D, seed=3, zero_rows=zero_rows)
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.normal(size=(3, D)).astype(np.float32))
    out = np.asarray(bsr_ops.bsr_predict_gather(x, bsr, jnp.asarray([1, 2])))
    assert (out[:, :16] == 0.0).all()                # block 1: pruned
    assert (out[:, 16:] != 0.0).any()                # block 2: real scores


def test_gather_topk_full_coverage_is_bit_exact():
    """sel = every row block (sorted) reproduces the exhaustive fused
    predict->topk bit-for-bit, tie order included."""
    L, D, k = 100, 300, 5
    _, bsr = _random_pruned_bsr(L, D, seed=5)
    R = bsr.shape[0] // bsr.block_shape[0]
    rng = np.random.default_rng(6)
    x = jnp.asarray(rng.normal(size=(8, D)).astype(np.float32))
    v1, i1 = bsr_ops.bsr_predict_topk(x, bsr, k, n_labels=L)
    v2, i2 = bsr_ops.bsr_predict_gather_topk(x, bsr, jnp.arange(R), k,
                                             n_labels=L)
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))


# ---------------------------------------------------------------------------
# Shortlist backend
# ---------------------------------------------------------------------------

def test_shortlist_backend_full_width_equals_exhaustive():
    """B covering all row blocks == exhaustive BSR: identical scores AND
    identical label ids (the B-covers-all acceptance gate)."""
    L, D, k = 200, 300, 5
    _, bsr = _random_pruned_bsr(L, D, seed=7)
    art = build_shortlist(bsr)
    R = art.n_row_blocks
    rng = np.random.default_rng(8)
    x = jnp.asarray(rng.normal(size=(6, D)).astype(np.float32))
    sl = make_backend("shortlist", bsr, k, n_labels=L, shortlist=art,
                      shortlist_blocks=R)
    ex = make_backend("bsr", bsr, k, n_labels=L)
    v1, i1 = sl.topk(x)
    v2, i2 = ex.topk(x)
    assert isinstance(sl, ShortlistBackend) and sl.candidate_fraction == 1.0
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))


def test_shortlist_recall_gate_on_clustered_power_law():
    """On a cluster-ordered power-law label space (the regime candidate
    stages serve), a B = 3/16 shortlist recovers >= 95% of the exhaustive
    top-5 for single-query batches — at under 25% of the row blocks."""
    L, D, k = 128, 1024, 5
    data = make_xmc_dataset(n_train=8, n_test=48, n_features=D, n_labels=L,
                            pool_stride=2, label_locality=0.9,
                            multi_label_p=0.9, seed=9)
    # Analytic OvR weights from the generator's signature pools (training
    # would find ~these; the test needs the serving stack, not TRON).
    W = np.zeros((L, D), np.float32)
    for l in range(L):
        W[l, data.label_pools[l]] = 1.0
    bsr = to_block_sparse(jnp.asarray(W), (8, 128))
    art = build_shortlist(bsr)
    assert art.n_row_blocks == 16
    sl = make_backend("shortlist", bsr, k, n_labels=L, shortlist=art,
                      shortlist_blocks=3)
    ex = make_backend("bsr", bsr, k, n_labels=L)
    assert sl.candidate_fraction < 0.25

    hits = total = 0
    for q in np.asarray(data.X_test, np.float32):
        x = jnp.asarray(q[None, :])
        _, want = ex.topk(x)
        _, got = sl.topk(x)
        hits += len(set(np.asarray(want)[0].tolist())
                    & set(np.asarray(got)[0].tolist()))
        total += k
    assert hits / total >= 0.95, f"recall@{k} = {hits / total:.3f}"


def test_shortlist_spec_and_registry_fallback():
    """Without an artifact the "shortlist" kind degrades to exhaustive BSR
    (same results); old-style plugin factories without the shortlist
    kwargs still work through make_backend."""
    L, D, k = 100, 256, 3
    _, bsr = _random_pruned_bsr(L, D, seed=10)
    rng = np.random.default_rng(11)
    x = jnp.asarray(rng.normal(size=(4, D)).astype(np.float32))
    fb = make_backend("shortlist", bsr, k, n_labels=L)       # no artifact
    ex = make_backend("bsr", bsr, k, n_labels=L)
    assert fb.name == "bsr"
    np.testing.assert_array_equal(np.asarray(fb.topk(x)[1]),
                                  np.asarray(ex.topk(x)[1]))

    from repro.serve import register_backend, unregister_backend

    @register_backend("_old_style")
    def _old_factory(bsr_, k_, *, n_labels, mesh, label_axis, interpret):
        return make_backend("dense", bsr_, k_, n_labels=n_labels)
    try:
        be = make_backend("_old_style", bsr, k, n_labels=L,
                          shortlist=build_shortlist(bsr), shortlist_blocks=2)
        assert be.name == "dense"                # kwargs filtered, no crash
    finally:
        unregister_backend("_old_style")


# ---------------------------------------------------------------------------
# Artifact persistence
# ---------------------------------------------------------------------------

def test_artifact_roundtrip_and_validation():
    L, D = 150, 300
    _, bsr = _random_pruned_bsr(L, D, seed=12)
    art = build_shortlist(bsr)
    assert art.centroids.shape == (bsr.shape[0] // 16, bsr.shape[1])
    assert art.validate_against(bsr) is art
    with tempfile.TemporaryDirectory() as d:
        entry = save_shortlist(d, art)
        assert entry["file"] == SHORTLIST_FILE
        back = load_shortlist(d)
    np.testing.assert_array_equal(back.centroids, art.centroids)
    assert (back.block_rows, back.n_labels, back.stat) == (16, L, "mean")
    _, other = _random_pruned_bsr(64, D, block=(32, 128), seed=13)
    with pytest.raises(ValueError, match="does not match"):
        back.validate_against(other)


def test_centroids_are_true_block_means():
    L, D = 96, 256
    W, bsr = _random_pruned_bsr(L, D, seed=14)
    art = build_shortlist(bsr)
    dense = np.asarray(bsr.to_dense())
    for r in range(art.n_row_blocks):
        np.testing.assert_allclose(art.centroids[r],
                                   dense[r * 16:(r + 1) * 16].mean(axis=0),
                                   rtol=1e-6, atol=1e-6)


def test_save_writes_artifact_and_legacy_checkpoint_falls_back():
    """`BlockSparseModel.save` persists the shortlist next to the BSR
    arrays; deleting it (a checkpoint from before this PR) must silently
    fall back to exhaustive scoring with identical results."""
    L, D, k = 140, 300, 5
    _, bsr = _random_pruned_bsr(L, D, seed=15)
    rng = np.random.default_rng(16)
    x = np.asarray(rng.normal(size=(3, D)), np.float32)
    with tempfile.TemporaryDirectory() as d:
        bsr.save(d, meta={"n_labels": L, "n_features": D})
        assert os.path.exists(os.path.join(d, SHORTLIST_FILE))
        index = load_block_sparse_meta(d)
        assert index["shortlist"]["n_row_blocks"] == bsr.shape[0] // 16
        art = load_shortlist(d)
        art.validate_against(load_block_sparse(d)[0])

        eng = XMCEngine.from_checkpoint(d, backend="shortlist", k=k,
                                        warmup=False, shortlist_blocks=2)
        assert isinstance(eng.backend, ShortlistBackend)
        got_sl = eng.serve([x])[0].labels

        os.remove(os.path.join(d, SHORTLIST_FILE))      # legacy checkpoint
        eng_fb = XMCEngine.from_checkpoint(d, backend="shortlist", k=k,
                                           warmup=False)
        assert eng_fb.backend.name == "bsr"
        got_fb = eng_fb.serve([x])[0].labels
        eng_ex = XMCEngine.from_checkpoint(d, backend="bsr", k=k,
                                           warmup=False)
        np.testing.assert_array_equal(got_fb, eng_ex.serve([x])[0].labels)
        assert got_sl.shape == got_fb.shape


# ---------------------------------------------------------------------------
# ServeSpec knob
# ---------------------------------------------------------------------------

def test_serve_spec_shortlist_blocks_roundtrip_and_validation():
    spec = ServeSpec(backend="shortlist", shortlist_blocks=4)
    assert ServeSpec.from_dict(spec.to_dict()) == spec
    # Manifests written before the knob existed deserialize to the default.
    old = spec.to_dict()
    del old["shortlist_blocks"]
    assert ServeSpec.from_dict(old).shortlist_blocks is None
    with pytest.raises(ValueError, match="shortlist_blocks"):
        ServeSpec(shortlist_blocks=0).validate()


# ---------------------------------------------------------------------------
# Shared warm-up compile ledger
# ---------------------------------------------------------------------------

def test_warmup_shared_across_equal_backends():
    """A second engine over an equal-shaped model must not repeat the
    first's warm-up dispatches: every bucket is a shared hit (the jitted
    scoring functions are module-level, so jax's compile cache is keyed on
    shapes/statics, not backend instances)."""
    L, D, k = 140, 256, 3
    _, bsr1 = _random_pruned_bsr(L, D, seed=17)
    _, bsr2 = _random_pruned_bsr(L, D, seed=18)     # same shapes, new values
    reset_warmup_cache()
    try:
        e1 = XMCEngine(make_backend("dense", bsr1, k, n_labels=L),
                       buckets=(2, 4), warmup=False, n_features=D)
        assert e1.warmup() == 2
        assert warmup_cache_stats() == {"dispatches": 2, "shared_hits": 0}
        e2 = XMCEngine(make_backend("dense", bsr2, k, n_labels=L),
                       buckets=(2, 4), warmup=False, n_features=D)
        assert e2.warmup() == 2                     # per-engine count stays
        assert warmup_cache_stats() == {"dispatches": 2, "shared_hits": 2}
        # A different k is a different computation: no false sharing.
        e3 = XMCEngine(make_backend("dense", bsr1, k + 1, n_labels=L),
                       buckets=(2,), warmup=False, n_features=D)
        assert e3.warmup() == 1
        assert warmup_cache_stats()["dispatches"] == 3
    finally:
        reset_warmup_cache()


def test_warmup_shared_across_bsr_and_shortlist_instances():
    """The bsr and shortlist backends share warm-up state per kind too —
    and the two kinds never collide with each other."""
    L, D, k = 100, 256, 3
    _, bsr = _random_pruned_bsr(L, D, seed=19)
    art = build_shortlist(bsr)
    reset_warmup_cache()
    try:
        for expected_hits, make in ((0, lambda: make_backend(
                "bsr", bsr, k, n_labels=L)),
                (0, lambda: make_backend(
                    "shortlist", bsr, k, n_labels=L, shortlist=art,
                    shortlist_blocks=2)),
                (2, lambda: make_backend("bsr", bsr, k, n_labels=L)),
                (4, lambda: make_backend(
                    "shortlist", bsr, k, n_labels=L, shortlist=art,
                    shortlist_blocks=2))):
            eng = XMCEngine(make(), buckets=(1, 2), warmup=False,
                            n_features=D)
            assert eng.warmup() == 2
            assert warmup_cache_stats()["shared_hits"] == expected_hits
    finally:
        reset_warmup_cache()


# ---------------------------------------------------------------------------
# v2 coarse stages: learned / tree artifacts, per-query selection
# ---------------------------------------------------------------------------

def _clustered_model_and_data(seed=11):
    """Small clustered problem + analytic OvR weights (shared by the v2
    coarse-stage tests): pool_stride/label_locality put co-occurring labels
    in adjacent ids, the regime every coarse stage targets."""
    L, D = 96, 768
    data = make_xmc_dataset(n_train=48, n_test=16, n_features=D, n_labels=L,
                            pool_stride=2, label_locality=0.9,
                            multi_label_p=0.9, seed=seed)
    W = np.zeros((L, D), np.float32)
    for l in range(L):
        W[l, data.label_pools[l]] = 1.0
    return data, W, to_block_sparse(jnp.asarray(W), (8, 128))


def test_v2_artifact_roundtrip_learned_and_tree(tmp_path):
    """save_shortlist/load_shortlist preserve the v2 payload exactly for
    both new kinds — including the tree arrays, which v1 never had."""
    from repro.serve.shortlist import (build_learned_shortlist,
                                       build_tree_shortlist)
    data, _, bsr = _clustered_model_and_data()
    X, Y = np.asarray(data.X_train), np.asarray(data.Y_train)
    for art in (build_learned_shortlist(bsr, X, Y, max_newton=3),
                build_tree_shortlist(bsr, X, Y, depth=2)):
        d = str(tmp_path / art.kind)
        os.makedirs(d)
        save_shortlist(d, art)
        back = load_shortlist(d)
        assert (back.kind, back.stat, back.block_rows, back.n_labels) == \
            (art.kind, art.stat, art.block_rows, art.n_labels)
        np.testing.assert_array_equal(back.centroids, art.centroids)
        if art.kind == "tree":
            assert back.tree_depth == art.tree_depth
            np.testing.assert_array_equal(back.tree_nodes, art.tree_nodes)
            np.testing.assert_array_equal(back.tree_leaf_scores,
                                          art.tree_leaf_scores)
        else:
            assert back.tree_depth == 0 and back.tree_nodes is None
        back.validate_against(bsr)                    # loads stay servable


def test_learned_and_tree_full_width_equal_exhaustive():
    """B = R with a learned or tree coarse stage is still exhaustive
    scoring: identical scores AND ids vs the plain BSR backend (the coarse
    stage may only ever RANK blocks, never perturb fine scores)."""
    from repro.serve.shortlist import (build_learned_shortlist,
                                       build_tree_shortlist)
    data, W, bsr = _clustered_model_and_data(seed=12)
    L, k = W.shape[0], 5
    X, Y = np.asarray(data.X_train), np.asarray(data.Y_train)
    R = bsr.shape[0] // bsr.block_shape[0]
    x = jnp.asarray(np.asarray(data.X_test[:4], np.float32))
    ex = make_backend("bsr", bsr, k, n_labels=L)
    v2, i2 = ex.topk(x)
    for art in (build_learned_shortlist(bsr, X, Y, max_newton=3),
                build_tree_shortlist(bsr, X, Y, depth=3)):
        sl = make_backend("shortlist", bsr, k, n_labels=L, shortlist=art,
                          shortlist_blocks=R)
        assert sl.kind == art.kind
        v1, i1 = sl.topk(x)
        np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))
        np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))


def test_per_query_selection_is_per_row_topB():
    """per_query=True: `select_blocks` returns each ROW's own top-B coarse
    blocks (sorted), matching the host-side reference selection — not the
    batch-union the shared path uses."""
    from repro.serve.shortlist import coarse_scores
    _, W, bsr = _clustered_model_and_data(seed=13)
    L, k, B = W.shape[0], 5, 3
    rng = np.random.default_rng(14)
    x = rng.normal(size=(5, W.shape[1])).astype(np.float32)
    art = build_shortlist(bsr)
    sl = make_backend("shortlist", bsr, k, n_labels=L, shortlist=art,
                      shortlist_blocks=B, shortlist_per_query=True)
    assert sl.per_query is True
    sel = sl.select_blocks(jnp.asarray(x))
    want = np.sort(np.argsort(-coarse_scores(art, x), axis=1)[:, :B], axis=1)
    assert sel.shape == (5, B)
    np.testing.assert_array_equal(np.sort(sel, axis=1), want)


def test_per_query_single_row_matches_shared():
    """For n = 1 the per-query selection IS the shared union, so the ragged
    path must be bit-identical to the shared gather on single-row batches
    (the equivalence the serving benchmark's per-query gate leans on)."""
    _, W, bsr = _clustered_model_and_data(seed=15)
    L, k, B = W.shape[0], 5, 4
    art = build_shortlist(bsr)
    shared = make_backend("shortlist", bsr, k, n_labels=L, shortlist=art,
                          shortlist_blocks=B)
    pq = make_backend("shortlist", bsr, k, n_labels=L, shortlist=art,
                      shortlist_blocks=B, shortlist_per_query=True)
    rng = np.random.default_rng(16)
    for _ in range(4):
        x = jnp.asarray(rng.normal(size=(1, W.shape[1])).astype(np.float32))
        vs, ls = shared.topk(x)
        vp, lp = pq.topk(x)
        np.testing.assert_array_equal(np.asarray(vs), np.asarray(vp))
        np.testing.assert_array_equal(np.asarray(ls), np.asarray(lp))


def test_per_query_collapses_to_shared_at_full_width():
    """B = R per-query collapses to the shared executable (the ragged
    kernel never sees a full-width request)."""
    _, W, bsr = _clustered_model_and_data(seed=17)
    L = W.shape[0]
    R = bsr.shape[0] // bsr.block_shape[0]
    art = build_shortlist(bsr)
    pq = make_backend("shortlist", bsr, 5, n_labels=L, shortlist=art,
                      shortlist_blocks=R, shortlist_per_query=True)
    assert pq.per_query is False


def test_validate_rejects_inconsistent_artifacts():
    """validate_against: unknown kinds and torn tree payloads must fail
    loudly at load, not at first query."""
    _, _, bsr = _clustered_model_and_data(seed=18)
    base = build_shortlist(bsr)
    bad_kind = ShortlistArtifact(centroids=base.centroids,
                                 block_rows=base.block_rows,
                                 n_labels=base.n_labels, kind="ann")
    with pytest.raises(ValueError, match="unknown shortlist kind"):
        bad_kind.validate_against(bsr)
    torn_tree = ShortlistArtifact(centroids=base.centroids,
                                  block_rows=base.block_rows,
                                  n_labels=base.n_labels, kind="tree",
                                  tree_nodes=None, tree_leaf_scores=None,
                                  tree_depth=3)
    with pytest.raises(ValueError, match="tree shortlist artifact"):
        torn_tree.validate_against(bsr)


def test_per_query_int8_single_row_matches_shared_int8():
    """The ragged int8 fine stage: single-row batches must be bit-identical
    to the shared gathered-int8 path (same collapse argument as fp32)."""
    _, W, bsr = _clustered_model_and_data(seed=19)
    L, k, B = W.shape[0], 5, 4
    art = build_shortlist(bsr)
    shared = make_backend("shortlist", bsr, k, n_labels=L, shortlist=art,
                          shortlist_blocks=B, int8=True)
    pq = make_backend("shortlist", bsr, k, n_labels=L, shortlist=art,
                      shortlist_blocks=B, int8=True,
                      shortlist_per_query=True)
    assert shared.int8 and pq.int8 and pq.per_query
    rng = np.random.default_rng(20)
    for _ in range(3):
        x = jnp.asarray(rng.normal(size=(1, W.shape[1])).astype(np.float32))
        vs, ls = shared.topk(x)
        vp, lp = pq.topk(x)
        np.testing.assert_array_equal(np.asarray(vs), np.asarray(vp))
        np.testing.assert_array_equal(np.asarray(ls), np.asarray(lp))


def test_tree_backend_selection_matches_host_reference():
    """The jitted tree routing (`_tree_coarse`) agrees with the host-side
    `coarse_scores` reference: the backend's shared B-block selection is
    exactly the reference's top-B of the batch-max leaf scores."""
    from repro.serve.shortlist import build_tree_shortlist, coarse_scores
    data, W, bsr = _clustered_model_and_data(seed=21)
    L, k, B = W.shape[0], 5, 3
    X, Y = np.asarray(data.X_train), np.asarray(data.Y_train)
    art = build_tree_shortlist(bsr, X, Y, depth=3)
    sl = make_backend("shortlist", bsr, k, n_labels=L, shortlist=art,
                      shortlist_blocks=B)
    assert sl.kind == "tree"
    x = np.asarray(data.X_test[:6], np.float32)
    sel = np.sort(np.asarray(sl.select_blocks(jnp.asarray(x))))
    coarse = coarse_scores(art, x)                    # host tree routing
    want = np.sort(np.argsort(-coarse.max(axis=0))[:B])
    np.testing.assert_array_equal(sel, want)


def test_fit_reorder_learned_per_query_end_to_end(tmp_path):
    """The fit-time tentpole path in one session: scrambled-label data +
    `ScheduleSpec(reorder_labels=True)` + `ServeSpec(shortlist_kind=
    "learned", shortlist_per_query=True)` -> the checkpoint persists a
    nontrivial `label_order` and a learned artifact, and the served
    full-width top-k ids are EXACTLY the dense reference of the packed
    model unmapped through that order (ids out are original label ids)."""
    from repro.serve.xmc import DenseBackend
    from repro.specs import ScheduleSpec, ServeSpec
    from repro.xmc_api import XMCSpec, fit

    L, D = 64, 1024
    data = make_xmc_dataset(n_train=160, n_test=24, n_features=D,
                            n_labels=L, pool_stride=2, label_locality=0.9,
                            multi_label_p=0.9, scramble_labels=True, seed=23)
    spec = XMCSpec(
        schedule=ScheduleSpec(label_batch=32, block_shape=(8, 128),
                              reorder_labels=True),
        serve=ServeSpec(backend="shortlist", k=5, shortlist_kind="learned",
                        shortlist_per_query=True, warmup=False))
    out = str(tmp_path / "ck")
    handle = fit(jnp.asarray(data.X_train), jnp.asarray(data.Y_train),
                 spec, out)
    assert handle.result.complete

    order = load_block_sparse_meta(out).get("label_order")
    assert order is not None
    order = np.asarray(order)
    assert sorted(order.tolist()) == list(range(L))        # permutation
    assert not np.array_equal(order, np.arange(L))         # and nontrivial
    assert load_shortlist(out).kind == "learned"           # fit upgraded it

    model, _ = load_block_sparse(out)
    R = model.shape[0] // model.block_shape[0]
    eng = handle.engine(ServeSpec(backend="shortlist", k=5,
                                  shortlist_kind="learned",
                                  shortlist_blocks=R, warmup=False))
    x = np.asarray(data.X_test[:6], np.float32)
    res = eng.serve([x])[0]

    Wp = np.asarray(model.to_dense())[:L, :D]              # packed order
    _, packed_ids = DenseBackend(jnp.asarray(Wp), 5, n_labels=L).topk(
        jnp.asarray(x))
    np.testing.assert_array_equal(np.asarray(res.labels),
                                  order[np.asarray(packed_ids)])
