"""Full-solve parity of the Pallas TRON path vs the jnp path, and the
cached-mask (margin-caching) protocol semantics.

The fused hinge kernel now emits the active mask tile-by-tile and the HVP
kernel consumes it (no separate mask matmul anywhere): a complete
`tron_solve` through the Pallas kernels must land on the same TronResult —
per-label objective, gradient norm, iteration counts, convergence — and the
same Delta-pruned W as the decomposed jnp path, on shapes that are NOT
multiples of the 128/32 kernel tiles (exercising every pad/slice seam of
the ops wrappers)."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import losses
from repro.core.dismec import DiSMECConfig, train_label_batch
from repro.core.pruning import prune
from repro.core.tron import tron_solve

# Deliberately awkward shapes: L, N, D share no factor with the 32/128
# tiles, L < bl, N spans several bn tiles with a ragged tail.
L, N, D = 13, 85, 48
DELTA = 0.01


@pytest.fixture(scope="module")
def problem():
    rng = np.random.default_rng(11)
    X = jnp.asarray(rng.normal(size=(N, D)), jnp.float32)
    S = jnp.asarray(np.sign(rng.normal(size=(L, N))), jnp.float32)
    return X, S


def test_full_solve_parity_non_tile_multiple(problem):
    """use_pallas=True vs the jnp path: matching TronResult fields and
    identical pruned W within fp32 tolerance, on non-tile-multiple
    (L, N, D)."""
    X, S = problem
    cfg_jnp = DiSMECConfig(eps=1e-2)
    cfg_pal = DiSMECConfig(eps=1e-2, use_pallas=True)
    r_jnp = train_label_batch(X, S, cfg_jnp)
    r_pal = train_label_batch(X, S, cfg_pal)

    assert bool(jnp.all(r_jnp.converged)) and bool(jnp.all(r_pal.converged))
    # Same trust-region trajectory: identical per-label iteration counts.
    np.testing.assert_array_equal(np.asarray(r_jnp.n_newton),
                                  np.asarray(r_pal.n_newton))
    np.testing.assert_array_equal(np.asarray(r_jnp.n_cg),
                                  np.asarray(r_pal.n_cg))
    np.testing.assert_allclose(np.asarray(r_jnp.f), np.asarray(r_pal.f),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(r_jnp.gnorm),
                               np.asarray(r_pal.gnorm),
                               rtol=1e-3, atol=1e-4)
    Wj = np.asarray(prune(r_jnp.W, DELTA))
    Wp = np.asarray(prune(r_pal.W, DELTA))
    assert (Wj != 0).sum() == (Wp != 0).sum()          # identical support
    np.testing.assert_allclose(Wj, Wp, rtol=1e-4, atol=1e-5)


def test_pallas_act_output_equals_jnp_mask(problem):
    """The mask streamed out of the fused hinge kernel is exactly the jnp
    active mask at the same iterate — including across pad/slice seams."""
    from repro.kernels.hinge import ops as hinge_ops
    X, S = problem
    rng = np.random.default_rng(3)
    W = jnp.asarray(rng.normal(size=(L, D)) * 0.1, jnp.float32)
    _, _, act_k = hinge_ops.objective_grad_act(W, X, S, 1.0)
    act_j = losses.active_mask(W, X, S)
    assert act_k.shape == (L, N)
    np.testing.assert_array_equal(np.asarray(act_k), np.asarray(act_j))


def test_cached_mask_matches_legacy_recompute(problem):
    """Threading the mask from obj_grad through CG/HVP is bit-identical to
    the pre-refactor behaviour of re-deriving it from W at every use. The
    legacy protocol is emulated through the act_aux payload itself: pass W
    as the payload and let hvp_fn rebuild the mask per call."""
    X, S = problem
    C = 1.0
    W0 = jnp.zeros((L, D), jnp.float32)

    cached = tron_solve(
        lambda W: losses.objective_grad_act(W, X, S, C),
        lambda V, act: losses.hessian_vp(V, X, act, C),
        W0, eps=1e-3)
    legacy = tron_solve(
        lambda W: (*losses.objective_and_grad(W, X, S, C), W),
        lambda V, W: losses.hessian_vp(V, X, losses.active_mask(W, X, S), C),
        W0, eps=1e-3)

    np.testing.assert_array_equal(np.asarray(cached.W),
                                  np.asarray(legacy.W))
    np.testing.assert_array_equal(np.asarray(cached.n_newton),
                                  np.asarray(legacy.n_newton))
    np.testing.assert_array_equal(np.asarray(cached.n_cg),
                                  np.asarray(legacy.n_cg))
    np.testing.assert_array_equal(np.asarray(cached.f), np.asarray(legacy.f))


def test_hvp_wrapper_rejects_mismatched_mask(problem):
    """The (L, N) mask contract is validated loudly, not silently padded."""
    from repro.kernels.hvp import ops as hvp_ops
    X, _ = problem
    V = jnp.zeros((L, D), jnp.float32)
    bad = jnp.zeros((L, N + 1), jnp.float32)
    with pytest.raises(ValueError, match="active mask"):
        hvp_ops.hessian_vp(V, X, bad, 1.0)
