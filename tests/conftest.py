"""Shared fixtures. NOTE: no XLA_FLAGS here — tests see the 1 real CPU device
(the 512-device override belongs to launch/dryrun.py ONLY, per the brief).
Multi-device sharding tests spawn subprocesses (tests/test_sharded.py)."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp


@pytest.fixture(scope="session")
def xmc_small():
    """Separable-ish power-law XMC problem, solved in seconds on CPU."""
    from repro.data.xmc import make_xmc_dataset
    return make_xmc_dataset(n_train=300, n_test=100, n_features=1024,
                            n_labels=64, seed=0)


@pytest.fixture(scope="session")
def xmc_small_jnp(xmc_small):
    d = xmc_small
    return (jnp.asarray(d.X_train), jnp.asarray(d.Y_train),
            jnp.asarray(d.X_test), jnp.asarray(d.Y_test))


@pytest.fixture(scope="session")
def dismec_model(xmc_small_jnp):
    """One trained DiSMEC model shared by accuracy/pruning/prediction tests."""
    from repro.core.dismec import DiSMECConfig, train
    X, Y, _, _ = xmc_small_jnp
    cfg = DiSMECConfig(C=1.0, delta=0.01, label_batch=64)
    return train(X, Y, cfg)


def assert_finite(tree, name="tree"):
    leaves = jax.tree.leaves(tree)
    for i, leaf in enumerate(leaves):
        assert bool(jnp.all(jnp.isfinite(leaf))), f"{name} leaf {i} not finite"
