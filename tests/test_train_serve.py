"""Training loop, optimizer, serving engine, checkpoint integration."""

import tempfile

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.registry import get_config
from repro.data.lm import make_lm_batch_iterator
from repro.models.model import build_model
from repro.train.trainer import make_train_step, train_loop
from repro.optim import adamw_init
from repro.optim.schedules import linear_warmup_cosine


@pytest.fixture(scope="module")
def lm_setup():
    cfg = get_config("qwen1.5-0.5b", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_loss_decreases_over_training(lm_setup):
    cfg, model, params = lm_setup
    it = make_lm_batch_iterator(cfg.vocab, 32, 8, seed=0)
    _, hist = train_loop(model, params, it, steps=30, lr=2e-3, log_every=1)
    first = np.mean([h["loss"] for h in hist[:5]])
    last = np.mean([h["loss"] for h in hist[-5:]])
    assert last < first, (first, last)


def test_grad_accumulation_equivalence(lm_setup):
    """accum=2 over a split batch == accum=1 over the full batch."""
    cfg, model, params = lm_setup
    rng = np.random.default_rng(0)
    B, T = 8, 32
    tokens = jnp.asarray(rng.integers(1, cfg.vocab, (B, T)), jnp.int32)
    batch1 = {"tokens": tokens, "targets": tokens,
              "valid": jnp.ones((B, T), jnp.float32)}
    batch2 = {k: v.reshape(2, B // 2, T) for k, v in batch1.items()}

    lr_fn = linear_warmup_cosine(1e-3, 1, 100)
    opt = adamw_init(params)
    step = jnp.zeros((), jnp.int32)

    s1 = jax.jit(make_train_step(model, lr_fn=lr_fn, accum=1))
    s2 = jax.jit(make_train_step(model, lr_fn=lr_fn, accum=2))
    p1, _, m1 = s1(params, opt, step, batch1)
    p2, _, m2 = s2(params, opt, step, batch2)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-4)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-2, atol=2e-4)


def test_schedule_shapes():
    lr_fn = linear_warmup_cosine(1e-3, 10, 100)
    assert float(lr_fn(jnp.int32(0))) < 1.1e-4          # warming up
    np.testing.assert_allclose(float(lr_fn(jnp.int32(10))), 1e-3, rtol=1e-5)
    assert float(lr_fn(jnp.int32(100))) < 1.2e-4        # decayed


def test_generate_teacher_forcing_consistency(lm_setup):
    """Driving the same tokens through the one-token decode_step (KV cache)
    must reproduce the bulk prefill logits. Note: prefill's returned cache is
    sized exactly to its prompt (ring-buffer policy is the caller's job, see
    serve/engine.generate) — so the apples-to-apples check is decode-only vs
    full prefill."""
    cfg, model, params = lm_setup
    rng = np.random.default_rng(1)
    B, T = 2, 12
    toks = jnp.asarray(rng.integers(1, cfg.vocab, (B, T + 1)), jnp.int32)

    # Full prefill on T+1 tokens -> top-5 at last position.
    v_full, i_full, _ = model.prefill(params, {"tokens": toks})
    # Same tokens, one decode_step at a time against a (T+1)-slot cache.
    cache = model.init_cache(B, T + 1)
    for t in range(T + 1):
        v_step, i_step, cache = model.decode_step(
            params, cache, toks[:, t:t + 1], jnp.int32(t))
    # bf16 KV-cache rounding allows ~1% drift; top-1 must be identical.
    np.testing.assert_allclose(np.asarray(v_full), np.asarray(v_step),
                               rtol=2e-2, atol=2e-2)
    assert (np.asarray(i_full[:, 0]) == np.asarray(i_step[:, 0])).all()


def test_serve_engine_generate(lm_setup):
    from repro.serve.engine import generate
    cfg, model, params = lm_setup
    toks = jnp.ones((2, 8), jnp.int32)
    out = generate(model, params, toks, steps=5)
    out = np.asarray(out)
    assert out.shape == (2, 5)
    assert (out >= 0).all() and (out < cfg.padded_vocab()).all()


def test_checkpoint_roundtrip_with_sparse():
    from repro.checkpoint.io import restore_pytree, save_pytree
    rng = np.random.default_rng(0)
    dense = jnp.asarray(rng.normal(size=(32, 16)), jnp.float32)
    sparse = jnp.asarray(np.where(rng.random((64, 64)) < 0.05,
                                  rng.normal(size=(64, 64)), 0.0), jnp.float32)
    tree = {"dense": dense, "nested": {"sparse": sparse},
            "step": jnp.int32(7)}
    with tempfile.TemporaryDirectory() as d:
        save_pytree(tree, d)
        out = restore_pytree(tree, d)
    np.testing.assert_array_equal(np.asarray(out["dense"]), np.asarray(dense))
    np.testing.assert_array_equal(np.asarray(out["nested"]["sparse"]),
                                  np.asarray(sparse))
    assert int(out["step"]) == 7


def test_checkpoint_dismec_model(dismec_model):
    """The paper's pruned model survives a save/restore cycle exactly."""
    from repro.checkpoint.io import restore_pytree, save_pytree
    with tempfile.TemporaryDirectory() as d:
        save_pytree({"W": dismec_model.W}, d)
        out = restore_pytree({"W": dismec_model.W}, d)
    np.testing.assert_array_equal(np.asarray(out["W"]),
                                  np.asarray(dismec_model.W))
