"""Fallback for `hypothesis` when it is unavailable (offline CI image).

Exports `given`, `settings`, `st`, `hnp`. With hypothesis installed these
are the real thing; without it, `given` degrades to running the test body
on a handful of deterministic pseudo-random examples drawn from lightweight
strategy stand-ins. Property tests keep running either way and the suite
never dies at collection.
"""

from __future__ import annotations

import functools
import inspect

import numpy as np

try:
    from hypothesis import given, settings, strategies as st
    import hypothesis.extra.numpy as hnp
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    _FALLBACK_EXAMPLES = 5

    class _Strategy:
        """A sampler: example(rng) -> one concrete value."""

        def __init__(self, sample):
            self._sample = sample

        def example(self, rng: np.random.Generator):
            return self._sample(rng)

    class _FloatsStrategy(_Strategy):
        """Keeps (lo, hi) so array strategies can vectorize element draws."""

        def __init__(self, lo, hi):
            self.lo, self.hi = float(lo), float(hi)
            super().__init__(lambda r: float(lo + (hi - lo) * r.random()))

    class _St:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda r: int(r.integers(min_value, max_value + 1)))

        @staticmethod
        def floats(min_value, max_value, width=64, **_kw):
            return _FloatsStrategy(min_value, max_value)

        @staticmethod
        def sampled_from(elements):
            seq = list(elements)
            return _Strategy(lambda r: seq[int(r.integers(len(seq)))])

        @staticmethod
        def tuples(*strategies):
            return _Strategy(
                lambda r: tuple(s.example(r) for s in strategies))

        @staticmethod
        def booleans():
            return _Strategy(lambda r: bool(r.integers(2)))

    st = _St()

    class _Hnp:
        @staticmethod
        def array_shapes(min_dims=1, max_dims=3, min_side=1, max_side=10):
            def sample(r):
                nd = int(r.integers(min_dims, max_dims + 1))
                return tuple(int(r.integers(min_side, max_side + 1))
                             for _ in range(nd))
            return _Strategy(sample)

        @staticmethod
        def arrays(dtype, shape, elements=None):
            def sample(r):
                shp = shape.example(r) if isinstance(shape, _Strategy) \
                    else tuple(shape)
                if isinstance(elements, _FloatsStrategy):
                    a = r.uniform(elements.lo, elements.hi, size=shp)
                elif elements is None:
                    a = r.standard_normal(size=shp)
                else:
                    flat = [elements.example(r)
                            for _ in range(int(np.prod(shp)))]
                    a = np.asarray(flat).reshape(shp)
                return a.astype(dtype)
            return _Strategy(sample)

    hnp = _Hnp()

    def given(**strategies):
        """Run the test on a few fixed-seed examples instead of searching."""
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                for seed in range(_FALLBACK_EXAMPLES):
                    rng = np.random.default_rng(seed)
                    example = {name: s.example(rng)
                               for name, s in strategies.items()}
                    fn(*args, **example, **kwargs)
            # Hide the strategy-filled parameters from pytest's fixture
            # resolution (functools.wraps copies the full signature).
            sig = inspect.signature(fn)
            kept = [p for name, p in sig.parameters.items()
                    if name not in strategies]
            wrapper.__signature__ = sig.replace(parameters=kept)
            return wrapper
        return deco

    def settings(**_kwargs):
        return lambda fn: fn


__all__ = ["given", "settings", "st", "hnp", "HAVE_HYPOTHESIS"]
