"""Ranking metrics (paper §3.2) and top-k prediction — exact values +
hypothesis properties."""

import numpy as np
import pytest
from _hyp_compat import given, settings, st

import jax.numpy as jnp

from repro.core.prediction import (evaluate, ndcg_at_k, precision_at_k,
                                   predict_scores, predict_topk)


def test_precision_exact():
    # 2 instances, 4 labels. Predictions rank label ids [0,1,2].
    Y = jnp.asarray([[1, 0, 1, 0],
                     [0, 1, 0, 0]], jnp.float32)
    topk = jnp.asarray([[0, 1, 2],
                        [0, 1, 2]])
    # instance 0: hits at rank 1 and 3 -> P@1=1, P@3=2/3
    # instance 1: hit at rank 2       -> P@1=0, P@3=1/3
    assert float(precision_at_k(Y, topk, 1)) == pytest.approx(0.5)
    assert float(precision_at_k(Y, topk, 3)) == pytest.approx(0.5)


def test_ndcg_exact():
    """Paper's point about nDCG: rank-1 hit scores higher than rank-k hit."""
    Y = jnp.asarray([[1, 0, 0, 0]], jnp.float32)
    hit_first = jnp.asarray([[0, 1, 2]])
    hit_last = jnp.asarray([[1, 2, 0]])
    n_first = float(ndcg_at_k(Y, hit_first, 3))
    n_last = float(ndcg_at_k(Y, hit_last, 3))
    assert n_first == pytest.approx(1.0)       # only positive, found at rank 1
    assert 0.0 < n_last < n_first              # found at rank 3: discounted
    assert n_last == pytest.approx(1.0 / np.log2(4.0), rel=1e-5)


def test_p_at_k_rank_insensitive():
    """P@5 is the same wherever inside the top-5 the hit sits (paper §3.2)."""
    Y = jnp.asarray([[1, 0, 0, 0, 0, 0]], jnp.float32)
    for pos in range(5):
        order = [5 - i for i in range(5)]      # ids 5,4,3,2,1 (no hit)
        order[pos] = 0                         # put the hit at `pos`
        p = float(precision_at_k(Y, jnp.asarray([order]), 5))
        assert p == pytest.approx(0.2)


@given(n=st.integers(1, 16), L=st.integers(6, 40), seed=st.integers(0, 99))
@settings(max_examples=30, deadline=None)
def test_metric_ranges_and_consistency(n, L, seed):
    import jax

    rng = np.random.default_rng(seed)
    Y = jnp.asarray((rng.random((n, L)) < 0.2).astype(np.float32))
    scores = jnp.asarray(rng.normal(size=(n, L)).astype(np.float32))
    _, idx = jax.lax.top_k(scores, 5)
    ev = evaluate(Y, idx)
    for k in (1, 3, 5):
        assert 0.0 <= ev[f"P@{k}"] <= 1.0
        assert 0.0 <= ev[f"nDCG@{k}"] <= 1.0 + 1e-6
    assert ev["nDCG@1"] == pytest.approx(ev["P@1"], abs=1e-5)


def test_predict_topk_matches_argmax(dismec_model, xmc_small_jnp):
    _, _, Xte, _ = xmc_small_jnp
    scores = predict_scores(Xte, dismec_model.W)
    _, idx = predict_topk(Xte, dismec_model.W, 1)
    np.testing.assert_array_equal(np.asarray(idx[:, 0]),
                                  np.asarray(jnp.argmax(scores, axis=1)))
