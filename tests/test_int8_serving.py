"""Int8 block-quantized serving: quantizer round-trip bounds, int8 kernel
parity (exhaustive + gathered) against the dequantize oracle, fp32/int8
top-k agreement across block densities, checkpoint persistence vs lazy
quantization (single-shard + stream), the ServeSpec knob, the D > Dp
guard on all four predict wrappers, and warm-up ledger isolation."""

import os

import numpy as np
import pytest

import jax.numpy as jnp

from repro.checkpoint.io import (BSR_ARRAYS, BlockSparseWriter,
                                 load_block_sparse, load_block_sparse_int8,
                                 save_block_sparse)
from repro.core.pruning import (INT8_QMAX, Int8BlockSparseModel,
                                dequantize_blocks, quantize_block_sparse,
                                quantize_blocks, to_block_sparse)
from repro.kernels.bsr_predict import ops as bsr_ops
from repro.kernels.bsr_predict import ref as bsr_ref
from repro.serve import (XMCEngine, build_shortlist, make_backend,
                         reset_warmup_cache, warmup_cache_stats)
from repro.specs import ServeSpec


def _block_sparse_W(L, D, density, seed, block=(16, 128),
                    guarantee_blocks=False):
    """Dense W whose zero pattern is aligned to the BSR block grid, with
    `density` the fraction of surviving blocks. `guarantee_blocks` pins
    two blocks on so low densities never zero the whole matrix."""
    bl, bd = block
    rng = np.random.default_rng(seed)
    W = rng.normal(size=(L, D)).astype(np.float32)
    keep = rng.random((L // bl + (L % bl > 0),
                       D // bd + (D % bd > 0))) < density
    if guarantee_blocks:
        keep[0, 0] = keep[-1, -1] = True
    mask = np.kron(keep, np.ones((bl, bd)))
    return W * mask[:L, :D]


# ---------------------------------------------------------------------------
# Quantizer: round-trip bound, zero-block convention, int8 range
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("density", [0.1, 0.5, 1.0])
def test_quantize_roundtrip_error_bound(density):
    """Symmetric per-block int8: |deq - orig| <= scale/2 elementwise (the
    rounding bound), all-zero blocks come back EXACTLY zero (scale 0, so a
    Delta-pruned label still scores a bit-exact 0.0), and no block value
    ever hits -128 (negation must round-trip)."""
    W = _block_sparse_W(96, 256, density, seed=int(density * 10))
    model = to_block_sparse(jnp.asarray(W), (16, 128))
    blocks = np.asarray(model.blocks)
    q, scales = quantize_blocks(blocks)
    assert q.dtype == np.int8 and scales.dtype == np.float32
    assert int(q.min()) >= -INT8_QMAX
    deq = dequantize_blocks(q, scales)
    bound = scales[:, None, None] / 2 + 1e-7
    assert np.all(np.abs(deq - blocks) <= bound)
    zero = np.all(blocks == 0.0, axis=(1, 2))
    if zero.any():
        assert np.all(scales[zero] == 0.0)
        assert np.all(deq[zero] == 0.0)


def test_model_quantize_method_matches_function():
    W = _block_sparse_W(64, 256, 0.5, seed=3)
    model = to_block_sparse(jnp.asarray(W), (16, 128))
    a = model.quantize()
    b = quantize_block_sparse(model)
    assert isinstance(a, Int8BlockSparseModel)
    assert np.array_equal(np.asarray(a.blocks), np.asarray(b.blocks))
    assert np.array_equal(np.asarray(a.scales), np.asarray(b.scales))
    assert a.payload_bytes() == b.payload_bytes()
    # int8 payload: 1 byte/value + one fp32 scale per block, vs 4 bytes/value.
    fp32 = 4 * int(np.prod(np.asarray(model.blocks).shape))
    assert a.payload_bytes() / fp32 < 0.55


# ---------------------------------------------------------------------------
# Int8 kernels vs oracle; full-coverage gather is bit-exact
# ---------------------------------------------------------------------------

def test_int8_kernel_matches_oracle():
    """Pallas int8 exhaustive scoring == dequantize-then-fp32 oracle, on a
    non-tile-aligned shape (row + feature padding both engaged)."""
    L, D = 100, 300
    W = _block_sparse_W(L, D, 0.6, seed=11)
    q = to_block_sparse(jnp.asarray(W), (16, 128)).quantize()
    x = jnp.asarray(np.random.default_rng(1).normal(size=(4, D)), jnp.float32)
    got = bsr_ops.bsr_predict_int8(x, q)
    want = bsr_ref.bsr_predict_int8(
        jnp.pad(x, ((0, 0), (0, q.shape[1] - D))), q)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_int8_gather_kernel_matches_oracle_unsorted_sel():
    L, D = 100, 300
    W = _block_sparse_W(L, D, 0.6, seed=12)
    q = to_block_sparse(jnp.asarray(W), (16, 128)).quantize()
    x = jnp.asarray(np.random.default_rng(2).normal(size=(3, D)), jnp.float32)
    sel = jnp.asarray([5, 0, 3], jnp.int32)
    got = bsr_ops.bsr_predict_gather_int8(x, q, sel)
    want = bsr_ref.bsr_predict_gather_int8(
        jnp.pad(x, ((0, 0), (0, q.shape[1] - D))), q, sel)
    assert got.shape == (3, 3 * 16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_full_coverage_gather_int8_bitwise_exhaustive():
    """sel = every row block: the gathered-int8 kernel must reproduce the
    exhaustive int8 kernel BIT-FOR-BIT — both dequantize against the same
    per-block scale in the same fp32 accumulation order, so composing the
    shortlist gate with int8 adds no numerics of its own."""
    L, D = 128, 256
    W = _block_sparse_W(L, D, 0.5, seed=13)
    q = to_block_sparse(jnp.asarray(W), (16, 128)).quantize()
    n_row_blocks = q.shape[0] // q.block_shape[0]
    x = jnp.asarray(np.random.default_rng(3).normal(size=(4, D)), jnp.float32)
    sel = jnp.arange(n_row_blocks, dtype=jnp.int32)
    full = bsr_ops.bsr_predict_gather_int8(x, q, sel)
    exhaustive = bsr_ops.bsr_predict_int8(x, q)
    assert np.array_equal(np.asarray(full), np.asarray(exhaustive))
    sc_g, lb_g = bsr_ops.bsr_predict_gather_int8_topk(x, q, sel, 5,
                                                      n_labels=L)
    sc_e, lb_e = bsr_ops.bsr_predict_int8_topk(x, q, 5, n_labels=L)
    assert np.array_equal(np.asarray(sc_g), np.asarray(sc_e))
    assert np.array_equal(np.asarray(lb_g), np.asarray(lb_e))


@pytest.mark.parametrize("density", [0.1, 0.5, 1.0])
def test_int8_topk_agreement_across_densities(density):
    """Serving-level acceptance: int8 top-5 label sets agree with fp32 on
    >= 99% of slots, at every block density — including through the
    shortlist backend at full coverage. Requests plant 5 labels per
    instance with unit score gaps (the decisive-margin regime real ranked
    retrieval lives in — fully random scores put rank-5 boundaries inside
    the quantization noise floor, which no 8-bit scheme can rank)."""
    L, D, k = 128, 256, 5
    seed = int(density * 100) + 7
    W = _block_sparse_W(L, D, density, seed=seed, guarantee_blocks=True)
    bsr = to_block_sparse(jnp.asarray(W), (16, 128))
    rng = np.random.default_rng(seed + 1)
    norms = np.linalg.norm(W, axis=1)
    live = np.flatnonzero(norms > 0)          # fully-pruned labels score 0
    coefs = np.arange(10, 10 - k, -1, dtype=np.float32)
    x = jnp.asarray(np.stack([
        (coefs[:, None] * W[labs] / (norms[labs, None] ** 2)).sum(0)
        for labs in (rng.choice(live, size=k, replace=False)
                     for _ in range(16))]), jnp.float32)
    _, lb_f = make_backend("bsr", bsr, k, n_labels=L).topk(x)
    _, lb_q = make_backend("int8", bsr, k, n_labels=L).topk(x)
    agree = np.mean([
        len(set(map(int, a)) & set(map(int, b))) / k
        for a, b in zip(np.asarray(lb_f), np.asarray(lb_q))])
    assert agree >= 0.99
    # Shortlist-composed int8 at B = n_row_blocks: bit-equal to Int8Backend.
    art = build_shortlist(bsr)
    n_row_blocks = bsr.shape[0] // bsr.block_shape[0]
    sl = make_backend("shortlist", bsr, k, n_labels=L, shortlist=art,
                      shortlist_blocks=n_row_blocks, int8=True)
    assert sl.int8
    sc_sl, lb_sl = sl.topk(x)
    sc_q, lb_q2 = make_backend("int8", bsr, k, n_labels=L).topk(x)
    assert np.array_equal(np.asarray(lb_sl), np.asarray(lb_q2))
    assert np.array_equal(np.asarray(sc_sl), np.asarray(sc_q))


# ---------------------------------------------------------------------------
# D > Dp guard: every wrapper, loud and early
# ---------------------------------------------------------------------------

def test_oversized_request_raises_on_all_wrappers():
    """A request wider than the model's padded feature dim must fail with
    a ValueError naming both dims — on the fp32 AND int8, exhaustive AND
    gathered wrappers — not shape-err deep inside the kernel."""
    L, D = 64, 256
    W = _block_sparse_W(L, D, 0.5, seed=21)
    bsr = to_block_sparse(jnp.asarray(W), (16, 128))
    q = bsr.quantize()
    Dp = bsr.shape[1]
    x_wide = jnp.ones((2, Dp + 64), jnp.float32)
    sel = jnp.asarray([0], jnp.int32)
    pattern = rf"feature dim {Dp + 64}.*{Dp}"
    with pytest.raises(ValueError, match=pattern):
        bsr_ops.bsr_predict(x_wide, bsr)
    with pytest.raises(ValueError, match=pattern):
        bsr_ops.bsr_predict_int8(x_wide, q)
    with pytest.raises(ValueError, match=pattern):
        bsr_ops.bsr_predict_gather(x_wide, bsr, sel)
    with pytest.raises(ValueError, match=pattern):
        bsr_ops.bsr_predict_gather_int8(x_wide, q, sel)


# ---------------------------------------------------------------------------
# Checkpoint persistence: single-shard, stream, and legacy fallback
# ---------------------------------------------------------------------------

def test_single_shard_persists_int8_bit_identical_to_lazy(tmp_path):
    W = _block_sparse_W(64, 256, 0.5, seed=31)
    model = to_block_sparse(jnp.asarray(W), (16, 128))
    ckpt = str(tmp_path / "ck")
    save_block_sparse(model, ckpt)
    data = np.load(os.path.join(ckpt, BSR_ARRAYS))
    assert "blocks_int8" in data.files and "block_scales" in data.files
    loaded, _ = load_block_sparse_int8(ckpt)
    lazy = quantize_block_sparse(model)
    assert np.array_equal(np.asarray(loaded.blocks), np.asarray(lazy.blocks))
    assert np.array_equal(np.asarray(loaded.scales), np.asarray(lazy.scales))


def test_legacy_single_shard_falls_back_to_lazy_quantize(tmp_path):
    """A pre-int8 checkpoint (no blocks_int8 in the npz) still serves
    int8: the loader quantizes the fp32 blocks lazily, bit-identical to
    what a re-save would persist."""
    W = _block_sparse_W(64, 256, 0.5, seed=32)
    model = to_block_sparse(jnp.asarray(W), (16, 128))
    ckpt = str(tmp_path / "ck")
    save_block_sparse(model, ckpt)
    path = os.path.join(ckpt, BSR_ARRAYS)
    data = np.load(path)
    legacy = {k: data[k] for k in data.files
              if k not in ("blocks_int8", "block_scales")}
    np.savez(path, **legacy)
    loaded, _ = load_block_sparse_int8(ckpt)
    lazy = quantize_block_sparse(model)
    assert np.array_equal(np.asarray(loaded.blocks), np.asarray(lazy.blocks))
    assert np.array_equal(np.asarray(loaded.scales), np.asarray(lazy.scales))
    # And the engine serves it end-to-end, agreeing with in-memory int8.
    eng = XMCEngine.from_checkpoint(ckpt, backend="int8", k=5, warmup=False)
    x = jnp.asarray(np.random.default_rng(6).normal(size=(4, 256)),
                    jnp.float32)
    _, lb = eng.backend.topk(x)
    _, lb_mem = make_backend("int8", model, 5, n_labels=64).topk(x)
    assert np.array_equal(np.asarray(lb), np.asarray(lb_mem))


def _write_stream_checkpoint(directory, W, *, block=(16, 128),
                             label_batch=32):
    bl, _ = block
    L, D = W.shape
    n_batches = L // label_batch
    w = BlockSparseWriter(directory, n_labels=L, n_features=D,
                          block_shape=block, label_batch=label_batch,
                          n_batches=n_batches)
    for b in range(n_batches):
        part = to_block_sparse(
            jnp.asarray(W[b * label_batch:(b + 1) * label_batch]), block,
            row_block_offset=b * label_batch // bl, device=False)
        w.write_batch(b, part, row_start=b * label_batch,
                      n_rows=label_batch)
    assert w.try_finalize() is not None


def test_stream_persists_int8_and_legacy_shards_fall_back(tmp_path):
    """Streamed multi-shard layout: per-shard blocks_int8 arrays stitch to
    the same bytes lazy quantization of the stitched fp32 model produces;
    stripping the int8 arrays from ANY shard flips the loader to the lazy
    path with identical results."""
    W = _block_sparse_W(64, 256, 0.5, seed=33)
    ckpt = str(tmp_path / "stream")
    _write_stream_checkpoint(ckpt, W)
    model, _ = load_block_sparse(ckpt)
    lazy = quantize_block_sparse(model)
    loaded, _ = load_block_sparse_int8(ckpt, model=model)
    assert np.array_equal(np.asarray(loaded.blocks), np.asarray(lazy.blocks))
    assert np.array_equal(np.asarray(loaded.scales), np.asarray(lazy.scales))
    # Legacy stream: rewrite one shard without the int8 arrays.
    shard = sorted(p for p in os.listdir(ckpt) if p.startswith("shard-"))[0]
    path = os.path.join(ckpt, shard)
    data = np.load(path)
    np.savez(path, **{k: data[k] for k in data.files
                      if k not in ("blocks_int8", "block_scales")})
    fell_back, _ = load_block_sparse_int8(ckpt, model=model)
    assert np.array_equal(np.asarray(fell_back.blocks),
                          np.asarray(lazy.blocks))
    assert np.array_equal(np.asarray(fell_back.scales),
                          np.asarray(lazy.scales))


# ---------------------------------------------------------------------------
# ServeSpec knob
# ---------------------------------------------------------------------------

def test_serve_spec_int8_roundtrip_and_legacy_default():
    spec = ServeSpec(backend="shortlist", int8=True)
    spec.validate()
    assert ServeSpec.from_dict(spec.to_dict()) == spec
    old = spec.to_dict()
    del old["int8"]          # manifest written before the int8 PR
    assert ServeSpec.from_dict(old).int8 is False


# ---------------------------------------------------------------------------
# Warm-up ledger: int8 never aliases fp32
# ---------------------------------------------------------------------------

def test_warmup_int8_does_not_alias_fp32():
    """An int8 backend over the SAME geometry as a fp32 bsr backend is a
    different executable: its warm-up must dispatch, not ride the fp32
    bucket's ledger entry — while two equal int8 backends do share."""
    L, D, k = 128, 256, 3
    W = _block_sparse_W(L, D, 0.5, seed=41)
    bsr = to_block_sparse(jnp.asarray(W), (16, 128))
    reset_warmup_cache()
    try:
        e_f = XMCEngine(make_backend("bsr", bsr, k, n_labels=L),
                        buckets=(1, 2), warmup=False, n_features=D)
        assert e_f.warmup() == 2
        assert warmup_cache_stats() == {"dispatches": 2, "shared_hits": 0}
        e_q = XMCEngine(make_backend("int8", bsr, k, n_labels=L),
                        buckets=(1, 2), warmup=False, n_features=D)
        assert e_q.warmup() == 2
        assert warmup_cache_stats() == {"dispatches": 4, "shared_hits": 0}
        e_q2 = XMCEngine(make_backend("int8", bsr, k, n_labels=L),
                         buckets=(1, 2), warmup=False, n_features=D)
        assert e_q2.warmup() == 2
        assert warmup_cache_stats() == {"dispatches": 4, "shared_hits": 2}
        # Shortlist with and without int8 are distinct computations too.
        art = build_shortlist(bsr)
        e_sf = XMCEngine(make_backend("shortlist", bsr, k, n_labels=L,
                                      shortlist=art, shortlist_blocks=2),
                         buckets=(1,), warmup=False, n_features=D)
        assert e_sf.warmup() == 1
        d_after_sl = warmup_cache_stats()["dispatches"]
        e_sq = XMCEngine(make_backend("shortlist", bsr, k, n_labels=L,
                                      shortlist=art, shortlist_blocks=2,
                                      int8=True),
                         buckets=(1,), warmup=False, n_features=D)
        assert e_sq.warmup() == 1
        assert warmup_cache_stats()["dispatches"] == d_after_sl + 1
    finally:
        reset_warmup_cache()
