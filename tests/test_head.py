"""DiSMECHead: OvR squared-hinge extreme output layer (core/head.py)."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.head import (init_head, ovr_multihot_loss,
                             ovr_squared_hinge_loss, softmax_xent_loss)


@pytest.fixture(scope="module")
def problem():
    rng = np.random.default_rng(0)
    V, d, T = 48, 24, 32
    W = jnp.asarray(rng.normal(size=(V, d)) * 0.1, jnp.float32)
    feats = jnp.asarray(rng.normal(size=(T, d)), jnp.float32)
    tgt = jnp.asarray(rng.integers(0, V, (T,)), jnp.int32)
    return W, feats, tgt


def test_ovr_loss_equals_signmatrix_form(problem):
    """The collective-free factored form must equal the naive (T, V)
    sign-matrix evaluation of Eq. 2.2."""
    W, feats, tgt = problem
    V = W.shape[0]
    loss = ovr_squared_hinge_loss(W, feats, tgt, C=1.0, reg=0.0)

    z = np.asarray(feats) @ np.asarray(W).T               # (T, V)
    S = -np.ones_like(z)
    S[np.arange(len(tgt)), np.asarray(tgt)] = 1.0
    h = np.maximum(1.0 - S * z, 0.0)
    ref = (h ** 2).sum() / len(tgt)
    np.testing.assert_allclose(float(loss), ref, rtol=1e-5)


def test_ovr_multihot_reduces_to_onehot(problem):
    W, feats, tgt = problem
    V = W.shape[0]
    Y = jax.nn.one_hot(tgt, V)
    l_mh = ovr_multihot_loss(W, feats, Y, C=1.0, reg=0.0)
    l_oh = ovr_squared_hinge_loss(W, feats, tgt, C=1.0, reg=0.0)
    np.testing.assert_allclose(float(l_mh), float(l_oh), rtol=1e-5)


def test_valid_mask_excludes_padding(problem):
    W, feats, tgt = problem
    valid = jnp.ones_like(tgt, jnp.float32).at[-8:].set(0.0)
    l_masked = ovr_squared_hinge_loss(W, feats, tgt, valid=valid, reg=0.0)
    l_short = ovr_squared_hinge_loss(W, feats[:-8], tgt[:-8], reg=0.0)
    np.testing.assert_allclose(float(l_masked), float(l_short), rtol=1e-5)


def test_gradient_step_improves(problem):
    """A gradient step on the OvR loss must decrease the loss and raise the
    average target-vs-rest margin (individual logits may move either way via
    shared feature directions)."""
    W, feats, tgt = problem
    loss_fn = lambda w: ovr_squared_hinge_loss(w, feats, tgt)
    g = jax.grad(loss_fn)(W)
    W2 = W - 0.05 * g
    assert float(loss_fn(W2)) < float(loss_fn(W))
    t = np.asarray(tgt)
    rows = np.arange(len(t))

    def margin(w):
        z = np.asarray(feats @ w.T)
        pos = z[rows, t]
        return (pos - (z.sum(axis=1) - pos) / (w.shape[0] - 1)).mean()

    assert margin(W2) > margin(W)


def test_softmax_baseline_sane(problem):
    W, feats, tgt = problem
    l = softmax_xent_loss(W, feats, tgt)
    assert float(l) > 0.0
    # Near-uniform logits -> loss ~ log V.
    l0 = softmax_xent_loss(jnp.zeros_like(W), feats, tgt)
    np.testing.assert_allclose(float(l0), np.log(W.shape[0]), rtol=1e-5)


def test_init_head_scale():
    W = init_head(jax.random.PRNGKey(0), 512, 64)
    assert W.shape == (512, 64)
    assert 0.5 / 8 < float(jnp.std(W)) < 2.0 / 8   # ~ d^-0.5 = 1/8
