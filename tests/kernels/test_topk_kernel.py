"""Blocked top-k kernel vs ref oracle and jax.lax.top_k."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels.topk import ops, ref


@pytest.mark.parametrize("n,L", [(2, 128), (8, 1024), (3, 1000), (16, 4096)])
@pytest.mark.parametrize("k", [1, 3, 5])
def test_topk_matches_lax(n, L, k):
    rng = np.random.default_rng(n * L + k)
    scores = jnp.asarray(rng.normal(size=(n, L)), jnp.float32)
    v_k, i_k = ops.topk(scores, k, bL=256)
    v_l, i_l = jax.lax.top_k(scores, k)
    np.testing.assert_allclose(np.asarray(v_k), np.asarray(v_l), rtol=1e-6)
    # Values determine indices except under exact ties (measure-zero here).
    np.testing.assert_array_equal(np.asarray(i_k), np.asarray(i_l))


def test_topk_matches_ref_oracle():
    rng = np.random.default_rng(0)
    scores = jnp.asarray(rng.normal(size=(4, 2048)), jnp.float32)
    v_k, i_k = ops.topk(scores, 5)
    v_r, i_r = ref.topk(scores, 5)
    np.testing.assert_allclose(np.asarray(v_k), np.asarray(v_r), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(i_k), np.asarray(i_r))


def test_topk_with_negative_scores():
    """All-negative rows must still return the true top-k (pad value is
    -3e38, not 0)."""
    scores = -jnp.abs(jnp.asarray(
        np.random.default_rng(1).normal(size=(2, 300)), jnp.float32)) - 1.0
    v_k, i_k = ops.topk(scores, 3, bL=128)
    v_l, i_l = jax.lax.top_k(scores, 3)
    np.testing.assert_allclose(np.asarray(v_k), np.asarray(v_l), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(i_k), np.asarray(i_l))
