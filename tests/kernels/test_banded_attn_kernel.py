"""Pallas banded-attention kernel vs ref oracle vs the XLA-level
layers.banded_attention, swept over GQA shapes/windows/dtypes."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.kernels.banded_attn import ops, ref
from repro.models import layers

CASES = [  # (B, T, H, KV, hd, window)
    (1, 256, 4, 2, 32, 64),
    (2, 512, 4, 4, 64, 128),
    (1, 1024, 8, 2, 64, 256),
    (2, 384, 6, 2, 32, 100),      # window not a multiple of anything
]


def _qkv(B, T, H, KV, hd, dtype, seed=0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(B, T, H, hd))).astype(dtype)
    k = jnp.asarray(rng.normal(size=(B, T, KV, hd))).astype(dtype)
    v = jnp.asarray(rng.normal(size=(B, T, KV, hd))).astype(dtype)
    return q, k, v


@pytest.mark.parametrize("B,T,H,KV,hd,window", CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kernel_matches_oracle(B, T, H, KV, hd, window, dtype):
    q, k, v = _qkv(B, T, H, KV, hd, dtype, seed=T + window)
    out_k = ops.banded_attention(q, k, v, window=window, qc=128)

    G = H // KV
    q4 = q.reshape(B, T, KV, G, hd).transpose(0, 2, 3, 1, 4) \
          .reshape(B * KV, G, T, hd)
    k3 = k.transpose(0, 2, 1, 3).reshape(B * KV, T, hd)
    v3 = v.transpose(0, 2, 1, 3).reshape(B * KV, T, hd)
    out_r = ref.banded_attention(q4.astype(jnp.float32),
                                 k3.astype(jnp.float32),
                                 v3.astype(jnp.float32), window=window)
    out_r = out_r.reshape(B, KV, G, T, hd).transpose(0, 3, 1, 2, 4) \
                 .reshape(B, T, H * hd)
    tol = 2e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out_k, np.float32),
                               np.asarray(out_r, np.float32),
                               rtol=tol, atol=tol)


def test_kernel_matches_xla_level_implementation():
    """The Pallas kernel and layers.banded_attention (the production XLA
    path) must agree — they implement the same SSPerf optimization."""
    B, T, H, KV, hd, window = 2, 512, 4, 2, 32, 128
    cfg = ArchConfig(name="t", family="dense", n_layers=1, d_model=H * hd,
                     n_heads=H, n_kv_heads=KV, d_ff=1, vocab=8,
                     dtype="float32")
    q, k, v = _qkv(B, T, H, KV, hd, jnp.float32, seed=9)
    out_k = ops.banded_attention(q, k, v, window=window, qc=128)
    out_x = layers.banded_attention(cfg, q, k, v, window=window, q_chunk=128)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_x),
                               rtol=2e-4, atol=2e-4)


def test_vmem_overflow_falls_back():
    """A window too large for VMEM must route to the oracle and stay
    correct (the wrapper's documented contract)."""
    B, T, H, KV, hd, window = 1, 2048, 2, 1, 128, 2048
    q, k, v = _qkv(B, T, H, KV, hd, jnp.float32, seed=3)
    out = ops.banded_attention(q, k, v, window=window, qc=1024)
    cfg = ArchConfig(name="t", family="dense", n_layers=1, d_model=H * hd,
                     n_heads=H, n_kv_heads=KV, d_ff=1, vocab=8,
                     dtype="float32")
    out_x = layers.banded_attention(cfg, q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_x),
                               rtol=2e-4, atol=2e-4)
