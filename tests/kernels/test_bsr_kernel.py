"""Block-sparse predict kernel vs ref oracle and dense matmul."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.pruning import prune, to_block_sparse
from repro.kernels.bsr_predict import ops, ref


def _sparse_W(L, D, density, seed, block=16):
    rng = np.random.default_rng(seed)
    W = rng.normal(size=(L, D)).astype(np.float32)
    # Zero whole blocks to the target density.
    nbl, nbd = L // block, D // block
    keep = rng.random((nbl, nbd)) < density
    mask = np.kron(keep, np.ones((block, block)))
    return W * mask[:L, :D]


@pytest.mark.parametrize("L,D,density", [(64, 64, 0.3), (128, 256, 0.1),
                                         (256, 128, 0.6), (64, 64, 1.0)])
@pytest.mark.parametrize("n", [1, 8])
def test_bsr_predict_allclose(L, D, density, n):
    W = _sparse_W(L, D, density, seed=L + D)
    model = to_block_sparse(jnp.asarray(W), (16, 16))
    x = jnp.asarray(np.random.default_rng(0).normal(size=(n, D)), jnp.float32)

    out_k = ops.bsr_predict(x, model)
    out_r = ref.bsr_predict(x, model)
    out_d = np.asarray(x) @ W.T
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(out_k)[:, :L], out_d,
                               rtol=1e-3, atol=1e-3)


def test_bsr_flops_accounting():
    W = _sparse_W(128, 128, 0.25, seed=7)
    model = to_block_sparse(jnp.asarray(W), (16, 16))
    assert ops.model_flops(model, 4) < ops.dense_flops(model, 4)
    ratio = ops.model_flops(model, 4) / ops.dense_flops(model, 4)
    assert abs(ratio - model.density) < 1e-9


def test_fully_pruned_model_predicts_zero():
    W = jnp.zeros((32, 32), jnp.float32)
    model = to_block_sparse(W, (16, 16))
    x = jnp.ones((2, 32), jnp.float32)
    out = ops.bsr_predict(x, model)
    assert float(jnp.max(jnp.abs(out))) == 0.0


def test_pruned_dismec_model_end_to_end(dismec_model, xmc_small_jnp):
    """The paper's serving path: prune -> BSR -> predict == dense predict."""
    _, _, Xte, _ = xmc_small_jnp
    W = prune(dismec_model.W, 0.01)
    model = to_block_sparse(W, (32, 32))
    out = ops.bsr_predict(Xte, model)
    dense = Xte @ W.T
    np.testing.assert_allclose(np.asarray(out)[:, :W.shape[0]],
                               np.asarray(dense), rtol=1e-3, atol=1e-3)
