"""Pallas Hessian-vector-product kernel vs ref.py oracle."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.kernels.hvp import ops, ref

SHAPES = [(4, 16, 8), (128, 128, 128), (130, 100, 64), (7, 300, 256)]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("L,N,D", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("C", [0.5, 2.0])
def test_hvp_allclose(L, N, D, dtype, C):
    rng = np.random.default_rng(L + N * 7)
    V = jnp.asarray(rng.normal(size=(L, D))).astype(dtype)
    X = jnp.asarray(rng.normal(size=(N, D))).astype(dtype)
    act = jnp.asarray((rng.random((L, N)) < 0.6).astype(np.float32))

    h_k = ops.hessian_vp(V, X, act, C, bl=32, bn=32)
    h_r = ref.hessian_vp(V.astype(jnp.float32), X.astype(jnp.float32), act, C)
    # f32 tolerance covers tile-accumulation-order differences at N=300.
    tol = 1e-3 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_r),
                               rtol=tol, atol=tol * 10)


def test_empty_active_set_is_regularizer_only():
    """act = 0 everywhere -> Hv = 2V exactly."""
    rng = np.random.default_rng(2)
    L, N, D = 8, 32, 16
    V = jnp.asarray(rng.normal(size=(L, D)), jnp.float32)
    X = jnp.asarray(rng.normal(size=(N, D)), jnp.float32)
    act = jnp.zeros((L, N), jnp.float32)
    h = ops.hessian_vp(V, X, act, 5.0, bl=8, bn=32)
    np.testing.assert_allclose(np.asarray(h), 2.0 * np.asarray(V),
                               rtol=1e-5, atol=1e-5)
