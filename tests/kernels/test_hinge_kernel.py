"""Pallas hinge kernel vs ref.py oracle: shape/dtype sweep (interpret mode)."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.kernels.hinge import ops, ref

SHAPES = [  # (L, N, D) incl. non-multiples of the 128 tiles
    (4, 16, 8),
    (128, 128, 128),
    (130, 100, 64),
    (7, 300, 256),
    (256, 64, 48),
]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("L,N,D", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("C", [0.5, 2.0])
def test_objective_and_grad_allclose(L, N, D, dtype, C):
    rng = np.random.default_rng(L * 31 + N)
    W = jnp.asarray(rng.normal(size=(L, D)) * 0.1).astype(dtype)
    X = jnp.asarray(rng.normal(size=(N, D))).astype(dtype)
    S = jnp.asarray(np.sign(rng.normal(size=(L, N))), jnp.float32)

    f_k, g_k, a_k = ops.objective_grad_act(W, X, S, C, bl=32, bn=32)
    f_r, g_r, a_r = ref.objective_grad_act(W.astype(jnp.float32),
                                           X.astype(jnp.float32), S, C)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(f_k), np.asarray(f_r),
                               rtol=tol, atol=tol * 10)
    np.testing.assert_allclose(np.asarray(g_k), np.asarray(g_r),
                               rtol=tol, atol=tol * 10)
    # The emitted active mask: exactly the (L, N) mask, pad columns/rows
    # sliced away (bf16 scores may flip exact-boundary ties vs the f32
    # oracle; none exist in this random data).
    assert a_k.shape == (L, N)
    np.testing.assert_array_equal(np.asarray(a_k), np.asarray(a_r))


def test_large_d_falls_back_to_ref():
    """D > MAX_FUSED_D must route to the decomposed path, still correct."""
    from repro.kernels.hinge.kernel import MAX_FUSED_D
    rng = np.random.default_rng(0)
    L, N, D = 4, 8, MAX_FUSED_D + 128
    W = jnp.asarray(rng.normal(size=(L, D)) * 0.01, jnp.float32)
    X = jnp.asarray(rng.normal(size=(N, D)) * 0.1, jnp.float32)
    S = jnp.asarray(np.sign(rng.normal(size=(L, N))), jnp.float32)
    f_k, g_k = ops.objective_and_grad(W, X, S, 1.0)
    f_r, g_r = ref.objective_and_grad(W, X, S, 1.0)
    np.testing.assert_allclose(np.asarray(f_k), np.asarray(f_r), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(g_k), np.asarray(g_r), rtol=1e-5)


def test_pad_instance_correction_exact():
    """The analytic pad-row correction must be exact: N=1 with bn=32 pads 31
    instances; objective must match the unpadded reference to fp precision."""
    rng = np.random.default_rng(1)
    L, N, D = 8, 1, 32
    W = jnp.asarray(rng.normal(size=(L, D)), jnp.float32)
    X = jnp.asarray(rng.normal(size=(N, D)), jnp.float32)
    S = jnp.asarray(np.sign(rng.normal(size=(L, N))), jnp.float32)
    f_k, _ = ops.objective_and_grad(W, X, S, 3.0, bl=8, bn=32)
    f_r, _ = ref.objective_and_grad(W, X, S, 3.0)
    np.testing.assert_allclose(np.asarray(f_k), np.asarray(f_r),
                               rtol=1e-5, atol=1e-4)
