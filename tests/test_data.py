"""Synthetic XMC generator invariants (hypothesis) + LM pipeline."""

import numpy as np
import pytest
from _hyp_compat import given, settings, st

from repro.data.xmc import (PAPER_LIKE, load_paper_like, make_xmc_dataset,
                            power_law_sizes)


@given(L=st.integers(8, 200), n1=st.integers(10, 500),
       beta=st.floats(0.5, 1.5))
@settings(max_examples=30, deadline=None)
def test_power_law_sizes_shape(L, n1, beta):
    sizes = power_law_sizes(L, n1, beta)
    assert sizes.shape == (L,)
    assert (sizes >= 1).all()
    assert (np.diff(sizes) <= 0).all()          # monotone decreasing in rank
    assert sizes[0] == max(n1, 1)


@given(seed=st.integers(0, 20))
@settings(max_examples=8, deadline=None)
def test_dataset_invariants(seed):
    d = make_xmc_dataset(n_train=200, n_test=50, n_features=768,
                         n_labels=48, seed=seed)
    # Every train label has >= 1 positive; every instance >= 1 label.
    assert (d.Y_train.sum(axis=0) >= 1).all()
    assert (d.Y_train.sum(axis=1) >= 1).all()
    assert (d.Y_test.sum(axis=1) >= 1).all()
    # Rows are L2-normalized, features sparse.
    norms = np.linalg.norm(d.X_train, axis=1)
    np.testing.assert_allclose(norms, 1.0, atol=1e-3)
    assert d.stats()["feat_density"] < 0.1


def test_power_law_tail_dominates():
    """Paper Fig. 1: a large fraction of labels are tail labels."""
    d = make_xmc_dataset(n_train=1000, n_test=100, n_features=4096,
                         n_labels=256, beta=1.1, seed=0)
    assert d.stats()["tail_leq5"] > 0.4


def test_paper_like_registry():
    for key in PAPER_LIKE:
        d = load_paper_like(key, seed=0)
        assert d.name == key
        assert d.n_labels == PAPER_LIKE[key]["n_labels"]


def test_lm_pipeline_batches():
    from repro.data.lm import make_lm_batch_iterator
    it = make_lm_batch_iterator(vocab=512, seq_len=32, batch=4, seed=0)
    b1 = next(it)
    b2 = next(it)
    assert b1["tokens"].shape == (4, 32)
    assert b1["targets"].shape == (4, 32)
    assert (np.asarray(b1["tokens"]) != np.asarray(b2["tokens"])).any()
    assert (np.asarray(b1["tokens"]) >= 0).all()
    assert (np.asarray(b1["tokens"]) < 512).all()
