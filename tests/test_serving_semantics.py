"""Serving-path semantics: SWA ring-buffer caches, enc-dec memory reuse,
and modality-prefix handling — the paths the decode dry-runs lower."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.configs.registry import get_config
from repro.models.model import build_model


def test_swa_ring_buffer_wraps_correctly():
    """With a sliding window w and cache length w, decoding past w tokens
    must equal full attention restricted to the last w tokens."""
    cfg = ArchConfig(name="t", family="dense", n_layers=2, d_model=64,
                     n_heads=4, n_kv_heads=4, d_ff=128, vocab=128,
                     sliding_window=8, swa_always=True, dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    B, T = 1, 20                                 # > 2x window
    toks = jnp.asarray(rng.integers(1, cfg.vocab, (B, T)), jnp.int32)

    # Decode-driven with a ring cache of exactly `window` slots.
    cache = model.init_cache(B, T, use_swa=True)
    for t in range(T):
        vals_ring, idx_ring, cache = model.decode_step(
            params, cache, toks[:, t:t + 1], jnp.int32(t), use_swa=True)

    # Reference: full prefill with the SWA mask (same window).
    vals_full, idx_full, _ = model.prefill(params, {"tokens": toks},
                                           use_swa=True)
    np.testing.assert_allclose(np.asarray(vals_ring), np.asarray(vals_full),
                               rtol=2e-2, atol=2e-2)
    assert int(idx_ring[0, 0]) == int(idx_full[0, 0])


def test_encdec_decode_reuses_encoder_memory():
    """seamless: the decoder's cross-attention memory K/V are computed at
    prefill and must be reused verbatim by decode_step (cache contract)."""
    cfg = get_config("seamless-m4t-medium", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    B, T = 2, 8
    toks = jnp.asarray(rng.integers(1, cfg.vocab, (B, T)), jnp.int32)
    frames = jnp.asarray(rng.normal(size=(B, cfg.n_prefix, cfg.d_model)),
                         jnp.float32)

    _, _, cache = model.prefill(params, {"tokens": toks, "prefix": frames})
    mem_k_before = np.asarray(cache["mem_k"])
    _, _, cache2 = model.decode_step(params, cache,
                                     jnp.ones((B, 1), jnp.int32),
                                     jnp.int32(T))
    np.testing.assert_array_equal(mem_k_before, np.asarray(cache2["mem_k"]))


def test_encdec_output_depends_on_frames():
    """The decoder must actually attend to the encoder memory: different
    frames -> different logits for the same tokens."""
    cfg = get_config("seamless-m4t-medium", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(2)
    B, T = 1, 6
    toks = jnp.asarray(rng.integers(1, cfg.vocab, (B, T)), jnp.int32)
    f1 = jnp.asarray(rng.normal(size=(B, cfg.n_prefix, cfg.d_model)),
                     jnp.float32)
    f2 = jnp.asarray(rng.normal(size=(B, cfg.n_prefix, cfg.d_model)),
                     jnp.float32)
    v1, _, _ = model.prefill(params, {"tokens": toks, "prefix": f1})
    v2, _, _ = model.prefill(params, {"tokens": toks, "prefix": f2})
    assert not np.allclose(np.asarray(v1), np.asarray(v2), atol=1e-4)


def test_vlm_prefix_changes_text_logits():
    """internvl2: patch-prefix embeddings must influence the language
    logits (the prefix is concatenated, not ignored), and the train loss
    must align targets with the TEXT positions only."""
    cfg = get_config("internvl2-26b", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    B, T = 2, 12
    toks = jnp.asarray(rng.integers(1, cfg.vocab, (B, T)), jnp.int32)
    p1 = jnp.asarray(rng.normal(size=(B, cfg.n_prefix, cfg.d_model)),
                     jnp.float32)
    p2 = jnp.asarray(rng.normal(size=(B, cfg.n_prefix, cfg.d_model)),
                     jnp.float32)
    v1, _, _ = model.prefill(params, {"tokens": toks, "prefix": p1})
    v2, _, _ = model.prefill(params, {"tokens": toks, "prefix": p2})
    assert not np.allclose(np.asarray(v1), np.asarray(v2), atol=1e-4)

    batch = {"tokens": toks, "targets": toks,
             "valid": jnp.ones((B, T), jnp.float32), "prefix": p1}
    loss, _ = model.train_loss(params, batch)
    assert bool(jnp.isfinite(loss))


def test_long_context_cache_shapes():
    """long_500k decode: SSM archs carry O(1) state regardless of the
    sequence length; attention archs carry O(min(T, window))."""
    ssm_cfg = get_config("xlstm-125m", smoke=True)
    ssm_model = build_model(ssm_cfg)
    c1 = ssm_model.init_cache(1, 1024)
    c2 = ssm_model.init_cache(1, 65536)
    for a, b in zip(jax.tree.leaves(c1), jax.tree.leaves(c2)):
        assert a.shape == b.shape                # O(1) in seq_len

    swa_cfg = get_config("mixtral-8x22b", smoke=True)
    swa_model = build_model(swa_cfg)
    w = swa_cfg.sliding_window
    c = swa_model.init_cache(1, 65536, use_swa=True)
    assert c["k"].shape[2] == min(w, 65536)      # ring buffer at window
