"""Per-architecture smoke tests (deliverable f): every assigned arch
instantiates a REDUCED variant (<= 2 layers, d_model <= 512, <= 4 experts)
and runs one forward/train step on CPU asserting output shapes + no NaNs.
Decode consistency: decode_step after prefill agrees with a longer prefill."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES
from repro.configs.registry import ARCH_IDS, SKIPS, get_config
from repro.models.model import build_model


def _batch(cfg, B=2, T=32):
    tokens = jnp.asarray(
        np.random.default_rng(0).integers(1, cfg.vocab, (B, T)), jnp.int32)
    batch = {"tokens": tokens, "targets": tokens,
             "valid": jnp.ones((B, T), jnp.float32)}
    if cfg.modality != "text" or cfg.is_encoder_decoder:
        batch["prefix"] = jnp.zeros((B, cfg.n_prefix or 8, cfg.d_model),
                                    jnp.float32)
    return batch


@pytest.fixture(scope="module")
def built():
    out = {}
    for arch in ARCH_IDS:
        cfg = get_config(arch, smoke=True)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        out[arch] = (cfg, model, params)
    return out


def test_smoke_configs_respect_reduction_bounds():
    for arch in ARCH_IDS:
        cfg = get_config(arch, smoke=True)
        assert cfg.n_layers <= 2, arch
        assert cfg.d_model <= 512, arch
        assert cfg.n_experts <= 4, arch


def test_full_configs_match_assignment():
    """Exact published numbers from the brief."""
    expect = {
        "xlstm-125m": (12, 768, 4, 4, 0, 50304),
        "chatglm3-6b": (28, 4096, 32, 2, 13696, 65024),
        "seamless-m4t-medium": (12, 1024, 16, 16, 4096, 256206),
        "qwen1.5-0.5b": (24, 1024, 16, 16, 2816, 151936),
        "internvl2-26b": (48, 6144, 48, 8, 16384, 92553),
        "qwen3-14b": (40, 5120, 40, 8, 17408, 151936),
        "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
        "qwen2-moe-a2.7b": (24, 2048, 16, 16, 1408, 151936),
        "deepseek-coder-33b": (62, 7168, 56, 8, 19200, 32256),
        "mixtral-8x22b": (56, 6144, 48, 8, 16384, 32768),
    }
    for arch, (nl, d, h, kv, ff, v) in expect.items():
        cfg = get_config(arch)
        got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
               cfg.moe_d_ff if arch == "qwen2-moe-a2.7b" else cfg.d_ff,
               cfg.vocab)
        assert got == (nl, d, h, kv, ff, v), (arch, got)
    assert get_config("qwen2-moe-a2.7b").n_experts == 60
    assert get_config("qwen2-moe-a2.7b").moe_top_k == 4
    assert get_config("qwen2-moe-a2.7b").n_shared_experts == 4
    assert get_config("mixtral-8x22b").n_experts == 8
    assert get_config("mixtral-8x22b").moe_top_k == 2
    assert get_config("hymba-1.5b").ssm_state == 16


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_loss_finite(arch, built):
    cfg, model, params = built[arch]
    loss, metrics = model.train_loss(params, _batch(cfg))
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), (arch, float(loss))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_updates_params_no_nans(arch, built):
    from repro.optim.schedules import linear_warmup_cosine
    from repro.train.trainer import make_train_step
    from repro.optim import adamw_init

    cfg, model, params = built[arch]
    step_fn = jax.jit(make_train_step(
        model, lr_fn=linear_warmup_cosine(1e-3, 2, 100)))
    opt = adamw_init(params)
    # step=1 so the warmup lr is nonzero and params actually move.
    new_params, _, metrics = step_fn(params, opt, jnp.ones((), jnp.int32),
                                     _batch(cfg))
    assert bool(jnp.isfinite(metrics["loss"]))
    for leaf, new_leaf in zip(jax.tree.leaves(params),
                              jax.tree.leaves(new_params)):
        assert leaf.shape == new_leaf.shape
        assert bool(jnp.all(jnp.isfinite(new_leaf))), arch
    # At least one parameter moved.
    moved = any(bool(jnp.any(a != b)) for a, b in
                zip(jax.tree.leaves(params), jax.tree.leaves(new_params)))
    assert moved, arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_shapes(arch, built):
    cfg, model, params = built[arch]
    B, T = 2, 16
    batch = _batch(cfg, B, T)
    del batch["targets"], batch["valid"]
    vals, idx, cache = model.prefill(params, batch)
    assert vals.shape == (B, 5) and idx.shape == (B, 5)
    assert bool(jnp.all(jnp.isfinite(vals))), arch
    assert bool(jnp.all((idx >= 0) & (idx < cfg.padded_vocab())))

    tok = jnp.ones((B, 1), jnp.int32)
    v2, i2, cache2 = model.decode_step(params, cache, tok, jnp.int32(T))
    assert v2.shape == (B, 5) and i2.shape == (B, 5)
    assert bool(jnp.all(jnp.isfinite(v2))), arch
    # Cache was updated, shapes preserved.
    for a, b in zip(jax.tree.leaves(cache), jax.tree.leaves(cache2)):
        assert a.shape == b.shape


def test_skip_table_is_exactly_the_documented_one():
    assert set(SKIPS) == {("seamless-m4t-medium", "long_500k")}
    # 10 archs x 4 shapes - 1 skip = 39 runnable pairs
    from repro.configs.registry import all_pairs
    assert len(list(all_pairs())) == 39


def test_param_count_sane():
    """Analytic param counts should be within ~35% of the marketing size
    (vocab padding, per-arch detail omissions allowed)."""
    approx = {"qwen1.5-0.5b": 0.62e9, "chatglm3-6b": 6e9,
              "qwen3-14b": 14e9, "deepseek-coder-33b": 33e9,
              "mixtral-8x22b": 141e9}
    for arch, expect in approx.items():
        n = get_config(arch).param_count()
        assert 0.55 * expect < n < 1.6 * expect, (arch, n, expect)
