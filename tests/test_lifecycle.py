"""Model lifecycle: generation counter, incomplete-checkpoint gating,
zero-downtime hot swap (XMCServer.swap / ModelRouter.refresh / watcher),
and the warm-start sweep driver (lifecycle.sweep)."""

import json
import os
import signal
import subprocess
import sys
import threading
import time

import numpy as np
import pytest

import jax.numpy as jnp

from repro.checkpoint.io import (BSR_INDEX, BSR_MANIFEST,
                                 checkpoint_generation, load_block_sparse,
                                 save_block_sparse)
from repro.core.pruning import prune, to_block_sparse
from repro.lifecycle import (CheckpointWatcher, SweepReport,
                             models_bit_identical, sweep)
from repro.serve import ModelRouter, XMCEngine, XMCResult, XMCServer, \
    make_backend
from repro.specs import (ScheduleSpec, ServeSpec, SolverSpec, SweepPolicy)
from repro.xmc_api import CheckpointHandle, XMCSpec, fit

L, D = 48, 512
SPEC = XMCSpec(solver=SolverSpec(eps=1e-2, delta=0.01),
               schedule=ScheduleSpec(label_batch=16, block_shape=(16, 16)),
               serve=ServeSpec(backend="bsr", k=3, buckets=(2, 4),
                               max_batch_delay_ms=1.0))


@pytest.fixture(scope="module")
def xmc_data():
    from repro.data.xmc import make_xmc_dataset
    d = make_xmc_dataset(n_train=150, n_test=40, n_features=D, n_labels=L,
                         seed=0)
    return (jnp.asarray(d.X_train), jnp.asarray(d.Y_train),
            np.asarray(d.X_test, np.float32), np.asarray(d.Y_test))


def _dense_engine(W, *, k=3, buckets=(2, 4, 8)):
    bsr = to_block_sparse(prune(jnp.asarray(W), 0.05), (128, 128))
    be = make_backend("dense", bsr, k, n_labels=W.shape[0])
    return XMCEngine(be, buckets=buckets, warmup=False,
                     n_features=W.shape[1])


# ---------------------------------------------------------------------------
# Generation counter (checkpoint/io.py)
# ---------------------------------------------------------------------------

def test_generation_bumps_on_fresh_fit(xmc_data, tmp_path):
    X, Y, _, _ = xmc_data
    out = str(tmp_path / "gen")
    fit(X, Y, SPEC, out)
    assert checkpoint_generation(out) == 1
    # Resuming (same spec, already complete) finishes the SAME model:
    # the generation must not move.
    fit(X, Y, SPEC, out)
    assert checkpoint_generation(out) == 1
    # A fresh refit (resume=False) publishes the next generation.
    spec2 = SPEC.replace(solver=SPEC.solver.replace(delta=0.2))
    fit(X, Y, spec2, out, resume=False)
    assert checkpoint_generation(out) == 2
    assert CheckpointHandle.open(out).generation == 2


def test_generation_one_shot_and_legacy_default(tmp_path):
    rng = np.random.default_rng(0)
    model = to_block_sparse(
        prune(jnp.asarray(rng.normal(size=(L, 128)).astype(np.float32)),
              0.2), (16, 16))
    out = str(tmp_path / "oneshot")
    save_block_sparse(model, out, meta={"n_features": 128})
    assert checkpoint_generation(out) == 1
    save_block_sparse(model, out, meta={"n_features": 128})
    assert checkpoint_generation(out) == 2
    # A checkpoint written before the counter existed reads as gen 1.
    path = os.path.join(out, BSR_INDEX)
    with open(path) as f:
        index = json.load(f)
    del index["generation"]
    with open(path, "w") as f:
        json.dump(index, f)
    assert checkpoint_generation(out) == 1


def test_incomplete_stream_gated_and_inspectable(xmc_data, tmp_path):
    X, Y, _, _ = xmc_data
    out = str(tmp_path / "partial")
    # One batch of L/label_batch=3: the stream stays incomplete.
    fit(X, Y, SPEC, out, max_batches=1)
    assert checkpoint_generation(out) is None     # not servable -> no gen
    with pytest.raises(ValueError, match="incomplete"):
        CheckpointHandle.open(out)
    with pytest.raises(ValueError, match="incomplete"):
        load_block_sparse(out)

    handle = CheckpointHandle.open(out, allow_incomplete=True)
    assert not handle.complete
    index = handle.index()
    assert index["complete"] is False
    model, _ = handle.model()                      # contiguous solved prefix
    assert model.orig_shape[0] == 16               # one 16-label batch
    with pytest.raises(ValueError, match="incomplete"):
        handle.engine()                            # serving stays strict

    # Finishing the stream makes it servable at generation 1.
    fit(X, Y, SPEC, out)
    assert checkpoint_generation(out) == 1
    assert CheckpointHandle.open(out).model()[0].orig_shape == (L, D)


# ---------------------------------------------------------------------------
# XMCServer.swap
# ---------------------------------------------------------------------------

def test_swap_flips_results_and_retains_previous():
    rng = np.random.default_rng(3)
    W = rng.normal(size=(96, 128)).astype(np.float32) * 0.1
    eng_a, eng_b = _dense_engine(W), _dense_engine(-W)
    x = rng.normal(size=(1, 128)).astype(np.float32)
    la = np.asarray(eng_a.backend.topk(jnp.asarray(x))[1])
    lb = np.asarray(eng_b.backend.topk(jnp.asarray(x))[1])
    assert not np.array_equal(la, lb)

    server = XMCServer(eng_a, max_batch_delay_ms=1.0)
    try:
        assert np.array_equal(server.submit(x).result(30).labels, la)
        prev = server.swap(eng_b)
        assert prev is eng_a and server.previous_engine is eng_a
        assert server.counters["swaps"] == 1
        # swap warmed the NEW engine for this server's buckets.
        assert set(server.queue.buckets) <= eng_b._warm
        assert server.last_swap["flip_ms"] < 1e3
        assert np.array_equal(server.submit(x).result(30).labels, lb)
        # Rollback is swap-back to the retained previous engine.
        server.swap(server.previous_engine)
        assert server.counters["swaps"] == 2
        assert np.array_equal(server.submit(x).result(30).labels, la)
    finally:
        server.stop()


def test_swap_feature_dim_mismatch_raises_before_flip():
    rng = np.random.default_rng(4)
    W = rng.normal(size=(96, 128)).astype(np.float32) * 0.1
    W_wide = rng.normal(size=(96, 256)).astype(np.float32) * 0.1
    server = XMCServer(_dense_engine(W), max_batch_delay_ms=1.0)
    try:
        old = server.engine
        with pytest.raises(ValueError, match="feature dim"):
            server.swap(_dense_engine(W_wide))
        assert server.engine is old                # nothing flipped
        assert server.counters["swaps"] == 0
        x = rng.normal(size=(2, 128)).astype(np.float32)
        assert isinstance(server.submit(x).result(30), XMCResult)
    finally:
        server.stop()


def test_swap_on_stopped_server_raises():
    rng = np.random.default_rng(5)
    W = rng.normal(size=(96, 128)).astype(np.float32) * 0.1
    server = XMCServer(_dense_engine(W), max_batch_delay_ms=1.0)
    server.stop()
    with pytest.raises(RuntimeError, match="stopped"):
        server.swap(_dense_engine(W))


def test_swap_under_poisson_load_zero_drops_clean_cut():
    """Open-loop traffic while swap() fires from another thread: every
    accepted request resolves, none rejected, and the completion stream is
    a clean cut — old-model answers strictly before new-model answers."""
    rng = np.random.default_rng(6)
    W = rng.normal(size=(96, 128)).astype(np.float32) * 0.1
    eng_a, eng_b = _dense_engine(W), _dense_engine(-W)
    n = 60
    # Single-row requests: never split across micro-batches, so each
    # answer is attributable to exactly one model.
    reqs = [rng.normal(size=(1, 128)).astype(np.float32) for _ in range(n)]
    pred = {id(e): [np.asarray(e.backend.topk(jnp.asarray(x))[1])
                    for x in reqs] for e in (eng_a, eng_b)}

    server = XMCServer(eng_a, max_batch_delay_ms=1.0)
    swapper = threading.Thread(target=lambda: server.swap(eng_b))
    futures = []
    try:
        for i, x in enumerate(reqs):
            futures.append(server.submit(x))
            if i == n // 2:
                swapper.start()
            time.sleep(rng.exponential(1.5e-3))
        swapper.join()
    finally:
        server.stop()

    results = [f.result(60) for f in futures]
    assert all(isinstance(r, XMCResult) for r in results)
    assert server.counters["accepted"] == n
    assert server.counters["completed"] == n
    assert server.counters["rejected"] == 0
    assert server.counters["swaps"] == 1

    kinds = []
    for i, r in enumerate(results):
        if np.array_equal(r.labels, pred[id(eng_a)][i]):
            kinds.append("a")
        else:
            assert np.array_equal(r.labels, pred[id(eng_b)][i])
            kinds.append("b")
    assert "a" in kinds                  # requests before the flip: old model
    # Micro-batches are FIFO and the flip happens between them, so the
    # submission-ordered answers are A...AB...B — never interleaved.
    first_b = kinds.index("b") if "b" in kinds else len(kinds)
    assert all(k == "b" for k in kinds[first_b:])


# ---------------------------------------------------------------------------
# CheckpointWatcher + ModelRouter.refresh/.watch
# ---------------------------------------------------------------------------

@pytest.fixture()
def ckpt_pair(xmc_data, tmp_path):
    """One served checkpoint dir (gen 1) + a second dir with a different
    delta (for refresh), both over the same feature dim."""
    X, Y, _, _ = xmc_data
    a, b = str(tmp_path / "a"), str(tmp_path / "b")
    fit(X, Y, SPEC, a)
    fit(X, Y, SPEC.replace(solver=SPEC.solver.replace(delta=0.3)), b,
        init_from=a)
    return a, b


def test_watcher_poll_once_swaps_on_new_generation(xmc_data, ckpt_pair):
    X, Y, _, _ = xmc_data
    a, _ = ckpt_pair
    server = CheckpointHandle.open(a).server()
    swaps = []
    try:
        watcher = CheckpointWatcher(
            a, server, poll_interval_s=0.05,
            on_swap=lambda gen, handle, prev: swaps.append(gen))
        assert watcher.generation == 1             # baseline: already served
        assert watcher.poll_once() is None         # nothing new
        old_engine = server.engine

        # A fresh refit into the SAME directory -> generation 2.
        fit(X, Y, SPEC.replace(solver=SPEC.solver.replace(delta=0.25)), a,
            resume=False)
        handle = watcher.poll_once()
        assert handle is not None and watcher.generation == 2
        assert server.counters["swaps"] == 1
        assert server.engine is not old_engine
        assert swaps == [2]
        assert watcher.poll_once() is None         # idempotent until gen 3
    finally:
        server.stop()


def test_watcher_never_swaps_a_half_written_generation(xmc_data, ckpt_pair):
    X, Y, _, _ = xmc_data
    a, _ = ckpt_pair
    server = CheckpointHandle.open(a).server()
    try:
        watcher = CheckpointWatcher(a, server, poll_interval_s=0.05)
        spec3 = SPEC.replace(solver=SPEC.solver.replace(delta=0.05))
        # Start streaming generation 2 but stop after one of three batches:
        # the manifest exists, is newer, and is NOT complete.
        fit(X, Y, spec3, a, resume=False, max_batches=1)
        assert checkpoint_generation(a) is None
        assert watcher.poll_once() is None
        assert server.counters["swaps"] == 0
        # Finishing the stream makes it swappable.
        fit(X, Y, spec3, a)
        assert watcher.poll_once() is not None
        assert watcher.generation == 2
        assert server.counters["swaps"] == 1
    finally:
        server.stop()


def test_router_refresh_and_watch(xmc_data, ckpt_pair):
    X, Y, _, _ = xmc_data
    a, b = ckpt_pair
    router = ModelRouter({"m": CheckpointHandle.open(a).server()})
    try:
        with pytest.raises(ValueError, match="unknown model"):
            router.refresh("nope", b)
        old = router["m"].engine
        prev = router.refresh("m", b)
        assert prev is old and router["m"].counters["swaps"] == 1
        assert isinstance(router["m"].submit(
            np.zeros((1, D), np.float32)).result(30), XMCResult)

        # Background watcher through the router: a refit into `b` is
        # picked up without any explicit refresh call.
        watcher = router.watch("m", b, poll_interval_s=0.05)
        fit(X, Y, SPEC.replace(solver=SPEC.solver.replace(delta=0.15)), b,
            resume=False)
        deadline = time.monotonic() + 60
        while router["m"].counters["swaps"] < 2:
            assert time.monotonic() < deadline, "watcher never swapped"
            time.sleep(0.05)
        assert watcher.swaps == 1 and watcher.generation == 2
    finally:
        router.stop()
    assert watcher._thread is None                 # stop() joined the watcher


# ---------------------------------------------------------------------------
# Sweep driver
# ---------------------------------------------------------------------------

def test_sweep_fixed_point_monotonicity_and_policy(xmc_data, tmp_path):
    X, Y, Xh, Yh = xmc_data
    report = sweep(
        X, Y, SPEC, {"same": {}, "hi": {"delta": 0.3}},
        str(tmp_path / "sweepA"), workers=2, holdout=(Xh, Yh),
        policy=SweepPolicy(kind="max_precision", metric="P@1"))
    assert isinstance(report, SweepReport)
    assert [a.name for a in report.arms] == ["base", "same", "hi"]

    base, same, hi = report.arms
    # Correctness anchor: the unchanged-spec arm warm-started from the
    # converged base is a bit-identical fixed point.
    assert same.fixed_point is True
    assert models_bit_identical(same.out_dir, base.out_dir)
    assert same.nnz == base.nnz
    assert hi.fixed_point is None                  # different solution
    # Fig. 5 monotonicity: a larger Delta prunes at least as hard.
    assert hi.nnz <= same.nnz
    assert hi.model_mb <= same.model_mb
    for arm in report.arms:
        assert arm.model_mb == pytest.approx(arm.nnz * 8 / 1e6)
        assert 0.0 < arm.nnz_frac <= 1.0
        assert arm.int8_mb > 0.0
        assert "P@1" in arm.metrics and "P@3" in arm.metrics
    assert base.warm_started is False and hi.warm_started is True

    assert report.winner in ("base", "same", "hi")
    assert report.winner_dir == report.arm(report.winner).out_dir
    json.dumps(report.to_dict())                   # report is JSON-clean

    # Declarative deployment policies over the same arms:
    budget = (hi.model_mb + same.model_mb) / 2
    under = SweepPolicy(kind="max_precision_under_size_mb", metric="P@1",
                        size_mb=budget)
    assert under.select(report.arms).name == "hi"
    assert SweepPolicy(kind="min_size").select(report.arms).name == "hi"

    # Re-running the sweep resumes every arm (no retraining) and lands on
    # the same report, regardless of worker count.
    again = sweep(X, Y, SPEC, {"same": {}, "hi": {"delta": 0.3}},
                  str(tmp_path / "sweepA"), workers=1, holdout=(Xh, Yh),
                  policy=SweepPolicy(kind="max_precision", metric="P@1"))
    assert again.winner == report.winner
    assert [a.nnz for a in again.arms] == [a.nnz for a in report.arms]
    assert [a.metrics["P@1"] for a in again.arms] == \
        [a.metrics["P@1"] for a in report.arms]


def test_sweep_rejects_bad_arms(xmc_data, tmp_path):
    X, Y, _, _ = xmc_data
    with pytest.raises(ValueError, match="reserved"):
        sweep(X, Y, SPEC, {"base": {}}, str(tmp_path / "s1"))
    with pytest.raises(ValueError, match="plain directory"):
        sweep(X, Y, SPEC, {"a/b": {}}, str(tmp_path / "s2"))
    with pytest.raises(ValueError, match="workers"):
        sweep(X, Y, SPEC, {"x": {}}, str(tmp_path / "s3"), workers=0)


def test_sweep_policy_validation():
    with pytest.raises(ValueError, match="unknown sweep policy"):
        SweepPolicy(kind="nope").validate()
    with pytest.raises(ValueError, match="size_mb"):
        SweepPolicy(kind="max_precision_under_size_mb").validate()
    with pytest.raises(ValueError, match="precision_floor"):
        SweepPolicy(kind="min_size_at_precision").validate()
    p = SweepPolicy(kind="max_precision_under_size_mb", size_mb=2.0,
                    int8=True)
    assert SweepPolicy.from_json(p.to_json()) == p


# ---------------------------------------------------------------------------
# launch/serve.py --server: signal-driven drain
# ---------------------------------------------------------------------------

def test_server_cli_sigterm_drains(tmp_path):
    """SIGTERM mid-load must drain the router (every accepted future
    resolves) and exit 143 — not kill dispatcher threads mid-batch."""
    env = dict(os.environ, PYTHONPATH="src")
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.launch.serve", "--xmc", "--server",
         "--ckpt", str(tmp_path / "cli_ckpt"), "--backend", "dense",
         "--features", "512", "--labels", "64",
         "--requests", "2000", "--rate", "20"],
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=env, stdout=subprocess.PIPE, stderr=subprocess.STDOUT,
        text=True)
    lines = []
    try:
        for line in proc.stdout:                   # blocks until EOF
            lines.append(line)
            if "offering" in line:
                break
        else:
            proc.wait(timeout=30)
            pytest.fail("server never started:\n" + "".join(lines))
        time.sleep(1.0)                            # let some load flow
        proc.send_signal(signal.SIGTERM)
        rest, _ = proc.communicate(timeout=180)
        lines.append(rest)
    finally:
        proc.kill()
    out = "".join(lines)
    assert proc.returncode == 128 + signal.SIGTERM, out
    assert "router drained" in out, out
