"""End-to-end behaviour of the paper's system (replaces the old placeholder).

The full DiSMEC pipeline: power-law data -> Algorithm 1 (batched TRON +
Delta-pruning) -> block-sparse serving -> top-k metrics, plus the paper's
headline claims at test scale.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.dismec import DiSMECConfig, train
from repro.core.prediction import evaluate, predict_topk
from repro.core.pruning import ambiguous_fraction, prune, to_block_sparse
from repro.kernels.bsr_predict import ops as bsr_ops


@pytest.fixture(scope="module")
def raw_model(xmc_small_jnp):
    """Unpruned (delta=0) model shared by the claim tests."""
    X, Y, _, _ = xmc_small_jnp
    return train(X, Y, DiSMECConfig(delta=0.0, label_batch=64))


def test_full_pipeline(xmc_small_jnp):
    """Data -> train -> prune -> BSR serve -> metrics, all public API."""
    import jax

    X, Y, Xte, Yte = xmc_small_jnp
    model = train(X, Y, DiSMECConfig(C=1.0, delta=0.01, label_batch=64))

    # Serving path: block-sparse predict + top-k.
    bsr = to_block_sparse(model.W, (32, 32))
    scores = bsr_ops.bsr_predict(Xte, bsr)[:, :model.n_labels]
    _, idx = jax.lax.top_k(scores, 5)
    ev = evaluate(Yte, idx)
    assert ev["P@1"] > 0.90

    # The serving path agrees with dense prediction.
    _, idx_dense = predict_topk(Xte, model.W, 5)
    assert (np.asarray(idx) == np.asarray(idx_dense)).mean() > 0.99


def test_pruning_is_lossless_at_001(raw_model, xmc_small_jnp):
    """Paper §2.2.1: Delta=0.01 has no adverse impact on P@k vs Delta=0."""
    _, _, Xte, Yte = xmc_small_jnp
    _, idx_raw = predict_topk(Xte, raw_model.W, 5)
    _, idx_pruned = predict_topk(Xte, prune(raw_model.W, 0.01), 5)
    p_raw = evaluate(Yte, idx_raw)
    p_pruned = evaluate(Yte, idx_pruned)
    for k in ("P@1", "P@3", "P@5"):
        assert abs(p_raw[k] - p_pruned[k]) < 0.02, (k, p_raw[k], p_pruned[k])


def test_ambiguous_weights_dominate(raw_model):
    """Paper Fig. 2a: a large share of learnt l2 weights are ambiguous
    (|w| < 0.01). The paper sees 96-99.5% at D ~ 10^6; at our toy D = 1024
    the background-feature pool is ~1000x smaller so the fraction is far
    lower — assert the structural effect (a substantial ambiguous mass),
    scale-calibrated."""
    frac = float(ambiguous_fraction(raw_model.W, 0.01))
    assert frac > 0.3, frac


def test_larger_delta_degrades(raw_model, xmc_small_jnp):
    """Paper Fig. 5: Delta >> 0.01 shrinks the model further but costs
    accuracy."""
    _, _, Xte, Yte = xmc_small_jnp
    p, n = {}, {}
    for delta in (0.01, 0.3):
        Wp = prune(raw_model.W, delta)
        _, idx = predict_topk(Xte, Wp, 5)
        p[delta] = evaluate(Yte, idx)["P@1"]
        n[delta] = int(jnp.sum(Wp != 0))
    assert n[0.3] < n[0.01]
    assert p[0.3] < p[0.01]


def test_linear_xmc_is_dismec_head_special_case(xmc_small_jnp):
    """DESIGN.md §4: with an identity backbone, the DiSMECHead multi-hot
    objective IS Eq. 2.2 (per-token mean). Gradient descent on it should
    agree with the TRON model on prediction."""
    import jax

    from repro.core.head import ovr_multihot_loss

    X, Y, Xte, Yte = xmc_small_jnp
    W = jnp.zeros((Y.shape[1], X.shape[1]), jnp.float32)
    loss_fn = lambda w: ovr_multihot_loss(w, X, Y, C=1.0, reg=1.0 / X.shape[0])
    g_fn = jax.jit(jax.grad(loss_fn))
    for _ in range(400):
        W = W - 0.5 * g_fn(W)
    _, idx = predict_topk(Xte, W, 5)
    assert evaluate(Yte, idx)["P@1"] > 0.85
