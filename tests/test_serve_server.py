"""Continuous-batching async server: deadline launch, admission control,
multi-model routing, and sync-vs-async bit-identity per backend."""

import tempfile
import time

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.pruning import prune, to_block_sparse
from repro.serve import (BACKENDS, ModelRouter, Rejected, XMCEngine,
                         XMCResult, XMCServer, build_shortlist, make_backend)
from repro.specs import ServeSpec
from repro.xmc_api import CheckpointHandle


def _pruned_bsr(L, D, *, seed=0, delta=0.05):
    rng = np.random.default_rng(seed)
    W = rng.normal(size=(L, D)).astype(np.float32) * 0.1
    return to_block_sparse(prune(jnp.asarray(W), delta), (128, 128))


def _engine(kind="dense", *, L=96, D=128, k=3, buckets=(2, 4, 8), seed=0,
            backend=None):
    bsr = _pruned_bsr(L, D, seed=seed)
    be = backend if backend is not None else make_backend(
        kind, bsr, k, n_labels=L, shortlist=build_shortlist(bsr))
    return XMCEngine(be, buckets=buckets, warmup=False, n_features=D)


def _requests(n, D, *, seed=0, max_rows=5):
    rng = np.random.default_rng(seed)
    return [rng.normal(size=(int(r), D)).astype(np.float32)
            for r in rng.integers(1, max_rows + 1, size=n)]


# ---------------------------------------------------------------------------
# Launch policy
# ---------------------------------------------------------------------------

def test_deadline_launches_partially_filled_bucket():
    """One lone request must ship once its deadline expires — it can never
    fill the largest bucket, so a fill-only policy would hang forever."""
    engine = _engine(buckets=(8, 16))
    server = XMCServer(engine, max_batch_delay_ms=5.0)
    x = np.random.default_rng(1).normal(size=(1, 128)).astype(np.float32)
    t0 = time.monotonic()
    res = server.submit(x).result(timeout=30)
    waited = time.monotonic() - t0
    server.stop()
    assert isinstance(res, XMCResult)
    assert res.labels.shape == (1, 3)
    assert waited < 25, "deadline launch took implausibly long"
    assert server.counters["completed"] == 1


def test_full_bucket_launches_before_deadline():
    """Enough queued rows to fill the largest bucket launch immediately —
    with a deadline much longer than the test timeout, only fill-launch
    can resolve these futures in time."""
    engine = _engine(buckets=(2, 4, 8))
    server = XMCServer(engine, max_batch_delay_ms=120_000.0)
    reqs = _requests(8, 128, seed=2, max_rows=1)     # 8 rows = largest bucket
    futures = [server.submit(x) for x in reqs]
    results = [f.result(timeout=60) for f in futures]
    server.stop()
    assert all(isinstance(r, XMCResult) for r in results)
    assert server.counters["completed"] == 8


def test_fifo_order_is_preserved_across_batches():
    """Mixed-size requests pre-queued then drained: request ids complete in
    submission order batch by batch (FIFO fairness — no size-based
    reordering), and every request keeps its own rows."""
    engine = _engine(buckets=(2, 4))
    server = XMCServer(engine, start=False)
    sizes = [3, 1, 4, 2, 1, 5]
    reqs = [np.full((n, 128), i, np.float32) for i, n in enumerate(sizes)]
    futures = [server.submit(x) for x in reqs]
    server.stop()                                    # inline force-drain
    results = [f.result(timeout=0) for f in futures]
    for i, (n, res) in enumerate(zip(sizes, results)):
        assert res.request_id == i
        assert res.labels.shape == (n, 3)
    # Dispatch order == submission order: later requests never complete in
    # an earlier batch than earlier ones (head-of-line pieces go first).
    assert server.counters["batches"] >= 2


# ---------------------------------------------------------------------------
# Admission control
# ---------------------------------------------------------------------------

def test_admission_rejects_past_max_queue_then_recovers():
    engine = _engine()
    server = XMCServer(engine, max_queue=2, start=False)
    reqs = _requests(6, 128, seed=3, max_rows=1)
    futures = [server.submit(x) for x in reqs]
    rejected = [f for f in futures if f.done()
                and isinstance(f.result(0), Rejected)]
    assert len(rejected) == 4                        # first 2 queued, rest shed
    for f in rejected:
        r = f.result(0)
        assert r.reason == "queue_full"
        assert r.request_id >= 0
    server.start()
    server.stop()
    completed = [f.result(5) for f in futures
                 if not isinstance(f.result(5), Rejected)]
    assert len(completed) == 2
    st = server.stats()
    assert st["rejected"] == 4 and st["completed"] == 2
    assert st["reject_rate"] == pytest.approx(4 / 6)
    # Queue drained: a fresh request is admitted again.
    server2 = XMCServer(_engine(), max_queue=2, start=False)
    f = server2.submit(reqs[0])
    assert not f.done()
    server2.stop()
    assert isinstance(f.result(0), XMCResult)


def test_rejected_requests_do_not_lose_ids():
    """Rejections consume an id from the same namespace as accepted
    requests — no two responses ever share an id."""
    server = XMCServer(_engine(), max_queue=1, start=False)
    reqs = _requests(5, 128, seed=4, max_rows=1)
    futures = [server.submit(x) for x in reqs]
    server.stop()
    ids = [f.result(5).request_id for f in futures]
    assert len(set(ids)) == len(ids)


def test_submit_after_stop_raises():
    server = XMCServer(_engine())
    server.stop()
    with pytest.raises(RuntimeError, match="stopped"):
        server.submit(np.zeros((1, 128), np.float32))


def test_server_checks_feature_dim_at_submit():
    server = XMCServer(_engine(), start=False)
    with pytest.raises(ValueError, match="feature dim"):
        server.submit(np.zeros((1, 64), np.float32))
    server.stop()


# ---------------------------------------------------------------------------
# Oversize requests (regression: one request id -> exactly one result)
# ---------------------------------------------------------------------------

def test_oversize_request_coalesces_to_one_result_sync():
    """A request split across micro-batches by the queue must return as ONE
    XMCResult with its rows in order — never several partial results
    sharing the request id."""
    L, D, k = 96, 128, 3
    bsr = _pruned_bsr(L, D, seed=5)
    be = make_backend("dense", bsr, k, n_labels=L)
    engine = XMCEngine(be, buckets=(2, 4), warmup=False, n_features=D)
    rng = np.random.default_rng(6)
    x = rng.normal(size=(11, D)).astype(np.float32)  # 11 rows >> bucket 4
    results = engine.serve([x])
    assert len(results) == 1                          # one id, one result
    assert results[0].labels.shape == (11, k)
    # Row order survives the split: the first piece is exactly x[:4] at
    # bucket 4 (no padding), so the direct backend call is the reference.
    ref_scores, ref_labels = be.topk(jnp.asarray(x[:4]))
    np.testing.assert_array_equal(results[0].labels[:4],
                                  np.asarray(ref_labels))
    np.testing.assert_array_equal(results[0].scores[:4],
                                  np.asarray(ref_scores))


def test_oversize_request_coalesces_to_one_result_async():
    engine = _engine(buckets=(2, 4))
    server = XMCServer(engine, max_batch_delay_ms=1.0)
    rng = np.random.default_rng(7)
    x = rng.normal(size=(11, 128)).astype(np.float32)
    fut = server.submit(x)
    res = fut.result(timeout=60)
    server.stop()
    assert isinstance(res, XMCResult)
    assert res.labels.shape == (11, 3)
    assert server.counters["completed"] == 1          # one future, once
    assert server.latency.count == 1                  # one latency sample


# ---------------------------------------------------------------------------
# Sync-vs-async bit-identity per backend
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", BACKENDS)
def test_async_results_bit_identical_to_sync(kind):
    """The async loop changes WHEN batches launch, never WHAT they compute:
    with the same pre-queued request stream (same grouping), every backend
    returns bit-identical scores and labels through both paths."""
    L, D, k = 96, 128, 3
    bsr = _pruned_bsr(L, D, seed=8)
    be = make_backend(kind, bsr, k, n_labels=L,
                      shortlist=build_shortlist(bsr))
    reqs = _requests(9, D, seed=9)
    sync_engine = XMCEngine(be, buckets=(2, 4, 8), warmup=False,
                            n_features=D)
    sync = sync_engine.serve(reqs)
    async_engine = XMCEngine(be, buckets=(2, 4, 8), warmup=False,
                             n_features=D)
    server = XMCServer(async_engine, start=False)     # pre-queue everything
    futures = [server.submit(x) for x in reqs]
    server.stop()
    for s, f in zip(sync, futures):
        a = f.result(timeout=0)
        assert a.request_id == s.request_id
        np.testing.assert_array_equal(s.scores, a.scores)
        np.testing.assert_array_equal(s.labels, a.labels)


# ---------------------------------------------------------------------------
# Multi-model routing
# ---------------------------------------------------------------------------

def test_router_dispatches_across_two_checkpoints():
    """Two checkpoints with distinct ServeSpecs behind one router: each
    model answers with its own backend/k, results match that model's own
    synchronous engine, and unknown names fail loudly."""
    rng = np.random.default_rng(10)
    with tempfile.TemporaryDirectory() as da, \
            tempfile.TemporaryDirectory() as db:
        for d, L, seed in ((da, 96, 11), (db, 160, 12)):
            _pruned_bsr(L, 128, seed=seed).save(
                d, meta={"n_labels": L, "n_features": 128})
        ha, hb = CheckpointHandle.open(da), CheckpointHandle.open(db)
        spec_a = ServeSpec(backend="dense", k=3, buckets=(2, 4),
                           warmup=False, max_batch_delay_ms=1.0)
        spec_b = ServeSpec(backend="bsr", k=5, buckets=(2, 4),
                           warmup=False, max_batch_delay_ms=1.0)
        router = ModelRouter({"a": ha.server(spec_a, start=False),
                              "b": hb.server(spec_b, start=False)})
        assert router.models() == ("a", "b")
        xa = rng.normal(size=(2, 128)).astype(np.float32)
        xb = rng.normal(size=(3, 128)).astype(np.float32)
        fa = router.submit("a", xa)
        fb = router.submit("b", xb)
        with pytest.raises(ValueError, match="unknown model"):
            router.submit("nope", xa)
        router.stop()
        ra, rb = fa.result(5), fb.result(5)
        assert ra.labels.shape == (2, 3)              # model a's k
        assert rb.labels.shape == (3, 5)              # model b's k
        np.testing.assert_array_equal(
            ra.labels, ha.engine(spec_a).serve([xa])[0].labels)
        np.testing.assert_array_equal(
            rb.labels, hb.engine(spec_b).serve([xb])[0].labels)
        assert router.stats()["a"]["completed"] == 1
        assert router.stats()["b"]["completed"] == 1


def test_router_rejects_duplicate_model_name():
    router = ModelRouter()
    server = XMCServer(_engine(), start=False, name="m")
    router.add("m", server)
    with pytest.raises(ValueError, match="already routed"):
        router.add("m", server)
    server.stop()


# ---------------------------------------------------------------------------
# ServeSpec plumbing
# ---------------------------------------------------------------------------

def test_servespec_server_fields_roundtrip_and_validate():
    spec = ServeSpec(max_batch_delay_ms=7.5, max_queue=32)
    assert ServeSpec.from_dict(spec.to_dict()) == spec
    # Manifests written before these fields existed deserialize to defaults.
    old = {k: v for k, v in spec.to_dict().items()
           if k not in ("max_batch_delay_ms", "max_queue")}
    assert ServeSpec.from_dict(old) == ServeSpec()
    with pytest.raises(ValueError, match="max_batch_delay_ms"):
        ServeSpec(max_batch_delay_ms=-1.0).validate()
    with pytest.raises(ValueError, match="max_queue"):
        ServeSpec(max_queue=0).validate()


def test_handle_server_uses_spec_knobs():
    with tempfile.TemporaryDirectory() as d:
        _pruned_bsr(96, 128, seed=13).save(
            d, meta={"n_labels": 96, "n_features": 128})
        handle = CheckpointHandle.open(d)
        server = handle.server(
            ServeSpec(backend="dense", k=3, buckets=(2, 4), warmup=False,
                      max_batch_delay_ms=9.0, max_queue=7), start=False)
        assert server.max_batch_delay_ms == 9.0
        assert server.max_queue == 7
        server.stop()
