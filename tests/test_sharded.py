"""Multi-device sharding semantics, run in subprocesses with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (the main test process
keeps the 1 real device, per the brief)."""

import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env, timeout=560)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def test_train_sharded_equals_single_device():
    """Paper-faithful label sharding AND beyond-paper data sharding must both
    reproduce the single-device Algorithm 1 solution."""
    out = _run("""
        import jax, jax.numpy as jnp
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        from repro.data.xmc import make_xmc_dataset
        from repro.core.dismec import DiSMECConfig, train, train_sharded
        d = make_xmc_dataset(n_train=256, n_test=50, n_features=512,
                             n_labels=48, seed=0)
        X, Y = jnp.asarray(d.X_train), jnp.asarray(d.Y_train)
        cfg = DiSMECConfig(label_batch=48)
        m1 = train(X, Y, cfg)
        m2 = train_sharded(X, Y, cfg, mesh)
        m3 = train_sharded(X, Y, cfg, mesh, shard_data=True)
        assert jnp.allclose(m1.W, m2.W, atol=1e-3), "label-sharded mismatch"
        assert jnp.allclose(m1.W, m3.W, atol=1e-3), "data-sharded mismatch"
        print("OK")
    """)
    assert "OK" in out


def test_label_padding_under_sharding():
    """L=50 not divisible by 8 shards: result must still be exact for the
    real labels (padding sliced away)."""
    out = _run("""
        import jax, jax.numpy as jnp
        mesh = jax.make_mesh((1, 8), ("data", "model"))
        from repro.data.xmc import make_xmc_dataset
        from repro.core.dismec import DiSMECConfig, train, train_sharded
        d = make_xmc_dataset(n_train=200, n_test=50, n_features=512,
                             n_labels=50, seed=1)
        X, Y = jnp.asarray(d.X_train), jnp.asarray(d.Y_train)
        cfg = DiSMECConfig(label_batch=50)
        m1 = train(X, Y, cfg)
        m2 = train_sharded(X, Y, cfg, mesh)
        assert m2.W.shape == m1.W.shape == (50, 512)
        assert jnp.allclose(m1.W, m2.W, atol=1e-3)
        print("OK")
    """)
    assert "OK" in out


def test_data_sharded_non_divisible_n():
    """N not divisible by the data axis: the psum path pads instances with
    zero rows + all-negative signs (gradient/Hessian contributions vanish,
    the constant objective offset is subtracted) and must reproduce the
    unsharded solution exactly — the old code hard-asserted divisibility."""
    out = _run("""
        import jax, jax.numpy as jnp
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        from repro.data.xmc import make_xmc_dataset
        from repro.core.dismec import DiSMECConfig, train, train_sharded
        d = make_xmc_dataset(n_train=201, n_test=50, n_features=512,
                             n_labels=48, seed=3)   # 201 % 4 == 1
        X, Y = jnp.asarray(d.X_train), jnp.asarray(d.Y_train)
        cfg = DiSMECConfig(label_batch=48)
        m1 = train(X, Y, cfg)
        m2 = train_sharded(X, Y, cfg, mesh, shard_data=True)
        assert m2.W.shape == m1.W.shape == (48, 512)
        assert jnp.allclose(m1.W, m2.W, atol=1e-3), "padded psum mismatch"
        print("OK")
    """)
    assert "OK" in out


def test_streaming_pipeline_on_mesh_matches_train():
    """The full composition: label-batch scheduler (layer 1) over the
    mesh-sharded solver (layer 2) with frequency-balanced shard dealing,
    streamed to a multi-shard checkpoint — must land on the single-device
    Algorithm 1 solution."""
    out = _run("""
        import tempfile
        import numpy as np
        import jax, jax.numpy as jnp
        mesh = jax.make_mesh((1, 4), ("data", "model"))
        from repro.checkpoint.io import load_block_sparse
        from repro.core.dismec import DiSMECConfig, train
        from repro.data.xmc import make_xmc_dataset
        from repro.train.xmc import XMCTrainJob
        d = make_xmc_dataset(n_train=200, n_test=50, n_features=1024,
                             n_labels=96, seed=4)
        X, Y = jnp.asarray(d.X_train), jnp.asarray(d.Y_train)
        cfg = DiSMECConfig(label_batch=32)
        job = XMCTrainJob(cfg=cfg, mesh=mesh, balance=True,
                          block_shape=(16, 16))
        with tempfile.TemporaryDirectory() as out_dir:
            res = job.run(X, Y, out_dir)
            assert res.complete and res.n_batches == 3
            bsr, meta = load_block_sparse(out_dir)
            W = np.asarray(bsr.to_dense())[:96, :1024]
        m1 = train(X, Y, cfg)
        assert np.allclose(W, np.asarray(m1.W), atol=1e-3)
        print("OK")
    """)
    assert "OK" in out


def test_distributed_topk_merge():
    """Shard-local top-k + global merge == dense top-k (paper §2.2.1)."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        mesh = jax.make_mesh((1, 8), ("data", "model"))
        from repro.core.prediction import predict_topk, predict_topk_sharded
        rng = np.random.default_rng(0)
        W = jnp.asarray(rng.normal(size=(64, 128)), jnp.float32)
        X = jnp.asarray(rng.normal(size=(16, 128)), jnp.float32)
        s1, i1 = predict_topk(X, W, 5)
        s2, i2 = predict_topk_sharded(X, W, 5, mesh)
        assert jnp.allclose(s1, s2, atol=1e-5)
        assert (np.asarray(i1) == np.asarray(i2)).all()
        print("OK")
    """)
    assert "OK" in out


def test_dismec_head_label_sharded_loss_invariance():
    """The DiSMEC OvR head loss must be identical whether the head weight is
    replicated or label-sharded over `model` — the technique's key property
    (no logits collective needed, only scalar psum)."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core.head import ovr_squared_hinge_loss
        mesh = jax.make_mesh((1, 8), ("data", "model"))
        rng = np.random.default_rng(0)
        V, d, T = 64, 32, 24
        W = jnp.asarray(rng.normal(size=(V, d)) * 0.1, jnp.float32)
        feats = jnp.asarray(rng.normal(size=(T, d)), jnp.float32)
        tgt = jnp.asarray(rng.integers(0, V, (T,)), jnp.int32)
        base = ovr_squared_hinge_loss(W, feats, tgt)
        Wsh = jax.device_put(W, NamedSharding(mesh, P("model", None)))
        with mesh:
            sh = jax.jit(lambda w: ovr_squared_hinge_loss(w, feats, tgt))(Wsh)
        assert jnp.allclose(base, sh, rtol=1e-5), (base, sh)
        print("OK")
    """)
    assert "OK" in out


def test_balanced_sharding_solution_invariance():
    """Frequency-balanced label sharding (beyond paper) permutes labels
    across shards but must return the IDENTICAL model."""
    out = _run("""
        import jax, jax.numpy as jnp
        mesh = jax.make_mesh((1, 8), ("data", "model"))
        from repro.data.xmc import make_xmc_dataset
        from repro.core.dismec import DiSMECConfig, train_sharded
        d = make_xmc_dataset(n_train=200, n_test=50, n_features=512,
                             n_labels=64, beta=1.2, seed=2)
        X, Y = jnp.asarray(d.X_train), jnp.asarray(d.Y_train)
        cfg = DiSMECConfig(label_batch=64)
        m_plain = train_sharded(X, Y, cfg, mesh)
        m_bal = train_sharded(X, Y, cfg, mesh, balance=True)
        assert jnp.allclose(m_plain.W, m_bal.W, atol=1e-3)
        print("OK")
    """)
    assert "OK" in out


def test_dryrun_smoke_config_compiles_on_8dev_mesh():
    """A miniature of deliverable (e): lower+compile a smoke config train
    step on a (2, 4) mesh via the dryrun machinery."""
    out = _run("""
        import jax
        from repro import compat
        from repro.launch.dryrun import build_lowerable
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        fn, args = build_lowerable("qwen1.5-0.5b", "train_4k", mesh,
                                   smoke=True)
        with mesh:
            compiled = jax.jit(fn).lower(*args).compile()
        assert compat.cost_analysis(compiled)["flops"] > 0
        print("OK")
    """)
    assert "OK" in out
