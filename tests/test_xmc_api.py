"""The declarative XMC API (repro.xmc_api + repro.specs): spec round-trips,
the fit -> checkpoint -> serve session, manifest-embedded spec recovery,
warm-start semantics, and the solver-ops / backend registries."""

import json
import os

import numpy as np
import pytest

import jax.numpy as jnp

from repro.checkpoint.io import (BSR_MANIFEST, load_block_sparse,
                                 load_label_range_dense)
from repro.core.dismec import (DiSMECConfig, available_solver_ops,
                               register_solver_ops, unregister_solver_ops)
from repro.core import losses
from repro.serve import XMCEngine
from repro.serve.xmc import (available_backends, make_backend,
                             register_backend, unregister_backend)
from repro.specs import (DEFAULT_BUCKETS, ScheduleSpec, ServeSpec,
                         SolverSpec)
from repro.specs.serve import DEFAULT_BUCKETS as SPEC_BUCKETS
from repro.train.xmc import train_streaming
from repro.xmc_api import CheckpointHandle, XMCSpec, fit

L, D = 48, 512
CFG_EPS = 1e-2
SPEC = XMCSpec(solver=SolverSpec(eps=CFG_EPS),
               schedule=ScheduleSpec(label_batch=16, block_shape=(16, 16)))


@pytest.fixture(scope="module")
def xmc_data():
    from repro.data.xmc import make_xmc_dataset
    d = make_xmc_dataset(n_train=150, n_test=40, n_features=D, n_labels=L,
                         seed=0)
    return (jnp.asarray(d.X_train), jnp.asarray(d.Y_train),
            np.asarray(d.X_test, np.float32))


@pytest.fixture(scope="module")
def cold_ckpt(xmc_data, tmp_path_factory):
    """One spec-fit checkpoint shared by the read-only tests."""
    X, Y, _ = xmc_data
    out = str(tmp_path_factory.mktemp("xmc_api_cold"))
    handle = fit(X, Y, SPEC, out)
    assert handle.result.complete
    return out, handle


# -- spec serialization ------------------------------------------------------

def test_spec_json_roundtrip_exact():
    spec = XMCSpec(
        solver=SolverSpec(C=4.0, delta=0.002, eps=1e-3, max_newton=7,
                          max_cg=9, ops="pallas", pallas_interpret=True),
        schedule=ScheduleSpec(label_batch=96, block_shape=(32, 64),
                              mesh=(2, 4), label_axis="m", data_axis="d",
                              shard_data=True, balance=True, overlap=False,
                              max_inflight=5),
        serve=ServeSpec(backend="sharded", k=7, buckets=(2, 8, 32),
                        interpret=False, warmup=False))
    again = XMCSpec.from_json(spec.to_json())
    assert again == spec
    # Tuples must come back as tuples (frozen hash/eq correctness).
    assert isinstance(again.schedule.block_shape, tuple)
    assert isinstance(again.schedule.mesh, tuple)
    assert isinstance(again.serve.buckets, tuple)
    # Sub-specs round-trip standalone too.
    assert SolverSpec.from_json(spec.solver.to_json()) == spec.solver
    assert ScheduleSpec.from_dict(spec.schedule.to_dict()) == spec.schedule
    assert ServeSpec.from_dict(spec.serve.to_dict()) == spec.serve


def test_spec_rejects_unknown_fields():
    with pytest.raises(ValueError, match="does not know field"):
        SolverSpec.from_dict({"C": 1.0, "capacity": 3})
    with pytest.raises(ValueError, match="does not know field"):
        XMCSpec.from_dict({"solver": {}, "sched": {}})


def test_spec_validation():
    with pytest.raises(ValueError, match="C must be positive"):
        SolverSpec(C=-1.0).validate()
    with pytest.raises(ValueError, match="label_batch"):
        ScheduleSpec(label_batch=0).validate()
    with pytest.raises(ValueError, match="ascending"):
        ServeSpec(buckets=(8, 4)).validate()
    with pytest.raises(ValueError, match="k must be"):
        ServeSpec(k=0).validate()


def test_spec_buckets_mirror_serving_defaults():
    from repro.serve.batching import DEFAULT_BUCKETS as REAL
    assert tuple(SPEC_BUCKETS) == tuple(REAL) == tuple(DEFAULT_BUCKETS)


def test_schedule_normalization_rounds_up_with_warning():
    sch = ScheduleSpec(label_batch=20, block_shape=(16, 16))
    with pytest.warns(UserWarning, match="rounding up to 32"):
        n = sch.normalized()
    assert n.label_batch == 32 and n.block_shape == (16, 16)
    aligned = ScheduleSpec(label_batch=32, block_shape=(16, 16))
    assert aligned.normalized() is aligned               # no-op when aligned


# -- the session path --------------------------------------------------------

def test_fit_equivalent_to_legacy_stream(xmc_data, cold_ckpt, tmp_path):
    """Acceptance: fit() + CheckpointHandle.engine() produce a checkpoint
    and served top-k bit-identical to the train_streaming +
    XMCEngine.from_checkpoint flow (which is kept as a deprecation shim)."""
    X, Y, Xte = xmc_data
    cold_dir, handle = cold_ckpt
    legacy_dir = str(tmp_path / "legacy")
    with pytest.deprecated_call():
        res = train_streaming(X, Y, DiSMECConfig(label_batch=16, eps=CFG_EPS),
                              legacy_dir, block_shape=(16, 16))
    assert res.complete
    with open(os.path.join(cold_dir, BSR_MANIFEST)) as f:
        m_fit = json.load(f)
    with open(os.path.join(legacy_dir, BSR_MANIFEST)) as f:
        m_legacy = json.load(f)
    assert m_fit == m_legacy                     # spec fingerprint and all
    np.testing.assert_array_equal(
        np.asarray(load_block_sparse(cold_dir)[0].to_dense()),
        np.asarray(load_block_sparse(legacy_dir)[0].to_dense()))

    eng_spec = handle.engine(ServeSpec(backend="bsr", k=5, warmup=False))
    eng_legacy = XMCEngine.from_checkpoint(legacy_dir, backend="bsr", k=5,
                                           warmup=False)
    r_spec = eng_spec.serve([Xte[:24]])[0]
    r_legacy = eng_legacy.serve([Xte[:24]])[0]
    np.testing.assert_array_equal(r_spec.labels, r_legacy.labels)
    np.testing.assert_array_equal(r_spec.scores, r_legacy.scores)


def test_spec_recovered_from_manifest_alone(xmc_data, tmp_path):
    """The full spec (serve section included) must be recoverable from the
    checkpoint directory with no side channel."""
    X, Y, _ = xmc_data
    spec = XMCSpec(
        solver=SolverSpec(C=2.0, delta=0.02, eps=CFG_EPS, max_newton=30),
        schedule=ScheduleSpec(label_batch=16, block_shape=(16, 16),
                              balance=False, overlap=False),
        serve=ServeSpec(backend="dense", k=3, buckets=(4, 16),
                        warmup=False))
    out = str(tmp_path / "ck")
    fit(X, Y, spec, out)
    reopened = CheckpointHandle.open(out)
    # Recovery returns the canonical form: runtime buffering knobs
    # (overlap/max_inflight) are not checkpoint identity and reset to
    # defaults; everything else round-trips exactly.
    assert reopened.spec == spec.canonical()
    assert reopened.spec.solver == spec.solver
    assert reopened.spec.serve == spec.serve
    assert reopened.spec.schedule.overlap is True        # canonicalized
    assert reopened.complete
    assert reopened.index()["meta"]["xmc_spec"] == spec.canonical().to_dict()
    # And the recovered serve plan actually drives the engine.
    eng = reopened.engine()
    assert eng.backend.name == "dense" and eng.backend.k == 3
    assert tuple(eng.queue.buckets) == (4, 16)


def test_fit_resume_and_mismatch(xmc_data, tmp_path):
    X, Y, _ = xmc_data
    out = str(tmp_path / "ck")
    h1 = fit(X, Y, SPEC, out, max_batches=1)
    assert not h1.result.complete and h1.result.solved == [0]
    h2 = fit(X, Y, SPEC, out)                        # resume the rest
    assert h2.result.complete and h2.result.skipped == [0]
    other = SPEC.replace(solver=SPEC.solver.replace(C=5.0))
    with pytest.raises(ValueError, match="manifest disagrees"):
        fit(X, Y, other, out)
    # Flipping the solution-neutral double-buffering knobs must NOT block.
    h3 = fit(X, Y, SPEC.replace(
        schedule=SPEC.schedule.replace(overlap=False, max_inflight=1)), out)
    assert h3.result.complete and len(h3.result.skipped) == 3


def test_fit_normalizes_misaligned_label_batch(xmc_data, tmp_path):
    """Satellite: fit() rounds a misaligned label_batch up with a warning
    where XMCTrainJob.run (the raw engine) still raises."""
    X, Y, _ = xmc_data
    spec = XMCSpec(solver=SolverSpec(eps=CFG_EPS),
                   schedule=ScheduleSpec(label_batch=20,
                                         block_shape=(16, 16)))
    out = str(tmp_path / "ck")
    with pytest.warns(UserWarning, match="rounding up to 32"):
        handle = fit(X, Y, spec, out)
    assert handle.result.complete
    assert handle.spec.schedule.label_batch == 32
    with open(os.path.join(out, BSR_MANIFEST)) as f:
        assert json.load(f)["label_batch"] == 32
    assert CheckpointHandle.open(out).spec.schedule.label_batch == 32


# -- warm start --------------------------------------------------------------

def test_load_label_range_dense_matches_full(cold_ckpt):
    ckpt, _ = cold_ckpt
    full = np.asarray(load_block_sparse(ckpt)[0].to_dense())[:L, :D]
    np.testing.assert_array_equal(load_label_range_dense(ckpt, 0, L), full)
    np.testing.assert_array_equal(load_label_range_dense(ckpt, 10, 37),
                                  full[10:37])
    # Rows past the prior label count cold-start at zero.
    grown = load_label_range_dense(ckpt, L - 4, L + 4)
    np.testing.assert_array_equal(grown[:4], full[L - 4:])
    assert not grown[4:].any()


def test_warm_start_fixed_point_bit_identical(xmc_data, cold_ckpt, tmp_path):
    """Acceptance: warm-start fit is bit-identical to the cold fit when
    init_from points at a converged checkpoint of the same spec — the
    solver recognizes the fixed point (cold-anchored tolerance) and
    accepts every batch's W0 unchanged."""
    X, Y, _ = xmc_data
    cold_dir, _ = cold_ckpt
    warm_dir = str(tmp_path / "warm")
    fit(X, Y, SPEC, warm_dir, init_from=cold_dir)
    np.testing.assert_array_equal(
        np.asarray(load_block_sparse(warm_dir)[0].to_dense()),
        np.asarray(load_block_sparse(cold_dir)[0].to_dense()))
    # The manifest records the warm-start provenance in the fingerprint...
    with open(os.path.join(warm_dir, BSR_MANIFEST)) as f:
        m = json.load(f)
    assert m["solver"]["init"] is not None
    # ...so a resume seeded from a different source must refuse.
    with pytest.raises(ValueError, match="manifest disagrees"):
        fit(X, Y, SPEC, warm_dir, max_batches=1)


def test_warm_start_respun_spec(xmc_data, cold_ckpt, tmp_path):
    """The ROADMAP warm-start story: re-train under a CHANGED spec (new
    Delta) seeded from the converged weights; the session completes, the
    new spec rides the new manifest, and pruning actually tightened."""
    X, Y, _ = xmc_data
    cold_dir, cold_handle = cold_ckpt
    sharper = SPEC.replace(solver=SPEC.solver.replace(delta=0.05))
    out = str(tmp_path / "warm2")
    handle = fit(X, Y, sharper, out, init_from=cold_dir)
    assert handle.result.complete
    assert CheckpointHandle.open(out).spec == sharper
    W_cold = np.asarray(load_block_sparse(cold_dir)[0].to_dense())
    W_warm = np.asarray(load_block_sparse(out)[0].to_dense())
    assert np.count_nonzero(W_warm) < np.count_nonzero(W_cold)
    assert (np.abs(W_warm[W_warm != 0]) >= 0.05).all()


def test_warm_start_from_single_shard_source(xmc_data, cold_ckpt, tmp_path):
    """init_from also accepts the one-shot single-shard artifact
    (BlockSparseModel.save): the reader densifies it once and the
    fingerprint digests its packed values (no manifest to lean on)."""
    X, Y, _ = xmc_data
    cold_dir, _ = cold_ckpt
    model, _ = load_block_sparse(cold_dir)
    single = str(tmp_path / "single")
    model.save(single, meta={"n_labels": L, "n_features": D})
    warm_dir = str(tmp_path / "warm")
    handle = fit(X, Y, SPEC, warm_dir, init_from=single)
    assert handle.result.complete
    np.testing.assert_array_equal(
        np.asarray(load_block_sparse(warm_dir)[0].to_dense()),
        np.asarray(load_block_sparse(cold_dir)[0].to_dense()))
    with open(os.path.join(warm_dir, BSR_MANIFEST)) as f:
        init_fp = json.load(f)["solver"]["init"]
    assert init_fp["nnz"] > 0 and "abs_sum" in init_fp
    # A different prior model produces a different fingerprint, so a
    # resume cannot silently swap warm-start sources.
    other = str(tmp_path / "other")
    from repro.core.pruning import BlockSparseModel
    import dataclasses as dc
    dc.replace(model, blocks=model.blocks * 2.0).save(
        other, meta={"n_labels": L, "n_features": D})
    with pytest.raises(ValueError, match="manifest disagrees"):
        fit(X, Y, SPEC, warm_dir, init_from=other, max_batches=1)


def test_warm_start_feature_mismatch_raises(xmc_data, cold_ckpt, tmp_path):
    X, Y, _ = xmc_data
    cold_dir, _ = cold_ckpt
    X_wrong = jnp.concatenate(
        [X, jnp.zeros((X.shape[0], 32), X.dtype)], axis=1)
    with pytest.raises(ValueError, match="feature dim"):
        fit(X_wrong, Y, SPEC, str(tmp_path / "ck"), init_from=cold_dir)


# -- registries --------------------------------------------------------------

def test_backend_registry_plugin(xmc_data, cold_ckpt):
    """A plugin backend registered via the decorator is reachable through
    ServeSpec / the engine with no engine changes, and serves identically
    to the built-in it wraps."""
    _, _, Xte = xmc_data
    _, handle = cold_ckpt

    @register_backend("dense_copy")
    def _make_copy(bsr, k, *, n_labels, mesh, label_axis, interpret):
        return make_backend("dense", bsr, k, n_labels=n_labels)

    try:
        assert "dense_copy" in available_backends()
        with pytest.raises(ValueError, match="already registered"):
            register_backend("dense_copy")(lambda *a, **kw: None)
        eng = handle.engine(ServeSpec(backend="dense_copy", k=4,
                                      warmup=False))
        ref = handle.engine(ServeSpec(backend="dense", k=4, warmup=False))
        np.testing.assert_array_equal(eng.serve([Xte[:16]])[0].labels,
                                      ref.serve([Xte[:16]])[0].labels)
    finally:
        unregister_backend("dense_copy")
    assert "dense_copy" not in available_backends()
    with pytest.raises(ValueError, match="unknown backend 'dense_copy'"):
        handle.engine(ServeSpec(backend="dense_copy", warmup=False))


def test_solver_ops_registry_plugin(xmc_data):
    """A plugin solver-ops factory selected by SolverSpec(ops=...) solves
    through the same session path, bit-identical to the built-in it
    wraps."""
    X, Y, _ = xmc_data
    assert {"jnp", "pallas"} <= set(available_solver_ops())

    @register_solver_ops("jnp_copy")
    def _copy_ops(Xa, S, cfg):
        return (lambda W: losses.objective_grad_act(W, Xa, S, cfg.C),
                lambda V, act: losses.hessian_vp(V, Xa, act, cfg.C))

    try:
        from repro.xmc_api import job_from_spec
        base = XMCSpec(solver=SolverSpec(eps=CFG_EPS, max_newton=10),
                       schedule=ScheduleSpec(label_batch=L))
        plugin = base.replace(solver=base.solver.replace(ops="jnp_copy"))
        W_base = job_from_spec(base).run(X, Y).model.W
        W_plugin = job_from_spec(plugin).run(X, Y).model.W
        np.testing.assert_array_equal(np.asarray(W_base),
                                      np.asarray(W_plugin))
    finally:
        unregister_solver_ops("jnp_copy")
    with pytest.raises(ValueError, match="unknown solver ops"):
        job_from_spec(plugin).run(X, Y)
