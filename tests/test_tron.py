"""Batched TRON solver: convergence, optimality, and per-label independence."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import losses
from repro.core.tron import tron_solve


def _fns(X, S, C):
    """Margin-caching protocol pair: obj_grad -> (f, g, act), hvp(V, act)."""
    obj_grad = lambda W: losses.objective_grad_act(W, X, S, C)
    hvp = lambda V, act: losses.hessian_vp(V, X, act, C)
    return obj_grad, hvp


@pytest.fixture(scope="module")
def problem():
    rng = np.random.default_rng(0)
    L, N, D = 12, 96, 48
    X = jnp.asarray(rng.normal(size=(N, D)), jnp.float32)
    S = jnp.asarray(np.sign(rng.normal(size=(L, N))), jnp.float32)
    return X, S


def test_converges_to_tolerance(problem):
    X, S = problem
    C = 1.0
    obj_grad, hvp = _fns(X, S, C)
    L = S.shape[0]
    res = tron_solve(obj_grad, hvp, jnp.zeros((L, X.shape[1])), eps=0.01)
    assert bool(jnp.all(res.converged))
    # ||g|| <= eps * ||g0|| (liblinear stopping rule)
    _, g0, _ = obj_grad(jnp.zeros((L, X.shape[1])))
    gn0 = jnp.linalg.norm(g0, axis=-1)
    assert bool(jnp.all(res.gnorm <= 0.01 * gn0 + 1e-6))


def test_objective_decreases_from_zero(problem):
    X, S = problem
    obj_grad, hvp = _fns(X, S, 1.0)
    L = S.shape[0]
    W0 = jnp.zeros((L, X.shape[1]))
    f0, _, _ = obj_grad(W0)
    res = tron_solve(obj_grad, hvp, W0)
    assert bool(jnp.all(res.f <= f0))


def test_matches_lbfgs_quality(problem):
    """TRON minimum should (approximately) match a long gradient-descent run
    on the same strongly-convex objective."""
    X, S = problem
    C = 0.5
    obj_grad, hvp = _fns(X, S, C)
    L, D = S.shape[0], X.shape[1]
    res = tron_solve(obj_grad, hvp, jnp.zeros((L, D)), eps=1e-3,
                     max_newton=100)

    # Plain GD with a safe step (Lipschitz bound 2 + 2C sigma_max^2).
    sigma = float(jnp.linalg.norm(X, ord=2))
    step = 1.0 / (2.0 + 2.0 * C * sigma ** 2)
    W = jnp.zeros((L, D))
    for _ in range(3000):
        _, g, _ = obj_grad(W)
        W = W - step * g
    f_gd, _, _ = obj_grad(W)
    # TRON should be at least as good (tiny slack for fp).
    assert bool(jnp.all(res.f <= f_gd + 1e-2 * jnp.abs(f_gd)))


def test_label_independence(problem):
    """Solving labels jointly or separately must give identical solutions —
    the property the paper's double parallelization relies on."""
    X, S = problem
    obj_grad, hvp = _fns(X, S, 1.0)
    L, D = S.shape[0], X.shape[1]
    res_all = tron_solve(obj_grad, hvp, jnp.zeros((L, D)), eps=1e-3)

    # Solve the first 3 labels on their own.
    S3 = S[:3]
    og3, hv3 = _fns(X, S3, 1.0)
    res_3 = tron_solve(og3, hv3, jnp.zeros((3, D)), eps=1e-3)
    np.testing.assert_allclose(np.asarray(res_all.W[:3]),
                               np.asarray(res_3.W), rtol=1e-2, atol=1e-4)


def test_newton_counts_are_per_label():
    """n_newton must count each label's OWN live iterations (like n_cg), not
    the global outer-loop count: labels that converge early report strictly
    fewer Newton steps than the label that kept the loop running."""
    rng = np.random.default_rng(7)
    N, D = 96, 48
    X = np.asarray(rng.normal(size=(N, D)), np.float32)
    # Label 0 is sign(x_0): linearly separable, so the squared hinge keeps
    # pushing the weight out and TRON needs many trust-region steps. Labels
    # 1..5 are random signs: a crude fit satisfies eps=1e-3 much sooner.
    S = np.concatenate([np.sign(X[:, :1].T * 10),
                        np.sign(rng.normal(size=(5, N)))]).astype(np.float32)
    Xj, Sj = jnp.asarray(X), jnp.asarray(S)
    obj_grad, hvp = _fns(Xj, Sj, 1.0)
    res = tron_solve(obj_grad, hvp, jnp.zeros((6, D)), eps=1e-3)
    n = np.asarray(res.n_newton)
    assert bool(jnp.all(res.converged))
    # Early-converged labels report fewer steps (the old bug reported the
    # global loop count k for every label, even early-converged ones).
    assert n.min() < n.max(), n
    assert n.min() >= 1

    # Stronger: a label's count in the joint solve equals its count when
    # solved alone — the accounting is truly per label, not loop-global.
    for l in (1, 2):
        ogl, hvl = _fns(Xj, Sj[l:l + 1], 1.0)
        solo = tron_solve(ogl, hvl, jnp.zeros((1, D)), eps=1e-3)
        assert int(solo.n_newton[0]) == int(n[l]), (l, solo.n_newton, n)


def test_all_negative_label_goes_to_zero_weight():
    """A padding label (all signs -1) has optimum near w=0 when instances are
    mild: the solver must keep it tiny (this is the label-padding trick the
    batch scheduler uses to keep every batch the same shape, train/xmc.py)."""
    rng = np.random.default_rng(4)
    N, D = 64, 16
    X = jnp.asarray(rng.normal(size=(N, D)) * 0.01, jnp.float32)
    S = -jnp.ones((1, N), jnp.float32)
    obj_grad, hvp = _fns(X, S, 1.0)
    res = tron_solve(obj_grad, hvp, jnp.zeros((1, D)))
    assert float(jnp.linalg.norm(res.W)) < 0.5
