#!/usr/bin/env python3
"""Docs gate: fail on broken intra-repo links in README.md and docs/*.md.

Checks every markdown link/image target that is not an external URL or a
pure in-page anchor: the referenced path (resolved relative to the file
that links it, with any #fragment stripped) must exist in the repo.
External links are deliberately NOT fetched — this gate must work
offline and never flake on the network.

Usage: python tools/check_docs.py        (run by tools/verify.sh)
"""

from __future__ import annotations

import glob
import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# [text](target) and ![alt](target); target ends at the first unescaped
# ')' — markdown titles ("... )" syntax) are not used in this repo.
LINK = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
EXTERNAL = ("http://", "https://", "mailto:")


def doc_files() -> list[str]:
    return [os.path.join(REPO, "README.md")] + sorted(
        glob.glob(os.path.join(REPO, "docs", "*.md")))


def strip_code(text: str) -> str:
    """Drop fenced code blocks and inline code spans: example snippets are
    not link promises."""
    text = re.sub(r"```.*?```", "", text, flags=re.S)
    return re.sub(r"`[^`]*`", "", text)


def check(path: str) -> list[str]:
    with open(path) as f:
        text = strip_code(f.read())
    errors = []
    for target in LINK.findall(text):
        if target.startswith(EXTERNAL) or target.startswith("#"):
            continue
        rel = target.split("#", 1)[0]
        resolved = os.path.normpath(os.path.join(os.path.dirname(path), rel))
        if not os.path.exists(resolved):
            errors.append(f"{os.path.relpath(path, REPO)}: broken link "
                          f"'{target}' -> {os.path.relpath(resolved, REPO)}")
    return errors


def main() -> int:
    files = doc_files()
    missing_docs = [f for f in (os.path.join(REPO, "README.md"),)
                    if not os.path.exists(f)]
    if missing_docs or not any("docs" in f for f in files):
        print("check_docs: README.md and docs/*.md must exist")
        return 1
    errors = [e for f in files for e in check(f)]
    for e in errors:
        print(f"check_docs: {e}")
    if errors:
        return 1
    n_links = sum(
        1 for f in files for t in LINK.findall(strip_code(open(f).read()))
        if not t.startswith(EXTERNAL) and not t.startswith("#"))
    print(f"check_docs: OK ({len(files)} files, {n_links} intra-repo links)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
