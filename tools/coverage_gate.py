#!/usr/bin/env python
"""Stdlib line-coverage floor for the serving layer (no `coverage` module
in the CI image, and installing one is off the table).

Runs the serving-layer test files in-process under a `sys.settrace` line
tracer restricted to the target modules, computes executed / executable
lines per module (executable = `dis.findlinestarts` over the compiled
module's code objects, recursively), and fails if any module drops below
its ratcheted floor.

The floors are deliberately a few points under today's measured coverage:
the gate exists to catch a serving-path regression (a new backend branch
or artifact kind the test matrix no longer reaches), not to force 100%.
Raise a floor when coverage durably improves; never lower one to make a
PR pass — add the missing test instead.

  PYTHONPATH=src python tools/coverage_gate.py            # gate
  PYTHONPATH=src python tools/coverage_gate.py --report   # per-file lines
"""

from __future__ import annotations

import argparse
import dis
import os
import sys
import threading
import types

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

#: module path (repo-relative) -> minimum covered/executable line fraction.
FLOORS = {
    "src/repro/serve/shortlist.py": 0.90,
    "src/repro/serve/xmc.py": 0.85,
    "src/repro/kernels/bsr_predict/ops.py": 0.80,
}

#: The serving-layer suites the floor is measured over — the equivalence
#: matrix + the shortlist/property/int8 suites, which together are meant
#: to reach every backend kind, artifact generation, and dtype path.
TEST_FILES = [
    "tests/test_backend_matrix.py",
    "tests/test_shortlist.py",
    "tests/test_properties.py",
    "tests/test_int8_serving.py",
]


def executable_lines(path: str) -> set[int]:
    """All line numbers the compiled module can start executing — the
    denominator `coverage.py` would report (module, class and def
    statements included; blank lines, comments and docstring bodies not)."""
    with open(path, encoding="utf-8") as f:
        code = compile(f.read(), path, "exec")
    lines: set[int] = set()
    stack = [code]
    while stack:
        c = stack.pop()
        lines.update(ln for _, ln in dis.findlinestarts(c) if ln is not None)
        stack.extend(k for k in c.co_consts if isinstance(k, types.CodeType))
    return lines


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--report", action="store_true",
                    help="also print the uncovered line numbers per file")
    args = ap.parse_args()

    os.chdir(REPO)
    targets = {os.path.abspath(p): p for p in FLOORS}
    hit: dict[str, set[int]] = {p: set() for p in FLOORS}

    def tracer(frame, event, arg):
        fn = frame.f_code.co_filename
        if fn not in targets:
            return None                       # never trace foreign frames
        rel = targets[fn]

        def local(frame, event, arg):
            if event == "line":
                hit[rel].add(frame.f_lineno)
            return local

        if event == "call":
            hit[rel].add(frame.f_lineno)
            return local
        return None

    import pytest

    threading.settrace(tracer)                # serving tests spawn threads
    sys.settrace(tracer)
    try:
        rc = pytest.main(["-x", "-q", "--no-header", *TEST_FILES])
    finally:
        sys.settrace(None)
        threading.settrace(None)              # type: ignore[arg-type]
    if rc != 0:
        print(f"coverage_gate: test run failed (exit {rc}); "
              "coverage not evaluated", file=sys.stderr)
        return int(rc)

    failed = False
    print(f"\n{'module':44s} {'lines':>11s} {'cover':>7s} {'floor':>7s}")
    for rel, floor in FLOORS.items():
        want = executable_lines(rel)
        got = hit[rel] & want
        frac = len(got) / len(want)
        ok = frac >= floor
        failed |= not ok
        print(f"{rel:44s} {len(got):5d}/{len(want):5d} {frac:7.3f} "
              f"{floor:7.2f}  {'ok' if ok else 'BELOW FLOOR'}")
        if args.report and want - got:
            missing = sorted(want - got)
            print(f"  uncovered: {missing}")
    if failed:
        print("\ncoverage_gate: FAILED — a serving path lost its test "
              "coverage; add a test (do not lower the floor)",
              file=sys.stderr)
        return 1
    print("\ncoverage_gate: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
