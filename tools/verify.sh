#!/usr/bin/env bash
# Repo verification gate: tier-1 tests + benchmark-entrypoint smoke.
#
#   tools/verify.sh            # full tier-1 pytest + benchmark smoke
#   tools/verify.sh --fast     # tier-1 pytest only
#
# The smoke leg runs `benchmarks.run --smoke` (train_pipeline +
# tron_hotpath + serve_latency on tiny shapes) so the benchmark
# entrypoints cannot silently rot: they import, run end-to-end, and keep
# their bit-identity assertions live on every change.

set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 pytest =="
python -m pytest -x -q

if [[ "${1:-}" != "--fast" ]]; then
    echo
    echo "== benchmark smoke (train_pipeline + tron_hotpath + serve_latency) =="
    python -m benchmarks.run --smoke
fi

echo
echo "verify.sh: OK"
