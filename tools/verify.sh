#!/usr/bin/env bash
# Repo verification gate: tier-1 tests + benchmark-entrypoint smoke + docs.
#
#   tools/verify.sh            # tier-1 pytest + benchmark smoke + docs gate
#   tools/verify.sh --fast     # tier-1 pytest only
#
# The smoke leg runs `benchmarks.run --smoke` (train_pipeline +
# tron_hotpath + serve_latency + lifecycle_sweep on tiny shapes) so the
# benchmark entrypoints cannot silently rot: they import, run end-to-end,
# and keep their bit-identity assertions live on every change.
# serve_latency's smoke includes the open-loop Poisson server gates
# (deadline launch beats drain-on-full on p99; admission control sheds
# overload with bounded queue wait), the shortlist gate (candidate
# fraction < 25% at recall@5 >= 0.95), the int8 serving gates (quantized
# payload <= 0.55x fp32, top-5 agreement >= 0.99 on the exhaustive AND
# shortlist-composed paths), and the zero-downtime refresh gate: a hot
# swap under open-loop Poisson load drops nothing (every accepted request
# resolves, old model answers before the flip, new model after) and the
# swap-window p99 stays <= 2x the steady-state p99, and the coarse-stage
# gates: the learned one-vs-rest coarse stage reaches recall@5 >= 0.95 at
# a STRICTLY smaller candidate width than the centroid baseline, per-query
# ragged selection is bit-exact vs exhaustive at B = n_row_blocks, and
# legacy / v1-artifact checkpoints keep serving via fallback.
# lifecycle_sweep's
# smoke gates the warm-start sweep driver: the unchanged-spec arm is
# bit-identical to its warm-start source, model size is monotone in
# Delta, and the size-budget winner policy picks a feasible arm.
#
# The coverage leg (tools/coverage_gate.py, stdlib settrace — the image
# has no coverage module) re-runs the serving-layer suites under a line
# tracer and enforces ratcheted per-module floors on serve/shortlist.py,
# serve/xmc.py and kernels/bsr_predict/ops.py, so a new backend branch or
# artifact kind cannot silently land untested.
#
# The docs gate keeps the documentation surface honest: every intra-repo
# link in README.md and docs/*.md must resolve (tools/check_docs.py), and
# the README's quickstart path must actually run (examples/quickstart.py
# --smoke exercises spec -> fit -> reopen -> serve -> warm-start
# end-to-end on tiny shapes).

set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 pytest =="
python -m pytest -x -q

if [[ "${1:-}" != "--fast" ]]; then
    echo
    echo "== benchmark smoke (train_pipeline + tron_hotpath + serve_latency + lifecycle_sweep) =="
    python -m benchmarks.run --smoke

    echo
    echo "== serving-layer coverage floor =="
    python tools/coverage_gate.py

    echo
    echo "== docs gate (link check + quickstart smoke) =="
    python tools/check_docs.py
    python examples/quickstart.py --smoke
fi

echo
echo "verify.sh: OK"
