"""Shared transformer layers: norms, RoPE variants, GQA attention, MLP.

Pure functional: params are nested dicts of jax.Arrays; every function takes
(cfg, params, x, ...). Sharding is induced by pjit in_shardings on params
(see models/sharding.py) plus a few activation constraints; GSPMD propagates
the rest.

Attention variants required by the assigned architectures:
  * GQA with arbitrary kv_heads (all ten archs)
  * RoPE on a fraction of head dims (chatglm3 "RoPE 2d": fraction = 0.5)
  * qk RMS-norm per head (qwen3)
  * QKV bias (qwen1.5)
  * sliding-window causal masks (mixtral, hymba, and the --swa long-context
    variant for dense archs, DESIGN.md §Arch-applicability)
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig

Array = jax.Array


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm(x: Array, scale: Array, eps: float = 1e-6) -> Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * scale.astype(jnp.float32)).astype(dt)


def layernorm(x: Array, scale: Array, bias: Array, eps: float = 1e-5) -> Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    out = (x - mu) * jax.lax.rsqrt(var + eps) * scale + bias
    return out.astype(dt)


def apply_norm(cfg: ArchConfig, p: dict, x: Array) -> Array:
    if cfg.norm == "layernorm":
        return layernorm(x, p["scale"], p["bias"])
    return rmsnorm(x, p["scale"])


def init_norm(cfg: ArchConfig, d: int) -> dict:
    p = {"scale": jnp.ones((d,), jnp.float32)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(cfg: ArchConfig) -> Array:
    """Inverse frequencies for the rotary fraction of head_dim."""
    rot = int(cfg.head_dim * cfg.rope_fraction)
    rot -= rot % 2
    return 1.0 / (cfg.rope_theta ** (jnp.arange(0, rot, 2, jnp.float32) / rot))


def apply_rope(cfg: ArchConfig, x: Array, positions: Array) -> Array:
    """x: (B, T, H, hd); positions: (B, T) int32. Rotates the first
    rope_fraction of head dims (chatglm3 rotates half), passes the rest."""
    rot = int(cfg.head_dim * cfg.rope_fraction)
    rot -= rot % 2
    if rot == 0:
        return x
    inv = rope_freqs(cfg)                                   # (rot/2,)
    ang = positions[..., None].astype(jnp.float32) * inv    # (B, T, rot/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    x1, x2 = x_rot[..., ::2], x_rot[..., 1::2]
    o1 = x1 * cos - x2 * sin
    o2 = x2 * cos + x1 * sin
    out = jnp.stack([o1, o2], axis=-1).reshape(x_rot.shape)
    return jnp.concatenate([out, x_pass], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

def init_attention(cfg: ArchConfig, rng: Array, dtype) -> dict:
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    k = jax.random.split(rng, 4)
    s = d ** -0.5
    p = {
        "wq": (jax.random.normal(k[0], (d, qd)) * s).astype(dtype),
        "wk": (jax.random.normal(k[1], (d, kvd)) * s).astype(dtype),
        "wv": (jax.random.normal(k[2], (d, kvd)) * s).astype(dtype),
        "wo": (jax.random.normal(k[3], (qd, d)) * s).astype(dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((qd,), dtype)
        p["bk"] = jnp.zeros((kvd,), dtype)
        p["bv"] = jnp.zeros((kvd,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((cfg.head_dim,), jnp.float32)
        p["k_norm"] = jnp.ones((cfg.head_dim,), jnp.float32)
    return p


def _qkv(cfg: ArchConfig, p: dict, x: Array, positions: Array):
    B, T, _ = x.shape
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, T, cfg.n_heads, cfg.head_dim)
    k = k.reshape(B, T, cfg.n_kv_heads, cfg.head_dim)
    v = v.reshape(B, T, cfg.n_kv_heads, cfg.head_dim)
    if cfg.qk_norm:                       # qwen3: per-head RMS on q and k
        q = rmsnorm(q, p["q_norm"])
        k = rmsnorm(k, p["k_norm"])
    q = apply_rope(cfg, q, positions)
    k = apply_rope(cfg, k, positions)
    return q, k, v


def _sdpa(cfg: ArchConfig, q: Array, k: Array, v: Array,
          mask: Optional[Array]) -> Array:
    """q (B,Tq,H,hd), k/v (B,Tk,KV,hd) -> (B,Tq,H*hd). GQA via head groups."""
    B, Tq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    q = q.reshape(B, Tq, KV, G, hd)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", q, k,
                        preferred_element_type=jnp.float32)
    scores = scores / math.sqrt(hd)
    if cfg.attn_logit_softcap:
        c = cfg.attn_logit_softcap
        scores = c * jnp.tanh(scores / c)
    if mask is not None:
        scores = jnp.where(mask, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqs,bskh->bqkgh", w, v)
    return out.reshape(B, Tq, H * hd)


def largest_divisor_leq(n: int, target: int) -> int:
    """Largest divisor of n that is <= target (chunk sizes must tile T —
    e.g. VLM prefill T = 32768 + 256 patches = 33024 tiles at 256)."""
    for c in range(min(target, n), 0, -1):
        if n % c == 0:
            return c
    return 1


def blockwise_attention(cfg: ArchConfig, q: Array, k: Array, v: Array,
                        *, window: Optional[int] = None,
                        is_causal: bool = True,
                        q_chunk: int = 512, kv_chunk: int = 1024) -> Array:
    """Memory-bounded attention with online softmax (FlashAttention
    recurrence in XLA ops): never materializes the (Tq, Tk) score matrix —
    the per-step working set is (B, H, q_chunk, kv_chunk). Mandatory for the
    32k/500k shapes where dense scores are O(100 GB) per device.

    q (B,Tq,H,hd), k/v (B,Tk,KV,hd) -> (B,Tq,H*hd)
    """
    B, Tq, H, hd = q.shape
    Tk, KV = k.shape[1], k.shape[2]
    G = H // KV
    q_chunk = largest_divisor_leq(Tq, q_chunk)
    kv_chunk = largest_divisor_leq(Tk, kv_chunk)
    nq, nk = Tq // q_chunk, Tk // kv_chunk
    scale = 1.0 / math.sqrt(hd)

    qs = jnp.moveaxis(q.reshape(B, nq, q_chunk, KV, G, hd), 1, 0)
    ks = jnp.moveaxis(k.reshape(B, nk, kv_chunk, KV, hd), 1, 0)
    vs = jnp.moveaxis(v.reshape(B, nk, kv_chunk, KV, hd), 1, 0)

    def q_step(carry, qi_qx):
        qi, qx = qi_qx                                 # qx (B,qc,KV,G,hd)
        q_pos = qi * q_chunk + jnp.arange(q_chunk)

        def kv_step(state, ki_kxvx):
            ki, kx, vx = ki_kxvx
            m, l, acc = state
            k_pos = ki * kv_chunk + jnp.arange(kv_chunk)
            s = jnp.einsum("bqkgh,bskh->bkgqs", qx, kx,
                           preferred_element_type=jnp.float32) * scale
            if cfg.attn_logit_softcap:
                c = cfg.attn_logit_softcap
                s = c * jnp.tanh(s / c)
            if is_causal:
                msk = k_pos[None, :] <= q_pos[:, None]
                if window is not None:
                    msk &= k_pos[None, :] > q_pos[:, None] - window
                s = jnp.where(msk[None, None, None], s, -1e30)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgqs,bskh->bkgqh", p.astype(vx.dtype), vx
            ).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, KV, G, q_chunk), -1e30, jnp.float32)
        l0 = jnp.zeros((B, KV, G, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, KV, G, q_chunk, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (jnp.arange(nk), ks, vs))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        out = jnp.moveaxis(out, 3, 1)                  # (B,qc,KV,G,hd)
        return carry, out.reshape(B, q_chunk, H * hd).astype(q.dtype)

    _, outs = jax.lax.scan(q_step, None, (jnp.arange(nq), qs))
    return jnp.moveaxis(outs, 0, 1).reshape(B, Tq, H * hd)


def banded_attention(cfg: ArchConfig, q: Array, k: Array, v: Array,
                     *, window: int, q_chunk: int = 512) -> Array:
    """Sliding-window attention that SKIPS out-of-window KV blocks.

    blockwise_attention visits every (q_chunk, kv_chunk) tile and relies on
    the mask, so a w=1024 window over T=32k still does O(T^2) MXU work.
    Here the window is STATIC: each q chunk dynamic-slices only the KV band
    [q_end - span, q_end) with span = window + q_chunk, so FLOPs drop from
    O(T^2) to O(T * (window + q_chunk)) — 13x for hymba prefill_32k
    (EXPERIMENTS.md SSPerf hymba iteration 2).

    q (B,Tq,H,hd), k/v (B,Tk,KV,hd) -> (B,Tq,H*hd). Causal by construction.
    """
    B, Tq, H, hd = q.shape
    Tk, KV = k.shape[1], k.shape[2]
    G = H // KV
    qc = largest_divisor_leq(Tq, q_chunk)
    span = min(Tk, window + qc)
    nq = Tq // qc
    scale = 1.0 / math.sqrt(hd)
    qs = jnp.moveaxis(q.reshape(B, nq, qc, KV, G, hd), 1, 0)

    def q_step(_, qi_qx):
        qi, qx = qi_qx                                  # qx (B,qc,KV,G,hd)
        q_end = (qi + 1) * qc
        start = jnp.clip(q_end - span, 0, Tk - span)
        kb = jax.lax.dynamic_slice_in_dim(k, start, span, axis=1)
        vb = jax.lax.dynamic_slice_in_dim(v, start, span, axis=1)
        q_pos = qi * qc + jnp.arange(qc)
        k_pos = start + jnp.arange(span)
        s = jnp.einsum("bqkgh,bskh->bkgqs", qx, kb,
                       preferred_element_type=jnp.float32) * scale
        if cfg.attn_logit_softcap:
            c = cfg.attn_logit_softcap
            s = c * jnp.tanh(s / c)
        msk = (k_pos[None, :] <= q_pos[:, None]) & \
              (k_pos[None, :] > q_pos[:, None] - window)
        s = jnp.where(msk[None, None, None], s, -1e30)
        w = jax.nn.softmax(s, axis=-1).astype(vb.dtype)
        out = jnp.einsum("bkgqs,bskh->bkgqh", w, vb)
        out = jnp.moveaxis(out, 3, 1)                   # (B,qc,KV,G,hd)
        return None, out.reshape(B, qc, H * hd).astype(q.dtype)

    _, outs = jax.lax.scan(q_step, None, (jnp.arange(nq), qs))
    return jnp.moveaxis(outs, 0, 1).reshape(B, Tq, H * hd)


def causal_mask(Tq: int, Tk: int, *, q_offset: int = 0,
                window: Optional[int] = None) -> Array:
    """(1,1,1,Tq,Tk) boolean mask; window => sliding-window causal."""
    qi = jnp.arange(Tq)[:, None] + q_offset
    ki = jnp.arange(Tk)[None, :]
    m = ki <= qi
    if window is not None:
        m = m & (ki > qi - window)
    return m[None, None, None, :, :]


DENSE_ATTN_MAX_T = 2048     # above this, scores would dominate HBM: go blockwise


def attention(cfg: ArchConfig, p: dict, x: Array, positions: Array,
              *, window: Optional[int] = None, is_causal: bool = True) -> Array:
    """Full-sequence attention (train / prefill). Dense scores for short T,
    online-softmax blockwise above DENSE_ATTN_MAX_T."""
    B, T, _ = x.shape
    q, k, v = _qkv(cfg, p, x, positions)
    if T > DENSE_ATTN_MAX_T:
        out = blockwise_attention(cfg, q, k, v, window=window,
                                  is_causal=is_causal)
    else:
        mask = causal_mask(T, T, window=window) if is_causal else None
        out = _sdpa(cfg, q, k, v, mask)
    return out @ p["wo"]


def cross_attention(cfg: ArchConfig, p: dict, x: Array, memory_kv: tuple,
                    ) -> Array:
    """Decoder cross-attention against precomputed encoder K/V."""
    B, T, _ = x.shape
    q = (x @ p["wq"]).reshape(B, T, cfg.n_heads, cfg.head_dim)
    k, v = memory_kv
    if T > DENSE_ATTN_MAX_T:
        out = blockwise_attention(cfg, q, k, v, is_causal=False)
    else:
        out = _sdpa(cfg, q, k, v, None)
    return out @ p["wo"]


def attention_decode(cfg: ArchConfig, p: dict, x: Array, positions: Array,
                     k_cache: Array, v_cache: Array, cache_index: Array,
                     *, window: Optional[int] = None):
    """One-token decode: x (B, 1, d) against cache (B, T_max, KV, hd).

    Sliding-window caches are ring buffers (T_max == window); the mask then
    keys off absolute positions stored alongside. For simplicity we store
    absolute position per cache slot implicitly: slot = pos % T_max, and
    validity = slot_pos <= current pos (& > pos - window for SWA).
    """
    B, one, _ = x.shape
    T_max = k_cache.shape[1]
    q, k, v = _qkv(cfg, p, x, positions)
    slot = (cache_index % T_max) if window is not None else cache_index
    k_cache = jax.lax.dynamic_update_slice(k_cache, k.astype(k_cache.dtype),
                                           (0, slot, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(v_cache, v.astype(v_cache.dtype),
                                           (0, slot, 0, 0))
    pos_now = cache_index                      # scalar absolute position
    slots = jnp.arange(T_max)
    if window is not None:
        # Ring buffer: slot s holds absolute position p_s with p_s % T_max == s
        # and p_s in (pos_now - window, pos_now]; valid iff it has been written.
        age = (pos_now - slots) % T_max        # tokens ago, in [0, T_max)
        abs_pos = pos_now - age
        valid = (abs_pos >= 0) & (abs_pos > pos_now - (window or T_max)) | (slots == slot)
        valid = valid & (abs_pos <= pos_now)
    else:
        valid = slots <= pos_now
    mask = valid[None, None, None, None, :]    # (1,1,1,1,T_max)
    out = _sdpa(cfg, q, k_cache, v_cache, mask)
    return out @ p["wo"], k_cache, v_cache


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def init_mlp(rng: Array, d: int, f: int, dtype, act: str = "silu") -> dict:
    k = jax.random.split(rng, 3)
    s = d ** -0.5
    p = {"w1": (jax.random.normal(k[0], (d, f)) * s).astype(dtype),
         "w2": (jax.random.normal(k[1], (f, d)) * (f ** -0.5)).astype(dtype)}
    if act == "silu":                          # SwiGLU needs the gate proj
        p["w3"] = (jax.random.normal(k[2], (d, f)) * s).astype(dtype)
    return p


def mlp(p: dict, x: Array, act: str = "silu") -> Array:
    if act == "silu":
        h = jax.nn.silu(x @ p["w1"]) * (x @ p["w3"])
    else:
        h = jax.nn.gelu(x @ p["w1"])
    return h @ p["w2"]
