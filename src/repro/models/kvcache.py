"""Serving caches: dense KV, ring-buffer sliding-window KV, SSM states.

Cache layout is *stacked over layers* — (n_layers, B, T_max, KV, hd) — so the
decode layer scan (models/transformer.py) carries one pytree and the whole
cache gets one sharding spec:

  dense decode      : batch over (pod, data), cache length over `model`
                      (sequence-sharded decode — kv_heads of the assigned
                      archs, 2..16, do not divide a 16-way model axis, but
                      32k/500k cache lengths do; softmax/psum over the length
                      shards is inserted by GSPMD)
  long_500k (B = 1) : cache length over (data, model) — 512-way sequence
                      sharding, the only axis with room
  SWA layers        : ring buffer of T_max == window slots, replicated length
  SSM layers        : O(1) state pytrees (models/ssm.py NamedTuples)
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig

Array = jax.Array


@dataclasses.dataclass
class CacheSpec:
    """Static description used by init_cache and input_specs."""
    kind: str                  # "attn" | "swa" | "mlstm" | "slstm" | "mamba" | "hybrid"
    t_max: int                 # slots for attention-style caches


def attn_cache_shape(cfg: ArchConfig, n_layers: int, B: int, t_max: int):
    return (n_layers, B, t_max, cfg.n_kv_heads, cfg.head_dim)


def init_attn_cache(cfg: ArchConfig, n_layers: int, B: int, t_max: int,
                    dtype=jnp.bfloat16) -> dict:
    shape = attn_cache_shape(cfg, n_layers, B, t_max)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def cache_t_max(cfg: ArchConfig, seq_len: int, *, use_swa: bool) -> int:
    """Ring buffers allocate only `window` slots."""
    if use_swa and cfg.sliding_window:
        return min(cfg.sliding_window, seq_len)
    return seq_len
