"""Mixture-of-Experts FFN: top-k routing, shared experts, expert parallelism.

Covers the two assigned MoE architectures:
  * qwen2-moe-a2.7b — 60 routed experts top-4 + 4 shared experts
    (per-expert d_ff 1408, shared 5632) [hf:Qwen/Qwen1.5-MoE-A2.7B]
  * mixtral-8x22b   — 8 routed experts top-2, SwiGLU d_ff 16384
    [arXiv:2401.04088]

Dispatch is sort-based (argsort by expert id + capacity clipping), not
one-hot-einsum: the GShard dispatch tensor is O(S^2 k) per group and blows
HBM at 4k x 256 shapes, while the sort path is O(n k) bookkeeping around
dense (E, C, d) batched matmuls — the TPU-friendly shape.

Distribution (DESIGN.md §5): this layer is an explicit shard_map island
inside the pjit graph. Tokens stay on their (pod, data) shard — dispatch is
LOCAL, so there is no token all-to-all at all; experts are *tensor*-parallel
(d_ff sharded over `model`, since neither 60 nor 8 divides a 16-way mesh)
with a single psum per layer. The router aux (load-balance) loss follows
Switch: E * sum_e f_e * p_e.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.compat import shard_map
from repro.configs.base import ArchConfig

Array = jax.Array


def init_moe(cfg: ArchConfig, rng: Array, dtype) -> dict:
    d = cfg.d_model
    fe = cfg.moe_d_ff or cfg.d_ff
    E = cfg.n_experts
    k = jax.random.split(rng, 5)
    s = d ** -0.5
    p = {
        "router": (jax.random.normal(k[0], (d, E)) * s).astype(jnp.float32),
        "w1": (jax.random.normal(k[1], (E, d, fe)) * s).astype(dtype),
        "w3": (jax.random.normal(k[2], (E, d, fe)) * s).astype(dtype),
        "w2": (jax.random.normal(k[3], (E, fe, d)) * (fe ** -0.5)).astype(dtype),
    }
    if cfg.n_shared_experts:
        fs = cfg.shared_d_ff or (fe * cfg.n_shared_experts)
        kk = jax.random.split(k[4], 4)
        p["shared"] = {
            "w1": (jax.random.normal(kk[0], (d, fs)) * s).astype(dtype),
            "w3": (jax.random.normal(kk[1], (d, fs)) * s).astype(dtype),
            "w2": (jax.random.normal(kk[2], (fs, d)) * (fs ** -0.5)).astype(dtype),
            # qwen2-moe gates the shared expert output per token
            "gate": (jax.random.normal(kk[3], (d, 1)) * s).astype(dtype),
        }
    return p


def _dispatch_combine(xf: Array, probs: Array, top_k: int, capacity: int,
                      w1: Array, w3: Array, w2: Array,
                      model_axis: Optional[str]) -> Array:
    """Sort-based dispatch -> batched expert FFN -> weighted combine.

    xf (n, d) local tokens, probs (n, E) router probabilities.
    w1/w3 (E, d, f_shard), w2 (E, f_shard, d); psum over model_axis if given.
    """
    n, d = xf.shape
    E = probs.shape[1]
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)          # (n, k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)      # renormalize

    e_flat = gate_idx.reshape(-1)                              # (n*k,)
    w_flat = gate_vals.reshape(-1)
    tok_flat = jnp.arange(n * top_k, dtype=jnp.int32) // top_k

    order = jnp.argsort(e_flat)                                # stable
    e_s, tok_s, w_s = e_flat[order], tok_flat[order], w_flat[order]

    # Position of each routed token within its expert's capacity buffer.
    counts = jnp.zeros((E,), jnp.int32).at[e_s].add(1)
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                              jnp.cumsum(counts)[:-1]])
    pos = jnp.arange(n * top_k, dtype=jnp.int32) - starts[e_s]
    keep = pos < capacity
    dst = jnp.where(keep, e_s * capacity + pos, E * capacity)  # overflow slot

    buf = jnp.zeros((E * capacity + 1, d), xf.dtype).at[dst].set(xf[tok_s])
    buf = buf[:-1].reshape(E, capacity, d)

    h = jnp.einsum("ecd,edf->ecf", buf, w1,
                   preferred_element_type=jnp.float32)
    g = jnp.einsum("ecd,edf->ecf", buf, w3,
                   preferred_element_type=jnp.float32)
    h = (jax.nn.silu(h) * g).astype(xf.dtype)
    y = jnp.einsum("ecf,efd->ecd", h, w2,
                   preferred_element_type=jnp.float32).astype(xf.dtype)

    # Combine BEFORE the TP psum: combine is linear in y, so
    # psum(combine(y)) == combine(psum(y)) — but the psum operand shrinks
    # from the padded capacity buffer (E, C, d) = k*capacity_factor x token
    # bytes to the token output (n, d). 2.5x less AR traffic for mixtral
    # (k=2, cf=1.25) — EXPERIMENTS.md SSPerf mixtral iteration m1.
    y_flat = jnp.concatenate(
        [y.reshape(E * capacity, d), jnp.zeros((1, d), y.dtype)])
    contrib = y_flat[jnp.where(keep, dst, E * capacity)] * w_s[:, None]
    out = jnp.zeros((n, d), xf.dtype).at[tok_s].add(contrib)
    if model_axis is not None:
        out = jax.lax.psum(out, model_axis)                    # f was sharded
    return out


def _shared_expert(p: dict, xf: Array,
                   model_axis: Optional[str] = None) -> Array:
    sh = p["shared"]
    h = jax.nn.silu(xf @ sh["w1"]) * (xf @ sh["w3"])   # fs possibly sharded
    y = h @ sh["w2"]
    if model_axis is not None:
        y = jax.lax.psum(y, model_axis)                # fs was sharded
    gate = jax.nn.sigmoid((xf @ sh["gate"]).astype(jnp.float32)).astype(y.dtype)
    return y * gate


def moe_ffn_local(cfg: ArchConfig, p: dict, xf: Array,
                  model_axis: Optional[str] = None,
                  w1=None, w3=None, w2=None) -> tuple[Array, Array]:
    """MoE FFN on local tokens xf (n, d). Returns (out, aux_loss)."""
    E, k = cfg.n_experts, cfg.moe_top_k
    n = xf.shape[0]
    capacity = max(int(n * k / E * cfg.capacity_factor), 4)
    logits = (xf @ p["router"].astype(xf.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    out = _dispatch_combine(xf, probs, k, capacity,
                            w1 if w1 is not None else p["w1"],
                            w3 if w3 is not None else p["w3"],
                            w2 if w2 is not None else p["w2"],
                            model_axis)
    if cfg.n_shared_experts:
        out = out + _shared_expert(p, xf, model_axis)
    # Switch-style load-balance loss: E * sum_e (token frac)_e * (prob mass)_e
    _, top1 = jax.lax.top_k(probs, 1)
    f_e = jnp.mean(jax.nn.one_hot(top1[:, 0], E, dtype=jnp.float32), axis=0)
    p_e = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(f_e * p_e)
    return out, aux


def moe_ffn(cfg: ArchConfig, p: dict, x: Array, *,
            mesh=None, batch_axes: tuple = (), model_axis: str = "model",
            ) -> tuple[Array, Array]:
    """MoE FFN on (B, T, d). With a mesh: shard_map island — tokens stay on
    their (pod, data) shard (local dispatch, no all-to-all), expert d_ff
    sharded over `model` with one psum."""
    B, T, d = x.shape

    if mesh is None:
        out, aux = moe_ffn_local(cfg, p, x.reshape(B * T, d))
        return out.reshape(B, T, d), aux

    from jax.sharding import PartitionSpec as P
    n_batch_shards = 1
    for a in batch_axes:
        n_batch_shards *= mesh.shape[a]
    if batch_axes and B % n_batch_shards == 0:
        bspec = P(batch_axes, None, None)
    elif "data" in mesh.shape and B % mesh.shape["data"] == 0:
        bspec = P("data", None, None)
    else:
        bspec = P(None, None, None)     # B=1 decode: tokens replicated
    fsdp = "data"

    def body(xl, router, w1, w3, w2, shared_p):
        # FSDP: expert weights arrive d-sharded over `data`; gather per layer
        # (the usual ZeRO-3 all-gather, explicit here).
        w1 = jax.lax.all_gather(w1, fsdp, axis=1, tiled=True)   # (E, d, f/TP)
        w3 = jax.lax.all_gather(w3, fsdp, axis=1, tiled=True)
        w2 = jax.lax.all_gather(w2, fsdp, axis=2, tiled=True)   # (E, f/TP, d)
        router = jax.lax.all_gather(router, fsdp, axis=0, tiled=True)
        pl = {"router": router, "w1": w1, "w3": w3, "w2": w2}
        if shared_p is not None:
            sh = dict(shared_p)
            sh["w1"] = jax.lax.all_gather(sh["w1"], fsdp, axis=0, tiled=True)
            sh["w3"] = jax.lax.all_gather(sh["w3"], fsdp, axis=0, tiled=True)
            sh["w2"] = jax.lax.all_gather(sh["w2"], fsdp, axis=1, tiled=True)
            sh["gate"] = jax.lax.all_gather(sh["gate"], fsdp, axis=0,
                                            tiled=True)
            pl["shared"] = sh
        Bl, Tl, _ = xl.shape
        out, aux = moe_ffn_local(cfg, pl, xl.reshape(Bl * Tl, d),
                                 model_axis=model_axis)
        aux = jax.lax.pmean(aux, batch_axes) if batch_axes else aux
        return out.reshape(Bl, Tl, d), aux

    shared = p.get("shared")
    shared_specs = None
    if shared is not None:
        shared_specs = {"w1": P(fsdp, model_axis), "w3": P(fsdp, model_axis),
                        "w2": P(model_axis, fsdp), "gate": P(fsdp, None)}
    fn = shard_map(
        body, mesh=mesh,
        in_specs=(bspec, P(fsdp, None), P(None, fsdp, model_axis),
                  P(None, fsdp, model_axis), P(None, model_axis, fsdp),
                  shared_specs),
        out_specs=(bspec, P()), check_vma=False)
    return fn(x, p["router"], p["w1"], p["w3"], p["w2"], shared)
