"""Decoder-only model assembly for all assigned architectures.

One parameterized stack covers the dense / moe / vlm / hybrid / ssm families:
  * homogeneous stacks (everything except xlstm) keep params STACKED over
    layers and run a lax.scan over layers — compile time is O(1) in depth
    (deepseek-coder's 62 layers compile as one block), and per-layer flags
    (hymba's global-vs-local attention schedule) ride along as scan inputs;
  * xlstm's heterogeneous mLSTM/sLSTM pattern is unrolled (12 layers).

Three entry points per model (built by models/model.py):
  train_loss  — full-sequence forward + DiSMEC OvR (or softmax) head loss
  prefill     — full-sequence forward that fills the serving cache
  decode_step — ONE token against the cache (what decode_32k/long_500k lower)

Per-layer remat (jax.checkpoint) keeps train activation memory at one
residual stream per layer.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import ad_checkpoint

from repro.configs.base import ArchConfig
from repro.core import head as dismec_head
from repro.models import layers, moe, ssm
from repro.models.kvcache import cache_t_max

Array = jax.Array


# ---------------------------------------------------------------------------
# Block kinds
# ---------------------------------------------------------------------------

def block_kind(cfg: ArchConfig, idx: int) -> str:
    if cfg.family == "ssm":
        pat = cfg.block_pattern or ("m",)
        return {"m": "mlstm", "s": "slstm"}[pat[idx % len(pat)]]
    if cfg.family == "hybrid":
        return "hybrid"
    return "attn"


def uses_layer_scan(cfg: ArchConfig) -> bool:
    """Scan over layers when every block has identical param structure."""
    return cfg.family != "ssm"


def layer_windows_static(cfg: ArchConfig, *, use_swa: bool) -> tuple:
    """Per-layer window sizes as PYTHON ints; 0 = full attention.
    hymba: SWA everywhere except global_attn_layers; mixtral: SWA
    everywhere; dense --swa variant: SWA everywhere."""
    w = cfg.sliding_window if (cfg.sliding_window and use_swa) else 0
    wins = [w] * cfg.n_layers
    for g in cfg.global_attn_layers:
        if g < cfg.n_layers:
            wins[g] = 0
    return tuple(wins)


def window_segments(cfg: ArchConfig, *, use_swa: bool) -> list:
    """Maximal runs of consecutive layers sharing a static window:
    [(start, end, window), ...]. Static windows let the attention path SKIP
    out-of-window KV blocks (layers.banded_attention) instead of masking
    them — the traced-window variant cost hymba prefill 13x (SSPerf)."""
    wins = layer_windows_static(cfg, use_swa=use_swa)
    segs, s = [], 0
    for i in range(1, len(wins) + 1):
        if i == len(wins) or wins[i] != wins[s]:
            segs.append((s, i, wins[s]))
            s = i
    return segs


def layer_windows(cfg: ArchConfig, *, use_swa: bool) -> Any:
    """Traced (n_layers,) window array — used only by the one-token decode
    scan, where the window is a mask bound (no quadratic work to skip)."""
    return jnp.asarray(layer_windows_static(cfg, use_swa=use_swa), jnp.int32)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _init_block(cfg: ArchConfig, rng: Array, kind: str, dtype) -> dict:
    ks = jax.random.split(rng, 4)
    p: dict = {"norm1": layers.init_norm(cfg, cfg.d_model)}
    if kind == "attn":
        p["attn"] = layers.init_attention(cfg, ks[0], dtype)
    elif kind == "mlstm":
        p["mixer"] = ssm.init_mlstm(cfg, ks[0], dtype)
    elif kind == "slstm":
        p["mixer"] = ssm.init_slstm(cfg, ks[0], dtype)
    elif kind == "hybrid":
        p["attn"] = layers.init_attention(cfg, ks[0], dtype)
        p["mamba"] = ssm.init_mamba(cfg, ks[1], dtype, cfg.d_model)
    if cfg.d_ff > 0:
        p["norm2"] = layers.init_norm(cfg, cfg.d_model)
        if cfg.family == "moe":
            p["moe"] = moe.init_moe(cfg, ks[2], dtype)
        else:
            p["mlp"] = layers.init_mlp(ks[2], cfg.d_model, cfg.d_ff, dtype,
                                       cfg.act)
    return p


def init_params(cfg: ArchConfig, rng: Array) -> dict:
    dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    Vp = cfg.padded_vocab()
    k_embed, k_blocks, k_head = jax.random.split(rng, 3)
    params: dict = {
        "embed": (jax.random.normal(k_embed, (Vp, cfg.d_model)) *
                  cfg.d_model ** -0.5).astype(dtype),
        "final_norm": layers.init_norm(cfg, cfg.d_model),
    }
    if uses_layer_scan(cfg):
        rngs = jax.random.split(k_blocks, cfg.n_layers)
        params["blocks"] = jax.vmap(
            lambda r: _init_block(cfg, r, block_kind(cfg, 0), dtype))(rngs)
    else:
        rngs = jax.random.split(k_blocks, cfg.n_layers)
        params["blocks"] = [
            _init_block(cfg, rngs[i], block_kind(cfg, i), dtype)
            for i in range(cfg.n_layers)]
    if cfg.tie_embeddings:
        pass                                  # head reuses embed
    else:
        params["head"] = dismec_head.init_head(k_head, Vp, cfg.d_model,
                                               dtype)
    return params


# ---------------------------------------------------------------------------
# Forward (full sequence)
# ---------------------------------------------------------------------------

def _block_forward(cfg: ArchConfig, p: dict, x: Array, positions: Array,
                   *, window: int, kind: str, mesh=None,
                   batch_axes=()) -> tuple[Array, Array]:
    """One block. window: STATIC python int (0 = full attention); static so
    sliding-window layers can skip out-of-window KV blocks entirely
    (EXPERIMENTS.md SSPerf hymba iteration 2). Returns (x, aux)."""
    aux = jnp.zeros((), jnp.float32)
    h = layers.apply_norm(cfg, p["norm1"], x)
    if kind == "attn":
        mix = _attention_window(cfg, p["attn"], h, positions, window)
    elif kind == "mlstm":
        mix = ssm.mlstm(cfg, p["mixer"], h)
    elif kind == "slstm":
        mix = ssm.slstm(cfg, p["mixer"], h, mesh=mesh,
                        batch_axes=batch_axes)
    elif kind == "hybrid":
        mix = _hybrid_mix(cfg, p, h, positions, window)
    else:
        raise ValueError(kind)
    # Name the post-all-reduce tensors so the remat policy can SAVE them:
    # re-running a collective inside the rematted bwd is pure wire waste
    # (270 GB/step on mixtral train — EXPERIMENTS.md SSPerf m2).
    mix = ad_checkpoint.checkpoint_name(mix, "block_mix_ar")
    x = x + mix
    if cfg.d_ff > 0:
        h2 = layers.apply_norm(cfg, p["norm2"], x)
        if cfg.family == "moe":
            out, aux = moe.moe_ffn(cfg, p["moe"], h2, mesh=mesh,
                                   batch_axes=batch_axes)
        else:
            out = layers.mlp(p["mlp"], h2, cfg.act)
        out = ad_checkpoint.checkpoint_name(out, "block_ffn_ar")
        x = x + out
    return x, aux


def _attention_window(cfg: ArchConfig, p: dict, x: Array,
                      positions: Array, window: int,
                      project: bool = True) -> Array:
    """Attention with a STATIC window (0 = full). Long sequences route to
    banded_attention (skips KV blocks) when the window actually cuts work,
    else the online-softmax blockwise kernel. project=False skips @wo (the
    hybrid block fuses it with the mamba out-projection — SSPerf 3b)."""
    B, T, _ = x.shape
    q, k, v = layers._qkv(cfg, p, x, positions)
    if T > layers.DENSE_ATTN_MAX_T:
        if window and window < T:
            out = layers.banded_attention(cfg, q, k, v, window=window)
        else:
            out = layers.blockwise_attention(cfg, q, k, v,
                                             window=window or None)
    else:
        mask = layers.causal_mask(T, T, window=window or None)
        out = layers._sdpa(cfg, q, k, v, mask)
    return out @ p["wo"] if project else out


def _hybrid_mix(cfg: ArchConfig, p: dict, h: Array, positions: Array,
                window: int) -> Array:
    """hymba parallel attention + mamba heads, mean-combined.

    0.5*(ctx @ wo + y @ w_out) == (0.5*[ctx, y]) @ [[wo],[w_out]] — ONE
    partial-sum dot over the model axis, so GSPMD inserts ONE all-reduce
    per layer instead of two (EXPERIMENTS.md SSPerf hymba iteration 3b)."""
    ctx = _attention_window(cfg, p["attn"], h, positions, window,
                            project=False)                  # (B,T,H*hd)
    y = ssm.mamba(cfg, p["mamba"], h, cfg.d_model, project=False)
    w_cat = jnp.concatenate([p["attn"]["wo"],
                             p["mamba"]["w_out"]], axis=0)  # (H*hd+d_in, d)
    mixed = jnp.concatenate([ctx, y.astype(ctx.dtype)], axis=-1)
    return (0.5 * mixed) @ w_cat


def _blockwise_dyn(cfg: ArchConfig, q, k, v, eff_window):
    """Blockwise attention with traced window (mask recomputed per tile)."""
    import math as _m
    B, Tq, H, hd = q.shape
    Tk, KV = k.shape[1], k.shape[2]
    G = H // KV
    qc = layers.largest_divisor_leq(Tq, 512)
    kc = layers.largest_divisor_leq(Tk, 1024)
    nq, nk = Tq // qc, Tk // kc
    scale = 1.0 / _m.sqrt(hd)
    qs = jnp.moveaxis(q.reshape(B, nq, qc, KV, G, hd), 1, 0)
    ks = jnp.moveaxis(k.reshape(B, nk, kc, KV, hd), 1, 0)
    vs = jnp.moveaxis(v.reshape(B, nk, kc, KV, hd), 1, 0)

    def q_step(_, qi_qx):
        qi, qx = qi_qx
        q_pos = qi * qc + jnp.arange(qc)

        def kv_step(state, inp):
            ki, kx, vx = inp
            m, l, acc = state
            k_pos = ki * kc + jnp.arange(kc)
            s = jnp.einsum("bqkgh,bskh->bkgqs", qx, kx,
                           preferred_element_type=jnp.float32) * scale
            msk = (k_pos[None, :] <= q_pos[:, None]) & \
                  (k_pos[None, :] > q_pos[:, None] - eff_window)
            s = jnp.where(msk[None, None, None], s, -1e30)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            pmat = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(pmat, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgqs,bskh->bkgqh", pmat.astype(vx.dtype), vx
            ).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, KV, G, qc), -1e30, jnp.float32)
        l0 = jnp.zeros((B, KV, G, qc), jnp.float32)
        a0 = jnp.zeros((B, KV, G, qc, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0),
                                      (jnp.arange(nk), ks, vs))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, jnp.moveaxis(out, 3, 1).reshape(B, qc, H * hd
                                                     ).astype(q.dtype)

    _, outs = jax.lax.scan(q_step, None, (jnp.arange(nq), qs))
    return jnp.moveaxis(outs, 0, 1).reshape(B, Tq, H * hd)


def forward(cfg: ArchConfig, params: dict, tokens: Array,
            prefix: Optional[Array] = None, *, mesh=None, batch_axes=(),
            use_swa: bool = False, remat: bool = True) -> Array:
    """Embeds tokens (plus optional modality prefix embeddings), runs the
    stack, returns final-norm features (B, T_total, d)."""
    x = jnp.take(params["embed"], tokens, axis=0)
    if prefix is not None:
        x = jnp.concatenate([prefix.astype(x.dtype), x], axis=1)
    if mesh is not None:
        from jax.sharding import PartitionSpec as P
        x = _constrain(x, mesh, P(batch_axes or None, None, None))
    B, T, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
    wins = layer_windows_static(cfg, use_swa=use_swa)

    if uses_layer_scan(cfg):
        kind = block_kind(cfg, 0)
        aux = jnp.zeros((), jnp.float32)
        # One scan per maximal same-window segment: the window stays STATIC
        # inside each scan so SWA layers skip out-of-window KV blocks.
        for s, e, win in window_segments(cfg, use_swa=use_swa):
            seg = jax.tree.map(lambda a: a[s:e], params["blocks"])

            def body(carry, blk, _win=win):
                xx, aux_in = carry
                # window bound STATICALLY via partial — jax.checkpoint would
                # otherwise trace it and break the int-valued branch.
                fn = partial(_block_forward, cfg, kind=kind, window=_win,
                             mesh=mesh, batch_axes=batch_axes)
                if remat:
                    fn = jax.checkpoint(fn, policy=_REMAT_POLICY)
                xx, aux_ = fn(blk, xx, positions)
                return (xx, aux_in + aux_), None

            (x, aux), _ = jax.lax.scan(body, (x, aux), seg)
    else:
        aux = jnp.zeros((), jnp.float32)
        for i, blk in enumerate(params["blocks"]):
            fn = partial(_block_forward, cfg, kind=block_kind(cfg, i),
                         window=wins[i], mesh=mesh, batch_axes=batch_axes)
            if remat:
                fn = jax.checkpoint(fn, policy=_REMAT_POLICY)
            x, a = fn(blk, x, positions)
            aux = aux + a
    x = layers.apply_norm(cfg, params["final_norm"], x)
    return x, aux


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------

def head_weight(cfg: ArchConfig, params: dict) -> Array:
    return params["embed"] if cfg.tie_embeddings else params["head"]


# Remat policy: recompute everything EXCEPT the post-collective block
# outputs — re-running an all-reduce in the bwd remat costs wire time, not
# just flops (SSPerf m2: -270 GB/step on mixtral train for +2 saved
# (B_mb, T, d) tensors per layer).
# SSPerf m2 (REFUTED): saving post-AR block outputs in the remat policy
# removes 90 GB/step of re-run collectives on mixtral train (-8%) but costs
# +11 GB/device peak (34.5 GB, over the 16 GB v5e budget). Not worth it at
# this memory budget — policy stays None; the checkpoint_name markers remain
# so a host-offload policy can target them later.
_REMAT_POLICY = None


def _constrain(x: Array, mesh, spec) -> Array:
    """Activation sharding constraint (no-op without a mesh)."""
    if mesh is None:
        return x
    from jax.sharding import NamedSharding
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# Token-chunk size for the head losses: the (tokens, labels) logit block is
# the single biggest activation in every assigned arch (65k x 9.5k f32 =
# 2.5 GB/device on qwen05 train, with ~6 live copies through the hinge
# chain + bwd). Scanning token chunks with per-chunk remat bounds the live
# block to (HEAD_CHUNK/devices, labels/16) — the paper's Algorithm-1 outer
# batch loop, applied to the LM head (EXPERIMENTS.md SSPerf q2).
HEAD_CHUNK = 32768


def _chunked_rows(n: int, target: int = HEAD_CHUNK) -> int:
    c = layers.largest_divisor_leq(n, target)
    return c if c > 1 else n


def ovr_loss_from_feats(cfg: ArchConfig, W: Array, feats: Array,
                        targets: Array, valid: Optional[Array],
                        *, mesh=None, batch_axes=()) -> Array:
    """DiSMEC OvR squared-hinge loss, formulated with one-hot ops so the
    vocab axis shards (no take_along_axis gather across label shards).

    The logits constraint IS the paper's layer-1 parallelism: rows (tokens)
    over the batch axes, labels over `model`; each device owns an
    independent (token-shard x label-shard) hinge block — zero cross-label
    traffic, one scalar psum at the end (vs softmax-CE's logsumexp
    collectives)."""
    from jax.sharding import PartitionSpec as P
    f2 = feats.reshape(-1, feats.shape[-1]).astype(jnp.float32)
    t2 = targets.reshape(-1)
    v2 = (valid.reshape(-1).astype(jnp.float32) if valid is not None
          else jnp.ones((f2.shape[0],), jnp.float32))
    # Rows shard over the batch axes MINUS "model" (which carries labels).
    # With backbone_tp=False the model axis is part of the batch axes for
    # the backbone; the feats all-gather over it happens here, at the head
    # boundary — tokens x d, ~8 MB — instead of 2 ARs/layer (SSPerf q1).
    rows = tuple(a for a in batch_axes if a != "model") or None
    Wf = W.astype(jnp.float32)

    def chunk_loss(f_c, t_c, v_c):
        # Gather rows over `model` BEFORE the dot: f_c arrives sharded over
        # ALL batch axes (incl. model when backbone_tp=False); letting GSPMD
        # reshard z itself replicates the whole (c, Vp) block per chunk
        # (40 GB/step measured — SSPerf q2).
        f_c = _constrain(f_c, mesh, P(rows, None))
        z = f_c @ Wf.T                                  # (c, Vp) label-sharded
        z = _constrain(z, mesh, P(rows, "model"))
        tmask = jax.lax.broadcasted_iota(jnp.int32, z.shape, 1) == t_c[:, None]
        z_y = jnp.sum(jnp.where(tmask, z, 0.0), axis=-1)
        neg = jnp.maximum(1.0 + z, 0.0)
        neg_sum = jnp.sum(neg * neg, axis=-1)           # every label negative
        neg_y = jnp.maximum(1.0 + z_y, 0.0)
        pos_y = jnp.maximum(1.0 - z_y, 0.0)
        per_tok = neg_sum - neg_y * neg_y + pos_y * pos_y
        return jnp.sum(per_tok * v_c)

    n = f2.shape[0]
    c = _chunked_rows(n)
    if c < n:
        def body(acc, xs):
            return acc + jax.checkpoint(chunk_loss)(*xs), None
        nc = n // c
        total, _ = jax.lax.scan(
            body, jnp.zeros((), jnp.float32),
            (f2.reshape(nc, c, -1), t2.reshape(nc, c), v2.reshape(nc, c)))
    else:
        total = chunk_loss(f2, t2, v2)
    denom = jnp.maximum(jnp.sum(v2), 1.0) if valid is not None else n
    l2 = cfg.ovr_reg * jnp.sum(Wf ** 2)
    return cfg.ovr_C * total / denom + l2


def softmax_loss_from_feats(W: Array, feats: Array, targets: Array,
                            valid: Optional[Array], *, mesh=None,
                            batch_axes=()) -> Array:
    """Baseline softmax-CE head, token-chunked like the OvR head. Note the
    logsumexp needs max+sum reductions over the label-sharded axis — the
    collectives the DiSMEC head does not have."""
    from jax.sharding import PartitionSpec as P
    f2 = feats.reshape(-1, feats.shape[-1]).astype(jnp.float32)
    t2 = targets.reshape(-1)
    v2 = (valid.reshape(-1).astype(jnp.float32) if valid is not None
          else jnp.ones((f2.shape[0],), jnp.float32))
    rows = tuple(a for a in batch_axes if a != "model") or None
    Wf = W.astype(jnp.float32)

    def chunk_nll(f_c, t_c, v_c):
        f_c = _constrain(f_c, mesh, P(rows, None))
        z = f_c @ Wf.T
        z = _constrain(z, mesh, P(rows, "model"))
        tmask = jax.lax.broadcasted_iota(jnp.int32, z.shape, 1) == t_c[:, None]
        logz = jax.nn.logsumexp(z, axis=-1)
        z_y = jnp.sum(jnp.where(tmask, z, 0.0), axis=-1)
        return jnp.sum((logz - z_y) * v_c)

    n = f2.shape[0]
    c = _chunked_rows(n)
    if c < n:
        def body(acc, xs):
            return acc + jax.checkpoint(chunk_nll)(*xs), None
        nc = n // c
        total, _ = jax.lax.scan(
            body, jnp.zeros((), jnp.float32),
            (f2.reshape(nc, c, -1), t2.reshape(nc, c), v2.reshape(nc, c)))
    else:
        total = chunk_nll(f2, t2, v2)
    denom = jnp.maximum(jnp.sum(v2), 1.0) if valid is not None else n
    return total / denom


# ---------------------------------------------------------------------------
# Serving: cache init, prefill, one-token decode
# ---------------------------------------------------------------------------

def _mixer_state_init(cfg: ArchConfig, kind: str, B: int):
    if kind == "mlstm":
        return ssm.mlstm_init_state(cfg, B)
    if kind == "slstm":
        return ssm.slstm_init_state(cfg, B)
    if kind == "hybrid":
        return ssm.mamba_init_state(cfg, B, cfg.d_model)
    return None


def decode_cache_len(cfg: ArchConfig, seq_len: int, *, use_swa: bool) -> int:
    """Uniform per-layer cache length. Pure-SWA stacks (mixtral; dense --swa)
    ring-buffer at `window`; stacks with any global layer (hymba) allocate
    full length (the window mask still applies per layer)."""
    if cfg.sliding_window and use_swa and not cfg.global_attn_layers:
        return min(cfg.sliding_window, seq_len)
    return seq_len


def init_cache(cfg: ArchConfig, B: int, seq_len: int, *, use_swa: bool,
               dtype=jnp.bfloat16) -> dict:
    """Serving cache pytree, stacked over layers for scanned stacks."""
    t_eff = decode_cache_len(cfg, seq_len, use_swa=use_swa)
    cache: dict = {}
    L = cfg.n_layers
    if cfg.family == "ssm":
        cache["states"] = [
            _mixer_state_init(cfg, block_kind(cfg, i), B) for i in range(L)]
        return cache
    shape = (L, B, t_eff, cfg.n_kv_heads, cfg.head_dim)
    cache["k"] = jnp.zeros(shape, dtype)
    cache["v"] = jnp.zeros(shape, dtype)
    if cfg.family == "hybrid":
        st = _mixer_state_init(cfg, "hybrid", B)
        cache["ssm"] = jax.tree.map(
            lambda a: jnp.zeros((L,) + a.shape, a.dtype), st)
    return cache


def _decode_block(cfg: ArchConfig, blk: dict, kind: str, x: Array,
                  positions: Array, window: Array, kc, vc, sst, pos, *,
                  mesh=None, batch_axes=()):
    """One decode block: x (B, 1, d). Returns (x, kc, vc, sst, aux)."""
    aux = jnp.zeros((), jnp.float32)
    h = layers.apply_norm(cfg, blk["norm1"], x)
    if kind in ("attn", "hybrid"):
        eff = jnp.where(window > 0, window, jnp.int32(2 ** 30))
        a, kc, vc = _attention_decode_dyn(
            cfg, blk["attn"], h, positions, kc, vc, pos, eff)
        if kind == "hybrid":
            m, sst = ssm.mamba_decode(cfg, blk["mamba"], h, sst, cfg.d_model)
            mix = 0.5 * (a + m)
        else:
            mix = a
    elif kind == "mlstm":
        mix, sst = ssm.mlstm_decode(cfg, blk["mixer"], h, sst)
    elif kind == "slstm":
        mix, sst = ssm.slstm_decode(cfg, blk["mixer"], h, sst)
    else:
        raise ValueError(kind)
    x = x + mix
    if cfg.d_ff > 0:
        h2 = layers.apply_norm(cfg, blk["norm2"], x)
        if cfg.family == "moe":
            out, aux = moe.moe_ffn(cfg, blk["moe"], h2, mesh=mesh,
                                   batch_axes=batch_axes)
        else:
            out = layers.mlp(blk["mlp"], h2, cfg.act)
        x = x + out
    return x, kc, vc, sst, aux


def _attention_decode_dyn(cfg: ArchConfig, p: dict, x: Array,
                          positions: Array, k_cache, v_cache, pos, eff):
    """attention_decode with a traced window scalar `eff` (2^30 = full)."""
    B = x.shape[0]
    T_max = k_cache.shape[1]
    q, k, v = layers._qkv(cfg, p, x, positions)
    slot = pos % T_max
    k_cache = jax.lax.dynamic_update_slice(
        k_cache, k.astype(k_cache.dtype), (0, slot, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(
        v_cache, v.astype(v_cache.dtype), (0, slot, 0, 0))
    slots = jnp.arange(T_max)
    age = (pos - slots) % T_max
    abs_pos = pos - age
    valid = (abs_pos >= 0) & (abs_pos > pos - eff) & (abs_pos <= pos)
    mask = valid[None, None, None, None, :]
    out = layers._sdpa(cfg, q, k_cache, v_cache, mask)
    return out @ p["wo"], k_cache, v_cache


def decode_step(cfg: ArchConfig, params: dict, cache: dict, tokens: Array,
                pos: Array, *, mesh=None, batch_axes=(), use_swa: bool = False,
                top_k: int = 5):
    """serve_step: ONE new token (B, 1) against the cache at position `pos`.
    Returns (topk_vals, topk_idx, logits_shape_marker, new_cache) — the top-k
    is the DiSMEC distributed-prediction merge over the label-sharded head.
    """
    B = tokens.shape[0]
    x = jnp.take(params["embed"], tokens, axis=0)              # (B, 1, d)
    if mesh is not None:
        # Same as prefill: keep the request batch sharded over `data` after
        # the vocab-sharded embedding gather (see EXPERIMENTS.md SSPerf).
        from jax.sharding import PartitionSpec as P
        x = _constrain(x, mesh, P(batch_axes or None, None, None))
    positions = jnp.broadcast_to(pos, (B, 1)).astype(jnp.int32)
    wins = layer_windows(cfg, use_swa=use_swa)
    new_cache = dict(cache)

    if cfg.family == "ssm":
        aux = 0.0
        states = []
        for i, blk in enumerate(params["blocks"]):
            kind = block_kind(cfg, i)
            x, _, _, sst, _ = _decode_block(
                cfg, blk, kind, x, positions, wins[i], None, None,
                cache["states"][i], pos, mesh=mesh, batch_axes=batch_axes)
            states.append(sst)
        new_cache["states"] = states
    else:
        kind = block_kind(cfg, 0)
        has_ssm = cfg.family == "hybrid"

        def body(carry, xs):
            xx = carry
            if has_ssm:
                blk, win, kc, vc, sst = xs
            else:
                blk, win, kc, vc = xs
                sst = None
            xx, kc, vc, sst, _ = _decode_block(
                cfg, blk, kind, xx, positions, win, kc, vc, sst, pos,
                mesh=mesh, batch_axes=batch_axes)
            ys = (kc, vc, sst) if has_ssm else (kc, vc)
            return xx, ys

        xs = (params["blocks"], wins, cache["k"], cache["v"])
        if has_ssm:
            xs = xs + (cache["ssm"],)
        x, ys = jax.lax.scan(body, x, xs)
        new_cache["k"], new_cache["v"] = ys[0], ys[1]
        if has_ssm:
            new_cache["ssm"] = ys[2]

    x = layers.apply_norm(cfg, params["final_norm"], x)
    W = head_weight(cfg, params)
    logits = (x[:, 0].astype(jnp.float32) @ W.T.astype(jnp.float32))
    vals, idx = jax.lax.top_k(logits, top_k)   # DiSMEC §2.2.1 distributed merge
    return vals, idx, new_cache


def prefill(cfg: ArchConfig, params: dict, tokens: Array,
            prefix: Optional[Array] = None, *, mesh=None, batch_axes=(),
            use_swa: bool = False):
    """Full-sequence forward that fills the serving cache and returns the
    last-position top-k. Cache length == prompt length (decode continues by
    ring/extend policy of the caller)."""
    x = jnp.take(params["embed"], tokens, axis=0)
    if prefix is not None:
        x = jnp.concatenate([prefix.astype(x.dtype), x], axis=1)
    if mesh is not None:
        # The vocab-sharded embedding gather loses the batch sharding; without
        # this constraint GSPMD replicates the whole prefill over `data`
        # (16x flops — measured in EXPERIMENTS.md SSPerf iteration 1).
        from jax.sharding import PartitionSpec as P
        x = _constrain(x, mesh, P(batch_axes or None, None, None))
    B, T, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
    wins = layer_windows_static(cfg, use_swa=use_swa)
    t_eff = decode_cache_len(cfg, T, use_swa=use_swa)

    def block_with_cache(blk, xx, win, kind):
        """win is a STATIC python int (0 = full attention)."""
        h = layers.apply_norm(cfg, blk["norm1"], xx)
        aux = jnp.zeros((), jnp.float32)
        kc = vc = sst = None
        if kind in ("attn", "hybrid"):
            q, k, v = layers._qkv(cfg, blk["attn"], h, positions)
            if T > layers.DENSE_ATTN_MAX_T:
                if win and win < T:
                    ctx = layers.banded_attention(cfg, q, k, v, window=win)
                else:
                    ctx = layers.blockwise_attention(cfg, q, k, v,
                                                     window=win or None)
            else:
                ctx = layers._sdpa(cfg, q, k, v,
                                   layers.causal_mask(T, T,
                                                      window=win or None))
            kc, vc = k[:, T - t_eff:], v[:, T - t_eff:]
            if kind == "hybrid":
                # Fused dual-head projection: one TP all-reduce (SSPerf 3b).
                y, sst = ssm.mamba(cfg, blk["mamba"], h, cfg.d_model,
                                   return_state=True, project=False)
                w_cat = jnp.concatenate([blk["attn"]["wo"],
                                         blk["mamba"]["w_out"]], axis=0)
                mixed = jnp.concatenate([ctx, y.astype(ctx.dtype)], axis=-1)
                mix = (0.5 * mixed) @ w_cat
            else:
                mix = ctx @ blk["attn"]["wo"]
        elif kind == "mlstm":
            mix, sst = ssm.mlstm(cfg, blk["mixer"], h, return_state=True)
        elif kind == "slstm":
            mix, sst = ssm.slstm(cfg, blk["mixer"], h, return_state=True)
        xx = xx + mix
        if cfg.d_ff > 0:
            h2 = layers.apply_norm(cfg, blk["norm2"], xx)
            if cfg.family == "moe":
                out, aux = moe.moe_ffn(cfg, blk["moe"], h2, mesh=mesh,
                                       batch_axes=batch_axes)
            else:
                out = layers.mlp(blk["mlp"], h2, cfg.act)
            xx = xx + out
        return xx, kc, vc, sst

    cache: dict = {}
    if cfg.family == "ssm":
        states = []
        for i, blk in enumerate(params["blocks"]):
            x, _, _, sst = block_with_cache(blk, x, wins[i], block_kind(cfg, i))
            states.append(sst)
        cache["states"] = states
    else:
        kind = block_kind(cfg, 0)
        # One scan per same-window segment (see forward); per-segment
        # cache stacks concatenate back to the (n_layers, ...) layout.
        seg_ys = []
        for s, e, win in window_segments(cfg, use_swa=use_swa):
            seg = jax.tree.map(lambda a: a[s:e], params["blocks"])

            def body(xx, blk, _win=win):
                xx, kc, vc, sst = block_with_cache(blk, xx, _win, kind)
                ys = (kc.astype(jnp.bfloat16), vc.astype(jnp.bfloat16))
                if sst is not None:
                    ys = ys + (sst,)
                return xx, ys

            x, ys = jax.lax.scan(body, x, seg)
            seg_ys.append(ys)
        ys = jax.tree.map(lambda *a: jnp.concatenate(a, axis=0), *seg_ys)
        cache["k"], cache["v"] = ys[0], ys[1]
        if cfg.family == "hybrid":
            cache["ssm"] = ys[2]

    x = layers.apply_norm(cfg, params["final_norm"], x)
    W = head_weight(cfg, params)
    logits = x[:, -1].astype(jnp.float32) @ W.T.astype(jnp.float32)
    vals, idx = jax.lax.top_k(logits, 5)
    return vals, idx, cache


def train_loss(cfg: ArchConfig, params: dict, batch: dict, *, mesh=None,
               batch_axes=()) -> tuple[Array, dict]:
    """batch: tokens (B,T), targets (B,T), valid (B,T) [+ prefix (B,P,d)]."""
    feats, aux = forward(cfg, params, batch["tokens"],
                         prefix=batch.get("prefix"), mesh=mesh,
                         batch_axes=batch_axes)
    if "prefix" in batch and batch["prefix"] is not None:
        feats = feats[:, batch["prefix"].shape[1]:]
    W = head_weight(cfg, params)
    if cfg.head_type == "dismec":
        loss = ovr_loss_from_feats(cfg, W, feats, batch["targets"],
                                   batch.get("valid"), mesh=mesh,
                                   batch_axes=batch_axes)
    else:
        loss = softmax_loss_from_feats(W, feats, batch["targets"],
                                       batch.get("valid"), mesh=mesh,
                                       batch_axes=batch_axes)
    total = loss + cfg.router_aux_coef * aux
    return total, {"loss": loss, "aux": aux}
