"""Model dispatch: one entry point per architecture family.

build_model(cfg) returns a Model with uniform signatures so the launcher,
trainer and dry-run treat all ten assigned architectures identically:

  init(rng)                                   -> params
  train_loss(params, batch, mesh, batch_axes) -> (loss, metrics)
  prefill(params, batch, ...)                 -> (topk_vals, topk_idx, cache)
  decode_step(params, cache, tokens, pos, ..) -> (vals, idx, new_cache)
  init_cache(B, seq_len, use_swa)             -> cache pytree
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import encdec, transformer

Array = jax.Array


@dataclasses.dataclass
class Model:
    cfg: ArchConfig
    init: Callable
    train_loss: Callable
    prefill: Callable
    decode_step: Callable
    init_cache: Callable


def build_model(cfg: ArchConfig) -> Model:
    if cfg.is_encoder_decoder:
        def init(rng):
            return encdec.init_params(cfg, rng)

        def train_loss(params, batch, *, mesh=None, batch_axes=()):
            return encdec.train_loss(cfg, params, batch, mesh=mesh,
                                     batch_axes=batch_axes)

        def prefill_fn(params, batch, *, mesh=None, batch_axes=(),
                       use_swa=False):
            return encdec.prefill(cfg, params, batch["tokens"],
                                  batch["prefix"])

        def decode_fn(params, cache, tokens, pos, *, mesh=None,
                      batch_axes=(), use_swa=False):
            return encdec.decode_step(cfg, params, cache, tokens, pos)

        def init_cache(B, seq_len, *, use_swa=False, t_enc=None):
            return encdec.init_cache(cfg, B, seq_len,
                                     t_enc or cfg.n_prefix)

        return Model(cfg, init, train_loss, prefill_fn, decode_fn, init_cache)

    def init(rng):
        return transformer.init_params(cfg, rng)

    def train_loss(params, batch, *, mesh=None, batch_axes=()):
        return transformer.train_loss(cfg, params, batch, mesh=mesh,
                                      batch_axes=batch_axes)

    def prefill_fn(params, batch, *, mesh=None, batch_axes=(),
                   use_swa=False):
        return transformer.prefill(cfg, params, batch["tokens"],
                                   prefix=batch.get("prefix"), mesh=mesh,
                                   batch_axes=batch_axes, use_swa=use_swa)

    def decode_fn(params, cache, tokens, pos, *, mesh=None, batch_axes=(),
                  use_swa=False):
        return transformer.decode_step(cfg, params, cache, tokens, pos,
                                       mesh=mesh, batch_axes=batch_axes,
                                       use_swa=use_swa)

    def init_cache(B, seq_len, *, use_swa=False, t_enc=None):
        return transformer.init_cache(cfg, B, seq_len, use_swa=use_swa)

    return Model(cfg, init, train_loss, prefill_fn, decode_fn, init_cache)
