"""Partition specs for params, activations, caches (DESIGN.md §5).

Conventions (mesh axes: optional "pod", then "data", "model"):

  weights    : FSDP over "data" x tensor-parallel over "model".
               Every 2D projection (a, b) is P(fsdp, tp) or P(tp, fsdp)
               depending on which dim is the TP dim; dims that don't divide
               their axis are replicated (helper `div`).
  batch      : P(("pod","data")) when pod exists; logits vocab dim over
               "model" (the DiSMEC label sharding).
  KV caches  : batch over (pod, data); *length* over "model" (kv_heads of
               the assigned archs don't divide 16, cache lengths do).
               long_500k (B=1): length over ("data","model").
  optimizer  : moments/master copy inherit the param spec.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig

Array = jax.Array

FSDP, TP = "data", "model"


def _axis(mesh_shape: dict, name: str, size: int) -> Optional[str]:
    """Axis name if `size` divides the axis, else None (replicate)."""
    return name if name in mesh_shape and size % mesh_shape[name] == 0 else None


def batch_axes(mesh_shape: dict, cfg: Optional[ArchConfig] = None) -> tuple:
    """Mesh axes the batch shards over. With backbone_tp=False the `model`
    axis carries no backbone TP, so it becomes EXTRA data parallelism for
    the backbone — the DiSMEC structure: data-parallel features,
    label-parallel head, one small feats all-gather at the boundary
    (EXPERIMENTS.md SSPerf q1)."""
    axes = ("pod", "data") if "pod" in mesh_shape else ("data",)
    if cfg is not None and not cfg.backbone_tp:
        axes = axes + (TP,)
    return axes


def batch_spec(mesh_shape: dict, global_batch: int, extra=(None,),
               cfg: Optional[ArchConfig] = None) -> P:
    axes = batch_axes(mesh_shape, cfg)
    cands = [axes]
    base = ("pod", "data") if "pod" in mesh_shape else ("data",)
    if axes != base:
        cands.append(base)               # without the model extension
    if base != ("data",):
        cands.append(("data",))
    for c in cands:
        n = 1
        for a in c:
            n *= mesh_shape[a]
        if global_batch % n == 0:
            return P(c, *extra)
    return P(None, *extra)


# Leaf names whose LAST dim is the tensor-parallel dim (column-parallel)...
_TP_LAST = {"wq", "wk", "wv", "w1", "w3", "w_in", "w_if", "w_dt", "w"}
# ...and whose SECOND-TO-LAST dim is (row-parallel / vocab-sharded).
_TP_FIRST = {"wo", "w2", "w_out", "embed", "head", "lm_head"}
# Contraction-dim-only sharding (output dim too small / must stay whole).
_FSDP_ONLY = {"router", "gate"}
# Fully replicated: tiny projections where TP-sharding the output dim turns
# every SSM chunk step into a partial-sum all-reduce — w_bc is (d, 2S)=100 KB
# but sharding S cost hymba prefill 13.4 GB of *serialized* in-scan ARs
# (EXPERIMENTS.md SSPerf hymba iteration 3a).
_REPLICATE = {"w_bc"}


def param_pspecs(cfg: ArchConfig, params, mesh_shape: dict):
    """Pytree of PartitionSpec matching `params` (leaf-name patterns).

    2D (or stacked 3D/4D) weights get P(..., FSDP_dim, TP_dim) with each
    axis dropped when the dim doesn't divide it — e.g. chatglm's kv_dim
    (2 heads x 128) is replicated over a 16-way model axis.
    """

    # The extreme output layer (and tied embedding) is ALWAYS label-sharded
    # over `model` — the paper's layer-1 parallelism. The backbone drops its
    # TP axis when cfg.backbone_tp=False (small models: 16-way TP shards are
    # MXU-starved and the 2 ARs/layer dominate the step — SSPerf q1).
    _HEAD_NAMES = {"embed", "head", "lm_head"}

    def spec_for(path: tuple, leaf) -> P:
        name = None
        for k in reversed(path):
            key = getattr(k, "key", None)
            if isinstance(key, str):
                name = key
                break
        shape = leaf.shape
        if leaf.ndim <= 1 or name is None:
            return P()
        lead = (None,) * (leaf.ndim - 2)
        if name in _REPLICATE:
            return P()
        # backbone_tp=False replicates backbone weights FULLY (not FSDP):
        # recurrent stacks (sLSTM) apply weights inside per-timestep scans,
        # and an FSDP shard there means an all-gather EVERY time step
        # (measured: xlstm train collective 0.46 -> 2.05 s with FSDP;
        # replication keeps the backbone collective-free). These backbones
        # are <= 0.5B params — replication costs ~5 GB incl. optimizer.
        backbone_no_tp = (not cfg.backbone_tp) and name not in _HEAD_NAMES
        if backbone_no_tp:
            return P()
        if name in _TP_FIRST:
            return P(*lead, _axis(mesh_shape, TP, shape[-2]),
                     _axis(mesh_shape, FSDP, shape[-1]))
        if name in _TP_LAST:
            return P(*lead, _axis(mesh_shape, FSDP, shape[-2]),
                     _axis(mesh_shape, TP, shape[-1]))
        if name in _FSDP_ONLY:
            return P(*lead, _axis(mesh_shape, FSDP, shape[-2]), None)
        return P()

    return jax.tree_util.tree_map_with_path(spec_for, params)


def cache_pspecs(cache, mesh_shape: dict, global_batch: int):
    """KV cache: (L, B, T, KV, hd) -> batch over (pod,data), T over model.
    B == 1 (long_500k): T over (data, model)."""
    def spec_for(leaf):
        if leaf.ndim == 5:                      # stacked attn cache
            B, T = leaf.shape[1], leaf.shape[2]
            baxes = batch_axes(mesh_shape)
            nb = 1
            for a in baxes:
                nb *= mesh_shape[a]
            if B % nb == 0:
                return P(None, baxes, _axis(mesh_shape, TP, T), None, None)
            if B % mesh_shape["data"] == 0:
                return P(None, "data", _axis(mesh_shape, TP, T), None, None)
            # B=1: shard length over every available axis
            seq_axes = tuple(a for a in ("data", "model")
                             if T % mesh_shape[a] == 0)
            if len(seq_axes) == 2 and T % (mesh_shape["data"] *
                                           mesh_shape["model"]) == 0:
                return P(None, None, seq_axes, None, None)
            return P(None, None, seq_axes[0] if seq_axes else None, None, None)
        # SSM states: (L, B, ...) — batch over data when divisible
        if leaf.ndim >= 2:
            B = leaf.shape[1]
            baxes = batch_axes(mesh_shape)
            nb = 1
            for a in baxes:
                nb *= mesh_shape[a]
            lead = (None,)
            rest = (None,) * (leaf.ndim - 2)
            if B % nb == 0:
                return P(None, baxes, *rest)
            if B % mesh_shape["data"] == 0:
                return P(None, "data", *rest)
            return P(*((None,) * leaf.ndim))
        return P(None)

    return jax.tree.map(spec_for, cache,
                        is_leaf=lambda x: hasattr(x, "ndim"))


def named(mesh: Mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
