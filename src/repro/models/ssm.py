"""Recurrent sequence-mixing layers: xLSTM (mLSTM + sLSTM) and Mamba-style SSD.

Covers the SSM/hybrid assigned architectures:
  * xlstm-125m  — sLSTM + mLSTM blocks [arXiv:2405.04517]
  * hymba-1.5b  — parallel attention + Mamba heads  [arXiv:2411.13676]

All three mixers expose the same two entry points:
  <mixer>(cfg, p, x)                       full-sequence (train / prefill)
  <mixer>_decode(cfg, p, x, state)         one token, O(1) state update

mLSTM trains in a CHUNKWISE-parallel form (chunk 256): intra-chunk quadratic
attention-like term + inter-chunk recurrent state carried by lax.scan — the
standard gated-linear-attention decomposition, adapted for TPU so the (T, T)
decay matrix never materializes beyond a chunk. Gate stabilization follows
the xLSTM paper's max-state m_t trick, done per chunk boundary.

sLSTM is inherently sequential (hidden-state mixing) and runs as lax.scan
over time with per-head block-diagonal recurrence.

Decode states are pytrees of fixed-shape arrays — they live in the serving
cache next to the attention KV blocks (models/kvcache.py). long_500k decode
is O(1) for all of these — the reason the SSM/hybrid archs run that shape
natively (DESIGN.md §Arch-applicability).
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.compat import shard_map
from repro.configs.base import ArchConfig

Array = jax.Array
CHUNK = 256


# ===========================================================================
# mLSTM (matrix memory, exponential gating) — xLSTM's parallel workhorse
# ===========================================================================

def init_mlstm(cfg: ArchConfig, rng: Array, dtype) -> dict:
    d = cfg.d_model
    H = cfg.mlstm_heads or cfg.n_heads
    hd = d // H
    k = jax.random.split(rng, 6)
    s = d ** -0.5
    return {
        "wq": (jax.random.normal(k[0], (d, d)) * s).astype(dtype),
        "wk": (jax.random.normal(k[1], (d, d)) * s).astype(dtype),
        "wv": (jax.random.normal(k[2], (d, d)) * s).astype(dtype),
        "wo": (jax.random.normal(k[3], (d, d)) * s).astype(dtype),
        "w_if": (jax.random.normal(k[4], (d, 2 * H)) * s).astype(dtype),
        "b_if": jnp.concatenate([jnp.zeros((H,)), 3.0 * jnp.ones((H,))]
                                ).astype(jnp.float32),
        "ln": jnp.ones((d,), jnp.float32),      # per-head group-norm scale
    }


class MLSTMState(NamedTuple):
    C: Array    # (B, H, hd, hd) matrix memory
    n: Array    # (B, H, hd)     normalizer
    m: Array    # (B, H)         max-gate stabilizer (log space)


def mlstm_init_state(cfg: ArchConfig, B: int, dtype=jnp.float32) -> MLSTMState:
    H = cfg.mlstm_heads or cfg.n_heads
    hd = cfg.d_model // H
    return MLSTMState(C=jnp.zeros((B, H, hd, hd), dtype),
                      n=jnp.zeros((B, H, hd), dtype),
                      m=jnp.full((B, H), -1e30, dtype))


def _mlstm_gates(p: dict, x: Array, H: int):
    """Log input/forget gates, (B, T, H) each, f via log-sigmoid."""
    g = (x @ p["w_if"]).astype(jnp.float32) + p["b_if"]
    log_i = g[..., :H]                       # i_t = exp(itilde): log_i = itilde
    log_f = jax.nn.log_sigmoid(g[..., H:])   # f_t = sigmoid(ftilde)
    return log_i, log_f


def _heads(x: Array, H: int) -> Array:
    B, T, d = x.shape
    return x.reshape(B, T, H, d // H).transpose(0, 2, 1, 3)  # (B, H, T, hd)


def mlstm(cfg: ArchConfig, p: dict, x: Array, return_state: bool = False):
    """Chunkwise-parallel mLSTM over the full sequence. x: (B, T, d)."""
    B, T, d = x.shape
    H = cfg.mlstm_heads or cfg.n_heads
    hd = d // H
    nc = (T + CHUNK - 1) // CHUNK
    Tp = nc * CHUNK
    if Tp != T:
        x = jnp.pad(x, ((0, 0), (0, Tp - T), (0, 0)))

    q = _heads(x @ p["wq"], H) / math.sqrt(hd)   # (B, H, Tp, hd)
    k = _heads(x @ p["wk"], H)
    v = _heads(x @ p["wv"], H)
    log_i, log_f = _mlstm_gates(p, x, H)          # (B, Tp, H)
    log_i = log_i.transpose(0, 2, 1)              # (B, H, Tp)
    log_f = log_f.transpose(0, 2, 1)

    # Reshape into chunks: (nc, B, H, CHUNK, ...)
    def chunked(a):
        tail = a.shape[3:]                        # () or (hd,)
        return jnp.moveaxis(a.reshape(B, H, nc, CHUNK, *tail), 2, 0)

    qc = chunked(q)                               # (nc, B, H, CHUNK, hd)
    kc = chunked(k)
    vc = chunked(v)
    lic = chunked(log_i)                          # (nc, B, H, CHUNK)
    lfc = chunked(log_f)

    state0 = mlstm_init_state(cfg, B)

    def scan_chunk(state, inp):
        """Exactly matches the per-token decode recurrence.

        Let F_t = sum_{u<=t} lf_u within the chunk. The decode stabilizer
        satisfies m_t = F_t + M_t with M_t = max(m_in, cummax_{s<=t}(li_s - F_s));
        stored states carry units exp(m). In units exp(m_t):
          intra weight (source s <= t): exp(li_s - F_s - M_t)
          carried-state weight:         exp(m_in - M_t)
        """
        qx, kx, vx, li, lf = inp                  # (B, H, CHUNK, ...) leading
        C_in, n_in, m_in = state.C, state.n, state.m
        F = jnp.cumsum(lf, axis=-1)               # (B, H, W)
        a = li - F                                # (B, H, W) source log-weight
        M = jnp.maximum(m_in[..., None], jax.lax.cummax(a, axis=a.ndim - 1))

        # Intra-chunk term: w[t, s] = exp(a_s - M_t), s <= t.
        wmat = jnp.exp(a[..., None, :] - M[..., :, None])
        causal = jnp.tril(jnp.ones((CHUNK, CHUNK), bool))
        scores = jnp.einsum("bhtd,bhsd->bhts", qx, kx,
                            preferred_element_type=jnp.float32)
        w = jnp.where(causal, wmat * scores, 0.0)
        h_intra = jnp.einsum("bhts,bhsd->bhtd", w, vx.astype(jnp.float32))
        den_intra = jnp.sum(w, axis=-1)

        # Carried state term.
        carry_w = jnp.exp(m_in[..., None] - M)    # (B, H, W)
        h_inter = jnp.einsum("bhtd,bhde->bhte", qx.astype(jnp.float32),
                             C_in) * carry_w[..., None]
        den_inter = jnp.einsum("bhtd,bhd->bht", qx.astype(jnp.float32),
                               n_in) * carry_w

        num = h_intra + h_inter
        den = den_intra + den_inter
        h = num / jnp.maximum(jnp.abs(den), 1.0)[..., None]

        # End-of-chunk state, in units exp(m_out), m_out = F_W + M_W.
        M_W = M[..., -1]
        w_s = jnp.exp(a - M_W[..., None])         # (B, H, W)
        keep = jnp.exp(m_in - M_W)
        C_out = keep[..., None, None] * C_in + \
            jnp.einsum("bhs,bhsd,bhse->bhde", w_s, kx.astype(jnp.float32),
                       vx.astype(jnp.float32))
        n_out = keep[..., None] * n_in + \
            jnp.einsum("bhs,bhsd->bhd", w_s, kx.astype(jnp.float32))
        m_out = F[..., -1] + M_W
        return MLSTMState(C=C_out, n=n_out, m=m_out), h

    final, hs = jax.lax.scan(scan_chunk, state0, (qc, kc, vc, lic, lfc))
    h = hs.transpose(1, 2, 0, 3, 4).reshape(B, H, Tp, hd)   # (B,H,T,hd)
    h = h.transpose(0, 2, 1, 3).reshape(B, Tp, d)[:, :T]
    h = _group_rmsnorm(h, p["ln"], H)
    out = (h.astype(x.dtype) @ p["wo"]).astype(x.dtype)  # bf16 pre-AR (SSPerf)
    if return_state:
        return out, final
    return out


def _group_rmsnorm(x: Array, scale: Array, H: int, eps: float = 1e-6) -> Array:
    """Per-head RMS norm on flattened (B, T, d=H*hd)."""
    B, T, d = x.shape
    xs = x.reshape(B, T, H, d // H).astype(jnp.float32)
    xs = xs * jax.lax.rsqrt(jnp.mean(xs * xs, axis=-1, keepdims=True) + eps)
    return (xs.reshape(B, T, d) * scale).astype(x.dtype)


def mlstm_decode(cfg: ArchConfig, p: dict, x: Array,
                 state: MLSTMState) -> tuple[Array, MLSTMState]:
    """One-token recurrent step. x: (B, 1, d)."""
    B, _, d = x.shape
    H = cfg.mlstm_heads or cfg.n_heads
    hd = d // H
    q = (x @ p["wq"]).reshape(B, H, hd) / math.sqrt(hd)
    k = (x @ p["wk"]).reshape(B, H, hd)
    v = (x @ p["wv"]).reshape(B, H, hd)
    log_i, log_f = _mlstm_gates(p, x, H)          # (B, 1, H)
    li, lf = log_i[:, 0], log_f[:, 0]             # (B, H)

    m_new = jnp.maximum(state.m + lf, li)
    w_old = jnp.exp(state.m + lf - m_new)
    w_in = jnp.exp(li - m_new)
    C = w_old[..., None, None] * state.C + \
        w_in[..., None, None] * jnp.einsum("bhd,bhe->bhde",
                                           k.astype(jnp.float32),
                                           v.astype(jnp.float32))
    n = w_old[..., None] * state.n + w_in[..., None] * k.astype(jnp.float32)
    num = jnp.einsum("bhd,bhde->bhe", q.astype(jnp.float32), C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh",
                                         q.astype(jnp.float32), n)), 1.0)
    h = (num / den[..., None]).reshape(B, 1, d)
    h = _group_rmsnorm(h, p["ln"], H)
    out = (h.astype(x.dtype) @ p["wo"]).astype(x.dtype)  # bf16 pre-AR (SSPerf)
    return out, MLSTMState(C=C, n=n, m=m_new)


# ===========================================================================
# sLSTM (scalar memory, exponential gating, head-wise state mixing)
# ===========================================================================

def init_slstm(cfg: ArchConfig, rng: Array, dtype) -> dict:
    d = cfg.d_model
    H = cfg.mlstm_heads or cfg.n_heads
    hd = d // H
    k = jax.random.split(rng, 3)
    s = d ** -0.5
    return {
        # 4 gates (z, i, f, o) from input...
        "w": (jax.random.normal(k[0], (d, 4 * d)) * s).astype(dtype),
        # ...and block-diagonal recurrence per head.
        "r": (jax.random.normal(k[1], (H, hd, 4 * hd)) * hd ** -0.5
              ).astype(dtype),
        "b": jnp.concatenate([jnp.zeros((2 * d,)), 3.0 * jnp.ones((d,)),
                              jnp.zeros((d,))]).astype(jnp.float32),
        "wo": (jax.random.normal(k[2], (d, d)) * s).astype(dtype),
        "ln": jnp.ones((d,), jnp.float32),
    }


class SLSTMState(NamedTuple):
    c: Array   # (B, d) cell
    n: Array   # (B, d) normalizer
    h: Array   # (B, d) hidden
    m: Array   # (B, d) stabilizer


def slstm_init_state(cfg: ArchConfig, B: int, dtype=jnp.float32) -> SLSTMState:
    d = cfg.d_model
    z = jnp.zeros((B, d), dtype)
    return SLSTMState(c=z, n=z, h=z, m=jnp.full((B, d), -1e30, dtype))


def _slstm_step(cfg: ArchConfig, p: dict, state: SLSTMState,
                xt: Array) -> tuple[SLSTMState, Array]:
    """xt: (B, d) -> (new_state, h_out (B, d))."""
    B, d = xt.shape
    H = cfg.mlstm_heads or cfg.n_heads
    hd = d // H
    hh = state.h.reshape(B, H, hd)
    rec = jnp.einsum("bhi,hio->bho", hh.astype(p["r"].dtype), p["r"])
    g = (xt @ p["w"]).astype(jnp.float32) + \
        rec.reshape(B, 4 * d).astype(jnp.float32) + p["b"]
    zt = jnp.tanh(g[:, :d])
    it = g[:, d:2 * d]                       # log-space input gate
    ft = jax.nn.log_sigmoid(g[:, 2 * d:3 * d])
    ot = jax.nn.sigmoid(g[:, 3 * d:])
    m_new = jnp.maximum(state.m + ft, it)
    w_old = jnp.exp(state.m + ft - m_new)
    w_in = jnp.exp(it - m_new)
    c = w_old * state.c + w_in * zt
    n = w_old * state.n + w_in
    h = ot * c / jnp.maximum(n, 1.0)
    return SLSTMState(c=c, n=n, h=h, m=m_new), h


def _slstm_impl(cfg: ArchConfig, p: dict, x: Array,
                return_state: bool = False):
    B, T, d = x.shape
    state0 = slstm_init_state(cfg, B)

    def step(s, xt):
        s2, h = _slstm_step(cfg, p, s, xt)
        return s2, h

    final, hs = jax.lax.scan(step, state0, x.swapaxes(0, 1))
    h = hs.swapaxes(0, 1)                                   # (B, T, d)
    h = _group_rmsnorm(h, p["ln"], cfg.mlstm_heads or cfg.n_heads)
    out = (h.astype(x.dtype) @ p["wo"]).astype(x.dtype)  # bf16 pre-AR (SSPerf)
    if return_state:
        return out, final
    return out


def slstm(cfg: ArchConfig, p: dict, x: Array, return_state: bool = False,
          *, mesh=None, batch_axes=()):
    """Sequential scan over T (sLSTM mixes state across time — no parallel
    form exists; xLSTM uses few sLSTM blocks for exactly this reason).

    With a mesh, the scan runs inside a shard_map island: inputs stay
    batch-sharded, weights replicated, and the recurrent-weight gradient is
    psum'd ONCE at the island boundary. Under plain pjit, GSPMD instead
    re-reduces the replicated dW at EVERY timestep of the bwd scan
    (97 GB/step on xlstm train — EXPERIMENTS.md SSPerf xlstm entry)."""
    if mesh is None or not batch_axes:
        return _slstm_impl(cfg, p, x, return_state)

    from jax.sharding import PartitionSpec as P

    ms = dict(zip(mesh.axis_names, mesh.devices.shape))
    B = x.shape[0]
    axes = tuple(batch_axes)
    while axes:
        n = 1
        for a in axes:
            n *= ms[a]
        if B % n == 0:
            break
        axes = axes[:-1]
    if not axes:
        return _slstm_impl(cfg, p, x, return_state)

    bspec = P(axes, None, None)
    wspec = jax.tree.map(lambda _: P(), p)
    sspec = SLSTMState(*(P(axes, None),) * 4)
    out_specs = (bspec, sspec) if return_state else bspec

    def body(xl, pl_):
        return _slstm_impl(cfg, pl_, xl, return_state)

    fn = shard_map(body, mesh=mesh, in_specs=(bspec, wspec),
                   out_specs=out_specs, check_vma=False)
    return fn(x, p)


def slstm_decode(cfg: ArchConfig, p: dict, x: Array,
                 state: SLSTMState) -> tuple[Array, SLSTMState]:
    s2, h = _slstm_step(cfg, p, state, x[:, 0])
    h = _group_rmsnorm(h[:, None], p["ln"], cfg.mlstm_heads or cfg.n_heads)
    return (h.astype(x.dtype) @ p["wo"]).astype(x.dtype), s2  # bf16 pre-AR


# ===========================================================================
# Mamba-style diagonal SSD (Hymba's SSM heads)
# ===========================================================================

def init_mamba(cfg: ArchConfig, rng: Array, dtype, d_inner: int) -> dict:
    d = cfg.d_model
    S = cfg.ssm_state
    H = d_inner // cfg.head_dim            # mamba heads, same head_dim
    k = jax.random.split(rng, 6)
    s = d ** -0.5
    return {
        "w_in": (jax.random.normal(k[0], (d, 2 * d_inner)) * s).astype(dtype),
        "w_bc": (jax.random.normal(k[1], (d, 2 * S)) * s).astype(dtype),
        "w_dt": (jax.random.normal(k[2], (d, H)) * s).astype(dtype),
        "b_dt": jnp.log(jnp.expm1(jnp.exp(jax.random.uniform(
            k[3], (H,), minval=jnp.log(0.001), maxval=jnp.log(0.1))))
        ).astype(jnp.float32),
        "A_log": jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "conv": (jax.random.normal(k[4], (4, d_inner)) * 0.5).astype(dtype),
        "w_out": (jax.random.normal(k[5], (d_inner, d)) *
                  d_inner ** -0.5).astype(dtype),
        "ln": jnp.ones((d_inner,), jnp.float32),
    }


class MambaState(NamedTuple):
    h: Array        # (B, H, hd, S) SSM state
    conv: Array     # (B, 3, d_inner) last inputs for the causal conv


def mamba_init_state(cfg: ArchConfig, B: int, d_inner: int,
                     dtype=jnp.float32) -> MambaState:
    H = d_inner // cfg.head_dim
    return MambaState(h=jnp.zeros((B, H, cfg.head_dim, cfg.ssm_state), dtype),
                      conv=jnp.zeros((B, 3, d_inner), dtype))


def _causal_conv(xc: Array, w: Array) -> Array:
    """Depthwise causal conv, window 4. xc (B, T, C), w (4, C)."""
    pad = jnp.pad(xc, ((0, 0), (3, 0), (0, 0)))
    out = sum(pad[:, i:i + xc.shape[1]] * w[i] for i in range(4))
    return out


def mamba(cfg: ArchConfig, p: dict, x: Array, d_inner: int,
          return_state: bool = False, project: bool = True):
    """Full-sequence SSD via associative scan. x: (B, T, d).

    project=False returns the gated pre-projection activations so hybrid
    blocks can FUSE the mamba out-projection with the attention wo into one
    partial-sum dot -> one TP all-reduce (EXPERIMENTS.md SSPerf hymba 3b).
    """
    B, T, d = x.shape
    hd = cfg.head_dim
    H = d_inner // hd
    S = cfg.ssm_state

    xz = x @ p["w_in"]
    xc, z = xz[..., :d_inner], xz[..., d_inner:]
    xc = jax.nn.silu(_causal_conv(xc, p["conv"]))
    bc = x @ p["w_bc"]
    Bm, Cm = bc[..., :S], bc[..., S:]                   # (B, T, S)
    dt = jax.nn.softplus((x @ p["w_dt"]).astype(jnp.float32) + p["b_dt"])
    A = -jnp.exp(p["A_log"])                            # (H,) negative
    decay = jnp.exp(dt * A)                             # (B, T, H)

    xh = xc.reshape(B, T, H, hd).astype(jnp.float32)

    # Chunked scan: the (B, T, H, hd, S) state sequence would be ~16x the
    # activation size; scanning CHUNK-sized windows with an intra-chunk
    # associative scan keeps the state working set to one chunk.
    W = min(CHUNK, T)
    W = W if T % W == 0 else math.gcd(T, W)
    nc = T // W

    def combine(a, b):
        d1, s1 = a
        d2, s2 = b
        return d1 * d2, s1 * d2 + s2

    def chunk_body(h_in, inp_c):
        dt_c, xh_c, B_c, C_c, dec_c = inp_c            # (B, W, ...) leading
        inp = jnp.einsum("bth,bthd,bts->bthds", dt_c, xh_c, B_c)
        dec = dec_c[..., None, None]                   # (B, W, H, 1, 1)
        cumdec, hwithin = jax.lax.associative_scan(combine, (dec, inp),
                                                   axis=1)
        h_t = cumdec * h_in[:, None] + hwithin         # (B, W, H, hd, S)
        y_c = jnp.einsum("bthds,bts->bthd", h_t, C_c)
        return h_t[:, -1], y_c

    xs = tuple(jnp.moveaxis(a.reshape(B, nc, W, *a.shape[2:]), 1, 0)
               for a in (dt, xh, Bm.astype(jnp.float32),
                         Cm.astype(jnp.float32), decay))
    h0 = jnp.zeros((B, H, hd, S), jnp.float32)
    h_final, ys = jax.lax.scan(chunk_body, h0, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, T, H, hd)
    y = y + xh * p["D"][None, None, :, None]
    y = y.reshape(B, T, d_inner)
    y = _group_rmsnorm(y, p["ln"], H)
    y = y * jax.nn.silu(z)
    # Cast BEFORE the row-parallel out projection: GSPMD all-reduces the
    # partial dot output, and a f32 partial doubles TP collective bytes
    # (EXPERIMENTS.md SSPerf hymba iteration 3).
    out = y.astype(x.dtype) if not project else \
        (y.astype(x.dtype) @ p["w_out"]).astype(x.dtype)
    if return_state:
        xc_raw = xz[..., :d_inner]                      # pre-conv inputs
        pad = jnp.concatenate([jnp.zeros((B, 3, d_inner), xc_raw.dtype),
                               xc_raw], axis=1)
        state = MambaState(h=h_final, conv=pad[:, T:T + 3])
        return out, state
    return out


def mamba_decode(cfg: ArchConfig, p: dict, x: Array, state: MambaState,
                 d_inner: int) -> tuple[Array, MambaState]:
    """One-token step. x: (B, 1, d)."""
    B, _, d = x.shape
    hd = cfg.head_dim
    H = d_inner // hd
    S = cfg.ssm_state

    xz = x[:, 0] @ p["w_in"]
    xc_t, z = xz[..., :d_inner], xz[..., d_inner:]
    window = jnp.concatenate([state.conv, xc_t[:, None]], axis=1)  # (B,4,di)
    xc = jax.nn.silu(jnp.einsum("bkc,kc->bc", window.astype(jnp.float32),
                                p["conv"].astype(jnp.float32)))
    bc = x[:, 0] @ p["w_bc"]
    Bm, Cm = bc[..., :S], bc[..., S:]
    dt = jax.nn.softplus((x[:, 0] @ p["w_dt"]).astype(jnp.float32) + p["b_dt"])
    A = -jnp.exp(p["A_log"])
    decay = jnp.exp(dt * A)                             # (B, H)

    xh = xc.reshape(B, H, hd).astype(jnp.float32)
    inp = jnp.einsum("bh,bhd,bs->bhds", dt, xh, Bm.astype(jnp.float32))
    h = state.h * decay[..., None, None] + inp
    y = jnp.einsum("bhds,bs->bhd", h, Cm.astype(jnp.float32))
    y = y + xh * p["D"][None, :, None]
    y = y.reshape(B, 1, d_inner)
    y = _group_rmsnorm(y, p["ln"], H)
    y = y * jax.nn.silu(z)[:, None]
    out = (y @ p["w_out"]).astype(x.dtype)
    return out, MambaState(h=h, conv=window[:, 1:])
