"""Encoder-decoder assembly (seamless-m4t-medium [arXiv:2308.11596]).

Per the brief, the modality frontend (mel-spectrogram + conv feature
extractor) is a STUB: input_specs() supplies precomputed frame embeddings
(B, T_enc, d) and this module implements the transformer backbone — a
bidirectional encoder over the frames and a causal decoder with per-layer
cross-attention, sharing layers.py primitives (GQA kv=16 is full MHA here).

Both stacks are scanned over layers. Serving caches hold the decoder
self-attention KV plus the encoder memory K/V precomputed once at prefill.
"""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import head as dismec_head
from repro.models import layers
from repro.models.transformer import (ovr_loss_from_feats,
                                      softmax_loss_from_feats,
                                      _attention_decode_dyn)

Array = jax.Array


def _init_enc_block(cfg: ArchConfig, rng: Array, dtype) -> dict:
    k = jax.random.split(rng, 2)
    return {"norm1": layers.init_norm(cfg, cfg.d_model),
            "attn": layers.init_attention(cfg, k[0], dtype),
            "norm2": layers.init_norm(cfg, cfg.d_model),
            "mlp": layers.init_mlp(k[1], cfg.d_model, cfg.d_ff, dtype,
                                   cfg.act)}


def _init_dec_block(cfg: ArchConfig, rng: Array, dtype) -> dict:
    k = jax.random.split(rng, 3)
    return {"norm1": layers.init_norm(cfg, cfg.d_model),
            "attn": layers.init_attention(cfg, k[0], dtype),
            "norm_x": layers.init_norm(cfg, cfg.d_model),
            "xattn": layers.init_attention(cfg, k[1], dtype),
            "norm2": layers.init_norm(cfg, cfg.d_model),
            "mlp": layers.init_mlp(k[2], cfg.d_model, cfg.d_ff, dtype,
                                   cfg.act)}


def init_params(cfg: ArchConfig, rng: Array) -> dict:
    dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    Vp = cfg.padded_vocab()
    ke, kenc, kdec, kh = jax.random.split(rng, 4)
    n_enc = cfg.n_encoder_layers or cfg.n_layers
    return {
        "embed": (jax.random.normal(ke, (Vp, cfg.d_model)) *
                  cfg.d_model ** -0.5).astype(dtype),
        "enc_blocks": jax.vmap(lambda r: _init_enc_block(cfg, r, dtype))(
            jax.random.split(kenc, n_enc)),
        "enc_norm": layers.init_norm(cfg, cfg.d_model),
        "dec_blocks": jax.vmap(lambda r: _init_dec_block(cfg, r, dtype))(
            jax.random.split(kdec, cfg.n_layers)),
        "final_norm": layers.init_norm(cfg, cfg.d_model),
        "head": dismec_head.init_head(kh, Vp, cfg.d_model, dtype),
    }


def encode(cfg: ArchConfig, params: dict, frames: Array,
           remat: bool = True) -> Array:
    """Bidirectional encoder over stub frame embeddings (B, T_enc, d)."""
    B, T, _ = frames.shape
    positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
    dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    x = frames.astype(dtype)  # match param dtype (transformer.prefill does same)

    def body(xx, blk):
        def f(b, x_):
            h = layers.apply_norm(cfg, b["norm1"], x_)
            x_ = x_ + layers.attention(cfg, b["attn"], h, positions,
                                       is_causal=False)
            h2 = layers.apply_norm(cfg, b["norm2"], x_)
            return x_ + layers.mlp(b["mlp"], h2, cfg.act)
        fn = jax.checkpoint(f) if remat else f
        return fn(blk, xx), None

    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return layers.apply_norm(cfg, params["enc_norm"], x)


def _memory_kv(cfg: ArchConfig, blk: dict, memory: Array):
    B, S, _ = memory.shape
    k = (memory @ blk["xattn"]["wk"]).reshape(B, S, cfg.n_kv_heads,
                                              cfg.head_dim)
    v = (memory @ blk["xattn"]["wv"]).reshape(B, S, cfg.n_kv_heads,
                                              cfg.head_dim)
    return k, v


def decode_train(cfg: ArchConfig, params: dict, tokens: Array,
                 memory: Array, remat: bool = True) -> Array:
    """Causal decoder with cross-attention; returns features (B, T, d)."""
    x = jnp.take(params["embed"], tokens, axis=0)
    B, T, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))

    def body(xx, blk):
        def f(b, x_):
            h = layers.apply_norm(cfg, b["norm1"], x_)
            x_ = x_ + layers.attention(cfg, b["attn"], h, positions)
            hx = layers.apply_norm(cfg, b["norm_x"], x_)
            mem_kv = _memory_kv(cfg, b, memory)
            x_ = x_ + layers.cross_attention(cfg, b["xattn"], hx, mem_kv)
            h2 = layers.apply_norm(cfg, b["norm2"], x_)
            return x_ + layers.mlp(b["mlp"], h2, cfg.act)
        fn = jax.checkpoint(f) if remat else f
        return fn(blk, xx), None

    x, _ = jax.lax.scan(body, x, params["dec_blocks"])
    return layers.apply_norm(cfg, params["final_norm"], x)


def train_loss(cfg: ArchConfig, params: dict, batch: dict, *, mesh=None,
               batch_axes=()) -> tuple[Array, dict]:
    memory = encode(cfg, params, batch["prefix"])
    feats = decode_train(cfg, params, batch["tokens"], memory)
    W = params["head"]
    if cfg.head_type == "dismec":
        loss = ovr_loss_from_feats(cfg, W, feats, batch["targets"],
                                   batch.get("valid"), mesh=mesh,
                                   batch_axes=batch_axes)
    else:
        loss = softmax_loss_from_feats(W, feats, batch["targets"],
                                       batch.get("valid"), mesh=mesh,
                                       batch_axes=batch_axes)
    return loss, {"loss": loss, "aux": jnp.zeros((), jnp.float32)}


def init_cache(cfg: ArchConfig, B: int, seq_len: int, t_enc: int,
               dtype=jnp.bfloat16) -> dict:
    L = cfg.n_layers
    kv = (L, B, seq_len, cfg.n_kv_heads, cfg.head_dim)
    mem = (L, B, t_enc, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(kv, dtype), "v": jnp.zeros(kv, dtype),
            "mem_k": jnp.zeros(mem, dtype), "mem_v": jnp.zeros(mem, dtype)}


def prefill(cfg: ArchConfig, params: dict, tokens: Array, frames: Array):
    """Encode + decode the prompt, build all caches, return top-k + cache."""
    memory = encode(cfg, params, frames, remat=False)
    x = jnp.take(params["embed"], tokens, axis=0)
    B, T, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))

    def body(xx, blk):
        h = layers.apply_norm(cfg, blk["norm1"], xx)
        q, k, v = layers._qkv(cfg, blk["attn"], h, positions)
        if T > layers.DENSE_ATTN_MAX_T:
            a = layers.blockwise_attention(cfg, q, k, v)
        else:
            a = layers._sdpa(cfg, q, k, v, layers.causal_mask(T, T))
        xx = xx + a @ blk["attn"]["wo"]
        hx = layers.apply_norm(cfg, blk["norm_x"], xx)
        mk, mv = _memory_kv(cfg, blk, memory)
        xx = xx + layers.cross_attention(cfg, blk["xattn"], hx, (mk, mv))
        h2 = layers.apply_norm(cfg, blk["norm2"], xx)
        xx = xx + layers.mlp(blk["mlp"], h2, cfg.act)
        return xx, (k.astype(jnp.bfloat16), v.astype(jnp.bfloat16),
                    mk.astype(jnp.bfloat16), mv.astype(jnp.bfloat16))

    x, (kc, vc, mk, mv) = jax.lax.scan(body, x, params["dec_blocks"])
    x = layers.apply_norm(cfg, params["final_norm"], x)
    logits = x[:, -1].astype(jnp.float32) @ params["head"].T.astype(jnp.float32)
    vals, idx = jax.lax.top_k(logits, 5)
    return vals, idx, {"k": kc, "v": vc, "mem_k": mk, "mem_v": mv}


def decode_step(cfg: ArchConfig, params: dict, cache: dict, tokens: Array,
                pos: Array, *, top_k: int = 5, **_):
    """serve_step: one decoder token against self + memory caches."""
    B = tokens.shape[0]
    x = jnp.take(params["embed"], tokens, axis=0)
    positions = jnp.broadcast_to(pos, (B, 1)).astype(jnp.int32)
    eff = jnp.int32(2 ** 30)

    def body(xx, xs):
        blk, kc, vc, mk, mv = xs
        h = layers.apply_norm(cfg, blk["norm1"], xx)
        a, kc, vc = _attention_decode_dyn(cfg, blk["attn"], h, positions,
                                          kc, vc, pos, eff)
        xx = xx + a
        hx = layers.apply_norm(cfg, blk["norm_x"], xx)
        xx = xx + layers.cross_attention(cfg, blk["xattn"], hx, (mk, mv))
        h2 = layers.apply_norm(cfg, blk["norm2"], xx)
        xx = xx + layers.mlp(blk["mlp"], h2, cfg.act)
        return xx, (kc, vc)

    x, (kc, vc) = jax.lax.scan(
        body, x, (params["dec_blocks"], cache["k"], cache["v"],
                  cache["mem_k"], cache["mem_v"]))
    x = layers.apply_norm(cfg, params["final_norm"], x)
    logits = x[:, 0].astype(jnp.float32) @ params["head"].T.astype(jnp.float32)
    vals, idx = jax.lax.top_k(logits, top_k)
    new_cache = dict(cache)
    new_cache["k"], new_cache["v"] = kc, vc
    return vals, idx, new_cache
