"""AdamW with decoupled weight decay and global-norm clipping.

Moments are stored in f32 regardless of param dtype (bf16 training needs f32
optimizer state); state pytrees mirror the param tree, so the param
PartitionSpecs (models/sharding.py) apply verbatim to the state — the
optimizer is FSDP-sharded for free.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array


class AdamWState(NamedTuple):
    step: Array
    mu: dict
    nu: dict


def adamw_init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      mu=jax.tree.map(zeros, params),
                      nu=jax.tree.map(zeros, params))


def global_norm(tree) -> Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def adamw_update(params, grads, state: AdamWState, lr: Array, *,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1, clip_norm: float = 1.0):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu2 = b1 * mu + (1.0 - b1) * g
        nu2 = b2 * nu + (1.0 - b2) * g * g
        mhat = mu2 / c1
        vhat = nu2 / c2
        delta = mhat / (jnp.sqrt(vhat) + eps)
        decay = weight_decay * p.astype(jnp.float32) if p.ndim >= 2 else 0.0
        p2 = p.astype(jnp.float32) - lr * (delta + decay)
        return p2.astype(p.dtype), mu2, nu2

    flat = jax.tree.map(upd, params, grads, state.mu, state.nu)
    new_params = jax.tree.map(lambda t: t[0], flat,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda t: t[1], flat,
                          is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda t: t[2], flat,
                          is_leaf=lambda x: isinstance(x, tuple))
    return new_params, AdamWState(step, new_mu, new_nu), {"grad_norm": gnorm}
