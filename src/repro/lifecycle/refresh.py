"""Zero-downtime model refresh: generation polling -> hot swap.

The deployment loop the ROADMAP's north star asks for: a trainer (or a
sweep session) keeps writing new checkpoints; the serving process picks
each one up without dropping a request and without restarting.

The contract is split across three layers so each piece stays simple:

  checkpoint/io.py  owns the **generation counter** — every fresh write
                    into a directory publishes `prior + 1`, and
                    `checkpoint_generation()` only ever reports *servable*
                    checkpoints (a streaming manifest that has not flipped
                    `complete` reads as None). A half-written model is
                    therefore invisible here by construction.
  serve/server.py   owns the **swap** — `XMCServer.swap(engine)` warms the
                    replacement off-thread and flips the reference between
                    micro-batches (see its docstring for the state
                    machine).
  this module       owns the **watching**: `CheckpointWatcher` polls the
                    generation counter and calls swap when it moves.

`ModelRouter.watch(name, dir)` attaches a watcher to a routed server and
`launch/serve.py --watch` exposes the whole loop on the CLI. Rollback
needs no machinery: the server retains `previous_engine`, so
`server.swap(server.previous_engine)` is the rollback.
"""

from __future__ import annotations

import threading
import time
from typing import Callable, Optional

from repro.checkpoint.io import checkpoint_generation


class CheckpointWatcher:
    """Poll one out_dir's generation counter; hot-swap a server on change.

    Polling (not inotify) on purpose: checkpoints land on shared/remote
    filesystems where event APIs are unreliable, and the poll is two small
    JSON reads. Each `poll_once()`:

      1. reads `checkpoint_generation(directory)` — None (nothing servable
         yet / stream mid-write) never triggers anything, which is the
         "never swap a half-written generation" guarantee;
      2. on a generation newer than the last one seen, opens the
         checkpoint strictly (`CheckpointHandle.open`), builds the engine
         its spec (or `serve_override`) describes, and `server.swap`s it
         in — the old model serves until the new one is warm.

    The constructor samples the directory's current generation as the
    baseline (the server was just built from it); pass
    `swap_existing=True` to treat whatever is on disk as new, e.g. when
    the server started on a different checkpoint.

    `start()` runs the poll on a daemon thread every `poll_interval_s`;
    `stop()` joins it. `poll_once()` is public so tests and cron-style
    callers can drive the loop deterministically. A poll that fails
    (checkpoint vanished mid-read, swap rejected) stores the exception on
    `last_error` and keeps watching — a broken nightly build must not kill
    the serving process.
    """

    def __init__(self, directory: str, server, *,
                 serve_override=None, mesh=None,
                 poll_interval_s: float = 2.0,
                 swap_existing: bool = False,
                 on_swap: Optional[Callable] = None):
        if poll_interval_s <= 0:
            raise ValueError(f"poll_interval_s must be > 0, got "
                             f"{poll_interval_s}")
        self.directory = directory
        self.server = server
        self.serve_override = serve_override
        self.mesh = mesh
        self.poll_interval_s = float(poll_interval_s)
        self.on_swap = on_swap
        self.generation = (None if swap_existing
                           else checkpoint_generation(directory))
        self.last_error: Optional[BaseException] = None
        self.swaps = 0
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    def poll_once(self):
        """One poll step: swap if a newer finalized generation landed.
        Returns the new `CheckpointHandle` on a swap, else None."""
        from repro.xmc_api import CheckpointHandle   # deferred: no cycle
        try:
            gen = checkpoint_generation(self.directory)
            if gen is None or (self.generation is not None
                               and gen <= self.generation):
                return None
            handle = CheckpointHandle.open(self.directory)   # strict
            serve = (self.serve_override or handle.spec.serve).validate()
            # swap() warms the server's own buckets — skip the engine's
            # construction-time warm-up so nothing compiles twice.
            engine = handle.engine(serve.replace(warmup=False),
                                   mesh=self.mesh)
            prev = self.server.swap(engine)
            self.generation = gen
            self.swaps += 1
            self.last_error = None
            if self.on_swap is not None:
                self.on_swap(gen, handle, prev)
            return handle
        except Exception as e:                       # noqa: BLE001
            self.last_error = e
            return None

    # -- background thread ------------------------------------------------

    def start(self) -> "CheckpointWatcher":
        if self._thread is None:
            self._thread = threading.Thread(
                target=self._loop, name=f"ckpt-watch-{self.directory}",
                daemon=True)
            self._thread.start()
        return self

    def _loop(self) -> None:
        while not self._stop.wait(self.poll_interval_s):
            self.poll_once()

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def __enter__(self) -> "CheckpointWatcher":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()
