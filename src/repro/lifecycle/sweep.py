"""Warm-start sweep sessions: DiSMEC's Fig. 5 as a driver, not a script.

The paper's capacity-control story is a sweep: train once, then re-train
under different Delta (and C) values and read the model-size/precision
frontier. The repo already has every primitive — `fit(init_from=...)`
warm-starts from a prior checkpoint (bit-identical fixed point for an
unchanged spec), each out_dir is its own lease-aware manifest, and the
serving engines report exact model sizes. `sweep()` composes them:

    base arm   fit(X, Y, base_spec, out_root/base)           (cold)
    arm i      fit(X, Y, spec_i,    out_root/<name>, init_from=base)

Arms fan out over a pool of `workers` threads; each arm is an independent
manifest, so per-arm multi-host scaling still works by pointing extra
`fit` processes at that arm's out_dir (the lease table coordinates them,
regardless of what this driver is doing). Arm results are deterministic
in (spec, data) — worker count and scheduling order never change any
checkpoint byte, which `tests/test_lifecycle.py` pins.

The **fixed-point check** is the correctness anchor: an arm whose
canonical solver+schedule equals the base's must reproduce the base
checkpoint bit-for-bit (warm start from a converged model re-derives it).
`sweep` verifies this on every such arm and records it in the report; a
False there means the warm-start path drifted and every other arm's
numbers are suspect.

The `SweepReport` carries per-arm model_mb (fp32 (value, index) pairs,
the fig5 accounting) / int8_mb (serving payload) / nnz fraction / holdout
P@k, and a declarative `SweepPolicy` (repro.specs) picks the winner —
feed it to `ModelRouter.refresh` and the sweep becomes a deployment.
"""

from __future__ import annotations

import dataclasses
import os
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Optional, Union

import numpy as np

from repro.specs import ServeSpec, SweepPolicy


@dataclasses.dataclass
class SweepArm:
    """One fitted sweep arm and its frontier coordinates."""
    name: str
    out_dir: str
    spec: object                       # XMCSpec
    C: float
    delta: float
    nnz: int
    nnz_frac: float                    # nnz / (L * D)
    model_mb: float                    # fp32 (value, index) pairs, fig5 style
    int8_mb: float                     # int8 serving payload (+ scales etc.)
    n_blocks: int
    metrics: dict                      # {"P@1": ..., "nDCG@5": ...} or {}
    train_s: float
    warm_started: bool
    fixed_point: Optional[bool] = None  # bit-identical to base (same-spec
    #                                     arms only; None otherwise)

    def row(self) -> dict:
        """JSON-ready summary (spec collapsed to its dict form)."""
        d = dataclasses.asdict(self)
        d["spec"] = self.spec.to_dict()
        return d


@dataclasses.dataclass
class SweepReport:
    """Everything a sweep produced: arms (base first), policy, winner."""
    out_root: str
    policy: SweepPolicy
    arms: list                          # [SweepArm, ...]; arms[0] is base
    winner: str                         # arm name the policy selected

    @property
    def base(self) -> SweepArm:
        return self.arms[0]

    def arm(self, name: str) -> SweepArm:
        for a in self.arms:
            if a.name == name:
                return a
        raise KeyError(f"no sweep arm {name!r}; have "
                       f"{[a.name for a in self.arms]}")

    @property
    def winner_dir(self) -> str:
        """Checkpoint directory of the winning arm — hand this to
        `ModelRouter.refresh` / `CheckpointHandle.open` to deploy it."""
        return self.arm(self.winner).out_dir

    def to_dict(self) -> dict:
        return {"out_root": self.out_root,
                "policy": self.policy.to_dict(),
                "winner": self.winner,
                "arms": [a.row() for a in self.arms]}


def models_bit_identical(dir_a: str, dir_b: str) -> bool:
    """True iff two checkpoints hold byte-for-byte the same packed model
    (blocks, block coordinates, row_ptr, shapes). The warm-start
    fixed-point test, as an equality instead of an assertion."""
    from repro.checkpoint.io import load_block_sparse   # deferred: no cycle
    a, _ = load_block_sparse(dir_a)
    b, _ = load_block_sparse(dir_b)
    if (a.shape != b.shape or a.block_shape != b.block_shape
            or a.orig_shape != b.orig_shape):
        return False
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in ((a.blocks, b.blocks),
                            (a.block_rows, b.block_rows),
                            (a.block_cols, b.block_cols),
                            (a.row_ptr, b.row_ptr)))


def _arm_spec(base_spec, variation):
    """An arm's full spec: an explicit XMCSpec passes through; a dict is
    solver-field overrides on the base (the common Delta/C sweep form)."""
    if isinstance(variation, dict):
        return base_spec.replace(
            solver=base_spec.solver.replace(**variation))
    return variation


def _same_solution(spec_a, spec_b) -> bool:
    """Whether two specs pin the same solved weights: canonical solver +
    schedule equal (serving and runtime knobs never touch the solution)."""
    ca, cb = spec_a.normalized().canonical(), spec_b.normalized().canonical()
    return ca.solver == cb.solver and ca.schedule == cb.schedule


def _measure_arm(name, handle, spec, *, holdout, eval_ks, train_s,
                 warm_started) -> SweepArm:
    """Frontier coordinates of one fitted arm, from its checkpoint."""
    from repro.checkpoint.io import load_block_sparse_int8  # deferred
    model, meta = handle.model()
    int8_model, _ = load_block_sparse_int8(handle.directory, model=model)
    blocks = np.asarray(model.blocks)
    n_nz = int(np.count_nonzero(blocks))
    L, D = model.orig_shape
    metrics: dict = {}
    if holdout is not None:
        Xh, Yh = holdout
        engine = handle.engine(ServeSpec(
            backend="bsr", k=max(eval_ks), warmup=False))
        labels = engine.serve([np.asarray(Xh, np.float32)])[0].labels
        from repro.core.prediction import evaluate          # deferred: jax
        metrics = evaluate(np.asarray(Yh), np.asarray(labels), ks=eval_ks)
    return SweepArm(
        name=name, out_dir=handle.directory, spec=spec,
        C=float(spec.solver.C), delta=float(spec.solver.delta),
        nnz=n_nz, nnz_frac=n_nz / float(L * D),
        model_mb=n_nz * 8 / 1e6,                 # (value, index) pairs
        int8_mb=int8_model.payload_bytes() / 1e6,
        n_blocks=int(model.n_blocks),
        metrics=metrics, train_s=train_s, warm_started=warm_started)


def sweep(X, Y, base_spec, variations: dict[str, Union[dict, object]],
          out_root: str, *, workers: int = 1,
          policy: Optional[SweepPolicy] = None,
          holdout: Optional[tuple] = None,
          eval_ks: tuple[int, ...] = (1, 3, 5),
          resume: bool = True) -> SweepReport:
    """Fit a warm-start sweep and pick a winner.

    X, Y       : training data, as `fit` takes them.
    base_spec  : the anchor experiment; fitted (cold) into
                 `out_root/base` first, then every arm warm-starts from
                 it (`fit(..., init_from=<base dir>)`).
    variations : arm name -> either a dict of `SolverSpec` overrides
                 (`{"delta": 0.05}` — the Fig. 5 form) or a full XMCSpec.
                 Each arm trains into `out_root/<name>`.
    workers    : arms fitted concurrently by this driver. Results are
                 deterministic in (spec, data) — the worker count and
                 completion order cannot change a checkpoint byte. For
                 *within-arm* multi-host scaling, point extra `fit`
                 processes at an arm's out_dir; its lease table does the
                 rest.
    policy     : declarative winner rule (`repro.specs.SweepPolicy`);
                 default picks max precision when a holdout is given,
                 else the smallest model (without labels there is nothing
                 else to rank by).
    holdout    : optional (X_test, Y_test) — per-arm P@k / nDCG@k on it.
    eval_ks    : precision depths to evaluate.
    resume     : passed to every `fit` — a killed sweep re-run skips
                 arms/batches already in their manifests.

    Any arm whose canonical solver+schedule equals the base's gets the
    warm-start **fixed-point check**: its checkpoint must be bit-identical
    to the base (`SweepArm.fixed_point`).
    """
    if "base" in variations:
        raise ValueError("arm name 'base' is reserved for the warm-start "
                         "source")
    for name in variations:
        if not name or os.sep in name or name != name.strip():
            raise ValueError(f"arm name {name!r} must be a plain directory "
                             "name")
    if workers < 1:
        raise ValueError(f"workers must be >= 1, got {workers}")
    if policy is None:
        policy = (SweepPolicy(kind="max_precision",
                              metric=f"P@{max(eval_ks)}")
                  if holdout is not None else SweepPolicy(kind="min_size"))
    policy.validate()

    from repro.xmc_api import fit                # deferred: jax-heavy import
    base_dir = os.path.join(out_root, "base")
    t0 = time.monotonic()
    base_handle = fit(X, Y, base_spec, base_dir, resume=resume)
    base_arm = _measure_arm(
        "base", base_handle, base_spec, holdout=holdout, eval_ks=eval_ks,
        train_s=time.monotonic() - t0, warm_started=False)

    specs = {name: _arm_spec(base_spec, v) for name, v in variations.items()}

    def run_arm(name: str) -> SweepArm:
        spec = specs[name]
        t_arm = time.monotonic()
        handle = fit(X, Y, spec, os.path.join(out_root, name),
                     init_from=base_dir, resume=resume)
        arm = _measure_arm(name, handle, spec, holdout=holdout,
                           eval_ks=eval_ks,
                           train_s=time.monotonic() - t_arm,
                           warm_started=True)
        if _same_solution(spec, base_spec):
            arm.fixed_point = models_bit_identical(handle.directory,
                                                   base_dir)
        return arm

    names = list(variations)
    if workers == 1 or len(names) <= 1:
        arms = [run_arm(n) for n in names]
    else:
        with ThreadPoolExecutor(max_workers=workers,
                                thread_name_prefix="sweep-arm") as pool:
            arms = list(pool.map(run_arm, names))

    all_arms = [base_arm] + arms
    winner = policy.select(all_arms).name
    return SweepReport(out_root=out_root, policy=policy, arms=all_arms,
                       winner=winner)
