"""Model lifecycle: sweep sessions and zero-downtime serving refresh.

The layer between training and serving that a deployment actually runs:

  sweep.py    — `sweep()`: DiSMEC's Fig. 5 Delta/C sweep as a warm-start
                session (base fit, arms fanned out across workers, per-arm
                size/precision report, declarative winner policy).
  refresh.py  — `CheckpointWatcher`: poll a checkpoint directory's
                generation counter and hot-swap a live `XMCServer` when a
                newer finalized model lands; rollback via the server's
                retained `previous_engine`.

`ModelRouter.refresh` / `.watch` (repro.serve.server) and
`launch/serve.py --watch` are the serving-side entry points; the
generation counter itself lives in `repro.checkpoint.io`.
"""

from repro.lifecycle.refresh import CheckpointWatcher
from repro.lifecycle.sweep import (SweepArm, SweepReport,
                                   models_bit_identical, sweep)

__all__ = ["CheckpointWatcher", "SweepArm", "SweepReport",
           "models_bit_identical", "sweep"]
