"""ScheduleSpec: how the label space is walked and laid out on hardware.

Layer 1 of Algorithm 1 as data: the label-batch size the streaming
scheduler loops over, the mesh shape the per-batch solve shards onto,
frequency balancing, and the double-buffering knobs. None of this changes
*what* is solved (that is `SolverSpec`), only where and in what order —
which is why `fingerprint()` drops the knobs that are proven
solution-neutral (`overlap`, `max_inflight`: checkpoints are
byte-identical either way) while keeping the ones that change reduction
order (mesh topology, `shard_data`, `balance`).
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Optional

from repro.specs.base import Spec


@dataclasses.dataclass(frozen=True)
class ScheduleSpec(Spec):
    """Label-batch scheduling + mesh layout of one training run.

    label_batch  : paper's per-node batch size (layer 1); `normalized()`
                   rounds it up to a multiple of the BSR block height.
    block_shape  : (bl, bd) BSR tile of the streamed checkpoint.
    mesh         : None for single-device, else (data_size, model_size)
                   axis extents; axes are named by data_axis/label_axis.
    shard_data   : also shard instances over the data axis (psum'd Newton).
    balance      : frequency-balanced label->shard dealing per batch.
    overlap      : double-buffer the scheduler (dispatch batch b+1 before
                   batch b's result leaves the device).
    max_inflight : bound on un-drained device results when overlapping.
    workers      : declared size of the cooperative multi-host drain; > 1
                   switches the scheduler to lease-based batch claiming
                   over the shared manifest (N `fit()` processes on one
                   `out_dir` -> one checkpoint). Like overlap, this never
                   changes the solved weights — any worker count writes a
                   bit-identical checkpoint.
    lease_ttl    : seconds before an unrefreshed batch lease expires and
                   the batch is re-dealt (crash recovery latency; solves
                   are heartbeat-refreshed well inside it).
    reorder_labels : pack the label space under a deterministic
                   co-occurrence clustering permutation
                   (`serve.shortlist.cooccurrence_label_order`): fit()
                   trains over `Y[:, order]`, the permutation is recorded
                   in the manifest as `label_order`, and the serving
                   engine maps top-k ids back exactly. Makes real label
                   spaces block-local (co-occurring labels share BSR row
                   blocks) so a small shortlist width covers correlated
                   top-k sets. Changes the packed checkpoint, so it is
                   part of the resume fingerprint (dropped when False to
                   keep pre-knob checkpoints resumable).
    """
    # The paper's per-node batch is ~1000; the default is rounded to the
    # BSR block grid so the no-argument spec is already normalized (a
    # misaligned value would warn and round up on every fit()).
    label_batch: int = 1024
    block_shape: tuple[int, int] = (128, 128)
    mesh: Optional[tuple[int, int]] = None
    label_axis: str = "model"
    data_axis: str = "data"
    shard_data: bool = False
    balance: bool = False
    overlap: bool = True
    max_inflight: int = 2
    workers: int = 1
    lease_ttl: float = 300.0
    reorder_labels: bool = False

    def validate(self) -> "ScheduleSpec":
        if self.label_batch < 1:
            raise ValueError(f"label_batch must be >= 1, got "
                             f"{self.label_batch}")
        if any(b < 1 for b in self.block_shape):
            raise ValueError(f"block_shape must be positive, got "
                             f"{self.block_shape}")
        if self.mesh is not None and any(int(s) < 1 for s in self.mesh):
            raise ValueError(f"mesh axis sizes must be >= 1, got {self.mesh}")
        if self.max_inflight < 1:
            raise ValueError(f"max_inflight must be >= 1, got "
                             f"{self.max_inflight}")
        if self.workers < 1:
            raise ValueError(f"workers must be >= 1, got {self.workers}")
        if self.lease_ttl <= 0.0:
            raise ValueError(f"lease_ttl must be positive, got "
                             f"{self.lease_ttl}")
        return self

    def normalized(self) -> "ScheduleSpec":
        """Round `label_batch` up to a multiple of the BSR block height
        (with a warning) instead of letting the streaming writer raise:
        streamed shards must be row-block-aligned to append without
        re-tiling, and a slightly larger batch is always a valid way to
        satisfy that."""
        self.validate()
        bl = self.block_shape[0]
        if self.label_batch % bl == 0:
            return self
        rounded = -(-self.label_batch // bl) * bl
        warnings.warn(
            f"label_batch={self.label_batch} is not a multiple of the BSR "
            f"block height {bl}; rounding up to {rounded} so streamed "
            "shards stay block-aligned", UserWarning, stacklevel=2)
        return dataclasses.replace(self, label_batch=rounded)

    def make_mesh(self):
        """Build the device mesh this spec names (None when unsharded)."""
        if self.mesh is None:
            return None
        from repro.compat import make_mesh            # deferred: no jax here
        d, m = (int(s) for s in self.mesh)
        return make_mesh((d, m), (self.data_axis, self.label_axis))

    @classmethod
    def from_job(cls, job) -> "ScheduleSpec":
        """Duck-typed: derive the spec from an `XMCTrainJob`'s fields (the
        adapter the legacy entry points use to write spec-shaped
        manifests)."""
        mesh = None
        if job.mesh is not None:
            mesh = (int(job.mesh.shape.get(job.data_axis, 1)),
                    int(job.mesh.shape.get(job.label_axis, 1)))
        return cls(label_batch=job.cfg.label_batch,
                   block_shape=tuple(job.block_shape), mesh=mesh,
                   label_axis=job.label_axis, data_axis=job.data_axis,
                   shard_data=job.shard_data, balance=job.balance,
                   overlap=job.overlap, max_inflight=job.max_inflight,
                   workers=job.workers, lease_ttl=job.lease_ttl)

    # Runtime tuning knobs that never change the solved checkpoint (the
    # double-buffered scheduler is proven byte-identical to the sequential
    # one, and so is any cooperative worker count — each batch's solve is
    # deterministic regardless of which worker claims it): excluded from
    # the resume fingerprint and canonicalized away in manifest-stored
    # specs, so flipping them never blocks a resume and never perturbs
    # checkpoint bytes. In particular, co-workers joining the same drain
    # may disagree on workers/lease_ttl without tripping the spec guard.
    RUNTIME_FIELDS = ("overlap", "max_inflight", "workers", "lease_ttl")

    def canonical(self) -> "ScheduleSpec":
        """This schedule with the runtime knobs reset to their defaults —
        the form that is embedded in checkpoint manifests (checkpoint
        identity must not depend on how the host loop was buffered)."""
        defaults = {f.name: f.default for f in dataclasses.fields(self)}
        return dataclasses.replace(
            self, **{k: defaults[k] for k in self.RUNTIME_FIELDS})

    def fingerprint(self) -> dict:
        """Resume-identity subset: everything that can change the solved
        weights or the shard layout (see RUNTIME_FIELDS for what is
        excluded, and why)."""
        d = self.to_dict()
        for k in self.RUNTIME_FIELDS:
            d.pop(k)
        # reorder_labels changes the packed checkpoint, so True must be in
        # the fingerprint — but the default False is dropped so fingerprints
        # stored before the knob existed still match (pre-knob checkpoints
        # stay resumable).
        if not d.get("reorder_labels"):
            d.pop("reorder_labels", None)
        return d
