"""ServeSpec: how a trained checkpoint is turned into a serving engine.

The serving half of the one experiment object: which predict backend
(an entry in `repro.serve.xmc.register_backend`'s registry), top-k depth,
micro-batch buckets, and Pallas execution mode. Serving choices never
affect the solved weights, so `ServeSpec` rides in the checkpoint
manifest's *meta* (recoverable, but changing it never blocks a resume)
and can be overridden per-session via
`CheckpointHandle.engine(serve_override=...)`.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.specs.base import Spec

# Mirrors repro.serve.batching.DEFAULT_BUCKETS — duplicated so the specs
# package stays importable without jax (tested equal in tests/test_xmc_api).
DEFAULT_BUCKETS = (1, 2, 4, 8, 16, 32, 64, 128, 256)


@dataclasses.dataclass(frozen=True)
class ServeSpec(Spec):
    """One serving configuration over a sparse checkpoint.

    backend   : predict-backend registry kind ("dense" / "bsr" / "sharded" /
                "shortlist" built in; plugins register more).
    k         : top-k labels returned per instance.
    buckets   : micro-batch bucket sizes (one XLA compile each).
    interpret : Pallas execution mode for kernel backends — None
                auto-selects per hardware (compiled Mosaic on TPU,
                interpreter elsewhere), True/False force it.
    warmup    : pre-compile every bucket at engine construction.
    shortlist_blocks : B, the number of BSR row blocks the "shortlist"
                backend's coarse stage keeps per micro-batch (its candidate
                fraction is B / n_row_blocks). None defers to the
                artifact's default (~1/8 of the row blocks); values above
                the row-block count are clamped, and B = n_row_blocks is
                exactly exhaustive scoring. Ignored by other backends.
    int8      : serve the symmetric per-block int8 weight artifact instead
                of fp32 blocks (~0.25x weight HBM traffic; top-k agreement
                rather than bit equality). With backend="shortlist" the
                gathered fine stage goes int8 (coarse stage stays fp32);
                with backend="bsr" the engine serves the exhaustive int8
                path. Checkpoints written before this field existed
                deserialize with int8=False — fp32 serving, unchanged.
    max_batch_delay_ms : continuous-batching launch deadline for the async
                server (`CheckpointHandle.server()`): a partially filled
                bucket launches once its oldest request has waited this
                long. 0 dispatches every submit immediately; the
                synchronous `engine()` path ignores it.
    max_queue : admission bound for the async server — requests arriving
                while this many are already queued get an immediate
                `Rejected` result instead of growing the queue without
                bound. None = unbounded. Ignored by `engine()`.
    shortlist_kind : which coarse-stage artifact `fit()` leaves on the
                checkpoint for two-stage serving: "centroid" (block means,
                free, the default and the pre-v2 behavior), "learned" (a
                one-vs-rest meta-classifier over row blocks trained at
                finalize from the run's own data), or "tree" (a
                fastxml-style routing tree). Serving reads whatever
                artifact is on disk; this knob decides what gets built.
                Old manifests deserialize to "centroid" — unchanged.
    shortlist_per_query : select top-B row blocks per QUERY instead of one
                shared selection per micro-batch (the ragged-gather fine
                stage: easy queries stop paying for the batch union's
                width). B = n_row_blocks collapses to the shared
                exhaustive-equivalent path. Ignored by other backends.
                Old manifests deserialize to False — shared selection,
                unchanged.
    """
    backend: str = "bsr"
    k: int = 5
    buckets: tuple[int, ...] = DEFAULT_BUCKETS
    interpret: Optional[bool] = None
    warmup: bool = True
    shortlist_blocks: Optional[int] = None
    int8: bool = False
    max_batch_delay_ms: float = 2.0
    max_queue: Optional[int] = None
    shortlist_kind: str = "centroid"
    shortlist_per_query: bool = False

    def validate(self) -> "ServeSpec":
        if self.k < 1:
            raise ValueError(f"k must be >= 1, got {self.k}")
        if not self.buckets or any(b < 1 for b in self.buckets):
            raise ValueError(f"buckets must be non-empty positive sizes, "
                             f"got {self.buckets}")
        if list(self.buckets) != sorted(self.buckets):
            raise ValueError(f"buckets must be ascending, got {self.buckets}")
        if self.shortlist_blocks is not None and self.shortlist_blocks < 1:
            raise ValueError(f"shortlist_blocks must be >= 1 (or None for "
                             f"the artifact default), got "
                             f"{self.shortlist_blocks}")
        if self.max_batch_delay_ms < 0:
            raise ValueError(f"max_batch_delay_ms must be >= 0, got "
                             f"{self.max_batch_delay_ms}")
        if self.max_queue is not None and self.max_queue < 1:
            raise ValueError(f"max_queue must be >= 1 (or None for "
                             f"unbounded), got {self.max_queue}")
        if self.shortlist_kind not in ("centroid", "learned", "tree"):
            raise ValueError(
                f"shortlist_kind must be 'centroid', 'learned' or 'tree', "
                f"got {self.shortlist_kind!r}")
        return self

    def resolved_interpret(self) -> bool:
        """The Pallas mode that will actually run (None -> hardware
        default)."""
        from repro.compat import resolve_interpret    # deferred: no jax here
        return resolve_interpret(self.interpret)
