"""SweepPolicy: declarative winner selection for Delta/C sweep sessions.

DiSMEC's Fig. 5 is a frontier — model size against precision@k as the
capacity-control threshold Delta (and C) move. Picking the deployed point
on that frontier is an operational decision, so it is a *spec*, not code:
`SweepPolicy` is frozen and JSON-round-trippable like every other spec,
rides in sweep reports, and selects over arm records by a registered rule.

Arms are anything with `.name`, `.model_mb`, `.int8_mb`, and `.metrics`
(a `{"P@1": ..., "P@3": ...}` dict) — `lifecycle.sweep.SweepArm` in
practice. The registry is open like the predict-backend registry: plug in
a new rule with `@register_sweep_policy("kind")`.

Like the rest of `repro.specs`, this module is a jax-free leaf.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

from repro.specs.base import Spec

#: kind -> selector(policy, arms) -> winning arm. Selectors may assume
#: `arms` is non-empty and `policy.validate()` passed.
SWEEP_POLICIES: dict[str, Callable] = {}


def register_sweep_policy(kind: str):
    """Register a winner-selection rule under `SweepPolicy(kind=...)`."""
    def wrap(fn: Callable) -> Callable:
        SWEEP_POLICIES[kind] = fn
        return fn
    return wrap


@dataclasses.dataclass(frozen=True)
class SweepPolicy(Spec):
    """One declarative winner-selection rule over sweep arms.

    kind   : registry entry (see `SWEEP_POLICIES`):
             "min_size" — smallest model, metrics ignored (the only
               meaningful rule when a sweep ran without a holdout);
             "max_precision" — highest `metric`, ties to the smaller model;
             "max_precision_under_size_mb" — highest `metric` among arms
               whose size fits `size_mb`; when nothing fits, the smallest
               model wins (the budget is a hard deployment constraint, so
               the closest-to-feasible arm is the only honest answer);
             "min_size_at_precision" — smallest model whose `metric` is
               >= `precision_floor`; when nothing reaches the floor, the
               most precise arm wins.
    metric : which `metrics` column drives precision comparisons ("P@1" /
             "P@3" / "P@5" ...).
    size_mb : model-size budget for "max_precision_under_size_mb".
    precision_floor : precision floor for "min_size_at_precision".
    int8   : judge size by the int8 serving payload (`int8_mb`) instead of
             the fp32 (value, index) size (`model_mb`).
    """
    kind: str = "max_precision"
    metric: str = "P@5"
    size_mb: Optional[float] = None
    precision_floor: Optional[float] = None
    int8: bool = False

    def validate(self) -> "SweepPolicy":
        if self.kind not in SWEEP_POLICIES:
            raise ValueError(f"unknown sweep policy kind {self.kind!r}; "
                             f"registered: {sorted(SWEEP_POLICIES)}")
        if self.kind == "max_precision_under_size_mb" and (
                self.size_mb is None or self.size_mb <= 0):
            raise ValueError("max_precision_under_size_mb needs a positive "
                             f"size_mb budget, got {self.size_mb}")
        if self.kind == "min_size_at_precision" and \
                self.precision_floor is None:
            raise ValueError("min_size_at_precision needs a "
                             "precision_floor")
        return self

    # -- selection --------------------------------------------------------

    def size_of(self, arm) -> float:
        return float(arm.int8_mb if self.int8 else arm.model_mb)

    def metric_of(self, arm) -> float:
        try:
            return float(arm.metrics[self.metric])
        except KeyError:
            raise ValueError(
                f"arm {arm.name!r} has no metric {self.metric!r}; "
                f"available: {sorted(arm.metrics)}") from None

    def select(self, arms):
        """The winning arm under this policy (`validate`d first)."""
        arms = list(arms)
        if not arms:
            raise ValueError("cannot select a winner from zero arms")
        return SWEEP_POLICIES[self.validate().kind](self, arms)


@register_sweep_policy("min_size")
def _min_size(policy: SweepPolicy, arms):
    return min(arms, key=policy.size_of)


@register_sweep_policy("max_precision")
def _max_precision(policy: SweepPolicy, arms):
    # Ties go to the smaller model: same precision, cheaper to serve.
    return max(arms, key=lambda a: (policy.metric_of(a),
                                    -policy.size_of(a)))


@register_sweep_policy("max_precision_under_size_mb")
def _max_precision_under_size(policy: SweepPolicy, arms):
    fits = [a for a in arms if policy.size_of(a) <= policy.size_mb]
    if not fits:
        return min(arms, key=policy.size_of)
    return max(fits, key=lambda a: (policy.metric_of(a),
                                    -policy.size_of(a)))


@register_sweep_policy("min_size_at_precision")
def _min_size_at_precision(policy: SweepPolicy, arms):
    ok = [a for a in arms if policy.metric_of(a) >= policy.precision_floor]
    if not ok:
        return max(arms, key=policy.metric_of)
    return min(ok, key=lambda a: (policy.size_of(a),
                                  -policy.metric_of(a)))
