"""SolverSpec: everything that determines the per-label TRON solution.

This is the spec-level face of `repro.core.dismec.DiSMECConfig` — the
same hyper-parameters, minus the scheduling knob (`label_batch` lives in
`ScheduleSpec`, where the rest of the layer-1 scheduling sits). `ops`
names an entry in the solver-ops registry
(`repro.core.dismec.register_solver_ops`): the factory that builds the
`obj_grad`/`hvp` pair the TRON loop drives, so alternative kernel stacks
plug in as new registry entries rather than new config booleans.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.specs.base import Spec

#: Built-in solver-ops kinds (the registry may grow beyond these).
SOLVER_OPS_JNP = "jnp"
SOLVER_OPS_PALLAS = "pallas"


@dataclasses.dataclass(frozen=True)
class SolverSpec(Spec):
    """Hyper-parameters of one per-label binary solve (paper Eq. 2.2).

    C / delta / eps / max_newton / max_cg are Algorithm 1's knobs;
    `ops` picks the obj-grad/Hv implementation from the solver-ops
    registry ("jnp" decomposed lax ops, "pallas" the fused hinge + HVP
    kernels); `pallas_interpret` forces interpreter (True) or compiled
    Mosaic (False) for the Pallas ops, None auto-selecting per backend.
    """
    C: float = 1.0
    delta: float = 0.01
    eps: float = 0.01
    max_newton: int = 50
    max_cg: int = 40
    ops: str = SOLVER_OPS_JNP
    pallas_interpret: Optional[bool] = None

    def validate(self) -> "SolverSpec":
        if self.C <= 0.0:
            raise ValueError(f"C must be positive, got {self.C}")
        if self.delta < 0.0:
            raise ValueError(f"delta must be >= 0, got {self.delta}")
        if self.eps <= 0.0:
            raise ValueError(f"eps must be positive, got {self.eps}")
        if self.max_newton < 1 or self.max_cg < 1:
            raise ValueError("max_newton and max_cg must be >= 1")
        return self

    # -- adapters to/from the core config --------------------------------

    @classmethod
    def from_config(cls, cfg) -> "SolverSpec":
        """Duck-typed: reads the `DiSMECConfig` attribute names."""
        ops = getattr(cfg, "ops", None) or (
            SOLVER_OPS_PALLAS if cfg.use_pallas else SOLVER_OPS_JNP)
        return cls(C=cfg.C, delta=cfg.delta, eps=cfg.eps,
                   max_newton=cfg.max_newton, max_cg=cfg.max_cg,
                   ops=ops, pallas_interpret=cfg.pallas_interpret)

    def to_config(self, *, label_batch: int):
        """Build the `DiSMECConfig` this spec describes (deferred import:
        specs stay importable without jax)."""
        from repro.core.dismec import DiSMECConfig
        return DiSMECConfig(
            C=self.C, delta=self.delta, eps=self.eps,
            max_newton=self.max_newton, max_cg=self.max_cg,
            label_batch=label_batch,
            use_pallas=self.ops == SOLVER_OPS_PALLAS,
            pallas_interpret=self.pallas_interpret,
            ops=self.ops)

    def fingerprint(self) -> dict:
        """The manifest-resume identity of this solver: `to_dict` with
        `pallas_interpret` resolved to the mode that actually runs, so
        shards solved under interpret and compiled Mosaic (different fp
        accumulation) can never be stitched into one checkpoint."""
        d = self.to_dict()
        if self.ops == SOLVER_OPS_JNP:
            d["pallas_interpret"] = None
        else:
            from repro.compat import resolve_interpret
            d["pallas_interpret"] = resolve_interpret(self.pallas_interpret)
        return d
