"""Sub-specs of the declarative XMC experiment description.

`SolverSpec` (what is solved), `ScheduleSpec` (how the label space is
walked and sharded), and `ServeSpec` (how the checkpoint is served)
compose into `repro.xmc_api.XMCSpec` — the one frozen,
JSON-round-trippable object that drives `fit()` and rides inside every
BSR checkpoint manifest. This package is a deliberate leaf: importable
without jax.
"""

from repro.specs.base import Spec
from repro.specs.schedule import ScheduleSpec
from repro.specs.serve import DEFAULT_BUCKETS, ServeSpec
from repro.specs.solver import (SOLVER_OPS_JNP, SOLVER_OPS_PALLAS,
                                SolverSpec)
from repro.specs.sweep import (SWEEP_POLICIES, SweepPolicy,
                               register_sweep_policy)

__all__ = ["Spec", "SolverSpec", "ScheduleSpec", "ServeSpec",
           "DEFAULT_BUCKETS", "SOLVER_OPS_JNP", "SOLVER_OPS_PALLAS",
           "SweepPolicy", "SWEEP_POLICIES", "register_sweep_policy"]
