"""Spec machinery: frozen, JSON-round-trippable experiment descriptions.

Every spec in `repro.specs` is a frozen dataclass deriving from `Spec`,
which contributes one serialization contract:

  spec.to_dict()  -> plain dict of JSON types (tuples become lists,
                     nested specs become nested dicts)
  Spec.from_dict(d) -> the spec back, with lists re-tupled and nested
                     dicts re-hydrated from the field's annotated type;
                     unknown keys are an error (a spec written by a newer
                     version must fail loudly, not be silently truncated)
  to_json / from_json -> the same through a JSON string

Round-tripping is exact: `Spec.from_json(spec.to_json()) == spec` for any
spec built from JSON-representable field values. This is what lets the
full experiment description ride inside the BSR checkpoint manifest and
come back out as the same object (repro.xmc_api.CheckpointHandle).

The package is a leaf: nothing here imports jax or the rest of `repro`,
so specs can be built, serialized, and validated in processes that never
touch an accelerator (launchers, dashboards, manifest tooling).
"""

from __future__ import annotations

import dataclasses
import json
import typing
from typing import Any


def _to_jsonable(v: Any) -> Any:
    if dataclasses.is_dataclass(v) and not isinstance(v, type):
        return {f.name: _to_jsonable(getattr(v, f.name))
                for f in dataclasses.fields(v)}
    if isinstance(v, (tuple, list)):
        return [_to_jsonable(x) for x in v]
    return v


def _coerce(tp: Any, v: Any) -> Any:
    """Re-hydrate a JSON value into the shape a field annotation promises."""
    origin = typing.get_origin(tp)
    if origin is typing.Union:                       # Optional[...] and friends
        if v is None:
            return None
        args = [a for a in typing.get_args(tp) if a is not type(None)]
        return _coerce(args[0], v) if len(args) == 1 else v
    if isinstance(tp, type) and dataclasses.is_dataclass(tp):
        return tp.from_dict(v) if isinstance(v, dict) else v
    if origin is tuple:
        args = typing.get_args(tp)
        if len(args) == 2 and args[1] is Ellipsis:   # tuple[T, ...]
            return tuple(_coerce(args[0], x) for x in v)
        return tuple(_coerce(a, x) for a, x in zip(args, v))
    return v


class Spec:
    """Serialization mixin shared by every spec dataclass."""

    def to_dict(self) -> dict:
        return _to_jsonable(self)

    @classmethod
    def from_dict(cls, d: dict) -> "Spec":
        fields = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - fields
        if unknown:
            raise ValueError(
                f"{cls.__name__} does not know field(s) {sorted(unknown)}; "
                f"valid fields: {sorted(fields)}")
        hints = typing.get_type_hints(cls)
        return cls(**{k: _coerce(hints[k], v) for k, v in d.items()})

    def to_json(self, *, indent: int | None = None) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=True)

    @classmethod
    def from_json(cls, s: str) -> "Spec":
        return cls.from_dict(json.loads(s))

    def replace(self, **changes) -> "Spec":
        return dataclasses.replace(self, **changes)
