"""Version-compatibility shims for jax.

The repo targets the `jax.shard_map` API (jax >= 0.6: top-level export,
`check_vma=` keyword). On the pinned 0.4.x toolchain that function lives in
`jax.experimental.shard_map` and the keyword is spelled `check_rep=`. Every
call site imports `shard_map` from here instead of touching `jax.shard_map`
directly, so the whole codebase moves between jax versions by editing this
one file.
"""

from __future__ import annotations

import inspect

import jax

if hasattr(jax, "shard_map"):                       # jax >= 0.6
    _shard_map = jax.shard_map
else:                                               # jax 0.4.x / 0.5.x
    from jax.experimental.shard_map import shard_map as _shard_map

_SHARD_MAP_PARAMS = frozenset(inspect.signature(_shard_map).parameters)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None, **kwargs):
    """`jax.shard_map` with the `check_vma` keyword mapped to whatever the
    installed jax calls it (`check_rep` before 0.6)."""
    if check_vma is not None:
        if "check_vma" in _SHARD_MAP_PARAMS:
            kwargs["check_vma"] = check_vma
        elif "check_rep" in _SHARD_MAP_PARAMS:
            kwargs["check_rep"] = check_vma
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kwargs)


try:                                                # jax >= 0.5.x
    from jax.sharding import AxisType
except ImportError:
    import enum

    class AxisType(enum.Enum):                      # minimal stand-in
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"

_MAKE_MESH_PARAMS = frozenset(inspect.signature(jax.make_mesh).parameters)


def make_mesh(axis_shapes, axis_names, *, axis_types=None, **kwargs):
    """`jax.make_mesh` with `axis_types=` dropped on jax versions that
    predate sharding-in-types (the old default is Auto everywhere, which is
    exactly what the dropped argument requested)."""
    if axis_types is not None and "axis_types" in _MAKE_MESH_PARAMS:
        kwargs["axis_types"] = axis_types
    return jax.make_mesh(axis_shapes, axis_names, **kwargs)


def default_pallas_interpret() -> bool:
    """Backend-appropriate default for pallas_call's `interpret=`: compiled
    Mosaic kernels on TPU, the (slow, portable) interpreter everywhere else.
    Callers that take `interpret: bool | None = None` resolve None through
    this so CPU CI and real TPU lanes share one code path."""
    return jax.default_backend() != "tpu"


def resolve_interpret(interpret) -> bool:
    """None -> backend default, anything else -> bool(it)."""
    return default_pallas_interpret() if interpret is None else bool(interpret)


def cost_analysis(compiled) -> dict:
    """`compiled.cost_analysis()` as one flat dict on every jax version
    (0.4.x returns a one-element list of per-program dicts)."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost
