"""Data substrate: synthetic power-law XMC generator + LM token pipeline."""

from repro.data.xmc import XMCDataset, make_xmc_dataset, power_law_sizes
from repro.data.lm import TokenPipeline, make_lm_batch_iterator

__all__ = ["XMCDataset", "make_xmc_dataset", "power_law_sizes",
           "TokenPipeline", "make_lm_batch_iterator"]
