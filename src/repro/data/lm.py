"""LM token pipeline for the transformer-zoo training path.

Deterministic synthetic corpus (no external data offline): a mixture of
Zipfian unigram draws and short repeated motifs, giving next-token structure
a small model can learn in a few hundred steps (examples/train_lm.py).
The pipeline yields sharding-ready (tokens, targets, valid) batches.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np


@dataclasses.dataclass
class TokenPipeline:
    vocab: int
    seq_len: int
    batch: int
    seed: int = 0
    zipf_a: float = 1.3
    motif_len: int = 8
    n_motifs: int = 64

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        effective = min(self.vocab, 32768)           # cap the hot vocab
        self._motifs = rng.integers(2, effective,
                                    size=(self.n_motifs, self.motif_len))
        self._effective = effective

    def batches(self) -> Iterator[dict]:
        rng = np.random.default_rng(self.seed + 1)
        while True:
            yield self.sample(rng)

    def sample(self, rng: np.random.Generator) -> dict:
        B, T = self.batch, self.seq_len
        toks = (rng.zipf(self.zipf_a, size=(B, T)) % (self._effective - 2)) + 2
        # Paste motifs at random offsets: learnable bigram structure.
        n_paste = max(1, T // (4 * self.motif_len))
        for b in range(B):
            for _ in range(n_paste):
                m = self._motifs[rng.integers(self.n_motifs)]
                off = rng.integers(0, T - self.motif_len)
                toks[b, off:off + self.motif_len] = m
        toks = toks.astype(np.int32)
        tokens = toks[:, :-1] if T > 1 else toks
        targets = toks[:, 1:] if T > 1 else toks
        valid = np.ones_like(targets, np.float32)
        return {"tokens": tokens, "targets": targets, "valid": valid}


def make_lm_batch_iterator(vocab: int, seq_len: int, batch: int,
                           seed: int = 0) -> Iterator[dict]:
    return TokenPipeline(vocab=vocab, seq_len=seq_len + 1, batch=batch,
                         seed=seed).batches()
