"""Synthetic extreme multi-label datasets with power-law label distributions.

The Extreme Classification Repository datasets (Table 1) are not available
offline, so the reproduction validates the paper's *claims* on controlled
synthetic data engineered to share the statistics the paper leans on:

  * label sizes follow N_r = N_1 * r^{-beta} (paper Eq. 1.1, Fig. 1):
    a large fraction of labels are tail labels with <= 5 positives;
  * features are sparse and Zipf-like, mimicking tf-idf bag-of-words;
  * generative process is topic-model-like: each label owns a small pool of
    signature features; an instance's features mix its labels' signatures
    with a large background vocabulary. A linear OvR machine therefore has
    an (almost) sparse optimum: O(1) weights on signature features, near-0
    "ambiguous" weights everywhere else — exactly the bimodal learnt-weight
    structure of paper Fig. 2, in which Delta-pruning is lossless;
  * every instance carries >= 1 label, every label has >= 1 positive.

Scaled-down name-alikes of the paper's Table 1 rows are provided
(wiki31k_like etc.) so benchmark tables read like the paper's.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class XMCDataset:
    X_train: np.ndarray        # (N, D) float32 (dense-ified sparse tf-idf)
    Y_train: np.ndarray        # (N, L) {0,1}
    X_test: np.ndarray
    Y_test: np.ndarray
    label_pools: np.ndarray    # (L, pool) signature feature ids (diagnostics)
    name: str = "synthetic"

    @property
    def n_labels(self) -> int:
        return self.Y_train.shape[1]

    @property
    def n_features(self) -> int:
        return self.X_train.shape[1]

    def stats(self) -> dict:
        Y = self.Y_train
        per_label = Y.sum(axis=0)
        per_point = Y.sum(axis=1)
        return {
            "n_train": len(self.X_train), "n_test": len(self.X_test),
            "n_labels": self.n_labels, "n_features": self.n_features,
            "APpL": float(per_label.mean()),      # avg points per label
            "ALpP": float(per_point.mean()),      # avg labels per point
            "tail_leq5": float((per_label <= 5).mean()),
            "feat_density": float((self.X_train != 0).mean()),
        }


def power_law_sizes(L: int, n1: int, beta: float) -> np.ndarray:
    """Label sizes N_r = N_1 * r^{-beta} (Eq. 1.1), clipped at >= 1."""
    r = np.arange(1, L + 1, dtype=np.float64)
    return np.maximum(n1 * r ** (-beta), 1.0).astype(np.int64)


def make_xmc_dataset(*, n_train: int = 2000, n_test: int = 500,
                     n_features: int = 4096, n_labels: int = 256,
                     beta: float = 1.0, n1: int | None = None,
                     pool_size: int = 6, pool_stride: int | None = None,
                     sig_per_label: int = 3,
                     bg_per_doc: int = 10, label_noise: float = 0.05,
                     multi_label_p: float = 0.3, label_locality: float = 0.0,
                     scramble_labels: bool = False,
                     seed: int = 0, name: str = "synthetic") -> XMCDataset:
    """Generate a power-law XMC problem by a topic-model-like process.

    Per instance: draw 1 + Binomial(2, multi_label_p) labels with power-law
    marginals; emit `sig_per_label` features from each label's signature pool
    and `bg_per_doc` Zipf-distributed background features. With probability
    `label_noise` a signature feature is swapped for a random one (makes tail
    labels imperfectly separable, as in real data).

    `pool_stride` spaces consecutive labels' signature pools. The default
    (pool_size) keeps pools disjoint: every label is independent. A stride
    below pool_size overlaps neighboring pools, so adjacent label ids score
    similarly on the same instances — a cluster-ordered label space like the
    tree/cluster orderings real XMC pipelines serve, which is the regime a
    contiguous-row-block candidate stage (serve/shortlist.py) targets.

    `label_locality` is the probability that each EXTRA label of a
    multi-label instance is drawn adjacent (within +-2) to the instance's
    first label instead of independently. 0 (default) keeps co-occurring
    labels independent; near 1 makes them cluster-adjacent, which is how
    co-occurring labels land in a cluster-ordered label space.

    `scramble_labels` applies a final random permutation to the label ids
    (columns of Y and rows of label_pools), destroying whatever locality
    the knobs above arranged WITHOUT changing the learning problem — the
    worst-case label order a contiguous-row-block candidate stage can
    face, and the regime `ScheduleSpec.reorder_labels` is meant to repair
    (its co-occurrence clustering should rediscover the structure).
    """
    rng = np.random.default_rng(seed)
    N = n_train + n_test
    D, L = n_features, n_labels

    # Feature space: the first bg_lo ids are signature features (pools laid
    # out `stride` apart), the rest are background vocabulary.
    stride = pool_size if pool_stride is None else int(pool_stride)
    assert 1 <= stride <= pool_size, "pool_stride must be in [1, pool_size]"
    bg_lo = (L - 1) * stride + pool_size
    assert D > bg_lo + 32, "need room for background vocabulary"
    pools = np.arange(L)[:, None] * stride + np.arange(pool_size)[None, :]
    n_bg = D - bg_lo

    # Power-law label sampling weights (Eq. 1.1), random rank assignment.
    sizes = power_law_sizes(L, n1 or max(N // 4, 8), beta).astype(np.float64)
    perm = rng.permutation(L)
    p_label = np.zeros(L)
    p_label[perm] = sizes / sizes.sum()

    X = np.zeros((N, D), np.float32)
    Y = np.zeros((N, L), np.int8)
    zipf_bg = (rng.zipf(1.4, size=(N, bg_per_doc)) - 1) % n_bg + bg_lo

    offsets = np.array([-2, -1, 1, 2])
    for i in range(N):
        k = 1 + rng.binomial(2, multi_label_p)
        if label_locality > 0.0 and k > 1:
            base = int(rng.choice(L, p=p_label))
            chosen = {base}
            while len(chosen) < k:
                if rng.random() < label_locality:
                    chosen.add(int(np.clip(base + rng.choice(offsets),
                                           0, L - 1)))
                else:
                    chosen.add(int(rng.choice(L, p=p_label)))
            labs = np.array(sorted(chosen))
        else:
            labs = rng.choice(L, size=k, replace=False, p=p_label)
        Y[i, labs] = 1
        for l in labs:
            sig = rng.choice(pools[l], size=sig_per_label, replace=False)
            swap = rng.random(sig_per_label) < label_noise
            sig = np.where(swap, rng.integers(0, D, sig_per_label), sig)
            X[i, sig] += rng.gamma(3.0, 1.0, sig_per_label).astype(np.float32)
        X[i, zipf_bg[i]] += rng.gamma(2.0, 1.0, bg_per_doc).astype(np.float32)

    # tf-idf-ish scaling + row normalization (standard for these benchmarks).
    df = np.maximum((X > 0).sum(axis=0), 1)
    X *= np.log(1.0 + N / df)[None, :]
    X /= np.linalg.norm(X, axis=1, keepdims=True) + 1e-8

    # Guarantee every label has >= 1 train positive.
    for l in range(L):
        if Y[:n_train, l].sum() == 0:
            j = rng.integers(0, n_train)
            Y[j, l] = 1
            sig = pools[l][:sig_per_label]
            X[j, sig] += 1.0
            X[j] /= np.linalg.norm(X[j]) + 1e-8

    if scramble_labels:
        # Column permutation only: X and the per-instance label SETS are
        # untouched, so any fixed relabeling of a model trained on the
        # unscrambled data solves this dataset identically.
        scram = rng.permutation(L)
        Y = Y[:, scram]
        pools = pools[scram]

    return XMCDataset(X_train=X[:n_train], Y_train=Y[:n_train],
                      X_test=X[n_train:], Y_test=Y[n_train:],
                      label_pools=pools, name=name)


# Scaled-down name-alikes of the paper's Table 1 rows (same shape statistics,
# ~1000x smaller so they run on one CPU device in seconds).
PAPER_LIKE = {
    "wiki31k_like": dict(n_train=1400, n_test=600, n_features=6144,
                         n_labels=512, beta=0.9, name="wiki31k_like"),
    "amazon670k_like": dict(n_train=2500, n_test=800, n_features=8192,
                            n_labels=1024, beta=1.2, name="amazon670k_like"),
    "delicious200k_like": dict(n_train=1000, n_test=500, n_features=4096,
                               n_labels=384, beta=0.6, multi_label_p=0.8,
                               name="delicious200k_like"),
    "wikilshtc325k_like": dict(n_train=1800, n_test=600, n_features=8192,
                               n_labels=768, beta=1.1, name="wikilshtc325k_like"),
}


def load_paper_like(key: str, seed: int = 0) -> XMCDataset:
    kw = dict(PAPER_LIKE[key])
    return make_xmc_dataset(seed=seed, **kw)
