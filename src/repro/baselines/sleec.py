"""SLEEC-lite: sparse local embeddings (paper §3.3, [6]).

Miniature of SLEEC's pipeline: (1) k-means cluster the training points,
(2) per cluster, learn a local low-rank label embedding (SVD of the cluster
label submatrix) and a linear regressor into the embedding space,
(3) predict by routing a test point to its nearest cluster centroid and
kNN-decoding label vectors of the cluster's training points in embedding
space. Captures the locally-low-rank assumption the paper critiques.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@dataclasses.dataclass
class SLEECModel:
    centroids: np.ndarray          # (n_clusters, D)
    regressors: list               # per cluster: (D, r)
    embeddings: list               # per cluster: (n_c, r) training embeddings
    labels: list                   # per cluster: (n_c, L) label rows
    knn: int

    def predict_topk(self, X, k: int = 5):
        Xn = np.asarray(X)
        n = len(Xn)
        L = self.labels[0].shape[1]
        scores = np.zeros((n, L), np.float32)
        cid = np.argmax(Xn @ self.centroids.T, axis=1)
        for c in range(len(self.centroids)):
            idx = np.nonzero(cid == c)[0]
            if len(idx) == 0:
                continue
            Z = Xn[idx] @ self.regressors[c]                 # (m, r)
            sim = Z @ self.embeddings[c].T                   # (m, n_c)
            kk = min(self.knn, sim.shape[1])
            nbr = np.argpartition(-sim, kk - 1, axis=1)[:, :kk]
            for j, row in enumerate(idx):
                w = sim[j, nbr[j]]
                w = np.maximum(w, 0) + 1e-6
                scores[row] = (w[:, None] * self.labels[c][nbr[j]]).sum(0)
        s = jnp.asarray(scores)
        return jax.lax.top_k(s, k)


def _kmeans(X: np.ndarray, k: int, iters: int = 15, seed: int = 0):
    rng = np.random.default_rng(seed)
    C = X[rng.choice(len(X), size=k, replace=False)].copy()
    for _ in range(iters):
        a = np.argmax(X @ C.T, axis=1)      # cosine-ish (rows normalized)
        for c in range(k):
            pts = X[a == c]
            if len(pts):
                C[c] = pts.mean(0)
                nc = np.linalg.norm(C[c])
                if nc > 0:
                    C[c] /= nc
    return C, a


def train_sleec(X, Y, *, n_clusters: int = 4, rank: int = 32, knn: int = 15,
                ridge: float = 0.1, seed: int = 0) -> SLEECModel:
    Xn = np.asarray(X, np.float32)
    Yn = np.asarray(Y, np.float32)
    D = Xn.shape[1]
    C, assign = _kmeans(Xn, n_clusters, seed=seed)
    regs, embs, labs = [], [], []
    for c in range(n_clusters):
        idx = np.nonzero(assign == c)[0]
        if len(idx) < 2:
            idx = np.arange(len(Xn))        # degenerate cluster: global
        Yc = Yn[idx]
        Xc = Xn[idx]
        r = min(rank, *Yc.shape)
        # Local label embedding: top-r right factors of the label submatrix.
        U, s, Vt = np.linalg.svd(Yc, full_matrices=False)
        Z = U[:, :r] * s[:r]                # (n_c, r) label embeddings
        # Linear regressor X -> Z (ridge).
        G = Xc.T @ Xc + ridge * np.eye(D, dtype=np.float32)
        Wr = np.linalg.solve(G, Xc.T @ Z)   # (D, r)
        regs.append(Wr.astype(np.float32))
        embs.append(Z.astype(np.float32))
        labs.append(Yc)
    return SLEECModel(centroids=C, regressors=regs, embeddings=embs,
                      labels=labs, knn=knn)
