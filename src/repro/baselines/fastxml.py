"""FastXML-lite: ensemble of random feature-space partition trees
(paper §3.3, [21]).

Miniature of FastXML: each tree recursively splits the feature space with a
random-then-refined linear separator; leaves store the label distribution of
their training points ranked by frequency (the nDCG-optimal leaf ranking for
uniform relevance). Prediction averages leaf distributions over the ensemble.
Exhibits the paper's critique: cascaded hard partitions lose tail labels.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@dataclasses.dataclass
class _Node:
    w: np.ndarray | None = None
    left: "._Node" = None
    right: "._Node" = None
    leaf_scores: np.ndarray | None = None


@dataclasses.dataclass
class FastXMLModel:
    trees: list
    n_labels: int

    def predict_topk(self, X, k: int = 5):
        Xn = np.asarray(X)
        scores = np.zeros((len(Xn), self.n_labels), np.float32)
        for tree in self.trees:
            for i, x in enumerate(Xn):
                node = tree
                while node.leaf_scores is None:
                    node = node.left if x @ node.w <= 0 else node.right
                scores[i] += node.leaf_scores
        return jax.lax.top_k(jnp.asarray(scores / len(self.trees)), k)


def _build(X, Y, rng, depth, max_depth, min_leaf):
    node = _Node()
    if depth >= max_depth or len(X) <= min_leaf or Y.sum() == 0:
        freq = Y.sum(0).astype(np.float32)
        node.leaf_scores = freq / max(freq.max(), 1.0)
        return node
    # Random hyperplane, refined by 3 sign-LDA-ish iterations: move the
    # plane toward balancing while separating label distributions.
    w = rng.standard_normal(X.shape[1]).astype(np.float32)
    for _ in range(3):
        side = X @ w > 0
        if side.all() or (~side).all():
            break
        mu1 = X[side].mean(0)
        mu0 = X[~side].mean(0)
        w = (mu1 - mu0).astype(np.float32)
    side = X @ w > 0
    if side.all() or (~side).all():          # unsplittable: make a leaf
        freq = Y.sum(0).astype(np.float32)
        node.leaf_scores = freq / max(freq.max(), 1.0)
        return node
    node.w = w
    node.left = _build(X[~side], Y[~side], rng, depth + 1, max_depth,
                       min_leaf)
    node.right = _build(X[side], Y[side], rng, depth + 1, max_depth,
                        min_leaf)
    return node


def train_fastxml(X, Y, *, n_trees: int = 5, max_depth: int = 8,
                  min_leaf: int = 16, seed: int = 0) -> FastXMLModel:
    Xn = np.asarray(X, np.float32)
    Yn = np.asarray(Y, np.float32)
    trees = []
    for t in range(n_trees):
        rng = np.random.default_rng(seed + t)
        trees.append(_build(Xn, Yn, rng, 0, max_depth, min_leaf))
    return FastXMLModel(trees=trees, n_labels=Yn.shape[1])
