"""LEML-lite: global low-rank label embedding (paper §3.3, [31]).

Solves min_{U,V} ||Y - X U V^T||_F^2 + mu(||U||^2 + ||V||^2) by alternating
ridge regressions — a faithful miniature of LEML's trace-norm-bounded global
embedding. The paper's argument: with power-law tail labels the low-rank
assumption fails, so this method collapses on tail-heavy data (Table 2's
LEML column is the weakest on the large datasets).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass
class LEMLModel:
    U: Array       # (D, r)
    V: Array       # (L, r)

    def predict_topk(self, X: Array, k: int = 5):
        scores = (X @ self.U) @ self.V.T
        return jax.lax.top_k(scores, k)


def train_leml(X: Array, Y: Array, *, rank: int = 32, mu: float = 0.1,
               n_alt: int = 10, seed: int = 0) -> LEMLModel:
    X = jnp.asarray(X, jnp.float32)
    Yf = jnp.asarray(Y, jnp.float32)
    N, D = X.shape
    L = Yf.shape[1]
    r = min(rank, L, D)
    key = jax.random.PRNGKey(seed)
    V = jax.random.normal(key, (L, r)) * 0.01

    G = X.T @ X + mu * jnp.eye(D)          # (D, D) shared Gram
    XtY = X.T @ Yf                          # (D, L)

    U = jnp.zeros((D, r))
    for _ in range(n_alt):
        # U-step: ridge regression of Y V onto X.
        U = jnp.linalg.solve(G, XtY @ V)                     # (D, r)
        Z = X @ U                                            # (N, r)
        # V-step: per-label ridge in the r-dim embedded space.
        A = Z.T @ Z + mu * jnp.eye(r)
        V = jnp.linalg.solve(A, Z.T @ Yf).T                  # (L, r)
    return LEMLModel(U=U, V=V)
