"""L1-SVM baseline (paper §3.3): l1-regularized OvR squared hinge via FISTA.

The paper's point (Fig. 4, §4.1): l1 gives sparser models but UNDERFITS
versus l2 + Delta-pruning. benchmarks/fig4_l1_vs_l2.py measures exactly that.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.losses import (l1_grad_smooth_part, l1_objective_smooth_part,
                               soft_threshold)

Array = jax.Array


@dataclasses.dataclass
class LinearModel:
    W: Array

    def predict_topk(self, X: Array, k: int = 5):
        return jax.lax.top_k(X @ self.W.T, k)

    @property
    def nnz(self) -> int:
        return int(jnp.sum(self.W != 0.0))


@partial(jax.jit, static_argnames=("n_steps",))
def _fista(X, S, C, lam, step, n_steps: int):
    L, N = S.shape
    D = X.shape[1]
    W = jnp.zeros((L, D), jnp.float32)
    Z = W
    t = jnp.float32(1.0)

    def body(carry, _):
        W, Z, t = carry
        g = l1_grad_smooth_part(Z, X, S, C)
        W_new = soft_threshold(Z - step * g, step * lam)
        t_new = 0.5 * (1.0 + jnp.sqrt(1.0 + 4.0 * t * t))
        Z_new = W_new + ((t - 1.0) / t_new) * (W_new - W)
        return (W_new, Z_new, t_new), None

    (W, _, _), _ = jax.lax.scan(body, (W, Z, t), None, length=n_steps)
    return W


def train_l1_svm(X: Array, Y: Array, *, C: float = 1.0, lam: float = 0.05,
                 n_steps: int = 300) -> LinearModel:
    S = (2.0 * Y.T - 1.0).astype(jnp.float32)
    X = jnp.asarray(X, jnp.float32)
    # Lipschitz estimate for the smooth part: 2C * sigma_max(X)^2 via a few
    # power iterations.
    v = jnp.ones((X.shape[1],)) / jnp.sqrt(X.shape[1])
    for _ in range(8):
        v = X.T @ (X @ v)
        v = v / (jnp.linalg.norm(v) + 1e-12)
    sigma2 = jnp.linalg.norm(X @ v) ** 2
    step = 1.0 / (2.0 * C * sigma2 + 1e-6)
    W = _fista(X, S, C, lam, step, n_steps)
    return LinearModel(W=W)
