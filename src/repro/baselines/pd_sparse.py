"""PD-Sparse-lite (paper §3.3, [30]): multiclass separation-ranking loss
with l1 regularization.

PD-Sparse optimizes a max-margin *multiclass* loss (positive labels must
outscore negatives) with elastic-net sparsity, solved primal-dual. This
miniature keeps the defining ingredients — multiclass separation loss +
l1 prox — with plain subgradient-prox steps. The paper's observations:
competitive on small data, cannot scale (dense intermediary state), which
our memory accounting in the benchmark echoes.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.losses import soft_threshold

Array = jax.Array


@dataclasses.dataclass
class PDSparseModel:
    W: Array

    def predict_topk(self, X: Array, k: int = 5):
        return jax.lax.top_k(X @ self.W.T, k)

    @property
    def nnz(self) -> int:
        return int(jnp.sum(self.W != 0.0))


@partial(jax.jit, static_argnames=("n_steps",))
def _train(X, Y, lam, lr, n_steps: int):
    N, D = X.shape
    L = Y.shape[1]
    W = jnp.zeros((L, D), jnp.float32)

    def body(W, _):
        Z = X @ W.T                                    # (N, L)
        # Multiclass separation: max over negatives vs min over positives.
        big = 1e30
        pos_min = jnp.min(jnp.where(Y > 0, Z, big), axis=1)
        neg_max = jnp.max(jnp.where(Y > 0, -big, Z), axis=1)
        margin = 1.0 - (pos_min - neg_max)             # hinge on separation
        active = margin > 0
        # Subgradient: push argmax-negative down, argmin-positive up.
        i_neg = jnp.argmax(jnp.where(Y > 0, -big, Z), axis=1)
        i_pos = jnp.argmin(jnp.where(Y > 0, Z, big), axis=1)
        coef = active.astype(jnp.float32) * jnp.maximum(margin, 0.0)
        G = jnp.zeros_like(W)
        G = G.at[i_neg].add(coef[:, None] * X)
        G = G.at[i_pos].add(-coef[:, None] * X)
        W = soft_threshold(W - lr * G / N, lr * lam)
        return W, None

    W, _ = jax.lax.scan(body, W, None, length=n_steps)
    return W


def train_pd_sparse(X, Y, *, lam: float = 0.0005, lr: float = 10.0,
                    n_steps: int = 1500) -> PDSparseModel:
    X = jnp.asarray(X, jnp.float32)
    Yf = jnp.asarray(Y, jnp.float32)
    return PDSparseModel(W=_train(X, Yf, lam, lr, n_steps))
