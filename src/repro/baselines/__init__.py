"""The paper's comparison methods (Table 2), reimplemented in JAX.

Scaled to the synthetic reproduction datasets; each returns a model object
with .predict_topk(X, k) so benchmarks/table2_accuracy.py can score all
methods identically.

  l1_svm     l1-regularized OvR squared hinge (FISTA) — paper's L1-SVM column
  leml       global low-rank embedding via alternating ridge — LEML
  sleec      cluster -> local SVD embedding -> kNN decode — SLEEC-lite
  fastxml    ensemble of balanced random feature-space trees — FastXML-lite
  pd_sparse  multiclass hinge with l1 prox — PD-Sparse-lite
"""

from repro.baselines.l1_svm import train_l1_svm
from repro.baselines.leml import train_leml
from repro.baselines.sleec import train_sleec
from repro.baselines.fastxml import train_fastxml
from repro.baselines.pd_sparse import train_pd_sparse

__all__ = ["train_l1_svm", "train_leml", "train_sleec", "train_fastxml",
           "train_pd_sparse"]
