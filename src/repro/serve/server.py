"""Continuous-batching async XMC server: the real request path.

`XMCEngine.step()` drains a static queue synchronously — fine for batch
scoring, wrong for production traffic, where requests ARRIVE over time and
host-side batching must not serialize with device compute. This module
wraps an engine in an arrival-time-aware serving loop:

  * **Deadline-launched buckets** — a micro-batch launches the moment the
    largest bucket fills, OR when the oldest queued request has waited
    `max_batch_delay_ms` (continuous batching). Low traffic never waits for
    a bucket to fill; high traffic always ships full buckets.
  * **Double-buffered dispatch** — the dispatcher thread packs/pads the
    next batch and hands the (asynchronously dispatched) device computation
    to a completion thread over a bounded hand-off queue, so host-side
    batching of batch b+1 overlaps with batch b's device compute. The
    bounded depth (`max_inflight`) is the dispatch-side backpressure.
  * **Admission control** — past `max_queue` pending requests, `submit`
    resolves the future immediately with a `Rejected` result instead of
    growing the queue without bound: under overload, queue wait stays
    bounded and the caller learns it must shed or retry.
  * **Futures** — `submit` returns an `XMCFuture`; `result()` blocks for
    that one request only. Oversize requests (split into several
    micro-batches by the queue) resolve exactly once, with their rows
    re-coalesced in order.
  * **Multi-model routing** — `ModelRouter` holds several named servers
    (one `CheckpointHandle` + `ServeSpec` each) in one process and
    dispatches by model name. Bucket warm-up compiles are shared
    process-wide for equal compile keys, so N models over equal-shaped
    checkpoints cost one compile set per (shape, k).
  * **Zero-downtime hot swap** — `swap(engine)` replaces the serving model
    between micro-batches: the new engine is warmed for this server's
    buckets OFF the dispatcher thread (old model keeps serving through the
    compiles), then the reference flips atomically under the server lock.
    Micro-batches formed before the flip finish on the old model; requests
    batched after it score on the new one — no accepted request is ever
    dropped or re-queued. The previous engine is retained
    (`previous_engine`) so rollback is just `swap` back.
    `ModelRouter.refresh(name, dir)` is the checkpoint-level form, and
    `lifecycle.refresh.CheckpointWatcher` (`ModelRouter.watch`) drives it
    from a generation counter on disk.

The batching policy itself lives in `serve.batching.MicroBatchQueue`
(`next_batch`); the engine's synchronous `step()` path is untouched and
remains bit-identical to this loop — same queue, same grouping, same
backend math (`tests/test_serve_server.py` holds that invariant per
registered backend).

Spec plumbing: `ServeSpec.max_batch_delay_ms` / `max_queue` configure the
server a checkpoint wants; `CheckpointHandle.server()` (repro.xmc_api)
builds one, and `launch/serve.py --server` runs a multi-model process from
the CLI.
"""

from __future__ import annotations

import dataclasses
import queue as queue_mod
import threading
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve.batching import LatencyStats
from repro.serve.xmc import XMCEngine, XMCResult


@dataclasses.dataclass
class Rejected:
    """Explicit load-shed answer: the request was NOT queued.

    Returned (through the future, immediately resolved) when admission
    control found `max_queue` requests already waiting. The caller decides
    to retry, back off, or route elsewhere — the server never buffers past
    its bound.
    """
    request_id: int
    reason: str = "queue_full"


class XMCFuture:
    """Hand-rolled future for one submitted request (stdlib-free on purpose:
    no executor semantics, just an event + value resolved by the server's
    completion thread — or instantly, for rejections)."""

    def __init__(self, request_id: int):
        self.request_id = request_id
        self._done = threading.Event()
        self._value: XMCResult | Rejected | None = None

    def done(self) -> bool:
        return self._done.is_set()

    def result(self, timeout: Optional[float] = None) -> XMCResult | Rejected:
        """Block until this request's answer (or `Rejected`) is ready."""
        if not self._done.wait(timeout):
            raise TimeoutError(
                f"request {self.request_id} not completed in {timeout}s")
        return self._value

    def _resolve(self, value: XMCResult | Rejected) -> None:
        self._value = value
        self._done.set()


@dataclasses.dataclass
class _Assembly:
    """Per-request completion state: parts arrive in dispatch order (the
    hand-off queue is FIFO), the future resolves when the last piece
    lands."""
    future: XMCFuture
    arrival: float
    pieces_left: int
    scores: list[np.ndarray] = dataclasses.field(default_factory=list)
    labels: list[np.ndarray] = dataclasses.field(default_factory=list)


_STOP = object()          # completion-thread sentinel


class XMCServer:
    """Arrival-time-aware continuous-batching loop over one `XMCEngine`.

    Request lifecycle (the backpressure state machine)::

        submit(x) --admission--> QUEUED --launch--> DISPATCHED --> COMPLETED
                      |            (fill or deadline)   (device)    (future
                      +--> REJECTED (pending_requests >= max_queue)  resolves)

    max_batch_delay_ms : launch deadline — a partially filled bucket ships
        after the oldest queued request has waited this long. 0 launches
        every submit immediately (pure latency mode); large values
        approximate drain-on-full batching (pure throughput mode).
    max_queue : admission bound on requests waiting for launch (dispatched/
        in-flight work does not count). None = unbounded (closed-loop /
        trusted callers only).
    max_inflight : depth of the dispatch->completion hand-off; 2 =
        double-buffering (pack batch b+1 while batch b computes).
    start : spawn the worker threads now. Pass False to pre-load requests
        and start later — with everything queued up front the launch
        grouping is identical to `engine.step()`'s drain, which is how the
        sync-vs-async bit-identity tests pin the loop.
    """

    def __init__(self, engine: XMCEngine, *,
                 max_batch_delay_ms: float = 2.0,
                 max_queue: Optional[int] = None,
                 max_inflight: int = 2,
                 name: Optional[str] = None,
                 start: bool = True):
        if max_batch_delay_ms < 0:
            raise ValueError(f"max_batch_delay_ms must be >= 0, got "
                             f"{max_batch_delay_ms}")
        if max_queue is not None and max_queue < 1:
            raise ValueError(f"max_queue must be >= 1 (or None for "
                             f"unbounded), got {max_queue}")
        if max_inflight < 1:
            raise ValueError(f"max_inflight must be >= 1, got {max_inflight}")
        self.engine = engine
        self.name = name
        self.max_batch_delay_ms = float(max_batch_delay_ms)
        self.max_queue = max_queue
        self.queue = engine.queue
        self.latency = LatencyStats()        # arrival -> completion
        self.queue_wait = LatencyStats()     # arrival -> device dispatch
        self.counters = {"accepted": 0, "rejected": 0, "completed": 0,
                         "batches": 0, "swaps": 0}
        self.previous_engine: Optional[XMCEngine] = None  # rollback target
        self.last_swap: Optional[dict] = None   # timing of the latest swap
        self._cv = threading.Condition()
        self._by_rid: dict[int, _Assembly] = {}
        self._inflight: queue_mod.Queue = queue_mod.Queue(maxsize=max_inflight)
        self._stopping = False
        self._started = False
        self._dispatcher = threading.Thread(
            target=self._dispatch_loop, name=f"xmc-dispatch-{name}",
            daemon=True)
        self._completer = threading.Thread(
            target=self._completion_loop, name=f"xmc-complete-{name}",
            daemon=True)
        if start:
            self.start()

    # -- lifecycle ----------------------------------------------------------

    def start(self) -> "XMCServer":
        if not self._started:
            self._started = True
            self._completer.start()
            self._dispatcher.start()
        return self

    def stop(self) -> None:
        """Flush and shut down: every accepted request still resolves (the
        dispatcher force-drains the queue on its way out), then both worker
        threads exit. Idempotent; `submit` after stop raises."""
        with self._cv:
            if self._stopping:
                self._started or self._drain_unstarted()
                return
            self._stopping = True
            self._cv.notify_all()
        if self._started:
            self._dispatcher.join()
            self._completer.join()
        else:
            self._drain_unstarted()

    def __enter__(self) -> "XMCServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    def _drain_unstarted(self) -> None:
        """A never-started server still owes answers on stop: run the loop
        body inline, completing after every dispatch so the bounded
        hand-off queue never fills without a completion thread to drain it
        (tests build servers with start=False)."""
        while self._dispatch_once(force=True):
            self._complete_pending()
        self._complete_pending()

    # -- hot swap -----------------------------------------------------------

    def swap(self, engine: XMCEngine) -> XMCEngine:
        """Replace the serving model with `engine`, zero downtime.

        The swap state machine::

            VALIDATE --> WARM (off-thread, old model still serving)
                     --> FLIP (atomic, under the server lock, between
                               micro-batches)

        VALIDATE raises before anything changes: a feature-dim mismatch
        (requests already accepted for D_old could never score on D_new)
        or a stopped server. WARM compiles the new engine's top-k for THIS
        server's buckets on the calling thread — the dispatcher keeps
        serving the old model throughout, so warm-up cost never shows up
        as request latency (equal-shaped models share compiles via the
        process-wide warm-up ledger and pay ~nothing here). FLIP takes the
        lock and replaces the engine reference: micro-batches already
        formed (they captured the old engine in `_dispatch_once`) complete
        on the old model; everything batched after the flip scores on the
        new one. No accepted request is dropped or re-queued.

        Returns the previous engine (also retained as `previous_engine`),
        so rollback is `server.swap(server.previous_engine)`.
        """
        with self._cv:
            if self._stopping:
                raise RuntimeError("cannot swap on a stopped server")
            old = self.engine
        nf_old, nf_new = old.n_features, engine.n_features
        if nf_new is None:
            nf_new = nf_old
            if nf_old is not None:
                engine.adopt_n_features(nf_old)
        if nf_old is not None and nf_new != nf_old:
            raise ValueError(
                f"cannot swap: new engine serves feature dim {nf_new}, "
                f"server accepts feature dim {nf_old}")
        t0 = time.monotonic()
        if engine.n_features is not None:       # warm outside the lock
            engine.warmup(self.queue.buckets)
        t_warm = time.monotonic()
        with self._cv:
            if self._stopping:
                raise RuntimeError("cannot swap on a stopped server")
            prev = self.engine
            self.engine = engine
            self.previous_engine = prev
            self.counters["swaps"] += 1
            t_flip = time.monotonic()
            self.last_swap = {"warm_ms": (t_warm - t0) * 1e3,
                              "flip_ms": (t_flip - t_warm) * 1e3,
                              "t_flip": t_flip}
            self._cv.notify_all()
        return prev

    # -- request path -------------------------------------------------------

    def submit(self, x: np.ndarray) -> XMCFuture:
        """Enqueue one (n_i, D) request; returns its future immediately.

        The future resolves to an `XMCResult` (top-k per instance, split
        requests re-coalesced) — or to `Rejected`, already resolved at
        return, when admission control sheds the request.
        """
        x = np.asarray(x, np.float32)
        assert x.ndim == 2, "a request is an (n_i, D) feature batch"
        nf = self.engine.n_features
        if nf is not None and x.shape[1] != nf:
            raise ValueError(f"request feature dim {x.shape[1]} != engine "
                             f"feature dim {nf}")
        with self._cv:
            if self._stopping:
                raise RuntimeError("server is stopped")
            if self.max_queue is not None and \
                    self.queue.pending_requests() >= self.max_queue:
                fut = XMCFuture(self.queue.reserve_id())
                fut._resolve(Rejected(fut.request_id))
                self.counters["rejected"] += 1
                return fut
            arrival = time.monotonic()
            rid = self.queue.submit(x, arrival=arrival)
            fut = XMCFuture(rid)
            self._by_rid[rid] = _Assembly(
                future=fut, arrival=arrival,
                pieces_left=self.queue.pieces_of(x.shape[0]))
            self.counters["accepted"] += 1
            self._cv.notify_all()
        return fut

    # -- worker loops -------------------------------------------------------

    def _dispatch_once(self, *, force: bool = False) -> bool:
        """Form one micro-batch if launchable, dispatch it to the device,
        and hand it to the completion side. Returns False when nothing was
        launchable."""
        delay_s = self.max_batch_delay_ms / 1e3
        with self._cv:
            mb = self.queue.next_batch(max_delay_s=delay_s, force=force)
            engine = self.engine     # captured with the batch: a concurrent
            # swap() must not tear one micro-batch across two models
        if mb is None:
            return False
        engine.ensure_warm(mb.bucket)
        xb = jnp.asarray(mb.x)                   # host pack -> device put
        t_dispatch = time.monotonic()
        scores, labels = engine.backend.topk(xb)        # async dispatch
        self.counters["batches"] += 1
        self._inflight.put((mb, scores, labels, t_dispatch))
        return True

    def _dispatch_loop(self) -> None:
        delay_s = self.max_batch_delay_ms / 1e3
        cap = self.queue.buckets[-1]
        while True:
            with self._cv:
                while True:
                    if self._stopping:
                        break
                    now = time.monotonic()
                    if self.queue.pending_rows() >= cap:
                        break                    # bucket full: launch now
                    oldest = self.queue.oldest_arrival()
                    if oldest is not None and now - oldest >= delay_s:
                        break                    # deadline expired: launch
                    wait = None if oldest is None else \
                        max(delay_s - (now - oldest), 0.0)
                    self._cv.wait(timeout=wait)
                stopping = self._stopping
            if not self._dispatch_once(force=stopping) and stopping:
                break
        self._inflight.put(_STOP)

    def _complete_batch(self, mb, scores, labels, t_dispatch: float) -> None:
        jax.block_until_ready(labels)
        scores, labels = np.asarray(scores), np.asarray(labels)
        t_done = time.monotonic()
        resolved = []
        with self._cv:
            for (rid, s), (_, l) in zip(mb.split(scores), mb.split(labels)):
                asm = self._by_rid.get(rid)
                if asm is None:     # enqueued via engine.submit, not ours
                    continue
                asm.scores.append(s)
                asm.labels.append(l)
                asm.pieces_left -= 1
                if asm.pieces_left == 0:
                    del self._by_rid[rid]
                    self.latency.record_span(asm.arrival, t_done)
                    self.queue_wait.record_span(asm.arrival, t_dispatch)
                    self.counters["completed"] += 1
                    resolved.append((asm.future, XMCResult(
                        request_id=rid,
                        scores=np.concatenate(asm.scores, axis=0),
                        labels=np.concatenate(asm.labels, axis=0))))
        for fut, res in resolved:        # wake waiters outside the lock
            fut._resolve(res)

    def _complete_pending(self) -> None:
        while True:
            try:
                item = self._inflight.get_nowait()
            except queue_mod.Empty:
                return
            if item is not _STOP:
                self._complete_batch(*item)

    def _completion_loop(self) -> None:
        while True:
            item = self._inflight.get()
            if item is _STOP:
                return
            self._complete_batch(*item)

    # -- introspection ------------------------------------------------------

    def stats(self) -> dict:
        """Counters + latency percentiles: `latency` is per-request
        arrival->completion, `queue_wait` is arrival->device-dispatch (what
        admission control bounds)."""
        out = dict(self.counters)
        out["pending_requests"] = self.queue.pending_requests()
        accepted = out["accepted"] + out["rejected"]
        out["reject_rate"] = (out["rejected"] / accepted) if accepted else 0.0
        out["latency"] = self.latency.summary()
        out["queue_wait"] = self.queue_wait.summary()
        return out


class ModelRouter:
    """Several named `XMCServer`s in one process; requests dispatch by model
    name. Pure routing — each server keeps its own queue, deadline, and
    admission bound (its model's `ServeSpec`), and bucket warm-up compiles
    for equal (shape, dtype, k) keys are already shared process-wide by the
    engines, so co-hosting N equal-shaped models costs one compile set.

        router = ModelRouter({"wiki": handle_a.server(),
                              "amazon": handle_b.server(ServeSpec(k=10))})
        fut = router.submit("wiki", x)
    """

    def __init__(self, servers: Optional[dict[str, XMCServer]] = None):
        self._servers: dict[str, XMCServer] = {}
        self._watchers: list = []            # CheckpointWatchers we own
        for name, srv in (servers or {}).items():
            self.add(name, srv)

    def add(self, name: str, server: XMCServer) -> "ModelRouter":
        if name in self._servers:
            raise ValueError(f"model {name!r} already routed")
        if server.name is None:
            server.name = name
        self._servers[name] = server
        return self

    def models(self) -> tuple[str, ...]:
        return tuple(sorted(self._servers))

    def __getitem__(self, name: str) -> XMCServer:
        return self._servers[name]

    def __len__(self) -> int:
        return len(self._servers)

    def submit(self, model: str, x: np.ndarray) -> XMCFuture:
        try:
            server = self._servers[model]
        except KeyError:
            raise ValueError(f"unknown model {model!r}; routed models: "
                             f"{self.models()}") from None
        return server.submit(x)

    def refresh(self, name: str, directory: str, *,
                serve_override=None, mesh=None):
        """Hot-swap the named server onto the checkpoint in `directory`.

        Opens the checkpoint strictly (a still-streaming directory raises
        — see `CheckpointHandle.open`), builds the engine its spec (or
        `serve_override`) describes, and `swap`s it in: the server keeps
        answering on the old model until the new one is warm, then flips
        between micro-batches. Returns the previous engine (kept on the
        server as `previous_engine`) for rollback.
        """
        try:
            server = self._servers[name]
        except KeyError:
            raise ValueError(f"unknown model {name!r}; routed models: "
                             f"{self.models()}") from None
        from repro.xmc_api import CheckpointHandle      # deferred: no cycle
        handle = CheckpointHandle.open(directory)
        serve = (serve_override or handle.spec.serve).validate()
        # swap() warms for the SERVER's buckets — skip the engine's own
        # construction-time warm-up so nothing compiles twice.
        engine = handle.engine(serve.replace(warmup=False), mesh=mesh)
        return server.swap(engine)

    def watch(self, name: str, directory: str, *, serve_override=None,
              mesh=None, poll_interval_s: float = 2.0, on_swap=None):
        """Attach a `lifecycle.refresh.CheckpointWatcher` that polls
        `directory`'s generation counter and `refresh`es the named server
        whenever a newer finalized checkpoint lands. The watcher thread is
        owned by the router and joined by `stop()`. Returns the watcher
        (use its `poll_once()` for deterministic tests)."""
        if name not in self._servers:
            raise ValueError(f"unknown model {name!r}; routed models: "
                             f"{self.models()}")
        from repro.lifecycle.refresh import CheckpointWatcher  # no cycle
        watcher = CheckpointWatcher(
            directory, self._servers[name], serve_override=serve_override,
            mesh=mesh, poll_interval_s=poll_interval_s, on_swap=on_swap)
        self._watchers.append(watcher)
        watcher.start()
        return watcher

    def start(self) -> "ModelRouter":
        for srv in self._servers.values():
            srv.start()
        return self

    def stop(self) -> None:
        for w in self._watchers:     # watchers first: no swap mid-drain
            w.stop()
        for srv in self._servers.values():
            srv.stop()

    def __enter__(self) -> "ModelRouter":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    def stats(self) -> dict[str, dict]:
        return {name: srv.stats() for name, srv in self._servers.items()}
