"""XMC serving engine: top-k label queries over a pruned DiSMEC model.

This is the paper's distributed prediction (§2.2.1) as a serving subsystem
rather than an example script. In the declarative session API it is the
back half of the one experiment object: a `ServeSpec` (backend kind, k,
buckets, Pallas mode) rides inside every checkpoint manifest, and

    from repro.xmc_api import CheckpointHandle
    engine = CheckpointHandle.open(ckpt_dir).engine()

builds this engine exactly as the spec describes (pass
`engine(serve_override=ServeSpec(...))` to serve the same weights
differently). Backends live in a decorator registry —
`@register_backend("kind")` plugs a new scoring implementation (quantized,
multi-model, ...) into the engine, `make_backend` is a thin lookup, and
`ServeSpec(backend="kind")` selects it without touching engine code.

One engine, four built-in interchangeable backends behind the
`PredictBackend` protocol:

  dense     — jitted X @ W.T + lax.top_k on the densified model. Baseline
              and reference semantics.
  bsr       — the block-sparse Pallas predict kernel fused with the blocked
              Pallas top-k (kernels/bsr_predict.ops.bsr_predict_topk); the
              model stays in packed BSR form end-to-end, compute scales
              with block density.
  sharded   — label-sharded local-topk + all-gather merge
              (core.prediction.predict_topk_sharded) on a device mesh; only
              k*n_shards candidates ever cross the interconnect.
  shortlist — two-stage sub-linear scoring: a coarse stage
              (serve/shortlist.py — block centroids, a learned one-vs-rest
              meta-classifier, or a fastxml-style routing tree, whichever
              the checkpoint's artifact holds) picks the top-B BSR row
              blocks, then the gathered-block Pallas kernel
              (bsr_predict_gather_topk) scores only those blocks. Compute
              scales with B * block_size + R * D, not L * D. Falls back to
              exhaustive BSR when the checkpoint has no shortlist artifact.
              `ShortlistBackend(int8=True)` swaps the fine stage to the
              int8 gathered kernel — coarse gate AND quarter weight traffic.
              `per_query=True` selects top-B blocks per QUERY and scores
              each row's own list through the ragged-gather kernel
              (bsr_predict_gather_pq_topk); B = n_row_blocks collapses back
              to the shared exhaustive-equivalent path.
  int8      — the bsr path over the symmetric per-block int8 artifact
              (`core.pruning.Int8BlockSparseModel`): int8 tiles + fp32
              per-block scales dequantized in-register, ~0.25x the weight
              HBM traffic of fp32 BSR at scores within the per-block
              quantization bound (so top-k agreement, not bit equality).

All built-ins except int8 produce identical top-k label ids on the same
pruned model
(the shortlist backend whenever its candidate set covers the true top-k;
exactly, tie order included, when B equals the row-block count): padding
labels a backend introduces (BSR block padding, shard divisibility padding)
are masked below any real score before the merge, and fully pruned real
labels keep their exact-zero dense score in every backend.

Request-side machinery lives here too: the engine pulls requests through
`serve.batching.MicroBatchQueue` (size-bucketed padding of ragged streams),
warms up one XLA compile per bucket, and tracks per-request latency
percentiles (enqueue -> completion, so queue wait is measured). The
synchronous path is `submit()` + `step()`; `engine.server()` wraps the
same engine in the async continuous-batching loop (`serve/server.py`) —
future-style results, deadline-launched buckets, admission control —
without changing the backend math or the top-k bits. Backend math lives in module-level jitted functions, so two
backends over equal-shaped models share one XLA compile cache entry per
bucket — opening a second engine never repeats the first one's warm-up
compiles (the process-wide ledger below skips the redundant dispatches).
Models load from the sparse checkpoint artifact written by
`BlockSparseModel.save` — saved once offline like the paper's per-batch
model files, served without re-densifying (the dense/sharded backends
densify in memory at load; the checkpoint on disk is always sparse).
"""

from __future__ import annotations

import dataclasses
import functools
import hashlib
import inspect
import time
from typing import Iterable, Protocol, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.prediction import predict_topk_sharded
from repro.core.pruning import (BlockSparseModel, Int8BlockSparseModel,
                                quantize_block_sparse, to_block_sparse)
from repro.serve.batching import (DEFAULT_BUCKETS, LatencyStats,
                                  MicroBatchQueue)
from repro.serve.shortlist import ShortlistArtifact, build_shortlist

Array = jax.Array

#: Built-in backend kinds (the registry below may grow beyond these).
BACKENDS = ("dense", "bsr", "sharded", "shortlist", "int8")


class PredictBackend(Protocol):
    """What the engine needs from a scoring implementation."""

    name: str
    n_labels: int
    k: int

    def topk(self, x: Array) -> tuple[Array, Array]:
        """x (n, D) -> (scores, label ids), each (n, k)."""
        ...


# ---------------------------------------------------------------------------
# Module-level jitted scoring functions. Backends used to close jit over
# per-instance state, so every backend object carried its own compile cache
# and a second engine over an equal-shaped model re-paid every bucket
# compile. At module level jax keys the cache on (arg shapes/dtypes, static
# values) alone: any two backends with equal (D, k) and model geometry share
# one executable per bucket.
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("k",))
def _dense_topk(x: Array, W: Array, k: int) -> tuple[Array, Array]:
    return jax.lax.top_k(x @ W.T, k)


@functools.partial(jax.jit, static_argnames=(
    "shape", "block_shape", "orig_shape", "k", "n_labels", "interpret"))
def _bsr_topk(x, blocks, block_rows, block_cols, row_ptr, *, shape,
              block_shape, orig_shape, k, n_labels, interpret):
    from repro.kernels.bsr_predict import ops as bsr_ops   # deferred: no cycle
    model = BlockSparseModel(blocks=blocks, block_rows=block_rows,
                             block_cols=block_cols, row_ptr=row_ptr,
                             shape=shape, block_shape=block_shape,
                             orig_shape=orig_shape)
    return bsr_ops.bsr_predict_topk(x, model, k, n_labels=n_labels,
                                    interpret=interpret)


@functools.partial(jax.jit, static_argnames=("B",))
def _shortlist_select(x: Array, centroids: Array, B: int) -> Array:
    """Coarse stage: top-B row blocks for one micro-batch, sorted ascending.

    One (n, Dp) x (Dp, R) matmul, max over the batch's per-query scores
    (static output shape: one selection serves the whole micro-batch), then
    lax.top_k. The sort makes B = R reproduce exhaustive scoring bit-for-bit
    (same float accumulation order into the same top-k input).
    """
    Dp = centroids.shape[1]
    xf = x.astype(jnp.float32)
    if xf.shape[1] < Dp:
        xf = jnp.pad(xf, ((0, 0), (0, Dp - xf.shape[1])))
    coarse = xf @ centroids.T                      # (n, R)
    _, sel = jax.lax.top_k(coarse.max(axis=0), B)
    return jnp.sort(sel)


@functools.partial(jax.jit, static_argnames=("B",))
def _shortlist_select_pq(x: Array, centroids: Array, B: int) -> Array:
    """Per-query coarse stage: top-B row blocks for EACH row of the
    micro-batch, each row's list sorted ascending. The ragged-gather fine
    stage scores row q against exactly its own list — easy queries stop
    paying for the batch union's width. Only reached for B < n_row_blocks
    (full width collapses to `_shortlist_select`, see ShortlistBackend)."""
    Dp = centroids.shape[1]
    xf = x.astype(jnp.float32)
    if xf.shape[1] < Dp:
        xf = jnp.pad(xf, ((0, 0), (0, Dp - xf.shape[1])))
    coarse = xf @ centroids.T                      # (n, R)
    _, sel = jax.lax.top_k(coarse, B)              # (n, B) per-row
    return jnp.sort(sel, axis=1)


@functools.partial(jax.jit, static_argnames=("depth",))
def _tree_coarse(x: Array, nodes: Array, leaf_scores: Array,
                 depth: int) -> Array:
    """Tree-routing coarse scores: descend the complete binary tree of
    hyperplanes (level-order `nodes`, one (Dp,) normal each) for `depth`
    static steps, then read the reached leaf's per-row-block score row.
    Returns (n, R) — fed to the same shared/per-query block selection as
    the matrix coarse kinds."""
    Dp = nodes.shape[1]
    xf = x.astype(jnp.float32)
    if xf.shape[1] < Dp:
        xf = jnp.pad(xf, ((0, 0), (0, Dp - xf.shape[1])))
    idx = jnp.zeros((xf.shape[0],), jnp.int32)
    for _ in range(depth):                         # static, tiny depth
        w = nodes[idx]                             # (n, Dp) routed normals
        go_right = (jnp.sum(xf * w, axis=1) >= 0.0).astype(jnp.int32)
        idx = 2 * idx + 1 + go_right
    leaf = idx - (2 ** depth - 1)
    return leaf_scores[leaf]                       # (n, R)


@functools.partial(jax.jit, static_argnames=("B",))
def _select_shared_from(coarse: Array, B: int) -> Array:
    """Shared top-B selection from precomputed (n, R) coarse scores."""
    _, sel = jax.lax.top_k(coarse.max(axis=0), B)
    return jnp.sort(sel)


@functools.partial(jax.jit, static_argnames=("B",))
def _select_pq_from(coarse: Array, B: int) -> Array:
    """Per-query top-B selection from precomputed (n, R) coarse scores."""
    _, sel = jax.lax.top_k(coarse, B)
    return jnp.sort(sel, axis=1)


@functools.partial(jax.jit, static_argnames=(
    "shape", "block_shape", "orig_shape", "k", "n_labels", "max_per_row",
    "interpret"))
def _gather_topk(x, sel, blocks, block_rows, block_cols, row_ptr, *, shape,
                 block_shape, orig_shape, k, n_labels, max_per_row,
                 interpret):
    """Shared-selection fine stage with the (B,) selection as a runtime
    argument (the tree coarse stage computes it outside this trace)."""
    from repro.kernels.bsr_predict import ops as bsr_ops   # deferred: no cycle
    model = BlockSparseModel(blocks=blocks, block_rows=block_rows,
                             block_cols=block_cols, row_ptr=row_ptr,
                             shape=shape, block_shape=block_shape,
                             orig_shape=orig_shape)
    return bsr_ops.bsr_predict_gather_topk(x, model, sel, k,
                                           n_labels=n_labels,
                                           max_per_row=max_per_row,
                                           interpret=interpret)


@functools.partial(jax.jit, static_argnames=(
    "shape", "block_shape", "orig_shape", "k", "n_labels", "max_per_row",
    "interpret"))
def _gather_int8_topk(x, sel, blocks, scales, block_rows, block_cols,
                      row_ptr, *, shape, block_shape, orig_shape, k,
                      n_labels, max_per_row, interpret):
    from repro.kernels.bsr_predict import ops as bsr_ops   # deferred: no cycle
    model = Int8BlockSparseModel(blocks=blocks, scales=scales,
                                 block_rows=block_rows, block_cols=block_cols,
                                 row_ptr=row_ptr, shape=shape,
                                 block_shape=block_shape,
                                 orig_shape=orig_shape)
    return bsr_ops.bsr_predict_gather_int8_topk(x, model, sel, k,
                                                n_labels=n_labels,
                                                max_per_row=max_per_row,
                                                interpret=interpret)


@functools.partial(jax.jit, static_argnames=(
    "shape", "block_shape", "orig_shape", "k", "n_labels", "max_per_row",
    "interpret"))
def _gather_pq_topk(x, sel, blocks, block_rows, block_cols, row_ptr, *,
                    shape, block_shape, orig_shape, k, n_labels,
                    max_per_row, interpret):
    """Per-query ragged fine stage: sel is (n, B), row q scores only its
    own block list through the prefetch-steered ragged-gather kernel."""
    from repro.kernels.bsr_predict import ops as bsr_ops   # deferred: no cycle
    model = BlockSparseModel(blocks=blocks, block_rows=block_rows,
                             block_cols=block_cols, row_ptr=row_ptr,
                             shape=shape, block_shape=block_shape,
                             orig_shape=orig_shape)
    return bsr_ops.bsr_predict_gather_pq_topk(x, model, sel, k,
                                              n_labels=n_labels,
                                              max_per_row=max_per_row,
                                              interpret=interpret)


@functools.partial(jax.jit, static_argnames=(
    "shape", "block_shape", "orig_shape", "k", "n_labels", "max_per_row",
    "interpret"))
def _gather_pq_int8_topk(x, sel, blocks, scales, block_rows, block_cols,
                         row_ptr, *, shape, block_shape, orig_shape, k,
                         n_labels, max_per_row, interpret):
    from repro.kernels.bsr_predict import ops as bsr_ops   # deferred: no cycle
    model = Int8BlockSparseModel(blocks=blocks, scales=scales,
                                 block_rows=block_rows, block_cols=block_cols,
                                 row_ptr=row_ptr, shape=shape,
                                 block_shape=block_shape,
                                 orig_shape=orig_shape)
    return bsr_ops.bsr_predict_gather_pq_int8_topk(x, model, sel, k,
                                                   n_labels=n_labels,
                                                   max_per_row=max_per_row,
                                                   interpret=interpret)


@functools.partial(jax.jit, static_argnames=(
    "shape", "block_shape", "orig_shape", "k", "n_labels", "B",
    "max_per_row", "interpret"))
def _shortlist_topk(x, centroids, blocks, block_rows, block_cols, row_ptr,
                    *, shape, block_shape, orig_shape, k, n_labels, B,
                    max_per_row, interpret):
    from repro.kernels.bsr_predict import ops as bsr_ops   # deferred: no cycle
    sel = _shortlist_select(x, centroids, B)
    model = BlockSparseModel(blocks=blocks, block_rows=block_rows,
                             block_cols=block_cols, row_ptr=row_ptr,
                             shape=shape, block_shape=block_shape,
                             orig_shape=orig_shape)
    return bsr_ops.bsr_predict_gather_topk(x, model, sel, k,
                                           n_labels=n_labels,
                                           max_per_row=max_per_row,
                                           interpret=interpret)


@functools.partial(jax.jit, static_argnames=(
    "shape", "block_shape", "orig_shape", "k", "n_labels", "interpret"))
def _bsr_int8_topk(x, blocks, scales, block_rows, block_cols, row_ptr, *,
                   shape, block_shape, orig_shape, k, n_labels, interpret):
    from repro.kernels.bsr_predict import ops as bsr_ops   # deferred: no cycle
    model = Int8BlockSparseModel(blocks=blocks, scales=scales,
                                 block_rows=block_rows, block_cols=block_cols,
                                 row_ptr=row_ptr, shape=shape,
                                 block_shape=block_shape,
                                 orig_shape=orig_shape)
    return bsr_ops.bsr_predict_int8_topk(x, model, k, n_labels=n_labels,
                                         interpret=interpret)


@functools.partial(jax.jit, static_argnames=(
    "shape", "block_shape", "orig_shape", "k", "n_labels", "B",
    "max_per_row", "interpret"))
def _shortlist_int8_topk(x, centroids, blocks, scales, block_rows,
                         block_cols, row_ptr, *, shape, block_shape,
                         orig_shape, k, n_labels, B, max_per_row, interpret):
    from repro.kernels.bsr_predict import ops as bsr_ops   # deferred: no cycle
    sel = _shortlist_select(x, centroids, B)
    model = Int8BlockSparseModel(blocks=blocks, scales=scales,
                                 block_rows=block_rows, block_cols=block_cols,
                                 row_ptr=row_ptr, shape=shape,
                                 block_shape=block_shape,
                                 orig_shape=orig_shape)
    return bsr_ops.bsr_predict_gather_int8_topk(x, model, sel, k,
                                                n_labels=n_labels,
                                                max_per_row=max_per_row,
                                                interpret=interpret)


class DenseBackend:
    """Reference semantics: jitted dense scores + lax.top_k."""

    name = "dense"

    def __init__(self, W: Array, k: int, *, n_labels: int | None = None):
        self.k = k
        self.n_labels = int(n_labels if n_labels is not None else W.shape[0])
        self._W = jnp.asarray(W[:self.n_labels])   # drop any padding rows
        self._fn = functools.partial(_dense_topk, W=self._W, k=k)

    def warmup_key(self):
        return ("dense", self._W.shape, str(self._W.dtype), self.k)

    def topk(self, x: Array) -> tuple[Array, Array]:
        return self._fn(x)


class BsrBackend:
    """Packed block-sparse model through the Pallas predict+topk kernels."""

    name = "bsr"

    def __init__(self, model: BlockSparseModel, k: int,
                 *, n_labels: int | None = None, interpret: bool = True):
        self.k = k
        self.n_labels = int(n_labels if n_labels is not None
                            else model.n_labels)
        self.model = model
        self._interpret = bool(interpret)

    def warmup_key(self):
        m = self.model
        return ("bsr", m.blocks.shape, str(jnp.asarray(m.blocks).dtype),
                m.shape, m.block_shape, m.orig_shape, self.k, self.n_labels,
                self._interpret)

    def topk(self, x: Array) -> tuple[Array, Array]:
        m = self.model
        return _bsr_topk(x, m.blocks, m.block_rows, m.block_cols, m.row_ptr,
                         shape=m.shape, block_shape=m.block_shape,
                         orig_shape=m.orig_shape, k=self.k,
                         n_labels=self.n_labels, interpret=self._interpret)


class Int8Backend:
    """Exhaustive BSR scoring over the int8 per-block-scaled artifact.

    Accepts either the quantized artifact directly or a fp32
    `BlockSparseModel` (quantized here — identical bytes to the persisted
    checkpoint artifact, so legacy fp32-only checkpoints serve int8 too).
    """

    name = "int8"

    def __init__(self, model, k: int, *, n_labels: int | None = None,
                 interpret: bool = True):
        if isinstance(model, BlockSparseModel):
            model = quantize_block_sparse(model)
        self.k = k
        self.n_labels = int(n_labels if n_labels is not None
                            else model.n_labels)
        self.model = model
        self._interpret = bool(interpret)

    def warmup_key(self):
        # Leads with a distinct kind tag AND the int8 dtype: an int8 backend
        # over the same geometry as a fp32 bsr backend must never mark the
        # fp32 bucket warm (different executable, different numerics).
        m = self.model
        return ("int8", m.blocks.shape, str(jnp.asarray(m.blocks).dtype),
                m.shape, m.block_shape, m.orig_shape, self.k, self.n_labels,
                self._interpret)

    def topk(self, x: Array) -> tuple[Array, Array]:
        m = self.model
        return _bsr_int8_topk(x, m.blocks, m.scales, m.block_rows,
                              m.block_cols, m.row_ptr, shape=m.shape,
                              block_shape=m.block_shape,
                              orig_shape=m.orig_shape, k=self.k,
                              n_labels=self.n_labels,
                              interpret=self._interpret)


class ShortlistBackend:
    """Two-stage sub-linear scoring: coarse block shortlist + gathered fine
    stage over the packed BSR tiles of the selected row blocks only.

    The coarse stage is whatever the artifact holds (`artifact.kind`):
    "centroid" and "learned" are both one (n, Dp) x (Dp, R) matmul (block
    means vs a trained one-vs-rest meta-classifier — same serving math,
    different matrix), "tree" routes each query down a fixed-depth
    hyperplane tree to a leaf's per-block score row. Selection is shared
    per micro-batch by default; `per_query=True` gives each row its own
    top-B list, scored through the ragged-gather kernel.

    B (the shortlist width, in row blocks) is static per backend: one XLA
    compile per bucket, candidate fraction B / R. At B == R every
    per-query sorted top-B list provably equals the one shared sorted full
    list, so full width ALWAYS collapses to the shared kernel: the
    exhaustive bit-exactness contract rides on the proven path, and the
    ragged kernel serves only genuinely sub-linear B < R work. One caveat
    inherited from bucket padding: the shared coarse max runs over the
    padded micro-batch, and a padding row's coarse score is exactly 0 — on
    models whose true coarse scores are all negative, padding can steer
    (never widen) the selection. Per-query selection is immune: padding
    rows select for themselves and their results are dropped at un-pad.
    """

    name = "shortlist"

    def __init__(self, model: BlockSparseModel, artifact: ShortlistArtifact,
                 k: int, *, n_labels: int | None = None,
                 blocks: int | None = None, interpret: bool = True,
                 int8: bool = False, int8_model=None,
                 per_query: bool = False):
        from repro.kernels.bsr_predict import ops as bsr_ops
        artifact.validate_against(model)
        self.k = k
        self.n_labels = int(n_labels if n_labels is not None
                            else model.n_labels)
        self.model = model
        self.artifact = artifact
        self.kind = artifact.kind
        R = artifact.n_row_blocks
        self.B = min(int(blocks if blocks is not None
                         else artifact.default_blocks()), R)
        if self.B < 1:
            raise ValueError(f"shortlist width must be >= 1, got {self.B}")
        # Full-width collapse (see class docstring): B == R means every
        # query's sorted list is 0..R-1 — identical to the shared list.
        self.per_query = bool(per_query) and self.B < R
        self._centroids = jnp.asarray(artifact.centroids)
        self._tree = None
        if self.kind == "tree":
            self._tree = (jnp.asarray(artifact.tree_nodes),
                          jnp.asarray(artifact.tree_leaf_scores),
                          int(artifact.tree_depth))
        self._max_per_row = bsr_ops.max_blocks_per_row(model)
        self._interpret = bool(interpret)
        # int8 composition: the coarse stage is unchanged (fp32 — tiny next
        # to the fine stage), the gathered fine stage scores quantized
        # tiles. Pass `int8_model` to reuse a persisted artifact; otherwise
        # quantize here (bit-identical either way).
        self.int8 = bool(int8)
        self.int8_model = None
        if self.int8:
            self.int8_model = (int8_model if int8_model is not None
                               else quantize_block_sparse(model))

    @property
    def candidate_fraction(self) -> float:
        """Fraction of row blocks the fine stage scores per query (shared
        selection charges the whole micro-batch the same B)."""
        return self.B / self.artifact.n_row_blocks

    def warmup_key(self):
        # `self.int8`, `self.kind` and `self.per_query` are part of the
        # key: int8 vs fp32 fine stages, tree vs matrix coarse stages, and
        # ragged vs shared gathers are different executables over the same
        # geometry and must not alias each other's warm buckets.
        m = self.model
        return ("shortlist", self.kind, self.per_query, self.int8,
                m.blocks.shape, str(jnp.asarray(m.blocks).dtype), m.shape,
                m.block_shape, m.orig_shape, self._centroids.shape, self.B,
                self._max_per_row, self.k, self.n_labels, self._interpret)

    def _select(self, x: Array) -> Array:
        """The selection the fine stage will score: (B,) shared, or (n, B)
        per-query, row-sorted either way."""
        if self.kind == "tree":
            nodes, leaf_scores, depth = self._tree
            coarse = _tree_coarse(x, nodes, leaf_scores, depth)
            if self.per_query:
                return _select_pq_from(coarse, self.B)
            return _select_shared_from(coarse, self.B)
        if self.per_query:
            return _shortlist_select_pq(x, self._centroids, self.B)
        return _shortlist_select(x, self._centroids, self.B)

    def select_blocks(self, x: Array) -> np.ndarray:
        """Coarse-stage introspection: the sorted row-block ids the fine
        stage would score for this batch — (B,) shared or (n, B) per-query
        (benchmarks measure recall and candidate fraction through this)."""
        return np.asarray(self._select(jnp.asarray(x, jnp.float32)))

    def topk(self, x: Array) -> tuple[Array, Array]:
        if self.kind != "tree" and not self.per_query:
            # Matrix coarse + shared selection: the original fused paths,
            # byte-for-byte untouched (the B == R bit-exactness contract
            # and all pre-v2 serving behavior ride on these).
            if self.int8:
                q = self.int8_model
                return _shortlist_int8_topk(
                    x, self._centroids, q.blocks, q.scales, q.block_rows,
                    q.block_cols, q.row_ptr, shape=q.shape,
                    block_shape=q.block_shape, orig_shape=q.orig_shape,
                    k=self.k, n_labels=self.n_labels, B=self.B,
                    max_per_row=self._max_per_row, interpret=self._interpret)
            m = self.model
            return _shortlist_topk(
                x, self._centroids, m.blocks, m.block_rows, m.block_cols,
                m.row_ptr, shape=m.shape, block_shape=m.block_shape,
                orig_shape=m.orig_shape, k=self.k, n_labels=self.n_labels,
                B=self.B, max_per_row=self._max_per_row,
                interpret=self._interpret)
        sel = self._select(x)
        if self.int8:
            q = self.int8_model
            fn = _gather_pq_int8_topk if self.per_query else _gather_int8_topk
            return fn(x, sel, q.blocks, q.scales, q.block_rows, q.block_cols,
                      q.row_ptr, shape=q.shape, block_shape=q.block_shape,
                      orig_shape=q.orig_shape, k=self.k,
                      n_labels=self.n_labels, max_per_row=self._max_per_row,
                      interpret=self._interpret)
        m = self.model
        fn = _gather_pq_topk if self.per_query else _gather_topk
        return fn(x, sel, m.blocks, m.block_rows, m.block_cols, m.row_ptr,
                  shape=m.shape, block_shape=m.block_shape,
                  orig_shape=m.orig_shape, k=self.k, n_labels=self.n_labels,
                  max_per_row=self._max_per_row, interpret=self._interpret)


class RelabelBackend:
    """Pack-time reorder unmapping: wraps any backend serving a checkpoint
    packed under a `label_order` permutation and maps its packed top-k ids
    back to original label ids (`order[packed_id]`), scores untouched.

    Sits at the backend layer (not the engine) so both the synchronous
    `step()` drain and the async server's direct `backend.topk` dispatch
    unmap identically; everything else — kernels, selection, warm-up —
    stays oblivious to the reorder. `__getattr__` delegates introspection
    (`select_blocks`, `model`, `candidate_fraction`, ...) to the inner
    backend."""

    def __init__(self, inner: PredictBackend, label_order):
        order = np.asarray(label_order, np.int64).reshape(-1)
        n = int(getattr(inner, "n_labels", order.shape[0]))
        if (order.shape[0] != n
                or not np.array_equal(np.sort(order), np.arange(n))):
            raise ValueError(
                f"label_order must be a permutation of range({n})")
        self.inner = inner
        self.name = inner.name
        self.k = inner.k
        self.n_labels = n
        self._order = jnp.asarray(order, jnp.int32)
        self._digest = hashlib.sha1(order.tobytes()).hexdigest()[:16]

    def warmup_key(self):
        key = getattr(self.inner, "warmup_key", lambda: None)()
        # The gather is one extra executable per shape; two engines over
        # the same inner geometry but different permutations must not mark
        # each other warm, hence the order digest.
        return None if key is None else ("relabel", self._digest, key)

    def topk(self, x: Array) -> tuple[Array, Array]:
        scores, labels = self.inner.topk(x)
        return scores, jnp.take(self._order, labels, axis=0)

    def __getattr__(self, name):
        return getattr(self.inner, name)


class ShardedBackend:
    """Mesh label-sharded local-topk + all-gather merge (paper §2.2.1)."""

    name = "sharded"

    def __init__(self, W: Array, k: int, mesh, *, label_axis: str = "model",
                 n_labels: int | None = None):
        self.k = k
        self.n_labels = int(n_labels if n_labels is not None else W.shape[0])
        n_shards = mesh.shape[label_axis]
        L = W.shape[0]
        Lp = ((L + n_shards - 1) // n_shards) * n_shards
        if Lp != L:                                 # shard-divisibility pad
            W = jnp.concatenate(
                [W, jnp.zeros((Lp - L, W.shape[1]), W.dtype)], axis=0)
        self._W = jnp.asarray(W)
        self._fn = jax.jit(
            lambda x: predict_topk_sharded(x, self._W, k, mesh,
                                           label_axis=label_axis,
                                           n_labels=self.n_labels))

    def warmup_key(self):
        return None        # mesh-bound closure: never share warm-up state

    def topk(self, x: Array) -> tuple[Array, Array]:
        return self._fn(x)


# ---------------------------------------------------------------------------
# Backend registry: kind -> factory(bsr, k, *, n_labels, mesh, label_axis,
# interpret) -> PredictBackend. New backends plug in via the decorator; the
# engine, the CLIs, and ServeSpec all resolve kinds through this one table.
# ---------------------------------------------------------------------------

_BACKEND_REGISTRY: dict[str, "object"] = {}


def register_backend(kind: str):
    """Decorator: plug a new predict backend into the serving registry.

    The factory receives the canonical model artifact and must return a
    `PredictBackend`::

        @register_backend("quantized")
        def _make_quantized(bsr, k, *, n_labels, mesh, label_axis,
                            interpret):
            return QuantizedBackend(bsr, k, n_labels=n_labels)

    After registration, `ServeSpec(backend="quantized")`,
    `XMCEngine.from_checkpoint(..., backend="quantized")` and the serving
    CLI all reach it — no engine code changes.
    """
    def deco(factory):
        if kind in _BACKEND_REGISTRY:
            raise ValueError(f"backend {kind!r} already registered")
        _BACKEND_REGISTRY[kind] = factory
        return factory
    return deco


def unregister_backend(kind: str) -> None:
    """Remove a registered backend kind (plugin teardown / tests)."""
    _BACKEND_REGISTRY.pop(kind, None)


def available_backends() -> tuple[str, ...]:
    """Every registered backend kind (built-ins + plugins), sorted."""
    return tuple(sorted(_BACKEND_REGISTRY))


@register_backend("dense")
def _make_dense_backend(bsr: BlockSparseModel, k: int, *, n_labels: int,
                        mesh, label_axis: str, interpret: bool):
    return DenseBackend(bsr.to_dense()[:n_labels, :bsr.n_features], k,
                        n_labels=n_labels)


@register_backend("bsr")
def _make_bsr_backend(bsr: BlockSparseModel, k: int, *, n_labels: int,
                      mesh, label_axis: str, interpret: bool,
                      int8=False, int8_model=None):
    if int8:      # ServeSpec(backend="bsr", int8=True) == the "int8" kind
        return Int8Backend(int8_model if int8_model is not None else bsr,
                           k, n_labels=n_labels, interpret=interpret)
    return BsrBackend(bsr, k, n_labels=n_labels, interpret=interpret)


@register_backend("sharded")
def _make_sharded_backend(bsr: BlockSparseModel, k: int, *, n_labels: int,
                          mesh, label_axis: str, interpret: bool):
    if mesh is None:
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh(1, jax.device_count())
    return ShardedBackend(bsr.to_dense()[:n_labels, :bsr.n_features], k,
                          mesh, label_axis=label_axis, n_labels=n_labels)


@register_backend("int8")
def _make_int8_backend(bsr: BlockSparseModel, k: int, *, n_labels: int,
                       mesh, label_axis: str, interpret: bool,
                       int8_model=None):
    return Int8Backend(int8_model if int8_model is not None else bsr, k,
                       n_labels=n_labels, interpret=interpret)


@register_backend("shortlist")
def _make_shortlist_backend(bsr: BlockSparseModel, k: int, *, n_labels: int,
                            mesh, label_axis: str, interpret: bool,
                            shortlist=None, shortlist_blocks=None,
                            int8=False, int8_model=None,
                            shortlist_per_query=False):
    if shortlist is None:
        # Legacy checkpoint (or in-memory model) without the artifact:
        # exhaustive BSR scoring, same results, no sub-linear gate.
        if int8:
            return Int8Backend(int8_model if int8_model is not None else bsr,
                               k, n_labels=n_labels, interpret=interpret)
        return BsrBackend(bsr, k, n_labels=n_labels, interpret=interpret)
    return ShortlistBackend(bsr, shortlist, k, n_labels=n_labels,
                            blocks=shortlist_blocks, interpret=interpret,
                            int8=int8, int8_model=int8_model,
                            per_query=shortlist_per_query)


def make_backend(kind: str, bsr: BlockSparseModel, k: int, *,
                 n_labels: int | None = None, mesh=None,
                 label_axis: str = "model", interpret: bool = True,
                 shortlist: ShortlistArtifact | None = None,
                 shortlist_blocks: int | None = None,
                 int8: bool = False,
                 int8_model: Int8BlockSparseModel | None = None,
                 shortlist_per_query: bool = False,
                 label_order=None,
                 ) -> PredictBackend:
    """Build any registered backend from the one canonical model artifact
    (packed BSR) — a thin lookup over the registry.

    dense/sharded densify in memory, sliced back to the true (L, D) so
    block padding never surfaces; bsr serves the packed form directly (its
    kernel pads x internally and its top-k masks padding labels); shortlist
    adds the coarse candidate stage when a `ShortlistArtifact` is supplied.
    kind="int8" (or shortlist with int8=True) serves the quantized artifact
    — pass `int8_model` to reuse a checkpoint's persisted int8 arrays,
    else the fp32 blocks are quantized on the spot (identical bytes).
    `shortlist_per_query` flips the shortlist backend to per-query ragged
    selection. `label_order` (the pack-time reorder permutation recorded in
    the checkpoint manifest) wraps ANY backend in `RelabelBackend` so
    returned ids are original label ids.

    Factories registered before the shortlist kwargs existed keep working:
    keyword args are filtered down to what each factory's signature accepts
    (factories with **kwargs receive everything).
    """
    try:
        factory = _BACKEND_REGISTRY[kind]
    except KeyError:
        raise ValueError(f"unknown backend {kind!r}; expected one of "
                         f"{available_backends()}") from None
    n_labels = int(n_labels if n_labels is not None else bsr.n_labels)
    kwargs = dict(n_labels=n_labels, mesh=mesh, label_axis=label_axis,
                  interpret=interpret, shortlist=shortlist,
                  shortlist_blocks=shortlist_blocks, int8=int8,
                  int8_model=int8_model,
                  shortlist_per_query=shortlist_per_query)
    try:
        params = inspect.signature(factory).parameters
        if not any(p.kind is p.VAR_KEYWORD for p in params.values()):
            kwargs = {k2: v for k2, v in kwargs.items() if k2 in params}
    except (TypeError, ValueError):      # uninspectable callable: old contract
        kwargs = dict(n_labels=n_labels, mesh=mesh, label_axis=label_axis,
                      interpret=interpret)
    be = factory(bsr, k, **kwargs)
    if label_order is not None:
        be = RelabelBackend(be, label_order)
    return be


# ---------------------------------------------------------------------------
# Process-wide warm-up ledger. The jitted functions above make the sharing
# real (one XLA cache entry per computation); this ledger makes it visible
# and cheap: a (warmup_key, bucket, n_features) triple already warmed by ANY
# engine is skipped outright — the second engine's warmup() marks the bucket
# warm without a dispatch. Backends whose key is None (mesh-bound sharded,
# plugins without warmup_key) always dispatch.
# ---------------------------------------------------------------------------

_WARMUP_SEEN: set = set()
_WARMUP_STATS = {"dispatches": 0, "shared_hits": 0}


def reset_warmup_cache() -> None:
    """Forget all shared warm-up state (tests / benchmark isolation). Does
    not touch jax's own compile cache — only the skip-dispatch ledger."""
    _WARMUP_SEEN.clear()
    _WARMUP_STATS["dispatches"] = 0
    _WARMUP_STATS["shared_hits"] = 0


def warmup_cache_stats() -> dict[str, int]:
    """Counters since the last reset: `dispatches` (warm-up calls actually
    issued; each may still hit jax's compile cache) and `shared_hits`
    (bucket warm-ups skipped because an equal computation was already
    warmed by another engine this process)."""
    return dict(_WARMUP_STATS)


@dataclasses.dataclass
class XMCResult:
    """Answer to one request: top-k labels for each of its instances."""
    request_id: int
    scores: np.ndarray                 # (n_i, k)
    labels: np.ndarray                 # (n_i, k) true label ids


class XMCEngine:
    """Micro-batched top-k label serving over a `PredictBackend`.

    The engine owns the request queue, bucket padding, per-bucket warm-up
    compilation, and latency accounting; the backend owns the math.
    """

    def __init__(self, backend: PredictBackend,
                 buckets: Sequence[int] = DEFAULT_BUCKETS,
                 *, warmup: bool = True, n_features: int | None = None):
        self.backend = backend
        self.queue = MicroBatchQueue(buckets)
        self.stats = LatencyStats()
        self._warm: set[int] = set()
        self._n_features = n_features
        if warmup and n_features is not None:
            self.warmup()

    @property
    def n_features(self) -> int | None:
        """Feature dim the engine serves (from checkpoint meta or the first
        submitted request); None until either is known."""
        return self._n_features

    def adopt_n_features(self, n_features: int) -> None:
        """Pin the feature dim on an engine that does not know it yet (no
        checkpoint meta, no request seen). `XMCServer.swap` uses this so an
        in-memory replacement engine can be warmed for the server's buckets
        before the flip; adopting a CONFLICTING dim is refused like a
        mismatched request would be."""
        n_features = int(n_features)
        if self._n_features is not None and self._n_features != n_features:
            raise ValueError(f"engine already serves feature dim "
                             f"{self._n_features}, cannot adopt {n_features}")
        self._n_features = n_features

    # -- model loading ------------------------------------------------------

    @classmethod
    def from_checkpoint(cls, directory: str, *, backend: str = "bsr",
                        k: int = 5, mesh=None, interpret: bool = True,
                        buckets: Sequence[int] = DEFAULT_BUCKETS,
                        warmup: bool = True,
                        shortlist_blocks: int | None = None,
                        int8: bool = False,
                        shortlist_per_query: bool = False) -> "XMCEngine":
        """Serve the sparse artifact written by `BlockSparseModel.save`.

        Also picks up the shortlist artifact saved next to the BSR arrays
        when present — absent (legacy checkpoints), the "shortlist" backend
        silently degrades to exhaustive BSR scoring. backend="int8" (or
        `int8=True` composing with shortlist) serves the checkpoint's
        persisted int8 arrays, quantizing lazily when the checkpoint
        predates them. A checkpoint packed under a `label_order`
        permutation (ScheduleSpec.reorder_labels) is unmapped here: EVERY
        backend's returned ids are original label ids, exactly.
        """
        from repro.checkpoint.io import (load_block_sparse_int8,   # deferred:
                                         load_block_sparse_meta,   # no cycle
                                         load_shortlist)
        bsr, meta = BlockSparseModel.load(directory)
        n_labels = int(meta.get("n_labels", bsr.n_labels))
        int8_model = None
        if int8 or backend == "int8":
            int8_model, _ = load_block_sparse_int8(directory, model=bsr)
        be = make_backend(backend, bsr, k, n_labels=n_labels, mesh=mesh,
                          interpret=interpret,
                          shortlist=load_shortlist(directory),
                          shortlist_blocks=shortlist_blocks,
                          int8=int8, int8_model=int8_model,
                          shortlist_per_query=shortlist_per_query,
                          label_order=load_block_sparse_meta(
                              directory).get("label_order"))
        return cls(be, buckets, warmup=warmup,
                   n_features=int(meta.get("n_features", bsr.n_features)))

    @classmethod
    def from_dismec(cls, model, *, backend: str = "dense", k: int = 5,
                    mesh=None, block_shape: tuple[int, int] = (128, 128),
                    interpret: bool = True,
                    buckets: Sequence[int] = DEFAULT_BUCKETS,
                    warmup: bool = False,
                    shortlist_blocks: int | None = None,
                    int8: bool = False,
                    shortlist_per_query: bool = False) -> "XMCEngine":
        """Convenience: engine straight from an in-memory DiSMECModel (the
        shortlist artifact is built on the fly — no checkpoint needed)."""
        bsr = to_block_sparse(model.W, block_shape)
        be = make_backend(backend, bsr, k, n_labels=model.W.shape[0],
                          mesh=mesh, interpret=interpret,
                          shortlist=build_shortlist(bsr),
                          shortlist_blocks=shortlist_blocks, int8=int8,
                          shortlist_per_query=shortlist_per_query)
        return cls(be, buckets, warmup=warmup,
                   n_features=int(model.W.shape[1]))

    # -- serving ------------------------------------------------------------

    def ensure_warm(self, bucket: int) -> None:
        """Warm one bucket if this engine has not yet (step() and the async
        server share this so no request pays a compile mid-flight)."""
        if bucket not in self._warm:
            self.warmup([bucket])

    def warmup(self, buckets: Sequence[int] | None = None) -> int:
        """Compile the backend once per bucket shape (cold-start cost paid
        up front, not on the first unlucky request). Returns the number of
        buckets newly warmed for THIS engine; buckets another engine
        already warmed process-wide (same `warmup_key`) count but skip the
        dispatch entirely — see `warmup_cache_stats`."""
        assert self._n_features is not None, "n_features needed for warmup"
        key = getattr(self.backend, "warmup_key", lambda: None)()
        done = 0
        for b in (buckets or self.queue.buckets):
            if b in self._warm:
                continue
            gkey = None if key is None else (key, b, self._n_features)
            if gkey is not None and gkey in _WARMUP_SEEN:
                _WARMUP_STATS["shared_hits"] += 1
            else:
                x = jnp.zeros((b, self._n_features), jnp.float32)
                jax.block_until_ready(self.backend.topk(x))
                _WARMUP_STATS["dispatches"] += 1
                if gkey is not None:
                    _WARMUP_SEEN.add(gkey)
            self._warm.add(b)
            done += 1
        return done

    def submit(self, x: np.ndarray) -> int:
        """Enqueue one request of (n_i, D) instances; returns request id.

        Shape-checked at enqueue time: a mismatched request must never
        reach step(), where a mid-drain failure would lose the results of
        co-batched good requests.
        """
        if self._n_features is None:
            self._n_features = int(x.shape[1])
        elif x.shape[1] != self._n_features:
            raise ValueError(
                f"request feature dim {x.shape[1]} != engine feature dim "
                f"{self._n_features}")
        return self.queue.submit(np.asarray(x, np.float32))

    def step(self) -> list[XMCResult]:
        """Drain the queue: run every micro-batch, un-pad, return results.

        One `XMCResult` per request id, always — a request the queue split
        across micro-batches (oversize) has its rows re-coalesced in
        dispatch order before anything is returned. Latency is recorded per
        request from its own enqueue timestamp to the completion of its
        last micro-batch, so time spent waiting in the queue (between
        `submit` and this drain) is part of the number.
        """
        out: dict[int, list[tuple[np.ndarray, np.ndarray]]] = {}
        arrival_by_rid: dict[int, float] = {}
        done_by_rid: dict[int, float] = {}
        for mb in self.queue.drain():
            self.ensure_warm(mb.bucket)
            scores, labels = self.backend.topk(jnp.asarray(mb.x))
            jax.block_until_ready(labels)
            t_done = time.monotonic()
            # A split request completes with its LAST micro-batch: later
            # batches overwrite t_done, the arrival never changes.
            for rid, arrival in zip(mb.request_ids, mb.arrivals):
                arrival_by_rid[rid] = arrival
                done_by_rid[rid] = t_done
            scores, labels = np.asarray(scores), np.asarray(labels)
            for (rid, s), (_, l) in zip(mb.split(scores), mb.split(labels)):
                out.setdefault(rid, []).append((s, l))
        for rid in sorted(done_by_rid):
            self.stats.record_span(arrival_by_rid[rid], done_by_rid[rid])
        results = []
        for rid in sorted(out):
            parts = out[rid]
            results.append(XMCResult(
                request_id=rid,
                scores=np.concatenate([p[0] for p in parts], axis=0),
                labels=np.concatenate([p[1] for p in parts], axis=0)))
        return results

    def serve(self, requests: Iterable[np.ndarray]) -> list[XMCResult]:
        """Submit a whole request stream and drain it. Results are ordered
        by request id (== submission order)."""
        for x in requests:
            self.submit(x)
        return self.step()

    def server(self, **kwargs) -> "object":
        """Wrap this engine in the async continuous-batching loop
        (`serve.server.XMCServer`): `submit` returns futures, buckets
        launch on fill OR deadline, admission control sheds overload. The
        synchronous `step()` path stays available and bit-identical.
        Keyword args go to `XMCServer` (max_batch_delay_ms, max_queue,
        max_inflight, name, start)."""
        from repro.serve.server import XMCServer     # deferred: no cycle
        return XMCServer(self, **kwargs)

    def latency_summary(self) -> dict[str, float]:
        return self.stats.summary()
