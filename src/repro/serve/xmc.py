"""XMC serving engine: top-k label queries over a pruned DiSMEC model.

This is the paper's distributed prediction (§2.2.1) as a serving subsystem
rather than an example script. In the declarative session API it is the
back half of the one experiment object: a `ServeSpec` (backend kind, k,
buckets, Pallas mode) rides inside every checkpoint manifest, and

    from repro.xmc_api import CheckpointHandle
    engine = CheckpointHandle.open(ckpt_dir).engine()

builds this engine exactly as the spec describes (pass
`engine(serve_override=ServeSpec(...))` to serve the same weights
differently). Backends live in a decorator registry —
`@register_backend("kind")` plugs a new scoring implementation (quantized,
multi-model, ...) into the engine, `make_backend` is a thin lookup, and
`ServeSpec(backend="kind")` selects it without touching engine code.

One engine, three built-in interchangeable backends behind the
`PredictBackend` protocol:

  dense    — jitted X @ W.T + lax.top_k on the densified model. Baseline
             and reference semantics.
  bsr      — the block-sparse Pallas predict kernel fused with the blocked
             Pallas top-k (kernels/bsr_predict.ops.bsr_predict_topk); the
             model stays in packed BSR form end-to-end, compute scales with
             block density.
  sharded  — label-sharded local-topk + all-gather merge
             (core.prediction.predict_topk_sharded) on a device mesh; only
             k*n_shards candidates ever cross the interconnect.

All three produce identical top-k label ids on the same pruned model: the
padding labels a backend introduces (BSR block padding, shard divisibility
padding) are masked below any real score before the merge, and fully pruned
real labels keep their exact-zero dense score in every backend.

Request-side machinery lives here too: the engine pulls requests through
`serve.batching.MicroBatchQueue` (size-bucketed padding of ragged streams),
warms up one XLA compile per bucket, and tracks per-request latency
percentiles. Models load from the sparse checkpoint artifact written by
`BlockSparseModel.save` — saved once offline like the paper's per-batch
model files, served without re-densifying (the dense/sharded backends
densify in memory at load; the checkpoint on disk is always sparse).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Iterable, Protocol, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.prediction import predict_topk_sharded
from repro.core.pruning import BlockSparseModel, to_block_sparse
from repro.serve.batching import (DEFAULT_BUCKETS, LatencyStats,
                                  MicroBatchQueue)

Array = jax.Array

#: Built-in backend kinds (the registry below may grow beyond these).
BACKENDS = ("dense", "bsr", "sharded")


class PredictBackend(Protocol):
    """What the engine needs from a scoring implementation."""

    name: str
    n_labels: int
    k: int

    def topk(self, x: Array) -> tuple[Array, Array]:
        """x (n, D) -> (scores, label ids), each (n, k)."""
        ...


class DenseBackend:
    """Reference semantics: jitted dense scores + lax.top_k."""

    name = "dense"

    def __init__(self, W: Array, k: int, *, n_labels: int | None = None):
        self.k = k
        self.n_labels = int(n_labels if n_labels is not None else W.shape[0])
        W = W[:self.n_labels]                      # drop any padding rows
        self._W = jnp.asarray(W)
        self._fn = jax.jit(lambda x: jax.lax.top_k(x @ self._W.T, k))

    def topk(self, x: Array) -> tuple[Array, Array]:
        return self._fn(x)


class BsrBackend:
    """Packed block-sparse model through the Pallas predict+topk kernels."""

    name = "bsr"

    def __init__(self, model: BlockSparseModel, k: int,
                 *, n_labels: int | None = None, interpret: bool = True):
        from repro.kernels.bsr_predict import ops as bsr_ops
        self.k = k
        self.n_labels = int(n_labels if n_labels is not None
                            else model.n_labels)
        self.model = model
        self._fn = jax.jit(
            lambda x: bsr_ops.bsr_predict_topk(
                x, model, k, n_labels=self.n_labels, interpret=interpret))

    def topk(self, x: Array) -> tuple[Array, Array]:
        return self._fn(x)


class ShardedBackend:
    """Mesh label-sharded local-topk + all-gather merge (paper §2.2.1)."""

    name = "sharded"

    def __init__(self, W: Array, k: int, mesh, *, label_axis: str = "model",
                 n_labels: int | None = None):
        self.k = k
        self.n_labels = int(n_labels if n_labels is not None else W.shape[0])
        n_shards = mesh.shape[label_axis]
        L = W.shape[0]
        Lp = ((L + n_shards - 1) // n_shards) * n_shards
        if Lp != L:                                 # shard-divisibility pad
            W = jnp.concatenate(
                [W, jnp.zeros((Lp - L, W.shape[1]), W.dtype)], axis=0)
        self._W = jnp.asarray(W)
        self._fn = jax.jit(
            lambda x: predict_topk_sharded(x, self._W, k, mesh,
                                           label_axis=label_axis,
                                           n_labels=self.n_labels))

    def topk(self, x: Array) -> tuple[Array, Array]:
        return self._fn(x)


# ---------------------------------------------------------------------------
# Backend registry: kind -> factory(bsr, k, *, n_labels, mesh, label_axis,
# interpret) -> PredictBackend. New backends plug in via the decorator; the
# engine, the CLIs, and ServeSpec all resolve kinds through this one table.
# ---------------------------------------------------------------------------

_BACKEND_REGISTRY: dict[str, "object"] = {}


def register_backend(kind: str):
    """Decorator: plug a new predict backend into the serving registry.

    The factory receives the canonical model artifact and must return a
    `PredictBackend`::

        @register_backend("quantized")
        def _make_quantized(bsr, k, *, n_labels, mesh, label_axis,
                            interpret):
            return QuantizedBackend(bsr, k, n_labels=n_labels)

    After registration, `ServeSpec(backend="quantized")`,
    `XMCEngine.from_checkpoint(..., backend="quantized")` and the serving
    CLI all reach it — no engine code changes.
    """
    def deco(factory):
        if kind in _BACKEND_REGISTRY:
            raise ValueError(f"backend {kind!r} already registered")
        _BACKEND_REGISTRY[kind] = factory
        return factory
    return deco


def unregister_backend(kind: str) -> None:
    """Remove a registered backend kind (plugin teardown / tests)."""
    _BACKEND_REGISTRY.pop(kind, None)


def available_backends() -> tuple[str, ...]:
    """Every registered backend kind (built-ins + plugins), sorted."""
    return tuple(sorted(_BACKEND_REGISTRY))


@register_backend("dense")
def _make_dense_backend(bsr: BlockSparseModel, k: int, *, n_labels: int,
                        mesh, label_axis: str, interpret: bool):
    return DenseBackend(bsr.to_dense()[:n_labels, :bsr.n_features], k,
                        n_labels=n_labels)


@register_backend("bsr")
def _make_bsr_backend(bsr: BlockSparseModel, k: int, *, n_labels: int,
                      mesh, label_axis: str, interpret: bool):
    return BsrBackend(bsr, k, n_labels=n_labels, interpret=interpret)


@register_backend("sharded")
def _make_sharded_backend(bsr: BlockSparseModel, k: int, *, n_labels: int,
                          mesh, label_axis: str, interpret: bool):
    if mesh is None:
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh(1, jax.device_count())
    return ShardedBackend(bsr.to_dense()[:n_labels, :bsr.n_features], k,
                          mesh, label_axis=label_axis, n_labels=n_labels)


def make_backend(kind: str, bsr: BlockSparseModel, k: int, *,
                 n_labels: int | None = None, mesh=None,
                 label_axis: str = "model",
                 interpret: bool = True) -> PredictBackend:
    """Build any registered backend from the one canonical model artifact
    (packed BSR) — a thin lookup over the registry.

    dense/sharded densify in memory, sliced back to the true (L, D) so
    block padding never surfaces; bsr serves the packed form directly (its
    kernel pads x internally and its top-k masks padding labels).
    """
    try:
        factory = _BACKEND_REGISTRY[kind]
    except KeyError:
        raise ValueError(f"unknown backend {kind!r}; expected one of "
                         f"{available_backends()}") from None
    n_labels = int(n_labels if n_labels is not None else bsr.n_labels)
    return factory(bsr, k, n_labels=n_labels, mesh=mesh,
                   label_axis=label_axis, interpret=interpret)


@dataclasses.dataclass
class XMCResult:
    """Answer to one request: top-k labels for each of its instances."""
    request_id: int
    scores: np.ndarray                 # (n_i, k)
    labels: np.ndarray                 # (n_i, k) true label ids


class XMCEngine:
    """Micro-batched top-k label serving over a `PredictBackend`.

    The engine owns the request queue, bucket padding, per-bucket warm-up
    compilation, and latency accounting; the backend owns the math.
    """

    def __init__(self, backend: PredictBackend,
                 buckets: Sequence[int] = DEFAULT_BUCKETS,
                 *, warmup: bool = True, n_features: int | None = None):
        self.backend = backend
        self.queue = MicroBatchQueue(buckets)
        self.stats = LatencyStats()
        self._warm: set[int] = set()
        self._n_features = n_features
        if warmup and n_features is not None:
            self.warmup()

    @property
    def n_features(self) -> int | None:
        """Feature dim the engine serves (from checkpoint meta or the first
        submitted request); None until either is known."""
        return self._n_features

    # -- model loading ------------------------------------------------------

    @classmethod
    def from_checkpoint(cls, directory: str, *, backend: str = "bsr",
                        k: int = 5, mesh=None, interpret: bool = True,
                        buckets: Sequence[int] = DEFAULT_BUCKETS,
                        warmup: bool = True) -> "XMCEngine":
        """Serve the sparse artifact written by `BlockSparseModel.save`."""
        bsr, meta = BlockSparseModel.load(directory)
        n_labels = int(meta.get("n_labels", bsr.n_labels))
        be = make_backend(backend, bsr, k, n_labels=n_labels, mesh=mesh,
                          interpret=interpret)
        return cls(be, buckets, warmup=warmup,
                   n_features=int(meta.get("n_features", bsr.n_features)))

    @classmethod
    def from_dismec(cls, model, *, backend: str = "dense", k: int = 5,
                    mesh=None, block_shape: tuple[int, int] = (128, 128),
                    interpret: bool = True,
                    buckets: Sequence[int] = DEFAULT_BUCKETS,
                    warmup: bool = False) -> "XMCEngine":
        """Convenience: engine straight from an in-memory DiSMECModel."""
        bsr = to_block_sparse(model.W, block_shape)
        be = make_backend(backend, bsr, k, n_labels=model.W.shape[0],
                          mesh=mesh, interpret=interpret)
        return cls(be, buckets, warmup=warmup,
                   n_features=int(model.W.shape[1]))

    # -- serving ------------------------------------------------------------

    def warmup(self, buckets: Sequence[int] | None = None) -> int:
        """Compile the backend once per bucket shape (cold-start cost paid
        up front, not on the first unlucky request). Returns #compiles."""
        assert self._n_features is not None, "n_features needed for warmup"
        done = 0
        for b in (buckets or self.queue.buckets):
            if b in self._warm:
                continue
            x = jnp.zeros((b, self._n_features), jnp.float32)
            jax.block_until_ready(self.backend.topk(x))
            self._warm.add(b)
            done += 1
        return done

    def submit(self, x: np.ndarray) -> int:
        """Enqueue one request of (n_i, D) instances; returns request id.

        Shape-checked at enqueue time: a mismatched request must never
        reach step(), where a mid-drain failure would lose the results of
        co-batched good requests.
        """
        if self._n_features is None:
            self._n_features = int(x.shape[1])
        elif x.shape[1] != self._n_features:
            raise ValueError(
                f"request feature dim {x.shape[1]} != engine feature dim "
                f"{self._n_features}")
        return self.queue.submit(np.asarray(x, np.float32))

    def step(self) -> list[XMCResult]:
        """Drain the queue: run every micro-batch, un-pad, return results."""
        out: dict[int, list[tuple[np.ndarray, np.ndarray]]] = {}
        lat_by_rid: dict[int, float] = {}
        for mb in self.queue.drain():
            if mb.bucket not in self._warm:
                self.warmup([mb.bucket])
            t0 = time.time()
            scores, labels = self.backend.topk(jnp.asarray(mb.x))
            jax.block_until_ready(labels)
            dt = time.time() - t0
            # Every co-batched request waited for the same dispatch; a
            # request split across micro-batches waited for all of them.
            for rid in set(mb.request_ids):
                lat_by_rid[rid] = lat_by_rid.get(rid, 0.0) + dt
            scores, labels = np.asarray(scores), np.asarray(labels)
            for (rid, s), (_, l) in zip(mb.split(scores), mb.split(labels)):
                out.setdefault(rid, []).append((s, l))
        for rid in sorted(lat_by_rid):
            self.stats.record(lat_by_rid[rid])
        results = []
        for rid in sorted(out):
            parts = out[rid]
            results.append(XMCResult(
                request_id=rid,
                scores=np.concatenate([p[0] for p in parts], axis=0),
                labels=np.concatenate([p[1] for p in parts], axis=0)))
        return results

    def serve(self, requests: Iterable[np.ndarray]) -> list[XMCResult]:
        """Submit a whole request stream and drain it. Results are ordered
        by request id (== submission order)."""
        for x in requests:
            self.submit(x)
        return self.step()

    def latency_summary(self) -> dict[str, float]:
        return self.stats.summary()
