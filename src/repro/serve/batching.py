"""Request-side batching shared by LM and XMC serving.

Both serving paths face the same problem: a ragged request stream (variable
token counts for the LM, variable instance counts for XMC) must be packed
into a small set of fixed shapes, because every distinct shape costs one XLA
compile. This module owns that machinery:

  * `left_pad_tokens`   — ragged token lists -> one (B, T) batch (LM decode).
  * `pick_bucket`       — smallest power-of-two-ish bucket covering n rows.
  * `pad_rows`          — zero-pad a feature batch up to its bucket size.
  * `MicroBatchQueue`   — FIFO micro-batcher: coalesces queued requests into
                          bucket-sized batches, preserving request identity.
  * `LatencyStats`      — per-request latency percentiles (p50/p90/p99).

The engines (`serve.engine` for LM decode, `serve.xmc.XMCEngine` for label
queries) are thin loops around these primitives.
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Iterator, Sequence

import numpy as np

DEFAULT_BUCKETS = (1, 2, 4, 8, 16, 32, 64, 128, 256)


def left_pad_tokens(requests: Sequence[np.ndarray],
                    pad_id: int = 0) -> np.ndarray:
    """Ragged token id lists -> one left-padded (B, max_len) int32 batch."""
    B = len(requests)
    T0 = max(len(r) for r in requests)
    toks = np.full((B, T0), pad_id, np.int32)
    for i, r in enumerate(requests):
        toks[i, T0 - len(r):] = r
    return toks


def pick_bucket(n: int, buckets: Sequence[int] = DEFAULT_BUCKETS) -> int:
    """Smallest bucket >= n. n larger than every bucket is a caller bug
    (the queue splits oversize requests before picking)."""
    for b in buckets:
        if n <= b:
            return b
    raise ValueError(f"request of {n} rows exceeds largest bucket "
                     f"{buckets[-1]}")


def pad_rows(x: np.ndarray, bucket: int) -> np.ndarray:
    """Zero-pad instances (rows) up to `bucket`. Zero rows score 0 for every
    label and are sliced away before results leave the engine."""
    n = x.shape[0]
    if n == bucket:
        return x
    assert n < bucket, "pad_rows cannot shrink a batch"
    return np.concatenate(
        [x, np.zeros((bucket - n,) + x.shape[1:], x.dtype)], axis=0)


@dataclasses.dataclass
class _Pending:
    request_id: int
    x: np.ndarray                      # (n_i, D)


@dataclasses.dataclass
class MicroBatch:
    """One padded batch plus the bookkeeping to un-pad it."""
    x: np.ndarray                      # (bucket, D)
    bucket: int
    request_ids: list[int]
    row_counts: list[int]              # rows per request, in order

    def split(self, results: np.ndarray) -> Iterator[tuple[int, np.ndarray]]:
        """Slice per-request rows back out of a (bucket, ...) result."""
        off = 0
        for rid, n in zip(self.request_ids, self.row_counts):
            yield rid, results[off:off + n]
            off += n


class MicroBatchQueue:
    """FIFO micro-batcher over size buckets.

    Requests (arbitrary row counts) are enqueued in arrival order; `drain`
    greedily coalesces consecutive requests while their combined row count
    still fits the largest bucket, then pads the group to the smallest
    covering bucket. Oversize requests are split across batches. FIFO order
    is never reordered — a latency-fairness choice, not a throughput one.
    """

    def __init__(self, buckets: Sequence[int] = DEFAULT_BUCKETS):
        self.buckets = tuple(sorted(buckets))
        self._pending: collections.deque[_Pending] = collections.deque()
        self._next_id = 0

    def submit(self, x: np.ndarray) -> int:
        """Enqueue one request of x.shape[0] instances; returns request id."""
        assert x.ndim == 2, "a request is an (n_i, D) feature batch"
        if x.shape[0] == 0:
            # A zero-row request would never produce a micro-batch and its
            # id would silently vanish from the results.
            raise ValueError("empty request: need at least one instance")
        rid = self._next_id
        self._next_id += 1
        cap = self.buckets[-1]
        for start in range(0, x.shape[0], cap):      # split oversize
            self._pending.append(_Pending(rid, x[start:start + cap]))
        return rid

    def __len__(self) -> int:
        return len(self._pending)

    def drain(self) -> Iterator[MicroBatch]:
        """Yield padded micro-batches until the queue is empty."""
        cap = self.buckets[-1]
        while self._pending:
            group: list[_Pending] = [self._pending.popleft()]
            rows = group[0].x.shape[0]
            while self._pending and \
                    rows + self._pending[0].x.shape[0] <= cap:
                nxt = self._pending.popleft()
                group.append(nxt)
                rows += nxt.x.shape[0]
            bucket = pick_bucket(rows, self.buckets)
            x = pad_rows(np.concatenate([p.x for p in group], axis=0), bucket)
            yield MicroBatch(x=x, bucket=bucket,
                             request_ids=[p.request_id for p in group],
                             row_counts=[p.x.shape[0] for p in group])


class LatencyStats:
    """Wall-clock per-request latency accounting for the serving engines."""

    def __init__(self):
        self._ms: list[float] = []

    def record(self, seconds: float, n_requests: int = 1):
        self._ms.extend([seconds * 1e3] * n_requests)

    @property
    def count(self) -> int:
        return len(self._ms)

    def summary(self) -> dict[str, float]:
        if not self._ms:
            return {"count": 0}
        a = np.asarray(self._ms)
        return {"count": len(a),
                "mean_ms": float(a.mean()),
                "p50_ms": float(np.percentile(a, 50)),
                "p90_ms": float(np.percentile(a, 90)),
                "p99_ms": float(np.percentile(a, 99))}
