"""Request-side batching shared by LM and XMC serving.

Both serving paths face the same problem: a ragged request stream (variable
token counts for the LM, variable instance counts for XMC) must be packed
into a small set of fixed shapes, because every distinct shape costs one XLA
compile. This module owns that machinery:

  * `left_pad_tokens`   — ragged token lists -> one (B, T) batch (LM decode).
  * `pick_bucket`       — smallest power-of-two-ish bucket covering n rows.
  * `pad_rows`          — zero-pad a feature batch up to its bucket size.
  * `MicroBatchQueue`   — FIFO micro-batcher: coalesces queued requests into
                          bucket-sized batches, preserving request identity.
                          Arrival-timestamp aware: `next_batch` launches a
                          batch when the largest bucket FILLS or the oldest
                          queued request's DEADLINE expires — the policy the
                          continuous-batching server (`serve.server`) runs.
  * `LatencyStats`      — per-request latency percentiles (p50/p90/p99) over
                          enqueue -> completion spans.

The engines (`serve.engine` for LM decode, `serve.xmc.XMCEngine` for label
queries) are thin loops around these primitives; `serve.server.XMCServer`
adds the open-loop deadline/backpressure machinery on top of the same queue.
"""

from __future__ import annotations

import collections
import dataclasses
import time
from typing import Iterator, Optional, Sequence

import numpy as np

DEFAULT_BUCKETS = (1, 2, 4, 8, 16, 32, 64, 128, 256)


def left_pad_tokens(requests: Sequence[np.ndarray],
                    pad_id: int = 0) -> np.ndarray:
    """Ragged token id lists -> one left-padded (B, max_len) int32 batch."""
    B = len(requests)
    T0 = max(len(r) for r in requests)
    toks = np.full((B, T0), pad_id, np.int32)
    for i, r in enumerate(requests):
        toks[i, T0 - len(r):] = r
    return toks


def pick_bucket(n: int, buckets: Sequence[int] = DEFAULT_BUCKETS) -> int:
    """Smallest bucket >= n. n larger than every bucket is a caller bug
    (the queue splits oversize requests before picking)."""
    for b in buckets:
        if n <= b:
            return b
    raise ValueError(f"request of {n} rows exceeds largest bucket "
                     f"{buckets[-1]}")


def pad_rows(x: np.ndarray, bucket: int) -> np.ndarray:
    """Zero-pad instances (rows) up to `bucket`. Zero rows score 0 for every
    label and are sliced away before results leave the engine."""
    n = x.shape[0]
    if n == bucket:
        return x
    assert n < bucket, "pad_rows cannot shrink a batch"
    return np.concatenate(
        [x, np.zeros((bucket - n,) + x.shape[1:], x.dtype)], axis=0)


@dataclasses.dataclass
class _Pending:
    request_id: int
    x: np.ndarray                      # (n_i, D)
    arrival: float                     # monotonic enqueue timestamp


@dataclasses.dataclass
class MicroBatch:
    """One padded batch plus the bookkeeping to un-pad it."""
    x: np.ndarray                      # (bucket, D)
    bucket: int
    request_ids: list[int]
    row_counts: list[int]              # rows per request, in order
    arrivals: list[float] = dataclasses.field(default_factory=list)
                                       # enqueue timestamp per request piece

    def split(self, results: np.ndarray) -> Iterator[tuple[int, np.ndarray]]:
        """Slice per-request rows back out of a (bucket, ...) result."""
        off = 0
        for rid, n in zip(self.request_ids, self.row_counts):
            yield rid, results[off:off + n]
            off += n


class MicroBatchQueue:
    """FIFO micro-batcher over size buckets.

    Requests (arbitrary row counts) are enqueued in arrival order with a
    monotonic timestamp; batches are formed by greedily coalescing
    consecutive requests while their combined row count still fits the
    largest bucket, then padding the group to the smallest covering bucket.
    Oversize requests are split across batches (a request's pieces keep its
    one id — result assembly coalesces them back, see `pieces_of`). FIFO
    order is never reordered — a latency-fairness choice, not a throughput
    one.

    Two launch styles share the grouping code:

      * `drain()`      — synchronous: yield batches until empty (the
                         `XMCEngine.step()` path).
      * `next_batch()` — continuous batching: return ONE batch only when
                         the largest bucket is full, the oldest request's
                         deadline (`max_delay_s` past its arrival) has
                         expired, or `force=True`; otherwise None. The
                         server loop in `serve.server` drives this.
    """

    def __init__(self, buckets: Sequence[int] = DEFAULT_BUCKETS):
        self.buckets = tuple(sorted(buckets))
        self._pending: collections.deque[_Pending] = collections.deque()
        self._rows = 0
        self._request_pieces: dict[int, int] = {}   # rid -> pieces queued
        self._next_id = 0

    def reserve_id(self) -> int:
        """Allocate a request id without enqueuing anything — rejected
        requests (admission control) still get a real id so every response
        carries one identity namespace."""
        rid = self._next_id
        self._next_id += 1
        return rid

    def submit(self, x: np.ndarray, *,
               arrival: Optional[float] = None) -> int:
        """Enqueue one request of x.shape[0] instances; returns request id.

        `arrival` is the monotonic enqueue timestamp (defaults to now); it
        anchors both the launch deadline and the request's
        enqueue->completion latency span.
        """
        assert x.ndim == 2, "a request is an (n_i, D) feature batch"
        if x.shape[0] == 0:
            # A zero-row request would never produce a micro-batch and its
            # id would silently vanish from the results.
            raise ValueError("empty request: need at least one instance")
        if arrival is None:
            arrival = time.monotonic()
        rid = self.reserve_id()
        cap = self.buckets[-1]
        for start in range(0, x.shape[0], cap):      # split oversize
            self._pending.append(_Pending(rid, x[start:start + cap], arrival))
        self._rows += x.shape[0]
        self._request_pieces[rid] = self.pieces_of(x.shape[0])
        return rid

    def pieces_of(self, n_rows: int) -> int:
        """How many micro-batch pieces an n_rows request splits into (1 for
        anything that fits the largest bucket). Result assembly waits for
        exactly this many parts before a request's answer is complete."""
        cap = self.buckets[-1]
        return -(-n_rows // cap)

    def __len__(self) -> int:
        return len(self._pending)

    def pending_requests(self) -> int:
        """Distinct requests with at least one piece still queued — the
        quantity admission control (`max_queue`) bounds."""
        return len(self._request_pieces)

    def pending_rows(self) -> int:
        """Total queued instance rows (fill-launch trigger: >= largest
        bucket means a full batch can launch now)."""
        return self._rows

    def oldest_arrival(self) -> Optional[float]:
        """Arrival timestamp of the head-of-line request; None when empty.
        The launch deadline is `oldest_arrival() + max_delay_s`."""
        return self._pending[0].arrival if self._pending else None

    def next_batch(self, *, now: Optional[float] = None,
                   max_delay_s: Optional[float] = None,
                   force: bool = False) -> Optional[MicroBatch]:
        """One continuous-batching launch decision.

        Returns a padded micro-batch when (a) queued rows fill the largest
        bucket, (b) the oldest queued request has waited `max_delay_s` or
        longer, or (c) `force` (drain/shutdown). Otherwise None — the
        caller sleeps until the deadline and asks again.
        """
        if not self._pending:
            return None
        cap = self.buckets[-1]
        if not force and self._rows < cap:
            if max_delay_s is None:
                return None
            now = time.monotonic() if now is None else now
            if now - self._pending[0].arrival < max_delay_s:
                return None
        group: list[_Pending] = [self._pending.popleft()]
        rows = group[0].x.shape[0]
        while self._pending and \
                rows + self._pending[0].x.shape[0] <= cap:
            nxt = self._pending.popleft()
            group.append(nxt)
            rows += nxt.x.shape[0]
        for p in group:
            self._rows -= p.x.shape[0]
            left = self._request_pieces[p.request_id] - 1
            if left:
                self._request_pieces[p.request_id] = left
            else:
                del self._request_pieces[p.request_id]
        bucket = pick_bucket(rows, self.buckets)
        x = pad_rows(np.concatenate([p.x for p in group], axis=0), bucket)
        return MicroBatch(x=x, bucket=bucket,
                          request_ids=[p.request_id for p in group],
                          row_counts=[p.x.shape[0] for p in group],
                          arrivals=[p.arrival for p in group])

    def drain(self) -> Iterator[MicroBatch]:
        """Yield padded micro-batches until the queue is empty."""
        while True:
            mb = self.next_batch(force=True)
            if mb is None:
                return
            yield mb


class LatencyStats:
    """Wall-clock per-request latency accounting for the serving engines.

    The primitive is `record_span(enqueue_ts, done_ts)` — one sample per
    request, measured from its own enqueue to its own completion, so queue
    wait is part of the number and percentiles are real order statistics.
    `record(seconds, n_requests)` remains as the legacy aggregate API (one
    pre-measured duration stamped onto n requests) as a thin wrapper.
    """

    def __init__(self):
        self._ms: list[float] = []

    def record_span(self, start: float, end: float) -> None:
        """One request's latency as its (enqueue, completion) timestamps."""
        self._ms.append((end - start) * 1e3)

    def record(self, seconds: float, n_requests: int = 1):
        for _ in range(n_requests):
            self.record_span(0.0, seconds)

    @property
    def count(self) -> int:
        return len(self._ms)

    def summary(self) -> dict[str, float]:
        if not self._ms:
            return {"count": 0}
        a = np.asarray(self._ms)
        return {"count": len(a),
                "mean_ms": float(a.mean()),
                "p50_ms": float(np.percentile(a, 50)),
                "p90_ms": float(np.percentile(a, 90)),
                "p99_ms": float(np.percentile(a, 99))}
