"""Serving subsystem: two engines over one shared batching layer, plus the
async request-path server.

  engine    — LM decode serving (prefill + decode_step loops).
  xmc       — XMC top-k label serving over a registry of pluggable predict
              backends (dense / BSR-Pallas / mesh-sharded / shortlist built
              in; `register_backend` adds more). The spec-driven way to
              build an engine is `repro.xmc_api.CheckpointHandle.engine()`.
  server    — continuous-batching async loop over an engine: deadline-
              launched buckets, double-buffered dispatch, admission
              control (`Rejected`), future-style results, and multi-model
              routing (`ModelRouter`). Spec-driven entry:
              `CheckpointHandle.server()`.
  shortlist — the coarse candidate stage of two-stage scoring: row-block
              centroids, a learned one-vs-rest meta-classifier, or a
              fastxml-style routing tree built over the packed BSR
              checkpoint, persisted by checkpoint/io.py, consumed by the
              "shortlist" backend; also the pack-time co-occurrence label
              reordering (`cooccurrence_label_order`).
  batching  — request-side machinery everything above shares: ragged
              padding, size-bucketed micro-batch queue with arrival
              timestamps and deadline launch, latency accounting.
"""

from repro.serve.engine import generate, serve_batch
from repro.serve.server import ModelRouter, Rejected, XMCFuture, XMCServer
from repro.serve.shortlist import (ShortlistArtifact, build_learned_shortlist,
                                   build_shortlist, build_tree_shortlist,
                                   coarse_scores, cooccurrence_label_order)
from repro.serve.xmc import (BACKENDS, BsrBackend, DenseBackend,
                             Int8Backend, PredictBackend, RelabelBackend,
                             ShardedBackend, ShortlistBackend, XMCEngine,
                             XMCResult, available_backends, make_backend,
                             register_backend, reset_warmup_cache,
                             unregister_backend, warmup_cache_stats)

__all__ = ["generate", "serve_batch", "XMCEngine", "XMCResult",
           "XMCServer", "XMCFuture", "ModelRouter", "Rejected",
           "PredictBackend", "DenseBackend", "BsrBackend", "Int8Backend",
           "ShardedBackend", "ShortlistBackend", "RelabelBackend",
           "ShortlistArtifact", "build_shortlist",
           "build_learned_shortlist", "build_tree_shortlist",
           "coarse_scores", "cooccurrence_label_order",
           "make_backend", "BACKENDS", "register_backend",
           "unregister_backend", "available_backends",
           "reset_warmup_cache", "warmup_cache_stats"]
