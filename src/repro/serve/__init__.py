from repro.serve.engine import generate, serve_batch

__all__ = ["generate", "serve_batch"]
