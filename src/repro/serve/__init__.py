"""Serving subsystem: two engines over one shared batching layer.

  engine   — LM decode serving (prefill + decode_step loops).
  xmc      — XMC top-k label serving over a registry of pluggable predict
             backends (dense / BSR-Pallas / mesh-sharded built in;
             `register_backend` adds more). The spec-driven way to build
             an engine is `repro.xmc_api.CheckpointHandle.engine()`.
  batching — request-side machinery both engines share: ragged padding,
             size-bucketed micro-batch queue, latency accounting.
"""

from repro.serve.engine import generate, serve_batch
from repro.serve.xmc import (BACKENDS, BsrBackend, DenseBackend,
                             PredictBackend, ShardedBackend, XMCEngine,
                             XMCResult, available_backends, make_backend,
                             register_backend, unregister_backend)

__all__ = ["generate", "serve_batch", "XMCEngine", "XMCResult",
           "PredictBackend", "DenseBackend", "BsrBackend", "ShardedBackend",
           "make_backend", "BACKENDS", "register_backend",
           "unregister_backend", "available_backends"]
