"""Serving subsystem: two engines over one shared batching layer.

  engine   — LM decode serving (prefill + decode_step loops).
  xmc      — XMC top-k label serving over pluggable predict backends
             (dense / BSR-Pallas / mesh-sharded).
  batching — request-side machinery both engines share: ragged padding,
             size-bucketed micro-batch queue, latency accounting.
"""

from repro.serve.engine import generate, serve_batch
from repro.serve.xmc import (BACKENDS, BsrBackend, DenseBackend,
                             PredictBackend, ShardedBackend, XMCEngine,
                             XMCResult, make_backend)

__all__ = ["generate", "serve_batch", "XMCEngine", "XMCResult",
           "PredictBackend", "DenseBackend", "BsrBackend", "ShardedBackend",
           "make_backend", "BACKENDS"]
