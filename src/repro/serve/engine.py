"""LM serving engine: batched prefill + greedy/top-k decode against the cache.

One of the two engines in the serving subsystem (the other is
`serve.xmc.XMCEngine` for top-k label queries); both sit on the shared
request-side layer in `serve.batching` — this engine uses its ragged token
padding, the XMC engine its size-bucketed micro-batch queue. The per-step
top-k here IS the paper's distributed prediction (§2.2.1): the head is
label-sharded, each shard reduces locally, candidates merge globally.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve.batching import left_pad_tokens

Array = jax.Array


def generate(model, params, prompt_tokens: Array, *, steps: int,
             prefix: Optional[Array] = None, use_swa: bool = False,
             mesh=None, batch_axes=()) -> np.ndarray:
    """Greedy continuation of `prompt_tokens` (B, T0) for `steps` tokens."""
    B, T0 = prompt_tokens.shape
    total = T0 + steps + (prefix.shape[1] if prefix is not None else 0)
    cache = model.init_cache(B, total, use_swa=use_swa)

    decode = jax.jit(
        lambda p, c, t, pos: model.decode_step(
            p, c, t, pos, mesh=mesh, batch_axes=batch_axes, use_swa=use_swa))

    # Teacher-forced prefill via decode steps (correct for every cache kind;
    # the bulk prefill path is model.prefill, exercised by the dry-run).
    pos = 0
    tok = None
    if prefix is not None:
        raise NotImplementedError("generate() with prefix: use model.prefill")
    for t in range(T0):
        vals, idx, cache = decode(params, cache,
                                  prompt_tokens[:, t:t + 1], jnp.int32(pos))
        pos += 1
    out = []
    tok = idx[:, :1]
    out.append(np.asarray(tok))
    for _ in range(steps - 1):
        vals, idx, cache = decode(params, cache, tok, jnp.int32(pos))
        pos += 1
        tok = idx[:, :1]
        out.append(np.asarray(tok))
    return np.concatenate(out, axis=1)


def serve_batch(model, params, requests: list[np.ndarray], *, steps: int,
                use_swa: bool = False) -> list[np.ndarray]:
    """Pad a ragged request list into one batch and decode `steps` tokens."""
    toks = left_pad_tokens(requests)
    outs = generate(model, params, jnp.asarray(toks), steps=steps,
                    use_swa=use_swa)
    return [outs[i] for i in range(len(requests))]
