"""Shortlist layer: pluggable coarse-stage scoring for sub-linear serving.

Every exhaustive `PredictBackend` scores all L labels per query — the wall
between this reproduction and the paper's 670k-label regime at production
traffic. Both XMC surveys in PAPERS.md document a candidate-selection stage
as the standard path to sub-linear inference; this module is that stage,
shaped for the packed BSR artifact the rest of the repo already serves:

  * The *unit of shortlisting is the BSR row block* (bl consecutive
    labels), because that is the granularity at which the fine stage —
    `kernels/bsr_predict.ops.bsr_predict_gather_topk` — can skip work
    without breaking the MXU-tiled matmul structure.
  * The coarse model is pluggable (`ShortlistArtifact.kind`):

      "centroid"  one (R, Dp) matrix of row-block centroids (R = Lp / bl):
                  row r is the mean of the bl label weight rows of block r,
                  computed directly from the packed blocks (never
                  densifying W). Unlearned, free to build, the v1 format.
      "learned"   a trained one-vs-rest linear meta-classifier over row
                  blocks: row r of the (R, Dp) matrix is the TRON-solved
                  weight vector of the binary problem "does this document
                  have a positive label inside block r?" — the same
                  `make_batch_solver` that trains the fine model, run once
                  over R block-membership problems at finalize time. Both
                  surveys report learned coarse stages dominating centroid
                  heuristics at equal recall; the serving benchmark gates
                  that here (strictly lower candidate fraction at
                  recall@5 >= 0.95).
      "tree"      a fixed-depth routing tree adapted from
                  `baselines/fastxml.py`'s node splitting: internal nodes
                  are mean-difference hyperplanes over the training
                  documents, leaves score row blocks by positive-block
                  frequency among the documents routed there. Routing a
                  query is `depth` dot products + one (R,) lookup —
                  O(depth * D + R) instead of O(R * D) coarse work.

    Either way coarse scoring stays one small dense op per query and the
    fine stage is unchanged.
  * Selection takes the top-B row blocks — shared across the micro-batch
    (max over per-query coarse scores: one selection, shapes static) or
    *per query* (`per_query=True` on the backend: each query gets its own
    top-B list, served by the ragged-gather kernel, so easy queries stop
    paying for the union's width). Compute scales with B * bl * D + R * D,
    not L * D.

The artifact is built at checkpoint-save/finalize time (`build_shortlist`
for centroids — free, always written) and optionally *upgraded* to a
learned/tree coarse stage by `fit()` once training data is still in hand
(`checkpoint.io.upgrade_shortlist`). It is persisted next to the BSR
arrays as `shortlist.npz` (v2 format: explicit `version`/`kind` keys;
v1 files — no version key — load as kind="centroid"). Checkpoints without
any artifact (written before PR 6) keep serving: the "shortlist" backend
falls back to exhaustive BSR scoring when `load_shortlist` finds nothing.

This module also owns the pack-time label-reorder policy
(`cooccurrence_label_order`): a deterministic co-occurrence clustering
permutation that makes real label spaces block-local the way the clustered
demo data already is — trained under `Y[:, order]`, recorded in the
manifest as `label_order`, unmapped exactly at serve time by `XMCEngine`.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

#: On-disk format version written by checkpoint/io.py::save_shortlist.
#: v1 (PR 6) had no version/kind keys and is always a centroid artifact.
SHORTLIST_VERSION = 2

SHORTLIST_KINDS = ("centroid", "learned", "tree")


@dataclasses.dataclass
class ShortlistArtifact:
    """The coarse stage of two-stage scoring, built from a packed BSR model.

    centroids   : (R, Dp) float32 coarse scoring matrix (block-padded
                  feature width). For kind="centroid" row r is the mean
                  weight vector of the bl labels in BSR row block r; for
                  kind="learned" it is the trained one-vs-rest weight
                  vector of block r's membership problem. For kind="tree"
                  it is the centroid fallback (kept so validation and
                  downgrades always work); routing uses the tree arrays.
    block_rows  : bl, the row-block height the coarse stage summarizes.
                  Must match the served model's block height.
    n_labels    : true (pre-padding) label count of the source model.
    stat        : reducer/trainer tag ("mean" for centroids, "ovr" for the
                  learned meta-classifier, "fastxml" for the tree).
    kind        : which coarse scorer this is ("centroid" | "learned" |
                  "tree"). v1 artifacts load as "centroid".
    tree_nodes  : (2^depth - 1, Dp) float32 — level-order internal-node
                  hyperplanes (kind="tree" only; node i's children are
                  2i+1 / 2i+2; x routes right iff x @ w >= 0).
    tree_leaf_scores : (2^depth, R) float32 — per-leaf row-block scores.
    tree_depth  : routing depth (0 when kind != "tree").
    """
    centroids: np.ndarray
    block_rows: int
    n_labels: int
    stat: str = "mean"
    kind: str = "centroid"
    tree_nodes: Optional[np.ndarray] = None
    tree_leaf_scores: Optional[np.ndarray] = None
    tree_depth: int = 0

    @property
    def n_row_blocks(self) -> int:
        return int(self.centroids.shape[0])

    def default_blocks(self) -> int:
        """Default shortlist width B when `ServeSpec.shortlist_blocks` is
        unset: 1/8 of the row blocks (12.5% candidate fraction), floored
        at 1 — comfortably inside the <25% regime the serving benchmark
        gates on while leaving recall headroom."""
        return max(1, -(-self.n_row_blocks // 8))

    def validate_against(self, model) -> "ShortlistArtifact":
        """Shape-check against the `BlockSparseModel` it will gate."""
        bl = model.block_shape[0]
        R = model.shape[0] // bl
        if self.block_rows != bl or self.centroids.shape != (R, model.shape[1]):
            raise ValueError(
                f"shortlist artifact ({self.centroids.shape} centroids, "
                f"block_rows={self.block_rows}) does not match model "
                f"(shape {model.shape}, block height {bl}); rebuild it with "
                "build_shortlist(model)")
        if self.kind not in SHORTLIST_KINDS:
            raise ValueError(f"unknown shortlist kind {self.kind!r}; "
                             f"expected one of {SHORTLIST_KINDS}")
        if self.kind == "tree":
            d = int(self.tree_depth)
            if (self.tree_nodes is None or self.tree_leaf_scores is None
                    or d < 1
                    or self.tree_nodes.shape != (2 ** d - 1,
                                                 model.shape[1])
                    or self.tree_leaf_scores.shape != (2 ** d, R)):
                raise ValueError(
                    "tree shortlist artifact is inconsistent: depth "
                    f"{self.tree_depth}, nodes "
                    f"{None if self.tree_nodes is None else self.tree_nodes.shape}, "
                    f"leaf_scores "
                    f"{None if self.tree_leaf_scores is None else self.tree_leaf_scores.shape}"
                    f" for model shape {model.shape}")
        return self


def build_shortlist(model) -> ShortlistArtifact:
    """Build the coarse centroid matrix from a packed `BlockSparseModel`.

    Works entirely on the packed arrays: each surviving (bl, bd) block
    contributes its column sums to its row block's centroid slice, then
    every centroid is divided by bl. Deterministic (packed blocks are
    row-major sorted), so cooperative multi-worker finalizes write
    byte-identical artifacts.
    """
    bl, bd = model.block_shape
    Lp, Dp = model.shape
    R = Lp // bl
    row_ptr = np.asarray(model.row_ptr)
    rows = np.asarray(model.block_rows)
    cols = np.asarray(model.block_cols)
    blocks = np.asarray(model.blocks, dtype=np.float32)
    C = np.zeros((R, Dp), np.float32)
    # row_ptr[-1] is the packed-block count; the all-pruned sentinel model
    # carries one zero block with row_ptr all zeros, which this skips.
    for k in range(int(row_ptr[-1])):
        r, c = int(rows[k]), int(cols[k])
        C[r, c * bd:(c + 1) * bd] += blocks[k].sum(axis=0)
    C /= float(bl)
    return ShortlistArtifact(centroids=C, block_rows=bl,
                             n_labels=model.n_labels, stat="mean")


def block_membership(Y, *, block_rows: int, n_row_blocks: int) -> np.ndarray:
    """(N, L) label matrix -> (N, R) 0/1 block-membership targets: document
    i is positive for row block r iff any of its positive labels lands in
    packed rows [r*bl, (r+1)*bl). Y must already be in *packed* label order
    (apply `label_order` first when the checkpoint was reordered)."""
    Yn = np.asarray(Y)
    N, L = Yn.shape
    Yb = np.zeros((N, n_row_blocks), np.float32)
    for r in range(n_row_blocks):
        lo, hi = r * block_rows, min((r + 1) * block_rows, L)
        if lo < L:
            Yb[:, r] = (Yn[:, lo:hi] > 0).any(axis=1)
    return Yb


def build_learned_shortlist(model, X, Y, *, C: float = 1.0,
                            max_newton: int = 20,
                            eps: float = 0.01) -> ShortlistArtifact:
    """Train the one-vs-rest coarse meta-classifier over row blocks.

    Reuses the fine model's TRON batch solver: R binary problems ("does
    this document hit block r?") solved as one batch, unpruned (delta=0 —
    the coarse matrix is (R, Dp) dense and tiny next to the fine model),
    then padded to the model's block-padded feature width. Deterministic
    for fixed (X, Y, model), so cooperative finalizers that race the
    upgrade write byte-identical artifacts.

    Y must be in *packed* label order (same convention as
    `block_membership`).
    """
    import jax.numpy as jnp
    from repro.core.dismec import DiSMECConfig, make_batch_solver

    bl = model.block_shape[0]
    Lp, Dp = model.shape
    R = Lp // bl
    Xn = np.asarray(X, np.float32)
    Yb = block_membership(Y, block_rows=bl, n_row_blocks=R)
    signs = (2.0 * Yb.T - 1.0).astype(np.float32)          # (R, N)
    cfg = DiSMECConfig(C=C, delta=0.0, eps=eps, max_newton=max_newton)
    solver = make_batch_solver(jnp.asarray(Xn), cfg)
    W = np.asarray(solver(jnp.asarray(signs), None))       # (R, D)
    Wp = np.zeros((R, Dp), np.float32)
    Wp[:, :W.shape[1]] = W
    return ShortlistArtifact(centroids=Wp, block_rows=bl,
                             n_labels=model.n_labels, stat="ovr",
                             kind="learned")


def build_tree_shortlist(model, X, Y, *, depth: int = 3,
                         seed: int = 0) -> ShortlistArtifact:
    """Build the fixed-depth routing tree coarse stage (fastxml-style).

    Adapts `baselines/fastxml.py`'s node splitting to the row-block
    targets: each internal node starts from a seeded random hyperplane and
    is refined by three mean-difference iterations (w = mu_right -
    mu_left over the node's documents); leaves score row blocks by the
    positive-block frequency of the documents routed there. The tree is
    complete (every query routes `depth` steps — jittable with static
    shapes); a leaf that receives no training documents inherits the
    nearest ancestor's scores so routing never hits an all-zero coarse
    row. Deterministic for fixed (X, Y, depth, seed).

    The returned artifact keeps the centroid matrix as `centroids` (the
    validation anchor and downgrade path); routing uses
    tree_nodes/tree_leaf_scores.
    """
    bl = model.block_shape[0]
    Lp, Dp = model.shape
    R = Lp // bl
    Xn = np.asarray(X, np.float32)
    N, D = Xn.shape
    Yb = block_membership(Y, block_rows=bl, n_row_blocks=R)
    rng = np.random.default_rng(seed)

    n_nodes = 2 ** depth - 1
    n_leaves = 2 ** depth
    nodes = np.zeros((n_nodes, Dp), np.float32)
    # node_scores[i] = block frequency over docs at node i (internal and
    # leaf level); leaves inherit from ancestors when empty.
    members: dict[int, np.ndarray] = {0: np.arange(N)}
    scores: dict[int, np.ndarray] = {}
    for i in range(n_nodes + n_leaves):
        idx = members.get(i, np.arange(0))
        if idx.size:
            freq = Yb[idx].sum(axis=0)
            scores[i] = (freq / max(float(freq.max()), 1.0)).astype(
                np.float32)
        else:
            # Inherit: parent of node i is (i - 1) // 2; node 0 always has
            # members, so the walk terminates.
            scores[i] = scores[(i - 1) // 2]
        if i >= n_nodes:
            continue                                   # leaf: no split
        w = rng.standard_normal(D).astype(np.float32)  # drawn per node, in
        if idx.size >= 2:                              # level order: stable
            for _ in range(3):                         # mean-difference
                side = Xn[idx] @ w >= 0.0              # refinement à la
                if side.all() or not side.any():       # fastxml
                    break
                w = (Xn[idx[side]].mean(axis=0)
                     - Xn[idx[~side]].mean(axis=0)).astype(np.float32)
            side = Xn[idx] @ w >= 0.0
            if side.all() or not side.any():
                w = np.zeros(D, np.float32)            # degenerate: all right
                side = np.ones(idx.size, bool)
            nodes[i, :D] = w
            members[2 * i + 1] = idx[~side]
            members[2 * i + 2] = idx[side]
        else:
            members[2 * i + 1] = np.arange(0)
            members[2 * i + 2] = idx                   # w = 0 routes right
    leaf_scores = np.stack([scores[n_nodes + j] for j in range(n_leaves)])
    base = build_shortlist(model)
    return ShortlistArtifact(centroids=base.centroids, block_rows=bl,
                             n_labels=model.n_labels, stat="fastxml",
                             kind="tree", tree_nodes=nodes,
                             tree_leaf_scores=leaf_scores.astype(np.float32),
                             tree_depth=int(depth))


def coarse_scores(artifact: ShortlistArtifact, x) -> np.ndarray:
    """(n, D*) queries -> (n, R) coarse row-block scores, host-side (the
    reference implementation the jitted serving paths mirror; used by
    tests and introspection). Pads/truncates x to the artifact's feature
    width."""
    xn = np.asarray(x, np.float32)
    Dp = artifact.centroids.shape[1]
    if xn.shape[1] < Dp:
        xn = np.concatenate(
            [xn, np.zeros((xn.shape[0], Dp - xn.shape[1]), np.float32)],
            axis=1)
    xn = xn[:, :Dp]
    if artifact.kind == "tree":
        idx = np.zeros(xn.shape[0], np.int64)
        for _ in range(int(artifact.tree_depth)):
            go_right = (xn * artifact.tree_nodes[idx]).sum(axis=1) >= 0.0
            idx = 2 * idx + 1 + go_right
        leaf = idx - (2 ** int(artifact.tree_depth) - 1)
        return artifact.tree_leaf_scores[leaf]
    return xn @ artifact.centroids.T


def cooccurrence_label_order(Y, *, block_rows: int) -> np.ndarray:
    """Deterministic co-occurrence clustering permutation over labels.

    Greedy block seriation: seed each row block with the most frequent
    unplaced label, then repeatedly append the unplaced label with the
    highest co-occurrence count against the block's current members
    (frequency, then smallest id, break ties) until the block holds
    `block_rows` labels. Co-occurring labels land in the same BSR row
    block, so a B-block shortlist covers correlated top-k sets — the
    locality the clustered demo data has by construction, manufactured
    for real label spaces at pack time.

    Returns `order` (L,) int64 with `order[packed_pos] = original_label`:
    train under `Y[:, order]`, serve packed top-k ids through
    `order[idx]`. O(L^2) memory/time — fine at the scales this repo
    trains; the docstring is the contract, the policy is replaceable.
    """
    Yn = (np.asarray(Y) > 0).astype(np.float32)
    L = Yn.shape[1]
    co = Yn.T @ Yn                                    # (L, L) co-occurrence
    freq = np.diag(co).copy()
    np.fill_diagonal(co, 0.0)
    placed = np.zeros(L, bool)
    order = np.empty(L, np.int64)
    pos = 0
    while pos < L:
        # Seed: most frequent unplaced label (smallest id on ties).
        seed_scores = np.where(placed, -1.0, freq)
        seed = int(np.argmax(seed_scores))
        order[pos] = seed
        placed[seed] = True
        pos += 1
        affinity = co[seed].copy()
        for _ in range(min(block_rows - 1, L - pos)):
            cand = np.where(placed, -1.0, affinity)
            if cand.max() <= 0.0:          # nothing co-occurs: next seed
                break
            nxt = int(np.argmax(cand))
            order[pos] = nxt
            placed[nxt] = True
            pos += 1
            affinity += co[nxt]
    return order
