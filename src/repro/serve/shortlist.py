"""Shortlist layer: per-row-block coarse scoring for sub-linear serving.

Every exhaustive `PredictBackend` scores all L labels per query — the wall
between this reproduction and the paper's 670k-label regime at production
traffic. Both XMC surveys in PAPERS.md document a candidate-selection stage
as the standard path to sub-linear inference; this module is that stage,
shaped for the packed BSR artifact the rest of the repo already serves:

  * The *unit of shortlisting is the BSR row block* (bl consecutive
    labels), because that is the granularity at which the fine stage —
    `kernels/bsr_predict.ops.bsr_predict_gather_topk` — can skip work
    without breaking the MXU-tiled matmul structure.
  * The coarse model is one (R, Dp) matrix of row-block centroids
    (R = Lp / bl): row r is the mean of the bl label weight rows of block
    r, computed directly from the packed blocks (never densifying W).
    Coarse scoring a query is one (n, Dp) x (Dp, R) matmul — O(R * D)
    instead of O(L * D), an L/R = bl-fold cheaper first pass.
  * Selection takes the top-B row blocks per micro-batch (max over the
    batch's per-query coarse scores, so shapes stay static and one XLA
    compile serves every bucket); the fine stage then scores only those
    B blocks' packed BSR tiles. Compute scales with B * bl * D + R * D,
    not L * D.

The artifact is built once at checkpoint-save/finalize time from the packed
model (`build_shortlist`) and persisted next to the BSR arrays by
`checkpoint/io.py::save_shortlist` — the serving-side analogue of the
paper's offline per-batch model files. Checkpoints without it (written
before this PR) keep serving: the "shortlist" backend falls back to
exhaustive BSR scoring when `load_shortlist` finds nothing.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class ShortlistArtifact:
    """The coarse stage of two-stage scoring, built from a packed BSR model.

    centroids  : (R, Dp) float32 — row r is the mean weight vector of the
                 bl labels in BSR row block r (block-padded feature width).
    block_rows : bl, the row-block height the centroids summarize. Must
                 match the served model's block height.
    n_labels   : true (pre-padding) label count of the source model.
    stat       : reducer used over each block's rows ("mean" today; the
                 field exists so a future artifact can declare a different
                 meta-classifier without a format break).
    """
    centroids: np.ndarray
    block_rows: int
    n_labels: int
    stat: str = "mean"

    @property
    def n_row_blocks(self) -> int:
        return int(self.centroids.shape[0])

    def default_blocks(self) -> int:
        """Default shortlist width B when `ServeSpec.shortlist_blocks` is
        unset: 1/8 of the row blocks (12.5% candidate fraction), floored
        at 1 — comfortably inside the <25% regime the serving benchmark
        gates on while leaving recall headroom."""
        return max(1, -(-self.n_row_blocks // 8))

    def validate_against(self, model) -> "ShortlistArtifact":
        """Shape-check against the `BlockSparseModel` it will gate."""
        bl = model.block_shape[0]
        R = model.shape[0] // bl
        if self.block_rows != bl or self.centroids.shape != (R, model.shape[1]):
            raise ValueError(
                f"shortlist artifact ({self.centroids.shape} centroids, "
                f"block_rows={self.block_rows}) does not match model "
                f"(shape {model.shape}, block height {bl}); rebuild it with "
                "build_shortlist(model)")
        return self


def build_shortlist(model) -> ShortlistArtifact:
    """Build the coarse centroid matrix from a packed `BlockSparseModel`.

    Works entirely on the packed arrays: each surviving (bl, bd) block
    contributes its column sums to its row block's centroid slice, then
    every centroid is divided by bl. Deterministic (packed blocks are
    row-major sorted), so cooperative multi-worker finalizes write
    byte-identical artifacts.
    """
    bl, bd = model.block_shape
    Lp, Dp = model.shape
    R = Lp // bl
    row_ptr = np.asarray(model.row_ptr)
    rows = np.asarray(model.block_rows)
    cols = np.asarray(model.block_cols)
    blocks = np.asarray(model.blocks, dtype=np.float32)
    C = np.zeros((R, Dp), np.float32)
    # row_ptr[-1] is the packed-block count; the all-pruned sentinel model
    # carries one zero block with row_ptr all zeros, which this skips.
    for k in range(int(row_ptr[-1])):
        r, c = int(rows[k]), int(cols[k])
        C[r, c * bd:(c + 1) * bd] += blocks[k].sum(axis=0)
    C /= float(bl)
    return ShortlistArtifact(centroids=C, block_rows=bl,
                             n_labels=model.n_labels, stat="mean")
