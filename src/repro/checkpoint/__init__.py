from repro.checkpoint.io import (BlockSparseWriter, has_block_sparse_checkpoint,
                                 load_block_sparse, load_block_sparse_meta,
                                 restore_pytree, save_block_sparse,
                                 save_pytree)

__all__ = ["save_pytree", "restore_pytree", "save_block_sparse",
           "load_block_sparse", "load_block_sparse_meta",
           "BlockSparseWriter", "has_block_sparse_checkpoint"]
