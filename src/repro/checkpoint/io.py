"""Checkpointing: flattened-path .npz per host + JSON index.

Mirrors DiSMEC's per-batch block model files (§2.1): the pruned head /
XMC weight blocks are stored sparse (values + indices) when density < 50%,
dense otherwise. Works for any pytree (params, optimizer state, caches).
"""

from __future__ import annotations

import json
import os
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        flat[key] = np.asarray(leaf)
    return flat


def save_pytree(tree, directory: str, *, sparse_threshold: float = 0.5):
    os.makedirs(directory, exist_ok=True)
    flat = _flatten(tree)
    index: dict[str, Any] = {"entries": {}}
    arrays = {}
    for key, arr in flat.items():
        if arr.ndim == 2 and arr.size > 4096:
            density = float((arr != 0).mean())
            if density < sparse_threshold:
                nz = np.nonzero(arr)
                arrays[f"{key}::values"] = arr[nz]
                arrays[f"{key}::rows"] = nz[0].astype(np.int32)
                arrays[f"{key}::cols"] = nz[1].astype(np.int32)
                index["entries"][key] = {"format": "coo", "shape": arr.shape,
                                         "dtype": str(arr.dtype),
                                         "density": density}
                continue
        arrays[key] = arr
        index["entries"][key] = {"format": "dense", "shape": list(arr.shape),
                                 "dtype": str(arr.dtype)}
    np.savez_compressed(os.path.join(directory, "arrays.npz"), **arrays)
    with open(os.path.join(directory, "index.json"), "w") as f:
        json.dump(index, f, indent=1)


def restore_pytree(template, directory: str):
    """Restores into the structure of `template` (shapes must match)."""
    with open(os.path.join(directory, "index.json")) as f:
        index = json.load(f)
    data = np.load(os.path.join(directory, "arrays.npz"))

    flat_template, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in flat_template:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        meta = index["entries"][key]
        if meta["format"] == "coo":
            arr = np.zeros(meta["shape"], dtype=meta["dtype"])
            arr[data[f"{key}::rows"], data[f"{key}::cols"]] = \
                data[f"{key}::values"]
        else:
            arr = data[key]
        leaves.append(jnp.asarray(arr).astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)
