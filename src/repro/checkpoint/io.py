"""Checkpointing: flattened-path .npz per host + JSON index.

Mirrors DiSMEC's per-batch block model files (§2.1): the pruned head /
XMC weight blocks are stored sparse (values + indices) when density < 50%,
dense otherwise. Works for any pytree (params, optimizer state, caches).

Beyond pytrees, `save_block_sparse` / `load_block_sparse` round-trip the
packed BSR artifact (`core.pruning.BlockSparseModel`) that the XMC serving
subsystem loads: a pruned model is converted once offline — like the paper's
per-batch model files — and served by any backend without re-densifying.

Two on-disk layouts share one loader:

  single-shard — `bsr_arrays.npz` + `bsr_index.json`, written in one shot by
                 `save_block_sparse` after an in-memory conversion;
  multi-shard  — `shard-<batch>.npz` per label batch + `bsr_manifest.json`,
                 appended incrementally by `BlockSparseWriter` as the
                 streaming trainer (train/xmc.py) finishes each batch. The
                 manifest is rewritten atomically after every shard, so a
                 killed job resumes by skipping the batches already listed;
                 `load_block_sparse` stitches the shards back into one
                 `BlockSparseModel` (pure row_ptr bookkeeping, no re-tiling)
                 so the serving engine never sees the difference.
"""

from __future__ import annotations

import json
import os
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        flat[key] = np.asarray(leaf)
    return flat


def save_pytree(tree, directory: str, *, sparse_threshold: float = 0.5):
    os.makedirs(directory, exist_ok=True)
    flat = _flatten(tree)
    index: dict[str, Any] = {"entries": {}}
    arrays = {}
    for key, arr in flat.items():
        if arr.ndim == 2 and arr.size > 4096:
            density = float((arr != 0).mean())
            if density < sparse_threshold:
                nz = np.nonzero(arr)
                arrays[f"{key}::values"] = arr[nz]
                arrays[f"{key}::rows"] = nz[0].astype(np.int32)
                arrays[f"{key}::cols"] = nz[1].astype(np.int32)
                index["entries"][key] = {"format": "coo", "shape": arr.shape,
                                         "dtype": str(arr.dtype),
                                         "density": density}
                continue
        arrays[key] = arr
        index["entries"][key] = {"format": "dense", "shape": list(arr.shape),
                                 "dtype": str(arr.dtype)}
    np.savez_compressed(os.path.join(directory, "arrays.npz"), **arrays)
    with open(os.path.join(directory, "index.json"), "w") as f:
        json.dump(index, f, indent=1)


BSR_ARRAYS = "bsr_arrays.npz"
BSR_INDEX = "bsr_index.json"


def save_block_sparse(model, directory: str, *, meta: dict | None = None):
    """Write a `BlockSparseModel` (+ optional serving metadata such as
    n_labels / delta) as one .npz + JSON index under `directory`."""
    os.makedirs(directory, exist_ok=True)
    np.savez_compressed(
        os.path.join(directory, BSR_ARRAYS),
        blocks=np.asarray(model.blocks),
        block_rows=np.asarray(model.block_rows),
        block_cols=np.asarray(model.block_cols),
        row_ptr=np.asarray(model.row_ptr))
    index = {
        "format": "bsr",
        "shape": list(model.shape),
        "orig_shape": list(model.orig_shape or model.shape),
        "block_shape": list(model.block_shape),
        "n_blocks": model.n_blocks,
        "dtype": str(np.asarray(model.blocks).dtype),
        "meta": dict(meta or {}),
    }
    with open(os.path.join(directory, BSR_INDEX), "w") as f:
        json.dump(index, f, indent=1)


BSR_MANIFEST = "bsr_manifest.json"


class BlockSparseWriter:
    """Incremental multi-shard BSR checkpoint (the paper's per-batch model
    files, written as training goes rather than after it).

    One `shard-<batch>.npz` per label batch plus a JSON manifest. Each
    `write_batch` first writes the shard file, then atomically rewrites the
    manifest (tmp + rename) — a crash between the two leaves an orphan shard
    that the next run simply re-solves and overwrites, so the manifest is
    always the ground truth for what is done. `done_batches` is what a
    resumed `XMCTrainJob` skips.
    """

    def __init__(self, directory: str, *, n_labels: int, n_features: int,
                 block_shape: tuple[int, int], label_batch: int,
                 n_batches: int, solver: dict | None = None,
                 meta: dict | None = None, resume: bool = True):
        """`solver` is an opaque dict of whatever determined the solution
        (hyperparameters, dataset fingerprint): it is stored in the manifest
        and must match exactly on resume — shards solved under different
        settings must never be stitched into one 'complete' checkpoint."""
        self.directory = directory
        os.makedirs(directory, exist_ok=True)
        self._path = os.path.join(directory, BSR_MANIFEST)
        # A single-shard artifact in the same directory would shadow the
        # stream on load (load_block_sparse prefers BSR_INDEX): refuse to
        # write behind it unless the caller explicitly starts fresh.
        index_path = os.path.join(directory, BSR_INDEX)
        if os.path.exists(index_path):
            if resume:
                raise ValueError(
                    f"{directory} already holds a single-shard checkpoint "
                    f"({BSR_INDEX}), which would shadow the streamed one on "
                    "load; pass resume=False to replace it, or stream into "
                    "a different directory")
            os.remove(index_path)
            try:
                os.remove(os.path.join(directory, BSR_ARRAYS))
            except OSError:
                pass
        header = {
            "format": "bsr-stream",
            "n_labels": int(n_labels), "n_features": int(n_features),
            "block_shape": [int(b) for b in block_shape],
            "label_batch": int(label_batch), "n_batches": int(n_batches),
            "solver": dict(solver or {}),
        }
        existing = None
        if os.path.exists(self._path):
            with open(self._path) as f:
                existing = json.load(f)
        if existing is not None and resume:
            mismatch = {k: (existing.get(k), v) for k, v in header.items()
                        if existing.get(k) != v}
            if mismatch:
                raise ValueError(
                    f"cannot resume into {directory}: manifest disagrees on "
                    f"{mismatch}; pass resume=False to start fresh")
            self.manifest = existing
        else:
            if existing is not None:                 # fresh start: drop shards
                for s in existing.get("shards", {}).values():
                    try:
                        os.remove(os.path.join(directory, s["file"]))
                    except OSError:
                        pass
            self.manifest = {**header, "complete": False, "shards": {},
                             "meta": dict(meta or {})}
            self._flush()
        if meta:
            self.manifest["meta"].update(meta)

    @property
    def complete(self) -> bool:
        return bool(self.manifest.get("complete"))

    @property
    def done_batches(self) -> set[int]:
        return {int(b) for b in self.manifest["shards"]}

    def _flush(self) -> None:
        tmp = self._path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(self.manifest, f, indent=1, sort_keys=True)
        os.replace(tmp, self._path)

    def write_batch(self, batch: int, part, *, row_start: int,
                    n_rows: int) -> None:
        """Append one solved label batch (append-form `BlockSparseModel`,
        see `core.pruning.to_block_sparse(row_block_offset=...)`)."""
        blocks = np.asarray(part.blocks)
        fname = f"shard-{batch:05d}.npz"
        np.savez_compressed(
            os.path.join(self.directory, fname),
            blocks=blocks,
            block_rows=np.asarray(part.block_rows),
            block_cols=np.asarray(part.block_cols),
            row_ptr=np.asarray(part.row_ptr))
        self.manifest["shards"][str(int(batch))] = {
            "file": fname, "row_start": int(row_start),
            "n_rows": int(n_rows), "padded_rows": int(part.shape[0]),
            "n_blocks": int(blocks.shape[0]),
            "nnz": int(np.count_nonzero(blocks)),
        }
        self._flush()

    def read_batch_dense(self, batch: int) -> np.ndarray:
        """Densify one already-written shard back to its (n_rows, D) weight
        rows — the resume path of a materializing caller."""
        entry = self.manifest["shards"][str(int(batch))]
        return _densify_shard(self.directory, entry,
                              self.manifest["block_shape"],
                              self.manifest["n_features"])

    def finalize(self) -> dict:
        """Mark the checkpoint servable (all batches present)."""
        missing = set(range(self.manifest["n_batches"])) - self.done_batches
        if missing:
            raise ValueError(f"cannot finalize: batches {sorted(missing)} "
                             "missing from manifest")
        self.manifest["complete"] = True
        self._flush()
        return self.manifest


def _densify_shard(directory: str, entry: dict, block_shape,
                   n_features: int) -> np.ndarray:
    """Unpack one stream shard's BSR blocks into its (n_rows, D) rows."""
    data = np.load(os.path.join(directory, entry["file"]))
    bl, bd = block_shape
    row_off = entry["row_start"] // bl
    W = np.zeros((entry["padded_rows"], -(-n_features // bd) * bd),
                 np.float32)
    for k in range(data["blocks"].shape[0]):
        r = int(data["block_rows"][k]) - row_off
        c = int(data["block_cols"][k])
        W[r * bl:(r + 1) * bl, c * bd:(c + 1) * bd] = data["blocks"][k]
    return W[:entry["n_rows"], :n_features]


def label_range_reader(directory: str):
    """A `read(start, stop) -> (stop - start, D) float32` view of a
    block-sparse checkpoint's label rows.

    The warm-start read path (repro.xmc_api.fit(init_from=...)): a prior
    checkpoint's shards are mapped back to label ranges one training batch
    at a time. For the streamed multi-shard layout each call densifies
    only the shards overlapping the range, so the full (L, D) matrix is
    never materialized; the one-shot single-shard layout (one monolithic
    block array, no per-range structure) is densified ONCE here and
    served as cached slices — build the reader once per run, not per
    batch. Rows past the prior model's label count come back as zeros
    (a grown label space cold-starts its new labels).
    """
    index = load_block_sparse_meta(directory)
    L, D = index["orig_shape"]

    if index.get("layout") == "stream":
        manifest = index["manifest"]

        def read(start: int, stop: int) -> np.ndarray:
            if stop <= start:
                raise ValueError(f"empty label range [{start}, {stop})")
            out = np.zeros((stop - start, D), np.float32)
            for b in sorted(manifest["shards"], key=int):
                entry = manifest["shards"][b]
                r0 = entry["row_start"]
                lo, hi = max(start, r0), min(stop, r0 + entry["n_rows"])
                if lo >= hi:
                    continue
                rows = _densify_shard(directory, entry,
                                      manifest["block_shape"], D)
                out[lo - start:hi - start] = rows[lo - r0:hi - r0]
            return out
        return read

    model, _ = load_block_sparse(directory)
    W_full = np.asarray(model.to_dense())

    def read(start: int, stop: int) -> np.ndarray:
        if stop <= start:
            raise ValueError(f"empty label range [{start}, {stop})")
        out = np.zeros((stop - start, D), np.float32)
        hi = min(stop, L)
        if hi > start:
            out[:hi - start] = W_full[start:hi, :D]
        return out
    return read


def load_label_range_dense(directory: str, start: int,
                           stop: int) -> np.ndarray:
    """One-shot convenience over `label_range_reader` (which see); for
    repeated ranges build the reader once instead."""
    return label_range_reader(directory)(start, stop)


def has_block_sparse_checkpoint(directory: str) -> bool:
    """True if `directory` holds a *servable* BSR checkpoint: a single-shard
    index, or a multi-shard manifest whose job ran to completion."""
    if os.path.exists(os.path.join(directory, BSR_INDEX)):
        return True
    path = os.path.join(directory, BSR_MANIFEST)
    if not os.path.exists(path):
        return False
    with open(path) as f:
        return bool(json.load(f).get("complete"))


def _stream_index(directory: str) -> dict:
    """Synthesize a single-shard-style index dict from a stream manifest so
    pre-flight consumers (serving CLIs) see one schema for both layouts."""
    with open(os.path.join(directory, BSR_MANIFEST)) as f:
        manifest = json.load(f)
    if not manifest.get("complete"):
        raise ValueError(
            f"{directory} holds an incomplete streamed checkpoint "
            f"({len(manifest.get('shards', {}))}/{manifest.get('n_batches')} "
            "batches); resume the training job to finish it")
    bl, bd = manifest["block_shape"]
    L, D = manifest["n_labels"], manifest["n_features"]
    shards = manifest["shards"]
    return {
        "format": "bsr", "layout": "stream",
        "shape": [sum(s["padded_rows"] for s in shards.values()),
                  -(-D // bd) * bd],
        "orig_shape": [L, D],
        "block_shape": [bl, bd],
        "n_blocks": sum(s["n_blocks"] for s in shards.values()),
        "dtype": "float32",
        "meta": manifest["meta"],
        "manifest": manifest,
    }


def load_block_sparse_meta(directory: str) -> dict:
    """The index of a block-sparse checkpoint (shapes + user meta) without
    touching the arrays — cheap pre-flight validation for serving CLIs.
    Reads both the single-shard and the streamed multi-shard layout."""
    if os.path.exists(os.path.join(directory, BSR_INDEX)):
        with open(os.path.join(directory, BSR_INDEX)) as f:
            index = json.load(f)
        if index.get("format") != "bsr":
            raise ValueError(f"{directory} is not a block-sparse checkpoint")
        return index
    if os.path.exists(os.path.join(directory, BSR_MANIFEST)):
        return _stream_index(directory)
    raise FileNotFoundError(
        f"no block-sparse checkpoint (index or manifest) in {directory}")


def load_block_sparse(directory: str):
    """Returns (BlockSparseModel, meta dict). Reads both layouts: the
    one-shot artifact written by `save_block_sparse` and the multi-shard
    stream written by `BlockSparseWriter` (shards are stitched by row_ptr
    bookkeeping — no block is ever unpacked)."""
    from repro.core.pruning import (BlockSparseModel,       # deferred: no
                                    concat_block_sparse)    # import cycle

    index = load_block_sparse_meta(directory)
    if index.get("layout") == "stream":
        manifest = index["manifest"]
        bl, bd = manifest["block_shape"]
        parts = []
        for b in sorted(manifest["shards"], key=int):
            entry = manifest["shards"][b]
            data = np.load(os.path.join(directory, entry["file"]))
            parts.append(BlockSparseModel(
                blocks=jnp.asarray(data["blocks"]),
                block_rows=jnp.asarray(data["block_rows"]),
                block_cols=jnp.asarray(data["block_cols"]),
                row_ptr=jnp.asarray(data["row_ptr"]),
                shape=(entry["padded_rows"], index["shape"][1]),
                block_shape=(bl, bd)))
        model = concat_block_sparse(parts, tuple(index["orig_shape"]))
        return model, index["meta"]
    data = np.load(os.path.join(directory, BSR_ARRAYS))
    model = BlockSparseModel(
        blocks=jnp.asarray(data["blocks"]),
        block_rows=jnp.asarray(data["block_rows"]),
        block_cols=jnp.asarray(data["block_cols"]),
        row_ptr=jnp.asarray(data["row_ptr"]),
        shape=tuple(index["shape"]),
        block_shape=tuple(index["block_shape"]),
        orig_shape=tuple(index.get("orig_shape", index["shape"])))
    return model, index["meta"]


def restore_pytree(template, directory: str):
    """Restores into the structure of `template` (shapes must match)."""
    with open(os.path.join(directory, "index.json")) as f:
        index = json.load(f)
    data = np.load(os.path.join(directory, "arrays.npz"))

    flat_template, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in flat_template:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        meta = index["entries"][key]
        if meta["format"] == "coo":
            arr = np.zeros(meta["shape"], dtype=meta["dtype"])
            arr[data[f"{key}::rows"], data[f"{key}::cols"]] = \
                data[f"{key}::values"]
        else:
            arr = data[key]
        leaves.append(jnp.asarray(arr).astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)
