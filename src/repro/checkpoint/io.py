"""Checkpointing: flattened-path .npz per host + JSON index.

Mirrors DiSMEC's per-batch block model files (§2.1): the pruned head /
XMC weight blocks are stored sparse (values + indices) when density < 50%,
dense otherwise. Works for any pytree (params, optimizer state, caches).

Beyond pytrees, `save_block_sparse` / `load_block_sparse` round-trip the
packed BSR artifact (`core.pruning.BlockSparseModel`) that the XMC serving
subsystem loads: a pruned model is converted once offline — like the paper's
per-batch model files — and served by any backend without re-densifying.
"""

from __future__ import annotations

import json
import os
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        flat[key] = np.asarray(leaf)
    return flat


def save_pytree(tree, directory: str, *, sparse_threshold: float = 0.5):
    os.makedirs(directory, exist_ok=True)
    flat = _flatten(tree)
    index: dict[str, Any] = {"entries": {}}
    arrays = {}
    for key, arr in flat.items():
        if arr.ndim == 2 and arr.size > 4096:
            density = float((arr != 0).mean())
            if density < sparse_threshold:
                nz = np.nonzero(arr)
                arrays[f"{key}::values"] = arr[nz]
                arrays[f"{key}::rows"] = nz[0].astype(np.int32)
                arrays[f"{key}::cols"] = nz[1].astype(np.int32)
                index["entries"][key] = {"format": "coo", "shape": arr.shape,
                                         "dtype": str(arr.dtype),
                                         "density": density}
                continue
        arrays[key] = arr
        index["entries"][key] = {"format": "dense", "shape": list(arr.shape),
                                 "dtype": str(arr.dtype)}
    np.savez_compressed(os.path.join(directory, "arrays.npz"), **arrays)
    with open(os.path.join(directory, "index.json"), "w") as f:
        json.dump(index, f, indent=1)


BSR_ARRAYS = "bsr_arrays.npz"
BSR_INDEX = "bsr_index.json"


def save_block_sparse(model, directory: str, *, meta: dict | None = None):
    """Write a `BlockSparseModel` (+ optional serving metadata such as
    n_labels / delta) as one .npz + JSON index under `directory`."""
    os.makedirs(directory, exist_ok=True)
    np.savez_compressed(
        os.path.join(directory, BSR_ARRAYS),
        blocks=np.asarray(model.blocks),
        block_rows=np.asarray(model.block_rows),
        block_cols=np.asarray(model.block_cols),
        row_ptr=np.asarray(model.row_ptr))
    index = {
        "format": "bsr",
        "shape": list(model.shape),
        "orig_shape": list(model.orig_shape or model.shape),
        "block_shape": list(model.block_shape),
        "n_blocks": model.n_blocks,
        "dtype": str(np.asarray(model.blocks).dtype),
        "meta": dict(meta or {}),
    }
    with open(os.path.join(directory, BSR_INDEX), "w") as f:
        json.dump(index, f, indent=1)


def load_block_sparse_meta(directory: str) -> dict:
    """The index of a block-sparse checkpoint (shapes + user meta) without
    touching the arrays — cheap pre-flight validation for serving CLIs."""
    with open(os.path.join(directory, BSR_INDEX)) as f:
        index = json.load(f)
    if index.get("format") != "bsr":
        raise ValueError(f"{directory} is not a block-sparse checkpoint")
    return index


def load_block_sparse(directory: str):
    """Returns (BlockSparseModel, meta dict). Inverse of save_block_sparse."""
    from repro.core.pruning import BlockSparseModel   # deferred: no cycle

    index = load_block_sparse_meta(directory)
    data = np.load(os.path.join(directory, BSR_ARRAYS))
    model = BlockSparseModel(
        blocks=jnp.asarray(data["blocks"]),
        block_rows=jnp.asarray(data["block_rows"]),
        block_cols=jnp.asarray(data["block_cols"]),
        row_ptr=jnp.asarray(data["row_ptr"]),
        shape=tuple(index["shape"]),
        block_shape=tuple(index["block_shape"]),
        orig_shape=tuple(index.get("orig_shape", index["shape"])))
    return model, index["meta"]


def restore_pytree(template, directory: str):
    """Restores into the structure of `template` (shapes must match)."""
    with open(os.path.join(directory, "index.json")) as f:
        index = json.load(f)
    data = np.load(os.path.join(directory, "arrays.npz"))

    flat_template, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in flat_template:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        meta = index["entries"][key]
        if meta["format"] == "coo":
            arr = np.zeros(meta["shape"], dtype=meta["dtype"])
            arr[data[f"{key}::rows"], data[f"{key}::cols"]] = \
                data[f"{key}::values"]
        else:
            arr = data[key]
        leaves.append(jnp.asarray(arr).astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)
