"""Checkpointing: flattened-path .npz per host + JSON index.

Mirrors DiSMEC's per-batch block model files (§2.1): the pruned head /
XMC weight blocks are stored sparse (values + indices) when density < 50%,
dense otherwise. Works for any pytree (params, optimizer state, caches).

Beyond pytrees, `save_block_sparse` / `load_block_sparse` round-trip the
packed BSR artifact (`core.pruning.BlockSparseModel`) that the XMC serving
subsystem loads: a pruned model is converted once offline — like the paper's
per-batch model files — and served by any backend without re-densifying.

Two on-disk layouts share one loader:

  single-shard — `bsr_arrays.npz` + `bsr_index.json`, written in one shot by
                 `save_block_sparse` after an in-memory conversion;
  multi-shard  — `shard-<batch>.npz` per label batch + `bsr_manifest.json`,
                 appended incrementally by `BlockSparseWriter` as the
                 streaming trainer (train/xmc.py) finishes each batch. The
                 manifest is rewritten atomically after every shard, so a
                 killed job resumes by skipping the batches already listed;
                 `load_block_sparse` stitches the shards back into one
                 `BlockSparseModel` (pure row_ptr bookkeeping, no re-tiling)
                 so the serving engine never sees the difference.

Both layouts carry a **generation counter**: every fresh write into a
directory (one-shot `save_block_sparse`, or a `BlockSparseWriter` started
with `resume=False`) records `generation = <prior generation> + 1`, and the
counter becomes visible to readers only once the artifact is servable (the
one-shot index exists / the stream manifest flips `complete`). A poller
(`checkpoint_generation`, consumed by `lifecycle.refresh.CheckpointWatcher`)
therefore sees a strictly increasing integer that changes exactly when a
new *finished* model lands — never a half-written one. Resumed streams keep
their generation: resuming is finishing the same model, not publishing a
new one.

Manifest version 2 adds a **batch-lease table** (`leases`) to the stream
manifest: the paper's layer-1 dispatch of label batches across nodes,
done as cooperative claiming over a shared filesystem. N independent
trainer processes pointed at the same directory each atomically claim the
next unleased (or expired) batch under an `flock`'d manifest lock, solve
it, and release the lease when the shard's manifest commit lands — so the
batch queue drains across hosts into ONE checkpoint, and a worker that
dies mid-batch is recovered by lease expiry (its batch becomes claimable
again after `ttl` seconds). Version-1 manifests (no `leases` key) are
still read and are upgraded in place on the next resume; complete
checkpoints always carry an empty lease table, so the final artifact is
bit-identical to a single-worker run.
"""

from __future__ import annotations

import json
import os
import time
from contextlib import contextmanager
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

try:                         # POSIX advisory locks; released on process death
    import fcntl
except ImportError:          # pragma: no cover - non-POSIX fallback
    fcntl = None


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        flat[key] = np.asarray(leaf)
    return flat


def save_pytree(tree, directory: str, *, sparse_threshold: float = 0.5):
    os.makedirs(directory, exist_ok=True)
    flat = _flatten(tree)
    index: dict[str, Any] = {"entries": {}}
    arrays = {}
    for key, arr in flat.items():
        if arr.ndim == 2 and arr.size > 4096:
            density = float((arr != 0).mean())
            if density < sparse_threshold:
                nz = np.nonzero(arr)
                arrays[f"{key}::values"] = arr[nz]
                arrays[f"{key}::rows"] = nz[0].astype(np.int32)
                arrays[f"{key}::cols"] = nz[1].astype(np.int32)
                index["entries"][key] = {"format": "coo", "shape": arr.shape,
                                         "dtype": str(arr.dtype),
                                         "density": density}
                continue
        arrays[key] = arr
        index["entries"][key] = {"format": "dense", "shape": list(arr.shape),
                                 "dtype": str(arr.dtype)}
    np.savez_compressed(os.path.join(directory, "arrays.npz"), **arrays)
    with open(os.path.join(directory, "index.json"), "w") as f:
        json.dump(index, f, indent=1)


BSR_ARRAYS = "bsr_arrays.npz"
BSR_INDEX = "bsr_index.json"
SHORTLIST_FILE = "shortlist.npz"


def save_shortlist(directory: str, artifact) -> dict:
    """Persist a `serve.shortlist.ShortlistArtifact` next to the BSR arrays
    (tmp + atomic rename — cooperative finalizers may race, and both write
    identical bytes). Returns the entry the index/manifest references.

    Writes the v2 format: explicit `version` and `kind` keys, plus the
    routing-tree arrays when `kind == "tree"`. v1 files (PR 6 — no version
    key, always centroids) are still read by `load_shortlist`."""
    from repro.serve.shortlist import SHORTLIST_VERSION  # deferred: no cycle
    path = os.path.join(directory, SHORTLIST_FILE)
    tmp = path + ".tmp.npz"
    arrays = dict(
        version=np.int32(SHORTLIST_VERSION),
        kind=np.str_(artifact.kind),
        centroids=np.asarray(artifact.centroids, np.float32),
        block_rows=np.int32(artifact.block_rows),
        n_labels=np.int32(artifact.n_labels),
        stat=np.str_(artifact.stat))
    if artifact.kind == "tree":
        arrays["tree_nodes"] = np.asarray(artifact.tree_nodes, np.float32)
        arrays["tree_leaf_scores"] = np.asarray(artifact.tree_leaf_scores,
                                                np.float32)
        arrays["tree_depth"] = np.int32(artifact.tree_depth)
    np.savez_compressed(tmp, **arrays)
    os.replace(tmp, path)
    return {"file": SHORTLIST_FILE,
            "version": int(SHORTLIST_VERSION),
            "kind": artifact.kind,
            "n_row_blocks": artifact.n_row_blocks,
            "block_rows": int(artifact.block_rows),
            "stat": artifact.stat}


def load_shortlist(directory: str):
    """The shortlist artifact of a checkpoint, or None when the checkpoint
    predates two-stage scoring (legacy checkpoints serve exhaustively).

    Reads both formats: v2 (version/kind keys, optional tree arrays) and
    v1 (PR 6 — centroids only, no version key), which loads as
    kind="centroid"."""
    path = os.path.join(directory, SHORTLIST_FILE)
    if not os.path.exists(path):
        return None
    from repro.serve.shortlist import ShortlistArtifact  # deferred: no cycle
    data = np.load(path, allow_pickle=False)
    kind = str(data["kind"]) if "version" in data.files else "centroid"
    tree_kwargs = {}
    if kind == "tree":
        tree_kwargs = dict(tree_nodes=np.asarray(data["tree_nodes"]),
                           tree_leaf_scores=np.asarray(
                               data["tree_leaf_scores"]),
                           tree_depth=int(data["tree_depth"]))
    return ShortlistArtifact(centroids=np.asarray(data["centroids"]),
                             block_rows=int(data["block_rows"]),
                             n_labels=int(data["n_labels"]),
                             stat=str(data["stat"]),
                             kind=kind, **tree_kwargs)


def upgrade_shortlist(directory: str, artifact) -> dict:
    """Replace a checkpoint's shortlist artifact (e.g. centroid -> learned
    or tree, built by `fit()` once training data is in hand) and update the
    index/manifest entry that references it, atomically for either layout.

    Runs under `manifest_lock`, so cooperative workers that both reach the
    post-finalize upgrade serialize; the builders are deterministic in
    (checkpoint, data), so the racers write identical bytes and the
    last-writer-wins rename is harmless. Returns the new entry."""
    index_path = os.path.join(directory, BSR_INDEX)
    manifest_path = os.path.join(directory, BSR_MANIFEST)
    with manifest_lock(directory):
        entry = save_shortlist(directory, artifact)
        if os.path.exists(index_path):
            with open(index_path) as f:
                index = json.load(f)
            index["shortlist"] = entry
            tmp = index_path + ".tmp"
            with open(tmp, "w") as f:
                json.dump(index, f, indent=1)
            os.replace(tmp, index_path)
        elif os.path.exists(manifest_path):
            with open(manifest_path) as f:
                manifest = json.load(f)
            manifest["shortlist"] = entry
            tmp = manifest_path + ".tmp"
            with open(tmp, "w") as f:
                json.dump(manifest, f, indent=1, sort_keys=True)
            os.replace(tmp, manifest_path)
        else:
            raise FileNotFoundError(
                f"no block-sparse checkpoint (index or manifest) in "
                f"{directory} to attach a shortlist to")
        return entry


def _prior_generation(directory: str) -> int:
    """Highest generation any artifact in `directory` has recorded —
    complete or not — so the next fresh write publishes a strictly larger
    one. 0 when the directory holds no checkpoint; artifacts that predate
    the counter count as generation 1."""
    gen = 0
    for name in (BSR_INDEX, BSR_MANIFEST):
        path = os.path.join(directory, name)
        if os.path.exists(path):
            try:
                with open(path) as f:
                    gen = max(gen, int(json.load(f).get("generation", 1)))
            except (OSError, ValueError):
                gen = max(gen, 1)
    return gen


def checkpoint_generation(directory: str) -> Optional[int]:
    """Generation of the *servable* checkpoint in `directory`, or None when
    nothing is servable yet (no checkpoint, or a stream whose manifest has
    not flipped `complete`).

    This is the cheap poll primitive behind zero-downtime refresh
    (`lifecycle.refresh.CheckpointWatcher`): two small JSON reads, no
    arrays touched. Checkpoints written before the counter existed report
    generation 1.
    """
    index_path = os.path.join(directory, BSR_INDEX)
    if os.path.exists(index_path):
        with open(index_path) as f:
            return int(json.load(f).get("generation", 1))
    path = os.path.join(directory, BSR_MANIFEST)
    if os.path.exists(path):
        with open(path) as f:
            manifest = json.load(f)
        if manifest.get("complete"):
            return int(manifest.get("generation", 1))
    return None


def save_block_sparse(model, directory: str, *, meta: dict | None = None,
                      label_order=None):
    """Write a `BlockSparseModel` (+ optional serving metadata such as
    n_labels / delta) as one .npz + JSON index under `directory`, plus the
    shortlist artifact for two-stage serving. Stamps the next generation
    (prior + 1) so pollers see the rewrite as a new model.

    `label_order` (optional, len n_labels) records the pack-time label
    permutation: packed row j holds original label `label_order[j]`. The
    serving engine maps top-k ids back through it, so reordered
    checkpoints serve original label ids exactly."""
    from repro.core.pruning import quantize_blocks       # deferred: no cycle
    from repro.serve.shortlist import build_shortlist    # deferred: no cycle
    os.makedirs(directory, exist_ok=True)
    generation = _prior_generation(directory) + 1
    blocks = np.asarray(model.blocks)
    blocks_int8, block_scales = quantize_blocks(blocks)
    np.savez_compressed(
        os.path.join(directory, BSR_ARRAYS),
        blocks=blocks,
        blocks_int8=blocks_int8,
        block_scales=block_scales,
        block_rows=np.asarray(model.block_rows),
        block_cols=np.asarray(model.block_cols),
        row_ptr=np.asarray(model.row_ptr))
    index = {
        "format": "bsr",
        "shape": list(model.shape),
        "orig_shape": list(model.orig_shape or model.shape),
        "block_shape": list(model.block_shape),
        "n_blocks": model.n_blocks,
        "dtype": str(blocks.dtype),
        "int8": True,
        "generation": generation,
        "meta": dict(meta or {}),
        "shortlist": save_shortlist(directory, build_shortlist(model)),
    }
    if label_order is not None:
        index["label_order"] = _check_label_order(label_order,
                                                  model.n_labels)
    with open(os.path.join(directory, BSR_INDEX), "w") as f:
        json.dump(index, f, indent=1)


def _check_label_order(label_order, n_labels: int) -> list[int]:
    """Validate a pack-time label permutation (length n_labels, a true
    permutation of range(n_labels)) and return it JSON-ready."""
    order = [int(v) for v in np.asarray(label_order).reshape(-1)]
    if sorted(order) != list(range(int(n_labels))):
        raise ValueError(
            f"label_order must be a permutation of range({n_labels}); got "
            f"length {len(order)}")
    return order


BSR_MANIFEST = "bsr_manifest.json"
BSR_MANIFEST_LOCK = "bsr_manifest.lock"

#: Stream-manifest schema version. 1 = shards only (pre-lease); 2 adds the
#: `leases` batch-lease table. Readers accept both; writers emit 2 and
#: upgrade a resumed v1 manifest in place.
MANIFEST_VERSION = 2


@contextmanager
def manifest_lock(directory: str):
    """Exclusive cross-process lock over a stream checkpoint's manifest.

    An `flock` on a sidecar lock file (never on the manifest itself — the
    manifest is replaced atomically, which would orphan a lock held on the
    old inode). The kernel drops the lock when the holder dies, so a
    crashed worker can never wedge the queue; without fcntl (non-POSIX)
    this degrades to no inter-process exclusion, which is only correct
    for single-worker use.
    """
    fd = os.open(os.path.join(directory, BSR_MANIFEST_LOCK),
                 os.O_CREAT | os.O_RDWR, 0o644)
    try:
        if fcntl is not None:
            fcntl.flock(fd, fcntl.LOCK_EX)
        yield
    finally:
        try:
            if fcntl is not None:
                fcntl.flock(fd, fcntl.LOCK_UN)
        finally:
            os.close(fd)


class BlockSparseWriter:
    """Incremental multi-shard BSR checkpoint (the paper's per-batch model
    files, written as training goes rather than after it).

    One `shard-<batch>.npz` per label batch plus a JSON manifest. Each
    `write_batch` first writes the shard file, then atomically rewrites the
    manifest (tmp + rename) — a crash between the two leaves an orphan shard
    that the next run simply re-solves and overwrites, so the manifest is
    always the ground truth for what is done. `done_batches` is what a
    resumed `XMCTrainJob` skips.

    Multi-host layer 1: the manifest also carries a batch-lease table.
    `claim_next_batch(worker, ttl=...)` atomically hands out the lowest
    batch that is neither written nor under a live lease;
    `heartbeat(worker, batches)` keeps long solves alive; the lease is
    released by the `write_batch` manifest commit (or explicitly by
    `release_leases` on the error path). Every lease operation — and every
    manifest mutation — runs as reload-mutate-flush under `manifest_lock`,
    so N writer processes sharing one directory see one consistent queue.
    Batches are solved deterministically from the spec + data (which the
    `solver` fingerprint pins), so the rare double-solve after a lease
    expires mid-flight just rewrites an identical shard.
    """

    def __init__(self, directory: str, *, n_labels: int, n_features: int,
                 block_shape: tuple[int, int], label_batch: int,
                 n_batches: int, solver: dict | None = None,
                 meta: dict | None = None, resume: bool = True,
                 label_order=None, clock=time.time):
        """`solver` is an opaque dict of whatever determined the solution
        (hyperparameters, dataset fingerprint): it is stored in the manifest
        and must match exactly on resume — shards solved under different
        settings must never be stitched into one 'complete' checkpoint.

        `label_order` (optional) is the pack-time label permutation: packed
        row j of the checkpoint holds original label `label_order[j]`. It
        lives in the identity-checked manifest header, so a resume under a
        different (or no) permutation is rejected — shards packed in
        different label orders must never be stitched together.

        `clock` is the lease table's time source (seconds, `time.time`
        semantics). Injected so lease-expiry logic is testable without
        real wall-clock sleeps; production callers never pass it.
        """
        self.directory = directory
        self._clock = clock
        os.makedirs(directory, exist_ok=True)
        self._path = os.path.join(directory, BSR_MANIFEST)
        # Sample the prior generation before anything is removed: a fresh
        # start over an old checkpoint (either layout) must publish a
        # strictly larger generation once it finalizes.
        prior_gen = _prior_generation(directory)
        # A single-shard artifact in the same directory would shadow the
        # stream on load (load_block_sparse prefers BSR_INDEX): refuse to
        # write behind it unless the caller explicitly starts fresh.
        index_path = os.path.join(directory, BSR_INDEX)
        if os.path.exists(index_path):
            if resume:
                raise ValueError(
                    f"{directory} already holds a single-shard checkpoint "
                    f"({BSR_INDEX}), which would shadow the streamed one on "
                    "load; pass resume=False to replace it, or stream into "
                    "a different directory")
            os.remove(index_path)
            try:
                os.remove(os.path.join(directory, BSR_ARRAYS))
            except OSError:
                pass
        header = {
            "format": "bsr-stream",
            "n_labels": int(n_labels), "n_features": int(n_features),
            "block_shape": [int(b) for b in block_shape],
            "label_batch": int(label_batch), "n_batches": int(n_batches),
            "solver": dict(solver or {}),
        }
        if label_order is not None:
            header["label_order"] = _check_label_order(label_order, n_labels)
        # Creation/validation runs under the manifest lock: co-workers
        # launched simultaneously must not both observe "no manifest yet"
        # and race to create it (one creates, the rest resume into it).
        with manifest_lock(directory):
            existing = None
            if os.path.exists(self._path):
                with open(self._path) as f:
                    existing = json.load(f)
            if existing is not None and resume:
                # `manifest_version` is deliberately not part of the
                # identity check: a v1 (pre-lease) manifest resumes fine
                # and is upgraded in place on the next flush.
                mismatch = {k: (existing.get(k), v) for k, v in header.items()
                            if existing.get(k) != v}
                # label_order is identity both ways: a manifest packed under
                # a permutation must not be resumed without it (absent from
                # header => not caught by the loop above).
                if ("label_order" in existing
                        and "label_order" not in header):
                    mismatch["label_order"] = (
                        "<set>", None)
                if mismatch:
                    raise ValueError(
                        f"cannot resume into {directory}: manifest disagrees "
                        f"on {mismatch}; pass resume=False to start fresh")
                self.manifest = existing
                self.manifest.setdefault("leases", {})
                # Resuming finishes the SAME model — keep its generation
                # (pre-counter manifests adopt 1, the legacy default).
                self.manifest.setdefault("generation", 1)
                self.manifest["manifest_version"] = MANIFEST_VERSION
                # Meta is creator-wins: a joiner only contributes keys the
                # manifest does not have yet, and the merge is flushed here
                # (inside the init lock) so the meta on disk is settled
                # before any lease/shard flush — co-workers admitted with a
                # divergent serve section (serving is deliberately not
                # fingerprinted) can never make meta.xmc_spec depend on
                # which worker's flush landed last.
                for k, v in (meta or {}).items():
                    self.manifest["meta"].setdefault(k, v)
                self._flush()
            else:
                if existing is not None:             # fresh start: drop shards
                    for s in existing.get("shards", {}).values():
                        try:
                            os.remove(os.path.join(directory, s["file"]))
                        except OSError:
                            pass
                self.manifest = {**header,
                                 "manifest_version": MANIFEST_VERSION,
                                 "generation": prior_gen + 1,
                                 "complete": False, "shards": {},
                                 "leases": {}, "meta": dict(meta or {})}
                self._flush()

    @property
    def complete(self) -> bool:
        return bool(self.manifest.get("complete"))

    @property
    def done_batches(self) -> set[int]:
        return {int(b) for b in self.manifest["shards"]}

    def _flush(self) -> None:
        tmp = self._path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(self.manifest, f, indent=1, sort_keys=True)
        os.replace(tmp, self._path)

    def _reload(self) -> None:
        """Adopt the shared mutable state (shards / leases / complete /
        meta) from disk; the header stays local (identity-checked at
        construction). Meta comes from disk because it was settled at init
        time (creator-wins merge) — adopting it keeps later flushes from
        re-imposing one worker's local view."""
        if not os.path.exists(self._path):
            return
        with open(self._path) as f:
            disk = json.load(f)
        self.manifest["shards"] = disk.get("shards", {})
        self.manifest["leases"] = disk.get("leases", {})
        self.manifest["complete"] = disk.get("complete", False)
        self.manifest["meta"] = disk.get("meta", self.manifest.get("meta",
                                                                   {}))
        if "shortlist" in disk:          # built by whichever worker finalized
            self.manifest["shortlist"] = disk["shortlist"]

    @contextmanager
    def _locked(self, write: bool = True):
        """One atomic reload-[mutate-flush] cycle under the manifest lock —
        the unit every manifest operation runs as, so concurrent writer
        processes never lose each other's updates. `write=False` is the
        read-only form: backoff polls must not rewrite the manifest on the
        shared filesystem once per second per idle worker."""
        with manifest_lock(self.directory):
            self._reload()
            yield
            if write:
                self._flush()

    def write_batch(self, batch: int, part, *, row_start: int,
                    n_rows: int) -> None:
        """Append one solved label batch (append-form `BlockSparseModel`,
        see `core.pruning.to_block_sparse(row_block_offset=...)`) and
        release this batch's lease (if any) in the same manifest commit."""
        from repro.core.pruning import quantize_blocks   # deferred: no cycle
        blocks = np.asarray(part.blocks)
        blocks_int8, block_scales = quantize_blocks(blocks)
        fname = f"shard-{batch:05d}.npz"
        path = os.path.join(self.directory, fname)
        # tmp + rename: a shard re-solved by a second worker (expired
        # lease) must replace the file atomically, never interleave with a
        # concurrent reader. The tmp name keeps the .npz suffix so
        # np.savez does not append another one.
        tmp = path + ".tmp.npz"
        np.savez_compressed(
            tmp,
            blocks=blocks,
            blocks_int8=blocks_int8,
            block_scales=block_scales,
            block_rows=np.asarray(part.block_rows),
            block_cols=np.asarray(part.block_cols),
            row_ptr=np.asarray(part.row_ptr))
        os.replace(tmp, path)
        with self._locked():
            self.manifest["shards"][str(int(batch))] = {
                "file": fname, "row_start": int(row_start),
                "n_rows": int(n_rows), "padded_rows": int(part.shape[0]),
                "n_blocks": int(blocks.shape[0]),
                "nnz": int(np.count_nonzero(blocks)),
                "int8": True,
            }
            self.manifest["leases"].pop(str(int(batch)), None)

    def read_batch_dense(self, batch: int) -> np.ndarray:
        """Densify one already-written shard back to its (n_rows, D) weight
        rows — the resume path of a materializing caller."""
        entry = self.manifest["shards"][str(int(batch))]
        return _densify_shard(self.directory, entry,
                              self.manifest["block_shape"],
                              self.manifest["n_features"])

    # -- batch leases (multi-host layer 1) --------------------------------

    def claim_next_batch(self, worker: str, *, ttl: float,
                         exclude=()) -> Optional[int]:
        """Atomically claim the lowest batch that is neither written nor
        under another worker's live lease; None when nothing is claimable
        right now (queue drained, or every remaining batch is leased by a
        live co-worker — see `claim_wait_seconds`). A worker's own lease is
        reclaimed immediately UNLESS the batch is in `exclude` — callers
        pass the batches they are solving right now, so a restart under
        the same worker id recovers its stale leases without a claimer
        being handed a batch it already holds.
        """
        if fcntl is None:
            raise RuntimeError(
                "multi-worker lease coordination needs POSIX flock "
                "(fcntl) for atomic manifest claims; this platform has "
                "none, so cooperative workers would silently corrupt the "
                "queue — run with workers=1 and no explicit worker id")
        exclude = {int(b) for b in exclude}
        with manifest_lock(self.directory):
            self._reload()
            now = self._clock()
            shards, leases = self.manifest["shards"], self.manifest["leases"]
            for b in range(self.manifest["n_batches"]):
                s = str(b)
                if b in exclude or s in shards:
                    continue
                lease = leases.get(s)
                if (lease is not None and lease["worker"] != worker
                        and now < lease["ts"] + lease["ttl"]):
                    continue
                leases[s] = {"worker": worker, "ts": now, "ttl": float(ttl)}
                self._flush()                    # flush only on a claim
                return b
            return None

    def heartbeat(self, worker: str, batches) -> None:
        """Refresh `worker`'s leases on `batches` (a solve outliving its
        TTL must not get its batch re-dealt under it)."""
        batches = [int(b) for b in batches]
        if not batches:
            return
        with manifest_lock(self.directory):
            self._reload()
            now = self._clock()
            touched = False
            for b in batches:
                lease = self.manifest["leases"].get(str(b))
                if lease is not None and lease["worker"] == worker:
                    lease["ts"] = now
                    touched = True
            if touched:
                self._flush()

    def release_leases(self, worker: str, batches) -> None:
        """Drop `worker`'s leases on `batches` without writing shards — the
        error/preemption path, so co-workers reclaim immediately instead of
        waiting out the TTL."""
        batches = [int(b) for b in batches]
        if not batches:
            return
        with manifest_lock(self.directory):
            self._reload()
            dropped = False
            for b in batches:
                lease = self.manifest["leases"].get(str(b))
                if lease is not None and lease["worker"] == worker:
                    del self.manifest["leases"][str(b)]
                    dropped = True
            if dropped:
                self._flush()

    def claim_wait_seconds(self) -> Optional[float]:
        """Seconds until some unwritten batch becomes claimable (0.0 when
        one already is), or None when every batch is written — the backoff
        a worker sleeps when `claim_next_batch` returns None but the
        checkpoint is not finished (a co-worker may yet die mid-batch)."""
        with self._locked(write=False):
            now = self._clock()
            shards, leases = self.manifest["shards"], self.manifest["leases"]
            waits = []
            for b in range(self.manifest["n_batches"]):
                s = str(b)
                if s in shards:
                    continue
                lease = leases.get(s)
                waits.append(0.0 if lease is None else
                             max(0.0, lease["ts"] + lease["ttl"] - now))
            return min(waits) if waits else None

    # -- completion -------------------------------------------------------

    def try_finalize(self) -> Optional[dict]:
        """Mark the checkpoint servable if every batch is present (clearing
        the lease table); None while batches are still missing. Idempotent
        — with cooperative workers, whichever one drains the last batch
        finalizes, and a second call is a no-op.

        Finalizing also builds the serving shortlist artifact
        (serve/shortlist.py) from the stitched shards and references it in
        the manifest — the coarse stage of two-stage scoring, computed once
        offline like the paper's model files. Deterministic in the shards,
        so cooperative finalizers (or a re-finalize after a crash between
        the two flushes) write identical bytes.
        """
        with manifest_lock(self.directory):
            self._reload()
            missing = (set(range(self.manifest["n_batches"]))
                       - self.done_batches)
            if missing:                          # read-only: nothing to flush
                return None
            self.manifest["complete"] = True
            self.manifest["leases"] = {}
            self._flush()
            if "shortlist" not in self.manifest:
                # Stitch via the normal loader (reads the just-flushed
                # complete manifest from disk) and persist the artifact
                # before the manifest entry that references it lands.
                from repro.serve.shortlist import build_shortlist
                model, _ = load_block_sparse(self.directory)
                self.manifest["shortlist"] = save_shortlist(
                    self.directory, build_shortlist(model))
                self._flush()
            return self.manifest

    def finalize(self) -> dict:
        """Mark the checkpoint servable (all batches present)."""
        manifest = self.try_finalize()
        if manifest is None:
            missing = (set(range(self.manifest["n_batches"]))
                       - self.done_batches)
            raise ValueError(f"cannot finalize: batches {sorted(missing)} "
                             "missing from manifest")
        return manifest


def _densify_shard(directory: str, entry: dict, block_shape,
                   n_features: int) -> np.ndarray:
    """Unpack one stream shard's BSR blocks into its (n_rows, D) rows."""
    data = np.load(os.path.join(directory, entry["file"]))
    bl, bd = block_shape
    row_off = entry["row_start"] // bl
    W = np.zeros((entry["padded_rows"], -(-n_features // bd) * bd),
                 np.float32)
    for k in range(data["blocks"].shape[0]):
        r = int(data["block_rows"][k]) - row_off
        c = int(data["block_cols"][k])
        W[r * bl:(r + 1) * bl, c * bd:(c + 1) * bd] = data["blocks"][k]
    return W[:entry["n_rows"], :n_features]


def label_range_reader(directory: str):
    """A `read(start, stop) -> (stop - start, D) float32` view of a
    block-sparse checkpoint's label rows.

    The warm-start read path (repro.xmc_api.fit(init_from=...)): a prior
    checkpoint's shards are mapped back to label ranges one training batch
    at a time. For the streamed multi-shard layout each call densifies
    only the shards overlapping the range, so the full (L, D) matrix is
    never materialized; the one-shot single-shard layout (one monolithic
    block array, no per-range structure) is densified ONCE here and
    served as cached slices — build the reader once per run, not per
    batch. Rows past the prior model's label count come back as zeros
    (a grown label space cold-starts its new labels).
    """
    index = load_block_sparse_meta(directory)
    L, D = index["orig_shape"]

    if index.get("layout") == "stream":
        manifest = index["manifest"]

        def read(start: int, stop: int) -> np.ndarray:
            if stop <= start:
                raise ValueError(f"empty label range [{start}, {stop})")
            out = np.zeros((stop - start, D), np.float32)
            for b in sorted(manifest["shards"], key=int):
                entry = manifest["shards"][b]
                r0 = entry["row_start"]
                lo, hi = max(start, r0), min(stop, r0 + entry["n_rows"])
                if lo >= hi:
                    continue
                rows = _densify_shard(directory, entry,
                                      manifest["block_shape"], D)
                out[lo - start:hi - start] = rows[lo - r0:hi - r0]
            return out
        return read

    model, _ = load_block_sparse(directory)
    W_full = np.asarray(model.to_dense())

    def read(start: int, stop: int) -> np.ndarray:
        if stop <= start:
            raise ValueError(f"empty label range [{start}, {stop})")
        out = np.zeros((stop - start, D), np.float32)
        hi = min(stop, L)
        if hi > start:
            out[:hi - start] = W_full[start:hi, :D]
        return out
    return read


def load_label_range_dense(directory: str, start: int,
                           stop: int) -> np.ndarray:
    """One-shot convenience over `label_range_reader` (which see); for
    repeated ranges build the reader once instead."""
    return label_range_reader(directory)(start, stop)


def has_block_sparse_checkpoint(directory: str) -> bool:
    """True if `directory` holds a *servable* BSR checkpoint: a single-shard
    index, or a multi-shard manifest whose job ran to completion."""
    if os.path.exists(os.path.join(directory, BSR_INDEX)):
        return True
    path = os.path.join(directory, BSR_MANIFEST)
    if not os.path.exists(path):
        return False
    with open(path) as f:
        return bool(json.load(f).get("complete"))


def _prefix_batches(manifest: dict) -> list[str]:
    """The contiguous prefix 0..m-1 of written batches — the only part of
    an incomplete stream that stitches into a well-formed (smaller) model:
    label rows are batch-ordered, so a gap would leave absolute block_rows
    pointing past the stitched row_ptr."""
    done = manifest["shards"]
    prefix = []
    for b in range(int(manifest["n_batches"])):
        if str(b) not in done:
            break
        prefix.append(str(b))
    return prefix


def _stream_index(directory: str, *, allow_incomplete: bool = False) -> dict:
    """Synthesize a single-shard-style index dict from a stream manifest so
    pre-flight consumers (serving CLIs) see one schema for both layouts.

    A still-streaming checkpoint raises unless `allow_incomplete=True` —
    the refresh watcher and serving CLIs must never pick up a half-written
    generation. With the opt-in, the index describes the contiguous prefix
    of solved label batches (`orig_shape` shrinks to the rows covered) and
    carries `complete: False` so callers can tell inspection from serving.
    """
    with open(os.path.join(directory, BSR_MANIFEST)) as f:
        manifest = json.load(f)
    complete = bool(manifest.get("complete"))
    if not complete and not allow_incomplete:
        raise ValueError(
            f"{directory} holds an incomplete streamed checkpoint "
            f"({len(manifest.get('shards', {}))}/{manifest.get('n_batches')} "
            "batches); resume the training job to finish it, or pass "
            "allow_incomplete=True to inspect the partial model")
    bl, bd = manifest["block_shape"]
    L, D = manifest["n_labels"], manifest["n_features"]
    batches = (sorted(manifest["shards"], key=int) if complete
               else _prefix_batches(manifest))
    shards = [manifest["shards"][b] for b in batches]
    rows_done = (L if complete else
                 (shards[-1]["row_start"] + shards[-1]["n_rows"]
                  if shards else 0))
    index = {
        "format": "bsr", "layout": "stream",
        "shape": [sum(s["padded_rows"] for s in shards),
                  -(-D // bd) * bd],
        "orig_shape": [rows_done, D],
        "block_shape": [bl, bd],
        "n_blocks": sum(s["n_blocks"] for s in shards),
        "dtype": "float32",
        "complete": complete,
        "generation": int(manifest.get("generation", 1)),
        "batches": batches,
        "meta": manifest["meta"],
        "manifest": manifest,
    }
    if "label_order" in manifest:        # pack-time label permutation
        index["label_order"] = manifest["label_order"]
    return index


def load_block_sparse_meta(directory: str, *,
                           allow_incomplete: bool = False) -> dict:
    """The index of a block-sparse checkpoint (shapes + user meta) without
    touching the arrays — cheap pre-flight validation for serving CLIs.
    Reads both the single-shard and the streamed multi-shard layout.
    `allow_incomplete=True` opts in to inspecting a still-streaming
    checkpoint (see `_stream_index`); the default raises on one."""
    if os.path.exists(os.path.join(directory, BSR_INDEX)):
        with open(os.path.join(directory, BSR_INDEX)) as f:
            index = json.load(f)
        if index.get("format") != "bsr":
            raise ValueError(f"{directory} is not a block-sparse checkpoint")
        return index
    if os.path.exists(os.path.join(directory, BSR_MANIFEST)):
        return _stream_index(directory, allow_incomplete=allow_incomplete)
    raise FileNotFoundError(
        f"no block-sparse checkpoint (index or manifest) in {directory}")


def load_block_sparse(directory: str, *, allow_incomplete: bool = False):
    """Returns (BlockSparseModel, meta dict). Reads both layouts: the
    one-shot artifact written by `save_block_sparse` and the multi-shard
    stream written by `BlockSparseWriter` (shards are stitched by row_ptr
    bookkeeping — no block is ever unpacked).

    `allow_incomplete=True` loads the contiguous solved prefix of a
    still-streaming checkpoint as a smaller model (first `orig_shape[0]`
    labels) — for inspection/debugging; serving always loads complete
    checkpoints (the default raises on incomplete ones)."""
    from repro.core.pruning import (BlockSparseModel,       # deferred: no
                                    concat_block_sparse)    # import cycle

    index = load_block_sparse_meta(directory,
                                   allow_incomplete=allow_incomplete)
    if index.get("layout") == "stream":
        if not index.get("batches") and not index.get("complete", True):
            raise ValueError(
                f"{directory}: no contiguous prefix of solved batches yet "
                "— nothing loadable")
        manifest = index["manifest"]
        bl, bd = manifest["block_shape"]
        parts = []
        for b in index["batches"]:
            entry = manifest["shards"][b]
            data = np.load(os.path.join(directory, entry["file"]))
            parts.append(BlockSparseModel(
                blocks=jnp.asarray(data["blocks"]),
                block_rows=jnp.asarray(data["block_rows"]),
                block_cols=jnp.asarray(data["block_cols"]),
                row_ptr=jnp.asarray(data["row_ptr"]),
                shape=(entry["padded_rows"], index["shape"][1]),
                block_shape=(bl, bd)))
        model = concat_block_sparse(parts, tuple(index["orig_shape"]))
        return model, index["meta"]
    data = np.load(os.path.join(directory, BSR_ARRAYS))
    model = BlockSparseModel(
        blocks=jnp.asarray(data["blocks"]),
        block_rows=jnp.asarray(data["block_rows"]),
        block_cols=jnp.asarray(data["block_cols"]),
        row_ptr=jnp.asarray(data["row_ptr"]),
        shape=tuple(index["shape"]),
        block_shape=tuple(index["block_shape"]),
        orig_shape=tuple(index.get("orig_shape", index["shape"])))
    return model, index["meta"]


def _stream_int8_arrays(directory: str, manifest: dict):
    """The persisted int8 block/scale arrays of a complete stream
    checkpoint, stitched in the SAME order `concat_block_sparse` packs the
    fp32 blocks (sorted batch id, first row_ptr[-1] blocks per shard), or
    None when any shard predates the int8 artifact."""
    qs, ss = [], []
    for b in sorted(manifest["shards"], key=int):
        entry = manifest["shards"][b]
        data = np.load(os.path.join(directory, entry["file"]))
        if "blocks_int8" not in data.files:
            return None
        n_p = int(np.asarray(data["row_ptr"])[-1])
        if n_p:
            qs.append(np.asarray(data["blocks_int8"])[:n_p])
            ss.append(np.asarray(data["block_scales"])[:n_p])
    if not qs:                       # fully pruned: mirror concat's sentinel
        bl, bd = manifest["block_shape"]
        return (np.zeros((1, bl, bd), np.int8), np.zeros((1,), np.float32))
    return np.concatenate(qs, axis=0), np.concatenate(ss)


def load_block_sparse_int8(directory: str, *, model=None):
    """Returns (Int8BlockSparseModel, meta dict) for either layout.

    Uses the persisted `blocks_int8` / `block_scales` arrays when the
    checkpoint carries them; legacy (pre-int8) checkpoints quantize lazily
    from the fp32 blocks — bit-identical to the persisted artifact, since
    quantization is a deterministic function of the fp32 blocks. Pass the
    already-loaded fp32 `model` to skip re-reading the block arrays (the
    serving engine loads fp32 first for the shortlist artifact anyway)."""
    from repro.core.pruning import (Int8BlockSparseModel,   # deferred: no
                                    quantize_block_sparse)  # import cycle

    index = load_block_sparse_meta(directory)
    if model is None:
        model, meta = load_block_sparse(directory)
    else:
        meta = index["meta"]

    if index.get("layout") == "stream":
        arrays = _stream_int8_arrays(directory, index["manifest"])
    else:
        data = np.load(os.path.join(directory, BSR_ARRAYS))
        arrays = ((data["blocks_int8"], data["block_scales"])
                  if "blocks_int8" in data.files else None)
    if arrays is None or arrays[0].shape[0] != model.n_blocks:
        return quantize_block_sparse(model), meta
    q, scales = arrays
    return Int8BlockSparseModel(
        blocks=jnp.asarray(q), scales=jnp.asarray(scales),
        block_rows=model.block_rows, block_cols=model.block_cols,
        row_ptr=model.row_ptr, shape=model.shape,
        block_shape=model.block_shape, orig_shape=model.orig_shape), meta


def restore_pytree(template, directory: str):
    """Restores into the structure of `template` (shapes must match)."""
    with open(os.path.join(directory, "index.json")) as f:
        index = json.load(f)
    data = np.load(os.path.join(directory, "arrays.npz"))

    flat_template, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in flat_template:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        meta = index["entries"][key]
        if meta["format"] == "coo":
            arr = np.zeros(meta["shape"], dtype=meta["dtype"])
            arr[data[f"{key}::rows"], data[f"{key}::cols"]] = \
                data[f"{key}::values"]
        else:
            arr = data[key]
        leaves.append(jnp.asarray(arr).astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)
