"""DiSMECHead — the paper's technique as a first-class extreme output layer.

Assigned architectures have vocabularies of 32k-256k: exactly XMC scale.
This module makes the LM output layer a DiSMEC one-vs-rest machine:

  * the (V, d) head weight is sharded over the mesh `model` axis — the
    paper's layer-1 label batching, as sharding;
  * training minimizes the per-label l2-regularized squared-hinge objective
    (Eq. 2.2) summed over the vocabulary. Because every label's loss touches
    only *its* weight row, a label-sharded device computes its shard's loss
    against (replicated-activation) features with NO logits collective —
    only a scalar psum. A softmax-CE head (the usual LM loss) needs a
    max+sum all-reduce over the vocab axis; the contrast is measured in
    EXPERIMENTS.md §Roofline;
  * at serving time the head is Delta-pruned (pruning.py) and evaluated with
    the block-sparse predict kernel + distributed top-k (prediction.py) —
    paper §2.2.1 as a serving feature.

Functions are pure (weights passed explicitly) so they drop into any backbone
in models/. One-positive-per-token LM targets are a special case of the
multi-hot XMC objective and are computed without materializing the (T, V)
sign matrix.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

Array = jax.Array

# Head weight partition spec: labels (vocab) over `model`, features replicated.
HEAD_PSPEC = P("model", None)


def init_head(rng: Array, vocab: int, d_model: int,
              dtype=jnp.float32) -> Array:
    scale = d_model ** -0.5
    return (jax.random.normal(rng, (vocab, d_model)) * scale).astype(dtype)


def ovr_squared_hinge_loss(W: Array, feats: Array, targets: Array,
                           *, C: float = 1.0, reg: float = 1e-6,
                           valid: Array | None = None) -> Array:
    """DiSMEC OvR loss for one-positive-per-token targets.

    W       : (V, d) head weights (label-sharded under pjit)
    feats   : (..., d) features from the backbone
    targets : (...,) int target ids
    valid   : optional (...,) 0/1 mask of real (non-pad) tokens

    For token t with target y: s_l = +1 iff l == y else -1, so

      loss_t = max(0, 1 - z_y)^2 + sum_{l != y} max(0, 1 + z_l)^2

    computed as sum_l max(0,1+z_l)^2 - max(0,1+z_y)^2 + max(0,1-z_y)^2,
    i.e. without building the (T, V) sign matrix. The l2 term ||W||^2 is the
    per-label regularizer of Eq. 2.2 (scaled by `reg` per token count).
    """
    f2 = feats.reshape(-1, feats.shape[-1]).astype(jnp.float32)
    t2 = targets.reshape(-1)
    z = f2 @ W.T.astype(jnp.float32)                       # (T, V) logits
    neg = jnp.maximum(1.0 + z, 0.0)
    neg_sum = jnp.sum(neg * neg, axis=-1)                  # all labels as negatives
    z_y = jnp.take_along_axis(z, t2[:, None], axis=1)[:, 0]
    neg_y = jnp.maximum(1.0 + z_y, 0.0)
    pos_y = jnp.maximum(1.0 - z_y, 0.0)
    per_tok = neg_sum - neg_y * neg_y + pos_y * pos_y
    if valid is not None:
        v = valid.reshape(-1).astype(jnp.float32)
        per_tok = per_tok * v
        denom = jnp.maximum(jnp.sum(v), 1.0)
    else:
        denom = per_tok.shape[0]
    l2 = reg * jnp.sum(W.astype(jnp.float32) ** 2)
    return C * jnp.sum(per_tok) / denom + l2


def ovr_multihot_loss(W: Array, feats: Array, Y: Array,
                      *, C: float = 1.0, reg: float = 1e-6) -> Array:
    """Full multi-hot XMC objective (Eq. 2.2 summed over labels).

    feats : (N, d), Y : (N, V) multi-hot. Used by the linear-XMC repro path
    (backbone = identity) and multi-label fine-tuning.
    """
    S = 2.0 * Y.astype(jnp.float32) - 1.0                  # (N, V)
    z = feats.astype(jnp.float32) @ W.T.astype(jnp.float32)
    h = jnp.maximum(1.0 - S * z, 0.0)
    l2 = reg * jnp.sum(W.astype(jnp.float32) ** 2)
    return C * jnp.mean(jnp.sum(h * h, axis=-1)) + l2


def softmax_xent_loss(W: Array, feats: Array, targets: Array,
                      valid: Array | None = None) -> Array:
    """Baseline head: standard softmax cross-entropy (needs vocab collectives
    when label-sharded — the contrast DiSMEC removes)."""
    f2 = feats.reshape(-1, feats.shape[-1]).astype(jnp.float32)
    t2 = targets.reshape(-1)
    z = f2 @ W.T.astype(jnp.float32)
    logz = jax.nn.logsumexp(z, axis=-1)
    z_y = jnp.take_along_axis(z, t2[:, None], axis=1)[:, 0]
    nll = logz - z_y
    if valid is not None:
        v = valid.reshape(-1).astype(jnp.float32)
        return jnp.sum(nll * v) / jnp.maximum(jnp.sum(v), 1.0)
    return jnp.mean(nll)
