"""Model sparsity via restricted ambiguity (paper §2.2).

Weights with |w| < Delta carry "very little discriminative information"; the
paper sets them to exact zero after training (Algorithm 1, step 7), shrinking
models ~3 orders of magnitude (870 GB -> 3 GB on WikiLSHTC-325K) with no
accuracy loss at Delta = 0.01.

On TPU we additionally convert the pruned matrix to *block*-sparse form
(BSR with MXU-aligned blocks): zero blocks are skipped entirely by the
Pallas predict kernel (kernels/bsr_predict). This is the TPU-native analogue
of the paper's sparse per-batch model files (DESIGN.md §2).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


def prune(W: Array, delta: float) -> Array:
    """Algorithm 1 step 7: zero all ambiguous weights |w| < delta."""
    return jnp.where(jnp.abs(W) < delta, 0.0, W)


def nnz(W: Array) -> Array:
    return jnp.sum((W != 0.0).astype(jnp.int32))


def sparsity(W: Array) -> Array:
    return 1.0 - nnz(W) / W.size


def ambiguous_fraction(W: Array, delta: float = 0.01) -> Array:
    """Fraction of weights in [-delta, delta] — paper reports 96% (Wiki-31K)
    and 99.5% (WikiLSHTC-325K)."""
    return jnp.mean((jnp.abs(W) < delta).astype(jnp.float32))


def weight_histogram(W: Array, bins: int = 81, lim: float = 0.2):
    """Histogram of learnt weights (paper Fig. 2a/2b)."""
    edges = jnp.linspace(-lim, lim, bins + 1)
    counts, _ = jnp.histogram(W.reshape(-1), bins=edges)
    return counts, edges


@dataclasses.dataclass
class BlockSparseModel:
    """Packed BSR representation of a pruned weight matrix.

    W (L, D) is tiled into (bl, bd) blocks; blocks that are entirely zero
    after Delta-pruning are dropped. The survivors are packed densely:

      blocks     : (n_blocks, bl, bd) packed nonzero blocks
      block_rows : (n_blocks,) label-block index of each packed block (sorted)
      block_cols : (n_blocks,) feature-block index of each packed block
      row_ptr    : (L/bl + 1,) CSR-style offsets into the packed arrays
      shape      : (Lp, Dp) block-padded shape of the packed matrix
      orig_shape : (L, D) true pre-padding shape — the labels/features that
                   actually exist; serving must never answer outside it
    """
    blocks: Array
    block_rows: Array
    block_cols: Array
    row_ptr: Array
    shape: tuple[int, int]
    block_shape: tuple[int, int]
    orig_shape: tuple[int, int] | None = None

    @property
    def n_labels(self) -> int:
        """True label count (pre-padding)."""
        return (self.orig_shape or self.shape)[0]

    @property
    def n_features(self) -> int:
        """True feature dim (pre-padding)."""
        return (self.orig_shape or self.shape)[1]

    @property
    def n_blocks(self) -> int:
        return int(self.blocks.shape[0])

    @property
    def density(self) -> float:
        bl, bd = self.block_shape
        total = (self.shape[0] // bl) * (self.shape[1] // bd)
        return self.n_blocks / max(total, 1)

    def to_dense(self) -> Array:
        # Host-side assembly into one numpy buffer: a single device transfer
        # instead of one functional full-matrix update per block (this is on
        # the dense/sharded backend load path).
        bl, bd = self.block_shape
        W = np.zeros(self.shape, np.asarray(self.blocks).dtype)
        rows = np.asarray(self.block_rows)
        cols = np.asarray(self.block_cols)
        blocks = np.asarray(self.blocks)
        for k in range(self.n_blocks):
            W[rows[k] * bl:(rows[k] + 1) * bl,
              cols[k] * bd:(cols[k] + 1) * bd] = blocks[k]
        return jnp.asarray(W)

    def quantize(self, *, device: bool = True) -> "Int8BlockSparseModel":
        """Symmetric per-block int8 artifact of this model (value payload
        ~0.25x, per-block fp32 scales riding alongside) — the `"int8"`
        serving backend's model. See `quantize_block_sparse`."""
        return quantize_block_sparse(self, device=device)

    def save(self, directory: str, *, meta: dict | None = None) -> None:
        """Persist as the serving checkpoint artifact (checkpoint/io.py) —
        the paper's offline model files, in packed BSR form."""
        from repro.checkpoint.io import save_block_sparse  # deferred: no cycle
        save_block_sparse(self, directory, meta=meta)

    @staticmethod
    def load(directory: str) -> tuple["BlockSparseModel", dict]:
        """Returns (model, meta). Inverse of `save`."""
        from repro.checkpoint.io import load_block_sparse
        return load_block_sparse(directory)


#: Symmetric int8 range: scale = max|block| / INT8_QMAX, values in
#: [-INT8_QMAX, INT8_QMAX]. -128 is never produced, so negation round-trips.
INT8_QMAX = 127


def quantize_blocks(blocks) -> tuple[np.ndarray, np.ndarray]:
    """Symmetric per-block int8 quantization of packed (nb, bl, bd) blocks.

    Returns (q, scales): q int8 with q[k] ~= blocks[k] / scales[k], scales
    float32 (nb,) with scales[k] = max|blocks[k]| / 127. Round-to-nearest-
    even (np.rint) keeps the worst-case per-element error at scales[k] / 2.
    An all-zero block (the fully-pruned sentinel) gets scale 0 and exact
    int8 zeros. Deterministic in the fp32 blocks, so lazy quantization at
    load reproduces the persisted artifact bit-for-bit.
    """
    b = np.asarray(blocks, np.float32)
    amax = np.abs(b).max(axis=(1, 2))                       # (nb,)
    scales = (amax / INT8_QMAX).astype(np.float32)
    safe = np.where(scales > 0.0, scales, 1.0)[:, None, None]
    q = np.clip(np.rint(b / safe), -INT8_QMAX, INT8_QMAX).astype(np.int8)
    return q, scales


def dequantize_blocks(q, scales) -> np.ndarray:
    """Inverse of `quantize_blocks` up to the rounding error bound."""
    return (np.asarray(q, np.float32)
            * np.asarray(scales, np.float32)[:, None, None])


@dataclasses.dataclass
class Int8BlockSparseModel:
    """Packed BSR with symmetric per-block int8 values + fp32 scales.

    The serving-side compression artifact (paper §4.2's model-size lever,
    taken one step past (value, index) pairs): each surviving (bl, bd)
    block stores int8 values and ONE fp32 scale, quartering the dominant
    payload — the predict kernel is bandwidth-bound, so HBM traffic drops
    with it. Coordinates (`block_rows` / `block_cols` / `row_ptr`) and
    shapes are shared with the fp32 `BlockSparseModel` it was quantized
    from; the int8 Pallas kernels dequantize in-register against the
    per-block scale and accumulate in fp32.
    """
    blocks: Array                     # (n_blocks, bl, bd) int8
    scales: Array                     # (n_blocks,) float32
    block_rows: Array
    block_cols: Array
    row_ptr: Array
    shape: tuple[int, int]
    block_shape: tuple[int, int]
    orig_shape: tuple[int, int] | None = None

    @property
    def n_labels(self) -> int:
        return (self.orig_shape or self.shape)[0]

    @property
    def n_features(self) -> int:
        return (self.orig_shape or self.shape)[1]

    @property
    def n_blocks(self) -> int:
        return int(self.blocks.shape[0])

    def payload_bytes(self) -> int:
        """Bytes of the quantized value payload (int8 blocks + scales) —
        what the predict kernel streams from HBM per full pass."""
        return (int(np.prod(self.blocks.shape))
                + 4 * int(self.scales.shape[0]))

    def dequantize(self) -> "BlockSparseModel":
        """Back to a float32 `BlockSparseModel` (within the rounding
        bound) — reference/debug path, never used by the serving kernels."""
        return BlockSparseModel(
            blocks=jnp.asarray(dequantize_blocks(self.blocks, self.scales)),
            block_rows=self.block_rows, block_cols=self.block_cols,
            row_ptr=self.row_ptr, shape=self.shape,
            block_shape=self.block_shape, orig_shape=self.orig_shape)


def quantize_block_sparse(model: "BlockSparseModel",
                          *, device: bool = True) -> Int8BlockSparseModel:
    """Quantize a packed fp32 model to the int8 serving artifact. The
    coordinate arrays are shared (not copied); `device=False` keeps the
    new arrays numpy for host-side checkpoint writers."""
    q, scales = quantize_blocks(model.blocks)
    put = jnp.asarray if device else np.asarray
    return Int8BlockSparseModel(
        blocks=put(q), scales=put(scales),
        block_rows=model.block_rows, block_cols=model.block_cols,
        row_ptr=model.row_ptr, shape=model.shape,
        block_shape=model.block_shape, orig_shape=model.orig_shape)


def to_block_sparse(W: Array, block_shape: tuple[int, int] = (128, 128),
                    pad_value: float = 0.0, *, row_block_offset: int = 0,
                    sentinel_if_empty: bool = True,
                    device: bool = True) -> BlockSparseModel:
    """Convert a (pruned) dense matrix to packed BSR. Host-side (numpy):
    model conversion happens once, offline, like the paper's model files.

    Append/row-offset form (streaming training, train/xmc.py): with
    `row_block_offset=k` the result describes rows [k*bl, k*bl + L) of a
    larger matrix — `block_rows` are offset into the enclosing matrix while
    `shape` and `row_ptr` stay local to this slice, so consecutive slices
    concatenate with `concat_block_sparse` without re-tiling any block.
    `sentinel_if_empty=False` lets an all-zero slice stay truly empty
    (0 packed blocks) instead of carrying the single-zero-block sentinel
    the standalone kernels expect.

    `device=False` keeps the packed arrays as numpy instead of jnp.
    The streaming checkpoint writer consumes them host-side immediately —
    and its background worker must not enqueue device puts that would
    contend with in-flight batch solves (train/xmc.py overlap mode); a
    serving-bound conversion should keep the default and land on device.
    """
    Wn = np.asarray(W)
    L, D = Wn.shape
    bl, bd = block_shape
    Lp = ((L + bl - 1) // bl) * bl
    Dp = ((D + bd - 1) // bd) * bd
    if (Lp, Dp) != (L, D):
        Wp = np.full((Lp, Dp), pad_value, Wn.dtype)
        Wp[:L, :D] = Wn
        Wn = Wp
    nbl, nbd = Lp // bl, Dp // bd
    tiles = Wn.reshape(nbl, bl, nbd, bd).transpose(0, 2, 1, 3)  # (nbl, nbd, bl, bd)
    nonzero = np.abs(tiles).max(axis=(2, 3)) > 0.0              # (nbl, nbd)
    rows, cols = np.nonzero(nonzero)                            # row-major sorted
    blocks = tiles[rows, cols]                                  # (n_blocks, bl, bd)
    counts = np.bincount(rows, minlength=nbl)
    row_ptr = np.concatenate([[0], np.cumsum(counts)]).astype(np.int32)
    if blocks.shape[0] == 0 and sentinel_if_empty:              # fully pruned
        blocks = np.zeros((1, bl, bd), Wn.dtype)
        rows = np.zeros((1,), np.int64)
        cols = np.zeros((1,), np.int64)
        row_ptr = np.zeros(nbl + 1, np.int32)
    put = jnp.asarray if device else np.asarray
    return BlockSparseModel(
        blocks=put(blocks),
        block_rows=put((rows + row_block_offset).astype(np.int32)),
        block_cols=put(cols.astype(np.int32)),
        row_ptr=put(row_ptr),
        shape=(Lp, Dp), block_shape=block_shape, orig_shape=(L, D))


def concat_block_sparse(parts: list[BlockSparseModel],
                        orig_shape: tuple[int, int]) -> BlockSparseModel:
    """Stack per-batch BSR slices (append form, consecutive row ranges) into
    one servable model without touching any packed block.

    Every part must have been produced by `to_block_sparse(...,
    row_block_offset=<its global start block>)` with the same block shape,
    the same (padded) feature width, and row-block-aligned starts — exactly
    what the streaming trainer emits. The merge is pure bookkeeping:
    blocks/rows/cols concatenate, and each part's local row_ptr is shifted
    by the packed-block count of everything before it.
    """
    if not parts:
        raise ValueError("concat_block_sparse needs at least one part")
    bl, bd = parts[0].block_shape
    Dp = parts[0].shape[1]
    blocks, rows, cols, row_ptr = [], [], [], [np.zeros(1, np.int32)]
    row_block_off = 0
    n_packed = 0
    for p in parts:
        if p.block_shape != (bl, bd) or p.shape[1] != Dp:
            raise ValueError("parts disagree on block shape / feature width")
        p_rows = np.asarray(p.block_rows, np.int64)
        p_ptr = np.asarray(p.row_ptr, np.int64)
        n_p = int(p_ptr[-1])            # packed blocks (0 for empty parts;
        if n_p:                         # the sentinel would report ptr[-1]=0)
            if p_rows[0] < row_block_off:
                raise ValueError("part rows overlap the previous part")
            blocks.append(np.asarray(p.blocks)[:n_p])
            rows.append(p_rows[:n_p])
            cols.append(np.asarray(p.block_cols, np.int64)[:n_p])
        row_ptr.append(p_ptr[1:] + n_packed)
        n_packed += n_p
        row_block_off += p.shape[0] // bl
    L, D = orig_shape
    Lp, Dp_full = row_block_off * bl, Dp
    if Lp < L or Dp_full < D:
        raise ValueError(f"parts cover ({Lp}, {Dp_full}), need {orig_shape}")
    if n_packed == 0:                                           # fully pruned
        return BlockSparseModel(
            blocks=jnp.zeros((1, bl, bd), jnp.float32),
            block_rows=jnp.zeros((1,), jnp.int32),
            block_cols=jnp.zeros((1,), jnp.int32),
            row_ptr=jnp.zeros(row_block_off + 1, jnp.int32),
            shape=(Lp, Dp_full), block_shape=(bl, bd), orig_shape=orig_shape)
    return BlockSparseModel(
        blocks=jnp.asarray(np.concatenate(blocks, axis=0)),
        block_rows=jnp.asarray(np.concatenate(rows), jnp.int32),
        block_cols=jnp.asarray(np.concatenate(cols), jnp.int32),
        row_ptr=jnp.asarray(np.concatenate(row_ptr), jnp.int32),
        shape=(Lp, Dp_full), block_shape=(bl, bd), orig_shape=orig_shape)
