"""Batched trust-region Newton (TRON) solver for DiSMEC's per-label problems.

Liblinear solves each binary problem with TRON [Lin, Weng, Keerthi 2008]:
an outer trust-region Newton loop whose steps are computed by Steihaug-Toint
truncated conjugate gradient on the generalized Hessian. The paper trains one
label per core; here an entire label shard is solved by ONE batched TRON loop
— every per-label scalar of the classical algorithm (trust radius Delta_l,
CG residuals, convergence flag) becomes a vector of length L, and converged
labels turn into masked no-ops instead of exiting (DESIGN.md §2, "SIMT-style").

This file is deliberately independent of how the data is laid out: callers
pass `obj_grad_fn(W) -> (f, grad, act_aux)` and `hvp_fn(V, act_aux) -> H V`,
so dismec.py can inject replicated-X, data-sharded (psum) or Pallas-kernel
implementations without touching the optimizer. All control flow is jax.lax
so the whole solve jits/shards.

Margin-caching protocol
-----------------------
The generalized Hessian H_l = 2I + 2C X^T D_l X is constant throughout one
Newton step: D_l = diag(active mask at the CURRENT iterate W). The scores
`W @ X.T` that determine that mask are already computed by `obj_grad_fn`,
so the solver threads its third return value — `act_aux`, an opaque
active-set payload whose leaves lead with the label axis — through the
Newton carry and hands it back to every `hvp_fn` call. CG therefore runs
ONE (L, N)-shaped score pass per iteration (the X v contraction) instead
of two (mask re-derivation + X v), and the quadratic-model `H d` reuses the
same cached mask. On a rejected trust-region step the cached `act_aux` of
the incumbent W is kept; on acceptance it is swapped for the one
`obj_grad_fn(W_try)` just produced — bit-identical to re-deriving the mask
from W at every use, minus the redundant matmuls.
"""

from __future__ import annotations

from functools import partial
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array

# Liblinear's trust-region constants.
ETA0, ETA1, ETA2 = 1e-4, 0.25, 0.75
SIGMA1, SIGMA2, SIGMA3 = 0.25, 0.5, 4.0


class TronResult(NamedTuple):
    W: Array            # (L, D) solution
    f: Array            # (L,) final objective
    gnorm: Array        # (L,) final gradient norm
    n_newton: Array     # (L,) newton iterations used
    n_cg: Array         # (L,) total CG iterations used
    converged: Array    # (L,) bool


def _boundary_tau(d: Array, p: Array, delta: Array) -> Array:
    """Smallest tau >= 0 with ||d + tau p|| = delta, batched over labels.

    Solves ||p||^2 tau^2 + 2<d,p> tau + ||d||^2 - delta^2 = 0 per label.
    """
    pp = jnp.sum(p * p, axis=-1)
    dp = jnp.sum(d * p, axis=-1)
    dd = jnp.sum(d * d, axis=-1)
    rad = jnp.sqrt(jnp.maximum(dp * dp + pp * (delta * delta - dd), 0.0))
    # Numerically stable positive root.
    tau = jnp.where(dp >= 0.0,
                    (delta * delta - dd) / (dp + rad + 1e-38),
                    (rad - dp) / (pp + 1e-38))
    return jnp.maximum(tau, 0.0)


def _steihaug_cg(hvp: Callable[[Array], Array], g: Array, delta: Array,
                 cg_tol: Array, max_cg: int, live: Array):
    """Batched Steihaug-Toint CG: approximately solve H d = -g, ||d|| <= delta.

    live : (L,) labels still being optimized; dead labels do no work (their
           updates are masked to zero, the loop still runs lockstep).
    Returns (d, iters_used_per_label).
    """
    L = g.shape[0]
    d0 = jnp.zeros_like(g)
    r0 = -g
    p0 = r0
    rtr0 = jnp.sum(r0 * r0, axis=-1)
    done0 = ~live  # dead labels are born done
    iters0 = jnp.zeros((L,), jnp.int32)

    def cond(state):
        _, _, _, _, done, _, k = state
        return (k < max_cg) & (~jnp.all(done))

    def body(state):
        d, r, p, rtr, done, iters, k = state
        Hp = hvp(p)                                  # (L, D) one batched matmul chain
        pHp = jnp.sum(p * Hp, axis=-1)
        alpha = rtr / jnp.where(pHp != 0.0, pHp, 1.0)
        neg_curv = pHp <= 0.0

        d_try = d + alpha[:, None] * p
        over = jnp.sqrt(jnp.sum(d_try * d_try, axis=-1)) >= delta
        hit_boundary = (neg_curv | over) & (~done)

        tau = _boundary_tau(d, p, delta)
        d_bound = d + tau[:, None] * p

        d_new = jnp.where(done[:, None], d,
                          jnp.where(hit_boundary[:, None], d_bound, d_try))
        r_new = jnp.where((done | hit_boundary)[:, None], r, r - alpha[:, None] * Hp)
        rtr_new = jnp.sum(r_new * r_new, axis=-1)
        small = jnp.sqrt(rtr_new) <= cg_tol
        done_new = done | hit_boundary | small

        beta = rtr_new / jnp.where(rtr != 0.0, rtr, 1.0)
        p_new = jnp.where(done_new[:, None], p, r_new + beta[:, None] * p)
        iters_new = iters + (~done).astype(jnp.int32)
        return d_new, r_new, p_new, rtr_new, done_new, iters_new, k + 1

    d, _, _, _, _, iters, _ = jax.lax.while_loop(
        cond, body, (d0, r0, p0, rtr0, done0, iters0, jnp.int32(0)))
    return d, iters


def _select_aux(accept: Array, new, old):
    """Per-label select over an opaque active-set payload: every leaf is
    assumed to lead with the label axis (the shape `accept` indexes)."""
    def sel(a, b):
        acc = accept.reshape(accept.shape + (1,) * (a.ndim - 1))
        return jnp.where(acc, a, b)
    return jax.tree_util.tree_map(sel, new, old)


@partial(jax.jit, static_argnames=("obj_grad_fn", "hvp_fn",
                                   "max_newton", "max_cg"))
def tron_solve(obj_grad_fn: Callable[[Array], tuple[Array, Array, Array]],
               hvp_fn: Callable[[Array, Array], Array],
               W0: Array,
               *,
               eps: float = 0.01,
               max_newton: int = 50,
               max_cg: int = 40,
               gnorm_ref: Array | None = None) -> TronResult:
    """Solve min_w f_l(w_l) for all labels l at once.

    obj_grad_fn(W) -> (f, grad, act_aux): objective, gradient, and the
        active-set payload at W (usually the (L, N) mask; opaque here, its
        leaves must lead with the label axis). Cached and threaded to every
        Hessian product at the same iterate — see module docstring.
    hvp_fn(V, act_aux) -> H V using the cached active set.
    eps: relative gradient-norm tolerance, ||g|| <= eps * ||g_0|| (liblinear).
    gnorm_ref: optional (L,) anchor for the relative tolerance in place of
        ||g(W0)||. A warm-started solve (W0 from a prior checkpoint) must
        keep the COLD-start stopping rule — eps * ||g(0)|| — or the
        shrunken warm gradient would tighten the tolerance and drive every
        already-converged label through extra Newton steps.
    """
    L = W0.shape[0]
    f0, g0, act0 = obj_grad_fn(W0)
    gnorm0 = jnp.linalg.norm(g0, axis=-1)
    delta0 = gnorm0                           # liblinear: Delta_0 = ||g_0||
    gref = gnorm0 if gnorm_ref is None else gnorm_ref
    tol = eps * gref

    def cond(state):
        _, _, _, _, gnorm, _, live, _, _, k = state
        del gnorm
        return (k < max_newton) & jnp.any(live)

    def body(state):
        W, act, f, g, gnorm, delta, live, n_newton, n_cg, k = state
        cg_tol = jnp.minimum(0.1, jnp.sqrt(gnorm / (gref + 1e-38))) * gnorm
        d, cg_iters = _steihaug_cg(lambda V: hvp_fn(V, act),
                                   g, delta, cg_tol, max_cg, live)

        W_try = W + d
        f_try, g_try, act_try = obj_grad_fn(W_try)

        # Quadratic-model decrease: -(<g,d> + 0.5 <d, H d>), H at W (cached).
        Hd = hvp_fn(d, act)
        pred = -(jnp.sum(g * d, axis=-1) + 0.5 * jnp.sum(d * Hd, axis=-1))
        actual = f - f_try
        rho = actual / jnp.where(pred != 0.0, pred, 1.0)

        accept = (rho > ETA0) & live
        dnorm = jnp.linalg.norm(d, axis=-1)

        # Trust-radius update (liblinear schedule).
        delta_new = jnp.where(rho < ETA0, SIGMA1 * jnp.minimum(dnorm, delta),
                     jnp.where(rho < ETA1, jnp.maximum(SIGMA1 * delta,
                                                       SIGMA2 * dnorm),
                      jnp.where(rho < ETA2, delta,
                                jnp.maximum(delta, SIGMA3 * dnorm))))
        delta_new = jnp.where(live, delta_new, delta)

        W_new = jnp.where(accept[:, None], W_try, W)
        act_new = _select_aux(accept, act_try, act)
        f_new = jnp.where(accept, f_try, f)
        g_new = jnp.where(accept[:, None], g_try, g)
        gnorm_new = jnp.linalg.norm(g_new, axis=-1)
        live_new = live & (gnorm_new > tol)
        # A label that entered this body live did one more Newton iteration;
        # labels that converged earlier are masked no-ops and must not count
        # (same per-label accounting as n_cg).
        return (W_new, act_new, f_new, g_new, gnorm_new, delta_new, live_new,
                n_newton + live.astype(jnp.int32), n_cg + cg_iters, k + 1)

    live0 = gnorm0 > tol
    init = (W0, act0, f0, g0, gnorm0, delta0, live0,
            jnp.zeros((L,), jnp.int32), jnp.zeros((L,), jnp.int32),
            jnp.int32(0))
    W, _, f, g, gnorm, _, live, n_newton, n_cg, _ = jax.lax.while_loop(
        cond, body, init)
    return TronResult(W=W, f=f, gnorm=gnorm, n_newton=n_newton,
                      n_cg=n_cg, converged=~live)
