"""DiSMEC core: distributed sparse one-vs-rest machines (the paper's contribution)."""

from repro.core.dismec import (DiSMECConfig, DiSMECModel,
                               available_solver_ops, make_batch_solver,
                               register_solver_ops, signs_from_labels, train,
                               train_label_batch, train_sharded,
                               unregister_solver_ops)
from repro.core.pruning import (ambiguous_fraction, concat_block_sparse, nnz,
                                prune, sparsity, to_block_sparse,
                                weight_histogram, BlockSparseModel)
from repro.core.prediction import (evaluate, ndcg_at_k, precision_at_k,
                                   predict_scores, predict_topk,
                                   predict_topk_sharded)
from repro.core import head, losses, tron

__all__ = [
    "DiSMECConfig", "DiSMECModel", "signs_from_labels", "train",
    "train_label_batch", "train_sharded", "make_batch_solver",
    "register_solver_ops", "unregister_solver_ops", "available_solver_ops",
    "prune",
    "nnz", "sparsity", "ambiguous_fraction", "weight_histogram",
    "to_block_sparse", "concat_block_sparse",
    "BlockSparseModel", "predict_scores", "predict_topk",
    "predict_topk_sharded", "precision_at_k", "ndcg_at_k", "evaluate",
    "head", "losses", "tron",
]
