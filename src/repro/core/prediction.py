"""Backend-agnostic XMC scoring + ranking metrics (paper §2.2.1, §3.2).

This module is the *scoring layer* of the serving subsystem: pure functions
from (X, W) to scores / top-k, with no request-side machinery. The serving
engine (`repro.serve.xmc`) wraps these behind a common `PredictBackend`
protocol — `predict_topk` backs the dense backend, `predict_topk_sharded`
backs the mesh-sharded backend, and the block-sparse Pallas path lives in
`repro.kernels.bsr_predict`.

The paper stores the per-batch block matrices W^1..W^B on separate nodes and
evaluates <w_l, x> for all blocks in parallel, then merges to a top-k. On the
mesh, W is label-sharded over `model`; each device computes its shard's
scores, takes a *local* top-k, and only the (k x n_shards) candidates are
gathered and merged — never the full L-dimensional score vector. That is the
collective-frugal form of the paper's distributed prediction.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map

Array = jax.Array


def predict_scores(X: Array, W: Array) -> Array:
    """Dense score matrix (n, L) = X @ W^T."""
    return X @ W.T


def predict_topk(X: Array, W: Array, k: int = 5) -> tuple[Array, Array]:
    """Top-k labels per test instance. Returns (scores, indices), (n, k)."""
    return jax.lax.top_k(predict_scores(X, W), k)


def predict_topk_sharded(X: Array, W: Array, k: int, mesh: Mesh,
                         *, label_axis: str = "model",
                         n_labels: int | None = None) -> tuple[Array, Array]:
    """Label-sharded distributed prediction with local-topk + global merge.

    X : (n, D) replicated test batch, W : (L, D) with L divisible by shard
    count. `n_labels` masks padding rows (label id >= n_labels) out of the
    merge so a row-padded W never serves phantom labels.
    """
    n_shards = mesh.shape[label_axis]
    L = W.shape[0]
    assert L % n_shards == 0, "pad labels before sharding"
    shard_size = L // n_shards

    def shard_fn(X_sh, W_sh):
        scores = X_sh @ W_sh.T                             # (n, L/shard)
        offset = jax.lax.axis_index(label_axis) * shard_size
        if n_labels is not None and n_labels < L:
            local_ids = offset + jnp.arange(shard_size)
            scores = jnp.where(local_ids[None, :] < n_labels, scores,
                               jnp.float32(-3.0e38))
        s_loc, i_loc = jax.lax.top_k(scores, k)            # local top-k
        # Globalize label indices of this shard.
        i_loc = i_loc + offset
        # Merge across shards: gather k*n_shards candidates, re-top-k.
        s_all = jax.lax.all_gather(s_loc, label_axis, axis=1, tiled=True)
        i_all = jax.lax.all_gather(i_loc, label_axis, axis=1, tiled=True)
        s_top, pos = jax.lax.top_k(s_all, k)
        i_top = jnp.take_along_axis(i_all, pos, axis=1)
        return s_top, i_top

    fn = shard_map(shard_fn, mesh=mesh,
                   in_specs=(P(), P(label_axis, None)),
                   out_specs=(P(), P()), check_vma=False)
    return fn(X, W)


# ---------------------------------------------------------------------------
# Metrics (paper §3.2). Y_true is (n, L) multi-hot; topk_idx is (n, k).
# ---------------------------------------------------------------------------

def precision_at_k(Y_true: Array, topk_idx: Array, k: int) -> Array:
    """P@k = (1/k) sum_{l in rank_k(yhat)} y_l   (averaged over instances)."""
    hits = jnp.take_along_axis(Y_true, topk_idx[:, :k], axis=1)
    return jnp.mean(jnp.sum(hits, axis=1) / k)


def ndcg_at_k(Y_true: Array, topk_idx: Array, k: int) -> Array:
    """nDCG@k with the paper's normalization: DCG@k / sum_{l=1..min(k,|y|)} 1/log2(l+1)."""
    hits = jnp.take_along_axis(Y_true, topk_idx[:, :k], axis=1)     # (n, k)
    ranks = jnp.arange(1, k + 1, dtype=jnp.float32)
    dcg = jnp.sum(hits / jnp.log2(ranks + 1.0), axis=1)
    n_pos = jnp.sum(Y_true, axis=1)
    denom_terms = 1.0 / jnp.log2(ranks + 1.0)
    cum = jnp.cumsum(denom_terms)
    idx = jnp.clip(jnp.minimum(n_pos, k).astype(jnp.int32) - 1, 0, k - 1)
    norm = cum[idx]
    return jnp.mean(jnp.where(n_pos > 0, dcg / norm, 0.0))


def evaluate(Y_true: Array, topk_idx: Array,
             ks: tuple[int, ...] = (1, 3, 5)) -> dict[str, float]:
    out = {}
    for k in ks:
        out[f"P@{k}"] = float(precision_at_k(Y_true, topk_idx, k))
        out[f"nDCG@{k}"] = float(ndcg_at_k(Y_true, topk_idx, k))
    return out
