"""DiSMEC training: double layer of parallelization, in JAX.

Paper Algorithm 1, re-mapped to a TPU mesh (DESIGN.md §2):

  layer 1 — label batches over nodes  ->  label axis sharded over the mesh
            `model` axis with shard_map; each device owns an L/n_model shard.
            For label sets larger than fits in memory at once, an outer
            *sequential* loop over label batches (paper's `for b in 0..B`)
            wraps the sharded solve, exactly like the paper's node dispatch.
  layer 2 — one label per OpenMP core ->  the per-device shard is solved by
            ONE batched TRON loop (core/tron.py) driving the MXU.

X is never replicated per label (paper §2.1): every binary problem shares the
same device buffer. Beyond the paper, `shard_data=True` additionally shards
the *instance* axis over the mesh `data` axis and reconstitutes gradients /
Hessian-vector products with `psum` — the collective-based Newton-CG the
paper could not express on a CPU cluster.

Layer 1's sequential batch loop itself lives in train/xmc.py
(`XMCTrainJob`) under the declarative session API (repro.xmc_api.fit):
`train` and `train_sharded` here are thin adapters over that one spec
path, and this module contributes the layer-2 engine (`make_batch_solver`,
warm-startable via a per-batch W0) every path shares. The obj-grad/Hv
implementations live in a solver-ops registry (`register_solver_ops`):
"jnp" and "pallas" are built in, and `SolverSpec(ops=...)` /
`DiSMECConfig(ops=...)` select plugins without touching the optimizer.

All three injection sites — the jnp losses path, the Pallas-kernel path
(`use_pallas=True`, interpret/compiled auto-selected per backend via
`cfg.pallas_interpret=None`), and the data-sharded psum closures — speak
core/tron.py's margin-caching protocol: `obj_grad(W) -> (f, grad, act)`
derives the active mask from the one score pass it already ran, and
`hvp(V, act)` consumes that cached mask, so no CG iteration ever re-runs
the (L, D) x (D, N) score matmul just to rebuild the active set.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map
from repro.core import losses
from repro.core.tron import TronResult, tron_solve
from repro.core.pruning import prune

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class DiSMECConfig:
    """Hyper-parameters of Algorithm 1."""
    C: float = 1.0               # error/regularization trade-off (Eq. 2.2)
    delta: float = 0.01          # ambiguity threshold Delta (paper fixes 0.01)
    eps: float = 0.01            # TRON relative gradient tolerance
    max_newton: int = 50
    max_cg: int = 40
    label_batch: int = 1000      # paper's per-node batch size (layer 1)
    use_pallas: bool = False     # route obj/grad + Hv through Pallas kernels
    # Pallas execution mode: None auto-selects per backend (compiled Mosaic
    # on TPU, interpreter elsewhere — compat.default_pallas_interpret);
    # True/False force it. Only consulted when use_pallas=True.
    pallas_interpret: Optional[bool] = None
    # Solver-ops registry kind (see `register_solver_ops`). None derives the
    # kind from `use_pallas` ("pallas"/"jnp"); a registered plugin name
    # routes obj/grad + Hv through that factory instead.
    ops: Optional[str] = None

    def ops_kind(self) -> str:
        return self.ops or ("pallas" if self.use_pallas else "jnp")


# ---------------------------------------------------------------------------
# Solver-ops registry: how obj/grad + Hv are computed for a label batch.
# ---------------------------------------------------------------------------

# kind -> factory(X, S, cfg) -> (obj_grad, hvp) speaking the margin-caching
# protocol: obj_grad(W) -> (f, grad, act_aux), hvp(V, act_aux) -> H V.
SOLVER_OPS: dict[str, Callable] = {}


def register_solver_ops(kind: str):
    """Decorator: plug a new obj-grad/Hv implementation into the solver.

    The factory receives (X (N, D), S (L, N), cfg: DiSMECConfig) and must
    return the margin-caching protocol pair (see core/tron.py). Select it
    with `DiSMECConfig(ops=kind)` / `SolverSpec(ops=kind)` — no engine or
    scheduler code needs touching.
    """
    def deco(factory):
        if kind in SOLVER_OPS:
            raise ValueError(f"solver ops {kind!r} already registered")
        SOLVER_OPS[kind] = factory
        return factory
    return deco


def unregister_solver_ops(kind: str) -> None:
    """Remove a registered solver-ops kind (plugin teardown / tests)."""
    SOLVER_OPS.pop(kind, None)


def available_solver_ops() -> tuple[str, ...]:
    return tuple(sorted(SOLVER_OPS))


@register_solver_ops("jnp")
def _jnp_solver_ops(X: Array, S: Array, cfg: "DiSMECConfig"):
    obj_grad = lambda W: losses.objective_grad_act(W, X, S, cfg.C)
    hvp = lambda V, act: losses.hessian_vp(V, X, act, cfg.C)
    return obj_grad, hvp


@register_solver_ops("pallas")
def _pallas_solver_ops(X: Array, S: Array, cfg: "DiSMECConfig"):
    from repro.kernels.hinge import ops as hinge_ops
    from repro.kernels.hvp import ops as hvp_ops
    interp = cfg.pallas_interpret
    obj_grad = lambda W: hinge_ops.objective_grad_act(
        W, X, S, cfg.C, interpret=interp)
    hvp = lambda V, act: hvp_ops.hessian_vp(V, X, act, cfg.C,
                                            interpret=interp)
    return obj_grad, hvp


@dataclasses.dataclass
class DiSMECModel:
    """Learnt matrix W_{L,D} (paper notation transposed: we store (L, D)).

    Stored pruned: exact zeros where |w| < delta. `blocks` mirrors the paper's
    per-batch block matrices W^1..W^B used for distributed prediction.
    """
    W: Array                    # (L, D), pruned
    delta: float
    n_labels: int               # true L before padding

    @property
    def nnz(self) -> int:
        return int(jnp.sum(self.W != 0.0))

    def size_bytes(self, bytes_per_weight: int = 8) -> int:
        """Sparse storage cost: (value, index) pairs, as the paper counts."""
        return self.nnz * bytes_per_weight

    def dense_size_bytes(self, bytes_per_weight: int = 4) -> int:
        return self.W.shape[0] * self.W.shape[1] * bytes_per_weight


def signs_from_labels(Y: Array) -> Array:
    """Y (N, L) in {0,1}  ->  S (L, N) in {+1,-1} (paper's s_l vectors)."""
    return (2.0 * Y.T - 1.0).astype(jnp.float32)


def _make_fns(X: Array, S: Array, cfg: "DiSMECConfig"):
    """The margin-caching TRON protocol pair (core/tron.py): obj_grad(W) ->
    (f, grad, act) and hvp(V, act), built by the registered solver-ops
    factory `cfg.ops_kind()` names. The active mask is produced by the same
    score pass that computes f/grad — on the Pallas path it streams out of
    the fused hinge kernel tile-by-tile, so no separate mask matmul exists
    anywhere."""
    kind = cfg.ops_kind()
    try:
        factory = SOLVER_OPS[kind]
    except KeyError:
        raise ValueError(f"unknown solver ops {kind!r}; registered kinds: "
                         f"{available_solver_ops()}") from None
    return factory(X, S, cfg)


# ---------------------------------------------------------------------------
# Single-host solve (used per label batch, and as the shard body).
# ---------------------------------------------------------------------------

def train_label_batch(X: Array, S: Array, cfg: DiSMECConfig,
                      W0: Optional[Array] = None) -> TronResult:
    """Solve all labels in S at once (layer-2 parallelism).

    A non-None W0 is treated as a warm start: the relative stopping rule
    is anchored at the cold-start gradient ||g(0)|| (one extra obj/grad
    evaluation), not at the warm iterate's already-small ||g(W0)|| —
    otherwise the tolerance would tighten and drive converged labels
    through pointless extra Newton steps.
    """
    L, _ = S.shape
    D = X.shape[1]
    obj_grad, hvp = _make_fns(X, S, cfg)
    gnorm_ref = None
    if W0 is None:
        W0 = jnp.zeros((L, D), jnp.float32)
    else:
        _, g_zero, _ = obj_grad(jnp.zeros_like(W0))
        gnorm_ref = jnp.linalg.norm(g_zero, axis=-1)
    return tron_solve(obj_grad, hvp, W0, eps=cfg.eps,
                      max_newton=cfg.max_newton, max_cg=cfg.max_cg,
                      gnorm_ref=gnorm_ref)


def train(X: Array, Y: Array, cfg: DiSMECConfig = DiSMECConfig()) -> DiSMECModel:
    """Algorithm 1 on one device: sequential label batches (layer 1),
    batched TRON per batch (layer 2), Delta-pruning per batch (step 7).

    Thin adapter over the one spec-driven session path (repro.xmc_api):
    the config becomes an `XMCSpec` and runs through the same scheduler
    `fit()` drives, with the in-memory assembly step 11. Use
    `repro.xmc_api.fit(X, Y, spec, out_dir)` instead to stream the batches
    straight to a servable sparse checkpoint and never assemble W at all.
    """
    from repro.xmc_api import spec_from_config, job_from_spec   # no cycle
    return job_from_spec(spec_from_config(cfg)).run(X, Y).model


# ---------------------------------------------------------------------------
# Mesh-sharded solve: labels over `model`, optionally instances over `data`.
# ---------------------------------------------------------------------------

def balance_permutation(Y: Array, n_shards: int) -> np.ndarray:
    """Frequency-balanced label->shard assignment (beyond paper, DESIGN §2).

    The batched TRON loop runs until the SLOWEST label of a shard converges;
    head labels (many positives, many active-set flips) take more Newton
    steps than tail labels (1-3). Sorting labels by frequency and dealing
    them round-robin gives every shard the same head/tail mix, so shard
    wall-times equalize. Returns a permutation `perm` such that label
    perm[i] goes to slot i (shards are contiguous slot blocks)."""
    counts = np.asarray(Y).sum(axis=0)
    order = np.argsort(-counts, kind="stable")       # head labels first
    L = len(order)
    per = (L + n_shards - 1) // n_shards
    # Greedy capacity-constrained balancing (LPT scheduling): biggest label
    # first, always into the lightest shard with room. Round-robin dealing
    # is not enough under Eq. 1.1 — the rank-1 label alone outweighs whole
    # shards (measured 4.9x vs 53x naive; greedy gets <2x).
    mass = np.zeros(n_shards)
    members: list[list[int]] = [[] for _ in range(n_shards)]
    for lab in order:
        open_shards = [s for s in range(n_shards) if len(members[s]) < per]
        s = min(open_shards, key=lambda i: (mass[i], i))
        members[s].append(int(lab))
        mass[s] += counts[lab]
    perm = np.asarray([lab for m in members for lab in m], dtype=np.int64)
    return perm


def make_batch_solver(X: Array, cfg: DiSMECConfig, mesh: Optional[Mesh] = None,
                      *, label_axis: str = "model", data_axis: str = "data",
                      shard_data: bool = False, warm: bool = False):
    """Layer 2 of Algorithm 1 as a reusable jitted solver: (S (rows, N),
    W0 (rows, D) or None) -> Delta-pruned W (rows, D), rows a multiple of
    the label-shard count when a mesh is given. The one code path behind
    `train`, `train_sharded` and the streaming scheduler (train/xmc.py) —
    the scheduler keeps every label batch the same padded shape so all
    batches share one executable.

    mesh=None        : single-device batched TRON.
    shard_data=False : paper-faithful — X replicated per label-shard "node".
    shard_data=True  : beyond-paper — X sharded over `data`, grad/Hv psum'd.
                       N not divisible by the data axis is handled by padding
                       X with zero rows and S with all-negative sign columns:
                       a zero instance contributes nothing to the gradient or
                       the Hessian-vector product (every term carries a factor
                       of x = 0), and its constant C contribution to the
                       squared-hinge objective (z = 1 - s*0 = 1, active) is
                       subtracted back out after the psum, so the padded
                       objective is exactly the unpadded one.
    warm=True        : the returned solver expects warm-start W0s (a prior
                       checkpoint's rows) and anchors TRON's relative
                       stopping rule at ||g(0)|| — the cold-start tolerance
                       — via one extra obj/grad evaluation at W=0 per batch.
                       Without the anchor a warm W0's small gradient would
                       TIGHTEN the tolerance and un-converge every label.
    """
    X = jnp.asarray(X, jnp.float32)
    D = X.shape[1]

    def run_tron(obj_grad, hvp, W0: Array) -> Array:
        ref = None
        if warm:
            _, g_zero, _ = obj_grad(jnp.zeros_like(W0))
            ref = jnp.linalg.norm(g_zero, axis=-1)
        res = tron_solve(obj_grad, hvp, W0, eps=cfg.eps,
                         max_newton=cfg.max_newton, max_cg=cfg.max_cg,
                         gnorm_ref=ref)
        return prune(res.W, cfg.delta)                  # step 7 on-device

    def solve_local(X_in: Array, S_in: Array, W0: Array) -> Array:
        obj_grad, hvp = _make_fns(X_in, S_in, cfg)
        return run_tron(obj_grad, hvp, W0)

    if mesh is None:
        # X stays a traced argument (not a captured constant): XLA would
        # otherwise try to constant-fold whole X contractions at compile.
        jitted = jax.jit(solve_local)

        def solve_single(S: Array, W0: Optional[Array] = None) -> Array:
            if W0 is None:
                W0 = jnp.zeros((S.shape[0], D), jnp.float32)
            return jitted(X, S, W0)
        return solve_single

    n_pad = 0
    if not shard_data:
        s_spec = P(label_axis, None)
        x_spec = P()                                    # replicated
    else:
        n_data = mesh.shape[data_axis]
        N = X.shape[0]
        n_pad = (-N) % n_data                           # instance padding
        if n_pad:
            X = jnp.concatenate(
                [X, jnp.zeros((n_pad, D), X.dtype)], axis=0)
        s_spec = P(label_axis, data_axis)
        x_spec = P(data_axis, None)

    def solve_shard(X_sh: Array, S_sh: Array, W0_sh: Array) -> Array:
        if shard_data:
            # Margin-caching protocol over the data axis: the act payload is
            # the LOCAL (rows, N/n_data) mask of this shard's instance slice
            # — the Hv psum reconstitutes the global product from the cached
            # local masks, so CG does one local score pass per iteration.
            def obj_grad(W):
                scores = W @ X_sh.T
                z = 1.0 - S_sh * scores
                act = (z > 0.0).astype(scores.dtype)
                r = act * (scores - S_sh)
                f_loc = cfg.C * jnp.sum(act * z * z, axis=-1)
                g_loc = 2.0 * cfg.C * (r @ X_sh)
                f = (jnp.sum(W * W, axis=-1)
                     + jax.lax.psum(f_loc, data_axis) - cfg.C * n_pad)
                g = 2.0 * W + jax.lax.psum(g_loc, data_axis)
                return f, g, act

            def hvp(V, act):
                Xv = V @ X_sh.T
                loc = 2.0 * cfg.C * ((act * Xv) @ X_sh)
                return 2.0 * V + jax.lax.psum(loc, data_axis)

            return run_tron(obj_grad, hvp, W0_sh)
        return solve_local(X_sh, S_sh, W0_sh)

    shmapped = shard_map(solve_shard, mesh=mesh,
                         in_specs=(x_spec, s_spec, P(label_axis, None)),
                         out_specs=P(label_axis, None), check_vma=False)

    def solve(X_in: Array, S: Array, W0: Array) -> Array:
        if n_pad:
            S = jnp.concatenate(
                [S, -jnp.ones((S.shape[0], n_pad), S.dtype)], axis=1)
        return shmapped(X_in, S, W0)

    jitted = jax.jit(solve)

    def solve_meshed(S: Array, W0: Optional[Array] = None) -> Array:
        if W0 is None:
            W0 = jnp.zeros((S.shape[0], D), jnp.float32)
        return jitted(X, S, W0)
    return solve_meshed


def train_sharded(X: Array, Y: Array, cfg: DiSMECConfig, mesh: Mesh,
                  *, label_axis: str = "model", data_axis: str = "data",
                  shard_data: bool = False,
                  balance: bool = False) -> DiSMECModel:
    """Double parallelization on a mesh (paper layer 1 == label sharding).

    Thin wrapper over the batch-scheduler code path (train/xmc.py): the
    outer label-batch loop (cfg.label_batch) wraps the mesh-sharded solve,
    exactly like the paper's node dispatch — the old one-shot behaviour is
    cfg.label_batch >= n_labels.

    shard_data=False : paper-faithful — X replicated per label-shard "node".
    shard_data=True  : beyond-paper — X sharded over `data`, grad/Hv psum'd
                       (non-divisible N handled by zero-instance padding,
                       see `make_batch_solver`).
    balance=True     : beyond-paper — frequency-balanced label shards
                       (equalizes per-shard TRON wall time; solution is
                       identical, labels are permuted and un-permuted).
    """
    from repro.xmc_api import spec_from_config, job_from_spec   # no cycle
    spec = spec_from_config(cfg, label_axis=label_axis, data_axis=data_axis,
                            shard_data=shard_data, balance=balance)
    return job_from_spec(spec, mesh=mesh).run(X, Y).model
