"""DiSMEC training: double layer of parallelization, in JAX.

Paper Algorithm 1, re-mapped to a TPU mesh (DESIGN.md §2):

  layer 1 — label batches over nodes  ->  label axis sharded over the mesh
            `model` axis with shard_map; each device owns an L/n_model shard.
            For label sets larger than fits in memory at once, an outer
            *sequential* loop over label batches (paper's `for b in 0..B`)
            wraps the sharded solve, exactly like the paper's node dispatch.
  layer 2 — one label per OpenMP core ->  the per-device shard is solved by
            ONE batched TRON loop (core/tron.py) driving the MXU.

X is never replicated per label (paper §2.1): every binary problem shares the
same device buffer. Beyond the paper, `shard_data=True` additionally shards
the *instance* axis over the mesh `data` axis and reconstitutes gradients /
Hessian-vector products with `psum` — the collective-based Newton-CG the
paper could not express on a CPU cluster.

Layer 1's sequential batch loop itself lives in train/xmc.py
(`XMCTrainJob`): `train` and `train_sharded` here are thin wrappers over
that one scheduler, and this module contributes the layer-2 engine
(`make_batch_solver`) every path shares.

All three injection sites — the jnp losses path, the Pallas-kernel path
(`use_pallas=True`, interpret/compiled auto-selected per backend via
`cfg.pallas_interpret=None`), and the data-sharded psum closures — speak
core/tron.py's margin-caching protocol: `obj_grad(W) -> (f, grad, act)`
derives the active mask from the one score pass it already ran, and
`hvp(V, act)` consumes that cached mask, so no CG iteration ever re-runs
the (L, D) x (D, N) score matmul just to rebuild the active set.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map
from repro.core import losses
from repro.core.tron import TronResult, tron_solve
from repro.core.pruning import prune

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class DiSMECConfig:
    """Hyper-parameters of Algorithm 1."""
    C: float = 1.0               # error/regularization trade-off (Eq. 2.2)
    delta: float = 0.01          # ambiguity threshold Delta (paper fixes 0.01)
    eps: float = 0.01            # TRON relative gradient tolerance
    max_newton: int = 50
    max_cg: int = 40
    label_batch: int = 1000      # paper's per-node batch size (layer 1)
    use_pallas: bool = False     # route obj/grad + Hv through Pallas kernels
    # Pallas execution mode: None auto-selects per backend (compiled Mosaic
    # on TPU, interpreter elsewhere — compat.default_pallas_interpret);
    # True/False force it. Only consulted when use_pallas=True.
    pallas_interpret: Optional[bool] = None


@dataclasses.dataclass
class DiSMECModel:
    """Learnt matrix W_{L,D} (paper notation transposed: we store (L, D)).

    Stored pruned: exact zeros where |w| < delta. `blocks` mirrors the paper's
    per-batch block matrices W^1..W^B used for distributed prediction.
    """
    W: Array                    # (L, D), pruned
    delta: float
    n_labels: int               # true L before padding

    @property
    def nnz(self) -> int:
        return int(jnp.sum(self.W != 0.0))

    def size_bytes(self, bytes_per_weight: int = 8) -> int:
        """Sparse storage cost: (value, index) pairs, as the paper counts."""
        return self.nnz * bytes_per_weight

    def dense_size_bytes(self, bytes_per_weight: int = 4) -> int:
        return self.W.shape[0] * self.W.shape[1] * bytes_per_weight


def signs_from_labels(Y: Array) -> Array:
    """Y (N, L) in {0,1}  ->  S (L, N) in {+1,-1} (paper's s_l vectors)."""
    return (2.0 * Y.T - 1.0).astype(jnp.float32)


def _make_fns(X: Array, S: Array, cfg: "DiSMECConfig"):
    """The margin-caching TRON protocol pair (core/tron.py): obj_grad(W) ->
    (f, grad, act) and hvp(V, act). The active mask is produced by the same
    score pass that computes f/grad — on the Pallas path it streams out of
    the fused hinge kernel tile-by-tile, so no separate mask matmul exists
    anywhere."""
    C = cfg.C
    if cfg.use_pallas:
        from repro.kernels.hinge import ops as hinge_ops
        from repro.kernels.hvp import ops as hvp_ops
        interp = cfg.pallas_interpret
        obj_grad = lambda W: hinge_ops.objective_grad_act(
            W, X, S, C, interpret=interp)
        hvp = lambda V, act: hvp_ops.hessian_vp(V, X, act, C,
                                                interpret=interp)
    else:
        obj_grad = lambda W: losses.objective_grad_act(W, X, S, C)
        hvp = lambda V, act: losses.hessian_vp(V, X, act, C)
    return obj_grad, hvp


# ---------------------------------------------------------------------------
# Single-host solve (used per label batch, and as the shard body).
# ---------------------------------------------------------------------------

def train_label_batch(X: Array, S: Array, cfg: DiSMECConfig,
                      W0: Optional[Array] = None) -> TronResult:
    """Solve all labels in S at once (layer-2 parallelism)."""
    L, _ = S.shape
    D = X.shape[1]
    if W0 is None:
        W0 = jnp.zeros((L, D), jnp.float32)
    obj_grad, hvp = _make_fns(X, S, cfg)
    return tron_solve(obj_grad, hvp, W0, eps=cfg.eps,
                      max_newton=cfg.max_newton, max_cg=cfg.max_cg)


def train(X: Array, Y: Array, cfg: DiSMECConfig = DiSMECConfig()) -> DiSMECModel:
    """Algorithm 1 on one device: sequential label batches (layer 1),
    batched TRON per batch (layer 2), Delta-pruning per batch (step 7).

    Thin wrapper over the one batch-scheduler code path (train/xmc.py,
    `XMCTrainJob`) with the in-memory assembly step 11; pass the job an
    output directory instead to stream the batches straight to a sparse
    multi-shard checkpoint and never assemble W at all.
    """
    from repro.train.xmc import XMCTrainJob           # deferred: no cycle
    return XMCTrainJob(cfg=cfg).run(X, Y).model


# ---------------------------------------------------------------------------
# Mesh-sharded solve: labels over `model`, optionally instances over `data`.
# ---------------------------------------------------------------------------

def balance_permutation(Y: Array, n_shards: int) -> np.ndarray:
    """Frequency-balanced label->shard assignment (beyond paper, DESIGN §2).

    The batched TRON loop runs until the SLOWEST label of a shard converges;
    head labels (many positives, many active-set flips) take more Newton
    steps than tail labels (1-3). Sorting labels by frequency and dealing
    them round-robin gives every shard the same head/tail mix, so shard
    wall-times equalize. Returns a permutation `perm` such that label
    perm[i] goes to slot i (shards are contiguous slot blocks)."""
    counts = np.asarray(Y).sum(axis=0)
    order = np.argsort(-counts, kind="stable")       # head labels first
    L = len(order)
    per = (L + n_shards - 1) // n_shards
    # Greedy capacity-constrained balancing (LPT scheduling): biggest label
    # first, always into the lightest shard with room. Round-robin dealing
    # is not enough under Eq. 1.1 — the rank-1 label alone outweighs whole
    # shards (measured 4.9x vs 53x naive; greedy gets <2x).
    mass = np.zeros(n_shards)
    members: list[list[int]] = [[] for _ in range(n_shards)]
    for lab in order:
        open_shards = [s for s in range(n_shards) if len(members[s]) < per]
        s = min(open_shards, key=lambda i: (mass[i], i))
        members[s].append(int(lab))
        mass[s] += counts[lab]
    perm = np.asarray([lab for m in members for lab in m], dtype=np.int64)
    return perm


def make_batch_solver(X: Array, cfg: DiSMECConfig, mesh: Optional[Mesh] = None,
                      *, label_axis: str = "model", data_axis: str = "data",
                      shard_data: bool = False):
    """Layer 2 of Algorithm 1 as a reusable jitted solver: S (rows, N) ->
    Delta-pruned W (rows, D), rows a multiple of the label-shard count when
    a mesh is given. The one code path behind `train`, `train_sharded` and
    the streaming scheduler (train/xmc.py) — the scheduler keeps every label
    batch the same padded shape so all batches share one executable.

    mesh=None        : single-device batched TRON.
    shard_data=False : paper-faithful — X replicated per label-shard "node".
    shard_data=True  : beyond-paper — X sharded over `data`, grad/Hv psum'd.
                       N not divisible by the data axis is handled by padding
                       X with zero rows and S with all-negative sign columns:
                       a zero instance contributes nothing to the gradient or
                       the Hessian-vector product (every term carries a factor
                       of x = 0), and its constant C contribution to the
                       squared-hinge objective (z = 1 - s*0 = 1, active) is
                       subtracted back out after the psum, so the padded
                       objective is exactly the unpadded one.
    """
    X = jnp.asarray(X, jnp.float32)
    D = X.shape[1]

    def solve_local(X_in: Array, S_in: Array) -> Array:
        obj_grad, hvp = _make_fns(X_in, S_in, cfg)
        W0 = jnp.zeros((S_in.shape[0], D), jnp.float32)
        res = tron_solve(obj_grad, hvp, W0, eps=cfg.eps,
                         max_newton=cfg.max_newton, max_cg=cfg.max_cg)
        return prune(res.W, cfg.delta)                  # step 7 on-device

    if mesh is None:
        # X stays a traced argument (not a captured constant): XLA would
        # otherwise try to constant-fold whole X contractions at compile.
        jitted = jax.jit(solve_local)
        return lambda S: jitted(X, S)

    n_pad = 0
    if not shard_data:
        s_spec = P(label_axis, None)
        x_spec = P()                                    # replicated
    else:
        n_data = mesh.shape[data_axis]
        N = X.shape[0]
        n_pad = (-N) % n_data                           # instance padding
        if n_pad:
            X = jnp.concatenate(
                [X, jnp.zeros((n_pad, D), X.dtype)], axis=0)
        s_spec = P(label_axis, data_axis)
        x_spec = P(data_axis, None)

    def solve_shard(X_sh: Array, S_sh: Array) -> Array:
        if shard_data:
            # Margin-caching protocol over the data axis: the act payload is
            # the LOCAL (rows, N/n_data) mask of this shard's instance slice
            # — the Hv psum reconstitutes the global product from the cached
            # local masks, so CG does one local score pass per iteration.
            def obj_grad(W):
                scores = W @ X_sh.T
                z = 1.0 - S_sh * scores
                act = (z > 0.0).astype(scores.dtype)
                r = act * (scores - S_sh)
                f_loc = cfg.C * jnp.sum(act * z * z, axis=-1)
                g_loc = 2.0 * cfg.C * (r @ X_sh)
                f = (jnp.sum(W * W, axis=-1)
                     + jax.lax.psum(f_loc, data_axis) - cfg.C * n_pad)
                g = 2.0 * W + jax.lax.psum(g_loc, data_axis)
                return f, g, act

            def hvp(V, act):
                Xv = V @ X_sh.T
                loc = 2.0 * cfg.C * ((act * Xv) @ X_sh)
                return 2.0 * V + jax.lax.psum(loc, data_axis)

            W0 = jnp.zeros((S_sh.shape[0], D), jnp.float32)
            res = tron_solve(obj_grad, hvp, W0, eps=cfg.eps,
                             max_newton=cfg.max_newton, max_cg=cfg.max_cg)
            return prune(res.W, cfg.delta)
        return solve_local(X_sh, S_sh)

    shmapped = shard_map(solve_shard, mesh=mesh, in_specs=(x_spec, s_spec),
                         out_specs=P(label_axis, None), check_vma=False)

    def solve(X_in: Array, S: Array) -> Array:
        if n_pad:
            S = jnp.concatenate(
                [S, -jnp.ones((S.shape[0], n_pad), S.dtype)], axis=1)
        return shmapped(X_in, S)

    jitted = jax.jit(solve)
    return lambda S: jitted(X, S)


def train_sharded(X: Array, Y: Array, cfg: DiSMECConfig, mesh: Mesh,
                  *, label_axis: str = "model", data_axis: str = "data",
                  shard_data: bool = False,
                  balance: bool = False) -> DiSMECModel:
    """Double parallelization on a mesh (paper layer 1 == label sharding).

    Thin wrapper over the batch-scheduler code path (train/xmc.py): the
    outer label-batch loop (cfg.label_batch) wraps the mesh-sharded solve,
    exactly like the paper's node dispatch — the old one-shot behaviour is
    cfg.label_batch >= n_labels.

    shard_data=False : paper-faithful — X replicated per label-shard "node".
    shard_data=True  : beyond-paper — X sharded over `data`, grad/Hv psum'd
                       (non-divisible N handled by zero-instance padding,
                       see `make_batch_solver`).
    balance=True     : beyond-paper — frequency-balanced label shards
                       (equalizes per-shard TRON wall time; solution is
                       identical, labels are permuted and un-permuted).
    """
    from repro.train.xmc import XMCTrainJob           # deferred: no cycle
    job = XMCTrainJob(cfg=cfg, mesh=mesh, label_axis=label_axis,
                      data_axis=data_axis, shard_data=shard_data,
                      balance=balance)
    return job.run(X, Y).model
