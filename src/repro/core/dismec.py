"""DiSMEC training: double layer of parallelization, in JAX.

Paper Algorithm 1, re-mapped to a TPU mesh (DESIGN.md §2):

  layer 1 — label batches over nodes  ->  label axis sharded over the mesh
            `model` axis with shard_map; each device owns an L/n_model shard.
            For label sets larger than fits in memory at once, an outer
            *sequential* loop over label batches (paper's `for b in 0..B`)
            wraps the sharded solve, exactly like the paper's node dispatch.
  layer 2 — one label per OpenMP core ->  the per-device shard is solved by
            ONE batched TRON loop (core/tron.py) driving the MXU.

X is never replicated per label (paper §2.1): every binary problem shares the
same device buffer. Beyond the paper, `shard_data=True` additionally shards
the *instance* axis over the mesh `data` axis and reconstitutes gradients /
Hessian-vector products with `psum` — the collective-based Newton-CG the
paper could not express on a CPU cluster.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.core import losses
from repro.core.tron import TronResult, tron_solve
from repro.core.pruning import prune

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class DiSMECConfig:
    """Hyper-parameters of Algorithm 1."""
    C: float = 1.0               # error/regularization trade-off (Eq. 2.2)
    delta: float = 0.01          # ambiguity threshold Delta (paper fixes 0.01)
    eps: float = 0.01            # TRON relative gradient tolerance
    max_newton: int = 50
    max_cg: int = 40
    label_batch: int = 1000      # paper's per-node batch size (layer 1)
    use_pallas: bool = False     # route obj/grad + Hv through Pallas kernels


@dataclasses.dataclass
class DiSMECModel:
    """Learnt matrix W_{L,D} (paper notation transposed: we store (L, D)).

    Stored pruned: exact zeros where |w| < delta. `blocks` mirrors the paper's
    per-batch block matrices W^1..W^B used for distributed prediction.
    """
    W: Array                    # (L, D), pruned
    delta: float
    n_labels: int               # true L before padding

    @property
    def nnz(self) -> int:
        return int(jnp.sum(self.W != 0.0))

    def size_bytes(self, bytes_per_weight: int = 8) -> int:
        """Sparse storage cost: (value, index) pairs, as the paper counts."""
        return self.nnz * bytes_per_weight

    def dense_size_bytes(self, bytes_per_weight: int = 4) -> int:
        return self.W.shape[0] * self.W.shape[1] * bytes_per_weight


def signs_from_labels(Y: Array) -> Array:
    """Y (N, L) in {0,1}  ->  S (L, N) in {+1,-1} (paper's s_l vectors)."""
    return (2.0 * Y.T - 1.0).astype(jnp.float32)


def _make_fns(X: Array, S: Array, C: float, use_pallas: bool = False):
    if use_pallas:
        from repro.kernels.hinge import ops as hinge_ops
        from repro.kernels.hvp import ops as hvp_ops
        obj_grad = lambda W: hinge_ops.objective_and_grad(W, X, S, C)
        hvp = lambda V, act: hvp_ops.hessian_vp(V, X, act, C)
    else:
        obj_grad = lambda W: losses.objective_and_grad(W, X, S, C)
        hvp = lambda V, act: losses.hessian_vp(V, X, act, C)
    act = lambda W: losses.active_mask(W, X, S)
    return obj_grad, hvp, act


# ---------------------------------------------------------------------------
# Single-host solve (used per label batch, and as the shard body).
# ---------------------------------------------------------------------------

def train_label_batch(X: Array, S: Array, cfg: DiSMECConfig,
                      W0: Optional[Array] = None) -> TronResult:
    """Solve all labels in S at once (layer-2 parallelism)."""
    L, _ = S.shape
    D = X.shape[1]
    if W0 is None:
        W0 = jnp.zeros((L, D), jnp.float32)
    obj_grad, hvp, act = _make_fns(X, S, cfg.C, cfg.use_pallas)
    return tron_solve(obj_grad, hvp, act, W0, eps=cfg.eps,
                      max_newton=cfg.max_newton, max_cg=cfg.max_cg)


def train(X: Array, Y: Array, cfg: DiSMECConfig = DiSMECConfig()) -> DiSMECModel:
    """Algorithm 1 on one device: sequential label batches (layer 1),
    batched TRON per batch (layer 2), Delta-pruning per batch (step 7)."""
    N, L = Y.shape
    S_full = signs_from_labels(Y)                     # (L, N)
    B = L // cfg.label_batch + (1 if L % cfg.label_batch else 0)
    blocks = []
    for b in range(B):                                # paper's step 3 loop
        S = S_full[b * cfg.label_batch:(b + 1) * cfg.label_batch]
        res = train_label_batch(X, S, cfg)
        blocks.append(prune(res.W, cfg.delta))        # step 7: model reduction
    W = jnp.concatenate(blocks, axis=0)               # step 11: assemble W_{D,L}
    return DiSMECModel(W=W, delta=cfg.delta, n_labels=L)


# ---------------------------------------------------------------------------
# Mesh-sharded solve: labels over `model`, optionally instances over `data`.
# ---------------------------------------------------------------------------

def _pad_labels(S: Array, n_shards: int) -> tuple[Array, int]:
    L = S.shape[0]
    Lp = ((L + n_shards - 1) // n_shards) * n_shards
    if Lp != L:
        # Padding labels have all-negative sign vectors; their solution is
        # w = 0 (objective minimized at 0 when no positives and C small) —
        # they converge instantly and are sliced away afterwards.
        pad = -jnp.ones((Lp - L, S.shape[1]), S.dtype)
        S = jnp.concatenate([S, pad], axis=0)
    return S, Lp


def balance_permutation(Y: Array, n_shards: int) -> np.ndarray:
    """Frequency-balanced label->shard assignment (beyond paper, DESIGN §2).

    The batched TRON loop runs until the SLOWEST label of a shard converges;
    head labels (many positives, many active-set flips) take more Newton
    steps than tail labels (1-3). Sorting labels by frequency and dealing
    them round-robin gives every shard the same head/tail mix, so shard
    wall-times equalize. Returns a permutation `perm` such that label
    perm[i] goes to slot i (shards are contiguous slot blocks)."""
    counts = np.asarray(Y).sum(axis=0)
    order = np.argsort(-counts, kind="stable")       # head labels first
    L = len(order)
    per = (L + n_shards - 1) // n_shards
    # Greedy capacity-constrained balancing (LPT scheduling): biggest label
    # first, always into the lightest shard with room. Round-robin dealing
    # is not enough under Eq. 1.1 — the rank-1 label alone outweighs whole
    # shards (measured 4.9x vs 53x naive; greedy gets <2x).
    mass = np.zeros(n_shards)
    members: list[list[int]] = [[] for _ in range(n_shards)]
    for lab in order:
        open_shards = [s for s in range(n_shards) if len(members[s]) < per]
        s = min(open_shards, key=lambda i: (mass[i], i))
        members[s].append(int(lab))
        mass[s] += counts[lab]
    perm = np.asarray([lab for m in members for lab in m], dtype=np.int64)
    return perm


def train_sharded(X: Array, Y: Array, cfg: DiSMECConfig, mesh: Mesh,
                  *, label_axis: str = "model", data_axis: str = "data",
                  shard_data: bool = False,
                  balance: bool = False) -> DiSMECModel:
    """Double parallelization on a mesh (paper layer 1 == label sharding).

    shard_data=False : paper-faithful — X replicated per label-shard "node".
    shard_data=True  : beyond-paper — X sharded over `data`, grad/Hv psum'd.
    balance=True     : beyond-paper — frequency-balanced label shards
                       (equalizes per-shard TRON wall time; solution is
                       identical, labels are permuted and un-permuted).
    """
    S_full = signs_from_labels(Y)
    n_label_shards = mesh.shape[label_axis]
    perm = None
    if balance:
        perm = balance_permutation(Y, n_label_shards)
        S_full = S_full[jnp.asarray(perm)]
    S_pad, Lp = _pad_labels(S_full, n_label_shards)
    D = X.shape[1]

    if not shard_data:
        s_spec = P(label_axis, None)
        x_spec = P()                                    # replicated
    else:
        n_data = mesh.shape[data_axis]
        assert X.shape[0] % n_data == 0, "N must divide data axis for psum path"
        s_spec = P(label_axis, data_axis)
        x_spec = P(data_axis, None)

    def solve_shard(X_sh: Array, S_sh: Array) -> Array:
        if shard_data:
            def obj_grad(W):
                scores = W @ X_sh.T
                z = 1.0 - S_sh * scores
                act = (z > 0.0).astype(scores.dtype)
                r = act * (scores - S_sh)
                f_loc = cfg.C * jnp.sum(act * z * z, axis=-1)
                g_loc = 2.0 * cfg.C * (r @ X_sh)
                f = jnp.sum(W * W, axis=-1) + jax.lax.psum(f_loc, data_axis)
                g = 2.0 * W + jax.lax.psum(g_loc, data_axis)
                return f, g

            def hvp(V, act):
                Xv = V @ X_sh.T
                loc = 2.0 * cfg.C * ((act * Xv) @ X_sh)
                return 2.0 * V + jax.lax.psum(loc, data_axis)

            def act_fn(W):
                return (1.0 - S_sh * (W @ X_sh.T) > 0.0).astype(jnp.float32)
        else:
            obj_grad, hvp, act_fn = _make_fns(X_sh, S_sh, cfg.C, cfg.use_pallas)

        W0 = jnp.zeros((S_sh.shape[0], D), jnp.float32)
        res = tron_solve(obj_grad, hvp, act_fn, W0, eps=cfg.eps,
                         max_newton=cfg.max_newton, max_cg=cfg.max_cg)
        return prune(res.W, cfg.delta)                  # step 7 on-device

    in_specs = (x_spec, s_spec)
    out_specs = P(label_axis, None)
    solve = shard_map(solve_shard, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_vma=False)
    W = solve(jnp.asarray(X, jnp.float32), S_pad)[: S_full.shape[0]]
    if perm is not None:
        inv = np.argsort(perm)                      # undo the permutation
        W = W[jnp.asarray(inv)]
    return DiSMECModel(W=W, delta=cfg.delta, n_labels=Y.shape[1])
