"""Objective/gradient/Hessian-vector products for DiSMEC's per-label solves.

The paper (Eq. 2.2) trains, for every label l, an l2-regularized
squared-hinge binary SVM over the shared design matrix X:

    f(w) = ||w||^2 + C * sum_i max(0, 1 - s_i w^T x_i)^2

All quantities here are *batched over labels*: weights have shape (L, D) and
sign matrices (L, N) (or (N, L) transposed views), so a whole label shard is
driven through the MXU at once — this is the paper's "one label per core"
layer-2 parallelism recast as matmul batching (DESIGN.md §2).

Conventions
-----------
X : (N, D) dense design matrix (replicated or data-sharded; see dismec.py)
S : (L, N) sign matrix in {+1, -1}
W : (L, D) weight matrix, one row per label
All math is done in f32 accumulation regardless of input dtype.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def margins(W: Array, X: Array, S: Array) -> Array:
    """z_{l,i} = 1 - s_{l,i} * <w_l, x_i>   of shape (L, N)."""
    scores = W @ X.T  # (L, N)
    return 1.0 - S * scores


def active_mask(W: Array, X: Array, S: Array) -> Array:
    """Active set I_l = {i : z_{l,i} > 0} as a float mask, (L, N)."""
    return (margins(W, X, S) > 0.0).astype(jnp.float32)


def objective(W: Array, X: Array, S: Array, C: float) -> Array:
    """f(w_l) per label, shape (L,)."""
    z = margins(W, X, S)
    hinge = jnp.maximum(z, 0.0)
    return jnp.sum(W * W, axis=-1) + C * jnp.sum(hinge * hinge, axis=-1)


def objective_grad_act(W: Array, X: Array, S: Array,
                       C: float) -> tuple[Array, Array, Array]:
    """Returns (f, grad, act) with f:(L,), grad:(L, D), act:(L, N).

    grad f(w_l) = 2 w_l + 2C X_I^T (X_I w_l - s_I)
                = 2 w_l - 2C sum_{i in I} s_i z_i x_i      [since s_i^2 = 1]
    (the paper quotes the gradient of f/2; we optimize f itself — same argmin).

    The third output is the active mask D_l already derived from the same
    score pass — the margin-caching TRON protocol (core/tron.py) threads it
    to every Hessian-vector product at this iterate so CG never re-runs the
    (L, D) x (D, N) score matmul just to rebuild the mask.
    """
    scores = W @ X.T                       # (L, N)
    z = 1.0 - S * scores                   # margins
    act = (z > 0.0).astype(scores.dtype)   # active mask
    # residual r_{l,i} = act * (score - s) = -act * s * z  (since s^2=1)
    r = act * (scores - S)                 # (L, N)
    f = jnp.sum(W * W, axis=-1) + C * jnp.sum(act * z * z, axis=-1)
    grad = 2.0 * W + 2.0 * C * (r @ X)     # (L, D)
    return f, grad, act


def objective_and_grad(W: Array, X: Array, S: Array, C: float) -> tuple[Array, Array]:
    """(f, grad) only — see `objective_grad_act` for the solver-facing form
    that also returns the active mask it derived along the way."""
    f, grad, _ = objective_grad_act(W, X, S, C)
    return f, grad


def hessian_vp(V: Array, X: Array, act: Array, C: float) -> Array:
    """Generalized-Hessian vector product, batched over labels.

    H_l = 2 I + 2C X^T D_l X  with D_l = diag(active mask for label l);
    Hv_l = 2 v_l + 2C X^T (act_l * (X v_l)).

    V   : (L, D) directions
    act : (L, N) active mask captured at the current Newton iterate
    """
    Xv = V @ X.T                # (L, N)
    return 2.0 * V + 2.0 * C * ((act * Xv) @ X)


def l1_objective_smooth_part(W: Array, X: Array, S: Array, C: float) -> Array:
    """Smooth part of the l1-SVM baseline objective: C * sum hinge^2 (no reg)."""
    z = margins(W, X, S)
    hinge = jnp.maximum(z, 0.0)
    return C * jnp.sum(hinge * hinge, axis=-1)


def l1_grad_smooth_part(W: Array, X: Array, S: Array, C: float) -> Array:
    """Gradient of the smooth part for proximal-gradient l1-SVM."""
    scores = W @ X.T
    z = 1.0 - S * scores
    act = (z > 0.0).astype(scores.dtype)
    r = act * (scores - S)
    return 2.0 * C * (r @ X)


def soft_threshold(W: Array, tau: float) -> Array:
    """Prox of tau*||.||_1 — used by the l1-SVM baseline."""
    return jnp.sign(W) * jnp.maximum(jnp.abs(W) - tau, 0.0)
