"""One declarative XMC API: spec-driven fit -> checkpoint -> serve sessions.

DiSMEC's pipeline is one conceptual object — double-parallel OvR training
with capacity control, a sparse model artifact, and fast sparse prediction
— and this module gives it one public surface:

    from repro.specs import ScheduleSpec, ServeSpec, SolverSpec
    from repro.xmc_api import XMCSpec, CheckpointHandle, fit

    spec = XMCSpec(solver=SolverSpec(C=1.0, delta=0.01),
                   schedule=ScheduleSpec(label_batch=256),
                   serve=ServeSpec(backend="bsr", k=5))
    handle = fit(X, Y, spec, "/ckpts/wiki31k")     # train -> sparse ckpt
    engine = handle.engine()                       # serve as the spec says
    results = engine.serve(requests)

`XMCSpec` is frozen and JSON-round-trippable; `fit` embeds it in the BSR
checkpoint manifest (the solver/schedule halves as the resume fingerprint,
the whole spec as recoverable metadata), so

    handle = CheckpointHandle.open("/ckpts/wiki31k")
    assert handle.spec == spec                     # the manifest IS the spec

re-opens a checkpoint with its full experiment description — no side
channel. Warm starting is a spec-level operation too::

    fit(X, Y, spec.replace(solver=spec.solver.replace(delta=0.02)),
        "/ckpts/wiki31k-d02", init_from="/ckpts/wiki31k")

seeds every label batch's TRON from the prior checkpoint's rows (shards
mapped back to label ranges, never the full matrix). The paper's layer-1
distribution over nodes is a session-level operation as well: launch the
same `fit(X, Y, spec, out_dir, worker=...)` in N plain processes (any
hosts that share the filesystem) and they cooperatively drain the
label-batch queue through the manifest's lease table into one checkpoint
— see `ScheduleSpec.workers` / `lease_ttl`. Solver-ops and
predict backends resolve through decorator registries
(`repro.core.dismec.register_solver_ops`,
`repro.serve.xmc.register_backend`), so new kernel stacks and new serving
backends plug in without touching this module.

`core.dismec.train/train_sharded`, `train.xmc.train_streaming`, both CLIs
(`launch/train.py --xmc`, `launch/serve.py --xmc`) and the benchmarks are
thin adapters over this one session path.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import numpy as np

from repro.specs import ScheduleSpec, ServeSpec, SolverSpec
from repro.specs.base import Spec

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class XMCSpec(Spec):
    """The whole experiment as one frozen, serializable value.

    solver   — what is solved per label (C, Delta, eps, ops kind).
    schedule — how the label space is walked and sharded (label_batch,
               mesh, balancing, double-buffering).
    serve    — how the resulting checkpoint is served (backend kind, k,
               buckets, Pallas mode).
    """
    solver: SolverSpec = SolverSpec()
    schedule: ScheduleSpec = ScheduleSpec()
    serve: ServeSpec = ServeSpec()

    def validate(self) -> "XMCSpec":
        self.solver.validate()
        self.schedule.validate()
        self.serve.validate()
        return self

    def normalized(self) -> "XMCSpec":
        """Validated spec with the schedule's label_batch rounded up to a
        BSR-block multiple (warns when it changes)."""
        self.validate()
        schedule = self.schedule.normalized()
        return self if schedule is self.schedule else dataclasses.replace(
            self, schedule=schedule)

    def canonical(self) -> "XMCSpec":
        """The manifest-stored form: runtime scheduling knobs (overlap /
        max_inflight) reset to defaults, so checkpoint bytes never depend
        on host-loop buffering. `CheckpointHandle.open` recovers this
        form."""
        return dataclasses.replace(self, schedule=self.schedule.canonical())


def spec_from_config(cfg, *, label_axis: str = "model",
                     data_axis: str = "data", shard_data: bool = False,
                     balance: bool = False,
                     serve: Optional[ServeSpec] = None) -> XMCSpec:
    """Adapter: a legacy `DiSMECConfig` (+ sharding kwargs) as an XMCSpec."""
    return XMCSpec(
        solver=SolverSpec.from_config(cfg),
        schedule=ScheduleSpec(label_batch=cfg.label_batch,
                              label_axis=label_axis, data_axis=data_axis,
                              shard_data=shard_data, balance=balance),
        serve=serve or ServeSpec())


def job_from_spec(spec: XMCSpec, *, mesh=None):
    """Build the streaming training engine (`XMCTrainJob`) a spec names.

    `mesh` overrides the schedule's declarative mesh with an existing
    device mesh (the legacy `train_sharded` path); otherwise the mesh is
    constructed from `spec.schedule.mesh`.
    """
    from repro.train.xmc import XMCTrainJob           # deferred: no cycle
    sch = spec.schedule
    return XMCTrainJob(
        cfg=spec.solver.to_config(label_batch=sch.label_batch),
        mesh=mesh if mesh is not None else sch.make_mesh(),
        label_axis=sch.label_axis, data_axis=sch.data_axis,
        shard_data=sch.shard_data, balance=sch.balance,
        block_shape=tuple(sch.block_shape), overlap=sch.overlap,
        max_inflight=sch.max_inflight, workers=sch.workers,
        lease_ttl=sch.lease_ttl)


def fit(X: Array, Y: Array, spec: XMCSpec, out_dir: str, *,
        init_from: Optional[str] = None, resume: bool = True,
        max_batches: Optional[int] = None, meta: Optional[dict] = None,
        on_batch: Optional[Callable[[int, int], None]] = None,
        worker: Optional[str] = None,
        ) -> "CheckpointHandle":
    """Train X (N, D), Y (N, L) under `spec` into a servable sparse
    checkpoint at `out_dir`; returns the handle to serve or re-open it.

    The spec is normalized first (label_batch rounded up to a BSR-block
    multiple with a warning — never a hard failure), embedded in the
    manifest, and enforced on resume: a second `fit` into the same
    directory with a different solver/schedule spec or different data
    raises instead of stitching incompatible shards.

    init_from : prior checkpoint directory — warm-start every label
                batch's TRON from its rows (the ROADMAP warm-start: e.g.
                re-train with a new Delta or C from existing weights).
                A converged checkpoint of the same spec is a fixed point:
                the warm fit reproduces it bit-identically.
    resume    : skip batches already in out_dir's manifest (False starts
                the checkpoint fresh).
    max_batches / on_batch : preemption bound and per-batch callback,
                passed through to the engine (`XMCTrainJob.run`).
    worker    : identity of this process in a cooperative multi-host
                drain (paper layer 1 over real nodes): N `fit()` calls on
                the same `out_dir` — same canonical spec, same data, any
                mix of hosts — claim label batches through the manifest's
                lease table and write ONE checkpoint, bit-identical to a
                single-worker run. Defaults to host-pid when
                `spec.schedule.workers > 1`; the manifest fingerprint
                rejects a co-worker whose spec or data disagrees. Each
                worker sees the job through: with nothing left to claim it
                waits for co-workers' commits (reclaiming their batches if
                their leases expire — dead workers recover automatically),
                so on a normal return `result.complete` is True; it is
                False only when `max_batches` stopped this worker early.

    Two spec knobs act at fit time beyond the solve itself:
    `schedule.reorder_labels` packs the label space under the deterministic
    co-occurrence permutation (trained as `Y[:, order]`, recorded in the
    manifest, unmapped exactly at serve time), and
    `serve.shortlist_kind != "centroid"` replaces the finalize-time
    centroid shortlist with a learned one-vs-rest meta-classifier or a
    routing tree built from the run's own training data (the only moment
    it is in scope). Both builders are deterministic, so cooperative
    workers racing the upgrade write identical bytes.
    """
    spec = spec.normalized()
    job = job_from_spec(spec)
    label_order = None
    if spec.schedule.reorder_labels:
        from repro.serve.shortlist import cooccurrence_label_order
        label_order = cooccurrence_label_order(
            np.asarray(Y), block_rows=int(spec.schedule.block_shape[0]))
    res = job.run(X, Y, out_dir, resume=resume, init_from=init_from,
                  max_batches=max_batches, on_batch=on_batch, worker=worker,
                  label_order=label_order,
                  meta={**(meta or {}),
                        "xmc_spec": spec.canonical().to_dict()})
    if res.complete and spec.serve.shortlist_kind != "centroid":
        _upgrade_coarse_stage(out_dir, spec, X, Y, label_order)
    return CheckpointHandle(directory=out_dir, spec=spec, result=res)


def _upgrade_coarse_stage(out_dir: str, spec: XMCSpec, X, Y,
                          label_order) -> None:
    """Swap the finalize-time centroid shortlist for the coarse artifact
    `spec.serve.shortlist_kind` names, trained from the run's own data.

    Runs after `try_finalize` because the training data is only in scope
    here — the writer's finalize path (which any co-worker may win) knows
    nothing about X/Y and always leaves the free centroid artifact; this
    upgrade then replaces it under the manifest lock. Y is permuted into
    packed label order first, so block membership matches the rows the
    checkpoint actually holds."""
    from repro.checkpoint.io import load_block_sparse, upgrade_shortlist
    from repro.serve.shortlist import (build_learned_shortlist,
                                       build_tree_shortlist)
    model, _ = load_block_sparse(out_dir)
    Yn = np.asarray(Y)
    if label_order is not None:
        Yn = Yn[:, np.asarray(label_order)]
    build = (build_learned_shortlist
             if spec.serve.shortlist_kind == "learned"
             else build_tree_shortlist)
    upgrade_shortlist(out_dir, build(model, np.asarray(X), Yn))


def _spec_from_index(index: dict) -> XMCSpec:
    """Recover the spec from a checkpoint's index/manifest: the embedded
    `xmc_spec` when present, else a best-effort rebuild from the legacy
    fingerprint keys (pre-spec checkpoints), else defaults."""
    meta = index.get("meta", {})
    if "xmc_spec" in meta:
        return XMCSpec.from_dict(meta["xmc_spec"])
    manifest = index.get("manifest")
    solver = dict(manifest.get("solver", {})) if manifest else {}
    if "spec" in solver:                     # spec fingerprint, no meta copy
        return XMCSpec(
            solver=SolverSpec.from_dict(solver["spec"]["solver"]),
            schedule=ScheduleSpec.from_dict(solver["spec"]["schedule"]))
    solver_kw = {k: solver[k] for k in
                 ("C", "delta", "eps", "max_newton", "max_cg")
                 if k in solver}
    if solver.get("use_pallas"):
        solver_kw["ops"] = "pallas"
        solver_kw["pallas_interpret"] = solver.get("pallas_interpret")
    mesh = solver.get("mesh")
    schedule_kw: dict = {}
    if manifest is not None:
        schedule_kw["label_batch"] = manifest["label_batch"]
        schedule_kw["block_shape"] = tuple(manifest["block_shape"])
    if mesh:
        schedule_kw["mesh"] = (int(mesh.get("data", 1)),
                               int(mesh.get("model", 1)))
    for k in ("shard_data", "balance"):
        if k in solver:
            schedule_kw[k] = solver[k]
    return XMCSpec(solver=SolverSpec(**solver_kw),
                   schedule=ScheduleSpec(**schedule_kw))


@dataclasses.dataclass
class CheckpointHandle:
    """A servable sparse checkpoint plus the spec that produced it.

    Returned by `fit`; re-created from disk alone with `open` (the spec
    travels inside the manifest). `engine()` turns it into a serving
    `XMCEngine` exactly as `spec.serve` describes; `model()` loads the
    packed BSR artifact for direct use.
    """
    directory: str
    spec: XMCSpec
    result: Optional[object] = None          # XMCTrainResult when from fit()
    allow_incomplete: bool = False           # opened for inspection only

    @classmethod
    def open(cls, directory: str, *,
             allow_incomplete: bool = False) -> "CheckpointHandle":
        """Re-open a checkpoint, recovering its spec from the manifest.

        A still-streaming out_dir raises (a half-written model must never
        reach serving — the refresh watcher relies on this). Pass
        `allow_incomplete=True` to inspect a partial checkpoint anyway:
        `index()`/`model()` then describe the contiguous solved prefix,
        while `engine()`/`server()` still require a finalized checkpoint.
        """
        from repro.checkpoint.io import load_block_sparse_meta
        index = load_block_sparse_meta(directory,
                                       allow_incomplete=allow_incomplete)
        return cls(directory=directory, spec=_spec_from_index(index),
                   allow_incomplete=allow_incomplete)

    # -- introspection ----------------------------------------------------

    @property
    def complete(self) -> bool:
        from repro.checkpoint.io import has_block_sparse_checkpoint
        return has_block_sparse_checkpoint(self.directory)

    @property
    def generation(self) -> Optional[int]:
        """Generation counter of the servable checkpoint (None while the
        stream is still being written) — what `CheckpointWatcher` polls."""
        from repro.checkpoint.io import checkpoint_generation
        return checkpoint_generation(self.directory)

    def index(self) -> dict:
        """Pre-flight metadata (shapes, block counts, user meta) without
        touching the arrays."""
        from repro.checkpoint.io import load_block_sparse_meta
        return load_block_sparse_meta(
            self.directory, allow_incomplete=self.allow_incomplete)

    def model(self):
        """Load the packed `BlockSparseModel` (+ meta dict)."""
        from repro.checkpoint.io import load_block_sparse
        return load_block_sparse(
            self.directory, allow_incomplete=self.allow_incomplete)

    # -- serving ----------------------------------------------------------

    def engine(self, serve_override: Optional[ServeSpec] = None, *,
               mesh=None):
        """Build the serving engine this checkpoint's spec describes.

        serve_override replaces the whole `ServeSpec` for this session
        (the weights are shared; only the serving plan changes); `mesh`
        supplies a device mesh to mesh-sharded backends.
        """
        from repro.serve.xmc import XMCEngine
        serve = (serve_override or self.spec.serve).validate()
        return XMCEngine.from_checkpoint(
            self.directory, backend=serve.backend, k=serve.k,
            mesh=mesh, interpret=serve.resolved_interpret(),
            buckets=tuple(serve.buckets), warmup=serve.warmup,
            shortlist_blocks=serve.shortlist_blocks, int8=serve.int8,
            shortlist_per_query=serve.shortlist_per_query)

    def server(self, serve_override: Optional[ServeSpec] = None, *,
               mesh=None, name: Optional[str] = None, start: bool = True):
        """Build the async continuous-batching server this checkpoint's
        spec describes (`serve.server.XMCServer`): `submit` returns
        futures, buckets launch on fill OR `max_batch_delay_ms`, and
        `max_queue` admission control sheds overload with `Rejected`
        results. Several handles' servers compose into one process via
        `serve.server.ModelRouter` — equal-shaped models share bucket
        warm-up compiles. The synchronous `engine()` path is unchanged.
        """
        from repro.serve.server import XMCServer
        serve = (serve_override or self.spec.serve).validate()
        return XMCServer(self.engine(serve, mesh=mesh),
                         max_batch_delay_ms=serve.max_batch_delay_ms,
                         max_queue=serve.max_queue, name=name, start=start)
