"""Production mesh construction (TPU v5e-256, 1 or 2 pods).

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (the dry-run must set XLA_FLAGS before first jax init).
Mesh construction goes through repro.compat so the same code runs on jax
versions with and without `AxisType` / `axis_types=`.
"""

from __future__ import annotations

from repro.compat import AxisType, make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over locally available devices (tests / examples)."""
    return make_mesh((data, model), ("data", "model"),
                     axis_types=(AxisType.Auto, AxisType.Auto))


def mesh_shape_dict(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))
