"""Serving launcher: LM decode or XMC top-k label serving.

LM mode (batched decode against a smoke model):

  PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x22b --smoke \
      --steps 16 --batch 4

XMC mode (the paper's distributed prediction as a service; trains and
checkpoints a small sparse model first if --ckpt does not exist yet, then
opens it as a CheckpointHandle — the spec rides in the manifest — and
overrides just its ServeSpec with the CLI flags):

  PYTHONPATH=src python -m repro.launch.serve --xmc --backend bsr \
      --ckpt /tmp/xmc_ckpt --requests 64 --k 5

XMC server mode (the continuous-batching async request path: deadline-
launched buckets, admission control, and a multi-model router in one
process; each --model carries its own per-model ServeSpec overrides and
an open-loop Poisson load generator drives the router):

  PYTHONPATH=src python -m repro.launch.serve --xmc --server \
      --model wiki=/tmp/ckpt_a,backend=bsr,k=5,delay=2,max_queue=256 \
      --model amazon=/tmp/ckpt_b,backend=dense,k=10 \
      --rate 200 --requests 400

With no --model, a single model named "default" is built from the plain
XMC flags (--ckpt/--backend/--k/--max-batch-delay-ms/--max-queue).
"""

from __future__ import annotations

import argparse
import signal
import threading
import time
from contextlib import contextmanager

import jax
import numpy as np

from repro.configs.registry import ARCH_IDS, get_config

#: --model value: NAME=CKPT_DIR[,key=value...]; these keys override the
#: checkpoint's own ServeSpec for that model's server.
MODEL_KEYS = ("backend", "k", "delay", "max_queue", "shortlist_blocks",
              "int8")


def parse_model_flag(value: str) -> tuple[str, str, dict]:
    """'wiki=/tmp/ckpt,backend=bsr,k=5' -> (name, ckpt_dir, overrides)."""
    head, *opts = value.split(",")
    if "=" not in head:
        raise argparse.ArgumentTypeError(
            f"--model must look like NAME=CKPT_DIR[,key=value...], "
            f"got {value!r}")
    name, ckpt = head.split("=", 1)
    overrides: dict = {}
    for opt in opts:
        if "=" not in opt:
            raise argparse.ArgumentTypeError(
                f"--model option {opt!r} is not key=value")
        key, val = opt.split("=", 1)
        if key not in MODEL_KEYS:
            raise argparse.ArgumentTypeError(
                f"--model key {key!r} unknown; valid: {MODEL_KEYS}")
        overrides[key] = val
    return name, ckpt, overrides


def serve_xmc(args) -> None:
    from repro.specs import ServeSpec
    from repro.train.xmc import train_demo_checkpoint
    from repro.xmc_api import CheckpointHandle

    # Shared demo setup (also used by examples/serve_xmc.py and
    # benchmarks/serve_latency.py): dataset + streamed sparse checkpoint
    # through the spec-driven session, reused if already on disk.
    d, index = train_demo_checkpoint(
        args.ckpt, n_train=600, n_test=max(args.requests * 4, 64),
        n_features=args.features, n_labels=args.labels,
        label_batch=min(128, args.labels), seed=args.seed)
    # Validate the request shape against the checkpoint meta BEFORE paying
    # for engine load + per-bucket warm-up compiles.
    ckpt_features = index["meta"].get(
        "n_features", index.get("orig_shape", index["shape"])[1])
    if ckpt_features != args.features:
        raise SystemExit(
            f"--features {args.features} does not match the checkpoint's "
            f"feature dim {ckpt_features}; re-run with --features "
            f"{ckpt_features} or point --ckpt elsewhere")

    t0 = time.time()
    # The manifest carries the full spec; CLI flags override just the
    # serving half of it for this session.
    handle = CheckpointHandle.open(args.ckpt)
    engine = handle.engine(
        handle.spec.serve.replace(backend=args.backend, k=args.k,
                                  shortlist_blocks=args.shortlist_blocks,
                                  int8=args.int8))
    print(f"[xmc] backend={args.backend} int8={args.int8} loaded+warmed in "
          f"{time.time() - t0:.1f}s "
          f"(L={engine.backend.n_labels}, k={engine.backend.k})")

    rng = np.random.default_rng(args.seed)
    pool = np.asarray(d.X_test, np.float32)
    requests = []
    for _ in range(args.requests):
        n_i = int(rng.integers(1, args.max_request_rows + 1))
        rows = rng.integers(0, pool.shape[0], size=n_i)
        requests.append(pool[rows])

    results = engine.serve(requests)
    stats = engine.latency_summary()
    n_inst = sum(r.labels.shape[0] for r in results)
    print(f"[xmc] served {len(results)} requests ({n_inst} instances): "
          f"p50={stats['p50_ms']:.2f}ms p99={stats['p99_ms']:.2f}ms "
          f"mean={stats['mean_ms']:.2f}ms")
    sample = results[0]
    print(f"[xmc] req[0] top-{args.k} labels per instance: "
          f"{sample.labels[:2].tolist()}")


@contextmanager
def drain_on_signals(router):
    """SIGTERM/SIGINT (main thread only) raise SystemExit(128+sig) so the
    enclosing `with router:` force-drains — every accepted future resolves
    before the process exits — instead of dying with dispatcher threads
    mid-batch. Prior handlers are restored on the way out."""
    if threading.current_thread() is not threading.main_thread():
        yield []                       # signals only reach the main thread
        return
    caught: list[int] = []

    def _handler(signum, frame):
        caught.append(signum)
        raise SystemExit(128 + signum)

    prev = [(s, signal.signal(s, _handler))
            for s in (signal.SIGTERM, signal.SIGINT)]
    try:
        yield caught
    finally:
        for s, h in prev:
            signal.signal(s, h)
        if caught:
            print(f"[server] caught signal {caught[0]}; router drained — "
                  "every accepted request resolved", flush=True)


def serve_xmc_server(args) -> None:
    """Multi-model continuous-batching server under open-loop Poisson load.

    Builds one async `XMCServer` per --model (training a small demo
    checkpoint first when the directory has none), routes a Poisson
    request stream across them through `ModelRouter`, and reports
    per-model arrival-to-completion percentiles, queue wait, goodput, and
    reject rate. `--watch` attaches a `CheckpointWatcher` per model: a
    newer finalized checkpoint generation in that model's directory is
    hot-swapped in with zero downtime. SIGTERM/SIGINT at any point —
    including mid-load — drain the router (every accepted future resolves)
    before the process exits.
    """
    from repro.serve.server import ModelRouter, Rejected
    from repro.train.xmc import train_demo_checkpoint
    from repro.xmc_api import CheckpointHandle

    model_flags = args.model or [
        (f"default={args.ckpt},backend={args.backend},k={args.k}")]
    router = ModelRouter()
    pools: dict[str, np.ndarray] = {}
    t0 = time.time()
    # The signal scope opens BEFORE models load: a SIGTERM during engine
    # warm-up still drains whatever servers are already routed. `with
    # router` guarantees the drain on every exit path (normal, exception,
    # or signal-raised SystemExit).
    with drain_on_signals(router), router:
        for flag in model_flags:
            name, ckpt, ov = parse_model_flag(flag) \
                if isinstance(flag, str) else flag
            d, _ = train_demo_checkpoint(
                ckpt, n_train=600, n_test=max(args.requests, 64),
                n_features=args.features, n_labels=args.labels,
                label_batch=min(128, args.labels), seed=args.seed)
            handle = CheckpointHandle.open(ckpt)
            serve = handle.spec.serve.replace(
                backend=ov.get("backend", args.backend),
                k=int(ov.get("k", args.k)),
                max_batch_delay_ms=float(ov.get("delay",
                                                args.max_batch_delay_ms)),
                max_queue=(int(ov["max_queue"]) if "max_queue" in ov
                           else args.max_queue),
                shortlist_blocks=(int(ov["shortlist_blocks"])
                                  if "shortlist_blocks" in ov
                                  else args.shortlist_blocks),
                int8=(ov["int8"].lower() in ("1", "true", "yes")
                      if "int8" in ov else args.int8))
            router.add(name, handle.server(serve, name=name))
            pools[name] = np.asarray(d.X_test, np.float32)
            print(f"[server] model {name!r}: backend={serve.backend} "
                  f"k={serve.k} delay={serve.max_batch_delay_ms}ms "
                  f"max_queue={serve.max_queue} ({ckpt})")
            if args.watch:
                router.watch(name, ckpt, serve_override=serve,
                             poll_interval_s=args.watch_interval)
                print(f"[server] watching {ckpt} for newer generations "
                      f"every {args.watch_interval}s")
        print(f"[server] {len(router)} model(s) loaded+warmed in "
              f"{time.time() - t0:.1f}s; offering ~{args.rate} req/s "
              f"({args.requests} requests, Poisson arrivals)", flush=True)

        rng = np.random.default_rng(args.seed)
        names = router.models()
        futures = []
        t_start = time.monotonic()
        t_next = t_start
        for _ in range(args.requests):
            t_next += rng.exponential(1.0 / args.rate)
            now = time.monotonic()
            if t_next > now:
                time.sleep(t_next - now)
            name = names[int(rng.integers(len(names)))]
            pool = pools[name]
            n_i = int(rng.integers(1, args.max_request_rows + 1))
            futures.append((name, router.submit(
                name, pool[rng.integers(0, pool.shape[0], size=n_i)])))
        router.stop()                 # flush: every accepted future resolves
        wall = time.monotonic() - t_start

        for name in names:
            st = router[name].stats()
            lat, qw = st["latency"], st["queue_wait"]
            print(f"[server] {name}: completed={st['completed']} "
                  f"rejected={st['rejected']} "
                  f"(reject_rate={st['reject_rate']:.3f}) "
                  f"swaps={st['swaps']} "
                  f"p50={lat.get('p50_ms', float('nan')):.2f}ms "
                  f"p99={lat.get('p99_ms', float('nan')):.2f}ms "
                  f"queue_wait_p99={qw.get('p99_ms', float('nan')):.2f}ms")
        done = sum(1 for _, f in futures
                   if not isinstance(f.result(0), Rejected))
        print(f"[server] goodput {done / wall:.1f} req/s over {wall:.2f}s "
              f"wall across {len(names)} model(s)")


def serve_lm(args) -> None:
    from repro.models.model import build_model
    from repro.serve import serve_batch

    cfg = get_config(args.arch, smoke=args.smoke)
    if cfg.is_encoder_decoder or cfg.n_prefix:
        raise SystemExit("serve CLI drives text-only archs; enc-dec/VLM "
                         "serving is exercised by examples/serve_xmc.py "
                         "and the dry-run")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    reqs = [rng.integers(2, cfg.vocab, size=rng.integers(4, 12))
            for _ in range(args.batch)]
    t0 = time.time()
    outs = serve_batch(model, params, reqs, steps=args.steps,
                       use_swa=cfg.swa_always)
    dt = time.time() - t0
    for i, o in enumerate(outs):
        print(f"req[{i}] -> {o.tolist()}")
    n_tok = args.batch * args.steps
    print(f"# {n_tok} tokens in {dt:.1f}s ({1e3 * dt / n_tok:.1f} ms/tok)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--xmc", action="store_true",
                    help="serve XMC top-k label queries instead of LM decode")
    ap.add_argument("--server", action="store_true",
                    help="XMC mode: run the async continuous-batching "
                         "multi-model server under Poisson load instead of "
                         "the synchronous engine demo")
    ap.add_argument("--model", action="append", default=None,
                    metavar="NAME=CKPT[,key=val...]",
                    help="server mode, repeatable: route NAME to CKPT with "
                         f"per-model ServeSpec overrides {MODEL_KEYS}")
    ap.add_argument("--rate", type=float, default=200.0,
                    help="server mode: offered load, requests/s (Poisson)")
    ap.add_argument("--max-batch-delay-ms", type=float, default=2.0,
                    help="server mode: bucket launch deadline")
    ap.add_argument("--max-queue", type=int, default=None,
                    help="server mode: admission bound on queued requests "
                         "(default unbounded)")
    ap.add_argument("--watch", action="store_true",
                    help="server mode: poll each model's checkpoint dir and "
                         "hot-swap newer finalized generations in with zero "
                         "downtime (lifecycle.refresh.CheckpointWatcher)")
    ap.add_argument("--watch-interval", type=float, default=2.0,
                    help="server mode: --watch poll interval, seconds")
    ap.add_argument("--arch", default=None, choices=list(ARCH_IDS),
                    help="LM mode: architecture to serve")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    from repro.serve.xmc import available_backends
    ap.add_argument("--backend", default="dense",
                    choices=available_backends(),
                    help="XMC mode: predict backend (registry kinds)")
    ap.add_argument("--ckpt", default="/tmp/repro_xmc_ckpt",
                    help="XMC mode: sparse checkpoint directory")
    ap.add_argument("--k", type=int, default=5)
    ap.add_argument("--shortlist-blocks", type=int, default=None,
                    help="XMC mode, shortlist backend: candidate row blocks "
                         "B per micro-batch (default: artifact's ~1/8)")
    ap.add_argument("--int8", action="store_true",
                    help="XMC mode: serve the per-block int8 weight "
                         "artifact (~0.25x weight HBM traffic; composes "
                         "with --backend shortlist's gathered fine stage)")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--max-request-rows", type=int, default=8)
    ap.add_argument("--features", type=int, default=4096)
    ap.add_argument("--labels", type=int, default=256)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.xmc:
        if args.server:
            serve_xmc_server(args)
        else:
            serve_xmc(args)
    elif args.server:
        ap.error("--server requires --xmc (the LM path has no async server)")
    else:
        if args.arch is None:
            ap.error("--arch is required in LM mode (or pass --xmc)")
        serve_lm(args)


if __name__ == "__main__":
    main()
