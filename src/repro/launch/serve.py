"""Serving launcher: batched decode against a smoke model.

  PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x22b --smoke \
      --steps 16 --batch 4
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs.registry import ARCH_IDS, get_config
from repro.models.model import build_model
from repro.serve import serve_batch


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(ARCH_IDS))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    if cfg.is_encoder_decoder or cfg.n_prefix:
        raise SystemExit("serve CLI drives text-only archs; enc-dec/VLM "
                         "serving is exercised by examples/serve_xmc.py "
                         "and the dry-run")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    reqs = [rng.integers(2, cfg.vocab, size=rng.integers(4, 12))
            for _ in range(args.batch)]
    t0 = time.time()
    outs = serve_batch(model, params, reqs, steps=args.steps,
                       use_swa=cfg.swa_always)
    dt = time.time() - t0
    for i, o in enumerate(outs):
        print(f"req[{i}] -> {o.tolist()}")
    n_tok = args.batch * args.steps
    print(f"# {n_tok} tokens in {dt:.1f}s ({1e3 * dt / n_tok:.1f} ms/tok)")


if __name__ == "__main__":
    main()
