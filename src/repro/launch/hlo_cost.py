"""HLO call-graph cost model with while-loop trip-count multipliers.

XLA's HloCostAnalysis (compiled.cost_analysis()) counts while-loop bodies
ONCE — our layer scans, microbatch accumulation, blockwise-attention scans
and SSM chunk scans therefore undercount FLOPs/bytes/collectives by the trip
count. This module re-derives the three roofline numerators from the
optimized per-device HLO text:

  flops       2 * prod(result dims) * prod(contracting dims) per dot,
              fusion/call/while expanded with known_trip_count multipliers
  hbm bytes   TWO models, bracketing the truth:
              * bytes_upper — operand + result bytes of every top-level
                instruction. The CPU pipeline barely fuses, so elementwise
                chains (convert/add/mul/broadcast) are all counted at full
                tensor size: a LOOSE UPPER bound (~20-50x real TPU traffic).
              * bytes_fused — only "anchor" ops that XLA:TPU cannot fuse
                away (dot, fusion, reduce, gather/scatter, dynamic slices,
                sort, concatenate, copies, collectives) charge operand +
                result bytes; elementwise/layout ops ride their producers
                for free. This models a perfectly-fusing TPU pipeline and
                is the roofline's memory numerator.
  collectives operand bytes per all-gather / all-reduce / reduce-scatter /
              all-to-all / collective-permute, trip-multiplied.

Shapes are per-device (post-SPMD-partitioning), so all totals are per-chip.
"""

from __future__ import annotations

import dataclasses
import json
import re
from functools import lru_cache

_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1,
          "f8e5m2": 1, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
          "s8": 1, "u8": 1, "pred": 1, "s64": 8, "u64": 8, "c64": 8,
          "c128": 16, "token": 0, "s4": 1, "u4": 1}

_COMP_HDR = re.compile(r"^(?:ENTRY )?%?([\w\.\-_]+) \(.*\) -> .* \{\s*$")
_INSTR = re.compile(
    r"^\s*(?:ROOT )?%([\w\.\-_]+) = ((?:\([^)]*\))|(?:[^ ]+)) "
    r"([\w\-]+)\((.*)$")
_SHAPE = re.compile(r"^(?:\()?([a-z0-9]+)\[([0-9,]*)\]")
_TUPLE_SHAPES = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_CALLS = re.compile(r"calls=%?([\w\.\-_]+)")
_BODY = re.compile(r"body=%?([\w\.\-_]+)")
_COND = re.compile(r"condition=%?([\w\.\-_]+)")
_TRIP = re.compile(r'known_trip_count[^0-9]*(\d+)')
_OPERANDS_SPLIT = re.compile(r"%([\w\.\-_]+)")
_LHS_C = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# Ops that force HBM traffic on a perfectly-fusing TPU pipeline. Everything
# elementwise / layout (convert, add, multiply, broadcast, reshape, bitcast,
# transpose, select, compare, iota, pad, ...) fuses into these for free.
ANCHOR_OPS = frozenset((
    "dot", "convolution", "fusion", "reduce", "reduce-window", "gather",
    "scatter", "dynamic-slice", "dynamic-update-slice", "sort", "copy",
    "concatenate", "rng-bit-generator", "cholesky", "triangular-solve",
    *COLLECTIVES, *(c + "-start" for c in COLLECTIVES),
))


def _shape_bytes(type_str: str) -> int:
    """Total bytes of a (possibly tuple) shape string."""
    total = 0
    for m in _TUPLE_SHAPES.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _BYTES[dt]
    return total


def _shape_bytes_f32(type_str: str) -> int:
    """Bytes of the f32/f64 components only — used to quantify the CPU
    lowering artifact where bf16 matmul partials are legalized to f32 dots,
    inflating the measured collective bytes 2x vs a real TPU lowering
    (EXPERIMENTS.md SSRoofline)."""
    total = 0
    for m in _TUPLE_SHAPES.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in ("f32", "f64"):
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _BYTES[dt]
    return total


def _shape_dims(type_str: str):
    m = _SHAPE.match(type_str)
    if not m:
        return None
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Costs:
    flops: float = 0.0
    bytes: float = 0.0          # bytes_upper (every instruction)
    bytes_fused: float = 0.0    # anchor ops only (TPU fusion model)
    coll: dict = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in COLLECTIVES})
    coll_f32: float = 0.0       # f32 share of collective bytes (CPU artifact)

    def __iadd__(self, o):
        self.flops += o.flops
        self.bytes += o.bytes
        self.bytes_fused += o.bytes_fused
        self.coll_f32 += o.coll_f32
        for k in self.coll:
            self.coll[k] += o.coll[k]
        return self

    def scaled(self, m: float) -> "Costs":
        return Costs(self.flops * m, self.bytes * m, self.bytes_fused * m,
                     {k: v * m for k, v in self.coll.items()},
                     self.coll_f32 * m)


def parse_module(text: str) -> dict:
    """computation name -> list of raw instruction lines."""
    comps: dict[str, list[str]] = {}
    cur = None
    for line in text.splitlines():
        m = _COMP_HDR.match(line.strip()) if "{" in line else None
        if m and ("->" in line):
            cur = m.group(1)
            comps[cur] = []
            continue
        if cur is not None:
            if line.strip() == "}":
                cur = None
                continue
            comps[cur].append(line)
    return comps


def module_costs(text: str) -> Costs:
    comps = parse_module(text)

    # Pre-parse instructions per computation.
    parsed: dict[str, list[dict]] = {}
    for name, lines in comps.items():
        instrs = []
        for ln in lines:
            m = _INSTR.match(ln)
            if not m:
                continue
            instrs.append({
                "name": m.group(1), "type": m.group(2), "op": m.group(3),
                "rest": m.group(4), "line": ln,
            })
        parsed[name] = instrs

    # Symbol tables: per computation, instr name -> type string.
    symtab = {
        cname: {i["name"]: i["type"] for i in instrs}
        for cname, instrs in parsed.items()
    }

    memo: dict[str, Costs] = {}

    def comp_costs(cname: str) -> Costs:
        if cname in memo:
            return memo[cname]
        memo[cname] = Costs()        # cycle guard (shouldn't happen)
        total = Costs()
        syms = symtab.get(cname, {})
        for ins in parsed.get(cname, []):
            op = ins["op"]
            line = ins["line"]
            own = Costs()
            if op == "dot":
                dims = _shape_dims(ins["type"]) or []
                out_prod = 1
                for d in dims:
                    out_prod *= d
                # contracting dims from lhs operand shape
                ops = _OPERANDS_SPLIT.findall(ins["rest"].split("),")[0])
                lhs_type = syms.get(ops[0] if ops else "", "")
                lhs_dims = _shape_dims(lhs_type) or []
                cm = _LHS_C.search(line)
                cprod = 1
                if cm and lhs_dims:
                    for ci in cm.group(1).split(","):
                        if ci:
                            cprod *= lhs_dims[int(ci)]
                own.flops += 2.0 * out_prod * cprod
            if op in COLLECTIVES or op.rstrip("-start") in COLLECTIVES:
                kind = op[:-6] if op.endswith("-start") else op
                if kind in COLLECTIVES:
                    opnames = _OPERANDS_SPLIT.findall(
                        ins["rest"].split("),")[0].split(")")[0])
                    ob = sum(_shape_bytes(syms.get(o, "")) for o in opnames)
                    own.coll[kind] += float(ob)
                    own.coll_f32 += float(sum(
                        _shape_bytes_f32(syms.get(o, "")) for o in opnames))
            # HBM traffic model: operand + result bytes at computation level
            # for compute/data ops (not for pure control ops).
            if op not in ("parameter", "constant", "tuple",
                          "get-tuple-element", "while", "conditional",
                          "call", "bitcast", "copy-start", "copy-done"):
                opnames = _OPERANDS_SPLIT.findall(ins["rest"])
                ob = sum(_shape_bytes(syms.get(o, "")) for o in opnames
                         if o in syms)
                traffic = _shape_bytes(ins["type"]) + ob
                own.bytes += traffic
                if op in ANCHOR_OPS:
                    own.bytes_fused += traffic

            # Recurse into called computations.
            mult = 1.0
            sub = Costs()
            if op == "while":
                b = _BODY.search(line)
                c = _COND.search(line)
                t = _TRIP.search(line)
                trips = float(t.group(1)) if t else 1.0
                if b:
                    sub += comp_costs(b.group(1))
                if c:
                    sub += comp_costs(c.group(1))
                mult = trips
            elif op == "conditional":
                br = _BRANCHES.search(line)
                if br:
                    branch_costs = [comp_costs(x.strip().lstrip("%"))
                                    for x in br.group(1).split(",")]
                    for bc in branch_costs:      # upper bound: sum branches
                        sub += bc
            else:
                cm = _CALLS.search(line)
                if cm:
                    called = comp_costs(cm.group(1))
                    # fusion boundary: flops+collectives recurse; bytes stay
                    # at the fusion's own operand/result traffic.
                    sub.flops += called.flops
                    for k in sub.coll:
                        sub.coll[k] += called.coll[k]
            total += own
            total += sub.scaled(mult)
        memo[cname] = total
        return total

    # Entry computation = the one nobody calls; jax names it main.*
    entry = None
    for cname in parsed:
        if cname.startswith("main"):
            entry = cname
            break
    if entry is None:
        entry = list(parsed)[-1]
    return comp_costs(entry)


def summarize(text: str) -> dict:
    c = module_costs(text)
    return {"flops": c.flops, "hbm_bytes": c.bytes,
            "hbm_bytes_fused": c.bytes_fused,
            "collectives": c.coll,
            "collective_bytes": sum(c.coll.values()),
            "collective_bytes_f32": c.coll_f32}
