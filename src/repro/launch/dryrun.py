import os
os.environ["XLA_FLAGS"] = (os.environ.get("_DRYRUN_EXTRA_XLA", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch x shape) on the production
meshes, print memory_analysis / cost_analysis, dump roofline inputs.

MUST be run as a module:  PYTHONPATH=src python -m repro.launch.dryrun \
    --arch chatglm3-6b --shape train_4k [--multi-pod] [--json out.json]

The XLA_FLAGS line above executes before ANY jax import (jax locks the device
count at first init); 512 placeholder host devices back the (2,16,16) mesh.
"""

import argparse
import json
import re
import sys
import time

import jax
import jax.numpy as jnp

from repro import compat
from repro.configs.base import SHAPES
from repro.configs.registry import ARCH_IDS, SKIPS, get_config
from repro.launch import specs as S
from repro.launch.mesh import make_production_mesh
from repro.models import sharding as shd
from repro.models.model import build_model
from repro.optim import adamw_init
from repro.train.trainer import make_train_step

# TPU v5e hardware constants (roofline denominators).
PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_COLL_RE = re.compile(
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"[^\n(]*\(([^\n]*)\)")
_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|s32|u32|s8|u8|pred|s64|u64)"
                       r"\[([0-9,]*)\]")
_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
          "s8": 1, "u8": 1, "pred": 1, "s64": 8, "u64": 8}


def collective_bytes(hlo_text: str) -> dict:
    """Per-device operand bytes of every collective in the optimized HLO."""
    out = {"all-gather": 0, "all-reduce": 0, "reduce-scatter": 0,
           "all-to-all": 0, "collective-permute": 0}
    for m in _COLL_RE.finditer(hlo_text):
        kind, operands = m.group(1), m.group(2)
        total = 0
        for sm in _SHAPE_RE.finditer(operands):
            dt, dims = sm.group(1), sm.group(2)
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            total += n * _BYTES[dt]
        out[kind] += total
    return out


def build_lowerable(arch: str, shape_name: str, mesh, *, smoke: bool = False):
    """Returns (fn, args) ready for jax.jit(fn).lower(*args)."""
    cfg = get_config(arch, smoke=smoke)
    shape = SHAPES[shape_name]
    model = build_model(cfg)
    ms = dict(zip(mesh.axis_names, mesh.devices.shape))
    baxes = shd.batch_axes(ms, cfg)
    use_swa = S.use_swa_for(cfg, shape_name)

    params_shape = S.abstract_params(model)
    params_sds = S.params_specs(cfg, params_shape, mesh)

    if shape.kind == "train":
        accum = S.TRAIN_ACCUM.get(arch, 1) if not smoke else 1
        batch_sds = S.train_batch_specs(cfg, shape, mesh, accum)
        opt_shape = jax.eval_shape(adamw_init, params_shape)
        opt_sds = jax.tree.map(
            lambda sds, ref: jax.ShapeDtypeStruct(
                sds.shape, sds.dtype, sharding=ref.sharding)
            if sds.shape else jax.ShapeDtypeStruct(sds.shape, sds.dtype),
            opt_shape,
            type(opt_shape)(step=opt_shape.step, mu=params_sds,
                            nu=params_sds),
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
        from repro.optim.schedules import linear_warmup_cosine
        lr_fn = linear_warmup_cosine(3e-4, 100, 10000)
        step_fn = make_train_step(model, lr_fn=lr_fn, mesh=mesh,
                                  batch_axes=baxes, accum=accum)
        step_sds = jax.ShapeDtypeStruct((), jnp.int32)
        return step_fn, (params_sds, opt_sds, step_sds, batch_sds)

    if shape.kind == "prefill":
        batch_sds = S.serve_batch_specs(cfg, shape, mesh)

        def prefill_fn(params, batch):
            return model.prefill(params, batch, mesh=mesh, batch_axes=baxes,
                                 use_swa=use_swa)
        return prefill_fn, (params_sds, batch_sds)

    # decode: ONE token against a seq_len cache
    cache_sds = S.cache_specs(cfg, model, shape, mesh, use_swa)
    batch_sds = S.serve_batch_specs(cfg, shape, mesh)

    def decode_fn(params, cache, tokens, pos):
        return model.decode_step(params, cache, tokens, pos, mesh=mesh,
                                 batch_axes=baxes, use_swa=use_swa)
    return decode_fn, (params_sds, cache_sds, batch_sds["tokens"],
                       batch_sds["pos"])


def run_one(arch: str, shape_name: str, *, multi_pod: bool,
            smoke: bool = False) -> dict:
    if (arch, shape_name) in SKIPS:
        return {"arch": arch, "shape": shape_name, "skipped": True,
                "reason": SKIPS[(arch, shape_name)]}
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    fn, args = build_lowerable(arch, shape_name, mesh, smoke=smoke)
    with mesh:
        lowered = jax.jit(fn).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    mem = compiled.memory_analysis()
    cost = compat.cost_analysis(compiled)
    hlo_text = compiled.as_text()
    coll = collective_bytes(hlo_text)
    from repro.launch import hlo_cost
    corrected = hlo_cost.summarize(hlo_text)
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        # XLA HloCostAnalysis (counts while bodies ONCE — undercounts scans):
        "flops_per_device": float(cost.get("flops", 0.0)),
        "bytes_per_device": float(cost.get("bytes accessed", 0.0)),
        "collective_bytes_per_device": coll,
        # Trip-count-corrected call-graph model (launch/hlo_cost.py):
        "flops_corrected": corrected["flops"],
        "hbm_bytes_corrected": corrected["hbm_bytes"],         # upper bound
        "hbm_bytes_fused": corrected["hbm_bytes_fused"],       # TPU fusion model
        "collective_bytes_corrected": corrected["collectives"],
        "collective_bytes_f32": corrected["collective_bytes_f32"],
        "argument_bytes": int(mem.argument_size_in_bytes),
        "output_bytes": int(mem.output_size_in_bytes),
        "temp_bytes": int(mem.temp_size_in_bytes),
        "peak_bytes": int(mem.argument_size_in_bytes +
                          mem.temp_size_in_bytes),
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
    }
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all", help="arch id or 'all'")
    ap.add_argument("--shape", default="all",
                    choices=list(SHAPES) + ["all"])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--json", default=None, help="append results to file")
    args = ap.parse_args()

    archs = list(ARCH_IDS) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    pods = [False, True] if args.both_meshes else [args.multi_pod]

    results = []
    failed = 0
    for arch in archs:
        for shape in shapes:
            for mp in pods:
                try:
                    rec = run_one(arch, shape, multi_pod=mp,
                                  smoke=args.smoke)
                except Exception as e:  # a dry-run failure IS a bug
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "2x16x16" if mp else "16x16",
                           "error": f"{type(e).__name__}: {e}"}
                    failed += 1
                results.append(rec)
                print(json.dumps(rec), flush=True)
    if args.json:
        with open(args.json, "a") as f:
            for rec in results:
                f.write(json.dumps(rec) + "\n")
    sys.exit(1 if failed else 0)


if __name__ == "__main__":
    main()
