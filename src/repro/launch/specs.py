"""input_specs(): ShapeDtypeStruct stand-ins for every lowered entry point.

No device allocation — these drive .lower()/.compile() only. Shardings follow
DESIGN.md §5. Modality frontends are stubs: audio/vision archs receive
precomputed frame/patch embeddings of the documented shape here.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import SHAPES, ArchConfig, ShapeConfig
from repro.models import sharding as shd
from repro.models.model import Model, build_model

Array = jax.Array

# Gradient-accumulation microbatching for train_4k (global_batch = 256):
# chosen so per-device activation memory fits a 16 GB v5e (DESIGN.md §5).
TRAIN_ACCUM = {
    "xlstm-125m": 1, "qwen1.5-0.5b": 1, "seamless-m4t-medium": 8,
    # hymba 2 -> 8: the banded-attention band slices + mamba chunk states
    # pushed train peak to 75 GB at accum=2; 8 brings activations within
    # budget (collective bytes are accum-invariant for activations).
    "hymba-1.5b": 8, "qwen2-moe-a2.7b": 4, "chatglm3-6b": 4,
    "internvl2-26b": 8, "qwen3-14b": 8, "deepseek-coder-33b": 8,
    "mixtral-8x22b": 8,
}


def use_swa_for(cfg: ArchConfig, shape_name: str) -> bool:
    """SWA-native archs always; dense archs only for the long_500k variant
    (DESIGN.md §Arch-applicability)."""
    if cfg.swa_always:
        return True
    return shape_name == "long_500k" and cfg.sliding_window is not None


def _sds(shape, dtype, mesh: Optional[Mesh], spec: Optional[P]):
    if mesh is None or spec is None:
        return jax.ShapeDtypeStruct(shape, dtype)
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def train_batch_specs(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh,
                      accum: int) -> dict:
    ms = dict(zip(mesh.axis_names, mesh.devices.shape))
    mb = shape.global_batch // accum
    T = shape.seq_len
    bspec = shd.batch_spec(ms, mb, cfg=cfg)
    lead = () if accum == 1 else (accum,)
    lspec = () if accum == 1 else (None,)
    batch = {
        "tokens": _sds(lead + (mb, T), jnp.int32, mesh, P(*lspec, *bspec)),
        "targets": _sds(lead + (mb, T), jnp.int32, mesh, P(*lspec, *bspec)),
        "valid": _sds(lead + (mb, T), jnp.float32, mesh, P(*lspec, *bspec)),
    }
    if cfg.n_prefix:
        batch["prefix"] = _sds(lead + (mb, cfg.n_prefix, cfg.d_model),
                               jnp.bfloat16, mesh, P(*lspec, *bspec, None))
    return batch


def serve_batch_specs(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh) -> dict:
    ms = dict(zip(mesh.axis_names, mesh.devices.shape))
    B, T = shape.global_batch, shape.seq_len
    bspec = shd.batch_spec(ms, B, cfg=cfg)
    if shape.kind == "prefill":
        batch = {"tokens": _sds((B, T), jnp.int32, mesh, P(*bspec))}
        if cfg.n_prefix:
            batch["prefix"] = _sds((B, cfg.n_prefix, cfg.d_model),
                                   jnp.bfloat16, mesh, P(*bspec, None))
        return batch
    # decode: ONE new token
    return {"tokens": _sds((B, 1), jnp.int32, mesh, P(*bspec)),
            "pos": jax.ShapeDtypeStruct((), jnp.int32)}


def abstract_params(model: Model) -> Any:
    return jax.eval_shape(model.init, jax.random.PRNGKey(0))


def params_specs(cfg: ArchConfig, params_shape: Any, mesh: Mesh) -> Any:
    ms = dict(zip(mesh.axis_names, mesh.devices.shape))
    pspecs = shd.param_pspecs(cfg, params_shape, ms)
    return jax.tree.map(
        lambda sds, spec: jax.ShapeDtypeStruct(
            sds.shape, sds.dtype, sharding=NamedSharding(mesh, spec)),
        params_shape, pspecs,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def cache_specs(cfg: ArchConfig, model: Model, shape: ShapeConfig,
                mesh: Mesh, use_swa: bool) -> Any:
    cache_shape = jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len,
                                 use_swa=use_swa))
    ms = dict(zip(mesh.axis_names, mesh.devices.shape))
    cspecs = shd.cache_pspecs(cache_shape, ms, shape.global_batch)
    return jax.tree.map(
        lambda sds, spec: jax.ShapeDtypeStruct(
            sds.shape, sds.dtype, sharding=NamedSharding(mesh, spec)),
        cache_shape, cspecs,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
