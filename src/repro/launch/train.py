"""Training launcher: LM train loop or streaming XMC pipeline.

LM mode:
  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b --smoke \
      --steps 100 --seq-len 128 --batch 8
  PYTHONPATH=src python -m repro.launch.train --arch xlstm-125m --smoke \
      --mesh 1x1 --head softmax

XMC mode (flags -> XMCSpec -> repro.xmc_api.fit: streaming label-batch
pipeline -> servable sparse checkpoint with the spec in its manifest;
re-running with the same --out resumes a killed job, --init-from warm
starts from a prior checkpoint's weights):
  PYTHONPATH=src python -m repro.launch.train --xmc --labels 512 \
      --label-batch 128 --out /tmp/xmc_ckpt
  PYTHONPATH=src python -m repro.launch.train --xmc --labels 512 \
      --delta 0.02 --out /tmp/xmc_d02 --init-from /tmp/xmc_ckpt
  PYTHONPATH=src python -m repro.launch.serve --xmc --ckpt /tmp/xmc_ckpt

Multi-host XMC (paper layer 1 over real nodes): launch the SAME command on
N hosts/processes sharing --out — each worker claims label batches through
the manifest's lease table and they drain one queue into one checkpoint
(bit-identical to a single-worker run; a worker killed mid-batch is
recovered by lease expiry):
  PYTHONPATH=src python -m repro.launch.train --xmc --labels 512 \
      --out /shared/xmc_ckpt --workers 2 --worker-id node0 &
  PYTHONPATH=src python -m repro.launch.train --xmc --labels 512 \
      --out /shared/xmc_ckpt --workers 2 --worker-id node1 &
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import ARCH_IDS, get_config
from repro.data.lm import make_lm_batch_iterator
from repro.models.model import build_model
from repro.models import sharding as shd
from repro.train.trainer import train_loop


def train_xmc(args) -> None:
    """--xmc: one declarative session — args become an XMCSpec, `fit()`
    streams the checkpoint, the handle quick-evals it."""
    from repro.core.prediction import evaluate, predict_topk
    from repro.data.xmc import make_xmc_dataset
    from repro.specs import ScheduleSpec, SolverSpec
    from repro.xmc_api import XMCSpec, fit

    if args.out is None:
        args.out = "/tmp/repro_xmc_train_ckpt"
    mesh = None
    if args.mesh:
        d, m = (int(x) for x in args.mesh.split("x"))
        mesh = (d, m)

    data = make_xmc_dataset(n_train=args.train_n, n_test=args.test_n,
                            n_features=args.features, n_labels=args.labels,
                            seed=args.seed)
    # fit() normalizes the spec: a label batch that is not a multiple of the
    # BSR block height is rounded up with a warning (the old CLI shrank the
    # block to gcd(label_batch, 128) instead, which could degrade streamed
    # blocks all the way to 1-row tiles).
    spec = XMCSpec(
        solver=SolverSpec(C=args.C, delta=args.delta),
        schedule=ScheduleSpec(label_batch=args.label_batch, mesh=mesh,
                              shard_data=args.shard_data,
                              balance=args.balance, workers=args.workers,
                              lease_ttl=args.lease_ttl))

    t0 = time.time()
    handle = fit(jnp.asarray(data.X_train), jnp.asarray(data.Y_train),
                 spec, args.out, resume=not args.fresh,
                 init_from=args.init_from, worker=args.worker_id,
                 on_batch=lambda b, n: print(
                     f"[xmc] batch {b + 1}/{n} done "
                     f"({time.time() - t0:.1f}s)"))
    wall = time.time() - t0
    res = handle.result
    print(f"[xmc] {len(res.solved)} batches solved, {len(res.skipped)} "
          f"resumed from manifest in {wall:.1f}s -> {args.out}"
          + (f" (warm-started from {args.init_from})"
             if args.init_from else ""))

    if not res.complete:
        # Defensive: a normal run (cooperative or not) returns complete —
        # workers wait out co-worker leases. Reaching here means the run
        # was cut short; re-running the same command resumes it.
        print(f"[xmc] checkpoint not complete ({len(res.solved)} batches "
              f"by this worker); re-run this command to finish {args.out}")
        return

    nnz = sum(s["nnz"] for s in res.manifest["shards"].values())
    total = args.labels * args.features
    print(f"[xmc] model: {nnz} nonzeros / {total} "
          f"({100.0 * nnz / total:.2f}% dense)")

    # Quick-eval only at smoke scale: to_dense() would rebuild the full
    # (L, D) matrix the streaming pipeline just avoided materializing.
    if args.labels * args.features <= 50_000_000:
        model, _ = handle.model()
        W = model.to_dense()[:args.labels, :args.features]
        _, idx = predict_topk(jnp.asarray(data.X_test), W, 5)
        ev = evaluate(jnp.asarray(data.Y_test), idx)
        print(f"[xmc] test P@1={ev['P@1']:.3f} P@5={ev['P@5']:.3f}")
    else:
        print("[xmc] model too large for dense quick-eval; serve it with "
              "the bsr backend instead")
    print(f"[xmc] serve it: PYTHONPATH=src python -m repro.launch.serve "
          f"--xmc --ckpt {args.out} --features {args.features} "
          f"--labels {args.labels}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--xmc", action="store_true",
                    help="run the streaming XMC pipeline instead of LM train")
    ap.add_argument("--arch", default=None, choices=list(ARCH_IDS),
                    help="LM mode: architecture to train")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-trainable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--head", choices=["dismec", "softmax"], default=None)
    ap.add_argument("--mesh", default=None, help="e.g. 2x4 (data x model)")
    ap.add_argument("--out", default=None, help="checkpoint directory")
    # XMC-mode knobs (streaming label-batch pipeline).
    ap.add_argument("--labels", type=int, default=512)
    ap.add_argument("--features", type=int, default=4096)
    ap.add_argument("--train-n", type=int, default=1000)
    ap.add_argument("--test-n", type=int, default=300)
    ap.add_argument("--label-batch", type=int, default=128)
    ap.add_argument("--C", type=float, default=1.0)
    ap.add_argument("--delta", type=float, default=0.01)
    ap.add_argument("--balance", action="store_true",
                    help="frequency-balanced label->shard dealing per batch")
    ap.add_argument("--shard-data", action="store_true",
                    help="also shard instances over the mesh data axis")
    ap.add_argument("--fresh", action="store_true",
                    help="ignore any existing manifest (no resume)")
    ap.add_argument("--init-from", default=None,
                    help="warm start: prior sparse checkpoint whose rows "
                         "seed each batch's TRON as W0")
    ap.add_argument("--workers", type=int, default=1,
                    help="cooperative worker count: >1 claims label batches "
                         "via the manifest lease table, so N processes "
                         "sharing --out drain one queue into one checkpoint")
    ap.add_argument("--worker-id", default=None,
                    help="stable identity of this worker in a multi-host "
                         "drain (default: hostname-pid); implies lease-"
                         "based claiming even with --workers 1")
    ap.add_argument("--lease-ttl", type=float, default=300.0,
                    help="seconds before an unrefreshed batch lease expires "
                         "and the batch is re-dealt (crash recovery)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.xmc:
        train_xmc(args)
        return
    if args.arch is None:
        ap.error("--arch is required in LM mode (or pass --xmc)")

    cfg = get_config(args.arch, smoke=args.smoke)
    if args.head:
        cfg = dataclasses.replace(cfg, head_type=args.head)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    mesh = None
    batch_axes = ()
    if args.mesh:
        d, m = (int(x) for x in args.mesh.split("x"))
        mesh = jax.make_mesh((d, m), ("data", "model"))
        batch_axes = ("data",)

    def batches():
        it = make_lm_batch_iterator(cfg.vocab, args.seq_len, args.batch)
        for b in it:
            if cfg.n_prefix:
                b["prefix"] = jnp.ones(
                    (args.batch, cfg.n_prefix, cfg.d_model),
                    jnp.float32) * 0.01
            yield b

    t0 = time.time()
    params, hist = train_loop(model, params, batches(), steps=args.steps,
                              lr=args.lr, mesh=mesh, batch_axes=batch_axes)
    for h in hist:
        print(json.dumps(h))
    print(f"# trained {args.steps} steps in {time.time() - t0:.1f}s; "
          f"loss {hist[0]['loss']:.2f} -> {hist[-1]['loss']:.2f}")
    if args.out:
        from repro.checkpoint import save_pytree
        save_pytree(params, args.out)
        print(f"# checkpoint saved to {args.out}")


if __name__ == "__main__":
    main()
