"""Training launcher: --arch <id> [--smoke] [--steps N] [--mesh dxm].

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b --smoke \
      --steps 100 --seq-len 128 --batch 8
  PYTHONPATH=src python -m repro.launch.train --arch xlstm-125m --smoke \
      --mesh 1x1 --head softmax
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import ARCH_IDS, get_config
from repro.data.lm import make_lm_batch_iterator
from repro.models.model import build_model
from repro.models import sharding as shd
from repro.train.trainer import train_loop


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(ARCH_IDS))
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-trainable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--head", choices=["dismec", "softmax"], default=None)
    ap.add_argument("--mesh", default=None, help="e.g. 2x4 (data x model)")
    ap.add_argument("--out", default=None, help="checkpoint directory")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    if args.head:
        cfg = dataclasses.replace(cfg, head_type=args.head)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    mesh = None
    batch_axes = ()
    if args.mesh:
        d, m = (int(x) for x in args.mesh.split("x"))
        mesh = jax.make_mesh((d, m), ("data", "model"))
        batch_axes = ("data",)

    def batches():
        it = make_lm_batch_iterator(cfg.vocab, args.seq_len, args.batch)
        for b in it:
            if cfg.n_prefix:
                b["prefix"] = jnp.ones(
                    (args.batch, cfg.n_prefix, cfg.d_model),
                    jnp.float32) * 0.01
            yield b

    t0 = time.time()
    params, hist = train_loop(model, params, batches(), steps=args.steps,
                              lr=args.lr, mesh=mesh, batch_axes=batch_axes)
    for h in hist:
        print(json.dumps(h))
    print(f"# trained {args.steps} steps in {time.time() - t0:.1f}s; "
          f"loss {hist[0]['loss']:.2f} -> {hist[-1]['loss']:.2f}")
    if args.out:
        from repro.checkpoint import save_pytree
        save_pytree(params, args.out)
        print(f"# checkpoint saved to {args.out}")


if __name__ == "__main__":
    main()
