"""internvl2-26b — InternViT + InternLM2 [arXiv:2404.16821].
48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92553.

Vision frontend (InternViT-6B + MLP projector) is a STUB per the brief:
input_specs() provides projected patch embeddings (B, n_prefix=256, d);
this config is the InternLM2-style language decoder that consumes them.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-26b", family="vlm", n_layers=48, d_model=6144,
    n_heads=48, n_kv_heads=8, d_ff=16384, vocab=92553, modality="vision",
    n_prefix=256, sliding_window=4096, source="arXiv:2404.16821",
)

SMOKE = ArchConfig(
    name="internvl2-26b-smoke", family="vlm", n_layers=2, d_model=256,
    n_heads=8, n_kv_heads=2, d_ff=512, vocab=512, modality="vision",
    n_prefix=16, dtype="float32", source="arXiv:2404.16821",
)
