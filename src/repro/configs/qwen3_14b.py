"""qwen3-14b — qk_norm, GQA [hf:Qwen/Qwen3-8B family scaling].
40L d_model=5120 40H (GQA kv=8) d_ff=17408 vocab=151936 head_dim=128."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-14b", family="dense", n_layers=40, d_model=5120,
    n_heads=40, n_kv_heads=8, d_ff=17408, vocab=151936, qk_norm=True,
    head_dim=128, rope_theta=1e6, sliding_window=4096,
    source="hf:Qwen/Qwen3-8B",
)

SMOKE = ArchConfig(
    name="qwen3-14b-smoke", family="dense", n_layers=2, d_model=256,
    n_heads=8, n_kv_heads=2, d_ff=512, vocab=512, qk_norm=True,
    head_dim=32, dtype="float32", source="hf:Qwen/Qwen3-8B",
)
