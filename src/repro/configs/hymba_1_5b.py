"""hymba-1.5b — parallel attention + Mamba heads [arXiv:2411.13676].
32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16.

Hymba fuses attention and SSM heads in every layer (outputs mean-combined)
and uses sliding-window attention everywhere except 3 global layers
(first / middle / last). Meta-tokens are not modeled (DESIGN.md).
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="hymba-1.5b", family="hybrid", n_layers=32, d_model=1600,
    n_heads=25, n_kv_heads=5, d_ff=5504, vocab=32001, ssm_state=16,
    head_dim=64, sliding_window=1024, swa_always=True,
    global_attn_layers=(0, 15, 31), source="arXiv:2411.13676",
)

SMOKE = ArchConfig(
    name="hymba-1.5b-smoke", family="hybrid", n_layers=2, d_model=128,
    n_heads=4, n_kv_heads=2, d_ff=256, vocab=512, ssm_state=8, head_dim=32,
    sliding_window=32, swa_always=True, global_attn_layers=(0,),
    dtype="float32", source="arXiv:2411.13676",
)
