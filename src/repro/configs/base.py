"""Architecture config schema shared by the whole framework.

Every assigned architecture gets one `src/repro/configs/<id>.py` exporting
CONFIG (exact published numbers, source cited) and SMOKE (reduced variant:
<= 2 layers, d_model <= 512, <= 4 experts) per the brief. `--arch <id>`
resolves through configs/registry.py.
"""

from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int

    # ---- attention variants ----
    head_dim: Optional[int] = None       # default d_model // n_heads
    rope_fraction: float = 1.0           # chatglm "RoPE 2d": rotary on half dims
    rope_theta: float = 10000.0
    qk_norm: bool = False                # qwen3
    qkv_bias: bool = False               # qwen1.5
    sliding_window: Optional[int] = None # mixtral SWA / hymba local attention
    swa_always: bool = False             # SWA is part of the arch (mixtral,
                                         # hymba); False = only the --swa
                                         # long-context variant uses it
    global_attn_layers: tuple = ()       # hymba: layers with full attention
    attn_logit_softcap: Optional[float] = None

    # ---- MoE ----
    n_experts: int = 0
    n_shared_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: Optional[int] = None       # per-expert hidden (qwen2-moe: 1408)
    shared_d_ff: Optional[int] = None    # shared-expert hidden
    router_aux_coef: float = 0.01
    capacity_factor: float = 1.25

    # ---- SSM / hybrid ----
    ssm_state: int = 0                   # mamba state per head (hymba: 16)
    block_pattern: tuple = ()            # xlstm: ("m","m","s","m",...) cycle
    mlstm_heads: Optional[int] = None

    # ---- encoder-decoder / modality ----
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    modality: str = "text"               # text | audio | vision
    n_prefix: int = 0                    # stub frame/patch embeddings length

    # ---- distribution ----
    backbone_tp: bool = True             # False: backbone FSDP/DP-only, head
                                         # stays label-sharded (small models
                                         # where TP shards are MXU-starved
                                         # and per-layer ARs dominate —
                                         # EXPERIMENTS.md SSPerf q1)

    # ---- head / misc ----
    head_type: str = "dismec"            # dismec | softmax
    ovr_C: float = 1.0                   # DiSMEC head C (Eq. 2.2)
    ovr_reg: float = 1e-6
    tie_embeddings: bool = False
    norm: str = "rmsnorm"                # rmsnorm | layernorm
    act: str = "silu"                    # silu (swiglu) | gelu
    dtype: str = "bfloat16"
    source: str = ""                     # citation

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        assert self.n_heads % self.n_kv_heads == 0, "GQA group size"

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def padded_vocab(self, mult: int = 512) -> int:
        """Vocab padded so the label axis shards evenly over `model`=16."""
        return ((self.vocab + mult - 1) // mult) * mult

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + blocks + head)."""
        d, f, V = self.d_model, self.d_ff, self.padded_vocab()
        n_attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        if self.family == "moe":
            fe = self.moe_d_ff or f
            per_expert = 3 * d * fe
            shared = self.n_shared_experts * 3 * d * (self.shared_d_ff or fe)
            n_mlp = self.n_experts * per_expert + shared + d * self.n_experts
        else:
            n_mlp = 3 * d * f
        if self.family == "ssm":
            # mLSTM: q/k/v + gates + out; rough but close enough for 6ND
            n_attn = 4 * d * d + 3 * d
            n_mlp = 3 * d * f if f else 2 * d * d
        n_block = n_attn + n_mlp + 2 * d
        n_layers = self.n_layers + self.n_encoder_layers
        return V * d + n_layers * n_block + V * d

    def active_param_count(self) -> int:
        """Params touched per token (MoE: routed top-k + shared only)."""
        if self.family != "moe":
            return self.param_count()
        d, V = self.d_model, self.padded_vocab()
        fe = self.moe_d_ff or self.d_ff
        n_attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        act_mlp = (self.moe_top_k * 3 * d * fe
                   + self.n_shared_experts * 3 * d * (self.shared_d_ff or fe))
        n_block = n_attn + act_mlp + 2 * d
        return V * d + self.n_layers * n_block + V * d


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One of the four assigned input shapes."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}
