"""seamless-m4t-medium — encoder-decoder speech/text model
[arXiv:2308.11596]. 12L d_model=1024 16H (MHA) d_ff=4096 vocab=256206.

Audio frontend (mel + conv) is a STUB per the brief: input_specs() provides
precomputed frame embeddings (B, n_prefix=1024, d). The backbone here is a
12L bidirectional encoder + 12L causal decoder with cross-attention.
long_500k is SKIPPED for this arch (DESIGN.md §Skips).
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-medium", family="audio", n_layers=12, d_model=1024,
    n_heads=16, n_kv_heads=16, d_ff=4096, vocab=256206,
    is_encoder_decoder=True, n_encoder_layers=12, modality="audio",
    n_prefix=1024, norm="layernorm", act="gelu", source="arXiv:2308.11596",
    backbone_tp=False,  # SSPerf q1 mechanism: d_model/16 TP shards are
    # MXU-starved; backbone goes data-parallel, the extreme head keeps its
    # label sharding (see EXPERIMENTS.md SSPerf pair 3)
)

SMOKE = ArchConfig(
    name="seamless-m4t-medium-smoke", family="audio", n_layers=2,
    d_model=128, n_heads=4, n_kv_heads=4, d_ff=256, vocab=512,
    is_encoder_decoder=True, n_encoder_layers=2, modality="audio",
    n_prefix=16, norm="layernorm", act="gelu", dtype="float32",
    source="arXiv:2308.11596",
)
