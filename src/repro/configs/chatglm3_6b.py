"""chatglm3-6b — RoPE 2d (rotary on half the head dims), GQA kv=2
[arXiv:2406.12793]. 28L d_model=4096 32H d_ff=13696 vocab=65024.
sliding_window=4096 is the --swa long-context *variant* only (swa_always=False).
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="chatglm3-6b", family="dense", n_layers=28, d_model=4096,
    n_heads=32, n_kv_heads=2, d_ff=13696, vocab=65024, rope_fraction=0.5,
    sliding_window=4096, source="arXiv:2406.12793",
)

SMOKE = ArchConfig(
    name="chatglm3-6b-smoke", family="dense", n_layers=2, d_model=256,
    n_heads=8, n_kv_heads=2, d_ff=512, vocab=512, rope_fraction=0.5,
    sliding_window=64, dtype="float32", source="arXiv:2406.12793",
)
