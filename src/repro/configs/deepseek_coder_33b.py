"""deepseek-coder-33b — llama-arch dense [arXiv:2401.14196].
62L d_model=7168 56H (GQA kv=8) d_ff=19200 vocab=32256."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-coder-33b", family="dense", n_layers=62, d_model=7168,
    n_heads=56, n_kv_heads=8, d_ff=19200, vocab=32256, sliding_window=4096,
    source="arXiv:2401.14196",
)

SMOKE = ArchConfig(
    name="deepseek-coder-33b-smoke", family="dense", n_layers=2,
    d_model=256, n_heads=8, n_kv_heads=2, d_ff=512, vocab=512,
    dtype="float32", source="arXiv:2401.14196",
)
