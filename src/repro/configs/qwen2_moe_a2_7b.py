"""qwen2-moe-a2.7b — 4 shared + 60 routed experts, top-4
[hf:Qwen/Qwen1.5-MoE-A2.7B]. 24L d_model=2048 16H (MHA kv=16)
per-expert d_ff=1408 vocab=151936."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-moe-a2.7b", family="moe", n_layers=24, d_model=2048,
    n_heads=16, n_kv_heads=16, d_ff=1408, vocab=151936, n_experts=60,
    n_shared_experts=4, moe_top_k=4, moe_d_ff=1408, shared_d_ff=5632,
    sliding_window=4096, source="hf:Qwen/Qwen1.5-MoE-A2.7B",
)

SMOKE = ArchConfig(
    name="qwen2-moe-a2.7b-smoke", family="moe", n_layers=2, d_model=128,
    n_heads=4, n_kv_heads=4, d_ff=64, vocab=512, n_experts=4,
    n_shared_experts=1, moe_top_k=2, moe_d_ff=64, shared_d_ff=128,
    dtype="float32", source="hf:Qwen/Qwen1.5-MoE-A2.7B",
)
