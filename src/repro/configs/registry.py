"""--arch <id> resolution for launch/train/dryrun/benchmarks."""

from __future__ import annotations

import importlib

from repro.configs.base import SHAPES, ArchConfig, ShapeConfig

_MODULES = {
    "xlstm-125m": "xlstm_125m",
    "chatglm3-6b": "chatglm3_6b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "qwen1.5-0.5b": "qwen1_5_0_5b",
    "internvl2-26b": "internvl2_26b",
    "qwen3-14b": "qwen3_14b",
    "hymba-1.5b": "hymba_1_5b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "mixtral-8x22b": "mixtral_8x22b",
}

ARCH_IDS = tuple(_MODULES)

# (arch, shape) pairs skipped with justification (DESIGN.md §Skips).
SKIPS = {
    ("seamless-m4t-medium", "long_500k"):
        "enc-dec speech model: 500k-token decode with cross-attention to the "
        "encoder memory is outside the architecture's operating regime",
}


def get_config(arch: str, smoke: bool = False) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.SMOKE if smoke else mod.CONFIG


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


def all_pairs():
    for arch in ARCH_IDS:
        for shape in SHAPES:
            if (arch, shape) in SKIPS:
                continue
            yield arch, shape
