"""mixtral-8x22b — 8 experts top-2, sliding-window attention
[arXiv:2401.04088]. 56L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=32768."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mixtral-8x22b", family="moe", n_layers=56, d_model=6144,
    n_heads=48, n_kv_heads=8, d_ff=16384, vocab=32768, n_experts=8,
    moe_top_k=2, moe_d_ff=16384, sliding_window=4096, swa_always=True,
    source="arXiv:2401.04088",
)

SMOKE = ArchConfig(
    name="mixtral-8x22b-smoke", family="moe", n_layers=2, d_model=128,
    n_heads=4, n_kv_heads=2, d_ff=128, vocab=512, n_experts=4, moe_top_k=2,
    moe_d_ff=128, sliding_window=32, swa_always=True, dtype="float32",
    source="arXiv:2401.04088",
)
