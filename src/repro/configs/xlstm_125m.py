"""xlstm-125m — sLSTM + mLSTM blocks [arXiv:2405.04517].

12L d_model=768 4H (GQA kv=4) d_ff=0 vocab=50304. Block pattern follows the
paper's xLSTM[7:1]-style mix: sLSTM at layers 5 and 11, mLSTM elsewhere.
d_ff=0: xLSTM blocks carry their own up-projections, no separate FFN sublayer.
"""

from repro.configs.base import ArchConfig

_PATTERN = tuple("s" if i in (5, 11) else "m" for i in range(12))

CONFIG = ArchConfig(
    name="xlstm-125m", family="ssm", n_layers=12, d_model=768, n_heads=4,
    n_kv_heads=4, d_ff=0, vocab=50304, mlstm_heads=4, block_pattern=_PATTERN,
    head_dim=192, source="arXiv:2405.04517",
    # SSPerf q1 mechanism, second attempt: plain-pjit backbone DP was
    # REFUTED (GSPMD all-reduced the sLSTM recurrent dW at EVERY bwd
    # timestep: 97 GB/step); with the sLSTM time scan now a shard_map
    # island (ssm.slstm: weights replicated, dW psum'd ONCE at the
    # boundary) the mechanism applies cleanly — see EXPERIMENTS.md.
    backbone_tp=False,
)

SMOKE = ArchConfig(
    name="xlstm-125m-smoke", family="ssm", n_layers=2, d_model=128,
    n_heads=4, n_kv_heads=4, d_ff=0, vocab=512, mlstm_heads=4,
    block_pattern=("m", "s"), head_dim=32, dtype="float32",
    source="arXiv:2405.04517",
)
