"""qwen1.5-0.5b — QKV bias [hf:Qwen/Qwen1.5-0.5B].
24L d_model=1024 16H (MHA kv=16) d_ff=2816 vocab=151936."""

from repro.configs.base import ArchConfig

# backbone_tp=False: a 0.46B backbone over a 16-way model axis gives
# 64-wide TP shards and 45 GB/step of layer all-reduces for 0.1 s of
# compute; the DiSMEC head (152k labels = 60% of params) keeps its label
# sharding. Measured in EXPERIMENTS.md SSPerf q1.
CONFIG = ArchConfig(
    name="qwen1.5-0.5b", family="dense", n_layers=24, d_model=1024,
    n_heads=16, n_kv_heads=16, d_ff=2816, vocab=151936, qkv_bias=True,
    sliding_window=4096, backbone_tp=False, source="hf:Qwen/Qwen1.5-0.5B",
)

SMOKE = ArchConfig(
    name="qwen1.5-0.5b-smoke", family="dense", n_layers=2, d_model=128,
    n_heads=4, n_kv_heads=4, d_ff=256, vocab=512, qkv_bias=True,
    dtype="float32", source="hf:Qwen/Qwen1.5-0.5B",
)
