from repro.train.trainer import TrainState, make_train_step, train_loop
from repro.train.xmc import (XMCTrainJob, XMCTrainResult,
                             train_demo_checkpoint, train_streaming)

__all__ = ["TrainState", "make_train_step", "train_loop",
           "XMCTrainJob", "XMCTrainResult", "train_streaming",
           "train_demo_checkpoint"]
