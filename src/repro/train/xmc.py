"""Streaming label-batch training engine for DiSMEC (Algorithm 1 at scale).

This module is the *engine* under the declarative session API: the public
way to train is

    from repro.xmc_api import XMCSpec, fit
    handle = fit(X, Y, XMCSpec(...), out_dir)        # -> CheckpointHandle
    engine = handle.engine()                          # -> serving XMCEngine

`fit()` builds an `XMCTrainJob` from the spec's `SolverSpec`/`ScheduleSpec`
and runs it here; the spec is embedded in the checkpoint manifest (both as
the resume fingerprint and as recoverable metadata), so the checkpoint
alone reproduces the experiment. `init_from=` warm-starts every batch's
TRON from a prior checkpoint's rows mapped back to label ranges.
`train_streaming` below is the deprecated pre-spec shim over the same
engine; `core.dismec.train/train_sharded` are the in-memory adapters.

The paper's model never exists dense — 870 GB of OvR weights become 3 GB of
(value, index) pairs via Delta-pruning (§2.2) — and this pipeline makes the
*training* side honor that: device memory is O(label_batch x D), the servable
artifact is written incrementally, and a killed job resumes where it stopped.

`XMCTrainJob` composes the two layers of Algorithm 1 with the streaming
writer; the mapping to the algorithm's steps 3-11:

  step 3    `for b in 0..B` over label batches   -> the host-side scheduler
            loop in `run()`. Batches are contiguous label ranges of size
            `cfg.label_batch` so the checkpoint streams in label order; the
            last partial batch is padded with all-negative sign rows so every
            batch shares one compiled solver executable.
  steps 4-6 dispatch batch b to a node, train its binary problems in
            parallel -> one mesh-sharded batched-TRON call
            (`core.dismec.make_batch_solver`): labels sharded over the mesh
            `model` axis (optionally instances over `data` with psum'd
            grad/Hv), each shard solved by one SIMT-style TRON loop.
            `balance=True` deals a batch's labels to shards with the
            frequency-balanced `balance_permutation` (the un-permutation is
            host-side, per batch), equalizing shard wall times.
  step 7    prune ambiguous weights  -> `prune` runs inside the jitted solve,
            on device, before the block ever travels to the host.
  steps 8-10 write batch b's sparse model file -> the pruned block lands on
            the host, is packed to append-form BSR
            (`to_block_sparse(row_block_offset=...)`) and appended to the
            multi-shard checkpoint by `checkpoint.io.BlockSparseWriter`
            (one shard .npz per batch + an atomically rewritten manifest).
            With `overlap=True` (default) this host leg runs on a bounded
            background worker: the scheduler dispatches batch b+1's solver
            (jax dispatch is asynchronous) before batch b's result has even
            left the device, so the device->host transfer + BSR pack +
            compressed shard write of batch b hide behind batch b+1's
            compute. `max_inflight` bounds how many un-drained device
            results may exist at once (device memory stays
            O(max_inflight x label_batch x D)); the single worker drains
            them strictly in dispatch order, so the manifest grows in
            exactly the sequential order and every crash/resume/manifest
            invariant below is unchanged (`overlap=False` restores the
            fully sequential scheduler).
  step 11   assemble W  -> never materialized during training. The manifest
            IS the model: `checkpoint.io.load_block_sparse` stitches the
            shards by row_ptr bookkeeping and PR 1's `XMCEngine` serves the
            result unchanged. (`materialize=True`, used by the in-memory
            `core.dismec.train` wrapper, assembles W host-side instead.)

Resume: the manifest lists finished batches; a restarted job skips them and
solves only the rest. A crash between a shard write and its manifest update
orphans one shard file, which the next run simply re-solves and overwrites.

Multi-host layer 1: with `ScheduleSpec(workers=N)` (or an explicit
`worker=` id), step 3's loop claims batches through the manifest's lease
table instead of walking them statically — N independent `fit()` processes
pointed at one `out_dir` cooperatively drain the label-batch queue into a
single checkpoint, exactly the paper's dispatch of batches to nodes. The
manifest's solver/schedule/data fingerprint gates every joiner, so
co-workers running a different spec (or different data) are rejected; a
worker that dies mid-batch is recovered by lease expiry (`lease_ttl`).
"""

from __future__ import annotations

import dataclasses
import os
import queue
import socket
import threading
import time
from typing import Callable, Iterable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.checkpoint.io import (BSR_ARRAYS, BlockSparseWriter,
                                 has_block_sparse_checkpoint,
                                 label_range_reader, load_block_sparse_meta)
from repro.core.dismec import (DiSMECConfig, DiSMECModel, balance_permutation,
                               make_batch_solver)
from repro.core.pruning import to_block_sparse
from repro.specs import ScheduleSpec, ServeSpec, SolverSpec

Array = jax.Array


def default_worker_id() -> str:
    """Identity of this trainer process in a cooperative multi-worker
    drain: unique per (host, process), stable for the process lifetime —
    what a batch lease records as its holder when the user does not pass
    an explicit `--worker-id`."""
    return f"{socket.gethostname()}-{os.getpid()}"


def _init_fingerprint(init_from: str) -> dict:
    """Content identity of a warm-start source. The solved weights depend
    on W0 (truncated Newton stops early), so a resumed warm run must not
    stitch shards seeded from a *different* prior model. A streamed source
    carries its own solver+data fingerprint in its manifest; a one-shot
    artifact has none, so its packed values are digested directly."""
    index = load_block_sparse_meta(init_from)
    if index.get("layout") == "stream":
        return {"solver": index["manifest"].get("solver"),
                "n_blocks": index["n_blocks"]}
    blocks = np.load(os.path.join(init_from, BSR_ARRAYS))["blocks"]
    return {"shape": list(index["shape"]), "n_blocks": index["n_blocks"],
            "nnz": int(np.count_nonzero(blocks)),
            "sum": float(blocks.sum()),
            "abs_sum": float(np.abs(blocks).sum())}


@dataclasses.dataclass
class XMCTrainResult:
    """What one `XMCTrainJob.run` did (and, if materialized, the model)."""
    model: Optional[DiSMECModel]   # only when materialize=True and complete
    out_dir: Optional[str]         # streamed checkpoint directory (if any)
    n_batches: int                 # total label batches of the job
    solved: list[int]              # batch ids solved by THIS run
    skipped: list[int]             # batch ids resumed from the manifest
    complete: bool                 # all batches present (checkpoint servable)
    manifest: Optional[dict]       # final manifest when streamed + complete


@dataclasses.dataclass(frozen=True)
class XMCTrainJob:
    """Algorithm 1's outer loop as a restartable streaming pipeline.

    cfg.label_batch sets the layer-1 batch size (the paper's per-node label
    count); when streaming to a checkpoint it must be a multiple of the BSR
    block height so per-batch blocks append without re-tiling. `mesh` turns
    on layer-2 mesh sharding for each batch's solve; `balance` deals each
    batch's labels to mesh shards frequency-balanced (no-op without a mesh).

    `overlap` double-buffers the loop: batch b+1's solve is dispatched
    before batch b's result is pulled off the device, and the
    transfer/pack/write leg runs on a background worker; a semaphore
    acquired before dispatch and released after the drain caps un-drained
    device results at `max_inflight` (see the module docstring). The
    produced checkpoint is byte-identical to a sequential
    (`overlap=False`) run.

    `workers > 1` (or an explicit `worker=` id to `run`) turns the static
    skip-finished loop into a lease-aware iterator over the shared
    manifest: each batch is atomically claimed before dispatch
    (`BlockSparseWriter.claim_next_batch`), held alive by a heartbeat
    thread while it solves, and released by its shard's manifest commit —
    so N independent processes pointed at the same `out_dir` drain one
    queue into one checkpoint (the paper's layer 1 over real nodes). A
    worker killed mid-batch is recovered when its lease outlives
    `lease_ttl`; a worker that exits cleanly (error, `max_batches`)
    releases its leases so co-workers reclaim immediately. Per-batch
    solves are deterministic, so the cooperative checkpoint is
    bit-identical to a single-worker one.
    """
    cfg: DiSMECConfig
    mesh: Optional[Mesh] = None
    label_axis: str = "model"
    data_axis: str = "data"
    shard_data: bool = False
    balance: bool = False
    block_shape: tuple[int, int] = (128, 128)
    overlap: bool = True
    max_inflight: int = 2
    workers: int = 1
    lease_ttl: float = 300.0

    def label_batches(self, n_labels: int) -> list[tuple[int, int]]:
        """Contiguous [start, stop) label ranges of the scheduler loop."""
        lb = min(self.cfg.label_batch, n_labels)
        return [(s, min(s + lb, n_labels)) for s in range(0, n_labels, lb)]

    def specs(self) -> tuple[SolverSpec, ScheduleSpec]:
        """This job as (SolverSpec, ScheduleSpec) — the adapter that lets
        every entry point (spec-driven or legacy) write one manifest
        format."""
        return SolverSpec.from_config(self.cfg), ScheduleSpec.from_job(self)

    def run(self, X: Array, Y: Array, out_dir: Optional[str] = None, *,
            resume: bool = True, materialize: Optional[bool] = None,
            max_batches: Optional[int] = None, meta: Optional[dict] = None,
            on_batch: Optional[Callable[[int, int], None]] = None,
            init_from: Optional[str] = None, worker: Optional[str] = None,
            label_order=None) -> XMCTrainResult:
        """Train X (N, D), Y (N, L) into `out_dir` (streamed multi-shard
        checkpoint) and/or an in-memory model.

        resume       : skip batches already listed in out_dir's manifest
                       (False starts the checkpoint fresh).
        materialize  : assemble the dense W host-side and return a
                       DiSMECModel; defaults to True only when not streaming.
        max_batches  : stop after solving this many new batches (the
                       checkpoint is left incomplete — the crash/preemption
                       story, used by tests and the resume benchmark).
        on_batch     : callback (batch_id, n_batches) after each solved
                       batch — progress reporting / instrumentation hooks.
                       With overlap=True it fires on the background writer
                       thread, still in batch order and still after that
                       batch's shard write; an exception it raises aborts
                       the run like a write failure would.
        init_from    : warm start — a prior block-sparse checkpoint whose
                       rows seed each batch's TRON as W0 (label ranges are
                       read shard-by-shard, never the full matrix; labels
                       past the prior model's L cold-start at zero). The
                       stopping tolerance stays anchored at the cold-start
                       gradient, so a converged same-spec source is a fixed
                       point: the solver accepts it unchanged.
        worker       : this process's identity in a cooperative multi-worker
                       drain (defaults to host-pid via `default_worker_id`).
                       Passing it — or setting `workers > 1` on the job —
                       switches the scheduler to lease-based batch claiming
                       over the shared manifest; `solved`/`on_batch` then
                       cover only the batches THIS worker claimed. A worker
                       with nothing left to claim sees the job through: it
                       polls (bounded ~1 s sleeps) until co-workers commit
                       their leases — or reclaims them when they expire, so
                       a dead co-worker's batches recover with no manual
                       step. `complete` is therefore True on every normal
                       cooperative return; False only when `max_batches`
                       cut this worker short or an error aborted the run.
                       Liveness caveat: a co-worker that is stuck alive
                       (still heartbeating, never committing) blocks
                       completion until an operator kills it and its lease
                       expires.
        label_order  : pack-time label permutation (len L): the run trains
                       and streams `Y[:, label_order]`, so packed row j of
                       the checkpoint holds original label label_order[j].
                       Recorded in the manifest (identity-checked on
                       resume, both directions) and unmapped exactly by
                       the serving engine. `fit()` computes it from
                       `ScheduleSpec.reorder_labels` via
                       `serve.shortlist.cooccurrence_label_order`.
        """
        Yn = np.asarray(Y)
        if label_order is not None:
            label_order = np.asarray(label_order, np.int64).reshape(-1)
            Yn = Yn[:, label_order]       # train/pack in permuted order
        N, L = Yn.shape
        D = int(X.shape[1])
        batches = self.label_batches(L)
        lb = batches[0][1] - batches[0][0]
        n_shards = self.mesh.shape[self.label_axis] if self.mesh else 1
        # Every batch is padded to one shape: lb rounded up to the label-shard
        # count, so the whole run compiles the solver exactly once.
        lb_solve = -(-lb // n_shards) * n_shards
        bl, bd = self.block_shape
        if materialize is None:
            materialize = out_dir is None
        init_read = None
        if init_from is not None:
            init_index = load_block_sparse_meta(init_from)
            init_D = init_index["orig_shape"][1]
            if init_D != D:
                raise ValueError(
                    f"init_from checkpoint has feature dim {init_D}, "
                    f"dataset has {D}; warm start needs matching features")
            # Built once: a one-shot source is densified a single time and
            # sliced per batch; a streamed source reads only the shards
            # each batch's range overlaps.
            init_read = label_range_reader(init_from)

        solver_spec, schedule_spec = self.specs()
        writer = None
        done: set[int] = set()
        if out_dir is not None:
            if lb % bl != 0 and len(batches) > 1:
                raise ValueError(
                    f"label_batch={lb} must be a multiple of the BSR block "
                    f"height {bl} to stream batches without re-tiling "
                    "(round label_batch up, or shrink block_shape — the "
                    "spec path, repro.xmc_api.fit, normalizes this "
                    "automatically)")
            # The solved weights depend on the full solver/schedule spec,
            # the dataset, and any warm-start source: record them so a
            # resumed run cannot silently mix shards trained under
            # different settings into one checkpoint.
            solver_id = {
                "spec": {"solver": solver_spec.fingerprint(),
                         "schedule": schedule_spec.fingerprint()},
                "init": (None if init_from is None
                         else _init_fingerprint(init_from)),
                "data": [int(N), int(D), float(np.asarray(X).sum()),
                         int(Yn.sum())]}
            # Full recoverable experiment description (adds the knobs the
            # fingerprint deliberately drops); fit() overrides this with
            # the user's spec, serve section included.
            meta_full = {"n_labels": L, "n_features": D,
                         "delta": self.cfg.delta, **(meta or {})}
            meta_full.setdefault("xmc_spec", {
                "solver": solver_spec.to_dict(),
                "schedule": schedule_spec.canonical().to_dict(),
                "serve": ServeSpec().to_dict()})
            writer = BlockSparseWriter(
                out_dir, n_labels=L, n_features=D,
                block_shape=self.block_shape, label_batch=lb,
                n_batches=len(batches), resume=resume, solver=solver_id,
                meta=meta_full, label_order=label_order)
            done = writer.done_batches

        X_dev = jnp.asarray(X, jnp.float32)
        solver = make_batch_solver(X_dev, self.cfg, self.mesh,
                                   label_axis=self.label_axis,
                                   data_axis=self.data_axis,
                                   shard_data=self.shard_data,
                                   warm=init_from is not None)

        host_blocks: dict[int, np.ndarray] = {}
        solved: list[int] = []
        skipped: list[int] = []

        # Multi-host layer 1: with a worker identity (explicit, or implied
        # by workers > 1) batches are claimed from the shared manifest's
        # lease table instead of walked statically.
        coordinate = writer is not None and (self.workers > 1
                                             or worker is not None)
        worker_id = worker or default_worker_id()
        held: set[int] = set()               # leases this worker holds now
        held_lock = threading.Lock()
        # First failure from the background drain worker (overlap mode).
        # Shared with leased_batches: the claim-wait loop must abort on it,
        # or a failed batch's still-held (and heartbeated) lease would keep
        # the loop waiting forever — wedging this worker AND every
        # co-worker behind the never-released lease.
        failed: list[BaseException] = []

        def dispatch(b: int, start: int, stop: int):
            """Host-side prep + asynchronous device dispatch of one batch."""
            rows = stop - start
            signs = (2.0 * Yn[:, start:stop].T - 1.0).astype(np.float32)
            perm = None
            if self.balance and self.mesh is not None and rows > n_shards:
                perm = balance_permutation(Yn[:, start:stop], n_shards)
                signs = signs[perm]
            W0 = None
            if init_read is not None:
                W0r = init_read(start, stop)
                if perm is not None:       # W0 rows follow the shard dealing
                    W0r = W0r[perm]
                if rows < lb_solve:
                    W0r = np.concatenate(
                        [W0r, np.zeros((lb_solve - rows, D), np.float32)])
                W0 = jnp.asarray(W0r)
            if rows < lb_solve:                           # shape-constant pad
                signs = np.concatenate(
                    [signs, -np.ones((lb_solve - rows, N), np.float32)])
            return b, start, rows, perm, solver(jnp.asarray(signs), W0)[:rows]

        def drain(item) -> None:
            """Device->host transfer + BSR pack + shard write of one solved
            batch (paper's steps 8-10) — the leg that overlaps batch b+1's
            device compute when `overlap=True`."""
            b, start, rows, perm, W_dev = item
            W_b = np.asarray(W_dev)
            if perm is not None:
                W_b = W_b[np.argsort(perm)]               # undo shard dealing
            if writer is not None:
                # device=False: the pack stays numpy end-to-end — a device
                # put here would queue behind the in-flight batch solves
                # this worker is meant to overlap.
                part = to_block_sparse(W_b, self.block_shape,
                                       row_block_offset=start // bl,
                                       sentinel_if_empty=False, device=False)
                # The manifest commit inside write_batch also releases
                # this batch's lease.
                writer.write_batch(b, part, row_start=start, n_rows=rows)
            with held_lock:
                held.discard(b)
            if materialize:
                host_blocks[b] = W_b
            solved.append(b)
            if on_batch is not None:
                on_batch(b, len(batches))

        def leased_batches() -> Iterable[tuple[int, int, int]]:
            """Lease-aware layer-1 iterator: claim the next unleased (or
            expired) batch from the shared manifest right before
            dispatching it; when everything left is leased by live
            co-workers, back off until the earliest lease could expire —
            normally its commit lands first and the queue reads drained,
            but a dead worker's batch is reclaimed here with no manual
            cleanup."""
            n_claimed = 0
            while max_batches is None or n_claimed < max_batches:
                if failed:                      # drain died: stop claiming
                    return
                with held_lock:
                    in_flight = set(held)
                b = writer.claim_next_batch(worker_id, ttl=self.lease_ttl,
                                            exclude=in_flight)
                if b is None:
                    wait = writer.claim_wait_seconds()
                    if wait is None:            # every batch is written
                        return
                    time.sleep(min(max(wait, 0.05), 1.0))
                    continue
                with held_lock:
                    held.add(b)
                n_claimed += 1
                yield (b, *batches[b])

        if coordinate:
            skipped.extend(sorted(done))                  # done before we ran
            if materialize:
                for b in skipped:
                    host_blocks[b] = writer.read_batch_dense(b)
            work_iter: Iterable[tuple[int, int, int]] = leased_batches()
        else:
            to_solve: list[tuple[int, int, int]] = []
            for b, (start, stop) in enumerate(batches):   # paper's step 3
                if b in done:
                    skipped.append(b)
                    if materialize:
                        host_blocks[b] = writer.read_batch_dense(b)
                    continue
                if max_batches is not None and len(to_solve) >= max_batches:
                    break
                to_solve.append((b, start, stop))
            work_iter = to_solve

        hb_stop = threading.Event()
        hb_thread = None
        if coordinate:
            # Leases must outlive arbitrarily long solves: refresh every
            # currently-held one well inside the TTL.
            def _heartbeat():
                interval = max(0.05, self.lease_ttl / 4.0)
                while not hb_stop.wait(interval):
                    with held_lock:
                        current = sorted(held)
                    try:
                        writer.heartbeat(worker_id, current)
                    except OSError:       # transient fs hiccup: next tick
                        pass
            hb_thread = threading.Thread(target=_heartbeat, daemon=True,
                                         name="xmc-lease-heartbeat")
            hb_thread.start()

        try:
            if not self.overlap:
                for item in work_iter:
                    drain(dispatch(*item))
            else:
                # Double-buffered: the main thread keeps dispatching solves;
                # a single background worker drains results in dispatch
                # order. A slot must be acquired BEFORE a batch is claimed
                # and dispatched, and is released only once its result is
                # fully drained, so at most max_inflight un-drained device
                # results (and held leases) exist at any moment.
                slots = threading.Semaphore(max(1, self.max_inflight))
                inflight: queue.Queue = queue.Queue()

                def _drain_loop():
                    while True:
                        item = inflight.get()
                        if item is None:
                            return
                        try:
                            if not failed:
                                drain(item)
                        except BaseException as e:   # propagate to main loop
                            failed.append(e)
                        finally:
                            slots.release()

                it = iter(work_iter)
                t = threading.Thread(target=_drain_loop, daemon=True,
                                     name="xmc-checkpoint-writer")
                t.start()
                try:
                    while True:
                        slots.acquire()
                        if failed:
                            slots.release()
                            break
                        item = next(it, None)
                        if item is None:
                            slots.release()
                            break
                        inflight.put(dispatch(*item))
                finally:
                    inflight.put(None)
                    t.join()
                if failed:
                    raise failed[0]
        finally:
            if coordinate:
                hb_stop.set()
                hb_thread.join()
                # Exit (clean or not) releases whatever is still held, so
                # co-workers reclaim now instead of waiting out the TTL.
                with held_lock:
                    leftover = sorted(held)
                writer.release_leases(worker_id, leftover)

        if coordinate:
            # Cooperative completion is a property of the shared manifest,
            # not of this worker's batches: whoever drains the last batch
            # finalizes (try_finalize is idempotent under the lock).
            manifest = writer.try_finalize()
            complete = manifest is not None
            if materialize and complete:
                for b in range(len(batches)):     # co-workers' batches
                    if b not in host_blocks:
                        host_blocks[b] = writer.read_batch_dense(b)
        else:
            complete = len(solved) + len(skipped) == len(batches)
            manifest = writer.finalize() if (writer and complete) else None
        model = None
        if materialize and complete:
            W = np.concatenate([host_blocks[b] for b in range(len(batches))])
            model = DiSMECModel(W=jnp.asarray(W), delta=self.cfg.delta,
                                n_labels=L)
        return XMCTrainResult(model=model, out_dir=out_dir,
                              n_batches=len(batches), solved=solved,
                              skipped=skipped, complete=complete,
                              manifest=manifest)


def train_streaming(X: Array, Y: Array, cfg: DiSMECConfig, out_dir: str,
                    **job_kwargs) -> XMCTrainResult:
    """DEPRECATED shim: stream-train into a servable multi-shard checkpoint.

    Use the declarative session API instead::

        from repro.xmc_api import XMCSpec, fit
        handle = fit(X, Y, XMCSpec(...), out_dir)

    This shim drives the exact same engine (`XMCTrainJob.run`), so the
    checkpoints it writes are bit-identical to `fit()`'s for an equivalent
    spec (tested in tests/test_xmc_api.py).
    """
    import warnings
    warnings.warn(
        "train_streaming is deprecated; build an XMCSpec and call "
        "repro.xmc_api.fit(X, Y, spec, out_dir) instead",
        DeprecationWarning, stacklevel=2)
    run_kwargs = {k: job_kwargs.pop(k)
                  for k in ("resume", "materialize", "max_batches", "meta",
                            "on_batch", "init_from") if k in job_kwargs}
    return XMCTrainJob(cfg=cfg, **job_kwargs).run(X, Y, out_dir, **run_kwargs)


def train_demo_checkpoint(ckpt_dir: str, *, n_train: int = 800,
                          n_test: int = 512, n_features: int = 4096,
                          n_labels: int = 256, label_batch: int = 128,
                          block_shape: tuple[int, int] = (128, 128),
                          data_kwargs: dict | None = None,
                          C: float = 1.0, delta: float = 0.01,
                          seed: int = 0, reuse: bool = True,
                          verbose: bool = True):
    """Train-and-checkpoint a small DiSMEC model for demos/benchmarks.

    The one shared setup behind `launch/serve.py --xmc`,
    `examples/serve_xmc.py` and `benchmarks/serve_latency.py`: builds the
    synthetic dataset, streams a model into `ckpt_dir` through `XMCTrainJob`
    (unless a servable checkpoint is already there and `reuse`), and returns
    `(dataset, index)` where `index` is the checkpoint's pre-flight metadata
    (`checkpoint.io.load_block_sparse_meta`). `block_shape` sets the BSR
    tile — the shortlist serving benchmark passes a finer block height so
    the demo model has enough row blocks for a meaningful candidate stage.
    `data_kwargs` forwards extra knobs to `make_xmc_dataset` (e.g.
    pool_stride / label_locality for a cluster-ordered label space).
    """
    from repro.data.xmc import make_xmc_dataset       # deferred: keep light
    data = make_xmc_dataset(n_train=n_train, n_test=n_test,
                            n_features=n_features, n_labels=n_labels,
                            seed=seed, **(data_kwargs or {}))
    if not (reuse and has_block_sparse_checkpoint(ckpt_dir)):
        if verbose:
            print(f"[xmc] no servable checkpoint at {ckpt_dir}; streaming a "
                  f"{n_labels}-label model in batches of {label_batch}...")
        from repro.xmc_api import XMCSpec, fit            # deferred: no cycle
        spec = XMCSpec(solver=SolverSpec(C=C, delta=delta),
                       schedule=ScheduleSpec(label_batch=label_batch,
                                             block_shape=tuple(block_shape)))
        fit(jnp.asarray(data.X_train), jnp.asarray(data.Y_train), spec,
            ckpt_dir)
        if verbose:
            index = load_block_sparse_meta(ckpt_dir)
            print(f"[xmc] saved sparse checkpoint: {index['n_blocks']} "
                  "blocks across "
                  f"{len(index['manifest']['shards'])} shards")
    return data, load_block_sparse_meta(ckpt_dir)
