"""Training loop: value_and_grad + AdamW + gradient accumulation.

`make_train_step` builds the jittable step the dry-run lowers for train_4k:
  * microbatching — global batch split into `accum` microbatches scanned with
    f32 gradient accumulation (memory: one microbatch of activations at a
    time; required for the 33B/141B assigned configs, DESIGN.md §5);
  * the DiSMEC OvR head loss needs no logits collective (core/head.py) —
    the gradient all-reduce over (pod, data) is inserted by GSPMD from the
    FSDP in_shardings.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.optim import AdamWState, adamw_init, adamw_update

Array = jax.Array


@dataclasses.dataclass
class TrainState:
    params: Any
    opt: AdamWState
    step: Array


def init_train_state(params) -> TrainState:
    return TrainState(params=params, opt=adamw_init(params),
                      step=jnp.zeros((), jnp.int32))


def make_train_step(model, *, lr_fn: Callable, mesh=None, batch_axes=(),
                    accum: int = 1, weight_decay: float = 0.1,
                    clip_norm: float = 1.0):
    """Returns train_step(params, opt, step, batch) -> (params, opt, metrics).

    With accum > 1, every leaf of `batch` must have leading dims
    (accum, micro_batch, ...).
    """

    def loss_fn(params, batch):
        loss, metrics = model.train_loss(params, batch, mesh=mesh,
                                         batch_axes=batch_axes)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt, step, batch):
        if accum == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            def micro(carry, mb):
                g_acc, l_acc = carry
                (l, _), g = grad_fn(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (g_acc, l_acc + l), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            (grads, loss), _ = jax.lax.scan(micro, (g0, jnp.zeros(())), batch)
            grads = jax.tree.map(lambda g: g / accum, grads)
            loss = loss / accum
            metrics = {}
        lr = lr_fn(step)
        params, opt, om = adamw_update(params, grads, opt, lr,
                                       weight_decay=weight_decay,
                                       clip_norm=clip_norm)
        out = {"loss": loss, "lr": lr, **om}
        out.update({k: v for k, v in metrics.items() if k != "loss"})
        return params, opt, out

    return train_step


def train_loop(model, params, batches, *, steps: int, lr: float = 3e-4,
               warmup: int = 20, log_every: int = 10, mesh=None,
               batch_axes=()) -> tuple[Any, list[dict]]:
    """Simple single-host loop used by examples/ and smoke tests."""
    from repro.optim.schedules import linear_warmup_cosine
    lr_fn = linear_warmup_cosine(lr, warmup, steps)
    step_fn = jax.jit(make_train_step(model, lr_fn=lr_fn, mesh=mesh,
                                      batch_axes=batch_axes))
    opt = adamw_init(params)
    history = []
    step = jnp.zeros((), jnp.int32)
    for i in range(steps):
        batch = next(batches)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt, metrics = step_fn(params, opt, step, batch)
        step = step + 1
        if i % log_every == 0 or i == steps - 1:
            rec = {k: float(v) for k, v in metrics.items()}
            rec["step"] = i
            history.append(rec)
    return params, history
