"""Public wrapper for the Hessian-vector-product kernel: padding, bounds,
fallback — the same arbitrary-shape contract as the hinge wrapper, so the
Pallas training path works on any (L, N, D) instead of silently requiring
tile-aligned inputs (the raw `hvp_pallas` rejects those loudly)."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.hvp import ref
from repro.kernels.hvp.kernel import MAX_FUSED_D, hvp_pallas


def _pad_to(x: jax.Array, axis: int, mult: int, value: float = 0.0):
    n = x.shape[axis]
    p = (-n) % mult
    if p == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, p)
    return jnp.pad(x, pad, constant_values=value)


@partial(jax.jit, static_argnames=("C", "bl", "bn", "interpret"))
def hessian_vp(V: jax.Array, X: jax.Array, act: jax.Array, C: float,
               *, bl: int = 128, bn: int = 128,
               interpret: bool | None = None) -> jax.Array:
    """Hv for all labels, any (L, N, D): pads every axis to its tile
    multiple. Padded instances have x = 0 and act = 0, so their contribution
    is exactly zero; padded label rows are sliced away. `act` is the cached
    mask from the hinge kernel's `objective_grad_act` (or any (L, N) float
    mask)."""
    L, D = V.shape
    N = X.shape[0]
    if act.shape != (L, N):
        raise ValueError(
            f"act must be the (L, N) = {(L, N)} active mask matching V/X; "
            f"got {act.shape} — pass the mask emitted by "
            "kernels.hinge.ops.objective_grad_act at the same iterate")
    if D > MAX_FUSED_D:
        return ref.hessian_vp(V, X, act, C)
    Vp = _pad_to(V, 0, bl)
    Xp = _pad_to(X, 0, bn)
    Ap = _pad_to(_pad_to(act, 0, bl), 1, bn)
    out = hvp_pallas(Vp, Xp, Ap, C, bl=bl, bn=bn, interpret=interpret)
    return out[:L]
